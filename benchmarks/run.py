"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes JSON payloads to
results/bench/.  Roofline analysis over the dry-run artifacts is
``python -m benchmarks.roofline [results/dryrun]``.
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (
        bench_adder,
        bench_anneal,
        bench_kernel,
        bench_learning,
        bench_maxcut,
        bench_table1,
        bench_tempering,
        bench_variability,
    )

    print("name,us_per_call,derived")
    bench_table1.run()        # Table 1: throughput/comparison
    bench_kernel.run()        # kernel traffic model
    bench_variability.run()   # Fig 8a
    bench_anneal.run()        # Fig 9a
    bench_maxcut.run()        # Fig 9b
    bench_tempering.run()     # beyond-paper: PT vs SA
    bench_learning.run()      # Fig 7b/c (slowest: CD training)
    bench_adder.run()         # Fig 8b
    print("done", file=sys.stderr)


if __name__ == "__main__":
    main()
