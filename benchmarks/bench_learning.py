"""Paper Fig 7b/7c: AND-gate Boltzmann learning on the mismatched chip.

Reports KL(target||model) and correlation error vs epoch, plus the central
hardware-aware-vs-transfer comparison (in-situ learning absorbs mismatch).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json
from repro.core import energy, tasks
from repro.core.cd import CDConfig, PBitMachine, sample_visible_dist, train_cd
from repro.core.chimera import make_chimera
from repro.core.hardware import HardwareConfig

CFG = CDConfig(lr=6.0, cd_k=15, pos_sweeps=15, burn_in=3, chains=256,
               epochs=80)


def run() -> dict:
    g = make_chimera(1, 1)
    task = tasks.and_gate_task(g)
    chip_key = jax.random.PRNGKey(42)

    t0 = time.perf_counter()
    real = PBitMachine.create(g, chip_key, HardwareConfig(), beta=1.0,
                              w_scale=0.05)
    res_real = train_cd(real, task.visible_idx, task.target_dist, CFG,
                        jax.random.PRNGKey(7), eval_every=10)
    t_insitu = time.perf_counter() - t0

    ideal = PBitMachine.create(g, chip_key, HardwareConfig.ideal(),
                               beta=1.0, w_scale=0.05)
    res_ideal = train_cd(ideal, task.visible_idx, task.target_dist, CFG,
                         jax.random.PRNGKey(7), eval_every=CFG.epochs)

    kl_transfer = energy.kl_divergence(
        task.target_dist,
        sample_visible_dist(real, jnp.asarray(res_ideal.Jm),
                            jnp.asarray(res_ideal.hm), task.visible_idx,
                            jax.random.PRNGKey(3)))
    out = {
        "kl_vs_epoch": res_real.kl_history,
        "corr_err_first5": float(np.mean(
            [m["corr_err"] for m in res_real.metric_history[:5]])),
        "corr_err_last5": float(np.mean(
            [m["corr_err"] for m in res_real.metric_history[-5:]])),
        "kl_insitu_final": res_real.kl_history[-1][1],
        "kl_ideal_weights_on_mismatched_chip": kl_transfer,
        "epochs": CFG.epochs,
        "train_seconds": t_insitu,
    }
    save_json("fig7_and_gate", out)
    us = t_insitu / CFG.epochs * 1e6
    emit("fig7_and_gate_cd_epoch", us,
         f"KL_insitu={out['kl_insitu_final']:.3f};"
         f"KL_transfer={kl_transfer:.3f}")
    return out


if __name__ == "__main__":
    run()
