"""Paper Fig 8a: node-to-node variability — <m> vs bias-DAC sweep."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json
from repro.core import pbit
from repro.core.cd import PBitMachine
from repro.core.chimera import make_chip_graph
from repro.core.hardware import HardwareConfig

BIASES = np.arange(-100, 101, 20)


def run() -> dict:
    g = make_chip_graph()
    machine = PBitMachine.create(g, jax.random.PRNGKey(8),
                                 HardwareConfig(), beta=1.0, w_scale=0.02)
    t0 = time.perf_counter()
    curves = []
    for b in BIASES:
        chip = machine.program(jnp.zeros((g.n_nodes, g.n_nodes), jnp.int32),
                               jnp.full((g.n_nodes,), int(b), jnp.int32))
        m0 = pbit.random_spins(jax.random.PRNGKey(0), 64, g.n_nodes)
        ns, nf = machine.noise_fn(jax.random.PRNGKey(1), 64)
        mean_s, _, _, _ = pbit.gibbs_stats(
            chip, jnp.asarray(g.color), m0, 1.0, 100, 20, ns, nf,
            jnp.asarray(g.edges))
        curves.append(np.asarray(mean_s))
    dt = time.perf_counter() - t0
    curves = np.stack(curves)            # (n_bias, 440)
    mid = len(BIASES) // 2
    spread = curves.std(axis=1)
    out = {
        "biases": BIASES.tolist(),
        "mean_activation": curves.mean(axis=1).tolist(),
        "node_spread_per_bias": spread.tolist(),
        "max_node_spread": float(spread.max()),
        "n_nodes": int(g.n_nodes),
    }
    save_json("fig8a_variability", out)
    emit("fig8a_bias_sweep_point", dt / len(BIASES) * 1e6,
         f"max_spread={out['max_node_spread']:.3f}")
    return out


if __name__ == "__main__":
    run()
