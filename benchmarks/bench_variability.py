"""Paper Fig 8a variability sweep + fault-yield curves.

``fig8a``: node-to-node variability — <m> vs bias-DAC sweep (unchanged).

``fault_yield``: the robustness benchmark.  For each fault rate we draw K
virtual chips (independent mismatch + independent `api.sample_faults`
draw), run short in-situ CD on the AND-gate task, and count the fraction
of chips whose KL to the target reaches the yield threshold.  This is the
manufacturing-yield question for a p-bit accelerator: how many fabricated
dies with stuck p-bits / dead couplers can hardware-aware learning still
train around?  Rows land in the tracked ``fault_yield`` section of the
repo-root ``BENCH_kernel.json`` (non-quick runs only; merge-preserving,
see bench_kernel.py).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json
from repro import api
from repro.core import pbit, tasks
from repro.core.cd import CDConfig, PBitMachine, train_cd
from repro.core.chimera import make_chimera, make_chip_graph
from repro.core.hardware import HardwareConfig

BIASES = np.arange(-100, 101, 20)

YIELD_KL = 0.35          # a chip "yields" if CD reaches this KL
FAULT_RATES = (0.0, 0.05, 0.1, 0.2)


def run_fig8a() -> dict:
    g = make_chip_graph()
    machine = PBitMachine.create(g, jax.random.PRNGKey(8),
                                 HardwareConfig(), beta=1.0, w_scale=0.02)
    t0 = time.perf_counter()
    curves = []
    for b in BIASES:
        chip = machine.program(jnp.zeros((g.n_nodes, g.n_nodes), jnp.int32),
                               jnp.full((g.n_nodes,), int(b), jnp.int32))
        m0 = pbit.random_spins(jax.random.PRNGKey(0), 64, g.n_nodes)
        ns, nf = machine.noise_fn(jax.random.PRNGKey(1), 64)
        mean_s, _, _, _ = pbit.gibbs_stats(
            chip, jnp.asarray(g.color), m0, 1.0, 100, 20, ns, nf,
            jnp.asarray(g.edges))
        curves.append(np.asarray(mean_s))
    dt = time.perf_counter() - t0
    curves = np.stack(curves)            # (n_bias, 440)
    spread = curves.std(axis=1)
    out = {
        "biases": BIASES.tolist(),
        "mean_activation": curves.mean(axis=1).tolist(),
        "node_spread_per_bias": spread.tolist(),
        "max_node_spread": float(spread.max()),
        "n_nodes": int(g.n_nodes),
    }
    save_json("fig8a_variability", out)
    emit("fig8a_bias_sweep_point", dt / len(BIASES) * 1e6,
         f"max_spread={out['max_node_spread']:.3f}")
    return out


def run_fault_yield(quick: bool = False) -> dict:
    """Yield (fraction of virtual chips reaching YIELD_KL) vs fault rate."""
    g = make_chimera(1, 1)
    task = tasks.and_gate_task(g)
    n_chips = 2 if quick else 8
    rates = FAULT_RATES[:2] if quick else FAULT_RATES
    cfg = (CDConfig(epochs=6, chains=64, cd_k=4, pos_sweeps=4, burn_in=1)
           if quick else
           CDConfig(lr=6.0, cd_k=15, pos_sweeps=15, burn_in=3,
                    chains=256, epochs=50))
    rows = []
    t0 = time.perf_counter()
    for rate in rates:
        kls = []
        for chip_id in range(n_chips):
            faults = api.sample_faults(
                1000 * chip_id + int(rate * 1e4) + 1, g,
                stuck_rate=rate, dead_rate=rate,
                exclude_nodes=task.visible_idx)
            machine = PBitMachine.create(
                g, jax.random.PRNGKey(chip_id), HardwareConfig(),
                noise="counter", beta=1.0, w_scale=0.05, faults=faults)
            res = train_cd(machine, task.visible_idx, task.target_dist,
                           cfg, jax.random.PRNGKey(100 + chip_id),
                           eval_every=cfg.epochs)
            kls.append(float(res.kl_history[-1][1]))
        n_ok = sum(1 for k in kls if k < YIELD_KL)
        rows.append({"fault_rate": float(rate), "n_chips": n_chips,
                     "n_yielding": n_ok, "yield": n_ok / n_chips,
                     "kl_threshold": YIELD_KL,
                     "kls": [round(k, 4) for k in kls]})
        emit("fault_yield", (time.perf_counter() - t0) * 1e6,
             f"rate={rate} yield={n_ok}/{n_chips}")
    out = {"task": "and_gate", "graph": "chimera_1x1", "quick": quick,
           "epochs": cfg.epochs, "rows": rows}
    save_json("fault_yield", out)
    return out


def run(quick: bool = False) -> dict:
    results = {"fig8a": run_fig8a(), "fault_yield": run_fault_yield(quick)}
    if not quick:
        # tracked robustness trajectory: merge our section into the root
        # BENCH_kernel.json without clobbering bench_kernel's sections
        root = Path(__file__).resolve().parent.parent / "BENCH_kernel.json"
        merged = json.loads(root.read_text()) if root.exists() else {}
        merged["fault_yield"] = results["fault_yield"]
        root.write_text(json.dumps(merged, indent=1))
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small fleet / short training (CI smoke)")
    args = ap.parse_args()
    run(quick=args.quick)
