"""Beyond-paper: parallel tempering vs simulated annealing on the SK glass.

The chip exposes one global V_temp; a replica-exchange controller (R chips
or R passes + energy readout) is a natural system extension.  Equal sweep
budget per replica/chain.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit, save_json
from repro.core.annealing import AnnealConfig, anneal, sk_instance
from repro.core.cd import PBitMachine
from repro.core.chimera import make_chip_graph
from repro.core.hardware import HardwareConfig
from repro.core.tempering import PTConfig, parallel_tempering


def run() -> dict:
    g = make_chip_graph()
    machine = PBitMachine.create(g, jax.random.PRNGKey(3),
                                 HardwareConfig(), w_scale=0.02)
    J, h = sk_instance(g, jax.random.PRNGKey(4))

    sa = anneal(machine, J, h,
                AnnealConfig(n_sweeps=600, beta_start=0.02, beta_end=3.0,
                             chains=16),
                jax.random.PRNGKey(5))
    t0 = time.perf_counter()
    pt = parallel_tempering(
        machine, J, h,
        PTConfig(n_replicas=16, n_sweeps=600, swap_every=10),
        jax.random.PRNGKey(5))
    dt = time.perf_counter() - t0
    out = {
        "sa_best_energy": sa["best_energy"],
        "pt_best_energy": pt["best_energy"],
        "pt_swap_rate": pt["swap_rate"],
        "improvement_pct": 100.0 * (sa["best_energy"] - pt["best_energy"])
        / abs(sa["best_energy"]),
        "equal_budget_sweeps_x_chains": 600 * 16,
        "seconds": dt,
    }
    save_json("ext_parallel_tempering", out)
    emit("ext_pt_vs_sa_600sweeps", dt * 1e6,
         f"PT={pt['best_energy']:.0f};SA={sa['best_energy']:.0f}")
    return out


if __name__ == "__main__":
    run()
