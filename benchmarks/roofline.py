"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, in seconds:
    compute    = HLO_FLOPs            / (peak_FLOP/s per chip)
    memory     = HLO_bytes_accessed   / (HBM bytes/s per chip)
    collective = collective_bytes     / (ICI bytes/s per link)

cost_analysis() runs on the *partitioned* module, so FLOPs/bytes are
per-device already.  collective_bytes is NOT in cost_analysis — we parse the
compiled HLO text: every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute op contributes its (per-device, post-SPMD)
payload bytes times an op-specific ring factor, times the trip count of any
enclosing while loop (scan bodies execute num_layers times — counting them
once would undercount collectives ~60x on a deepseek-67b).

Trip counts are recovered from each while's condition computation (the loop
bound is the max integer literal in the compare), and multipliers compose
through the call graph (nested scans multiply).
"""
from __future__ import annotations

import json
import re
import sys
from pathlib import Path

import numpy as np

PEAK_FLOPS = 197e12      # TPU v5e bf16
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(text: str) -> int:
    """Sum bytes of every array shape literal in `text`."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int = 2) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota format [ngroups,group_size]
        return int(m.group(2))
    return default


def _ring_factor(op: str, g: int) -> float:
    if op == "all-reduce":
        return 2.0 * (g - 1) / g
    if op in ("all-gather", "all-to-all"):
        return (g - 1) / g
    if op == "reduce-scatter":
        return float(g - 1)   # result shape is the scattered (small) shard
    return 1.0                # collective-permute


def parse_computations(hlo: str) -> dict[str, list[str]]:
    """computation name -> list of op lines.

    A computation header is a line-initial `%name (...) -> ... {` or
    `ENTRY %name ... {`; nested parens in tuple-typed params make a regex
    over the param list unreliable, so we key off the opening brace only.
    """
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        s = line.strip()
        if cur is None:
            if not s.endswith("{"):
                continue
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", s)
            if m and not line.startswith(" "):
                cur = m.group(1)
                comps[cur] = []
            continue
        if s == "}":
            cur = None
        elif s:
            comps[cur].append(s)
    return comps


def _while_info(comps: dict[str, list[str]]):
    """[(body, cond, trip)] for every while op found."""
    infos = []
    for lines in comps.values():
        for ln in lines:
            if " while(" in ln:
                mb = re.search(r"body=%?([\w\.\-]+)", ln)
                mc = re.search(r"condition=%?([\w\.\-]+)", ln)
                if not (mb and mc):
                    continue
                trip = 1
                cond_lines = comps.get(mc.group(1), [])
                consts = []
                for cl in cond_lines:
                    consts += [int(x) for x in
                               re.findall(r"constant\((\d+)\)", cl)]
                if consts:
                    trip = max(consts)
                infos.append((mb.group(1), mc.group(1), max(trip, 1)))
    return infos


def _call_multipliers(comps: dict[str, list[str]]) -> dict[str, int]:
    """computation -> product of enclosing while trip counts."""
    whiles = _while_info(comps)
    body_trip = {b: t for b, _, t in whiles}
    # call graph: comp -> comps it invokes (calls/to_apply/body/condition).
    # One name per keyword — a greedy multi-name tail would swallow the
    # following ", body=..." keyword and drop the loop-body edge entirely.
    edge_re = re.compile(r"(?:to_apply|calls|body|condition)=%?([\w\.\-]+)")
    list_re = re.compile(r"branch_computations=\{([^}]*)\}")
    calls: dict[str, set[str]] = {c: set() for c in comps}
    for c, lines in comps.items():
        for ln in lines:
            for m in edge_re.finditer(ln):
                calls[c].add(m.group(1))
            for m in list_re.finditer(ln):
                for name in m.group(1).split(","):
                    calls[c].add(name.strip().lstrip("%"))
    mult: dict[str, int] = {}

    # roots: the real entry ("main*") when present — dead loop clones left
    # behind by loop transformations must NOT be visited, or their dots and
    # collectives get phantom-counted
    roots = [c for c in comps if c.startswith("main")] or \
        [c for c in comps if not any(c in v for v in calls.values())]

    def visit(comp: str, m: int):
        if comp not in comps:
            return
        if mult.get(comp, 0) >= m:
            return
        mult[comp] = max(mult.get(comp, 0), m)
        for callee in calls.get(comp, ()):
            mm = m * body_trip.get(callee, 1)
            visit(callee, mm)

    for e in roots:
        visit(e, 1)
    return mult


def _shape_dims(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype in _DTYPE_BYTES:
            out.append((dtype,
                        [int(d) for d in dims.split(",")] if dims else []))
    return out


_DOT_DIMS_RE = {
    k: re.compile(rf"{k}={{([\d,]*)}}")
    for k in ("lhs_batch_dims", "lhs_contracting_dims",
              "rhs_batch_dims", "rhs_contracting_dims")
}


def dot_flops_from_hlo(hlo: str) -> float:
    """Trip-count-aware MAC count of every `dot` in the compiled module.

    CPU cost_analysis counts a while-loop body ONCE, so a 95-layer scanned
    model reports ~1/95th of its real FLOPs; this walks the call graph with
    the same trip multipliers as the collective parser and computes
    2·batch·M·N·K per dot from the operand shapes.
    """
    comps = parse_computations(hlo)
    mult = _call_multipliers(comps)
    total = 0.0
    for comp, lines in comps.items():
        if comp not in mult:
            continue  # unreachable (dead loop clone) — do not count
        m = mult[comp]
        # shape table for this computation (every op line defines its shape)
        shapes: dict[str, list[int]] = {}
        for ln in lines:
            mm = re.match(r"(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*([^\s]+)", ln)
            if mm:
                dims = _shape_dims(mm.group(2))
                if dims:
                    shapes[mm.group(1)] = dims[0][1]
        for ln in lines:
            if " dot(" not in ln:
                continue
            ops = re.search(r"dot\(([^)]*)\)", ln)
            if not ops:
                continue
            names = [o.strip().lstrip("%") for o in ops.group(1).split(",")]
            if len(names) < 2:
                continue
            lhs = shapes.get(names[0])
            rhs = shapes.get(names[1])
            if lhs is None or rhs is None:
                continue
            dims = {k: ([int(x) for x in r.search(ln).group(1).split(",")]
                        if r.search(ln) and r.search(ln).group(1) else [])
                    for k, r in _DOT_DIMS_RE.items()}
            K = int(np.prod([lhs[i] for i in
                             dims["lhs_contracting_dims"]])) \
                if dims["lhs_contracting_dims"] else 1
            Bt = int(np.prod([lhs[i] for i in dims["lhs_batch_dims"]])) \
                if dims["lhs_batch_dims"] else 1
            M = int(np.prod(lhs)) // max(K * Bt, 1)
            N = int(np.prod(rhs)) // max(K * Bt, 1)
            total += 2.0 * Bt * M * N * K * m
    return total


def collective_bytes_from_hlo(hlo: str) -> dict:
    """Sum per-device collective payload bytes, trip-count aware."""
    comps = parse_computations(hlo)
    mult = _call_multipliers(comps)
    per_op: dict[str, float] = {}
    total = 0.0
    raw = 0.0
    for comp, lines in comps.items():
        if comp not in mult:
            continue  # unreachable (dead loop clone)
        m = mult[comp]
        for ln in lines:
            for op in _COLLECTIVES:
                # "%x = TYPE op(...)" — match op name as the instruction
                if re.search(rf"=\s*[^=]*\b{op}\(", ln) or \
                        re.search(rf"\b{op}(?:\.\d+)?\s*=", ln) or \
                        f" {op}(" in ln:
                    lhs = ln.split("=", 1)[-1]
                    lhs = lhs.split(op + "(", 1)[0]
                    size = _shape_bytes(lhs)
                    g = _group_size(ln)
                    b = size * _ring_factor(op, g) * m
                    per_op[op] = per_op.get(op, 0.0) + b
                    total += b
                    raw += size
                    break
    return {"total_bytes": total, "raw_result_bytes": raw,
            "per_op_bytes": per_op}


# ---------------------------------------------------------------------------
# Report over dry-run JSON records
# ---------------------------------------------------------------------------
def analytic_hbm_bytes(rec: dict) -> float:
    """Per-device HBM traffic model for one step (TPU fusion assumed).

    CPU cost_analysis' "bytes accessed" is pre-fusion (every op's operands
    re-counted) and misses loop trip counts, so the memory term comes from
    an explicit model instead:

      train:   3x param reads (fwd + remat re-fwd + bwd) + grad write
               + AdamW state read/write (2 moments, f32, r+w)
               + activation streams: C_ACT x L x tokens x D (fwd+bwd)
               + CE logits (chunked): 2 passes over tokens x V_local x f32
      prefill: 1x param read + C_ACT/2 activation streams + KV-cache write
      decode:  active-param read + full KV/state-cache read + write of 1 tok
    """
    dev = max(rec.get("n_devices", 1), 1)
    P = rec.get("params", 0) / dev            # per-device param count
    P_act = rec.get("active_params", 0) / dev
    kind = rec.get("kind")
    B = rec.get("global_batch", 0)
    S = rec.get("seq_len", 0)
    # batch shards over pod x data = dev/16 (model axis = 16)
    toks_loc = B * S / max(dev / 16, 1) if kind != "decode" else \
        B * 1 / max(dev / 16, 1)
    arch = rec.get("arch", "")
    D = {"jamba-v0.1-52b": 4096, "deepseek-67b": 8192, "gemma2-9b": 3584,
         "qwen1.5-110b": 8192, "gemma2-2b": 2304, "whisper-tiny": 384,
         "qwen2-vl-72b": 8192, "granite-moe-1b-a400m": 1024,
         "kimi-k2-1t-a32b": 7168, "rwkv6-3b": 2560}.get(arch, 4096)
    L = {"jamba-v0.1-52b": 32, "deepseek-67b": 95, "gemma2-9b": 42,
         "qwen1.5-110b": 80, "gemma2-2b": 26, "whisper-tiny": 8,
         "qwen2-vl-72b": 80, "granite-moe-1b-a400m": 24,
         "kimi-k2-1t-a32b": 61, "rwkv6-3b": 32}.get(arch, 32)
    V_loc = {"jamba-v0.1-52b": 65536, "deepseek-67b": 102400,
             "gemma2-9b": 256000, "qwen1.5-110b": 152064,
             "gemma2-2b": 256000, "whisper-tiny": 51865,
             "qwen2-vl-72b": 152064, "granite-moe-1b-a400m": 49155,
             "kimi-k2-1t-a32b": 163840, "rwkv6-3b": 65536}.get(
        arch, 65536) / 16
    C_ACT = 16  # activation stream r/w coefficient per layer (fwd+bwd)
    if kind == "train":
        return (3 * P * 2 + P * 2          # param reads + grad write
                + P * 4 * 2 * 2            # mu, nu f32 read+write
                + C_ACT * L * toks_loc * D * 2
                + 2 * toks_loc * V_loc * 4)
    if kind == "prefill":
        cache = toks_loc * D * 2 * 2       # K+V bf16 write
        return P * 2 + (C_ACT / 2) * L * toks_loc * D * 2 + cache
    # decode: stream active params + the whole cache once
    cache_bytes = rec.get("memory", {}).get("argument_bytes", 0) - P * 10
    cache_bytes = max(cache_bytes, 0)
    return P_act * 2 + cache_bytes + toks_loc * D * 2 * L


def roofline_row(rec: dict) -> dict:
    cost = rec.get("cost", {})
    flops = rec.get("dot_flops") or cost.get("flops", 0.0)
    coll = rec.get("collectives", {}).get("total_bytes", 0.0)
    bytes_model = analytic_hbm_bytes(rec)
    t_c = flops / PEAK_FLOPS
    t_m = bytes_model / HBM_BW
    t_i = coll / ICI_BW
    dominant = max((t_c, "compute"), (t_m, "memory"), (t_i, "collective"))
    # model FLOPs: 6 * N_active * tokens for train, 2 * N_active * tokens
    # for inference steps (per device)
    n_act = rec.get("active_params", 0)
    toks = rec.get("global_batch", 0) * (
        rec.get("seq_len", 0) if rec.get("kind") in ("train", "prefill")
        else 1)
    factor = 6 if rec.get("kind") == "train" else 2
    model_flops = factor * n_act * toks / max(rec.get("n_devices", 1), 1)
    return {
        "cell": f"{rec['arch']} x {rec['shape']} x {rec['mesh']}",
        "t_compute_s": t_c,
        "t_memory_s": t_m,
        "t_collective_s": t_i,
        "bottleneck": dominant[1],
        "hlo_flops": flops,
        "model_flops": model_flops,
        "useful_flop_frac": (model_flops / flops) if flops else 0.0,
        "roofline_frac": (t_c / max(t_c, t_m, t_i)
                          if max(t_c, t_m, t_i) > 0 else 0.0),
        "step_time_lb_s": max(t_c, t_m, t_i),
    }


def main() -> None:
    out_dir = Path(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
    rows = []
    for p in sorted(out_dir.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("status") != "ok":
            continue
        rows.append(roofline_row(rec))
    hdr = (f"{'cell':58s} {'t_comp':>9s} {'t_mem':>9s} {'t_coll':>9s} "
           f"{'bound':>10s} {'MF/HF':>6s} {'roofl':>6s}")
    print(hdr)
    for r in rows:
        print(f"{r['cell']:58s} {r['t_compute_s']:9.4f} "
              f"{r['t_memory_s']:9.4f} {r['t_collective_s']:9.4f} "
              f"{r['bottleneck']:>10s} {r['useful_flop_frac']:6.2f} "
              f"{r['roofline_frac']:6.2f}")


if __name__ == "__main__":
    main()
