"""Pallas kernel microbenchmark: sweep-resident fused engine vs unfused,
dense (N, N) matmul vs Chimera-native block-sparse (degree-6 slot gather).

Times the real kernels (CPU interpret mode — the TPU story is projected
from the HBM traffic + roofline model) and writes the perf trajectory to
``BENCH_kernel.json`` at the repo root so regressions across PRs are
visible in review diffs.

Reported per configuration:
  * measured CPU-interpret wall time, sweeps/sec and flips/ns for the jnp
    reference, the per-half-sweep Pallas kernel, and the fused engine at
    S=1 and S=S_RESIDENT sweeps per launch;
  * the modeled HBM bytes/sweep for each path and the fused-vs-half-sweep
    traffic reduction (the kernel's reason to exist);
  * projected TPU v5e sweeps/sec from the max(HBM-bound, MXU-bound) time;
  * dense-vs-sparse configs (N = 440, 2048, 8192): modeled FLOPs, weight
    bytes, VMEM residency feasibility, measured sparse-kernel flips/ns.
    The ≥8k-spin rows run *only* on the sparse path — the dense W no
    longer fits a 16 MB VMEM core, the sparse slot layout always does.
  * `sharded_sweep` (N = 440, 2048, 8192): the mesh-sharded scan path on
    1 vs 2 forced host devices, with the exact modeled halo bytes per
    sweep from the partition plan and the TPU ICI-vs-HBM napkin ratio
    (docs/sharding.md).  Never run concurrently with the test suite on
    a small box — timings distort.
  * `weight_streaming` (N = 440): runtime program swaps into a warm
    Session (`sample_program`) vs a fresh-Session recompile, the
    double-buffered upload kernel vs serialized launches, and
    `sample_fleet` throughput vs K stacked programs
    (docs/api.md §Program lifecycle).
  * `sync_policies` (N = 440, 2048; k in {1, 4, inf}): the first-class
    `api.Sync` policies on a forced 2-device host — measured us/sweep
    for the per-sweep-launch baseline (one 1-sweep Session call per
    sweep, the serving/record loop's shape), the same barrier policy as
    one resident S-sweep call, and the relaxed k=4 / launch-resident
    policies — plus each policy's modeled halo bytes per sweep
    (docs/sharding.md §Sync policies).

Usage: python benchmarks/bench_kernel.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json, timed, timer
from repro.core.chimera import make_chimera, make_chip_graph
from repro.kernels.pbit_update import pbit_half_sweep_pallas
from repro.kernels.ref import pbit_half_sweep_ref
from repro.kernels.sweep_fused import (
    sweep_fused_pallas,
    sweep_sparse_pallas,
    sweep_sparse_stream_pallas,
)
from repro.launch.mesh import HBM_BW
from repro.launch.mesh import PEAK_FLOPS_BF16 as PEAK_FLOPS

S_RESIDENT = 16
VMEM_BYTES = 16 * 2 ** 20       # per-core VMEM the resident engine fits in
SPARSE_DEGREE = 6               # Chimera: 4 in-cell K4,4 + 2 chain couplers


def traffic_model(B: int, N: int, S: int) -> dict:
    """Modeled HBM bytes per full sweep for each execution path."""
    w = N * N * 4
    a = B * N * 4
    # jnp reference: matmul (W + m in + I out) then a ~5-op elementwise
    # chain re-reading/writing activations, twice per sweep (two colors),
    # plus host noise generation (write + read u)
    ref = 2 * (w + 2 * a + 5 * 2 * a) + 2 * 2 * a
    # per-half-sweep Pallas kernel: fused elementwise, but spins + noise
    # still cross HBM every half-sweep (m in, u in, m out) and noise is
    # generated outside the kernel (u write)
    half = 2 * (w + 3 * a) + 2 * a
    # fused S-sweep resident engine: W + spins in/out once per S sweeps;
    # noise never leaves the kernel; betas are S*B*4 per launch
    fused = (w + 2 * a) / S + B * 4
    return {
        "hbm_bytes_per_sweep_ref": ref,
        "hbm_bytes_per_sweep_halfsweep": half,
        "hbm_bytes_per_sweep_fused": fused,
        "traffic_reduction_vs_halfsweep": half / fused,
        "traffic_reduction_vs_ref": ref / fused,
    }


def projected_tpu_sweeps_per_sec(B: int, N: int, bytes_per_sweep: float
                                 ) -> float:
    flops_per_sweep = 2 * 2 * B * N * N  # two half-sweep matmuls
    t = max(bytes_per_sweep / HBM_BW, flops_per_sweep / PEAK_FLOPS)
    return 1.0 / t


def bench_config(B: int, N: int, iters: int = 3) -> dict:
    rng = np.random.default_rng(0)
    m = jnp.asarray(rng.integers(0, 2, (B, N)) * 2 - 1, jnp.float32)
    W = jnp.asarray(rng.normal(size=(N, N)) * 0.05, jnp.float32)
    h, g, o, rg, co = (jnp.asarray(rng.normal(size=N), jnp.float32)
                       for _ in range(5))
    g = 1.0 + 0.05 * g
    color = rng.integers(0, 2, N)
    mask0, mask1 = jnp.asarray(color == 0), jnp.asarray(color == 1)
    u = jnp.asarray(rng.uniform(-1, 1, (B, N)), jnp.float32)
    seedctr = jnp.asarray([1234, 0], jnp.uint32)

    out = {"B": B, "N": N, "S_resident": S_RESIDENT}
    out.update(traffic_model(B, N, S_RESIDENT))

    # -- jnp reference half-sweep (x2 per sweep)
    ref = jax.jit(lambda *a: pbit_half_sweep_ref(*a))
    t_ref = timer(ref, m, W, h, g, o, rg, co, mask0, 0.7, u, iters=iters)
    out["cpu_ref_half_us"] = t_ref * 1e6
    out["cpu_ref_sweeps_per_sec"] = 1.0 / (2 * t_ref)

    # -- per-half-sweep Pallas kernel (interpret mode on CPU)
    t_half = timer(
        lambda: pbit_half_sweep_pallas(m, W, h, g, o, rg, co, mask0, 0.7, u,
                                       interpret=True), iters=iters)
    out["cpu_halfsweep_kernel_us"] = t_half * 1e6
    out["cpu_halfsweep_sweeps_per_sec"] = 1.0 / (2 * t_half)

    # -- fused engine, 1 sweep and S_RESIDENT sweeps per launch
    for S in (1, S_RESIDENT):
        betas = jnp.full((S, B), 0.7, jnp.float32)
        t = timer(
            lambda b=betas: sweep_fused_pallas(
                m, W, h, g, o, rg, co, mask0, mask1, b, seedctr,
                noise_mode="counter", interpret=True)[0],
            iters=iters)
        key = "fused_s1" if S == 1 else f"fused_s{S}"
        sweeps_per_sec = S / t
        out[f"cpu_{key}_us_per_launch"] = t * 1e6
        out[f"cpu_{key}_sweeps_per_sec"] = sweeps_per_sec
        out[f"cpu_{key}_flips_per_ns"] = sweeps_per_sec * B * N * 1e-9

    _add_tpu_projection(B, N, out)
    return out


def _add_tpu_projection(B: int, N: int, out: dict) -> None:
    for key in ("halfsweep", "fused"):
        sps = projected_tpu_sweeps_per_sec(
            B, N, out[f"hbm_bytes_per_sweep_{key}"])
        out[f"tpu_projected_{key}_sweeps_per_sec"] = sps
        out[f"tpu_projected_{key}_flips_per_ns"] = sps * B * N * 1e-9


# ---------------------------------------------------------------------------
# api.Session dispatch: compile-once vs the legacy per-call path
# ---------------------------------------------------------------------------
def bench_session_dispatch(N: int = 440, B: int = 64, S: int = 8,
                           iters: int = 5) -> dict:
    """Measure what the unified API buys at the dispatch layer.

    The legacy path calls `pbit.gibbs_sample` as a plain Python function:
    every call re-resolves the backend (env read), rebuilds the sweep
    closure, and re-traces the scan before XLA's executable cache kicks
    in.  An `api.Session` jits the closure once at compile; steady-state
    calls replay the cached executable.  Both run the identical engine
    ("ref" backend, counter noise), so the delta is pure
    dispatch/trace overhead — the tax the CD loop, the tempering swap
    loop, and the serving path used to pay per call.
    """
    import jax.numpy as jnp

    from repro import api
    from repro.core import pbit
    from repro.core.cd import PBitMachine
    from repro.core.hardware import HardwareConfig

    g = _chimera_for(N)
    machine = PBitMachine.create(g, jax.random.PRNGKey(0),
                                 HardwareConfig(), noise="counter",
                                 backend="ref", w_scale=0.05)
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(-40, 40, g.n_edges), jnp.int32)
    h = jnp.zeros((g.n_nodes,), jnp.int32)
    session = machine.session(
        schedule=api.Constant(beta=0.7, n_sweeps=S), chains=B)
    chip = session.program_edges(codes, h)
    m0 = session.random_spins(jax.random.PRNGKey(1))
    ns = session.noise_state(jax.random.PRNGKey(2))
    state, step = machine.noise_fn(jax.random.PRNGKey(2), B)
    betas = jnp.full((S,), 0.7, jnp.float32)
    color = jnp.asarray(g.color)

    t_legacy = timer(
        lambda: pbit.gibbs_sample(chip, color, m0, betas, state, step,
                                  backend="ref")[0], iters=iters)
    t_session = timer(lambda: session.sample(chip, m0, ns)[0], iters=iters)
    return {
        "N": N, "B": B, "S": S, "backend": "ref",
        "legacy_us_per_call": t_legacy * 1e6,
        "session_us_per_call": t_session * 1e6,
        "dispatch_overhead_us": (t_legacy - t_session) * 1e6,
        "speedup_per_call": t_legacy / t_session,
    }


# ---------------------------------------------------------------------------
# mesh-sharded sweep: 1 vs 2 host devices, measured + modeled halo bytes
# ---------------------------------------------------------------------------
_SHARDED_WORKER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import json, time
    import jax, jax.numpy as jnp
    import numpy as np
    from repro import api
    from repro.core.cd import PBitMachine
    from repro.core.chimera import make_chimera, make_chip_graph
    from repro.core.hardware import HardwareConfig

    rows = []
    for N, B, S in {configs}:
        g = make_chip_graph() if N == 440 else \\
            make_chimera(int(round((N / 8) ** 0.5)),
                         int(round((N / 8) ** 0.5)))
        mesh = jax.make_mesh((2,), ("data",))
        mach = PBitMachine.create(g, jax.random.PRNGKey(0),
                                  HardwareConfig.ideal(), sparse=True,
                                  noise="counter", mesh=mesh,
                                  partition=api.Partition(rows="data"))
        ses = mach.session(schedule=api.Constant(0.7, n_sweeps=S),
                           chains=B)
        rng = np.random.default_rng(N)
        chip = ses.program_edges(
            jnp.asarray(rng.integers(-60, 60, g.n_edges), jnp.int32),
            jnp.zeros((g.n_nodes,), jnp.int32))
        st = ses.init_state(jax.random.PRNGKey(1))
        m, ns, _ = ses.sample(chip, st.m, st.noise_state)
        jax.block_until_ready(m)              # compile + warm
        t0 = time.perf_counter()
        m, ns, _ = ses.sample(chip, m, ns)
        jax.block_until_ready(m)
        rows.append({{"N": N, "us_per_sweep":
                     (time.perf_counter() - t0) / S * 1e6}})
    print(json.dumps(rows))
""")


def _sharded_single_device_us(N: int, B: int, S: int) -> float:
    """Baseline: the same sparse scan path, one device, in-process."""
    from repro import api
    from repro.core.cd import PBitMachine
    from repro.core.hardware import HardwareConfig

    g = _chimera_for(N)
    mach = PBitMachine.create(g, jax.random.PRNGKey(0),
                              HardwareConfig.ideal(), sparse=True,
                              noise="counter")
    ses = mach.session(schedule=api.Constant(0.7, n_sweeps=S), chains=B)
    rng = np.random.default_rng(N)
    chip = ses.program_edges(
        jnp.asarray(rng.integers(-60, 60, g.n_edges), jnp.int32),
        jnp.zeros((g.n_nodes,), jnp.int32))
    st = ses.init_state(jax.random.PRNGKey(1))
    _, (m, ns, _) = timed(ses.sample, chip, st.m, st.noise_state)
    t, _ = timed(ses.sample, chip, m, ns)
    return t / S * 1e6


def bench_sharded_sweep(quick: bool = False) -> dict:
    """The `sharded_sweep` section: per N, the modeled partition/halo
    numbers (exact, from the compile-time plan) plus measured sweep times
    on 1 and 2 forced host devices (2-dev in a subprocess — the device
    count is locked at first jax init).  On this 2-core CPU box the
    sharded time mostly measures shard_map overhead; the modeled halo
    bytes and the ICI/HBM ratio are the TPU-relevant outputs."""
    from repro.core.distributed import halo_bytes_per_sweep, \
        plan_row_partition
    from repro.launch.mesh import halo_vs_hbm_seconds

    shapes = {440: (64, 8), 2048: (16, 4), 8192: (8, 2)}
    if quick:
        shapes = {440: (16, 4), 2048: (8, 2), 8192: (4, 1)}
    rows = []
    for N, (B, S) in shapes.items():
        g = _chimera_for(N)
        plan = plan_row_partition(g, 2)
        halo = halo_bytes_per_sweep(plan, B)
        # per-device HBM stream per sweep: slot weights + spins, 2x/sweep
        hbm = (2 * 2 * SPARSE_DEGREE * N * 4 + 2 * B * N * 4) // 2
        row = {
            "N": N, "B": B, "S": S, "n_devices": 2,
            "n_boundary_spins": plan.n_boundary,
            "halo_bytes_per_sweep": halo,
            "halo_bytes_per_sweep_stats": halo_bytes_per_sweep(
                plan, B, refresh_for_moments=True),
            "dense_w_replication_bytes": 4 * N * N,
            **{f"tpu_{k}": v for k, v in halo_vs_hbm_seconds(
                halo // 2, hbm, exchanges=2.0).items()},
        }
        measure = not quick or N == 440
        if measure:
            row["cpu_1dev_us_per_sweep"] = _sharded_single_device_us(N, B, S)
        rows.append(row)

    measured = [(N, *shapes[N]) for N in shapes
                if not quick or N == 440]
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_WORKER.format(configs=measured)],
        capture_output=True, text=True, timeout=1200,
        cwd=Path(__file__).resolve().parent.parent,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-2000:]
    two_dev = {r["N"]: r["us_per_sweep"]
               for r in json.loads(out.stdout.strip().splitlines()[-1])}
    for row in rows:
        if row["N"] in two_dev:
            row["cpu_2dev_us_per_sweep"] = two_dev[row["N"]]
    return {"note": "sharded sparse scan path, rows partition over a "
                    "forced 2-device host mesh (docs/sharding.md)",
            "configs": rows}


# ---------------------------------------------------------------------------
# sync policies: barrier vs relaxed halo exchange on 2 forced host devices
# ---------------------------------------------------------------------------
_SYNC_WORKER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import json, math, time
    import jax, jax.numpy as jnp
    import numpy as np
    from repro import api
    from repro.core.cd import PBitMachine
    from repro.core.chimera import make_chimera, make_chip_graph
    from repro.core.hardware import HardwareConfig

    POLICIES = {{
        "1": api.Sync(),
        "4": api.Sync(halo_every=4, sweeps_per_launch=4),
        "inf": api.Sync(halo_every=math.inf, sweeps_per_launch=8),
    }}

    def time_calls(fn, m, ns, reps=5):
        jax.block_until_ready(fn(m, ns))         # compile + warm
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(m, ns))
            ts.append(time.perf_counter() - t0)
        # median of fresh-input calls: chaining un-consumed sharded
        # outputs back as inputs stalls the forced-host runtime for
        # ~100 ms/call and would swamp the policy signal
        return sorted(ts)[len(ts) // 2]

    rows = []
    for N, B, S in {configs}:
        g = make_chip_graph() if N == 440 else \\
            make_chimera(int(round((N / 8) ** 0.5)),
                         int(round((N / 8) ** 0.5)))
        mesh = jax.make_mesh((2,), ("data",))
        rng = np.random.default_rng(N)
        codes = jnp.asarray(rng.integers(-60, 60, g.n_edges), jnp.int32)
        h0 = jnp.zeros((g.n_nodes,), jnp.int32)
        for kname, sync in POLICIES.items():
            mach = PBitMachine.create(g, jax.random.PRNGKey(0),
                                      HardwareConfig.ideal(), sparse=True,
                                      noise="counter", mesh=mesh,
                                      partition=api.Partition(rows="data"),
                                      sync=sync)
            ses = mach.session(chains=B)
            chip = ses.program_edges(codes, h0)
            st = ses.init_state(jax.random.PRNGKey(1))
            betas = jnp.full((S,), 0.7, jnp.float32)
            t_call = time_calls(
                lambda m, ns: ses.sample(chip, m, ns, betas)[0],
                st.m, st.noise_state)
            row = {{"N": N, "halo_every": kname,
                    "sweeps_per_launch": sync.sweeps_per_launch,
                    "mode": sync.mode,
                    "cpu_us_per_sweep": t_call / S * 1e6}}
            if kname == "1":
                # the per-sweep-launch baseline: one 1-sweep Session call
                # per sweep, blocking on each result — the dispatch shape
                # of a serving / record loop that consumes every sweep,
                # which is exactly what the sweep-resident policies
                # amortize away
                beta1 = jnp.full((1,), 0.7, jnp.float32)

                def per_sweep(m, ns):
                    for _ in range(S):
                        m, ns, _ = ses.sample(chip, m, ns, beta1)
                        jax.block_until_ready(m)
                    return m
                t_ps = time_calls(per_sweep, st.m, st.noise_state)
                row["cpu_us_per_sweep_launch_baseline"] = t_ps / S * 1e6
            rows.append(row)
    print(json.dumps(rows))
""")


def bench_sync_policies(quick: bool = False) -> dict:
    """The `sync_policies` section: for N = 440 / 2048 and halo_every
    k in {1, 4, inf}, the modeled halo bytes per sweep under each policy
    and the measured 2-forced-host-device sweep times — the per-sweep-
    launch barrier baseline vs resident multi-sweep calls (the k=1
    resident call isolates dispatch amortization; the relaxed rows add
    the exchange savings).  Quick mode measures N=440 only."""
    import math as _math

    from repro import api
    from repro.core.distributed import halo_bytes_per_sweep, \
        plan_row_partition

    policies = {
        "1": api.Sync(),
        "4": api.Sync(halo_every=4, sweeps_per_launch=4),
        "inf": api.Sync(halo_every=_math.inf, sweeps_per_launch=8),
    }
    shapes = {440: (64, 16), 2048: (16, 16)}
    if quick:
        shapes = {440: (16, 8), 2048: (8, 8)}
    rows = []
    for N, (B, S) in shapes.items():
        g = _chimera_for(N)
        plan = plan_row_partition(g, 2)
        for kname, sync in policies.items():
            rows.append({
                "N": N, "B": B, "S": S, "n_devices": 2,
                "halo_every": kname,
                "sweeps_per_launch": sync.sweeps_per_launch,
                "mode": sync.mode,
                "exchanges_per_sweep": sync.exchanges_per_sweep(),
                "halo_bytes_per_sweep": halo_bytes_per_sweep(
                    plan, B, sync=sync),
            })

    measured = [(N, *shapes[N]) for N in shapes if not quick or N == 440]
    out = subprocess.run(
        [sys.executable, "-c", _SYNC_WORKER.format(configs=measured)],
        capture_output=True, text=True, timeout=1200,
        cwd=Path(__file__).resolve().parent.parent,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-2000:]
    timed = json.loads(out.stdout.strip().splitlines()[-1])
    by_key = {(r["N"], r["halo_every"]): r for r in timed}
    for row in rows:
        t = by_key.get((row["N"], row["halo_every"]))
        if t is not None:
            row["cpu_us_per_sweep"] = t["cpu_us_per_sweep"]
            if "cpu_us_per_sweep_launch_baseline" in t:
                row["cpu_us_per_sweep_launch_baseline"] = \
                    t["cpu_us_per_sweep_launch_baseline"]
    return {"note": "api.Sync policies on a forced 2-device host: "
                    "per-sweep-launch barrier baseline vs resident "
                    "multi-sweep calls (docs/sharding.md §Sync policies)",
            "configs": rows}


# ---------------------------------------------------------------------------
# Kernel-resident halo exchange vs host-exchange dispatch
# ---------------------------------------------------------------------------
_HALO_WORKER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import json, time
    import jax, jax.numpy as jnp
    import numpy as np
    from repro import api
    from repro.core.cd import PBitMachine
    from repro.core.chimera import make_chimera, make_chip_graph
    from repro.core.hardware import HardwareConfig

    def time_calls(fn, reps=5):
        jax.block_until_ready(fn())              # compile + warm
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[len(ts) // 2]

    rows = []
    for N, B, S in {configs}:
        g = make_chip_graph() if N == 440 else \\
            make_chimera(int(round((N / 8) ** 0.5)),
                         int(round((N / 8) ** 0.5)))
        mesh = jax.make_mesh((2,), ("data",))
        mach = PBitMachine.create(g, jax.random.PRNGKey(0),
                                  HardwareConfig.ideal(), sparse=True,
                                  noise="counter")
        rng = np.random.default_rng(N)
        codes = jnp.asarray(rng.integers(-60, 60, g.n_edges), jnp.int32)
        h0 = jnp.zeros((g.n_nodes,), jnp.int32)
        ses0 = api.Session(mach.sampler_spec(chains=B))
        chip = ses0.program_edges(codes, h0)
        m0 = ses0.random_spins(jax.random.PRNGKey(1))
        ns = ses0.noise_state(jax.random.PRNGKey(2))
        betas = jnp.full((S,), 0.7, jnp.float32)

        def session(sync, backend):
            sp = mach.sampler_spec(
                chains=B, mesh=mesh, sync=sync,
                partition=api.Partition(rows="data"))
            return api.Session(sp.replace(backend=backend))

        for k in (1, 4):
            sync = api.Sync(halo_every=k, sweeps_per_launch=S)
            fz = session(sync, "fused_sparse")
            t_res = time_calls(
                lambda: fz.sample(chip, m0, ns, betas)[0])
            sc = session(sync, "sparse")
            t_scan = time_calls(
                lambda: sc.sample(chip, m0, ns, betas)[0])
            row = {{"N": N, "halo_every": k, "sweeps_per_launch": S,
                    "cpu_us_per_sweep_resident": t_res / S * 1e6,
                    "cpu_us_per_sweep_segment_scan": t_scan / S * 1e6}}
            if k == 1:
                # the host-exchange baseline the kernel-resident path
                # replaces: every exchange point ends the launch, so a
                # k=1 policy dispatches one 1-sweep launch per sweep and
                # pays the host round-trip on each boundary refresh
                ps = session(api.Sync(halo_every=1, sweeps_per_launch=1),
                             "sparse")
                beta1 = jnp.full((1,), 0.7, jnp.float32)

                def per_sweep():
                    m, n2 = m0, ns
                    for _ in range(S):
                        m, n2, _ = ps.sample(chip, m, n2, beta1)
                        jax.block_until_ready(m)
                    return m
                t_ps = time_calls(per_sweep)
                row["cpu_us_per_sweep_host_exchange_baseline"] = \\
                    t_ps / S * 1e6
                row["speedup_vs_host_exchange"] = t_ps / t_res
            rows.append(row)
    print(json.dumps(rows))
""")


def bench_halo_fused(quick: bool = False) -> dict:
    """The `halo_fused` section: kernel-resident halo exchange
    (docs/kernels.md §In-kernel halo exchange) vs the host-exchange
    paths, on a forced 2-device host.

    For N = 440 / 2048 and halo_every k in {1, 4}: the fused
    kernel-owned-exchange launch (one dispatch per S-sweep launch, the
    exchange points refreshed inside the jitted graph) against (a) at
    k=1 the host-exchange baseline — one 1-sweep launch per sweep,
    blocking on each, which is what a frequent-refresh policy was forced
    into before the kernel could own the exchange — and (b) the sparse
    segment-scan engine under the identical policy (single dispatch,
    host ppermute between segments).  The modeled halo bytes are
    identical for the kernel-resident and host paths — the policy fixes
    the transfer schedule; only who issues it changes."""
    from repro import api
    from repro.core.distributed import halo_bytes_per_sweep, \
        plan_row_partition

    shapes = {440: (16, 8), 2048: (8, 8)}
    if quick:
        shapes = {440: (8, 4)}
    rows = []
    for N, (B, S) in shapes.items():
        g = _chimera_for(N)
        plan = plan_row_partition(g, 2)
        for k in (1, 4):
            sync = api.Sync(halo_every=k, sweeps_per_launch=S)
            rows.append({
                "N": N, "B": B, "S": S, "n_devices": 2,
                "halo_every": k,
                "sweeps_per_launch": S,
                "exchanges_per_sweep": sync.exchanges_per_sweep(),
                # identical for kernel-resident and host exchange: the
                # Sync policy fixes the bytes, the kernel only moves
                # where the transfer is issued from
                "halo_bytes_per_sweep": halo_bytes_per_sweep(
                    plan, B, sync=sync),
            })

    measured = [(N, *shapes[N]) for N in shapes if not quick or N == 440]
    out = subprocess.run(
        [sys.executable, "-c", _HALO_WORKER.format(configs=measured)],
        capture_output=True, text=True, timeout=2400,
        cwd=Path(__file__).resolve().parent.parent,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-2000:]
    timed = json.loads(out.stdout.strip().splitlines()[-1])
    by_key = {(r["N"], r["halo_every"]): r for r in timed}
    for row in rows:
        t = by_key.get((row["N"], row["halo_every"]))
        if t is not None:
            for key in ("cpu_us_per_sweep_resident",
                        "cpu_us_per_sweep_segment_scan",
                        "cpu_us_per_sweep_host_exchange_baseline",
                        "speedup_vs_host_exchange"):
                if key in t:
                    row[key] = t[key]
    return {"note": "kernel-resident halo exchange vs host-exchange "
                    "dispatch on a forced 2-device host (docs/kernels.md "
                    "§In-kernel halo exchange); halo bytes are modeled "
                    "and identical for both paths",
            "configs": rows}


def _emit_halo(hf: dict) -> None:
    k1 = [r for r in hf["configs"]
          if r["N"] == 440 and r["halo_every"] == 1]
    if k1 and "speedup_vs_host_exchange" in k1[0]:
        r = k1[0]
        emit("kernel_halo_fused_speedup_N440_k1",
             r["speedup_vs_host_exchange"],
             f"resident={r['cpu_us_per_sweep_resident']:.0f}us/sweep, "
             f"host_exchange="
             f"{r['cpu_us_per_sweep_host_exchange_baseline']:.0f}us, "
             f"halo_bytes={r['halo_bytes_per_sweep']:.0f}")


# ---------------------------------------------------------------------------
# PSL compiler: embedding overhead + end-to-end correct-answer rate
# ---------------------------------------------------------------------------
def bench_psl_embed(quick: bool = False) -> dict:
    """The `psl_embed` section: the PSL compiler's (docs/psl.md)
    chain-embedding overhead and the end-to-end correct-answer rate of
    forward inference through an unmodified `api.Session`.

    Chain length is the scaling knob to watch: the clique-ladder
    embedder grows chains linearly with circuit size, and Gibbs mixing
    through a chain requires a coordinated all-member flip.  Measured:
    4-spin chains (adder2) and 8-spin chains (adder4) infer perfectly;
    14-spin chains (mult3) stop mixing — ~0% clause-valid samples at
    every schedule tried — so the mult3 row is *expected* to score ~0
    and is tracked here as the target for the connectivity-aware
    embedder (ROADMAP).
    """
    import time

    from repro import psl

    def adder_readout(n):
        def check(r, a, b):
            return r.infer("sum") + (r.infer("cout") << n) == a + b
        return check

    def mult_readout(n):
        def check(r, a, b):
            return r.infer("prod") == a * b
        return check

    cases = [
        ("adder2", psl.ripple_adder_circuit(2), adder_readout(2), 2,
         make_chimera(2, 2), {}),
        ("adder4", psl.ripple_adder_circuit(4), adder_readout(4), 4,
         make_chimera(4, 4), {}),
        ("mult3", psl.multiplier_circuit(3), mult_readout(3), 3,
         make_chip_graph(), {"n_sweeps": 600}),
    ]
    n_rows = 4 if quick else 8
    if quick:
        cases = cases[:1]

    rng = np.random.default_rng(0)
    rows = []
    for name, circuit, check, n_bits, g, kw in cases:
        if quick:
            kw = {**kw, "chains": 32, "n_sweeps": 200}
        t0 = time.perf_counter()
        cc = psl.compile_circuit(circuit, g, **kw)
        compile_ms = (time.perf_counter() - t0) * 1e3
        logical = cc.logical
        pairs = sorted({(int(a), int(b)) for a, b in
                        rng.integers(0, 1 << n_bits, (4 * n_rows, 2))}
                       )[:n_rows]
        key = jax.random.PRNGKey(0)
        correct, broken, valid, times = 0, [], [], []
        for a, b in pairs:
            key, sub = jax.random.split(key)
            t0 = time.perf_counter()
            r = cc.run_forward(sub, {"a": a, "b": b})
            times.append(time.perf_counter() - t0)
            correct += bool(check(r, a, b))
            s = r.summary()
            broken.append(s["broken_chain_fraction"])
            valid.append(s["clause_valid_fraction"])
        rows.append({
            "circuit": name,
            "n_logical_edges": logical.n_edges,
            **cc.embedding.stats(),
            "chains": cc.spec.chains,
            "n_sweeps": cc.spec.schedule.n_sweeps,
            "compile_ms": compile_ms,
            "rows_tested": len(pairs),
            "rows_correct": correct,
            "correct_rate": correct / len(pairs),
            "broken_chain_fraction": float(np.mean(broken)),
            "clause_valid_fraction": float(np.mean(valid)),
            # first call includes jit compile; steady state is the rest
            "sample_s_first": times[0],
            "sample_s_steady": float(np.mean(times[1:])) if times[1:]
            else times[0],
        })
    return {"note": "PSL compiler forward inference (docs/psl.md): "
                    "clique-ladder embedding stats + correct-answer "
                    "rate; mult3's 14-spin chains are the known mixing "
                    "cliff the ROADMAP embedder item targets",
            "configs": rows}


# ---------------------------------------------------------------------------
# runtime weight streaming: program swaps, double-buffered uploads, fleets
# ---------------------------------------------------------------------------
def bench_weight_streaming(quick: bool = False) -> dict:
    """The `weight_streaming` section (docs/api.md §Program lifecycle).

    * ``program_swap_ms`` vs ``session_recompile_ms`` at the paper chip
      (N=440): retargeting a warm Session to fresh couplings through
      `Session.make_program` + `sample_program` — an O(E) operand copy
      into the compiled executable — against building a new
      `api.Session` and paying its first-call XLA compile, which is what
      a value-keyed fingerprint used to force per chip instance.
    * ``double_buffered`` vs ``serialized``: an L-launch program chain
      through `sweep_sparse_stream_pallas` (the NEXT program stages into
      a second VMEM slot while the CURRENT one sweeps — the SpikeHard
      DMA overlap) vs the same chain as plain `sweep_sparse_pallas`
      launches with the program swapped on the host between launches.
      CPU-interpret wall times; ``staged_bytes_per_launch`` is the
      modeled upload the overlap hides on a real accelerator.
    * ``fleet`` — `Session.sample_fleet` throughput vs K stacked
      programs (mismatch draws / tenants / CD replicas) through ONE
      vmapped executable, against K sequential `sample_program` calls.
    """
    from repro import api
    from repro.core.cd import PBitMachine

    B, S, L = (8, 4, 3) if quick else (16, 8, 4)
    g = make_chip_graph()
    mach = PBitMachine.create(g, jax.random.PRNGKey(0), sparse=True,
                              noise="counter")
    spec = mach.sampler_spec(schedule=api.Constant(0.7, n_sweeps=S),
                             chains=B)
    ses = api.Session(spec)

    def codes(seed):
        r = np.random.default_rng(seed)
        return (jnp.asarray(r.integers(-60, 60, g.n_edges), jnp.int32),
                jnp.asarray(r.integers(-15, 15, g.n_nodes), jnp.int32))

    m0 = ses.random_spins(jax.random.PRNGKey(1))
    ns = ses.noise_state(jax.random.PRNGKey(2))

    # -- program swap vs Session recompile
    timed(lambda: ses.sample_program(ses.make_program(*codes(0)), m0,
                                     ns)[0])  # compile once
    swaps = []
    for seed in range(1, 4 if quick else 6):
        J, h = codes(seed)
        t, _ = timed(lambda: ses.sample_program(ses.make_program(J, h),
                                                m0, ns)[0])
        swaps.append(t)
    swap_s = sorted(swaps)[len(swaps) // 2]

    recompiles = []
    for _ in range(1 if quick else 2):
        fresh = api.Session(spec)
        chip = fresh.program_edges(*codes(1))
        t, _ = timed(lambda: fresh.sample(chip, m0, ns)[0])
        recompiles.append(t)
    recompile_s = min(recompiles)

    out = {
        "note": "runtime weight streaming: O(E) program swaps into a "
                "compiled executable vs per-problem Session recompiles, "
                "the double-buffered upload kernel, and the vmapped "
                "K-program fleet axis (docs/api.md §Program lifecycle)",
        "N": int(g.n_nodes), "B": B, "S": S, "backend": "sparse",
        "program_swap_ms": swap_s * 1e3,
        "session_recompile_ms": recompile_s * 1e3,
        "swap_speedup": recompile_s / swap_s,
    }

    # -- double-buffered vs serialized upload (kernel-level, L launches)
    chips = [ses.program_edges(*codes(40 + i)) for i in range(L)]
    c0 = chips[0]
    masks = (jnp.asarray(g.color == 0), jnp.asarray(g.color == 1))
    betas = jnp.full((S, B), 0.7, jnp.float32)
    ns0 = jnp.asarray([1234, 0], jnp.uint32)
    block_b = min(128, B)

    def serialized():
        m, st = m0, ns0
        for chip in chips:
            m, st = sweep_sparse_pallas(
                m, c0.nbr_idx, chip.nbr_w, chip.h, chip.tanh_gain,
                chip.tanh_offset, chip.rand_gain, chip.comp_offset,
                *masks, betas, st, noise_mode="counter",
                block_b=block_b, interpret=True)
        return m

    def double_buffered():
        m, st = m0, ns0
        w, h = chips[0].nbr_w, chips[0].h
        for i, chip in enumerate(chips):
            nxt = chips[(i + 1) % L]
            m, st, w, h = sweep_sparse_stream_pallas(
                m, c0.nbr_idx, w, h, chip.tanh_gain, chip.tanh_offset,
                chip.rand_gain, chip.comp_offset, *masks, betas, st,
                nxt.nbr_w, nxt.h, block_b=block_b, interpret=True)
        return m

    iters = 1 if quick else 3
    t_ser = timer(serialized, iters=iters)
    t_db = timer(double_buffered, iters=iters)
    out["upload"] = {
        "launches": L, "sweeps_per_launch": S,
        "serialized_us_per_launch": t_ser / L * 1e6,
        "double_buffered_us_per_launch": t_db / L * 1e6,
        "staged_bytes_per_launch": int(c0.nbr_w.size * 4 + c0.h.size * 4),
    }

    # -- fleet axis: K programs through one vmapped executable
    fleet_rows = []
    for K in (1, 2, 4) if quick else (1, 2, 4, 8):
        progs = api.stack_programs(
            [ses.make_program(*codes(70 + k)) for k in range(K)])
        mK = jnp.broadcast_to(m0, (K, *m0.shape))
        nsK = jnp.stack([ses.noise_state(jax.random.PRNGKey(90 + k))
                         for k in range(K)])
        t_fleet = timer(lambda: ses.sample_fleet(progs, mK, nsK)[0],
                        iters=iters)

        def sequential():
            outs = []
            for k in range(K):
                p = jax.tree_util.tree_map(lambda x, k=k: x[k], progs)
                outs.append(ses.sample_program(p, mK[k], nsK[k])[0])
            return outs

        t_seq = timer(sequential, iters=iters)
        fleet_rows.append({
            "K": K,
            "fleet_us_per_call": t_fleet * 1e6,
            "sequential_us_per_call": t_seq * 1e6,
            "fleet_chain_sweeps_per_sec": K * B * S / t_fleet,
            "sequential_chain_sweeps_per_sec": K * B * S / t_seq,
            "fleet_speedup": t_seq / t_fleet,
        })
    out["fleet"] = fleet_rows
    return out


# ---------------------------------------------------------------------------
# dense vs Chimera-native block-sparse
# ---------------------------------------------------------------------------
def dense_vs_sparse_model(B: int, N: int, S: int,
                          D: int = SPARSE_DEGREE) -> dict:
    """Modeled FLOPs / bytes for the two weight layouts of the resident
    engine, plus VMEM-residency feasibility."""
    a = B * N * 4
    dense_w = N * N * 4                    # fp32 couplings
    sparse_w = 2 * D * N * 4               # fp32 slot weights + int32 table
    flops_dense = 2 * 2 * B * N * N        # two half-sweep matmuls
    flops_sparse = 2 * 2 * B * N * D       # two half-sweeps of D-slot FMAs
    # the resident engine needs W + one (block_b, N) spin tile (+ scratch
    # of the same order) simultaneously live in VMEM
    tile = 128 * N * 4
    return {
        "dense_weight_bytes": dense_w,
        "sparse_weight_bytes": sparse_w,
        "weight_bytes_reduction": dense_w / sparse_w,
        "flops_per_sweep_dense": flops_dense,
        "flops_per_sweep_sparse": flops_sparse,
        "flop_reduction": flops_dense / flops_sparse,
        "hbm_bytes_per_sweep_fused_dense": (dense_w + 2 * a) / S + B * 4,
        "hbm_bytes_per_sweep_fused_sparse": (sparse_w + 2 * a) / S + B * 4,
        "dense_vmem_resident_feasible": dense_w + 2 * tile <= VMEM_BYTES,
        "sparse_vmem_resident_feasible": sparse_w + 2 * tile <= VMEM_BYTES,
    }


def _chimera_for(N: int):
    if N == 440:
        return make_chip_graph()
    side = int(round((N / 8) ** 0.5))
    g = make_chimera(side, side)
    assert g.n_nodes == N, (g.n_nodes, N)
    return g


def bench_sparse_config(N: int, B: int, S: int, iters: int = 1,
                        measure: bool = True) -> dict:
    """Dense-vs-sparse comparison row; measures the sparse kernel (CPU
    interpret) on a real Chimera instance of N spins.  The dense resident
    engine is measured only where its W still fits VMEM."""
    out = {"B": B, "N": N, "S": S, "D": SPARSE_DEGREE, "layout": "chimera"}
    out.update(dense_vs_sparse_model(B, N, S))
    sps_flops = out["flops_per_sweep_sparse"]
    out["tpu_projected_sparse_sweeps_per_sec"] = 1.0 / max(
        out["hbm_bytes_per_sweep_fused_sparse"] / HBM_BW,
        sps_flops / PEAK_FLOPS)
    out["tpu_projected_sparse_flips_per_ns"] = (
        out["tpu_projected_sparse_sweeps_per_sec"] * B * N * 1e-9)
    if not measure:
        return out

    g = _chimera_for(N)
    nbr_idx, nbr_mask = g.neighbor_table()
    rng = np.random.default_rng(N)
    nbr_w = jnp.asarray(
        np.where(nbr_mask, rng.normal(size=nbr_idx.shape) * 0.05, 0.0),
        jnp.float32)
    idx = jnp.asarray(nbr_idx)
    m = jnp.asarray(rng.integers(0, 2, (B, N)) * 2 - 1, jnp.float32)
    h, gn, o, rg, co = (jnp.asarray(rng.normal(size=N) * 0.1, jnp.float32)
                        for _ in range(5))
    mask0 = jnp.asarray(g.color == 0)
    mask1 = jnp.asarray(g.color == 1)
    betas = jnp.full((S, B), 0.7, jnp.float32)
    seedctr = jnp.asarray([1234, 0], jnp.uint32)
    block_b = min(128, B)

    t = timer(
        lambda: sweep_sparse_pallas(
            m, idx, nbr_w, h, gn, o, rg, co, mask0, mask1, betas, seedctr,
            noise_mode="counter", block_b=block_b, interpret=True)[0],
        iters=iters)
    out["cpu_sparse_us_per_launch"] = t * 1e6
    out["cpu_sparse_sweeps_per_sec"] = S / t
    out["cpu_sparse_flips_per_ns"] = (S / t) * B * N * 1e-9
    return out


def _write_root_merge(results: dict) -> None:
    """Merge-preserve our sections into the tracked repo-root JSON:
    other benches own sections of this file (e.g. bench_variability's
    fault_yield) — only replace our own keys."""
    root = Path(__file__).resolve().parent.parent / "BENCH_kernel.json"
    merged = json.loads(root.read_text()) if root.exists() else {}
    merged.update(results)
    root.write_text(json.dumps(merged, indent=1))


def _emit_streaming(ws: dict) -> None:
    emit("kernel_program_swap_ms_N440", ws["program_swap_ms"],
         f"recompile={ws['session_recompile_ms']:.0f}ms "
         f"({ws['swap_speedup']:.0f}x)")
    up = ws["upload"]
    emit("kernel_stream_double_buffered_us",
         up["double_buffered_us_per_launch"],
         f"serialized={up['serialized_us_per_launch']:.0f}us, "
         f"staged={up['staged_bytes_per_launch']}B")
    top = ws["fleet"][-1]
    emit(f"kernel_fleet_k{top['K']}_chain_sweeps_per_sec",
         top["fleet_chain_sweeps_per_sec"],
         f"sequential={top['sequential_chain_sweeps_per_sec']:.0f} "
         f"({top['fleet_speedup']:.2f}x)")


def run(quick: bool = False, psl_only: bool = False,
        streaming_only: bool = False, halo_only: bool = False) -> dict:
    if halo_only:
        # regenerate just the kernel-resident halo-exchange section
        # (cheap next to the full kernel sweeps) and merge it into the
        # tracked root JSON
        results = {"halo_fused": bench_halo_fused(quick)}
        _emit_halo(results["halo_fused"])
        save_json("halo_fused", results["halo_fused"])
        if not quick:
            _write_root_merge(results)
        return results

    if psl_only:
        # regenerate just the PSL section (it is far cheaper than the
        # kernel sweeps) and merge it into the tracked root JSON
        results = {"psl_embed": bench_psl_embed(quick)}
        for row in results["psl_embed"]["configs"]:
            emit(f"psl_{row['circuit']}_correct_rate", row["correct_rate"],
                 f"chain_len={row['chain_length']}, "
                 f"valid={row['clause_valid_fraction']:.2%}")
        if not quick:
            _write_root_merge(results)
        return results

    if streaming_only:
        # regenerate just the weight-streaming section (cheap next to the
        # full kernel sweeps) and merge it into the tracked root JSON
        results = {"weight_streaming": bench_weight_streaming(quick)}
        _emit_streaming(results["weight_streaming"])
        save_json("weight_streaming", results["weight_streaming"])
        if not quick:
            _write_root_merge(results)
        return results

    # chip scale is always measured; the paper-chip N=440 rounds to 512
    # lanes in-kernel.  The production-scale config is traffic-model only
    # in quick mode (interpret-mode matmuls at N=2048 take minutes).
    results = {"configs": []}
    results["configs"].append(bench_config(64 if quick else 256, 440,
                                           iters=1 if quick else 3))
    big = {"B": 256, "N": 2048, "S_resident": S_RESIDENT}
    big.update(traffic_model(256, 2048, S_RESIDENT))
    big["traffic_reduction_s1_vs_halfsweep"] = (
        traffic_model(256, 2048, 1)["traffic_reduction_vs_halfsweep"])
    _add_tpu_projection(256, 2048, big)
    results["configs"].append(big)

    # dense-vs-sparse rows: the chip graph, the largest dense-resident
    # lattice, and a 32x32 Chimera (8192 spins) that only the sparse slot
    # layout can keep VMEM-resident (dense W = 256 MB >> 16 MB)
    results["sparse_configs"] = [
        bench_sparse_config(440, 64 if quick else 256, S_RESIDENT,
                            iters=1 if quick else 3),
        bench_sparse_config(2048, 16 if quick else 64, 4,
                            iters=1, measure=not quick),
        bench_sparse_config(8192, 8, 2, iters=1, measure=not quick),
    ]

    # compile-once Session dispatch vs legacy per-call re-trace at N=440
    results["session_dispatch"] = bench_session_dispatch(
        440, 16 if quick else 64, 8, iters=3 if quick else 5)

    # mesh-sharded sweep: 1 vs 2 forced host devices + halo-bytes model
    results["sharded_sweep"] = bench_sharded_sweep(quick)

    # sync policies: barrier vs relaxed halo exchange, measured + modeled
    results["sync_policies"] = bench_sync_policies(quick)

    # kernel-resident halo exchange vs host-exchange dispatch
    results["halo_fused"] = bench_halo_fused(quick)

    # PSL compiler: embedding overhead + forward correct-answer rate
    results["psl_embed"] = bench_psl_embed(quick)

    # runtime weight streaming: swaps, double-buffered uploads, fleets
    results["weight_streaming"] = bench_weight_streaming(quick)

    chip = results["configs"][0]
    emit("kernel_session_dispatch_N440",
         results["session_dispatch"]["session_us_per_call"],
         f"legacy={results['session_dispatch']['legacy_us_per_call']:.0f}us"
         f" ({results['session_dispatch']['speedup_per_call']:.1f}x)")
    emit("kernel_fused_s16_cpu", chip["cpu_fused_s16_us_per_launch"],
         f"sweeps/s={chip['cpu_fused_s16_sweeps_per_sec']:.1f}")
    emit("kernel_traffic_reduction_B256_N2048",
         big["traffic_reduction_vs_halfsweep"],
         f"s1={big['traffic_reduction_s1_vs_halfsweep']:.2f}x")
    sp2048 = results["sparse_configs"][1]
    emit("kernel_sparse_flop_reduction_N2048", sp2048["flop_reduction"],
         f"weight_bytes={sp2048['weight_bytes_reduction']:.0f}x")
    sp8192 = results["sparse_configs"][2]
    emit("kernel_sparse_N8192_dense_resident",
         float(sp8192["dense_vmem_resident_feasible"]),
         f"sparse_resident={sp8192['sparse_vmem_resident_feasible']}")
    sh440 = results["sharded_sweep"]["configs"][0]
    emit("kernel_sharded_halo_bytes_N440",
         sh440["halo_bytes_per_sweep"],
         f"boundary={sh440['n_boundary_spins']} spins, "
         f"ici/hbm={sh440['tpu_ici_over_hbm']:.3f}")
    sy = {r["halo_every"]: r for r in results["sync_policies"]["configs"]
          if r["N"] == 440}
    emit("kernel_sync_resident_N440", sy["inf"].get("cpu_us_per_sweep", 0),
         f"per_sweep_launch_baseline="
         f"{sy['1'].get('cpu_us_per_sweep_launch_baseline', 0):.0f}us, "
         f"halo_bytes inf/k1={sy['inf']['halo_bytes_per_sweep']:.0f}/"
         f"{sy['1']['halo_bytes_per_sweep']:.0f}")
    _emit_halo(results["halo_fused"])
    for row in results["psl_embed"]["configs"]:
        emit(f"psl_{row['circuit']}_correct_rate", row["correct_rate"],
             f"chain_len={row['chain_length']}, "
             f"valid={row['clause_valid_fraction']:.2%}")
    _emit_streaming(results["weight_streaming"])

    save_json("kernel_pbit_update", results)
    if not quick:
        # perf trajectory tracked across PRs at the repo root; --quick runs
        # (CI smoke) use incomparable shapes and must not overwrite it
        _write_root_merge(results)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes / single iteration (CI smoke)")
    ap.add_argument("--psl-only", action="store_true",
                    help="regenerate only the psl_embed section")
    ap.add_argument("--streaming-only", action="store_true",
                    help="regenerate only the weight_streaming section")
    ap.add_argument("--halo-only", action="store_true",
                    help="regenerate only the halo_fused section")
    args = ap.parse_args()
    run(quick=args.quick, psl_only=args.psl_only,
        streaming_only=args.streaming_only, halo_only=args.halo_only)
