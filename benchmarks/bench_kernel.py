"""Pallas kernel microbenchmark: fused half-sweep vs unfused jnp reference.

On CPU both run through XLA/interpreter so wall time is not the TPU story;
the figure of merit reported is the *HBM traffic model* of fused vs unfused
(the kernel's reason to exist) plus correctness-checked call timing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json, timer
from repro.kernels.ops import ref_half_sweep
from repro.kernels.pbit_update import pbit_half_sweep_pallas
from repro.kernels.ref import pbit_half_sweep_ref


def run() -> dict:
    rng = np.random.default_rng(0)
    B, N = 256, 2048
    m = jnp.asarray((rng.integers(0, 2, (B, N)) * 2 - 1), jnp.float32)
    W = jnp.asarray(rng.normal(size=(N, N)) * 0.05, jnp.float32)
    vecs = [jnp.asarray(rng.normal(size=N), jnp.float32) for _ in range(5)]
    mask = jnp.asarray(rng.integers(0, 2, N).astype(bool))
    u = jnp.asarray(rng.uniform(-1, 1, (B, N)), jnp.float32)

    ref = jax.jit(lambda *a: pbit_half_sweep_ref(*a))
    t_ref = timer(ref, m, W, *vecs, mask, 0.7, u)

    # HBM traffic model (bytes), fused vs unfused chain of 5 elementwise ops
    w_bytes = N * N * 4
    act = B * N * 4
    unfused = w_bytes + act * 2 + 5 * 2 * act   # matmul + 5 rw passes
    fused = w_bytes + act * 3                   # m, u in; out
    out = {
        "B": B, "N": N,
        "cpu_ref_us": t_ref * 1e6,
        "hbm_bytes_unfused": unfused,
        "hbm_bytes_fused": fused,
        "traffic_reduction": unfused / fused,
        "projected_tpu_us_fused": fused / 819e9 * 1e6,
        "projected_tpu_us_unfused": unfused / 819e9 * 1e6,
    }
    save_json("kernel_pbit_update", out)
    emit("kernel_pbit_halfsweep_ref", t_ref * 1e6,
         f"traffic_x{out['traffic_reduction']:.2f}")
    return out


if __name__ == "__main__":
    run()
