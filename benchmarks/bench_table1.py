"""Paper Table 1: chip comparison metrics, mapped to the simulator/TPU.

Chip numbers (440 spins, Gibbs sampling, 50 ns TTS-class updates) are the
silicon's; here we report what the TPU-native engine achieves per sweep,
both through the jnp reference path and the fused Pallas kernel path
(interpret mode on CPU — per-sweep *work*, plus the analytic TPU projection
from the roofline model).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json, timer
from repro.core import pbit
from repro.core.cd import PBitMachine, quantize_codes
from repro.core.annealing import sk_instance
from repro.core.chimera import make_chip_graph
from repro.core.hardware import HardwareConfig
from repro.kernels.ops import make_kernel_half_sweep


def run() -> dict:
    g = make_chip_graph()
    machine = PBitMachine.create(g, jax.random.PRNGKey(0),
                                 HardwareConfig(), w_scale=0.02)
    J, h = sk_instance(g, jax.random.PRNGKey(1))
    chip = machine.program(quantize_codes(jnp.asarray(J)),
                           quantize_codes(jnp.asarray(h)))
    chains = 64
    color = jnp.asarray(g.color)
    m0 = pbit.random_spins(jax.random.PRNGKey(2), chains, g.n_nodes)
    noise = pbit.make_philox_noise(chains, g.n_nodes)
    betas = jnp.ones((100,), jnp.float32)

    def sweep100(m):
        out, _, _ = pbit.gibbs_sample(chip, color, m, betas,
                                      jax.random.PRNGKey(3), noise)
        return out

    f = jax.jit(sweep100)
    dt = timer(f, m0)
    flips = 100 * chains * g.n_nodes
    us_per_sweep = dt / 100 * 1e6

    # analytic TPU v5e projection for the fused kernel (roofline):
    # per half-sweep matmul: 2 * B * N * N MACs, bf16 on MXU
    B, N = chains, g.n_nodes
    flops_per_sweep = 2 * 2 * B * N * N
    t_mxu = flops_per_sweep / 197e12
    bytes_per_sweep = 2 * (N * N * 2 + 3 * B * N * 2)  # W + spins/noise/out
    t_hbm = bytes_per_sweep / 819e9
    tpu_sweep_s = max(t_mxu, t_hbm)

    out = {
        "spins": int(g.n_nodes),
        "graph": "Chimera 7x8 (1 cell masked)",
        "spin_update": "chromatic Gibbs (2 half-sweeps)",
        "hamiltonian": "Gibbs sampling (paper row: 'This Work')",
        "chains": chains,
        "cpu_us_per_sweep_per_chain": us_per_sweep / chains,
        "cpu_flips_per_second": flips / dt,
        "projected_tpu_us_per_sweep_64chains": tpu_sweep_s * 1e6,
        "projected_tpu_flips_per_ns": flips / 100 / tpu_sweep_s / 1e9,
        "paper_chip_tts_ns": 50,
    }
    save_json("table1_throughput", out)
    emit("table1_gibbs_sweep_64chains", dt / 100 * 1e6,
         f"tpu_projected={tpu_sweep_s*1e6:.2f}us")
    return out


if __name__ == "__main__":
    run()
