"""Paper Fig 8b: full-adder distribution learning on the mismatched chip."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, save_json
from repro.core import tasks
from repro.core.cd import CDConfig, PBitMachine, train_cd
from repro.core.chimera import make_chimera
from repro.core.hardware import HardwareConfig

CFG = CDConfig(lr=6.0, cd_k=15, pos_sweeps=15, burn_in=3, chains=256,
               epochs=100)


def run() -> dict:
    g = make_chimera(1, 2)
    machine = PBitMachine.create(g, jax.random.PRNGKey(9),
                                 HardwareConfig(), beta=1.0, w_scale=0.05)
    task = tasks.full_adder_task(g)
    t0 = time.perf_counter()
    res = train_cd(machine, task.visible_idx, task.target_dist, CFG,
                   jax.random.PRNGKey(1), eval_every=20)
    dt = time.perf_counter() - t0
    out = {
        "kl_vs_epoch": res.kl_history,
        "kl_final": res.kl_history[-1][1],
        "kl_uniform_baseline": float(np.log(32 / 8)),  # 8 valid rows of 32
        "epochs": CFG.epochs,
        "train_seconds": dt,
    }
    save_json("fig8b_full_adder", out)
    emit("fig8b_full_adder_cd_epoch", dt / CFG.epochs * 1e6,
         f"KL_final={out['kl_final']:.3f}")
    return out


if __name__ == "__main__":
    run()
