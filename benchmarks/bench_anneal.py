"""Paper Fig 9a: simulated annealing of an SK spin glass, all 440 spins."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, save_json
from repro.core.annealing import AnnealConfig, anneal, sk_instance
from repro.core.cd import PBitMachine
from repro.core.chimera import make_chip_graph
from repro.core.hardware import HardwareConfig


def run() -> dict:
    g = make_chip_graph()
    machine = PBitMachine.create(g, jax.random.PRNGKey(3),
                                 HardwareConfig(), beta=1.0, w_scale=0.02)
    J, h = sk_instance(g, jax.random.PRNGKey(4))
    cfg = AnnealConfig(n_sweeps=1000, beta_start=0.02, beta_end=3.0,
                       chains=64)
    t0 = time.perf_counter()
    out_a = anneal(machine, J, h, cfg, jax.random.PRNGKey(5),
                   record_every=50)
    dt = time.perf_counter() - t0
    out = {
        "sweeps": out_a["sweeps"].tolist(),
        "energy_mean": out_a["energy_mean"].tolist(),
        "energy_min": out_a["energy_min"].tolist(),
        "best_energy": out_a["best_energy"],
        "chains": cfg.chains,
        "seconds": dt,
        "sweeps_per_second_per_chain": cfg.n_sweeps * cfg.chains / dt,
    }
    save_json("fig9a_sk_annealing", out)
    emit("fig9a_sk_anneal_sweep", dt / cfg.n_sweeps * 1e6,
         f"best_E={out['best_energy']:.0f}")
    return out


if __name__ == "__main__":
    run()
