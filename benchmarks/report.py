"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun."""
from __future__ import annotations

import json
import sys
from pathlib import Path

from benchmarks.roofline import roofline_row


def rows(out_dir: Path, mesh: str | None = None):
    out = []
    for p in sorted(out_dir.glob("*.json")):
        rec = json.loads(p.read_text())
        if mesh and rec.get("mesh") != mesh:
            continue
        out.append(rec)
    return out


def dryrun_table(out_dir: Path) -> str:
    lines = ["| arch | shape | mesh | status | compile s | args GiB/dev | "
             "temp GiB/dev | coll GiB/dev |",
             "|---|---|---|---|---|---|---|---|"]
    for rec in rows(out_dir):
        if rec["status"] == "skip":
            lines.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']}"
                         f" | skip | — | — | — | — |")
            continue
        m = rec.get("memory", {})
        c = rec.get("collectives", {})
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | ok | "
            f"{rec.get('compile_s', 0):.0f} | "
            f"{m.get('argument_bytes', 0)/2**30:.2f} | "
            f"{m.get('temp_bytes', 0)/2**30:.1f} | "
            f"{c.get('total_bytes', 0)/2**30:.2f} |")
    return "\n".join(lines)


def roofline_table(out_dir: Path, mesh: str = "pod") -> str:
    lines = ["| arch × shape | t_comp ms | t_mem ms | t_coll ms | bound | "
             "MODEL/HLO FLOPs | roofline frac |",
             "|---|---|---|---|---|---|---|"]
    for rec in rows(out_dir, mesh):
        if rec["status"] != "ok":
            continue
        r = roofline_row(rec)
        cell = f"{rec['arch']} × {rec['shape']}"
        lines.append(
            f"| {cell} | {r['t_compute_s']*1e3:.2f} | "
            f"{r['t_memory_s']*1e3:.2f} | {r['t_collective_s']*1e3:.2f} | "
            f"{r['bottleneck']} | {r['useful_flop_frac']:.2f} | "
            f"{r['roofline_frac']:.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    d = Path(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
    which = sys.argv[2] if len(sys.argv) > 2 else "roofline"
    if which == "dryrun":
        print(dryrun_table(d))
    else:
        print(roofline_table(d, sys.argv[3] if len(sys.argv) > 3
                             else "pod"))
