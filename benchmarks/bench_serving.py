"""Serving-layer latency/throughput benchmark (docs/serving.md).

The full-stack p-bits survey (arXiv:2302.06457) and the SpikeHard
methodology both argue the deployment figure of merit is not raw sweep
rate but the *split*: model-load overhead vs per-invocation overhead vs
steady-state throughput.  This bench publishes exactly that split for
the `repro.serve` stack, tracked across PRs in the ``serving`` section
of BENCH_kernel.json:

* ``model_load`` — cold cost of bringing a shape bucket up: Session
  construction, chip programming, and the first-call XLA compile
  (amortized by the LRU compile cache across every request that fits
  the bucket).
* ``invocation`` — warm per-launch overhead at S=1: what a request pays
  to ride a launch, excluding sweep work.
* ``steady_state`` — warm resident-launch throughput at the serving S:
  microseconds per sweep and sweeps/second at the paper-chip bucket.
* ``compile_cache`` — end-to-end request latency through
  `SamplerService` split three ways: ``recompile`` (first request into
  an empty cache — Session build + XLA compile; also published under
  the legacy ``miss`` key), ``hit`` (same program again), and
  ``program_swap`` (warm bucket, fresh couplings every request — the
  runtime-weight-streaming path, which must cost ~a hit, not a
  recompile).
* ``steady_state_degraded`` — (forced 2-device subprocess) per-sweep
  time on the healthy 2-shard mesh vs after a scripted mid-stream shard
  kill degraded it to single-device, plus the one-off replay/recompile
  cost of the degradation itself.

Usage: PYTHONPATH=src:. python benchmarks/bench_serving.py [--quick]
(--quick uses small shapes for CI smoke and does not touch the tracked
root file.)
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json, timed, timer

ROOT = Path(__file__).resolve().parent.parent


def _codes(g, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.integers(-40, 41, size=g.edges.shape[0], dtype=np.int32),
            rng.integers(-10, 11, size=g.n_nodes, dtype=np.int32))


def bench_bucket_split(bshape, B, S, iters=3) -> list[dict]:
    """model_load / invocation / steady_state rows for one bucket."""
    from repro import api
    from repro.core import pbit
    from repro.serve import SamplerService, make_bucket_graph

    svc = SamplerService(capacity_chains=B, buckets=(bshape,))
    g = make_bucket_graph(*bshape)
    spec = svc.bucket_spec(g)
    t_session, sess = timed(api.Session, spec)
    J, h = _codes(g)
    t_program, chip = timed(sess.program_edges, jnp.asarray(J),
                            jnp.asarray(h))
    km, kn = jax.random.split(jax.random.PRNGKey(0))
    m0 = pbit.random_spins(km, B, g.n_nodes)
    ns = sess.noise_state(kn)
    betas = jnp.ones((S,), jnp.float32)
    betas1 = jnp.ones((1,), jnp.float32)

    t_first, _ = timed(sess.sample, chip, m0, ns, betas)  # compile + run
    t_steady = timer(sess.sample, chip, m0, ns, betas, warmup=0,
                     iters=iters)
    t_invoke = timer(sess.sample, chip, m0, ns, betas1, iters=iters)

    bucket = f"{bshape[0]}x{bshape[1]}"
    return [
        {"phase": "model_load", "bucket": bucket, "N": int(g.n_nodes),
         "B": B, "session_build_ms": t_session * 1e3,
         "program_ms": t_program * 1e3,
         "first_call_compile_ms": max(t_first - t_steady, 0.0) * 1e3},
        {"phase": "invocation", "bucket": bucket, "N": int(g.n_nodes),
         "B": B, "S": 1, "us_per_call": t_invoke * 1e6},
        {"phase": "steady_state", "bucket": bucket, "N": int(g.n_nodes),
         "B": B, "S": S, "us_per_sweep": t_steady / S * 1e6,
         "sweeps_per_sec": S / t_steady,
         "chain_sweeps_per_sec": S * B / t_steady},
    ]


def bench_compile_cache(bshape, B, S) -> dict:
    """End-to-end request latency: recompile vs hit vs program swap.

    ``miss_ms``/``recompile_ms`` are the same event under two names (the
    old dashboard key survives the split): the first request into an
    empty cache pays Session build + XLA compile.  ``program_swap_ms``
    re-codes the warm bucket with fresh couplings every request — the
    program is a runtime operand (`Session.sample_program`), so a swap
    rides the compiled executable and must sit near ``hit_ms``, orders
    of magnitude under ``recompile_ms``."""
    from repro.core.chimera import make_chimera
    from repro.serve import SampleRequest, SamplerService

    svc = SamplerService(capacity_chains=B, buckets=(bshape,))
    g = make_chimera(*bshape)
    J, h = _codes(g)

    def request_latency(J, h):
        t0 = time.perf_counter()
        t = svc.submit(SampleRequest(tenant="bench", graph=g, J_codes=J,
                                     h_codes=h, chains=1, n_sweeps=S))
        svc.drain()
        assert t.result().status == "ok"
        return (time.perf_counter() - t0) * 1e3

    miss_ms = request_latency(J, h)
    hit_ms = min(request_latency(J, h) for _ in range(3))
    swap_ms = min(request_latency(*_codes(g, seed)) for seed in (1, 2, 3))
    # new couplings every swap request, still exactly one compile ever
    assert svc.cache.stats()["misses"] == 1
    return {"phase": "compile_cache",
            "bucket": f"{bshape[0]}x{bshape[1]}", "B": B, "S": S,
            "miss_ms": miss_ms, "hit_ms": hit_ms,
            "speedup": miss_ms / max(hit_ms, 1e-9),
            "recompile_ms": miss_ms, "program_swap_ms": swap_ms,
            "swap_speedup": miss_ms / max(swap_ms, 1e-9)}


_DEGRADED_WORKER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import jax
import numpy as np
from jax.sharding import Mesh
from repro.core.chimera import make_chimera
from repro.serve import (FaultEvent, FaultInjector, FaultPlan,
                         SampleRequest, SamplerService,
                         ShardHealthMonitor)

ROWS, COLS, B, S, R = {rows}, {cols}, {B}, {S}, {R}
KILL = R // 2
mesh = Mesh(np.asarray(jax.devices()), ("data",))
plan = FaultPlan.make([FaultEvent(step=KILL, kind="kill_shard", shard=1)])
svc = SamplerService(seed=0, capacity_chains=B, mesh=mesh,
                     monitor=ShardHealthMonitor(),
                     injector=FaultInjector(plan),
                     buckets=((ROWS, COLS),))
g = make_chimera(ROWS, COLS)
rng = np.random.default_rng(0)
J = rng.integers(-40, 41, size=g.edges.shape[0], dtype=np.int32)
h = rng.integers(-10, 11, size=g.n_nodes, dtype=np.int32)
# chains=B: each request fills a launch, so launch seq == request index
tickets = [svc.submit(SampleRequest(
    tenant="bench", graph=g, J_codes=J, h_codes=h, chains=B,
    n_sweeps=S, timeout_s=3600.0)) for _ in range(R)]
svc.drain()
res = [t.result() for t in tickets]
assert all(r.status == "ok" for r in res), [r.status for r in res]
by_seq = {{r.launch_seq: r for r in res}}
healthy = [by_seq[i].exec_s for i in range(1, KILL)]        # skip compile
degraded = [by_seq[i].exec_s for i in range(KILL + 1, R)]   # skip replay
med = lambda xs: sorted(xs)[len(xs) // 2]
print(json.dumps({{
    "healthy_2dev_us_per_sweep": med(healthy) / S * 1e6,
    "degraded_1dev_us_per_sweep": med(degraded) / S * 1e6,
    "replay_recompile_ms": by_seq[KILL].exec_s * 1e3,
    "zero_drops": svc.metrics["completed"] == svc.metrics["admitted"] == R,
    "state": svc.state,
    "degradations": svc.metrics["degradations"],
}}))
"""


def bench_degraded(bshape, B, S, R) -> dict:
    env = {**os.environ, "JAX_PLATFORMS": "cpu",
           "PYTHONPATH": f"{ROOT}/src"}
    script = _DEGRADED_WORKER.format(rows=bshape[0], cols=bshape[1],
                                     B=B, S=S, R=R)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-4000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["zero_drops"] and payload["state"] == "single", payload
    from repro.core.chimera import make_chimera
    g = make_chimera(*bshape)
    row = {"phase": "steady_state_degraded",
           "bucket": f"{bshape[0]}x{bshape[1]}", "N": int(g.n_nodes),
           "B": B, "S": S, "n_requests": R, "killed_shard": 1}
    row.update({k: payload[k] for k in
                ("healthy_2dev_us_per_sweep", "degraded_1dev_us_per_sweep",
                 "replay_recompile_ms", "degradations")})
    return row


def run(quick: bool = False) -> dict:
    if quick:
        split = bench_bucket_split((2, 2), B=8, S=8, iters=2)
        cache = bench_compile_cache((2, 2), B=8, S=8)
        degraded = bench_degraded((2, 2), B=4, S=8, R=6)
    else:
        # the paper-chip bucket (7x8 Chimera = 448 sites) at serving batch
        split = bench_bucket_split((7, 8), B=16, S=32, iters=3)
        cache = bench_compile_cache((7, 8), B=16, S=32)
        degraded = bench_degraded((4, 4), B=8, S=16, R=8)
    rows = split + [cache, degraded]
    results = {
        "note": "model-load vs invocation vs steady-state split for the "
                "repro.serve stack (docs/serving.md §Benchmark "
                "methodology); degraded row = scripted mid-stream shard "
                "kill on a forced 2-device host",
        "rows": rows,
    }

    steady = next(r for r in rows if r["phase"] == "steady_state")
    load = next(r for r in rows if r["phase"] == "model_load")
    emit("serving_steady_state", steady["us_per_sweep"],
         f"N={steady['N']} sweeps/s={steady['sweeps_per_sec']:.1f}")
    emit("serving_model_load_ms",
         load["session_build_ms"] + load["first_call_compile_ms"],
         f"program={load['program_ms']:.1f}ms")
    emit("serving_cache_hit_ms", cache["hit_ms"],
         f"miss={cache['miss_ms']:.0f}ms ({cache['speedup']:.0f}x)")
    emit("serving_program_swap_ms", cache["program_swap_ms"],
         f"recompile={cache['recompile_ms']:.0f}ms "
         f"({cache['swap_speedup']:.0f}x)")
    emit("serving_degraded_us_per_sweep",
         degraded["degraded_1dev_us_per_sweep"],
         f"healthy_2dev={degraded['healthy_2dev_us_per_sweep']:.0f}us")

    save_json("serving", results)
    if not quick:
        # tracked across PRs; merge-preserve the other benches' sections
        root = ROOT / "BENCH_kernel.json"
        merged = json.loads(root.read_text()) if root.exists() else {}
        merged["serving"] = results
        root.write_text(json.dumps(merged, indent=1))
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes / CI smoke; skips the tracked root "
                         "file")
    args = ap.parse_args()
    run(quick=args.quick)
