"""Paper Fig 9b: Max-Cut via annealing on the chip graph."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, save_json
from repro.core.annealing import AnnealConfig
from repro.core.cd import PBitMachine
from repro.core.chimera import make_chip_graph
from repro.core.hardware import HardwareConfig
from repro.core.maxcut import random_chimera_maxcut, solve_maxcut


def run() -> dict:
    g = make_chip_graph()
    machine = PBitMachine.create(g, jax.random.PRNGKey(0),
                                 HardwareConfig(), beta=1.0, w_scale=0.03)
    prob = random_chimera_maxcut(g, jax.random.PRNGKey(1), edge_prob=0.8)
    cfg = AnnealConfig(n_sweeps=500, beta_start=0.05, beta_end=3.0,
                       chains=64)
    t0 = time.perf_counter()
    sol = solve_maxcut(machine, prob, cfg, jax.random.PRNGKey(2))
    dt = time.perf_counter() - t0

    rng = np.random.default_rng(0)
    rand_cut = max(prob.cut_value(rng.choice([-1.0, 1.0], size=g.n_nodes))
                   for _ in range(64))
    out = {
        "n_nodes": int(g.n_nodes),
        "n_problem_edges": int(prob.n_edges),
        "cut_annealed": sol["cut"],
        "cut_polished": sol["cut_polished"],
        "cut_random_best_of_64": rand_cut,
        "upper_bound_total_weight": sol["upper_bound"],
        "fraction_of_ub": sol["cut_polished"] / sol["upper_bound"],
        "seconds": dt,
    }
    save_json("fig9b_maxcut", out)
    emit("fig9b_maxcut_solve", dt * 1e6,
         f"cut={out['cut_polished']:.0f}/"
         f"{out['upper_bound_total_weight']:.0f}")
    return out


if __name__ == "__main__":
    run()
