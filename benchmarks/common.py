"""Shared benchmark utilities."""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax

RESULTS = Path("results/bench")


def timer(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall time of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(name: str, payload: dict) -> None:
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.json").write_text(json.dumps(payload, indent=1))
