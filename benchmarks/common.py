"""Shared benchmark utilities."""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax

RESULTS = Path("results/bench")


def timer(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall time of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        ts.append(timed(fn, *args)[0])
    return sorted(ts)[len(ts) // 2]


def timed(fn, *args):
    """One-shot wall time of fn(*args): (seconds, result), result fully
    materialized via block_until_ready — the only honest way to time a
    dispatch under jax's async execution.  Use `timer` for steady-state
    medians; use this for costs that exist exactly once (first-call
    compile, a cache miss, a cold model load)."""
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(*args))
    return time.perf_counter() - t0, out


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(name: str, payload: dict) -> None:
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.json").write_text(json.dumps(payload, indent=1))
