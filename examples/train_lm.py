"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the production train step (sharded fwd+bwd+AdamW, remat, checkpoints)
on a CPU-sized mesh.  The same entry point scales to the pod meshes via
launch/train.py.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import ModelCfg, ShapeCfg
from repro.data.pipeline import DataConfig, make_source
from repro.launch import mesh as mesh_mod
from repro.launch.steps import make_train_step
from repro.models.model import build_model
from repro.optim import adamw

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=256)
args = ap.parse_args()

# ~100M params: 12L x 768, llama-style (deepseek family geometry, scaled)
cfg = ModelCfg(
    name="lm-100m", family="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
    vocab_size=32000, dtype="float32", remat=False)
shape = ShapeCfg("train", args.seq, args.batch, "train")
mesh = mesh_mod.make_host_mesh(1, 1)

model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
print(f"model: {cfg.name} ({n/1e6:.1f}M params)")

opt_cfg = adamw.AdamWConfig(lr=6e-4, warmup_steps=30,
                            total_steps=args.steps)
step = make_train_step(cfg, shape, mesh, opt_cfg)
opt_state = adamw.init(params)
src = make_source(DataConfig(seed=0, vocab_size=cfg.vocab_size))

t0 = time.time()
for s in range(args.steps):
    batch = src.batch(s, args.batch, args.seq)
    params, opt_state, m = step.fn(params, opt_state, batch)
    if (s + 1) % 25 == 0 or s == 0:
        print(f"step {s+1:4d}  loss={float(m['loss']):.4f}  "
              f"lr={float(m['lr']):.2e}  "
              f"gnorm={float(m['grad_norm']):.2f}")
dt = time.time() - t0
print(f"\n{args.steps} steps in {dt:.0f}s "
      f"({args.steps*args.batch*args.seq/dt/1e3:.1f}k tok/s on CPU)")
