"""LANGUAGE-MODEL serving example: batched prefill + decode for a
decoder-only transformer (thin wrapper over the LM demo driver,
repro/launch/serve.py).

Not the p-bit sampling service — that is `python -m repro.serve`
(see docs/serving.md and examples/serve_pbit.py).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "gemma2-2b", "--reduced",
                "--batch", "4", "--prompt-len", "32", "--gen", "32",
                *sys.argv[1:]]
    main()
