"""Serving example: batched prefill + decode (thin wrapper over the
production driver, repro/launch/serve.py).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "gemma2-2b", "--reduced",
                "--batch", "4", "--prompt-len", "32", "--gen", "32",
                *sys.argv[1:]]
    main()
