"""Paper Fig 9: optimization on the chip — SK annealing + Max-Cut.

Both workloads run through one compiled `api.Session` per anneal
schedule (`machine.session(schedule=api.Anneal(...))`); `anneal` and
`solve_maxcut` construct no samplers of their own — see docs/api.md.

Run:  PYTHONPATH=src python examples/maxcut.py
(REPRO_EXAMPLE_QUICK=1 shrinks the run for the CI smoke job.)
"""
import os

import jax
import numpy as np

from repro.core import (
    AnnealConfig,
    HardwareConfig,
    PBitMachine,
    anneal,
    random_chimera_maxcut,
    sk_instance,
    solve_maxcut,
)
from repro.core.chimera import make_chip_graph

graph = make_chip_graph()
machine = PBitMachine.create(graph, jax.random.PRNGKey(0),
                             HardwareConfig(), beta=1.0, w_scale=0.03)
quick = bool(os.environ.get("REPRO_EXAMPLE_QUICK"))
sweeps = 150 if quick else 600
chains = 16 if quick else 64

# --- Fig 9a: SK spin glass annealing -----------------------------------
J, h = sk_instance(graph, jax.random.PRNGKey(4))
out = anneal(machine, J, h,
             AnnealConfig(n_sweeps=sweeps, beta_start=0.02, beta_end=3.0,
                          chains=chains),
             jax.random.PRNGKey(5), record_every=sweeps // 10)
print(f"SK annealing energy trajectory (mean over {chains} chains):")
for s, e in zip(out["sweeps"], out["energy_mean"]):
    print(f"  sweep {s:4d}: E = {e:9.1f}")
print(f"best energy found: {out['best_energy']:.1f}")

# --- Fig 9b: Max-Cut -----------------------------------------------------
prob = random_chimera_maxcut(graph, jax.random.PRNGKey(1), edge_prob=0.8)
cut_cfg = AnnealConfig(n_sweeps=sweeps, beta_start=0.05, beta_end=3.0,
                       chains=chains)
# explicit Session: compile the anneal schedule once, hand it to the solver
session = machine.session(schedule=cut_cfg.to_schedule(),
                          chains=cut_cfg.chains)
sol = solve_maxcut(machine, prob, cut_cfg, jax.random.PRNGKey(2),
                   session=session)
rng = np.random.default_rng(0)
rand = max(prob.cut_value(rng.choice([-1.0, 1.0], size=graph.n_nodes))
           for _ in range(64))
print(f"\nMax-Cut on {prob.n_edges} chimera edges:")
print(f"  annealed cut : {sol['cut']:.0f}")
print(f"  + 1-opt      : {sol['cut_polished']:.0f}")
print(f"  random best  : {rand:.0f}")
print(f"  upper bound  : {sol['upper_bound']:.0f}")
