"""Paper Fig 9: optimization on the chip — SK annealing + Max-Cut.

Run:  PYTHONPATH=src python examples/maxcut.py
"""
import jax
import numpy as np

from repro.core import (
    AnnealConfig,
    HardwareConfig,
    PBitMachine,
    anneal,
    random_chimera_maxcut,
    sk_instance,
    solve_maxcut,
)
from repro.core.chimera import make_chip_graph

graph = make_chip_graph()
machine = PBitMachine.create(graph, jax.random.PRNGKey(0),
                             HardwareConfig(), beta=1.0, w_scale=0.03)

# --- Fig 9a: SK spin glass annealing -----------------------------------
J, h = sk_instance(graph, jax.random.PRNGKey(4))
out = anneal(machine, J, h,
             AnnealConfig(n_sweeps=600, beta_start=0.02, beta_end=3.0,
                          chains=64),
             jax.random.PRNGKey(5), record_every=60)
print("SK annealing energy trajectory (mean over 64 chains):")
for s, e in zip(out["sweeps"], out["energy_mean"]):
    print(f"  sweep {s:4d}: E = {e:9.1f}")
print(f"best energy found: {out['best_energy']:.1f}")

# --- Fig 9b: Max-Cut -----------------------------------------------------
prob = random_chimera_maxcut(graph, jax.random.PRNGKey(1), edge_prob=0.8)
sol = solve_maxcut(machine, prob,
                   AnnealConfig(n_sweeps=600, beta_start=0.05,
                                beta_end=3.0, chains=64),
                   jax.random.PRNGKey(2))
rng = np.random.default_rng(0)
rand = max(prob.cut_value(rng.choice([-1.0, 1.0], size=graph.n_nodes))
           for _ in range(64))
print(f"\nMax-Cut on {prob.n_edges} chimera edges:")
print(f"  annealed cut : {sol['cut']:.0f}")
print(f"  + 1-opt      : {sol['cut_polished']:.0f}")
print(f"  random best  : {rand:.0f}")
print(f"  upper bound  : {sol['upper_bound']:.0f}")
