"""Quickstart: learn an AND gate on a simulated mismatched p-bit chip.

This is the paper's Fig 7 experiment end-to-end in ~40 lines of public API:
build the chip graph, sample a chip instance (process variation included),
train with in-situ contrastive divergence, and inspect the learned visible
distribution.

Run:  PYTHONPATH=src python examples/quickstart.py
(REPRO_EXAMPLE_QUICK=1 shrinks the run for the CI smoke job.)
"""
import os

import jax

from repro.core import HardwareConfig, PBitMachine, CDConfig
from repro.core.chimera import make_chimera
from repro.core import tasks

# one Chimera unit cell = a 4:4 RBM, exactly like the chip's
graph = make_chimera(1, 1)

# a chip *instance*: mismatch sampled from the process-variation model.
# All sampling below goes through one compiled api.Session under the hood
# (machine.session(...) — see docs/api.md).
machine = PBitMachine.create(
    graph, jax.random.PRNGKey(42), HardwareConfig(), beta=1.0,
    w_scale=0.05)

# target: uniform distribution over AND's 4 valid truth-table rows
task = tasks.and_gate_task(graph)
print(f"chip: {graph.n_nodes} p-bits, task '{task.name}', "
      f"{task.n_visible} visible spins")

quick = bool(os.environ.get("REPRO_EXAMPLE_QUICK"))
cfg = CDConfig(lr=6.0, cd_k=15, pos_sweeps=15, chains=256,
               epochs=12 if quick else 80)
result = task.train(machine, cfg, jax.random.PRNGKey(7),
                    eval_every=4 if quick else 20, verbose=True)

dist = task.sample_dist(machine, result.Jm, result.hm,
                        jax.random.PRNGKey(3))
print("\nlearned visible distribution (A, B, A∧B):")
for code in range(8):
    bits = [(code >> i) & 1 for i in range(3)]
    target = task.target_dist[code]
    print(f"  A={bits[0]} B={bits[1]} C={bits[2]}  "
          f"p={dist[code]:.3f}  target={target:.3f}"
          + ("   <-- valid row" if target > 0 else ""))
