"""Paper Fig 8b: learn a full adder's probability distribution on-chip,
then *use* it for inference — two ways.

1. The learned machine: CD-trained couplings, clamp (A, B, Cin), read
   the mean of the free-running (S, Cout) spins.  This is the paper's
   original demo and it is known-weak (~3/8 truth-table rows): the
   learned Hamiltonian's ground structure is approximate and the raw
   mean readout has no error correction.
2. The PSL compiler (src/repro/psl, docs/psl.md): the *exact* full-adder
   Hamiltonian chain-embedded onto the Chimera graph, inputs clamped as
   whole chains, outputs decoded by clause-filtered chain-majority
   vote.  8/8 rows.

Run:  PYTHONPATH=src python examples/full_adder.py
      REPRO_EXAMPLE_QUICK=1 shrinks the CD run for CI smoke.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import HardwareConfig, PBitMachine, CDConfig
from repro.core import tasks
from repro.core.chimera import make_chimera

QUICK = bool(os.environ.get("REPRO_EXAMPLE_QUICK"))

graph = make_chimera(1, 2)   # two coupled cells: 5 visibles + 8 hiddens
machine = PBitMachine.create(graph, jax.random.PRNGKey(0),
                             HardwareConfig(), beta=1.0, w_scale=0.05)
task = tasks.full_adder_task(graph)

cfg = CDConfig(lr=6.0, cd_k=15, pos_sweeps=15, chains=256,
               epochs=12 if QUICK else 120)
res = task.train(machine, cfg, jax.random.PRNGKey(1),
                 eval_every=6 if QUICK else 30, verbose=True)

# -- route 1: learned machine, raw clamped inference ---------------------
session = machine.session(
    schedule=api.Constant(beta=2.0, n_sweeps=120), chains=128)
chip = session.program_master(jnp.asarray(res.Jm), jnp.asarray(res.hm))
vis = task.visible_idx
clamp_mask = jnp.zeros((graph.n_nodes,), bool).at[vis[:3]].set(True)
print("\nlearned machine, raw clamped inference (mode of S, Cout):")
correct = 0
for a in (0, 1):
    for b in (0, 1):
        for cin in (0, 1):
            cv = jnp.zeros((128, graph.n_nodes))
            cv = cv.at[:, vis[0]].set(2 * a - 1)
            cv = cv.at[:, vis[1]].set(2 * b - 1)
            cv = cv.at[:, vis[2]].set(2 * cin - 1)
            m0 = session.random_spins(jax.random.PRNGKey(0))
            ns = session.noise_state(jax.random.PRNGKey(2))
            m, _, traj = session.sample(
                chip, m0, ns, clamp_mask=clamp_mask, clamp_values=cv,
                collect=True)
            samples = np.asarray(traj[40:])
            s = int(samples[..., vis[3]].mean() > 0)
            cout = int(samples[..., vis[4]].mean() > 0)
            want_s = a ^ b ^ cin
            want_c = (a & b) | (cin & (a ^ b))
            ok = (s == want_s) and (cout == want_c)
            correct += ok
            print(f"  {a}+{b}+{cin} -> S={s} Cout={cout} "
                  f"(want {want_s},{want_c}) {'OK' if ok else 'x'}")
print(f"{correct}/8 adder rows correct (learned machine)")

# -- route 2: PSL-compiled exact Hamiltonian + chain-majority readout ----
print("\nPSL compiler (chain embedding + clause-filtered majority):")
out = tasks.full_adder_inference(make_chimera(2, 2),
                                 key=jax.random.PRNGKey(3))
for (a, b, cin), (s, cout, ok) in sorted(out["rows"].items()):
    print(f"  {a}+{b}+{cin} -> S={s} Cout={cout} {'OK' if ok else 'x'}")
print(f"{out['rows_correct']}/8 adder rows correct (PSL), "
      f"broken-chain fraction {out['broken_chain_fraction']:.3f}")
