"""Multi-tenant p-bit sampling service, end to end (docs/serving.md).

Three tenants share one `repro.serve.SamplerService`: an AND-gate
inference problem and two random instances, all embedded into shape
buckets and multiplexed onto the chains axis of shared launches — then
the same traffic is replayed under a scripted link flap + straggler to
show the resilience path leaves results untouched.  A final hot-swap
demo retargets a warm bucket with fresh couplings every call through
`Session.sample_program` (runtime weight streaming) and prints the
measured swap latency against the pre-streaming per-program path
(eager `program_edges` + `sample`) and a full Session recompile.

Run:  PYTHONPATH=src python examples/serve_pbit.py
Quick CI mode:  REPRO_EXAMPLE_QUICK=1 (smaller sweep counts)
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core.chimera import make_chimera
from repro.serve import (
    FaultEvent,
    FaultInjector,
    FaultPlan,
    SampleRequest,
    SamplerService,
    ShardHealthMonitor,
)

QUICK = bool(os.environ.get("REPRO_EXAMPLE_QUICK"))
SWEEPS = 8 if QUICK else 64


def build_requests():
    """Three tenants, two buckets, one shared chip program per bucket.

    The first tenant runs clamped inference — a ferromagnetic instance
    with its first two spins pinned to query data per chain (the
    chains-axis multiplexing model: same chip, per-chain inputs)."""
    g_small = make_chimera(1, 1)
    J_ferro = np.full(g_small.edges.shape[0], 40, np.int32)
    h_zero = np.zeros(g_small.n_nodes, np.int32)
    mask = np.zeros(g_small.n_nodes, bool)
    mask[:2] = True
    queries = np.zeros((4, g_small.n_nodes), np.float32)
    queries[:, 0] = (1, 1, -1, -1)
    queries[:, 1] = (1, -1, 1, -1)
    g_big = make_chimera(2, 2)
    rng = np.random.default_rng(0)
    J_big = rng.integers(-40, 41, size=g_big.edges.shape[0],
                         dtype=np.int32)
    h_big = rng.integers(-10, 11, size=g_big.n_nodes, dtype=np.int32)
    reqs = [
        SampleRequest(tenant="inference-inc", graph=g_small,
                      J_codes=J_ferro, h_codes=h_zero, chains=4,
                      clamp_mask=mask, clamp_values=queries,
                      n_sweeps=SWEEPS),
        SampleRequest(tenant="anneal-co", graph=g_big, J_codes=J_big,
                      h_codes=h_big, chains=2, n_sweeps=SWEEPS),
        SampleRequest(tenant="sampling-ltd", graph=g_big, J_codes=J_big,
                      h_codes=h_big, chains=2, n_sweeps=SWEEPS),
    ]
    return reqs


def run(injector=None, monitor=None):
    svc = SamplerService(seed=0, capacity_chains=8, injector=injector,
                         monitor=monitor, backoff_s=0.01,
                         max_backoff_s=0.1)
    tickets = [svc.submit(r) for r in build_requests()]
    svc.drain()
    return svc, [t.result() for t in tickets]


def hot_swap_demo():
    """Runtime weight streaming on a warm bucket Session: new couplings
    every call, one compiled executable throughout."""
    import jax
    import jax.numpy as jnp

    from repro import api
    from repro.serve import SamplerService, make_bucket_graph

    svc = SamplerService(seed=0, capacity_chains=8)
    g = make_bucket_graph(2, 2)
    ses = api.Session(svc.bucket_spec(g))
    betas = jnp.ones((SWEEPS,), jnp.float32)
    m0 = ses.random_spins(jax.random.PRNGKey(1))
    ns = ses.noise_state(jax.random.PRNGKey(2))
    rng = np.random.default_rng(0)

    def codes():
        return (jnp.asarray(rng.integers(-40, 41, g.edges.shape[0]),
                            jnp.int32),
                jnp.asarray(rng.integers(-10, 11, g.n_nodes), jnp.int32))

    def med(fn, n=5):
        ts = []
        for _ in range(n):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[len(ts) // 2] * 1e3

    # warm both paths once (first call pays the one-time XLA compile)
    J0, h0 = codes()
    jax.block_until_ready(
        ses.sample_program(ses.make_program(J0, h0), m0, ns, betas)[0])
    jax.block_until_ready(ses.sample(ses.program_edges(J0, h0), m0, ns,
                                     betas)[0])

    # hot swap: fresh couplings every call, program as runtime operand
    swap_ms = med(lambda: ses.sample_program(
        ses.make_program(*codes()), m0, ns, betas)[0])
    # the PR-7 per-program path: eagerly compile each program through
    # the analog model, then sample with the chip as argument (what the
    # serving cache's per-entry chip LRU used to amortize)
    eager_ms = med(lambda: ses.sample(ses.program_edges(*codes()), m0, ns,
                                      betas)[0])
    # full rebuild: what a value-keyed fingerprint forced per instance
    t0 = time.perf_counter()
    fresh = api.Session(svc.bucket_spec(g))
    jax.block_until_ready(fresh.sample(fresh.program_edges(*codes()), m0,
                                       ns, betas)[0])
    rebuild_ms = (time.perf_counter() - t0) * 1e3

    print("=== hot swap: new couplings per call, warm 2x2 bucket ===")
    print(f"  program swap (sample_program):   {swap_ms:8.2f} ms/call")
    print(f"  per-program eager (PR-7 path):   {eager_ms:8.2f} ms/call")
    print(f"  session rebuild + compile:       {rebuild_ms:8.2f} ms")
    print(f"  swap vs rebuild: {rebuild_ms / max(swap_ms, 1e-9):.0f}x")


def main():
    print("=== clean run ===")
    svc, clean = run()
    for r in clean:
        print(f"  {r.tenant:<14} {r.status:<4} bucket="
              f"{r.bucket_shape[0]}x{r.bucket_shape[1]} "
              f"launch={r.launch_seq} offset={r.chain_offset} "
              f"exec={r.exec_s * 1e3:.1f}ms")
    shared = clean[1].launch_seq == clean[2].launch_seq
    print(f"  tenants anneal-co + sampling-ltd shared one launch: "
          f"{shared}")
    print(f"  cache: {svc.cache.stats()}")

    print("=== same traffic under a link flap + straggler ===")
    plan = FaultPlan.make([
        FaultEvent(step=0, kind="link_flap", flaps=2),
        FaultEvent(step=1, kind="straggler", delay_s=0.05),
    ])
    svc2, faulted = run(FaultInjector(plan), ShardHealthMonitor())
    identical = all(np.array_equal(a.spins, b.spins)
                    for a, b in zip(clean, faulted))
    print(f"  retries absorbed: "
          f"{svc2.metrics['transient_retries']} transient")
    print(f"  results bit-identical to clean run: {identical}")
    assert identical, "fault schedule must not change results"
    assert all(r.status == "ok" for r in faulted)

    hot_swap_demo()
    print("OK")


if __name__ == "__main__":
    main()
