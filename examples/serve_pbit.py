"""Multi-tenant p-bit sampling service, end to end (docs/serving.md).

Three tenants share one `repro.serve.SamplerService`: an AND-gate
inference problem and two random instances, all embedded into shape
buckets and multiplexed onto the chains axis of shared launches — then
the same traffic is replayed under a scripted link flap + straggler to
show the resilience path leaves results untouched.

Run:  PYTHONPATH=src python examples/serve_pbit.py
Quick CI mode:  REPRO_EXAMPLE_QUICK=1 (smaller sweep counts)
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.chimera import make_chimera
from repro.serve import (
    FaultEvent,
    FaultInjector,
    FaultPlan,
    SampleRequest,
    SamplerService,
    ShardHealthMonitor,
)

QUICK = bool(os.environ.get("REPRO_EXAMPLE_QUICK"))
SWEEPS = 8 if QUICK else 64


def build_requests():
    """Three tenants, two buckets, one shared chip program per bucket.

    The first tenant runs clamped inference — a ferromagnetic instance
    with its first two spins pinned to query data per chain (the
    chains-axis multiplexing model: same chip, per-chain inputs)."""
    g_small = make_chimera(1, 1)
    J_ferro = np.full(g_small.edges.shape[0], 40, np.int32)
    h_zero = np.zeros(g_small.n_nodes, np.int32)
    mask = np.zeros(g_small.n_nodes, bool)
    mask[:2] = True
    queries = np.zeros((4, g_small.n_nodes), np.float32)
    queries[:, 0] = (1, 1, -1, -1)
    queries[:, 1] = (1, -1, 1, -1)
    g_big = make_chimera(2, 2)
    rng = np.random.default_rng(0)
    J_big = rng.integers(-40, 41, size=g_big.edges.shape[0],
                         dtype=np.int32)
    h_big = rng.integers(-10, 11, size=g_big.n_nodes, dtype=np.int32)
    reqs = [
        SampleRequest(tenant="inference-inc", graph=g_small,
                      J_codes=J_ferro, h_codes=h_zero, chains=4,
                      clamp_mask=mask, clamp_values=queries,
                      n_sweeps=SWEEPS),
        SampleRequest(tenant="anneal-co", graph=g_big, J_codes=J_big,
                      h_codes=h_big, chains=2, n_sweeps=SWEEPS),
        SampleRequest(tenant="sampling-ltd", graph=g_big, J_codes=J_big,
                      h_codes=h_big, chains=2, n_sweeps=SWEEPS),
    ]
    return reqs


def run(injector=None, monitor=None):
    svc = SamplerService(seed=0, capacity_chains=8, injector=injector,
                         monitor=monitor, backoff_s=0.01,
                         max_backoff_s=0.1)
    tickets = [svc.submit(r) for r in build_requests()]
    svc.drain()
    return svc, [t.result() for t in tickets]


def main():
    print("=== clean run ===")
    svc, clean = run()
    for r in clean:
        print(f"  {r.tenant:<14} {r.status:<4} bucket="
              f"{r.bucket_shape[0]}x{r.bucket_shape[1]} "
              f"launch={r.launch_seq} offset={r.chain_offset} "
              f"exec={r.exec_s * 1e3:.1f}ms")
    shared = clean[1].launch_seq == clean[2].launch_seq
    print(f"  tenants anneal-co + sampling-ltd shared one launch: "
          f"{shared}")
    print(f"  cache: {svc.cache.stats()}")

    print("=== same traffic under a link flap + straggler ===")
    plan = FaultPlan.make([
        FaultEvent(step=0, kind="link_flap", flaps=2),
        FaultEvent(step=1, kind="straggler", delay_s=0.05),
    ])
    svc2, faulted = run(FaultInjector(plan), ShardHealthMonitor())
    identical = all(np.array_equal(a.spins, b.spins)
                    for a, b in zip(clean, faulted))
    print(f"  retries absorbed: "
          f"{svc2.metrics['transient_retries']} transient")
    print(f"  results bit-identical to clean run: {identical}")
    assert identical, "fault schedule must not change results"
    assert all(r.status == "ok" for r in faulted)
    print("OK")


if __name__ == "__main__":
    main()
