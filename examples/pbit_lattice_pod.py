"""The paper's chip at lattice scale: anneal a large Chimera p-bit fabric
through a mesh-sharded `api.Session` — cell rows partition over the
device mesh and only the O(√N) chain-coupler boundary spins move between
devices (ppermute halo exchange), exactly the chip's inter-cell wires.

Nothing O(N²) is ever built: the machine is sparse-native
(`SparseMismatch`, O(D·N)) and the sharded engine keeps per-device slot
tables local.  Under the default barrier policy a sharded run reproduces
the single-device spin trajectory bit for bit (docs/sharding.md).

``--sync`` demos the first-class synchronization policies (`api.Sync`):

  * ``barrier`` — per-half-sweep halo exchange, the bit-exact default;
  * ``halo4``   — exchange every 4th half-sweep, 4-sweep launches;
  * ``async``   — PASS-style: launch-resident bands, double-buffered
                  (fire-and-forget) exchanges at launch boundaries only.

With a relaxed policy the script runs the barrier baseline too and prints
the measured sweeps/sec for both plus the energy-trace gap — the
sampling-quality cost is measured, never assumed away.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/pbit_lattice_pod.py --sync async
(REPRO_EXAMPLE_QUICK=1 shrinks the lattice for the CI smoke job.)
"""
import argparse
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core.cd import PBitMachine
from repro.core.chimera import make_chimera
from repro.core.distributed import halo_bytes_per_sweep, sparse_energy
from repro.core.hardware import HardwareConfig
from repro.launch.mesh import halo_vs_hbm_seconds, make_line_mesh

SYNCS = {
    "barrier": api.Sync(),
    "halo4": api.Sync(halo_every=4, sweeps_per_launch=4),
    "async": api.Sync(halo_every=math.inf, mode="async",
                      sweeps_per_launch=4),
}

ap = argparse.ArgumentParser()
ap.add_argument("--sync", choices=sorted(SYNCS), default="barrier",
                help="shard synchronization policy (api.Sync)")
args = ap.parse_args()

quick = bool(os.environ.get("REPRO_EXAMPLE_QUICK"))
side = 8 if quick else 32          # 32x32 cells = 8192 p-bits
n_sweeps = 60 if quick else 400
rec = 12 if quick else 40          # energy-trace segment (divisible by 4)
chains = 4 if quick else 16

graph = make_chimera(side, side)
n_dev = len(jax.devices())
mesh = make_line_mesh() if (n_dev > 1 or args.sync != "barrier") else None
print(f"lattice: {side}x{side} cells = {graph.n_nodes} p-bits, "
      f"{graph.n_edges} couplers over {n_dev} device(s), "
      f"sync={args.sync}")

# sparse-native chip instance: process variation sampled straight into the
# O(D·N) slot layout; mesh+partition+sync ride the machine into every
# Session (backend stays "sparse", so relaxed policies run the scan path)
machine = PBitMachine.create(
    graph, jax.random.PRNGKey(0), HardwareConfig(), sparse=True,
    noise="counter", w_scale=0.05, mesh=mesh,
    partition=api.Partition(rows="data") if mesh is not None else None)

# random SK instance on the physical couplers (one 8-bit code per edge)
rng = np.random.default_rng(1)
codes = jnp.asarray(rng.integers(-100, 101, graph.n_edges), jnp.int32)
betas = api.Anneal(0.05, 2.5, n_sweeps=n_sweeps).betas()
segs = betas.reshape(n_sweeps // rec, rec)


def run_policy(sync):
    """Anneal under one Sync policy; returns (sweeps/sec, energy trace)."""
    spec = machine.sampler_spec(
        chains=chains, sync=sync if mesh is not None else None)
    session = api.Session(spec)
    chip = session.program_edges(codes,
                                 jnp.zeros((graph.n_nodes,), jnp.int32))
    state = session.init_state(jax.random.PRNGKey(2))
    # energy trace: the record loop, one Session call per segment
    m, ns = state.m, state.noise_state
    trace = []
    for seg in segs:
        m, ns, _ = session.sample(chip, m, ns, seg)
        trace.append(float(sparse_energy(chip, m).mean()) / graph.n_nodes)
    e = np.asarray(sparse_energy(chip, m))
    # throughput: median of fresh whole-schedule calls (chaining
    # un-consumed sharded outputs across timed calls stalls the
    # forced-host runtime and would swamp the policy signal)
    out = session.sample(chip, state.m, state.noise_state, betas)
    jax.block_until_ready(out[0])  # warm-up: compile + first run
    ts = []
    for _ in range(3):
        t0 = time.time()
        out = session.sample(chip, state.m, state.noise_state, betas)
        jax.block_until_ready(out[0])
        ts.append(time.time() - t0)
    dt = sorted(ts)[1]
    return session, m, n_sweeps / dt, np.asarray(trace), e, dt


session, m, sps, trace, e, dt = run_policy(SYNCS[args.sync])
print(f"energy/spin after anneal: best {e.min() / graph.n_nodes:+.3f}, "
      f"mean {e.mean() / graph.n_nodes:+.3f} over {chains} chains")
print(f"{n_sweeps * chains * graph.n_nodes / dt / 1e6:.1f}M spin-updates/s "
      f"({sps:.1f} sweeps/s, {dt:.2f}s for {n_sweeps} sweeps)")

if args.sync != "barrier":
    _, _, sps_base, trace_base, e_base, _ = run_policy(SYNCS["barrier"])
    gap = np.abs(trace - trace_base)
    print(f"vs barrier baseline: {sps_base:.1f} sweeps/s "
          f"({sps / sps_base:.2f}x), energy-trace gap "
          f"mean {gap.mean():.4f} / max {gap.max():.4f} per spin "
          f"(baseline best {e_base.min() / graph.n_nodes:+.3f})")

plan = session.partition_plan
if plan is not None:
    sync = SYNCS[args.sync]
    halo = halo_bytes_per_sweep(plan, chains, sync=sync)
    # local HBM traffic/sweep/device: slot weights + spins once per sweep
    hbm = (2 * 6 * graph.n_nodes * 4 + 2 * chains * graph.n_nodes * 4) \
        // max(n_dev, 1)
    napkin = halo_vs_hbm_seconds(halo // max(n_dev - 1, 1), hbm,
                                 exchanges=sync.exchanges_per_sweep())
    print(f"halo traffic under sync={args.sync}: {halo:.0f} B/sweep total "
          f"({plan.n_boundary} boundary spins, "
          f"{sync.exchanges_per_sweep():.2f} exchanges/sweep); "
          f"TPUv5e napkin: ICI/HBM time ratio "
          f"{napkin['ici_over_hbm']:.3f} per device, "
          f"{napkin['ici_latency_share']:.0%} of ICI time is per-exchange "
          f"latency (the cost the kernel-resident exchange amortizes)")
