"""The paper's chip at lattice scale: anneal a large Chimera p-bit fabric
through a mesh-sharded `api.Session` — cell rows partition over the
device mesh and only the O(√N) chain-coupler boundary spins move between
devices (ppermute halo exchange), exactly the chip's inter-cell wires.

Nothing O(N²) is ever built: the machine is sparse-native
(`SparseMismatch`, O(D·N)) and the sharded engine keeps per-device slot
tables local.  A sharded run reproduces the single-device spin
trajectory bit for bit (docs/sharding.md).

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/pbit_lattice_pod.py
(REPRO_EXAMPLE_QUICK=1 shrinks the lattice for the CI smoke job.)
"""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core.cd import PBitMachine
from repro.core.chimera import make_chimera
from repro.core.distributed import halo_bytes_per_sweep, sparse_energy
from repro.core.hardware import HardwareConfig
from repro.launch.mesh import halo_vs_hbm_seconds, make_line_mesh

quick = bool(os.environ.get("REPRO_EXAMPLE_QUICK"))
side = 8 if quick else 32          # 32x32 cells = 8192 p-bits
n_sweeps = 60 if quick else 400
chains = 4 if quick else 16

graph = make_chimera(side, side)
n_dev = len(jax.devices())
mesh = make_line_mesh() if n_dev > 1 else None
print(f"lattice: {side}x{side} cells = {graph.n_nodes} p-bits, "
      f"{graph.n_edges} couplers over {n_dev} device(s)")

# sparse-native chip instance: process variation sampled straight into the
# O(D·N) slot layout; mesh+partition ride the machine into every Session
machine = PBitMachine.create(
    graph, jax.random.PRNGKey(0), HardwareConfig(), sparse=True,
    noise="counter", w_scale=0.05, mesh=mesh,
    partition=api.Partition(rows="data") if mesh is not None else None)

session = machine.session(
    schedule=api.Anneal(0.05, 2.5, n_sweeps=n_sweeps), chains=chains)

# random SK instance on the physical couplers (one 8-bit code per edge)
rng = np.random.default_rng(1)
codes = jnp.asarray(rng.integers(-100, 101, graph.n_edges), jnp.int32)
chip = session.program_edges(codes, jnp.zeros((graph.n_nodes,), jnp.int32))

state = session.init_state(jax.random.PRNGKey(2))
m, ns, _ = session.sample(chip, state.m, state.noise_state)
jax.block_until_ready(m)           # warm-up: compile + first run

t0 = time.time()
m, ns, _ = session.sample(chip, m, ns)
jax.block_until_ready(m)
dt = time.time() - t0

e = np.asarray(sparse_energy(chip, m))
print(f"energy/spin after anneal: best {e.min() / graph.n_nodes:+.3f}, "
      f"mean {e.mean() / graph.n_nodes:+.3f} over {chains} chains")
print(f"{n_sweeps * chains * graph.n_nodes / dt / 1e6:.1f}M spin-updates/s "
      f"({dt:.2f}s for {n_sweeps} sweeps)")

plan = session.partition_plan
if plan is not None:
    halo = halo_bytes_per_sweep(plan, chains)
    # local HBM traffic/sweep/device: slot weights + spins once per sweep
    hbm = (2 * 6 * graph.n_nodes * 4 + 2 * chains * graph.n_nodes * 4) \
        // n_dev
    napkin = halo_vs_hbm_seconds(halo // max(n_dev - 1, 1), hbm)
    print(f"halo traffic: {halo} B/sweep total "
          f"({plan.n_boundary} boundary spins); "
          f"TPUv5e napkin: ICI/HBM time ratio "
          f"{napkin['ici_over_hbm']:.3f} per device")
