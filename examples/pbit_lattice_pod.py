"""The paper's chip at pod scale: anneal a 65,536-cell (1M p-bit) Chimera
lattice, spatially sharded over all local devices with halo exchange.

On real hardware this runs on the 16x16 mesh via launch/dryrun.py --pbit;
here it runs a smaller lattice over however many host devices exist.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/pbit_lattice_pod.py
"""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import (
    LatticeSpec,
    lattice_input_sharding,
    make_lattice_anneal,
    make_sk_lattice,
)
from repro.core.hardware import HardwareConfig

n_dev = len(jax.devices())
rows = cols = {1: 1, 2: 2, 4: 2}.get(n_dev, 4)
if n_dev == 2:
    rows, cols = 2, 1
mesh = jax.make_mesh((rows, max(1, n_dev // rows)), ("data", "model")) \
    if n_dev > 1 else None

spec = LatticeSpec(64, 64)   # 32,768 p-bits (scale up on real pods)
print(f"lattice: {spec.cell_rows}x{spec.cell_cols} cells = "
      f"{spec.n_spins} p-bits over {n_dev} device(s)")

chip = make_sk_lattice(spec, jax.random.PRNGKey(0), HardwareConfig())
run = make_lattice_anneal(spec, mesh, n_sweeps=400, record_every=40)
if mesh is not None:
    sh = lattice_input_sharding(mesh)
    chip = jax.device_put(chip, jax.tree.map(lambda _: sh, chip))

betas = jnp.linspace(0.05, 2.5, 400)
t0 = time.time()
state, energies = run(chip, jax.random.PRNGKey(1), betas)
jax.block_until_ready(energies)
dt = time.time() - t0
e = np.asarray(energies)
e = e[e != 0]
print("energy trajectory:", " ".join(f"{x:.0f}" for x in e))
print(f"{400 * spec.n_spins / dt / 1e6:.1f}M spin-updates/s "
      f"({dt:.1f}s for 400 sweeps)")
