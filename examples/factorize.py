"""Invertible logic: factorize by running a multiplier backwards.

The PSL compiler (src/repro/psl, docs/psl.md) synthesizes an n×n-bit
array multiplier as an Ising Hamiltonian whose ground states are the
valid (a, b, a·b) triples, chain-embeds it onto the Chimera graph, and
samples it through an unmodified `api.Session`.  A Hamiltonian has no
notion of signal direction, so clamping the *product* chains and
annealing leaves the free factor chains sampling the preimage — the
chip's headline invertible-logic demo (and the reason p-bit hardware
papers always show a factorizer).

Run:  PYTHONPATH=src python examples/factorize.py
      REPRO_EXAMPLE_QUICK=1: 2-bit multiplier, small graph (CI smoke).
      Full mode: 2-bit multiplier on the paper's 440-spin chip graph.

A 3-bit multiplier also *embeds* on the chip graph (27 logical spins ->
14-spin chains across a 7x7 cell window; benchmarks/bench_kernel.py
tracks it in the `psl_embed` section), but clique-ladder chains that
long stop mixing under Gibbs annealing — measured ~0% clause-valid
samples at any schedule tried — so the runnable demo stays at 2 bits.
Shorter chains from the planned connectivity-aware embedder
(ROADMAP.md) are what unlocks 3-bit factorization.
"""
import os

import jax
import numpy as np

from repro import psl
from repro.core.chimera import make_chimera, make_chip_graph

QUICK = bool(os.environ.get("REPRO_EXAMPLE_QUICK"))

if QUICK:
    n_bits, graph, products = 2, make_chimera(3, 3), [6, 9]
    chains, n_sweeps = 64, 400
else:
    # 12 logical spins -> chains of length 6 across a 3x3 cell window of
    # the chip graph (the masked SPI cell is dodged by the placement scan)
    n_bits, graph, products = 2, make_chip_graph(), [2, 3, 4, 6, 9]
    chains, n_sweeps = 128, 800

circuit = psl.multiplier_circuit(n_bits)
cc = psl.compile_circuit(circuit, graph, chains=chains, n_sweeps=n_sweeps)
st = cc.embedding.stats()
print(f"{n_bits}x{n_bits}-bit multiplier: {st['n_logical']} logical spins "
      f"-> {st['n_physical']} physical ({st['chain_length']}-spin chains), "
      f"window {st['window']} on {graph.rows}x{graph.cols} Chimera")

key = jax.random.PRNGKey(0)
for product in products:
    key, sub = jax.random.split(key)
    r = cc.run_inverse(sub, {"prod": product})
    valid = r.valid_mask()
    a, b = r.port_values("a")[valid], r.port_values("b")[valid]
    pairs = {}
    for pa, pb in zip(a.tolist(), b.tolist()):
        pairs[(pa, pb)] = pairs.get((pa, pb), 0) + 1
    shown = ", ".join(f"{pa}x{pb} ({c})"
                      for (pa, pb), c in sorted(pairs.items()))
    wrong = [p for p in pairs if p[0] * p[1] != product]
    print(f"  {product} = {shown or '<no valid samples>'}"
          f"   [valid {valid.mean():.0%} of {r.n_samples}, "
          f"broken chains {r.broken_chain_fraction:.3f}]")
    assert not wrong, f"clause-valid samples with a*b != {product}: {wrong}"
    assert pairs, f"no valid factorization sampled for {product}"
print("every clause-valid sample is a true factorization")
