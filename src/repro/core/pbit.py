"""The p-bit sampling engine (paper eqns 1 & 2), vectorized + batched.

Eqn 1:  I_i = sum_{j != i} J_ij m_j + h_i        (current summation)
Eqn 2:  m_i = sgn( tanh(beta I_i) + U(-1, +1) )  (stochastic neuron)

(The paper's eqn 1 prints "h_i m_i"; the standard p-bit bias term — and the
chip's bias-DAC current path, which does not multiply by m_i — is "+ h_i".
We implement "+ h_i" and note the typo here.)

On silicon all 440 neurons update asynchronously in parallel.  The exact
digital emulation for a 2-colorable graph (Chimera is — see chimera.py) is
*chromatic Gibbs*: update color class 0 in parallel, then class 1, each with
fresh noise.  Each half-sweep is one (B, N) x (N, N) matmul — MXU food.

`half_sweep` runs through an `EffectiveChip` (hardware.py) so every analog
non-ideality is in the loop; with `HardwareConfig.ideal()` it reduces to the
textbook equations, which tests/test_pbit.py verifies against exact
enumeration of the Boltzmann distribution.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lfsr as lfsr_mod
from repro.core.chimera import ChimeraGraph
from repro.core.hardware import EffectiveChip

NoiseFn = Callable[[jax.Array], tuple[jax.Array, jax.Array]]


# ---------------------------------------------------------------------------
# Noise sources
# ---------------------------------------------------------------------------
def make_philox_noise(batch: int, n_nodes: int, quantize: bool = True
                      ) -> NoiseFn:
    """Counter-based noise (scale mode): state is a PRNG key."""

    def step(key: jax.Array) -> tuple[jax.Array, jax.Array]:
        key, sub = jax.random.split(key)
        if quantize:  # mimic the 8-bit RNG DAC's discrete levels
            b = jax.random.randint(sub, (batch, n_nodes), 0, 256)
            u = (b.astype(jnp.float32) - 127.5) / 128.0
        else:
            u = jax.random.uniform(
                sub, (batch, n_nodes), minval=-1.0, maxval=1.0)
        return key, u

    return step


def make_lfsr_noise(graph: ChimeraGraph, batch: int, decimation: int = 8
                    ) -> tuple[Callable[[jax.Array], jax.Array], NoiseFn]:
    """Chip-faithful noise: one 32-bit LFSR per unit cell.

    Returns (init_fn(key) -> state, step_fn(state) -> (state, u[batch, N])).
    Vertical nodes read the register bytes; horizontal nodes read the
    bit-reversed bytes (paper's sharing trick).
    """
    cells = sorted(
        {(int(r), int(c)) for r, c in zip(graph.node_r, graph.node_c)}
    )
    vert = np.stack([graph.cell_nodes(r, c, side=0) for r, c in cells])
    horiz = np.stack([graph.cell_nodes(r, c, side=1) for r, c in cells])
    vert_j = jnp.asarray(vert)
    horiz_j = jnp.asarray(horiz)
    n_cells = len(cells)

    def init(key: jax.Array) -> jax.Array:
        return lfsr_mod.seed_states(key, (batch, n_cells))

    def step(state: jax.Array) -> tuple[jax.Array, jax.Array]:
        return lfsr_mod.lfsr_uniform_for_graph(
            state, vert_j, horiz_j, graph.n_nodes, decimation)

    return init, step


# ---------------------------------------------------------------------------
# Core update
# ---------------------------------------------------------------------------
def neuron_input(m: jax.Array, chip: EffectiveChip) -> jax.Array:
    """Eqn 1 for every node: I = m @ W^T + h.  m: (B, N) in {-1, +1}."""
    return m @ chip.W.T + chip.h


def half_sweep(
    m: jax.Array,
    chip: EffectiveChip,
    update_mask: jax.Array,
    beta: jax.Array,
    u: jax.Array,
) -> jax.Array:
    """Parallel update of the nodes selected by ``update_mask`` (eqn 2)."""
    I = neuron_input(m, chip)
    act = jnp.tanh(beta * chip.tanh_gain * (I + chip.tanh_offset))
    decision = act + chip.rand_gain * u + chip.comp_offset
    new = jnp.where(decision >= 0.0, 1.0, -1.0).astype(m.dtype)
    return jnp.where(update_mask, new, m)


class SweepCarry(NamedTuple):
    m: jax.Array
    noise_state: jax.Array


def make_sweep_fn(
    chip: EffectiveChip,
    color: jax.Array,
    noise_fn: NoiseFn,
    clamp_mask: jax.Array | None = None,
    clamp_values: jax.Array | None = None,
    kernel: Callable | None = None,
):
    """Build one full Gibbs sweep (two chromatic half-sweeps).

    clamp_mask: (N,) bool — nodes held at clamp_values (B, N) (CD positive
    phase).  `kernel`, if given, replaces the jnp half-sweep with the Pallas
    fused implementation (same signature, see kernels/ops.py).
    """
    hs = kernel if kernel is not None else half_sweep
    masks = [(color == c) for c in (0, 1)]
    if clamp_mask is not None:
        masks = [mk & (~clamp_mask) for mk in masks]

    def sweep(carry: SweepCarry, beta: jax.Array) -> SweepCarry:
        m, ns = carry.m, carry.noise_state
        if clamp_values is not None:
            m = jnp.where(clamp_mask, clamp_values, m)
        for mk in masks:
            ns, u = noise_fn(ns)
            m = hs(m, chip, mk, beta, u)
        return SweepCarry(m, ns)

    return sweep


def gibbs_sample(
    chip: EffectiveChip,
    color: jax.Array,
    init_m: jax.Array,
    betas: jax.Array,
    noise_state: jax.Array,
    noise_fn: NoiseFn,
    clamp_mask: jax.Array | None = None,
    clamp_values: jax.Array | None = None,
    collect: bool = False,
    kernel: Callable | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array | None]:
    """Run len(betas) sweeps.  Returns (final_m, noise_state, traj|None).

    traj (if collect): (n_sweeps, B, N) spin states after every sweep.
    """
    sweep = make_sweep_fn(chip, color, noise_fn, clamp_mask, clamp_values,
                          kernel)

    def body(carry, beta):
        nxt = sweep(carry, beta)
        return nxt, (nxt.m if collect else None)

    (final, traj) = jax.lax.scan(
        body, SweepCarry(init_m, noise_state), betas)
    return final.m, final.noise_state, traj


def gibbs_stats(
    chip: EffectiveChip,
    color: jax.Array,
    init_m: jax.Array,
    beta: float,
    n_sweeps: int,
    burn_in: int,
    noise_state: jax.Array,
    noise_fn: NoiseFn,
    edges: jax.Array,
    clamp_mask: jax.Array | None = None,
    clamp_values: jax.Array | None = None,
    kernel: Callable | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Accumulate first/second moments on-line (no trajectory storage).

    Returns (mean_spin[N], mean_edge_corr[E], final_m, noise_state), with
    moments averaged over chains and post-burn-in sweeps — exactly the
    statistics contrastive divergence needs.
    """
    sweep = make_sweep_fn(chip, color, noise_fn, clamp_mask, clamp_values,
                          kernel)
    e0, e1 = edges[:, 0], edges[:, 1]
    betas = jnp.full((n_sweeps,), beta, dtype=jnp.float32)

    def body(carry, inp):
        state, s_sum, c_sum = carry
        beta_t, is_measured = inp
        state = sweep(state, beta_t)
        w = is_measured.astype(jnp.float32)
        s_sum = s_sum + w * state.m.mean(axis=0)
        corr = (state.m[:, e0] * state.m[:, e1]).mean(axis=0)
        c_sum = c_sum + w * corr
        return (state, s_sum, c_sum), None

    measured = (jnp.arange(n_sweeps) >= burn_in)
    init = (
        SweepCarry(init_m, noise_state),
        jnp.zeros((init_m.shape[1],), jnp.float32),
        jnp.zeros((edges.shape[0],), jnp.float32),
    )
    (state, s_sum, c_sum), _ = jax.lax.scan(body, init, (betas, measured))
    denom = jnp.maximum(n_sweeps - burn_in, 1).astype(jnp.float32)
    return s_sum / denom, c_sum / denom, state.m, state.noise_state


def random_spins(key: jax.Array, batch: int, n_nodes: int) -> jax.Array:
    return jnp.where(
        jax.random.bernoulli(key, 0.5, (batch, n_nodes)), 1.0, -1.0
    ).astype(jnp.float32)
