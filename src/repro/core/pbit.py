"""The p-bit sampling engine (paper eqns 1 & 2), vectorized + batched.

Eqn 1:  I_i = sum_{j != i} J_ij m_j + h_i        (current summation)
Eqn 2:  m_i = sgn( tanh(beta I_i) + U(-1, +1) )  (stochastic neuron)

(The paper's eqn 1 prints "h_i m_i"; the standard p-bit bias term — and the
chip's bias-DAC current path, which does not multiply by m_i — is "+ h_i".
We implement "+ h_i" and note the typo here.)

On silicon all 440 neurons update asynchronously in parallel.  The exact
digital emulation for a 2-colorable graph (Chimera is — see chimera.py) is
*chromatic Gibbs*: update color class 0 in parallel, then class 1, each with
fresh noise.  Each half-sweep is one (B, N) x (N, N) matmul — MXU food.

`half_sweep` runs through an `EffectiveChip` (hardware.py) so every analog
non-ideality is in the loop; with `HardwareConfig.ideal()` it reduces to the
textbook equations, which tests/test_pbit.py verifies against exact
enumeration of the Boltzmann distribution.

Execution backends (see docs/kernels.md):
  * "ref"    — pure jnp chromatic half-sweeps under `lax.scan` (default).
  * "pallas" — the tiled per-half-sweep Pallas kernel (kernels/pbit_update).
  * "fused"  — the sweep-resident engine (kernels/sweep_fused): S sweeps per
               kernel launch, spins in VMEM, noise generated in-kernel, CD
               moments accumulated on-line.  Needs "counter" or "lfsr" noise.
  * "sparse" — jnp scan like "ref", but eqn 1 is the Chimera-native
               fixed-degree gather (≤6 neighbors/node) instead of the dense
               matmul.  Needs a chip carrying the slot layout
               (hardware.attach_sparse / program_weights_sparse).
  * "fused_sparse" — the sweep-resident engine on the slot layout: D
               lane-gathers replace the (B,N)x(N,N) matmul and the moment
               scratch shrinks from the (N,N) Gram to (D,N) per-slot edge
               correlations, which is what lets ≥32k-spin lattices stay
               VMEM-resident.  Needs "counter" or "lfsr" noise.
Selected per call via the ``backend=`` argument, or globally via the
REPRO_PBIT_BACKEND environment variable (used when backend is None/"auto").

This module is the *engine* layer.  Workload code builds samplers through
`repro.api` (a declarative SamplerSpec compiled into a Session) which
resolves backend/interpret/noise/schedule once and calls in here with
everything explicit; the free functions keep their legacy env-consulting
defaults as deprecation shims (docs/api.md has the migration table).

Multi-device execution sits one layer up: a spec carrying ``mesh=`` +
``partition=`` compiles into `core/distributed.ShardedEngine`, which runs
the "sparse" slot-layout scan per device shard with ppermute halo
exchange of the chain-coupler boundary spins (docs/sharding.md).  The
noise sources here are the single-device references the sharded engine
must match bit for bit: "counter" regenerates from the global
(chain, node) coordinate hash and "lfsr" from the per-cell register
band, so any shard can reproduce exactly its columns of the global
stream — which is why sharded specs require one of those two kinds.
"""
from __future__ import annotations

import os
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lfsr as lfsr_mod
from repro.core.chimera import ChimeraGraph
from repro.core.hardware import EffectiveChip

NoiseFn = Callable[[jax.Array], tuple[jax.Array, jax.Array]]

BACKENDS = ("ref", "pallas", "fused", "sparse", "fused_sparse")
FUSED_BACKENDS = ("fused", "fused_sparse")


def resolve_backend(backend: str | None = None) -> str:
    """Map None/"auto" to the env default; validate explicit choices."""
    if backend in (None, "auto"):
        backend = os.environ.get("REPRO_PBIT_BACKEND", "ref")
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; pick from {BACKENDS}")
    return backend


class NoiseSpec(NamedTuple):
    """Static description of a noise source, attached to step fns as
    ``step.spec`` so the fused kernel can regenerate the same stream
    in-kernel (see kernels/sweep_fused.py)."""

    kind: str                        # "philox" | "counter" | "lfsr"
    decimation: int = 8
    gather_perm: tuple | None = None  # node -> flat LFSR column (static)


# ---------------------------------------------------------------------------
# Noise sources
# ---------------------------------------------------------------------------
def make_philox_noise(batch: int, n_nodes: int, quantize: bool = True
                      ) -> NoiseFn:
    """Host-side counter noise (scale mode): state is a PRNG key.

    Not reproducible inside the fused kernel — use `make_counter_noise` for
    a bit-exact host/kernel pair.
    """

    def step(key: jax.Array) -> tuple[jax.Array, jax.Array]:
        key, sub = jax.random.split(key)
        if quantize:  # mimic the 8-bit RNG DAC's discrete levels
            b = jax.random.randint(sub, (batch, n_nodes), 0, 256)
            u = (b.astype(jnp.float32) - 127.5) / 128.0
        else:
            u = jax.random.uniform(
                sub, (batch, n_nodes), minval=-1.0, maxval=1.0)
        return key, u

    step.spec = NoiseSpec(kind="philox")
    return step


def make_counter_noise(batch: int, n_nodes: int
                       ) -> tuple[Callable[[jax.Array], jax.Array], NoiseFn]:
    """Stateless-hash noise, bit-exact between host and the fused kernel.

    State is uint32[2] = (seed, step counter); every step consumes one
    counter tick and hashes (seed, ctr, chain, node) — the scale-mode
    equivalent of the chip's per-cell LFSRs, quantized like the 8-bit RNG
    DAC.  Returns (init_fn(key) -> state, step_fn).
    """
    rows = jnp.arange(batch, dtype=jnp.uint32)[:, None]
    cols = jnp.arange(n_nodes, dtype=jnp.uint32)[None, :]

    def init(key: jax.Array) -> jax.Array:
        seed = jax.random.bits(key, (1,), jnp.uint32)[0]
        return jnp.stack([seed, jnp.uint32(0)])

    def step(state: jax.Array) -> tuple[jax.Array, jax.Array]:
        u = lfsr_mod.counter_uniform(state[0], state[1], rows, cols)
        return state + jnp.array([0, 1], jnp.uint32), u

    step.spec = NoiseSpec(kind="counter")
    return init, step


def make_lfsr_noise(graph: ChimeraGraph, batch: int, decimation: int = 8
                    ) -> tuple[Callable[[jax.Array], jax.Array], NoiseFn]:
    """Chip-faithful noise: one 32-bit LFSR per unit cell.

    Returns (init_fn(key) -> state, step_fn(state) -> (state, u[batch, N])).
    Vertical nodes read the register bytes; horizontal nodes read the
    bit-reversed bytes (paper's sharing trick).  Per-node mapping is one
    gather through the precomputed inverse permutation (shared with the
    fused kernel's in-kernel LFSR path).
    """
    cells = sorted(
        {(int(r), int(c)) for r, c in zip(graph.node_r, graph.node_c)}
    )
    vert = np.stack([graph.cell_nodes(r, c, side=0) for r, c in cells])
    horiz = np.stack([graph.cell_nodes(r, c, side=1) for r, c in cells])
    perm = lfsr_mod.node_gather_perm(vert, horiz, graph.n_nodes)
    perm_j = jnp.asarray(perm)
    n_cells = len(cells)

    def init(key: jax.Array) -> jax.Array:
        return lfsr_mod.seed_states(key, (batch, n_cells))

    def step(state: jax.Array) -> tuple[jax.Array, jax.Array]:
        return lfsr_mod.lfsr_uniform_for_graph(
            state, None, None, graph.n_nodes, decimation, gather_perm=perm_j)

    step.spec = NoiseSpec(kind="lfsr", decimation=decimation,
                          gather_perm=tuple(int(x) for x in perm))
    return init, step


# ---------------------------------------------------------------------------
# Core update
# ---------------------------------------------------------------------------
def neuron_input(m: jax.Array, chip: EffectiveChip) -> jax.Array:
    """Eqn 1 for every node: I = m @ W^T + h.  m: (B, N) in {-1, +1}."""
    if chip.W is None:
        raise ValueError(
            "this chip carries only the sparse slot layout (W=None); use a "
            "sparse backend ('sparse' or 'fused_sparse'), e.g. "
            "PBitMachine(backend='sparse') or REPRO_PBIT_BACKEND=sparse")
    return m @ chip.W.T + chip.h


def half_sweep(
    m: jax.Array,
    chip: EffectiveChip,
    update_mask: jax.Array,
    beta: jax.Array,
    u: jax.Array,
) -> jax.Array:
    """Parallel update of the nodes selected by ``update_mask`` (eqn 2).

    ``beta`` may be a scalar or a (B,) per-chain vector (tempering ladder).
    """
    beta = jnp.asarray(beta, jnp.float32)
    if beta.ndim == 1:
        beta = beta[:, None]
    I = neuron_input(m, chip)
    act = jnp.tanh(beta * chip.tanh_gain * (I + chip.tanh_offset))
    decision = act + chip.rand_gain * u + chip.comp_offset
    new = jnp.where(decision >= 0.0, 1.0, -1.0).astype(m.dtype)
    return jnp.where(update_mask, new, m)


class SweepCarry(NamedTuple):
    m: jax.Array
    noise_state: jax.Array


def make_sweep_fn(
    chip: EffectiveChip,
    color: jax.Array,
    noise_fn: NoiseFn,
    clamp_mask: jax.Array | None = None,
    clamp_values: jax.Array | None = None,
    kernel: Callable | None = None,
    flip_fn: Callable[[jax.Array], jax.Array] | None = None,
):
    """Build one full Gibbs sweep (two chromatic half-sweeps).

    clamp_mask: (N,) bool — nodes held at clamp_values (B, N) (CD positive
    phase).  `kernel`, if given, replaces the jnp half-sweep with the Pallas
    fused implementation (same signature, see kernels/ops.py).

    flip_fn(noise_state) -> (B, N) bool is the transient-fault hook
    (api.Faults.flip_prob): just-updated spins where it reads True are
    inverted after their half-sweep.  It receives the noise state *before*
    the half-sweep's draw, so the flip stream is addressed by the same
    (seed, counter) coordinates as the sampling stream without consuming
    it; clamped/stuck nodes never flip (the update mask gates it).
    """
    hs = kernel if kernel is not None else half_sweep
    masks = [(color == c) for c in (0, 1)]
    if clamp_mask is not None:
        masks = [mk & (~clamp_mask) for mk in masks]

    def sweep(carry: SweepCarry, beta: jax.Array) -> SweepCarry:
        m, ns = carry.m, carry.noise_state
        if clamp_values is not None:
            m = jnp.where(clamp_mask, clamp_values, m)
        for mk in masks:
            ns0 = ns
            ns, u = noise_fn(ns)
            m = hs(m, chip, mk, beta, u)
            if flip_fn is not None:
                m = jnp.where(mk & flip_fn(ns0), -m, m)
        return SweepCarry(m, ns)

    return sweep


def _resolve_kernel(backend: str, kernel: Callable | None,
                    interpret: bool | None = None) -> Callable | None:
    """Half-sweep implementation for the scan-based backends."""
    if kernel is not None:
        return kernel
    if backend == "pallas":
        from repro.kernels import ops as kernel_ops
        return kernel_ops.make_kernel_half_sweep(interpret=interpret)
    if backend in ("sparse", "fused_sparse"):
        # "fused_sparse" lands here only on the collect=True fallback
        from repro.kernels import ops as kernel_ops
        return kernel_ops.sparse_half_sweep
    return None  # "ref" (and "fused" fallbacks) use the jnp half_sweep


def gibbs_sample(
    chip: EffectiveChip,
    color: jax.Array,
    init_m: jax.Array,
    betas: jax.Array,
    noise_state: jax.Array,
    noise_fn: NoiseFn,
    clamp_mask: jax.Array | None = None,
    clamp_values: jax.Array | None = None,
    collect: bool = False,
    kernel: Callable | None = None,
    backend: str | None = None,
    interpret: bool | None = None,
    flip_fn: Callable | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array | None]:
    """Run n_sweeps sweeps.  Returns (final_m, noise_state, traj|None).

    betas: (n_sweeps,) shared schedule or (n_sweeps, B) per-chain inverse
    temperatures (parallel-tempering replicas).
    traj (if collect): (n_sweeps, B, N) spin states after every sweep.
    backend: "ref" | "pallas" | "fused" (None/"auto" -> REPRO_PBIT_BACKEND
    env var, default "ref").  The fused engine runs every sweep inside one
    kernel launch; it cannot emit per-sweep trajectories, so ``collect``
    falls back to the scan path.
    interpret: Pallas interpret mode for the kernel backends (None -> the
    REPRO_PALLAS_INTERPRET env default; api.Session resolves it once at
    compile and passes it explicitly).
    """
    backend = resolve_backend(backend)
    # an explicit kernel= always wins (custom half-sweep injection): the
    # fused engine could not honor it, so fall through to the scan path —
    # same for a flip_fn fault hook, which runs between half-sweeps
    if backend in FUSED_BACKENDS and not collect and kernel is None \
            and flip_fn is None:
        from repro.kernels import ops as kernel_ops
        m, ns = kernel_ops.fused_sweeps(
            init_m, chip, color, betas, noise_state,
            getattr(noise_fn, "spec", None),
            clamp_mask=clamp_mask, clamp_values=clamp_values,
            sparse=(backend == "fused_sparse"), interpret=interpret)
        return m, ns, None

    sweep = make_sweep_fn(chip, color, noise_fn, clamp_mask, clamp_values,
                          _resolve_kernel(backend, kernel, interpret),
                          flip_fn=flip_fn)

    def body(carry, beta):
        nxt = sweep(carry, beta)
        return nxt, (nxt.m if collect else None)

    (final, traj) = jax.lax.scan(
        body, SweepCarry(init_m, noise_state), betas)
    return final.m, final.noise_state, traj


def gibbs_stats(
    chip: EffectiveChip,
    color: jax.Array,
    init_m: jax.Array,
    beta: float,
    n_sweeps: int,
    burn_in: int,
    noise_state: jax.Array,
    noise_fn: NoiseFn,
    edges: jax.Array,
    clamp_mask: jax.Array | None = None,
    clamp_values: jax.Array | None = None,
    kernel: Callable | None = None,
    backend: str | None = None,
    interpret: bool | None = None,
    flip_fn: Callable | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Accumulate first/second moments on-line (no trajectory storage).

    Returns (mean_spin[N], mean_edge_corr[E], final_m, noise_state), with
    moments averaged over chains and post-burn-in sweeps — exactly the
    statistics contrastive divergence needs.  With backend="fused" (or
    "fused_sparse") the whole phase (every sweep AND the moment
    accumulation) is one kernel launch: per-sweep spins never touch HBM;
    edge correlations are read out of the accumulated m^T m Gram matrix
    (dense) or the (D, N) per-slot correlation table (sparse).
    """
    backend = resolve_backend(backend)
    e0, e1 = edges[:, 0], edges[:, 1]
    betas = jnp.full((n_sweeps,), beta, dtype=jnp.float32)
    denom = jnp.maximum(n_sweeps - burn_in, 1).astype(jnp.float32)

    if backend in FUSED_BACKENDS and kernel is None and flip_fn is None:
        from repro.kernels import ops as kernel_ops
        sparse = backend == "fused_sparse"
        measured = (jnp.arange(n_sweeps) >= burn_in).astype(jnp.float32)
        m, ns, s_sum, c_sum = kernel_ops.fused_sweeps(
            init_m, chip, color, betas, noise_state,
            getattr(noise_fn, "spec", None),
            clamp_mask=clamp_mask, clamp_values=clamp_values,
            measured=measured, sparse=sparse, interpret=interpret)
        scale = denom * init_m.shape[0]
        if sparse:
            # edge (i, j) lives at slot row d with nbr_idx[d, i] == j
            slot = jnp.argmax(chip.nbr_idx[:, e0] == e1[None, :], axis=0)
            c_edge = c_sum[slot, e0]
        else:
            c_edge = c_sum[e0, e1]
        return s_sum / scale, c_edge / scale, m, ns

    sweep = make_sweep_fn(chip, color, noise_fn, clamp_mask, clamp_values,
                          _resolve_kernel(backend, kernel, interpret),
                          flip_fn=flip_fn)

    def body(carry, inp):
        state, s_sum, c_sum = carry
        beta_t, is_measured = inp
        state = sweep(state, beta_t)
        w = is_measured.astype(jnp.float32)
        s_sum = s_sum + w * state.m.mean(axis=0)
        corr = (state.m[:, e0] * state.m[:, e1]).mean(axis=0)
        c_sum = c_sum + w * corr
        return (state, s_sum, c_sum), None

    measured = (jnp.arange(n_sweeps) >= burn_in)
    init = (
        SweepCarry(init_m, noise_state),
        jnp.zeros((init_m.shape[1],), jnp.float32),
        jnp.zeros((edges.shape[0],), jnp.float32),
    )
    (state, s_sum, c_sum), _ = jax.lax.scan(body, init, (betas, measured))
    return s_sum / denom, c_sum / denom, state.m, state.noise_state


def gibbs_visible_hist(
    chip: EffectiveChip,
    color: jax.Array,
    init_m: jax.Array,
    betas: jax.Array,
    burn_in: int,
    noise_state: jax.Array,
    noise_fn: NoiseFn,
    visible_idx: np.ndarray,
    backend: str | None = None,
    interpret: bool | None = None,
    clamp_mask: jax.Array | None = None,
    clamp_values: jax.Array | None = None,
    flip_fn: Callable | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Free-run and histogram the visible bit patterns, streaming.

    Returns (counts[2^nv], final_m, noise_state): counts[c] is the number
    of (chain, post-burn-in sweep) samples whose visible spins encode c
    (energy.empirical_visible_dist code order).  The scan backends fold the
    histogram into the sweep loop; the fused backends accumulate it inside
    the kernel — either way the (sweeps, B, N) trajectory never
    materializes, unlike the old `gibbs_sample(collect=True)` route.

    clamp_mask/clamp_values freeze nodes through the run (stuck-at-spin
    faults; conditioned histograms) — the in-kernel histogram takes no
    clamps, so a clamped (or flip-injected) call uses the scan path.
    """
    backend = resolve_backend(backend)
    visible_idx = np.asarray(visible_idx)
    nv = int(visible_idx.shape[0])
    n_sweeps = betas.shape[0]
    measured = (jnp.arange(n_sweeps) >= burn_in).astype(jnp.float32)

    if backend in FUSED_BACKENDS and clamp_mask is None and flip_fn is None:
        from repro.kernels import ops as kernel_ops
        from repro.kernels.sweep_fused import MAX_HIST_VISIBLE
        spec = getattr(noise_fn, "spec", None)
        # host noise (philox) or an oversized visible set cannot histogram
        # in-kernel: fall back to the scan path, like collect=True used to
        if (spec is not None and spec.kind in ("counter", "lfsr")
                and nv <= MAX_HIST_VISIBLE):
            m, ns, hist = kernel_ops.fused_visible_hist(
                init_m, chip, color, betas, noise_state, spec, visible_idx,
                measured, sparse=(backend == "fused_sparse"),
                interpret=interpret)
            return hist, m, ns

    sweep = make_sweep_fn(chip, color, noise_fn, clamp_mask, clamp_values,
                          _resolve_kernel(backend, None, interpret),
                          flip_fn=flip_fn)
    vis = jnp.asarray(visible_idx)
    pow2 = jnp.asarray(2 ** np.arange(nv), jnp.int32)

    def body(carry, inp):
        state, hist = carry
        beta_t, w = inp
        state = sweep(state, beta_t)
        codes = jnp.sum((state.m[:, vis] > 0).astype(jnp.int32) * pow2,
                        axis=1)
        # scatter-add, not a (B, 2^nv) one-hot: this path is the fallback
        # for visible sets too wide for the in-kernel histogram
        return (state, hist.at[codes].add(w)), None

    init = (SweepCarry(init_m, noise_state),
            jnp.zeros((2 ** nv,), jnp.float32))
    (state, hist), _ = jax.lax.scan(body, init, (betas, measured))
    return hist, state.m, state.noise_state


def random_spins(key: jax.Array, batch: int, n_nodes: int) -> jax.Array:
    return jnp.where(
        jax.random.bernoulli(key, 0.5, (batch, n_nodes)), 1.0, -1.0
    ).astype(jnp.float32)
