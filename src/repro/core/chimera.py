"""Chimera graph topology (D-Wave style), as used by the paper's chip.

The chip arranges 440 spins as a 7x8 array of Chimera unit cells with one
cell replaced by bias circuits / SPI (=> 55 cells x 8 spins = 440).

Each unit cell is a K_{4,4} bipartite "restricted Boltzmann machine":
4 *vertical* nodes (side=0) fully connected to 4 *horizontal* nodes (side=1).
Inter-cell couplers connect vertical node i of cell (r, c) to vertical node i
of cells (r±1, c), and horizontal node j of (r, c) to horizontal node j of
(r, c±1).  Maximum degree is therefore 4 (in-cell) + 2 (inter-cell) = 6,
matching the paper's "each node has 6 current inputs".

Chimera is 2-colorable: color(r, c, side=0) = (r + c) % 2 and
color(r, c, side=1) = (r + c + 1) % 2 is a proper coloring (in-cell edges
cross sides; vertical inter-cell edges change r; horizontal change c).
Chromatic Gibbs therefore needs exactly two parallel half-sweeps per sweep —
the TPU analogue of the chip's fully parallel analog update.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

K_CELL = 4  # nodes per side of a unit cell


@dataclasses.dataclass(frozen=True)
class ChimeraGraph:
    """Static description of a (possibly cell-masked) Chimera graph.

    Nodes of masked cells are removed entirely; all index arrays refer to the
    *compacted* node numbering [0, n_nodes).
    """

    rows: int
    cols: int
    k: int
    masked_cells: tuple[tuple[int, int], ...]
    n_nodes: int
    # per-node coordinates, shape (n_nodes,)
    node_r: np.ndarray
    node_c: np.ndarray
    node_side: np.ndarray  # 0 = vertical, 1 = horizontal
    node_k: np.ndarray     # 0..k-1 within side
    color: np.ndarray      # chromatic class in {0, 1}
    edges: np.ndarray      # (n_edges, 2) int32, i < j, compacted ids

    # ------------------------------------------------------------------
    @property
    def n_edges(self) -> int:
        return int(self.edges.shape[0])

    @property
    def n_cells(self) -> int:
        return self.rows * self.cols - len(self.masked_cells)

    def adjacency(self) -> np.ndarray:
        """Dense boolean adjacency (n_nodes, n_nodes)."""
        a = np.zeros((self.n_nodes, self.n_nodes), dtype=bool)
        a[self.edges[:, 0], self.edges[:, 1]] = True
        a[self.edges[:, 1], self.edges[:, 0]] = True
        return a

    def degree(self) -> np.ndarray:
        a = self.adjacency()
        return a.sum(axis=1).astype(np.int32)

    def color_mask(self, color: int) -> np.ndarray:
        return self.color == color

    def cell_nodes(self, r: int, c: int, side: int | None = None) -> np.ndarray:
        """Compacted node ids of cell (r, c), optionally one side only."""
        sel = (self.node_r == r) & (self.node_c == c)
        if side is not None:
            sel &= self.node_side == side
        return np.nonzero(sel)[0].astype(np.int32)

    def validate_two_coloring(self) -> bool:
        e = self.edges
        return bool(np.all(self.color[e[:, 0]] != self.color[e[:, 1]]))

    def coord_lut(self) -> np.ndarray:
        """Coordinate -> compacted-node-id lookup table.

        ``lut[r, c, side, k]`` is the compacted node id at that Chimera
        coordinate, or -1 where the cell is masked.  This is the inverse
        of the (node_r, node_c, node_side, node_k) arrays and the basis
        of every coordinate-addressed embedding (the serving layer's
        shape buckets, the PSL chain embedder).
        """
        lut = -np.ones((self.rows, self.cols, 2, self.k), np.int64)
        lut[self.node_r, self.node_c, self.node_side,
            self.node_k] = np.arange(self.n_nodes)
        return lut

    def edge_index(self) -> dict[tuple[int, int], int]:
        """Map (i, j) with i < j -> row index into ``edges``."""
        return {(int(i), int(j)): e
                for e, (i, j) in enumerate(np.asarray(self.edges))}

    # -- fixed-degree sparse layout -------------------------------------
    def neighbor_table(self) -> tuple[np.ndarray, np.ndarray]:
        """Fixed-degree neighbor table (ELL layout) of the coupler set.

        Returns ``(nbr_idx, nbr_mask)``, both ``(D, n_nodes)`` with
        D = max degree (k + 2 on an unmasked Chimera: k in-cell K_{k,k}
        partners + 2 chain couplers).  ``nbr_idx[d, i]`` is node i's d-th
        neighbor in ascending node order; unused slots point at i itself
        (mask False) so gathers stay in bounds and gathered weights are 0.
        Ascending order matters: it makes the slot-major sparse sum visit
        nonzeros in the same order as a sequential dense row reduction,
        which is what keeps the sparse backends bit-exact vs the dense ref
        (zeros are additive identities).

        Built from the edge list in O(E) — never materializes the dense
        adjacency, so it scales to lattices where (N, N) does not fit.
        """
        e = self.edges
        src = np.concatenate([e[:, 0], e[:, 1]])
        dst = np.concatenate([e[:, 1], e[:, 0]])
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
        deg = np.bincount(src, minlength=self.n_nodes)
        max_deg = int(deg.max()) if deg.size else 0
        D = max(max_deg, 1)
        starts = np.concatenate([[0], np.cumsum(deg)[:-1]])
        slot = np.arange(src.size) - starts[src]
        nbr_idx = np.tile(np.arange(self.n_nodes, dtype=np.int32), (D, 1))
        nbr_mask = np.zeros((D, self.n_nodes), dtype=bool)
        nbr_idx[slot, src] = dst
        nbr_mask[slot, src] = True
        return nbr_idx, nbr_mask

    def edge_slots(self, nbr_idx: np.ndarray | None = None
                   ) -> tuple[np.ndarray, np.ndarray]:
        """Per-edge slot coordinates in the neighbor table.

        For edge e = (i, j): ``slot_ij[e]`` is the row d with
        ``nbr_idx[d, i] == j`` and ``slot_ji[e]`` the row with
        ``nbr_idx[d, j] == i`` — the two directed entries every undirected
        coupler owns in the (D, N) slot layout.
        """
        if nbr_idx is None:
            nbr_idx, _ = self.neighbor_table()
        e0, e1 = self.edges[:, 0], self.edges[:, 1]
        slot_ij = np.argmax(nbr_idx[:, e0] == e1[None, :], axis=0)
        slot_ji = np.argmax(nbr_idx[:, e1] == e0[None, :], axis=0)
        return slot_ij.astype(np.int32), slot_ji.astype(np.int32)


def make_chimera(
    rows: int,
    cols: int,
    k: int = K_CELL,
    masked_cells: Sequence[tuple[int, int]] = (),
) -> ChimeraGraph:
    """Build a Chimera graph C(rows, cols, k) with optional masked cells."""
    masked = set((int(r), int(c)) for r, c in masked_cells)
    for (r, c) in masked:
        if not (0 <= r < rows and 0 <= c < cols):
            raise ValueError(f"masked cell {(r, c)} out of range")

    # raw id -> compact id
    def raw_id(r: int, c: int, s: int, kk: int) -> int:
        return (((r * cols) + c) * 2 + s) * k + kk

    n_raw = rows * cols * 2 * k
    compact = -np.ones(n_raw, dtype=np.int64)
    node_r, node_c, node_side, node_k, color = [], [], [], [], []
    nid = 0
    for r in range(rows):
        for c in range(cols):
            if (r, c) in masked:
                continue
            for s in range(2):
                for kk in range(k):
                    compact[raw_id(r, c, s, kk)] = nid
                    node_r.append(r)
                    node_c.append(c)
                    node_side.append(s)
                    node_k.append(kk)
                    color.append((r + c + s) % 2)
                    nid += 1

    edges = []

    def add_edge(a: int, b: int) -> None:
        ca, cb = compact[a], compact[b]
        if ca >= 0 and cb >= 0:
            edges.append((min(ca, cb), max(ca, cb)))

    for r in range(rows):
        for c in range(cols):
            if (r, c) in masked:
                continue
            # in-cell K_{k,k}
            for i in range(k):
                for j in range(k):
                    add_edge(raw_id(r, c, 0, i), raw_id(r, c, 1, j))
            # vertical inter-cell (row direction, side 0)
            if r + 1 < rows and (r + 1, c) not in masked:
                for i in range(k):
                    add_edge(raw_id(r, c, 0, i), raw_id(r + 1, c, 0, i))
            # horizontal inter-cell (col direction, side 1)
            if c + 1 < cols and (r, c + 1) not in masked:
                for j in range(k):
                    add_edge(raw_id(r, c, 1, j), raw_id(r, c + 1, 1, j))

    edges_arr = np.array(sorted(set(edges)), dtype=np.int32)
    if edges_arr.size == 0:
        edges_arr = np.zeros((0, 2), dtype=np.int32)
    g = ChimeraGraph(
        rows=rows,
        cols=cols,
        k=k,
        masked_cells=tuple(sorted(masked)),
        n_nodes=nid,
        node_r=np.array(node_r, dtype=np.int32),
        node_c=np.array(node_c, dtype=np.int32),
        node_side=np.array(node_side, dtype=np.int32),
        node_k=np.array(node_k, dtype=np.int32),
        color=np.array(color, dtype=np.int32),
        edges=edges_arr,
    )
    assert g.validate_two_coloring(), "Chimera 2-coloring broken"
    return g


def make_chip_graph() -> ChimeraGraph:
    """The paper's chip: 7x8 Chimera with one cell replaced by bias/SPI.

    440 spins = (7*8 - 1) cells * 8 spins.
    """
    return make_chimera(7, 8, K_CELL, masked_cells=[(6, 7)])
