"""Analog hardware model of the chip's non-idealities.

The paper's area-efficiency choices (standard-cell analog pitch-matched to
digital, shared 1 V supply, MOS R-2R DACs with no output-resistance
enhancement, un-matched current mirrors) buy density at the cost of
process-variation mismatch.  This module is the physics model of those
non-idealities; `program_weights` compiles digital 8-bit weights through it
into the *effective* analog quantities the sampler sees.

Modeled effects (all per chip *instance*, sampled from a PRNG key):
  * R-2R DAC per-bit branch mismatch       -> nonmonotonic INL/DNL in J & h
  * DAC output-resistance / supply droop   -> soft compression of large currents
  * Gilbert-multiplier gain error per edge *direction* -> asymmetric W[i,j] != W[j,i]
  * disabled-coupler leakage (enable bit leaks a small current)
  * WTA-tanh gain (beta) variation and input offset per node
  * RNG-DAC amplitude mismatch per node
  * comparator input offset per node

Setting ``HardwareConfig.ideal()`` zeroes every sigma, giving a bit-exact
textbook p-bit (used as the oracle in tests).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chimera import ChimeraGraph

WMIN, WMAX = -128, 127  # 8-bit signed DAC codes


def quantize_codes(w: jax.Array, lsb: float = 1.0) -> jax.Array:
    """Float master weights -> signed 8-bit DAC codes."""
    return jnp.clip(jnp.round(w / lsb), WMIN, WMAX).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class HardwareConfig:
    """Process-variation sigmas (fraction of nominal unless noted)."""

    sigma_dac_bit: float = 0.04      # per-R-2R-branch current mismatch
    sigma_edge_gain: float = 0.05    # Gilbert multiplier gain, per direction
    sigma_tanh_gain: float = 0.08    # WTA tanh beta spread per node
    sigma_tanh_offset: float = 2.0   # input-referred offset, LSB units
    sigma_rand_gain: float = 0.05    # RNG DAC amplitude spread per node
    sigma_comp_offset: float = 0.02  # comparator offset, fraction of FS
    leak_frac: float = 0.004         # disabled-coupler leakage, fraction of FS
    compression: float = 3e-3        # soft saturation: I/(1+compression*|I|/FS)

    @staticmethod
    def ideal() -> "HardwareConfig":
        return HardwareConfig(0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0)

    def is_ideal(self) -> bool:
        return all(
            getattr(self, f.name) == 0.0 for f in dataclasses.fields(self)
        )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Mismatch:
    """Sampled per-instance variation (a pytree of arrays)."""

    dac_bit_j: jax.Array      # (N, N, 8) per-bit branch error for J DACs
    dac_bit_h: jax.Array      # (N, 8)
    edge_gain: jax.Array      # (N, N) directional multiplier gain error
    tanh_gain: jax.Array      # (N,)   multiplicative beta error
    tanh_offset: jax.Array    # (N,)   additive input offset (weight LSB units)
    rand_gain: jax.Array      # (N,)
    comp_offset: jax.Array    # (N,)
    leak: jax.Array           # (N, N) leakage of disabled couplers

    def tree_flatten(self):
        fields = dataclasses.fields(self)
        return tuple(getattr(self, f.name) for f in fields), None

    @classmethod
    def tree_unflatten(cls, aux: Any, children):
        return cls(*children)


def sample_mismatch(
    key: jax.Array, n_nodes: int, cfg: HardwareConfig
) -> Mismatch:
    """Draw one chip instance's process variation."""
    ks = jax.random.split(key, 8)
    n = n_nodes

    def g(k, shape, sigma):
        if sigma == 0.0:
            return jnp.zeros(shape, dtype=jnp.float32)
        return sigma * jax.random.normal(k, shape, dtype=jnp.float32)

    return Mismatch(
        dac_bit_j=g(ks[0], (n, n, 8), cfg.sigma_dac_bit),
        dac_bit_h=g(ks[1], (n, 8), cfg.sigma_dac_bit),
        edge_gain=g(ks[2], (n, n), cfg.sigma_edge_gain),
        tanh_gain=g(ks[3], (n,), cfg.sigma_tanh_gain),
        tanh_offset=g(ks[4], (n,), cfg.sigma_tanh_offset),
        rand_gain=g(ks[5], (n,), cfg.sigma_rand_gain),
        comp_offset=g(ks[6], (n,), cfg.sigma_comp_offset),
        leak=jnp.abs(g(ks[7], (n, n), cfg.leak_frac)),
    )


def _bits(w_mag: jax.Array) -> jax.Array:
    """Binary expansion of |code| in [0, 128]. Returns float (..., 8)."""
    shifts = jnp.arange(8, dtype=jnp.int32)
    return ((w_mag[..., None].astype(jnp.int32) >> shifts) & 1).astype(
        jnp.float32
    )


def dac_transfer(code: jax.Array, bit_err: jax.Array) -> jax.Array:
    """R-2R DAC: signed 8-bit code -> analog current (weight-LSB units).

    Sign-magnitude current steering with per-branch mismatch:
      I = sign(code) * sum_b bit_b(|code|) * 2^b * (1 + eps_b)
    """
    sign = jnp.sign(code.astype(jnp.float32))
    mag = jnp.abs(code.astype(jnp.int32))
    weights = (2.0 ** jnp.arange(8, dtype=jnp.float32)) * (1.0 + bit_err)
    return sign * jnp.sum(_bits(mag) * weights, axis=-1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EffectiveChip:
    """Digital weights compiled through the analog model — what physics sees.

    W is *directional*: W[i, j] is the current injected into node i per unit
    spin m_j (the shared-edge DAC value times node-i's multiplier gain), so
    in general W != W.T under mismatch, exactly as on silicon.

    ``nbr_idx``/``nbr_w`` are the Chimera-native fixed-degree slot layout
    (see ChimeraGraph.neighbor_table): ``nbr_w[d, i] = W[i, nbr_idx[d, i]]``.
    A chip may carry both views (dense programming + `attach_sparse`), or
    only the sparse one (`program_weights_sparse`, W=None) for lattices
    where the dense (N, N) matrix cannot exist at all.
    """

    W: jax.Array | None     # (N, N) effective couplings, weight-LSB units
    h: jax.Array            # (N,)  effective biases
    tanh_gain: jax.Array    # (N,)  multiplicative on beta
    tanh_offset: jax.Array  # (N,)  additive current offset
    rand_gain: jax.Array    # (N,)
    comp_offset: jax.Array  # (N,)
    nbr_idx: jax.Array | None = None  # (D, N) int32 neighbor table
    nbr_w: jax.Array | None = None    # (D, N) per-slot couplings

    def tree_flatten(self):
        fields = dataclasses.fields(self)
        return tuple(getattr(self, f.name) for f in fields), None

    @classmethod
    def tree_unflatten(cls, aux: Any, children):
        return cls(*children)

    @property
    def n_nodes(self) -> int:
        return self.h.shape[-1]

    @property
    def degree(self) -> int:
        """Slot count D of the sparse layout (0 when dense-only)."""
        return 0 if self.nbr_idx is None else int(self.nbr_idx.shape[0])


def program_weights(
    J: jax.Array,
    h: jax.Array,
    enable: jax.Array,
    mism: Mismatch,
    cfg: HardwareConfig,
    adjacency: jax.Array | None = None,
    neighbors: jax.Array | None = None,
) -> EffectiveChip:
    """Compile digital (int8) weights into effective analog quantities.

    J: (N, N) symmetric int8 codes; h: (N,) int8 codes;
    enable: (N, N) bool coupler-enable bits; adjacency: (N, N) bool physical
    couplers (no current path at all where False); neighbors: optional
    (D, N) neighbor table — when given, the sparse slot view is attached to
    the returned chip (a gather of the final W, bit-identical entries).
    """
    J = jnp.asarray(J)
    n = J.shape[0]
    Wdac = dac_transfer(J, mism.dac_bit_j)           # shared per-edge DAC
    Wdir = Wdac * (1.0 + mism.edge_gain)             # per-direction multiplier
    # enable bit: disabled couplers leak a small fraction of full scale
    Wdir = jnp.where(enable, Wdir, jnp.sign(Wdir) * mism.leak * 128.0)
    if adjacency is not None:
        Wdir = jnp.where(adjacency, Wdir, 0.0)
    Wdir = Wdir * (1.0 - jnp.eye(n, dtype=Wdir.dtype))  # no self coupling
    # soft compression from finite DAC output resistance / supply droop
    if cfg.compression > 0.0:
        Wdir = Wdir / (1.0 + cfg.compression * jnp.abs(Wdir))
    h_eff = dac_transfer(h, mism.dac_bit_h)
    chip = EffectiveChip(
        W=Wdir.astype(jnp.float32),
        h=h_eff.astype(jnp.float32),
        tanh_gain=1.0 + mism.tanh_gain,
        tanh_offset=mism.tanh_offset,
        rand_gain=1.0 + mism.rand_gain,
        comp_offset=mism.comp_offset,
    )
    if neighbors is not None:
        chip = attach_sparse(chip, neighbors)
    return chip


def attach_sparse(chip: EffectiveChip, nbr_idx: jax.Array) -> EffectiveChip:
    """Gather the dense W into the (D, N) slot layout.

    ``nbr_w[d, i] = W[i, nbr_idx[d, i]]`` — bit-identical entries, so the
    sparse backends sample the exact same physics as the dense ones.
    Self-pointing padding slots read the (zero) diagonal.
    """
    idx = jnp.asarray(nbr_idx)
    rows = jnp.arange(chip.n_nodes)[None, :]
    nbr_w = chip.W[rows, idx].astype(jnp.float32)
    return dataclasses.replace(chip, nbr_idx=idx.astype(jnp.int32),
                               nbr_w=nbr_w)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SparseMismatch:
    """Per-instance variation in the fixed-degree slot layout.

    Pair fields are (D, N) — one entry per physical coupler *direction*
    (slot d of node i), exactly the entries the dense (N, N) model carries
    on the Chimera adjacency; everything off-graph, which the dense model
    samples and then masks to zero, is simply never sampled.  O(D·N)
    memory, so chip instances exist at lattice sizes where the dense
    Mismatch (N² and N²·8 arrays) cannot.
    """

    dac_bit_j: jax.Array      # (D, N, 8) per-bit branch error for J DACs
    dac_bit_h: jax.Array      # (N, 8)
    edge_gain: jax.Array      # (D, N) directional multiplier gain error
    tanh_gain: jax.Array      # (N,)
    tanh_offset: jax.Array    # (N,)
    rand_gain: jax.Array      # (N,)
    comp_offset: jax.Array    # (N,)
    leak: jax.Array           # (D, N) leakage of disabled couplers

    def tree_flatten(self):
        fields = dataclasses.fields(self)
        return tuple(getattr(self, f.name) for f in fields), None

    @classmethod
    def tree_unflatten(cls, aux: Any, children):
        return cls(*children)

    @classmethod
    def from_dense(cls, mism: "Mismatch", nbr_idx: jax.Array
                   ) -> "SparseMismatch":
        """Reproduce a *given* dense chip instance in the slot layout.

        Gathers exactly the on-graph entries of the dense draw, so a
        sparse-native machine built from this carries bit-identical
        mismatch to the dense machine: programming the same codes yields
        bit-identical ``nbr_w``, and the sparse backends then sample the
        identical spin trajectory (asserted at chip scale in
        tests/test_sparse.py::test_sparse_machine_reproduces_dense_chip).
        The dense (N², N²·8) arrays exist only as the *input* — the
        result is O(D·N), ready for lattice-scale sharded sampling.
        """
        idx = jnp.asarray(nbr_idx)
        rows = jnp.arange(mism.tanh_gain.shape[0])[None, :]
        return cls(
            dac_bit_j=mism.dac_bit_j[rows, idx],
            dac_bit_h=mism.dac_bit_h,
            edge_gain=mism.edge_gain[rows, idx],
            tanh_gain=mism.tanh_gain,
            tanh_offset=mism.tanh_offset,
            rand_gain=mism.rand_gain,
            comp_offset=mism.comp_offset,
            leak=mism.leak[rows, idx],
        )


def sample_mismatch_sparse(
    key: jax.Array, n_nodes: int, degree: int, cfg: HardwareConfig
) -> SparseMismatch:
    """Draw one chip instance's process variation, slot layout (O(D·N))."""
    ks = jax.random.split(key, 8)
    n, d = n_nodes, degree

    def g(k, shape, sigma):
        if sigma == 0.0:
            return jnp.zeros(shape, dtype=jnp.float32)
        return sigma * jax.random.normal(k, shape, dtype=jnp.float32)

    return SparseMismatch(
        dac_bit_j=g(ks[0], (d, n, 8), cfg.sigma_dac_bit),
        dac_bit_h=g(ks[1], (n, 8), cfg.sigma_dac_bit),
        edge_gain=g(ks[2], (d, n), cfg.sigma_edge_gain),
        tanh_gain=g(ks[3], (n,), cfg.sigma_tanh_gain),
        tanh_offset=g(ks[4], (n,), cfg.sigma_tanh_offset),
        rand_gain=g(ks[5], (n,), cfg.sigma_rand_gain),
        comp_offset=g(ks[6], (n,), cfg.sigma_comp_offset),
        leak=jnp.abs(g(ks[7], (d, n), cfg.leak_frac)),
    )


def gather_mismatch(mism: Mismatch, nbr_idx: jax.Array) -> SparseMismatch:
    """Dense (N, N) mismatch -> (D, N) slot layout.

    Alias of `SparseMismatch.from_dense` (kept for existing call sites)."""
    return SparseMismatch.from_dense(mism, nbr_idx)


def program_weights_sparse(
    J_slots: jax.Array,
    h: jax.Array,
    enable_slots: jax.Array,
    mism: SparseMismatch,
    cfg: HardwareConfig,
    nbr_idx: jax.Array,
    nbr_mask: jax.Array,
) -> EffectiveChip:
    """Sparse-native programming: slot codes -> EffectiveChip with W=None.

    J_slots/enable_slots: (D, N) int8 codes / enable bits in the neighbor
    table layout; nbr_mask marks physical couplers (padding slots carry no
    current path, mirroring the dense adjacency mask).  The elementwise
    analog chain is applied in the same order as `program_weights`, so with
    a gathered dense mismatch the resulting nbr_w is bit-identical to
    gathering the densely programmed W.  Never touches O(N²) memory.
    """
    J = jnp.asarray(J_slots)
    Wdac = dac_transfer(J, mism.dac_bit_j)
    Wdir = Wdac * (1.0 + mism.edge_gain)
    Wdir = jnp.where(enable_slots, Wdir, jnp.sign(Wdir) * mism.leak * 128.0)
    Wdir = jnp.where(nbr_mask, Wdir, 0.0)
    if cfg.compression > 0.0:
        Wdir = Wdir / (1.0 + cfg.compression * jnp.abs(Wdir))
    h_eff = dac_transfer(h, mism.dac_bit_h)
    return EffectiveChip(
        W=None,
        h=h_eff.astype(jnp.float32),
        tanh_gain=1.0 + mism.tanh_gain,
        tanh_offset=mism.tanh_offset,
        rand_gain=1.0 + mism.rand_gain,
        comp_offset=mism.comp_offset,
        nbr_idx=jnp.asarray(nbr_idx, jnp.int32),
        nbr_w=Wdir.astype(jnp.float32),
    )


def ideal_chip(J: jax.Array, h: jax.Array,
               adjacency: jax.Array | None = None,
               neighbors: jax.Array | None = None) -> EffectiveChip:
    """Zero-mismatch chip from float or int weights (the textbook p-bit)."""
    J = jnp.asarray(J, dtype=jnp.float32)
    n = J.shape[0]
    W = J * (1.0 - jnp.eye(n, dtype=jnp.float32))
    if adjacency is not None:
        W = jnp.where(adjacency, W, 0.0)
    ones = jnp.ones((n,), dtype=jnp.float32)
    chip = EffectiveChip(
        W=W,
        h=jnp.asarray(h, dtype=jnp.float32),
        tanh_gain=ones,
        tanh_offset=0.0 * ones,
        rand_gain=ones,
        comp_offset=0.0 * ones,
    )
    if neighbors is not None:
        chip = attach_sparse(chip, neighbors)
    return chip


def measure_node_transfer(
    chip_sampler,
    bias_codes: np.ndarray,
    **kw,
) -> np.ndarray:
    """Paper Fig. 8a: sweep the bias DAC and record <m> per node.

    `chip_sampler(bias_code) -> mean_spin[N]` is provided by callers; kept
    here for discoverability.  See benchmarks/bench_variability.py.
    """
    return np.stack([np.asarray(chip_sampler(b, **kw)) for b in bias_codes])
