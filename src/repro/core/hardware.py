"""Analog hardware model of the chip's non-idealities.

The paper's area-efficiency choices (standard-cell analog pitch-matched to
digital, shared 1 V supply, MOS R-2R DACs with no output-resistance
enhancement, un-matched current mirrors) buy density at the cost of
process-variation mismatch.  This module is the physics model of those
non-idealities; `program_weights` compiles digital 8-bit weights through it
into the *effective* analog quantities the sampler sees.

Modeled effects (all per chip *instance*, sampled from a PRNG key):
  * R-2R DAC per-bit branch mismatch       -> nonmonotonic INL/DNL in J & h
  * DAC output-resistance / supply droop   -> soft compression of large currents
  * Gilbert-multiplier gain error per edge *direction* -> asymmetric W[i,j] != W[j,i]
  * disabled-coupler leakage (enable bit leaks a small current)
  * WTA-tanh gain (beta) variation and input offset per node
  * RNG-DAC amplitude mismatch per node
  * comparator input offset per node

Setting ``HardwareConfig.ideal()`` zeroes every sigma, giving a bit-exact
textbook p-bit (used as the oracle in tests).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chimera import ChimeraGraph

WMIN, WMAX = -128, 127  # 8-bit signed DAC codes


@dataclasses.dataclass(frozen=True)
class HardwareConfig:
    """Process-variation sigmas (fraction of nominal unless noted)."""

    sigma_dac_bit: float = 0.04      # per-R-2R-branch current mismatch
    sigma_edge_gain: float = 0.05    # Gilbert multiplier gain, per direction
    sigma_tanh_gain: float = 0.08    # WTA tanh beta spread per node
    sigma_tanh_offset: float = 2.0   # input-referred offset, LSB units
    sigma_rand_gain: float = 0.05    # RNG DAC amplitude spread per node
    sigma_comp_offset: float = 0.02  # comparator offset, fraction of FS
    leak_frac: float = 0.004         # disabled-coupler leakage, fraction of FS
    compression: float = 3e-3        # soft saturation: I/(1+compression*|I|/FS)

    @staticmethod
    def ideal() -> "HardwareConfig":
        return HardwareConfig(0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0)

    def is_ideal(self) -> bool:
        return all(
            getattr(self, f.name) == 0.0 for f in dataclasses.fields(self)
        )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Mismatch:
    """Sampled per-instance variation (a pytree of arrays)."""

    dac_bit_j: jax.Array      # (N, N, 8) per-bit branch error for J DACs
    dac_bit_h: jax.Array      # (N, 8)
    edge_gain: jax.Array      # (N, N) directional multiplier gain error
    tanh_gain: jax.Array      # (N,)   multiplicative beta error
    tanh_offset: jax.Array    # (N,)   additive input offset (weight LSB units)
    rand_gain: jax.Array      # (N,)
    comp_offset: jax.Array    # (N,)
    leak: jax.Array           # (N, N) leakage of disabled couplers

    def tree_flatten(self):
        fields = dataclasses.fields(self)
        return tuple(getattr(self, f.name) for f in fields), None

    @classmethod
    def tree_unflatten(cls, aux: Any, children):
        return cls(*children)


def sample_mismatch(
    key: jax.Array, n_nodes: int, cfg: HardwareConfig
) -> Mismatch:
    """Draw one chip instance's process variation."""
    ks = jax.random.split(key, 8)
    n = n_nodes

    def g(k, shape, sigma):
        if sigma == 0.0:
            return jnp.zeros(shape, dtype=jnp.float32)
        return sigma * jax.random.normal(k, shape, dtype=jnp.float32)

    return Mismatch(
        dac_bit_j=g(ks[0], (n, n, 8), cfg.sigma_dac_bit),
        dac_bit_h=g(ks[1], (n, 8), cfg.sigma_dac_bit),
        edge_gain=g(ks[2], (n, n), cfg.sigma_edge_gain),
        tanh_gain=g(ks[3], (n,), cfg.sigma_tanh_gain),
        tanh_offset=g(ks[4], (n,), cfg.sigma_tanh_offset),
        rand_gain=g(ks[5], (n,), cfg.sigma_rand_gain),
        comp_offset=g(ks[6], (n,), cfg.sigma_comp_offset),
        leak=jnp.abs(g(ks[7], (n, n), cfg.leak_frac)),
    )


def _bits(w_mag: jax.Array) -> jax.Array:
    """Binary expansion of |code| in [0, 128]. Returns float (..., 8)."""
    shifts = jnp.arange(8, dtype=jnp.int32)
    return ((w_mag[..., None].astype(jnp.int32) >> shifts) & 1).astype(
        jnp.float32
    )


def dac_transfer(code: jax.Array, bit_err: jax.Array) -> jax.Array:
    """R-2R DAC: signed 8-bit code -> analog current (weight-LSB units).

    Sign-magnitude current steering with per-branch mismatch:
      I = sign(code) * sum_b bit_b(|code|) * 2^b * (1 + eps_b)
    """
    sign = jnp.sign(code.astype(jnp.float32))
    mag = jnp.abs(code.astype(jnp.int32))
    weights = (2.0 ** jnp.arange(8, dtype=jnp.float32)) * (1.0 + bit_err)
    return sign * jnp.sum(_bits(mag) * weights, axis=-1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EffectiveChip:
    """Digital weights compiled through the analog model — what physics sees.

    W is *directional*: W[i, j] is the current injected into node i per unit
    spin m_j (the shared-edge DAC value times node-i's multiplier gain), so
    in general W != W.T under mismatch, exactly as on silicon.
    """

    W: jax.Array            # (N, N) effective couplings, weight-LSB units
    h: jax.Array            # (N,)  effective biases
    tanh_gain: jax.Array    # (N,)  multiplicative on beta
    tanh_offset: jax.Array  # (N,)  additive current offset
    rand_gain: jax.Array    # (N,)
    comp_offset: jax.Array  # (N,)

    def tree_flatten(self):
        fields = dataclasses.fields(self)
        return tuple(getattr(self, f.name) for f in fields), None

    @classmethod
    def tree_unflatten(cls, aux: Any, children):
        return cls(*children)

    @property
    def n_nodes(self) -> int:
        return self.W.shape[-1]


def program_weights(
    J: jax.Array,
    h: jax.Array,
    enable: jax.Array,
    mism: Mismatch,
    cfg: HardwareConfig,
    adjacency: jax.Array | None = None,
) -> EffectiveChip:
    """Compile digital (int8) weights into effective analog quantities.

    J: (N, N) symmetric int8 codes; h: (N,) int8 codes;
    enable: (N, N) bool coupler-enable bits; adjacency: (N, N) bool physical
    couplers (no current path at all where False).
    """
    J = jnp.asarray(J)
    n = J.shape[0]
    Wdac = dac_transfer(J, mism.dac_bit_j)           # shared per-edge DAC
    Wdir = Wdac * (1.0 + mism.edge_gain)             # per-direction multiplier
    # enable bit: disabled couplers leak a small fraction of full scale
    Wdir = jnp.where(enable, Wdir, jnp.sign(Wdir) * mism.leak * 128.0)
    if adjacency is not None:
        Wdir = jnp.where(adjacency, Wdir, 0.0)
    Wdir = Wdir * (1.0 - jnp.eye(n, dtype=Wdir.dtype))  # no self coupling
    # soft compression from finite DAC output resistance / supply droop
    if cfg.compression > 0.0:
        Wdir = Wdir / (1.0 + cfg.compression * jnp.abs(Wdir))
    h_eff = dac_transfer(h, mism.dac_bit_h)
    return EffectiveChip(
        W=Wdir.astype(jnp.float32),
        h=h_eff.astype(jnp.float32),
        tanh_gain=1.0 + mism.tanh_gain,
        tanh_offset=mism.tanh_offset,
        rand_gain=1.0 + mism.rand_gain,
        comp_offset=mism.comp_offset,
    )


def ideal_chip(J: jax.Array, h: jax.Array,
               adjacency: jax.Array | None = None) -> EffectiveChip:
    """Zero-mismatch chip from float or int weights (the textbook p-bit)."""
    J = jnp.asarray(J, dtype=jnp.float32)
    n = J.shape[0]
    W = J * (1.0 - jnp.eye(n, dtype=jnp.float32))
    if adjacency is not None:
        W = jnp.where(adjacency, W, 0.0)
    ones = jnp.ones((n,), dtype=jnp.float32)
    return EffectiveChip(
        W=W,
        h=jnp.asarray(h, dtype=jnp.float32),
        tanh_gain=ones,
        tanh_offset=0.0 * ones,
        rand_gain=ones,
        comp_offset=0.0 * ones,
    )


def measure_node_transfer(
    chip_sampler,
    bias_codes: np.ndarray,
    **kw,
) -> np.ndarray:
    """Paper Fig. 8a: sweep the bias DAC and record <m> per node.

    `chip_sampler(bias_code) -> mean_spin[N]` is provided by callers; kept
    here for discoverability.  See benchmarks/bench_variability.py.
    """
    return np.stack([np.asarray(chip_sampler(b, **kw)) for b in bias_codes])
