"""Simulated annealing on the chip (paper Fig. 9a).

On silicon the annealing temperature is a voltage (V_temp) scaling the tanh
gain; here it is the per-sweep beta passed to the chromatic Gibbs sweep.
The SK-style spin glass uses Gaussian couplings on the *Chimera edge set*
(the chip has no other current paths), quantized to 8-bit DAC codes exactly
as the hardware requires.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pbit
from repro.core.cd import PBitMachine, quantize_codes
from repro.core.chimera import ChimeraGraph
from repro.core.energy import ising_energy


@dataclasses.dataclass
class AnnealConfig:
    n_sweeps: int = 1000
    beta_start: float = 0.05
    beta_end: float = 3.0
    schedule: str = "geometric"  # or "linear"
    chains: int = 64


def beta_schedule(cfg: AnnealConfig) -> jnp.ndarray:
    t = jnp.linspace(0.0, 1.0, cfg.n_sweeps)
    if cfg.schedule == "geometric":
        return cfg.beta_start * (cfg.beta_end / cfg.beta_start) ** t
    return cfg.beta_start + (cfg.beta_end - cfg.beta_start) * t


def sk_instance(graph: ChimeraGraph, key: jax.Array,
                scale: float = 64.0) -> tuple[np.ndarray, np.ndarray]:
    """Sherrington-Kirkpatrick-style Gaussian couplings on Chimera edges,
    as 8-bit DAC codes (J_codes symmetric, h = 0)."""
    e = graph.edges
    vals = np.asarray(jax.random.normal(key, (e.shape[0],))) * scale / 2.0
    J = np.zeros((graph.n_nodes, graph.n_nodes), np.float32)
    J[e[:, 0], e[:, 1]] = vals
    J[e[:, 1], e[:, 0]] = vals
    J = np.clip(np.round(J), -128, 127)
    h = np.zeros((graph.n_nodes,), np.float32)
    return J, h


def anneal(
    machine: PBitMachine,
    J_codes: np.ndarray,
    h_codes: np.ndarray,
    cfg: AnnealConfig,
    key: jax.Array,
    record_every: int = 10,
) -> dict:
    """Run SA; returns energy trajectory (measured with the *ideal* digital
    weights — the figure of merit is the true problem energy, while dynamics
    run through the mismatched analog path, as on the real chip)."""
    g = machine.graph
    chip = machine.program(quantize_codes(jnp.asarray(J_codes)),
                           quantize_codes(jnp.asarray(h_codes)))
    k1, k2 = jax.random.split(key)
    m0 = pbit.random_spins(k1, cfg.chains, g.n_nodes)
    noise_state, noise_fn = machine.noise_fn(k2, cfg.chains)
    betas = beta_schedule(cfg) * machine.w_scale ** 0  # beta acts on LSB units

    _, _, traj = pbit.gibbs_sample(
        chip, jnp.asarray(g.color), m0, betas, noise_state, noise_fn,
        collect=True, backend=machine.backend)
    Jf = jnp.asarray(J_codes, jnp.float32)
    hf = jnp.asarray(h_codes, jnp.float32)
    sel = np.arange(0, cfg.n_sweeps, record_every)
    e = jax.vmap(lambda mm: ising_energy(mm, Jf, hf))(traj[sel])
    e = np.asarray(e)  # (len(sel), chains)
    final_e = np.asarray(ising_energy(traj[-1], Jf, hf))
    return {
        "sweeps": sel,
        "energy_mean": e.mean(axis=1),
        "energy_min": e.min(axis=1),
        "best_energy": float(final_e.min()),
        "best_state": np.asarray(traj[-1][int(final_e.argmin())]),
    }
