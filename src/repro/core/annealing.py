"""Simulated annealing on the chip (paper Fig. 9a).

On silicon the annealing temperature is a voltage (V_temp) scaling the tanh
gain; here it is the per-sweep beta of a first-class `api.Anneal` schedule
compiled into an `api.Session`.  The SK-style spin glass uses Gaussian
couplings on the *Chimera edge set* (the chip has no other current paths),
quantized to 8-bit DAC codes exactly as the hardware requires.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core.cd import PBitMachine, quantize_codes
from repro.core.chimera import ChimeraGraph
from repro.core.energy import ising_energy


@dataclasses.dataclass
class AnnealConfig:
    n_sweeps: int = 1000
    beta_start: float = 0.05
    beta_end: float = 3.0
    schedule: str = "geometric"  # or "linear"
    chains: int = 64

    def to_schedule(self) -> api.Anneal:
        """The declarative `api.Anneal` this config describes."""
        return api.Anneal(n_sweeps=self.n_sweeps,
                          beta_start=self.beta_start,
                          beta_end=self.beta_end, kind=self.schedule)


def beta_schedule(cfg: AnnealConfig) -> jnp.ndarray:
    """Deprecated shim: materialize the schedule (use `api.Anneal`)."""
    return cfg.to_schedule().betas()


def sk_instance(graph: ChimeraGraph, key: jax.Array,
                scale: float = 64.0) -> tuple[np.ndarray, np.ndarray]:
    """Sherrington-Kirkpatrick-style Gaussian couplings on Chimera edges,
    as 8-bit DAC codes (J_codes symmetric, h = 0)."""
    e = graph.edges
    vals = np.asarray(jax.random.normal(key, (e.shape[0],))) * scale / 2.0
    J = np.zeros((graph.n_nodes, graph.n_nodes), np.float32)
    J[e[:, 0], e[:, 1]] = vals
    J[e[:, 1], e[:, 0]] = vals
    J = np.clip(np.round(J), -128, 127)
    h = np.zeros((graph.n_nodes,), np.float32)
    return J, h


def anneal(
    machine: PBitMachine,
    J_codes: np.ndarray,
    h_codes: np.ndarray,
    cfg: AnnealConfig,
    key: jax.Array,
    record_every: int = 10,
    session: api.Session | None = None,
) -> dict:
    """Run SA; returns energy trajectory (measured with the *ideal* digital
    weights — the figure of merit is the true problem energy, while dynamics
    run through the mismatched analog path, as on the real chip).

    ``session`` lets callers (e.g. maxcut.solve_maxcut) supply their own
    compiled `api.Session`; by default one is compiled from the machine
    with the config's `api.Anneal` schedule.
    """
    if session is None:
        session = machine.session(schedule=cfg.to_schedule(),
                                  chains=cfg.chains)
    else:
        # a mismatched schedule would silently truncate the trajectory
        # (traj[sel] clamps out-of-range sweep indices) — reject it here
        if session.spec.chains != cfg.chains:
            raise ValueError(
                f"session runs {session.spec.chains} chains but "
                f"cfg.chains={cfg.chains}")
        if session.default_betas is None or \
                session.default_betas.shape[0] != cfg.n_sweeps:
            have = (None if session.default_betas is None
                    else session.default_betas.shape[0])
            raise ValueError(
                f"session schedule has {have} sweeps but "
                f"cfg.n_sweeps={cfg.n_sweeps}; build it with "
                f"schedule=cfg.to_schedule()")
    chip = session.program(quantize_codes(jnp.asarray(J_codes)),
                           quantize_codes(jnp.asarray(h_codes)))
    k1, k2 = jax.random.split(key)
    m0 = session.random_spins(k1)
    noise_state = session.noise_state(k2)

    _, _, traj = session.sample(chip, m0, noise_state, collect=True)
    Jf = jnp.asarray(J_codes, jnp.float32)
    hf = jnp.asarray(h_codes, jnp.float32)
    sel = np.arange(0, cfg.n_sweeps, record_every)
    e = jax.vmap(lambda mm: ising_energy(mm, Jf, hf))(traj[sel])
    e = np.asarray(e)  # (len(sel), chains)
    final_e = np.asarray(ising_energy(traj[-1], Jf, hf))
    return {
        "sweeps": sel,
        "energy_mean": e.mean(axis=1),
        "energy_min": e.min(axis=1),
        "best_energy": float(final_e.min()),
        "best_state": np.asarray(traj[-1][int(final_e.argmin())]),
    }
