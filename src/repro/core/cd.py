"""In-situ hardware-aware learning: contrastive divergence through the chip.

Paper Fig. 7a: the training loop alternates
  positive phase  — clamp the visible nodes to data, Gibbs-sample the hidden
                    nodes *on the (mismatched) chip*, measure <m_i m_j>+.
  negative phase  — release the clamp, free-run the chip k sweeps, measure
                    <m_i m_j>-.
  update          — J_ij += lr (<mimj>+ - <mimj>-) on the physical couplers,
                    h_i  += lr (<mi>+   - <mi>-),
then re-program the 8-bit weight DACs.  Because both phases are sampled
through the same analog non-idealities, the learned weights absorb the
mismatch — the paper's central claim (we verify it in
tests/test_cd.py::test_hardware_aware_beats_transfer).

Weights are kept as float "master" values (the host accumulator) and
quantized to signed 8-bit DAC codes on every (re)program, matching the
chip's digital weight storage.  The master couplings live on the *edge
list* — one float per physical coupler, exactly the chip's weight-DAC
count — so the CD update is O(E) and never touches an (n, n) matrix.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy as energy_mod
from repro.core import pbit
from repro.core.chimera import ChimeraGraph
from repro.core.hardware import (
    WMAX,
    WMIN,
    EffectiveChip,
    HardwareConfig,
    Mismatch,
    SparseMismatch,
    attach_sparse,
    program_weights,
    program_weights_sparse,
    sample_mismatch,
    sample_mismatch_sparse,
)


@dataclasses.dataclass
class PBitMachine:
    """A (simulated) chip instance: graph + mismatch + programmable weights.

    With a dense `Mismatch` the machine programs the full analog model and
    attaches the Chimera-native slot view (a gather — bit-identical
    entries), so every backend runs on the same physics.  With a
    `SparseMismatch` (create(..., sparse=True)) nothing O(n²) is ever
    built: the machine only supports the sparse backends, which is the
    point — it instantiates at lattice sizes where the dense model cannot.
    """

    graph: ChimeraGraph
    hw: HardwareConfig
    mismatch: Mismatch | SparseMismatch
    beta: float = 1.0
    noise: str = "philox"   # "philox" | "counter" | "lfsr"
    backend: str = "auto"   # auto | ref | pallas | fused | sparse | fused_sparse
    w_scale: float = 0.05  # weight-LSB -> coupling units (ext. resistor knob)

    @staticmethod
    def create(graph: ChimeraGraph, key: jax.Array,
               hw: HardwareConfig | None = None, sparse: bool = False,
               **kw) -> "PBitMachine":
        hw = hw or HardwareConfig()
        if sparse:
            nbr_idx, _ = graph.neighbor_table()
            mism = sample_mismatch_sparse(key, graph.n_nodes,
                                          nbr_idx.shape[0], hw)
            # sparse-native chips have no dense W: the dense backends
            # cannot run them, so don't let "auto" resolve to one
            kw.setdefault("backend", "sparse")
        else:
            mism = sample_mismatch(key, graph.n_nodes, hw)
        return PBitMachine(graph=graph, hw=hw, mismatch=mism, **kw)

    @property
    def sparse_native(self) -> bool:
        """True when only the O(D·n) slot model exists (no dense W ever)."""
        return isinstance(self.mismatch, SparseMismatch)

    def neighbor_tables(self):
        """(nbr_idx, nbr_mask, slot_ij, slot_ji), cached per machine."""
        nt = getattr(self, "_nbr_tables", None)
        if nt is None:
            nbr_idx, nbr_mask = self.graph.neighbor_table()
            slot_ij, slot_ji = self.graph.edge_slots(nbr_idx)
            nt = (nbr_idx, nbr_mask, slot_ij, slot_ji)
            self._nbr_tables = nt
        return nt

    # -- programming ----------------------------------------------------
    def program(self, J_codes: jax.Array, h_codes: jax.Array,
                enable: jax.Array | None = None) -> EffectiveChip:
        """Program dense (n, n) symmetric codes (chip-scale convenience)."""
        nbr_idx, nbr_mask, _, _ = self.neighbor_tables()
        if enable is None:
            enable = jnp.abs(J_codes) > 0
        if self.sparse_native:
            rows = jnp.arange(self.graph.n_nodes)[None, :]
            idx = jnp.asarray(nbr_idx)
            chip = program_weights_sparse(
                jnp.asarray(J_codes)[rows, idx], h_codes,
                jnp.asarray(enable)[rows, idx], self.mismatch, self.hw,
                idx, jnp.asarray(nbr_mask))
        else:
            adj = jnp.asarray(self.graph.adjacency())
            chip = program_weights(J_codes, h_codes, enable, self.mismatch,
                                   self.hw, adjacency=adj,
                                   neighbors=jnp.asarray(nbr_idx))
        return self._scale(chip)

    def program_edges(self, J_edge_codes: jax.Array, h_codes: jax.Array
                      ) -> EffectiveChip:
        """Program per-edge codes (E,) — the CD master-weight layout.

        Sparse-native machines scatter straight into the (D, n) slot
        layout (two O(E) scatters, one per coupler direction); dense
        machines scatter to the symmetric (n, n) code matrix first.
        """
        nbr_idx, nbr_mask, slot_ij, slot_ji = self.neighbor_tables()
        e = self.graph.edges
        codes = jnp.asarray(J_edge_codes)
        if self.sparse_native:
            D = nbr_idx.shape[0]
            n = self.graph.n_nodes
            J_slots = (jnp.zeros((D, n), codes.dtype)
                       .at[slot_ij, e[:, 0]].set(codes)
                       .at[slot_ji, e[:, 1]].set(codes))
            chip = program_weights_sparse(
                J_slots, h_codes, jnp.abs(J_slots) > 0, self.mismatch,
                self.hw, jnp.asarray(nbr_idx), jnp.asarray(nbr_mask))
            return self._scale(chip)
        n = self.graph.n_nodes
        J = (jnp.zeros((n, n), codes.dtype)
             .at[e[:, 0], e[:, 1]].set(codes)
             .at[e[:, 1], e[:, 0]].set(codes))
        return self.program(J, h_codes)

    def program_master(self, Jm: jax.Array, hm: jax.Array) -> EffectiveChip:
        """Quantize float master weights — edge-list (E,) or dense (n, n) —
        to 8-bit DAC codes and program."""
        Jm = jnp.asarray(Jm)
        if Jm.ndim == 1:
            return self.program_edges(quantize_codes(Jm), quantize_codes(hm))
        return self.program(quantize_codes(Jm), quantize_codes(hm))

    def _scale(self, chip: EffectiveChip) -> EffectiveChip:
        # external-resistor scale: DAC LSB units -> neuron-input units
        upd = {"h": chip.h * self.w_scale}
        if chip.W is not None:
            upd["W"] = chip.W * self.w_scale
        if chip.nbr_w is not None:
            upd["nbr_w"] = chip.nbr_w * self.w_scale
        return dataclasses.replace(chip, **upd)

    def noise_fn(self, key: jax.Array, batch: int):
        if self.noise == "lfsr":
            init, step = pbit.make_lfsr_noise(self.graph, batch)
            return init(key), step
        if self.noise == "counter":
            init, step = pbit.make_counter_noise(batch, self.graph.n_nodes)
            return init(key), step
        return key, pbit.make_philox_noise(batch, self.graph.n_nodes)


def quantize_codes(w: jax.Array, lsb: float = 1.0) -> jax.Array:
    """Float master weights -> signed 8-bit DAC codes."""
    return jnp.clip(jnp.round(w / lsb), WMIN, WMAX).astype(jnp.int32)


@dataclasses.dataclass
class CDConfig:
    lr: float = 4.0            # in DAC-LSB units per unit correlation error
    cd_k: int = 10             # sweeps per negative phase
    pos_sweeps: int = 10       # sweeps with visibles clamped
    burn_in: int = 2
    chains: int = 256          # parallel Gibbs chains (chip reprogram batches)
    epochs: int = 60
    h_lr_scale: float = 1.0
    weight_decay: float = 0.0
    # beyond-paper options (EXPERIMENTS §Perf extensions):
    persistent: bool = False   # PCD: negative chains persist across epochs
                               # instead of restarting from the data clamp
    momentum: float = 0.0      # heavy-ball on the correlation gradient


def _phase_stats(machine, chip, color, edges, m0, n_sweeps, burn_in,
                 noise_state, noise_fn, clamp_mask=None, clamp_values=None):
    return pbit.gibbs_stats(
        chip, color, m0, machine.beta, n_sweeps, burn_in,
        noise_state, noise_fn, edges,
        clamp_mask=clamp_mask, clamp_values=clamp_values,
        backend=machine.backend)


def make_cd_step(machine: PBitMachine, cfg: CDConfig,
                 visible_idx: np.ndarray):
    """Build the jitted one-epoch CD update.

    Returns step(Jm, hm, data_vis, m, noise_state, vel) ->
      (Jm, hm, m, noise_state, vel, metrics) where Jm is the (n_edges,)
    float master couplings (one per physical coupler — no (n, n) matrix
    anywhere in the update), hm the (n,) master biases, and data_vis
    (chains, n_visible) ±1 data samples for the positive phase.  The CD
    gradient is already an edge-list quantity (<m_i m_j>+ - <m_i m_j>-),
    so the weight update is a pure O(E) axpy.
    """
    g = machine.graph
    edges = jnp.asarray(g.edges)
    color = jnp.asarray(g.color)
    n = g.n_nodes
    vis = jnp.asarray(visible_idx)
    clamp_mask = jnp.zeros((n,), bool).at[vis].set(True)

    # the noise *step* fn is static (closed over scatter tables); the noise
    # *state* threads through `step` as a carry.
    _, noise_fn = machine.noise_fn(jax.random.PRNGKey(0), cfg.chains)

    @jax.jit
    def step(Jm, hm, data_vis, m, noise_state, vel):
        chip = machine.program_edges(quantize_codes(Jm), quantize_codes(hm))
        clamp_values = jnp.zeros((cfg.chains, n), jnp.float32)
        clamp_values = clamp_values.at[:, vis].set(data_vis)

        # positive phase: visibles pinned to data
        pos_s, pos_c, m_pos, noise_state = _phase_stats(
            machine, chip, color, edges, m, cfg.pos_sweeps, cfg.burn_in,
            noise_state, noise_fn, clamp_mask, clamp_values)
        # negative phase: CD-k from the positive-phase state, or from the
        # persistent chains (PCD — the chip never reinitializes; it just
        # keeps free-running between weight reprograms)
        neg_init = m if cfg.persistent else m_pos
        neg_s, neg_c, m_neg, noise_state = _phase_stats(
            machine, chip, color, edges, neg_init, cfg.cd_k, cfg.burn_in,
            noise_state, noise_fn)

        gJ = pos_c - neg_c
        gh = pos_s - neg_s
        vel_J, vel_h = vel
        vel_J = cfg.momentum * vel_J + gJ
        vel_h = cfg.momentum * vel_h + gh
        Jm = (1.0 - cfg.weight_decay) * Jm + cfg.lr * vel_J
        hm = (1.0 - cfg.weight_decay) * hm + cfg.lr * cfg.h_lr_scale * vel_h
        Jm = jnp.clip(Jm, WMIN, WMAX)
        hm = jnp.clip(hm, WMIN, WMAX)
        metrics = {
            "corr_err": jnp.abs(pos_c - neg_c).mean(),
            "mean_err": jnp.abs(pos_s - neg_s).mean(),
        }
        return Jm, hm, m_neg, noise_state, (vel_J, vel_h), metrics

    return step


def sample_visible_dist(machine: PBitMachine, Jm, hm,
                        visible_idx: np.ndarray, key: jax.Array,
                        chains: int = 256, sweeps: int = 200,
                        burn_in: int = 20) -> np.ndarray:
    """Free-run the programmed chip and histogram the visible marginal.

    Jm may be edge-list (E,) or dense (n, n) float master weights.  The
    histogram streams (pbit.gibbs_visible_hist): on the scan backends it
    folds into the sweep loop, on the fused backends it accumulates inside
    the kernel — the (sweeps, chains, N) trajectory never materializes.
    """
    g = machine.graph
    chip = machine.program_master(Jm, hm)
    k1, k2 = jax.random.split(key)
    m0 = pbit.random_spins(k1, chains, g.n_nodes)
    noise_state, noise_fn = machine.noise_fn(k2, chains)
    betas = jnp.full((sweeps,), machine.beta, jnp.float32)
    counts, _, _ = pbit.gibbs_visible_hist(
        chip, jnp.asarray(g.color), m0, betas, burn_in, noise_state,
        noise_fn, visible_idx, backend=machine.backend)
    counts = np.asarray(counts, np.float64)
    return counts / max(counts.sum(), 1.0)


@dataclasses.dataclass
class CDResult:
    """Learned master weights.  ``J_edges`` is the native (E,) edge-list
    form; ``Jm`` reconstructs the symmetric dense matrix for small-n
    reporting and eval."""

    J_edges: np.ndarray
    hm: np.ndarray
    kl_history: list
    metric_history: list
    edges: np.ndarray
    n_nodes: int

    @property
    def Jm(self) -> np.ndarray:
        J = np.zeros((self.n_nodes, self.n_nodes), np.float32)
        J[self.edges[:, 0], self.edges[:, 1]] = self.J_edges
        J[self.edges[:, 1], self.edges[:, 0]] = self.J_edges
        return J


def train_cd(
    machine: PBitMachine,
    visible_idx: np.ndarray,
    target_dist: np.ndarray,
    cfg: CDConfig,
    key: jax.Array,
    eval_every: int = 10,
    verbose: bool = False,
) -> CDResult:
    """Full in-situ CD training loop against a target visible distribution."""
    g = machine.graph
    n, nv = g.n_nodes, len(visible_idx)
    step = make_cd_step(machine, cfg, visible_idx)

    key, k1, k2, k3 = jax.random.split(key, 4)
    Jm = jnp.zeros((g.n_edges,), jnp.float32)
    hm = jnp.zeros((n,), jnp.float32)
    m = pbit.random_spins(k1, cfg.chains, n)
    noise_state, _ = machine.noise_fn(k2, cfg.chains)

    # enumerate visible configs for sampling data from the target dist
    codes = energy_mod.all_states(nv)  # (2^nv, nv) ±1, code order
    vel = (jnp.zeros((g.n_edges,), jnp.float32),
           jnp.zeros((n,), jnp.float32))
    kl_hist, met_hist = [], []
    for epoch in range(cfg.epochs):
        key, kd, ke = jax.random.split(key, 3)
        idx = jax.random.choice(
            kd, codes.shape[0], (cfg.chains,), p=jnp.asarray(target_dist))
        data_vis = jnp.asarray(codes)[idx]
        Jm, hm, m, noise_state, vel, metrics = step(Jm, hm, data_vis, m,
                                                    noise_state, vel)
        met_hist.append({k: float(v) for k, v in metrics.items()})
        if (epoch + 1) % eval_every == 0 or epoch == cfg.epochs - 1:
            emp = sample_visible_dist(machine, Jm, hm, visible_idx, ke)
            kl = energy_mod.kl_divergence(np.asarray(target_dist), emp)
            kl_hist.append((epoch + 1, kl))
            if verbose:
                print(f"epoch {epoch+1:4d}  KL={kl:.4f}  "
                      f"corr_err={met_hist[-1]['corr_err']:.4f}")
    return CDResult(np.asarray(Jm), np.asarray(hm), kl_hist, met_hist,
                    edges=np.asarray(g.edges), n_nodes=n)
