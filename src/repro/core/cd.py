"""In-situ hardware-aware learning: contrastive divergence through the chip.

Paper Fig. 7a: the training loop alternates
  positive phase  — clamp the visible nodes to data, Gibbs-sample the hidden
                    nodes *on the (mismatched) chip*, measure <m_i m_j>+.
  negative phase  — release the clamp, free-run the chip k sweeps, measure
                    <m_i m_j>-.
  update          — J_ij += lr (<mimj>+ - <mimj>-) on the physical couplers,
                    h_i  += lr (<mi>+   - <mi>-),
then re-program the 8-bit weight DACs.  Because both phases are sampled
through the same analog non-idealities, the learned weights absorb the
mismatch — the paper's central claim (we verify it in
tests/test_cd.py::test_hardware_aware_beats_transfer).

Weights are kept as float "master" values (the host accumulator) and
quantized to signed 8-bit DAC codes on every (re)program, matching the
chip's digital weight storage.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy as energy_mod
from repro.core import pbit
from repro.core.chimera import ChimeraGraph
from repro.core.hardware import (
    WMAX,
    WMIN,
    EffectiveChip,
    HardwareConfig,
    Mismatch,
    program_weights,
    sample_mismatch,
)


@dataclasses.dataclass
class PBitMachine:
    """A (simulated) chip instance: graph + mismatch + programmable weights."""

    graph: ChimeraGraph
    hw: HardwareConfig
    mismatch: Mismatch
    beta: float = 1.0
    noise: str = "philox"   # "philox" | "counter" | "lfsr"
    backend: str = "auto"   # sampling backend: auto | ref | pallas | fused
    w_scale: float = 0.05  # weight-LSB -> coupling units (ext. resistor knob)

    @staticmethod
    def create(graph: ChimeraGraph, key: jax.Array,
               hw: HardwareConfig | None = None, **kw) -> "PBitMachine":
        hw = hw or HardwareConfig()
        return PBitMachine(
            graph=graph, hw=hw,
            mismatch=sample_mismatch(key, graph.n_nodes, hw), **kw)

    # -- programming ----------------------------------------------------
    def program(self, J_codes: jax.Array, h_codes: jax.Array,
                enable: jax.Array | None = None) -> EffectiveChip:
        adj = jnp.asarray(self.graph.adjacency())
        if enable is None:
            enable = jnp.abs(J_codes) > 0
        chip = program_weights(J_codes, h_codes, enable, self.mismatch,
                               self.hw, adjacency=adj)
        # external-resistor scale: DAC LSB units -> neuron-input units
        return dataclasses.replace(
            chip, W=chip.W * self.w_scale, h=chip.h * self.w_scale)

    def noise_fn(self, key: jax.Array, batch: int):
        if self.noise == "lfsr":
            init, step = pbit.make_lfsr_noise(self.graph, batch)
            return init(key), step
        if self.noise == "counter":
            init, step = pbit.make_counter_noise(batch, self.graph.n_nodes)
            return init(key), step
        return key, pbit.make_philox_noise(batch, self.graph.n_nodes)


def quantize_codes(w: jax.Array, lsb: float = 1.0) -> jax.Array:
    """Float master weights -> signed 8-bit DAC codes."""
    return jnp.clip(jnp.round(w / lsb), WMIN, WMAX).astype(jnp.int32)


@dataclasses.dataclass
class CDConfig:
    lr: float = 4.0            # in DAC-LSB units per unit correlation error
    cd_k: int = 10             # sweeps per negative phase
    pos_sweeps: int = 10       # sweeps with visibles clamped
    burn_in: int = 2
    chains: int = 256          # parallel Gibbs chains (chip reprogram batches)
    epochs: int = 60
    h_lr_scale: float = 1.0
    weight_decay: float = 0.0
    # beyond-paper options (EXPERIMENTS §Perf extensions):
    persistent: bool = False   # PCD: negative chains persist across epochs
                               # instead of restarting from the data clamp
    momentum: float = 0.0      # heavy-ball on the correlation gradient


def _phase_stats(machine, chip, color, edges, m0, n_sweeps, burn_in,
                 noise_state, noise_fn, clamp_mask=None, clamp_values=None):
    return pbit.gibbs_stats(
        chip, color, m0, machine.beta, n_sweeps, burn_in,
        noise_state, noise_fn, edges,
        clamp_mask=clamp_mask, clamp_values=clamp_values,
        backend=machine.backend)


def make_cd_step(machine: PBitMachine, cfg: CDConfig,
                 visible_idx: np.ndarray):
    """Build the jitted one-epoch CD update.

    Returns step(Jm, hm, data_vis, m, noise_state) ->
      (Jm, hm, m, noise_state, metrics) where Jm/hm are float master weights,
    data_vis is (chains, n_visible) ±1 data samples for the positive phase.
    """
    g = machine.graph
    edges = jnp.asarray(g.edges)
    color = jnp.asarray(g.color)
    n = g.n_nodes
    vis = jnp.asarray(visible_idx)
    clamp_mask = jnp.zeros((n,), bool).at[vis].set(True)
    e0, e1 = edges[:, 0], edges[:, 1]

    # the noise *step* fn is static (closed over scatter tables); the noise
    # *state* threads through `step` as a carry.
    _, noise_fn = machine.noise_fn(jax.random.PRNGKey(0), cfg.chains)

    @jax.jit
    def step(Jm, hm, data_vis, m, noise_state, vel):
        chip = machine.program(quantize_codes(Jm), quantize_codes(hm))
        clamp_values = jnp.zeros((cfg.chains, n), jnp.float32)
        clamp_values = clamp_values.at[:, vis].set(data_vis)

        # positive phase: visibles pinned to data
        pos_s, pos_c, m_pos, noise_state = _phase_stats(
            machine, chip, color, edges, m, cfg.pos_sweeps, cfg.burn_in,
            noise_state, noise_fn, clamp_mask, clamp_values)
        # negative phase: CD-k from the positive-phase state, or from the
        # persistent chains (PCD — the chip never reinitializes; it just
        # keeps free-running between weight reprograms)
        neg_init = m if cfg.persistent else m_pos
        neg_s, neg_c, m_neg, noise_state = _phase_stats(
            machine, chip, color, edges, neg_init, cfg.cd_k, cfg.burn_in,
            noise_state, noise_fn)

        gJ = pos_c - neg_c
        gh = pos_s - neg_s
        vel_J, vel_h = vel
        vel_J = cfg.momentum * vel_J + gJ
        vel_h = cfg.momentum * vel_h + gh
        dJ_edge = cfg.lr * vel_J
        dh = cfg.lr * cfg.h_lr_scale * vel_h
        dJ = jnp.zeros((n, n), jnp.float32)
        dJ = dJ.at[e0, e1].add(dJ_edge)
        dJ = dJ.at[e1, e0].add(dJ_edge)
        Jm = (1.0 - cfg.weight_decay) * Jm + dJ
        hm = (1.0 - cfg.weight_decay) * hm + dh
        Jm = jnp.clip(Jm, WMIN, WMAX)
        hm = jnp.clip(hm, WMIN, WMAX)
        metrics = {
            "corr_err": jnp.abs(pos_c - neg_c).mean(),
            "mean_err": jnp.abs(pos_s - neg_s).mean(),
        }
        return Jm, hm, m_neg, noise_state, (vel_J, vel_h), metrics

    return step


def sample_visible_dist(machine: PBitMachine, Jm, hm,
                        visible_idx: np.ndarray, key: jax.Array,
                        chains: int = 256, sweeps: int = 200,
                        burn_in: int = 20) -> np.ndarray:
    """Free-run the programmed chip and histogram the visible marginal."""
    g = machine.graph
    chip = machine.program(quantize_codes(Jm), quantize_codes(hm))
    k1, k2 = jax.random.split(key)
    m0 = pbit.random_spins(k1, chains, g.n_nodes)
    noise_state, noise_fn = machine.noise_fn(k2, chains)
    betas = jnp.full((sweeps,), machine.beta, jnp.float32)
    _, _, traj = pbit.gibbs_sample(
        chip, jnp.asarray(g.color), m0, betas, noise_state, noise_fn,
        collect=True, backend=machine.backend)
    samples = np.asarray(traj[burn_in:]).reshape(-1, g.n_nodes)
    return energy_mod.empirical_visible_dist(samples, visible_idx)


@dataclasses.dataclass
class CDResult:
    Jm: np.ndarray
    hm: np.ndarray
    kl_history: list
    metric_history: list


def train_cd(
    machine: PBitMachine,
    visible_idx: np.ndarray,
    target_dist: np.ndarray,
    cfg: CDConfig,
    key: jax.Array,
    eval_every: int = 10,
    verbose: bool = False,
) -> CDResult:
    """Full in-situ CD training loop against a target visible distribution."""
    g = machine.graph
    n, nv = g.n_nodes, len(visible_idx)
    step = make_cd_step(machine, cfg, visible_idx)

    key, k1, k2, k3 = jax.random.split(key, 4)
    Jm = jnp.zeros((n, n), jnp.float32)
    hm = jnp.zeros((n,), jnp.float32)
    m = pbit.random_spins(k1, cfg.chains, n)
    noise_state, _ = machine.noise_fn(k2, cfg.chains)

    # enumerate visible configs for sampling data from the target dist
    codes = energy_mod.all_states(nv)  # (2^nv, nv) ±1, code order
    vel = (jnp.zeros((g.n_edges,), jnp.float32),
           jnp.zeros((n,), jnp.float32))
    kl_hist, met_hist = [], []
    for epoch in range(cfg.epochs):
        key, kd, ke = jax.random.split(key, 3)
        idx = jax.random.choice(
            kd, codes.shape[0], (cfg.chains,), p=jnp.asarray(target_dist))
        data_vis = jnp.asarray(codes)[idx]
        Jm, hm, m, noise_state, vel, metrics = step(Jm, hm, data_vis, m,
                                                    noise_state, vel)
        met_hist.append({k: float(v) for k, v in metrics.items()})
        if (epoch + 1) % eval_every == 0 or epoch == cfg.epochs - 1:
            emp = sample_visible_dist(machine, Jm, hm, visible_idx, ke)
            kl = energy_mod.kl_divergence(np.asarray(target_dist), emp)
            kl_hist.append((epoch + 1, kl))
            if verbose:
                print(f"epoch {epoch+1:4d}  KL={kl:.4f}  "
                      f"corr_err={met_hist[-1]['corr_err']:.4f}")
    return CDResult(np.asarray(Jm), np.asarray(hm), kl_hist, met_hist)
