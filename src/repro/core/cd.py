"""In-situ hardware-aware learning: contrastive divergence through the chip.

Paper Fig. 7a: the training loop alternates
  positive phase  — clamp the visible nodes to data, Gibbs-sample the hidden
                    nodes *on the (mismatched) chip*, measure <m_i m_j>+.
  negative phase  — release the clamp, free-run the chip k sweeps, measure
                    <m_i m_j>-.
  update          — J_ij += lr (<mimj>+ - <mimj>-) on the physical couplers,
                    h_i  += lr (<mi>+   - <mi>-),
then re-program the 8-bit weight DACs.  Because both phases are sampled
through the same analog non-idealities, the learned weights absorb the
mismatch — the paper's central claim (we verify it in
tests/test_cd.py::test_hardware_aware_beats_transfer).

Weights are kept as float "master" values (the host accumulator) and
quantized to signed 8-bit DAC codes on every (re)program, matching the
chip's digital weight storage.  The master couplings live on the *edge
list* — one float per physical coupler, exactly the chip's weight-DAC
count — so the CD update is O(E) and never touches an (n, n) matrix.

All sampling and programming goes through `repro.api.Session`:
`PBitMachine` is the convenience wrapper that owns the chip description
(graph + mismatch + noise/backend choices) and hands out compiled
sessions; the schedule handling and backend dispatch that used to live
here are gone (see docs/api.md).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import energy as energy_mod
from repro.runtime import fault_tolerance
from repro.core import pbit
from repro.core.chimera import ChimeraGraph
from repro.core.hardware import (
    EffectiveChip,
    HardwareConfig,
    Mismatch,
    SparseMismatch,
    quantize_codes,  # noqa: F401  (re-export: legacy import site)
    sample_mismatch,
    sample_mismatch_sparse,
)


@dataclasses.dataclass
class PBitMachine:
    """A (simulated) chip instance: graph + mismatch + programmable weights.

    With a dense `Mismatch` the machine programs the full analog model and
    attaches the Chimera-native slot view (a gather — bit-identical
    entries), so every backend runs on the same physics.  With a
    `SparseMismatch` (create(..., sparse=True)) nothing O(n²) is ever
    built: the machine only supports the sparse backends, which is the
    point — it instantiates at lattice sizes where the dense model cannot.

    The machine is sugar over `api.SamplerSpec`/`api.Session`:
    ``sampler_spec()`` builds the declarative spec, ``session()`` compiles
    (and caches) sessions per (schedule, chains).
    """

    graph: ChimeraGraph
    hw: HardwareConfig
    mismatch: Mismatch | SparseMismatch
    beta: float = 1.0
    noise: str = "philox"   # "philox" | "counter" | "lfsr"
    backend: str = "auto"   # auto | ref | pallas | fused | sparse | fused_sparse
    w_scale: float = 0.05  # weight-LSB -> coupling units (ext. resistor knob)
    mesh: object = None     # jax.sharding.Mesh -> multi-device sessions
    partition: object = None  # api.Partition; None -> rows over "data"
    sync: object = None     # api.Sync; None -> bit-exact barrier policy
    faults: object = None   # api.Faults; None -> healthy chip

    @staticmethod
    def create(graph: ChimeraGraph, key: jax.Array,
               hw: HardwareConfig | None = None, sparse: bool = False,
               **kw) -> "PBitMachine":
        hw = hw or HardwareConfig()
        if sparse:
            nbr_idx, _ = graph.neighbor_table()
            mism = sample_mismatch_sparse(key, graph.n_nodes,
                                          nbr_idx.shape[0], hw)
            # sparse-native chips have no dense W: the dense backends
            # cannot run them, so don't let "auto" resolve to one
            kw.setdefault("backend", "sparse")
        else:
            mism = sample_mismatch(key, graph.n_nodes, hw)
        return PBitMachine(graph=graph, hw=hw, mismatch=mism, **kw)

    @property
    def sparse_native(self) -> bool:
        """True when only the O(D·n) slot model exists (no dense W ever)."""
        return isinstance(self.mismatch, SparseMismatch)

    def to_sparse(self) -> "PBitMachine":
        """Sparse-native twin reproducing THIS chip instance exactly.

        The dense machine's mismatch is gathered into the O(D·n) slot
        layout (`SparseMismatch.from_dense` — bit-identical on-graph
        entries), so programming the same codes on both machines yields
        the same effective couplings and the same spin trajectories for
        the same noise stream.  This is the bridge from a dense
        chip-scale model to lattice-scale sharded sampling: characterize
        a chip with the full (n, n) analog model, then scale out on the
        slot layout without changing the physics by a single bit.
        """
        if self.sparse_native:
            return self
        nbr_idx, _, _, _ = self.neighbor_tables()
        backend = {"ref": "sparse", "pallas": "sparse",
                   "fused": "fused_sparse"}.get(self.backend, self.backend)
        return dataclasses.replace(
            self, mismatch=SparseMismatch.from_dense(self.mismatch,
                                                     jnp.asarray(nbr_idx)),
            backend=backend)

    def neighbor_tables(self):
        """(nbr_idx, nbr_mask, slot_ij, slot_ji), cached per machine."""
        nt = getattr(self, "_nbr_tables", None)
        if nt is None:
            nbr_idx, nbr_mask = self.graph.neighbor_table()
            slot_ij, slot_ji = self.graph.edge_slots(nbr_idx)
            nt = (nbr_idx, nbr_mask, slot_ij, slot_ji)
            self._nbr_tables = nt
        return nt

    # -- the api seam ----------------------------------------------------
    def sampler_spec(self, schedule: api.Schedule | None = None,
                     chains: int = 256, **kw) -> api.SamplerSpec:
        """The declarative `api.SamplerSpec` for this chip instance."""
        kw.setdefault("mesh", self.mesh)
        kw.setdefault("partition", self.partition)
        kw.setdefault("sync", self.sync)
        kw.setdefault("faults", self.faults)
        return api.SamplerSpec(
            graph=self.graph, hw=self.hw, mismatch=self.mismatch,
            noise=self.noise, backend=self.backend, schedule=schedule,
            chains=chains, beta=self.beta, w_scale=self.w_scale, **kw)

    def session(self, schedule: api.Schedule | None = None,
                chains: int = 256) -> api.Session:
        """Compiled `api.Session`, cached per (schedule, chains)."""
        cache = getattr(self, "_sessions", None)
        if cache is None:
            cache = {}
            self._sessions = cache
        key = (schedule, chains)
        ses = cache.get(key)
        if ses is None:
            ses = api.Session(self.sampler_spec(schedule, chains))
            cache[key] = ses
        return ses

    # -- programming (the spec-level api layer: needs no backend/noise
    # resolution, so it works even where a full Session would not compile)
    def program(self, J_codes: jax.Array, h_codes: jax.Array,
                enable: jax.Array | None = None) -> EffectiveChip:
        """Program dense (n, n) symmetric codes (chip-scale convenience)."""
        return api.program(self.sampler_spec(), J_codes, h_codes, enable,
                           tables=self.neighbor_tables())

    def program_edges(self, J_edge_codes: jax.Array, h_codes: jax.Array
                      ) -> EffectiveChip:
        """Program per-edge codes (E,) — the CD master-weight layout."""
        return api.program_edges(self.sampler_spec(), J_edge_codes, h_codes,
                                 tables=self.neighbor_tables())

    def program_master(self, Jm: jax.Array, hm: jax.Array) -> EffectiveChip:
        """Quantize float master weights — edge-list (E,) or dense (n, n) —
        to 8-bit DAC codes and program."""
        return api.program_master(self.sampler_spec(), Jm, hm,
                                  tables=self.neighbor_tables())

    def fleet_mismatch(self, key: jax.Array, n_chips: int):
        """Draw a stacked (K, ...) fleet of chip-instance mismatches.

        Every leaf gains a leading ``n_chips`` axis; the result feeds the
        fleet axis directly (`make_cd_fleet_step`,
        `api.Session.make_cd_fleet_step`), running K virtual chips of
        this machine's SKU through one compiled executable.  Draw k
        equals `sample_mismatch[_sparse](split(key)[k], ...)`, so a
        fleet member is bit-identical to a standalone machine built from
        the same subkey.
        """
        keys = jax.random.split(key, n_chips)
        if self.sparse_native:
            nbr_idx, _ = self.graph.neighbor_table()
            draws = [sample_mismatch_sparse(k, self.graph.n_nodes,
                                            nbr_idx.shape[0], self.hw)
                     for k in keys]
        else:
            draws = [sample_mismatch(k, self.graph.n_nodes, self.hw)
                     for k in keys]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *draws)

    def noise_fn(self, key: jax.Array, batch: int):
        """Legacy noise constructor: (state, step).  New code should use
        ``session().noise_state(key)`` — the Session owns the step fn."""
        if self.noise == "lfsr":
            init, step = pbit.make_lfsr_noise(self.graph, batch)
            return init(key), step
        if self.noise == "counter":
            init, step = pbit.make_counter_noise(batch, self.graph.n_nodes)
            return init(key), step
        return key, pbit.make_philox_noise(batch, self.graph.n_nodes)


@dataclasses.dataclass
class CDConfig:
    lr: float = 4.0            # in DAC-LSB units per unit correlation error
    cd_k: int = 10             # sweeps per negative phase
    pos_sweeps: int = 10       # sweeps with visibles clamped
    burn_in: int = 2
    chains: int = 256          # parallel Gibbs chains (chip reprogram batches)
    epochs: int = 60
    h_lr_scale: float = 1.0
    weight_decay: float = 0.0
    # beyond-paper options (EXPERIMENTS §Perf extensions):
    persistent: bool = False   # PCD: negative chains persist across epochs
                               # instead of restarting from the data clamp
    momentum: float = 0.0      # heavy-ball on the correlation gradient


def make_cd_step(machine: PBitMachine, cfg: CDConfig,
                 visible_idx: np.ndarray):
    """Build the jitted one-epoch CD update (shim over `Session.make_cd_step`).

    Returns step(Jm, hm, data_vis, m, noise_state, vel) ->
      (Jm, hm, m, noise_state, vel, metrics) where Jm is the (n_edges,)
    float master couplings (one per physical coupler — no (n, n) matrix
    anywhere in the update), hm the (n,) master biases, and data_vis
    (chains, n_visible) ±1 data samples for the positive phase.  The CD
    gradient is already an edge-list quantity (<m_i m_j>+ - <m_i m_j>-),
    so the weight update is a pure O(E) axpy.
    """
    return machine.session(chains=cfg.chains).make_cd_step(cfg, visible_idx)


def make_cd_fleet_step(machine: PBitMachine, cfg: CDConfig,
                       visible_idx: np.ndarray):
    """Build the K-replica CD step (shim over `Session.make_cd_fleet_step`).

    Trains K virtual chip instances — K mismatch draws of the machine's
    SKU, stacked by `PBitMachine.fleet_mismatch` — through ONE compiled
    executable, each with its own master weights, chains, and noise
    stream but a shared data batch:

        step(mismatches, Jm[K,E], hm[K,N], data_vis, m[K,B,N],
             noise_state[K,...], vel) -> same, stacked

    Zero retraces across epochs *and* across chips: the mismatch is a
    streamed operand, not a baked constant, so fleet-scale
    hardware-aware learning costs one compile.
    """
    return machine.session(chains=cfg.chains).make_cd_fleet_step(
        cfg, visible_idx)


def sample_visible_dist(machine: PBitMachine, Jm, hm,
                        visible_idx: np.ndarray, key: jax.Array,
                        chains: int = 256, sweeps: int = 200,
                        burn_in: int = 20) -> np.ndarray:
    """Free-run the programmed chip and histogram the visible marginal.

    Jm may be edge-list (E,) or dense (n, n) float master weights.  The
    histogram streams (`Session.visible_hist`): on the scan backends it
    folds into the sweep loop, on the fused backends it accumulates inside
    the kernel — the (sweeps, chains, N) trajectory never materializes.
    """
    session = machine.session(
        schedule=api.Constant(beta=machine.beta, n_sweeps=sweeps),
        chains=chains)
    chip = session.program_master(Jm, hm)
    k1, k2 = jax.random.split(key)
    m0 = session.random_spins(k1)
    noise_state = session.noise_state(k2)
    counts, _, _ = session.visible_hist(chip, m0, noise_state, visible_idx,
                                        burn_in)
    counts = np.asarray(counts, np.float64)
    return counts / max(counts.sum(), 1.0)


@dataclasses.dataclass
class CDResult:
    """Learned master weights.  ``J_edges`` is the native (E,) edge-list
    form; ``Jm`` reconstructs the symmetric dense matrix for small-n
    reporting and eval."""

    J_edges: np.ndarray
    hm: np.ndarray
    kl_history: list
    metric_history: list
    edges: np.ndarray
    n_nodes: int

    @property
    def Jm(self) -> np.ndarray:
        J = np.zeros((self.n_nodes, self.n_nodes), np.float32)
        J[self.edges[:, 0], self.edges[:, 1]] = self.J_edges
        J[self.edges[:, 1], self.edges[:, 0]] = self.J_edges
        return J


def train_cd(
    machine: PBitMachine,
    visible_idx: np.ndarray,
    target_dist: np.ndarray,
    cfg: CDConfig,
    key: jax.Array,
    eval_every: int = 10,
    verbose: bool = False,
) -> CDResult:
    """Full in-situ CD training loop against a target visible distribution."""
    g = machine.graph
    n, nv = g.n_nodes, len(visible_idx)
    session = machine.session(chains=cfg.chains)
    step = session.make_cd_step(cfg, visible_idx)

    key, k1, k2, k3 = jax.random.split(key, 4)
    Jm = jnp.zeros((g.n_edges,), jnp.float32)
    hm = jnp.zeros((n,), jnp.float32)
    m = session.random_spins(k1)
    noise_state = session.noise_state(k2)

    # enumerate visible configs for sampling data from the target dist
    codes = energy_mod.all_states(nv)  # (2^nv, nv) ±1, code order
    vel = (jnp.zeros((g.n_edges,), jnp.float32),
           jnp.zeros((n,), jnp.float32))
    kl_hist, met_hist = [], []
    for epoch in range(cfg.epochs):
        key, kd, ke = jax.random.split(key, 3)
        idx = jax.random.choice(
            kd, codes.shape[0], (cfg.chains,), p=jnp.asarray(target_dist))
        data_vis = jnp.asarray(codes)[idx]
        Jm, hm, m, noise_state, vel, metrics = step(Jm, hm, data_vis, m,
                                                    noise_state, vel)
        met_hist.append({k: float(v) for k, v in metrics.items()})
        if (epoch + 1) % eval_every == 0 or epoch == cfg.epochs - 1:
            emp = sample_visible_dist(machine, Jm, hm, visible_idx, ke)
            kl = energy_mod.kl_divergence(np.asarray(target_dist), emp)
            kl_hist.append((epoch + 1, kl))
            if verbose:
                print(f"epoch {epoch+1:4d}  KL={kl:.4f}  "
                      f"corr_err={met_hist[-1]['corr_err']:.4f}")
    return CDResult(np.asarray(Jm), np.asarray(hm), kl_hist, met_hist,
                    edges=np.asarray(g.edges), n_nodes=n)


# -- crash-safe training ---------------------------------------------------

@dataclasses.dataclass
class CDTrainState:
    """Everything CD training needs to resume bit-exactly after a crash:
    master weights, chain spins, the noise-generator state, optimizer
    velocity and the epoch counter.  Per-epoch randomness is *derived*
    (``fold_in(base_key, epoch)``), never threaded, so restoring this
    state replays the exact uninterrupted trajectory."""

    Jm: jax.Array
    hm: jax.Array
    m: jax.Array
    noise_state: jax.Array
    vel_J: jax.Array
    vel_h: jax.Array
    epoch: int = 0

    def tree(self, base_key) -> dict:
        """Checkpointable pytree (the epoch rides as the checkpoint step)."""
        return {"Jm": self.Jm, "hm": self.hm, "m": self.m,
                "noise_state": self.noise_state, "vel_J": self.vel_J,
                "vel_h": self.vel_h, "base_key": jnp.asarray(base_key)}

    @staticmethod
    def from_tree(tree: dict, epoch: int) -> "CDTrainState":
        return CDTrainState(
            Jm=jnp.asarray(tree["Jm"]), hm=jnp.asarray(tree["hm"]),
            m=jnp.asarray(tree["m"]),
            noise_state=jnp.asarray(tree["noise_state"]),
            vel_J=jnp.asarray(tree["vel_J"]),
            vel_h=jnp.asarray(tree["vel_h"]), epoch=epoch)


def _spec_fingerprint(machine: PBitMachine, cfg: CDConfig) -> dict:
    """What must match for a resumed run to continue the same trajectory."""
    return {"noise": machine.noise, "backend": machine.backend,
            "chains": int(cfg.chains), "n_nodes": int(machine.graph.n_nodes),
            "faults": repr(machine.faults)}


def train_cd_resilient(
    machine: PBitMachine,
    visible_idx: np.ndarray,
    target_dist: np.ndarray,
    cfg: CDConfig,
    key: jax.Array,
    *,
    ckpt_dir=None,
    save_every: int = 10,
    resume: bool = True,
    eval_every: int = 10,
    max_retries: int = 3,
    backoff_s: float = 0.05,
    watchdog=None,
    on_epoch_start=None,
    sleep=time.sleep,
    verbose: bool = False,
) -> CDResult:
    """`train_cd` hardened for long unattended runs on faulty virtual chips.

    Differences from the plain loop:
      * all per-epoch randomness is ``fold_in``-derived from ``key``, so a
        run resumed from a checkpoint is bit-identical to one that never
        crashed (tests/test_resilience.py kills a training subprocess
        mid-run and asserts equal master weights);
      * every ``save_every`` epochs the full `CDTrainState` is committed
        atomically via `repro.checkpoint` — with ``resume=True`` the loop
        picks up from the latest complete checkpoint in ``ckpt_dir`` after
        validating it came from the same spec (noise/backend/chains/faults);
      * each epoch runs under `retry_step` (TransientError -> exponential
        backoff) and feeds a `StragglerWatchdog` if one is passed;
      * the jitted step's NaN/Inf guard reports via the ``update_skipped``
        metric — skipped epochs leave the master weights untouched but
        still advance the noise stream, keeping resume determinism.

    ``on_epoch_start(epoch)`` is called inside the retried region — tests
    use it to raise TransientError or to kill the process at a chosen
    epoch.
    """
    g = machine.graph
    n, nv = g.n_nodes, len(visible_idx)
    session = machine.session(chains=cfg.chains)
    step = session.make_cd_step(cfg, visible_idx)

    base_key = jnp.asarray(key)
    k1, k2 = jax.random.split(jax.random.fold_in(key, 0))
    state = CDTrainState(
        Jm=jnp.zeros((g.n_edges,), jnp.float32),
        hm=jnp.zeros((n,), jnp.float32),
        m=session.random_spins(k1),
        noise_state=session.noise_state(k2),
        vel_J=jnp.zeros((g.n_edges,), jnp.float32),
        vel_h=jnp.zeros((n,), jnp.float32))
    kl_hist, met_hist = [], []

    ckpt_mod = None
    if ckpt_dir is not None:
        from repro.checkpoint import checkpoint as ckpt_mod
        if resume and ckpt_mod.latest_step(ckpt_dir) is not None:
            step_no, tree, extra = ckpt_mod.load(
                ckpt_dir, target=state.tree(base_key))
            fp, saved = _spec_fingerprint(machine, cfg), extra.get("spec", {})
            for k_, v in fp.items():
                if k_ in saved and saved[k_] != v:
                    raise ValueError(
                        f"checkpoint {ckpt_dir} was written by a different "
                        f"run: {k_}={saved[k_]!r} != {v!r}")
            if not np.array_equal(np.asarray(tree["base_key"]),
                                  np.asarray(base_key)):
                raise ValueError(
                    f"checkpoint {ckpt_dir} was written under a different "
                    "base key; resuming would fork the trajectory")
            state = CDTrainState.from_tree(tree, step_no)
            kl_hist = [tuple(x) for x in extra.get("kl_history", [])]
            met_hist = list(extra.get("metric_history", []))
            if verbose:
                print(f"resumed from epoch {step_no}")

    codes = energy_mod.all_states(nv)
    k_data, k_eval = jax.random.fold_in(key, 1), jax.random.fold_in(key, 2)

    def _save(epoch_done: int) -> None:
        ckpt_mod.save(ckpt_dir, epoch_done, state.tree(base_key),
                      extra={"kl_history": [list(x) for x in kl_hist],
                             "metric_history": met_hist,
                             "spec": _spec_fingerprint(machine, cfg)})

    for epoch in range(state.epoch, cfg.epochs):
        t0 = time.perf_counter()

        def one_epoch():
            if on_epoch_start is not None:
                on_epoch_start(epoch)
            idx = jax.random.choice(
                jax.random.fold_in(k_data, epoch), codes.shape[0],
                (cfg.chains,), p=jnp.asarray(target_dist))
            data_vis = jnp.asarray(codes)[idx]
            return step(state.Jm, state.hm, data_vis, state.m,
                        state.noise_state, (state.vel_J, state.vel_h))

        Jm, hm, m, noise_state, vel, metrics = fault_tolerance.retry_step(
            one_epoch, max_retries=max_retries, backoff_s=backoff_s,
            sleep=sleep)
        state = CDTrainState(Jm, hm, m, noise_state, vel[0], vel[1],
                             epoch + 1)
        met_hist.append({k_: float(v) for k_, v in metrics.items()})
        if met_hist[-1].get("update_skipped", 0.0) and verbose:
            print(f"epoch {epoch+1:4d}  non-finite gradient: update skipped")
        if watchdog is not None:
            watchdog.observe(epoch, time.perf_counter() - t0)
        if (epoch + 1) % eval_every == 0 or epoch == cfg.epochs - 1:
            emp = sample_visible_dist(machine, state.Jm, state.hm,
                                      visible_idx,
                                      jax.random.fold_in(k_eval, epoch))
            kl = energy_mod.kl_divergence(np.asarray(target_dist), emp)
            kl_hist.append((epoch + 1, kl))
            if verbose:
                print(f"epoch {epoch+1:4d}  KL={kl:.4f}")
        if ckpt_mod is not None and (
                (epoch + 1) % save_every == 0 or epoch == cfg.epochs - 1):
            _save(epoch + 1)
    return CDResult(np.asarray(state.Jm), np.asarray(state.hm), kl_hist,
                    met_hist, edges=np.asarray(g.edges), n_nodes=n)
