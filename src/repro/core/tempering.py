"""Parallel tempering (replica exchange) on the p-bit chip.

Beyond-paper optimization feature: the chip's V_temp knob gives one global
temperature; running R replicas at a beta ladder and Metropolis-swapping
neighbors every k sweeps dramatically improves ground-state hit rates on
frustrated instances vs single-schedule annealing (benchmarks: see
EXPERIMENTS §Perf extensions).  Maps to hardware as R chips (or R
time-multiplexed passes) with an SPI readout + swap controller — the swap
decision needs only the two replicas' energies.

All replicas advance in one batched chromatic sweep (the chains dimension).
The ladder is a first-class `api.Tempered` schedule compiled into an
`api.Session`; each swap round passes the slot-permuted (swap_every, R)
beta matrix to `Session.sample` explicitly, so with a fused backend each
round is a single resident-sweep kernel launch.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core.cd import PBitMachine, quantize_codes
from repro.core.energy import ising_energy


@dataclasses.dataclass
class PTConfig:
    n_replicas: int = 16
    beta_min: float = 0.05
    beta_max: float = 3.0
    n_sweeps: int = 1000
    swap_every: int = 10

    def to_schedule(self) -> api.Tempered:
        """The declarative per-replica ladder (one swap round per run)."""
        return api.Tempered.geometric(self.beta_min, self.beta_max,
                                      self.n_replicas,
                                      n_sweeps=self.swap_every)


def beta_ladder(cfg: PTConfig) -> jnp.ndarray:
    """Deprecated shim: materialize the ladder (use `api.Tempered`)."""
    return jnp.asarray(cfg.to_schedule().ladder, jnp.float32)


def parallel_tempering(
    machine: PBitMachine,
    J_codes: np.ndarray,
    h_codes: np.ndarray,
    cfg: PTConfig,
    key: jax.Array,
) -> dict:
    """Returns best energy/state + replica-exchange statistics."""
    g = machine.graph
    R = cfg.n_replicas
    session = machine.session(schedule=cfg.to_schedule(), chains=R)
    chip = session.program(quantize_codes(jnp.asarray(J_codes)),
                           quantize_codes(jnp.asarray(h_codes)))
    Jf = jnp.asarray(J_codes, jnp.float32)
    hf = jnp.asarray(h_codes, jnp.float32)

    k1, k2, k3 = jax.random.split(key, 3)
    m = session.random_spins(k1)
    noise_state = session.noise_state(k2)
    betas = jnp.asarray(session.spec.schedule.ladder, jnp.float32)

    n_rounds = cfg.n_sweeps // cfg.swap_every

    def round_body(carry, rkey):
        m, ns, order = carry                   # order: slot -> replica id
        slot_of = jnp.argsort(order)           # replica id -> slot
        bvec = betas[slot_of]                  # per-replica beta
        beta_rows = jnp.broadcast_to(bvec, (cfg.swap_every, R))
        m, ns, _ = session.sample(chip, m, ns, beta_rows)
        e = ising_energy(m, Jf, hf)                       # (R,)
        # Metropolis swap of adjacent *temperature slots* (even pairs one
        # round, odd pairs the next, chosen by key parity)
        rk1, rk2 = jax.random.split(rkey)
        start = jax.random.bernoulli(rk1, 0.5).astype(jnp.int32)
        rep_in_slot = order                                # slot -> replica
        e_slot = e[rep_in_slot]
        b_slot = betas
        i = jnp.arange(R - 1)
        active = (i % 2) == start
        # detailed balance: accept with prob min(1, exp((b_j-b_i)(E_i-E_j)))
        delta = (b_slot[i + 1] - b_slot[i]) * (e_slot[i] - e_slot[i + 1])
        accept = jnp.log(jax.random.uniform(rk2, (R - 1,))) < delta
        accept = accept & active
        # build permutation of slots
        new_rep = rep_in_slot
        swap_lo = jnp.where(accept, new_rep[i + 1], new_rep[i])
        swap_hi = jnp.where(accept, new_rep[i], new_rep[i + 1])
        new_rep = new_rep.at[i].set(jnp.where(active, swap_lo, new_rep[i]))
        new_rep = new_rep.at[i + 1].set(
            jnp.where(active, swap_hi, new_rep[i + 1]))
        return (m, ns, new_rep), (e.min(), accept.sum())

    order0 = jnp.arange(R)
    rkeys = jax.random.split(k3, n_rounds)
    (m, ns, order), (e_min_hist, n_swaps) = jax.lax.scan(
        round_body, (m, noise_state, order0), rkeys)
    e_fin = ising_energy(m, Jf, hf)
    best = int(jnp.argmin(e_fin))
    return {
        "best_energy": float(e_fin[best]),
        "best_state": np.asarray(m[best]),
        "e_min_per_round": np.asarray(e_min_hist),
        "swap_rate": float(jnp.sum(n_swaps)) / max(n_rounds * (R // 2), 1),
        "final_order": np.asarray(order),
    }
