"""Parallel tempering (replica exchange) on the p-bit chip.

Beyond-paper optimization feature: the chip's V_temp knob gives one global
temperature; running R replicas at a beta ladder and Metropolis-swapping
neighbors every k sweeps dramatically improves ground-state hit rates on
frustrated instances vs single-schedule annealing (benchmarks: see
EXPERIMENTS §Perf extensions).  Maps to hardware as R chips (or R
time-multiplexed passes) with an SPI readout + swap controller — the swap
decision needs only the two replicas' energies.

All replicas advance in one batched chromatic sweep (the chains dimension),
so the TPU cost over plain multi-chain annealing is just the energy
evaluation every `swap_every` sweeps.  Sweeps run through the shared
backend API in core/pbit.py (per-replica betas ride the (n_sweeps, R) beta
matrix): with backend="fused" each swap round is a single resident-sweep
kernel launch.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pbit
from repro.core.cd import PBitMachine, quantize_codes
from repro.core.energy import ising_energy


@dataclasses.dataclass
class PTConfig:
    n_replicas: int = 16
    beta_min: float = 0.05
    beta_max: float = 3.0
    n_sweeps: int = 1000
    swap_every: int = 10


def beta_ladder(cfg: PTConfig) -> jnp.ndarray:
    return cfg.beta_min * (cfg.beta_max / cfg.beta_min) ** (
        jnp.arange(cfg.n_replicas) / max(cfg.n_replicas - 1, 1))


def parallel_tempering(
    machine: PBitMachine,
    J_codes: np.ndarray,
    h_codes: np.ndarray,
    cfg: PTConfig,
    key: jax.Array,
) -> dict:
    """Returns best energy/state + replica-exchange statistics."""
    g = machine.graph
    chip = machine.program(quantize_codes(jnp.asarray(J_codes)),
                           quantize_codes(jnp.asarray(h_codes)))
    Jf = jnp.asarray(J_codes, jnp.float32)
    hf = jnp.asarray(h_codes, jnp.float32)
    color = jnp.asarray(g.color)
    R = cfg.n_replicas

    k1, k2, k3 = jax.random.split(key, 3)
    m = pbit.random_spins(k1, R, g.n_nodes)
    noise_state, noise_fn = machine.noise_fn(k2, R)
    betas = beta_ladder(cfg)

    n_rounds = cfg.n_sweeps // cfg.swap_every

    def round_body(carry, rkey):
        m, ns, order = carry                   # order: slot -> replica id
        slot_of = jnp.argsort(order)           # replica id -> slot
        bvec = betas[slot_of]                  # per-replica beta
        beta_rows = jnp.broadcast_to(bvec, (cfg.swap_every, R))
        m, ns, _ = pbit.gibbs_sample(
            chip, color, m, beta_rows, ns, noise_fn,
            backend=machine.backend)
        e = ising_energy(m, Jf, hf)                       # (R,)
        # Metropolis swap of adjacent *temperature slots* (even pairs one
        # round, odd pairs the next, chosen by key parity)
        rk1, rk2 = jax.random.split(rkey)
        start = jax.random.bernoulli(rk1, 0.5).astype(jnp.int32)
        rep_in_slot = order                                # slot -> replica
        e_slot = e[rep_in_slot]
        b_slot = betas
        i = jnp.arange(R - 1)
        active = (i % 2) == start
        # detailed balance: accept with prob min(1, exp((b_j-b_i)(E_i-E_j)))
        delta = (b_slot[i + 1] - b_slot[i]) * (e_slot[i] - e_slot[i + 1])
        accept = jnp.log(jax.random.uniform(rk2, (R - 1,))) < delta
        accept = accept & active
        # build permutation of slots
        new_rep = rep_in_slot
        swap_lo = jnp.where(accept, new_rep[i + 1], new_rep[i])
        swap_hi = jnp.where(accept, new_rep[i], new_rep[i + 1])
        new_rep = new_rep.at[i].set(jnp.where(active, swap_lo, new_rep[i]))
        new_rep = new_rep.at[i + 1].set(
            jnp.where(active, swap_hi, new_rep[i + 1]))
        return (m, ns, new_rep), (e.min(), accept.sum())

    order0 = jnp.arange(R)
    rkeys = jax.random.split(k3, n_rounds)
    (m, ns, order), (e_min_hist, n_swaps) = jax.lax.scan(
        round_body, (m, noise_state, order0), rkeys)
    e_fin = ising_energy(m, Jf, hf)
    best = int(jnp.argmin(e_fin))
    return {
        "best_energy": float(e_fin[best]),
        "best_state": np.asarray(m[best]),
        "e_min_per_round": np.asarray(e_min_hist),
        "swap_rate": float(jnp.sum(n_swaps)) / max(n_rounds * (R // 2), 1),
        "final_order": np.asarray(order),
    }
