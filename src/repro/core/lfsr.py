"""LFSR random number generation, faithful to the chip.

The chip drives each Chimera unit cell with a 32-bit LFSR (clocked from 64
decimated random clocks derived from two 200 MHz LFSRs).  Each 32-bit LFSR
exposes only 4 unique bytes per cycle; the four *vertical* nodes of a cell
consume the bytes in normal bit order while the four *horizontal* nodes
consume the bit-reversed bytes (the paper's area-saving trick; measured to
cause no performance degradation — we test that claim in
tests/test_lfsr.py::test_reversed_byte_correlation).

We implement a Galois LFSR over uint32 with the maximal-length polynomial
x^32 + x^22 + x^2 + x + 1 (mask 0x80200003).  All ops vectorize over an
arbitrary leading shape of independent LFSR states, so (chains, cells) runs
as one fused update on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

GALOIS_MASK_32 = np.uint32(0x80200003)  # x^32 + x^22 + x^2 + x + 1
_BYTE_REV = np.array(
    [int(f"{b:08b}"[::-1], 2) for b in range(256)], dtype=np.uint32
)


def seed_states(key: jax.Array, shape: tuple[int, ...]) -> jax.Array:
    """Nonzero uint32 LFSR states of the given shape."""
    bits = jax.random.bits(key, shape, dtype=jnp.uint32)
    return jnp.where(bits == 0, jnp.uint32(0xDEADBEEF), bits)


def lfsr_step(state: jax.Array) -> jax.Array:
    """One Galois LFSR clock. state: uint32[...]"""
    lsb = state & jnp.uint32(1)
    shifted = state >> jnp.uint32(1)
    return jnp.where(lsb == 1, shifted ^ GALOIS_MASK_32, shifted)


def lfsr_step_n(state: jax.Array, n: int) -> jax.Array:
    """Advance every state by ``n`` clocks (unrolled; n is small/static)."""
    for _ in range(n):
        state = lfsr_step(state)
    return state


def cell_bytes(state: jax.Array) -> jax.Array:
    """Extract the 4 bytes of each 32-bit state. uint32[...] -> uint32[..., 4]."""
    shifts = jnp.array([0, 8, 16, 24], dtype=jnp.uint32)
    return (state[..., None] >> shifts) & jnp.uint32(0xFF)


def reverse_bytes_bits(b: jax.Array) -> jax.Array:
    """Bit-reverse each byte (uint32 values in [0,256))."""
    table = jnp.asarray(_BYTE_REV)
    return table[b]


def byte_to_uniform(b: jax.Array) -> jax.Array:
    """Map a byte to a mid-tread uniform in (-1, 1), as the 8-bit RNG DAC does."""
    return (b.astype(jnp.float32) - 127.5) / 128.0


def cell_uniforms(state: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-cell uniforms for (vertical[..., 4], horizontal[..., 4]) nodes."""
    by = cell_bytes(state)
    return byte_to_uniform(by), byte_to_uniform(reverse_bytes_bits(by))


def next_uniforms(state: jax.Array, decimation: int = 8
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Advance states ``decimation`` clocks and emit fresh cell uniforms.

    Returns (new_state, vert_u[..., 4], horiz_u[..., 4]).  The chip refreshes
    one byte-worth of entropy per sample (decimated clocking); decimation=8
    reproduces that.
    """
    state = lfsr_step_n(state, decimation)
    v, h = cell_uniforms(state)
    return state, v, h


def lfsr_uniform_for_graph(
    state: jax.Array,
    vert_scatter: jax.Array,
    horiz_scatter: jax.Array,
    n_nodes: int,
    decimation: int = 8,
) -> tuple[jax.Array, jax.Array]:
    """Produce per-node uniforms for a Chimera graph.

    state: uint32[..., n_cells]; *_scatter: int32[n_cells, 4] node ids
    (vertical / horizontal nodes of each cell, compacted numbering).
    Returns (new_state, u[..., n_nodes]).
    """
    state, v, h = next_uniforms(state, decimation)
    batch = state.shape[:-1]
    u = jnp.zeros(batch + (n_nodes,), dtype=jnp.float32)
    u = u.at[..., vert_scatter.reshape(-1)].set(
        v.reshape(batch + (-1,)))
    u = u.at[..., horiz_scatter.reshape(-1)].set(
        h.reshape(batch + (-1,)))
    return state, u
