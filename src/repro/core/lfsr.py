"""LFSR random number generation, faithful to the chip.

The chip drives each Chimera unit cell with a 32-bit LFSR (clocked from 64
decimated random clocks derived from two 200 MHz LFSRs).  Each 32-bit LFSR
exposes only 4 unique bytes per cycle; the four *vertical* nodes of a cell
consume the bytes in normal bit order while the four *horizontal* nodes
consume the bit-reversed bytes (the paper's area-saving trick; measured to
cause no performance degradation — we test that claim in
tests/test_lfsr.py::test_reversed_byte_correlation).

We implement a Galois LFSR over uint32 with the maximal-length polynomial
x^32 + x^22 + x^2 + x + 1 (mask 0x80200003).  All ops vectorize over an
arbitrary leading shape of independent LFSR states, so (chains, cells) runs
as one fused update on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

GALOIS_MASK_32 = np.uint32(0x80200003)  # x^32 + x^22 + x^2 + x + 1
_BYTE_REV = np.array(
    [int(f"{b:08b}"[::-1], 2) for b in range(256)], dtype=np.uint32
)


def seed_states(key: jax.Array, shape: tuple[int, ...]) -> jax.Array:
    """Nonzero uint32 LFSR states of the given shape."""
    bits = jax.random.bits(key, shape, dtype=jnp.uint32)
    return jnp.where(bits == 0, jnp.uint32(0xDEADBEEF), bits)


def lfsr_step(state: jax.Array) -> jax.Array:
    """One Galois LFSR clock. state: uint32[...]"""
    lsb = state & jnp.uint32(1)
    shifted = state >> jnp.uint32(1)
    return jnp.where(lsb == 1, shifted ^ GALOIS_MASK_32, shifted)


def lfsr_step_n(state: jax.Array, n: int) -> jax.Array:
    """Advance every state by ``n`` clocks (unrolled; n is small/static)."""
    for _ in range(n):
        state = lfsr_step(state)
    return state


def cell_bytes(state: jax.Array) -> jax.Array:
    """Extract the 4 bytes of each 32-bit state. uint32[...] -> uint32[..., 4]."""
    shifts = jnp.array([0, 8, 16, 24], dtype=jnp.uint32)
    return (state[..., None] >> shifts) & jnp.uint32(0xFF)


def reverse_bytes_bits(b: jax.Array) -> jax.Array:
    """Bit-reverse each byte (uint32 values in [0,256))."""
    table = jnp.asarray(_BYTE_REV)
    return table[b]


def byte_to_uniform(b: jax.Array) -> jax.Array:
    """Map a byte to a mid-tread uniform in (-1, 1), as the 8-bit RNG DAC does."""
    return (b.astype(jnp.float32) - 127.5) / 128.0


def reverse_byte_bits_swar(b: jax.Array) -> jax.Array:
    """Bit-reverse each byte with shift/mask ops only (no table gather).

    Equivalent to ``reverse_bytes_bits`` but kernel-friendly: inside a Pallas
    TPU kernel a 256-entry table lookup is a gather, while this is three VPU
    shift/or rounds.  Used by the fused sweep engine's in-kernel LFSR.
    """
    b = ((b & jnp.uint32(0xF0)) >> jnp.uint32(4)) | \
        ((b & jnp.uint32(0x0F)) << jnp.uint32(4))
    b = ((b & jnp.uint32(0xCC)) >> jnp.uint32(2)) | \
        ((b & jnp.uint32(0x33)) << jnp.uint32(2))
    b = ((b & jnp.uint32(0xAA)) >> jnp.uint32(1)) | \
        ((b & jnp.uint32(0x55)) << jnp.uint32(1))
    return b


def cell_uniforms(state: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-cell uniforms for (vertical[..., 4], horizontal[..., 4]) nodes."""
    by = cell_bytes(state)
    return byte_to_uniform(by), byte_to_uniform(reverse_bytes_bits(by))


def flat_cell_uniforms(state: jax.Array) -> jax.Array:
    """Uniforms in the flat byte-major layout [v0..v3, h0..h3] x cells.

    state: uint32[..., C].  Returns float32[..., 8*C] where column
    ``k*C + cell`` is vertical byte k of ``cell`` and ``(4+k)*C + cell`` is
    the bit-reversed (horizontal) byte k.  Built from 2-D shift/mask ops only
    so the same code runs inside the fused Pallas kernel.
    """
    parts = []
    for k in range(4):
        b = (state >> jnp.uint32(8 * k)) & jnp.uint32(0xFF)
        parts.append(byte_to_uniform(b))
    for k in range(4):
        b = (state >> jnp.uint32(8 * k)) & jnp.uint32(0xFF)
        parts.append(byte_to_uniform(reverse_byte_bits_swar(b)))
    return jnp.concatenate(parts, axis=-1)


def node_gather_perm(vert_scatter, horiz_scatter, n_nodes: int) -> np.ndarray:
    """Inverse permutation: node id -> column of ``flat_cell_uniforms``.

    One precomputed gather replaces the two dynamic-update scatters the old
    ``lfsr_uniform_for_graph`` issued per noise step.
    """
    vert = np.asarray(vert_scatter)
    horiz = np.asarray(horiz_scatter)
    n_cells, k = vert.shape
    perm = np.zeros(n_nodes, dtype=np.int32)
    cells = np.arange(n_cells, dtype=np.int32)
    for kk in range(k):
        perm[vert[:, kk]] = kk * n_cells + cells
        perm[horiz[:, kk]] = (k + kk) * n_cells + cells
    return perm


def next_uniforms(state: jax.Array, decimation: int = 8
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Advance states ``decimation`` clocks and emit fresh cell uniforms.

    Returns (new_state, vert_u[..., 4], horiz_u[..., 4]).  The chip refreshes
    one byte-worth of entropy per sample (decimated clocking); decimation=8
    reproduces that.
    """
    state = lfsr_step_n(state, decimation)
    v, h = cell_uniforms(state)
    return state, v, h


def lfsr_uniform_for_graph(
    state: jax.Array,
    vert_scatter: jax.Array,
    horiz_scatter: jax.Array,
    n_nodes: int,
    decimation: int = 8,
    gather_perm: np.ndarray | jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Produce per-node uniforms for a Chimera graph.

    state: uint32[..., n_cells]; *_scatter: int32[n_cells, 4] node ids
    (vertical / horizontal nodes of each cell, compacted numbering).
    Returns (new_state, u[..., n_nodes]).

    One ``take`` with the precomputed inverse permutation replaces the old
    pair of ``.at[...].set`` scatters (each of which materialized a fresh
    (..., n_nodes) buffer per noise step).  Pass ``gather_perm`` (from
    ``node_gather_perm``) to skip rebuilding it per call.
    """
    state = lfsr_step_n(state, decimation)
    if gather_perm is None:
        # traceable fallback (scatter tables may be traced jax arrays);
        # precompute with node_gather_perm + pass gather_perm to skip it
        n_cells, k = vert_scatter.shape
        cols = jnp.arange(n_cells, dtype=jnp.int32)
        gather_perm = jnp.zeros((n_nodes,), jnp.int32)
        for kk in range(k):
            gather_perm = gather_perm.at[vert_scatter[:, kk]].set(
                kk * n_cells + cols)
            gather_perm = gather_perm.at[horiz_scatter[:, kk]].set(
                (k + kk) * n_cells + cols)
    flat = flat_cell_uniforms(state)
    u = jnp.take(flat, jnp.asarray(gather_perm), axis=-1)
    return state, u


# ---------------------------------------------------------------------------
# Counter-based (stateless) RNG — the fused kernel's "scale mode" noise
# ---------------------------------------------------------------------------
def mix32(x: jax.Array) -> jax.Array:
    """Avalanche finalizer (lowbias32 constants). uint32 -> uint32."""
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return x


def counter_bits(seed: jax.Array, ctr: jax.Array,
                 row: jax.Array, col: jax.Array) -> jax.Array:
    """Stateless hash of (seed, step counter, chain row, node col) -> uint32.

    Pure uint32 shift/mul/xor arithmetic: the identical expression runs on
    the host (reference path) and inside the fused Pallas kernel, so the two
    are bit-exact by construction.
    """
    x = mix32(jnp.uint32(seed) ^ (jnp.uint32(ctr) * jnp.uint32(0x9E3779B9)))
    x = mix32(x
              ^ (row.astype(jnp.uint32) * jnp.uint32(0x85EBCA77))
              ^ (col.astype(jnp.uint32) * jnp.uint32(0xC2B2AE3D)))
    return x


def counter_uniform(seed: jax.Array, ctr: jax.Array,
                    row: jax.Array, col: jax.Array) -> jax.Array:
    """Counter-mode uniform in (-1, 1), quantized like the 8-bit RNG DAC."""
    return byte_to_uniform(counter_bits(seed, ctr, row, col)
                           & jnp.uint32(0xFF))
