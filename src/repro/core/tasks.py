"""Paper workloads: logic-gate / full-adder Boltzmann targets + embeddings.

The chip learns *probability distributions* over visible spins: a gate is
represented by the uniform distribution over its valid truth-table rows
(invalid rows get probability 0).  Visible spins live on one side of one or
two Chimera cells (a 4:4 RBM per cell, per the paper), hiddens on the other.

Tasks are pure data; ``BoltzmannTask.train`` / ``.sample_dist`` are the
workload entry points, and they construct samplers exclusively through
`api.Session` (via core/cd.py's Session-routed training loop).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.chimera import ChimeraGraph


@dataclasses.dataclass(frozen=True)
class BoltzmannTask:
    name: str
    visible_idx: np.ndarray     # compacted node ids
    target_dist: np.ndarray     # (2^n_visible,) — code = sum_i (m_i>0)<<i

    @property
    def n_visible(self) -> int:
        return len(self.visible_idx)

    # -- Session-routed workload entry points ---------------------------
    def train(self, machine, cfg, key, **kw):
        """In-situ CD training of this task on ``machine`` (an
        `api.Session`-backed `PBitMachine`).  Returns a `cd.CDResult`."""
        from repro.core import cd
        return cd.train_cd(machine, self.visible_idx, self.target_dist,
                           cfg, key, **kw)

    def sample_dist(self, machine, Jm, hm, key, **kw) -> np.ndarray:
        """Empirical visible distribution of the programmed chip (streams
        through `Session.visible_hist`)."""
        from repro.core import cd
        return cd.sample_visible_dist(machine, Jm, hm, self.visible_idx,
                                      key, **kw)

    def kl_to_target(self, dist: np.ndarray) -> float:
        """KL(target || dist) — the paper's Fig 7/8 figure of merit."""
        from repro.core import energy
        return float(energy.kl_divergence(np.asarray(self.target_dist),
                                          np.asarray(dist)))


def _dist_from_rows(n_vis: int, rows: list[tuple[int, ...]]) -> np.ndarray:
    """Uniform distribution over the given ±1-coded truth-table rows."""
    d = np.zeros(2 ** n_vis)
    for row in rows:
        code = sum((1 << i) for i, v in enumerate(row) if v > 0)
        d[code] = 1.0
    return d / d.sum()


def and_gate_rows() -> list[tuple[int, int, int]]:
    rows = []
    for a in (-1, 1):
        for b in (-1, 1):
            c = 1 if (a > 0 and b > 0) else -1
            rows.append((a, b, c))
    return rows


def full_adder_rows() -> list[tuple[int, ...]]:
    rows = []
    for a in (0, 1):
        for b in (0, 1):
            for cin in (0, 1):
                s = a ^ b ^ cin
                cout = (a & b) | (cin & (a ^ b))
                rows.append(tuple(2 * v - 1 for v in (a, b, cin, s, cout)))
    return rows


def and_gate_task(graph: ChimeraGraph, cell: tuple[int, int] = (0, 0)
                  ) -> BoltzmannTask:
    """AND on 3 visible spins (A, B, A∧B) = vertical nodes 0..2 of one cell;
    the cell's 4 horizontal nodes are hidden (paper Fig. 7b)."""
    vis = graph.cell_nodes(*cell, side=0)[:3]
    return BoltzmannTask("and_gate", vis, _dist_from_rows(3, and_gate_rows()))


def full_adder_task(graph: ChimeraGraph,
                    cells: tuple[tuple[int, int], tuple[int, int]] = ((0, 0), (0, 1)),
                    ) -> BoltzmannTask:
    """Full adder (A, B, Cin, S, Cout): 5 visibles across two adjacent cells'
    vertical nodes; 8 hiddens = both cells' horizontal nodes (paper Fig. 8b).
    Horizontal inter-cell couplers connect the two cells' hidden layers."""
    v0 = graph.cell_nodes(*cells[0], side=0)
    v1 = graph.cell_nodes(*cells[1], side=0)
    vis = np.concatenate([v0[:3], v1[:2]])
    return BoltzmannTask(
        "full_adder", vis, _dist_from_rows(5, full_adder_rows()))


def full_adder_inference(graph: ChimeraGraph | None = None, *,
                         key=None, chains: int = 64,
                         **compile_kw) -> dict:
    """Full-adder truth-table inference through the PSL compiler.

    This is the *fixed* inference path for the chip's Fig-8b demo: the
    exact gate Hamiltonian (psl/gates.py) chain-embedded onto ``graph``
    (default: the smallest Chimera that fits, 2x2), inputs clamped per
    row, outputs read by clause-filtered chain-majority vote
    (psl/readout.py).  The learned-machine route (`full_adder_task` +
    CD + raw clamped sampling, examples/full_adder.py) recovers only
    ~3/8 rows; this one recovers 8/8 — the before/after is asserted in
    tests/test_system.py.

    Returns ``{"rows_correct", "rows", "broken_chain_fraction"}`` where
    ``rows`` maps (a, b, cin) -> (s, cout, ok).
    """
    import jax

    from repro import psl

    if graph is None:
        from repro.core.chimera import make_chimera
        graph = make_chimera(2, 2)
    key = jax.random.PRNGKey(0) if key is None else key
    cc = psl.compile_circuit(psl.full_adder_circuit(), graph,
                             chains=chains, **compile_kw)
    rows: dict[tuple[int, int, int], tuple[int, int, bool]] = {}
    correct, broken = 0, []
    for a, b, cin, s, cout in (
            tuple((v + 1) // 2 for v in row) for row in full_adder_rows()):
        key, sub = jax.random.split(key)
        r = cc.run_forward(sub, {"a": a, "b": b, "cin": cin})
        got_s, got_c = r.infer("s"), r.infer("cout")
        ok = (got_s == s and got_c == cout)
        correct += ok
        broken.append(r.broken_chain_fraction)
        rows[(a, b, cin)] = (got_s, got_c, ok)
    return {"rows_correct": correct, "rows": rows,
            "broken_chain_fraction": float(np.mean(broken))}


def xor_gate_task(graph: ChimeraGraph, cell: tuple[int, int] = (0, 0)
                  ) -> BoltzmannTask:
    """XOR needs hidden units (not linearly separable) — a good stress test."""
    vis = graph.cell_nodes(*cell, side=0)[:3]
    rows = []
    for a in (-1, 1):
        for b in (-1, 1):
            c = 1 if (a > 0) != (b > 0) else -1
            rows.append((a, b, c))
    return BoltzmannTask("xor_gate", vis, _dist_from_rows(3, rows))
