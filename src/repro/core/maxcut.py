"""Max-Cut on the chip (paper Fig. 9b).

Max-Cut maximizes cut(m) = sum_{(i,j) in E} (1 - m_i m_j)/2.  With the
energy convention E(m) = -1/2 sum J_ij m_i m_j, setting J_ij = -w_ij for
each problem edge makes minimizing E equivalent to maximizing the cut.
Problems must be subgraphs of the Chimera coupler set (the chip has no other
wires); `random_chimera_maxcut` samples chip-native instances.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro import api
from repro.core.annealing import AnnealConfig, anneal
from repro.core.cd import PBitMachine
from repro.core.chimera import ChimeraGraph


@dataclasses.dataclass(frozen=True)
class MaxCutProblem:
    edges: np.ndarray    # (E, 2) node ids (subset of chimera edges)
    weights: np.ndarray  # (E,) positive weights, float32

    def __post_init__(self):
        # float32 throughout: weights meet jnp arrays downstream, and a
        # float64 store would silently downcast there (x64 is disabled by
        # default).  Cut values stay exact — the paper's instances use
        # small integer weights, exactly representable in float32.
        object.__setattr__(self, "edges",
                           np.asarray(self.edges, np.int32))
        object.__setattr__(self, "weights",
                           np.asarray(self.weights, np.float32))

    @property
    def n_edges(self) -> int:
        return int(self.edges.shape[0])

    def cut_value(self, m: np.ndarray) -> float:
        mi = m[self.edges[:, 0]]
        mj = m[self.edges[:, 1]]
        return float(np.sum(self.weights * (1.0 - mi * mj) / 2.0))


def random_chimera_maxcut(graph: ChimeraGraph, key: jax.Array,
                          edge_prob: float = 0.7,
                          weighted: bool = False) -> MaxCutProblem:
    k1, k2 = jax.random.split(key)
    keep = np.asarray(
        jax.random.bernoulli(k1, edge_prob, (graph.n_edges,)))
    edges = graph.edges[keep]
    if weighted:
        w = np.asarray(jax.random.randint(k2, (edges.shape[0],), 1, 4))
    else:
        w = np.ones((edges.shape[0],))
    return MaxCutProblem(edges=edges, weights=w.astype(np.float32))


def maxcut_codes(problem: MaxCutProblem, n_nodes: int,
                 scale: float = 42.0) -> tuple[np.ndarray, np.ndarray]:
    """Problem -> 8-bit antiferromagnetic coupling codes."""
    J = np.zeros((n_nodes, n_nodes), np.float32)
    w = -problem.weights * scale / max(problem.weights.max(), 1.0)
    J[problem.edges[:, 0], problem.edges[:, 1]] = w
    J[problem.edges[:, 1], problem.edges[:, 0]] = w
    return np.clip(np.round(J), -128, 127), np.zeros((n_nodes,), np.float32)


def solve_maxcut(machine: PBitMachine, problem: MaxCutProblem,
                 cfg: AnnealConfig, key: jax.Array,
                 session: api.Session | None = None) -> dict:
    J, h = maxcut_codes(problem, machine.graph.n_nodes)
    # the sampler is an api.Session compiled once for the anneal schedule;
    # Max-Cut just programs antiferromagnetic codes onto it
    if session is None:
        session = machine.session(schedule=cfg.to_schedule(),
                                  chains=cfg.chains)
    out = anneal(machine, J, h, cfg, key, session=session)
    cut = problem.cut_value(out["best_state"])
    # greedy 1-opt polish (the chip reads out spins; polishing is host-side)
    m = out["best_state"].copy()
    improved = True
    while improved:
        improved = False
        gains = _flip_gains(problem, m)
        i = int(np.argmax(gains))
        if gains[i] > 0:
            m[i] = -m[i]
            improved = True
    out["cut"] = cut
    out["cut_polished"] = problem.cut_value(m)
    out["upper_bound"] = float(problem.weights.sum())
    return out


def _flip_gains(problem: MaxCutProblem, m: np.ndarray) -> np.ndarray:
    """Cut-value gain of flipping each node."""
    n = m.shape[0]
    g = np.zeros(n)
    mi = m[problem.edges[:, 0]]
    mj = m[problem.edges[:, 1]]
    contrib = problem.weights * mi * mj  # flip of either endpoint negates
    np.add.at(g, problem.edges[:, 0], contrib)
    np.add.at(g, problem.edges[:, 1], contrib)
    return g
