"""Core p-bit probabilistic computing library (the paper's contribution)."""
from repro.core.chimera import ChimeraGraph, make_chimera, make_chip_graph
from repro.core.hardware import (
    EffectiveChip,
    HardwareConfig,
    Mismatch,
    SparseMismatch,
    attach_sparse,
    ideal_chip,
    program_weights,
    program_weights_sparse,
    sample_mismatch,
    sample_mismatch_sparse,
)
from repro.core.cd import CDConfig, PBitMachine, train_cd
from repro.core.annealing import AnnealConfig, anneal, sk_instance
from repro.core.maxcut import random_chimera_maxcut, solve_maxcut

__all__ = [
    "ChimeraGraph", "make_chimera", "make_chip_graph",
    "EffectiveChip", "HardwareConfig", "Mismatch", "SparseMismatch",
    "attach_sparse", "ideal_chip",
    "program_weights", "program_weights_sparse",
    "sample_mismatch", "sample_mismatch_sparse",
    "CDConfig", "PBitMachine", "train_cd",
    "AnnealConfig", "anneal", "sk_instance",
    "random_chimera_maxcut", "solve_maxcut",
]
from repro.core.tempering import PTConfig, parallel_tempering  # noqa: E402

__all__ += ["PTConfig", "parallel_tempering"]
