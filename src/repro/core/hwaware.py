"""Generalized hardware-aware learning for arbitrary JAX models.

The paper's insight — put the hardware's quantization + analog mismatch *in
the training forward path* so learning absorbs it — generalizes beyond Ising
lattices.  This module provides a straight-through-estimator (STE) transform
that fake-quantizes selected weight matrices to signed 8-bit "DAC codes"
with per-output-channel gain mismatch (the same R-2R + multiplier model as
`core/hardware.py`, at tensor granularity), for use inside any `train_step`
(`--hardware-aware` in launch/train.py; available to all 10 assigned archs —
see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class HwAwareConfig:
    bits: int = 8
    sigma_gain: float = 0.03      # per-output-channel analog gain mismatch
    sigma_bit: float = 0.0        # optional per-bit DNL (0 = plain quant)
    min_ndim: int = 2             # only quantize matrices/tensors, not norms
    min_size: int = 4096          # skip tiny params (biases, scales)

    @staticmethod
    def from_chip(hw, bits: int = 8) -> "HwAwareConfig":
        """Derive QAT sigmas from a chip `HardwareConfig` so the STE
        forward models the same silicon an `api.SamplerSpec` samples:
        the Gilbert-multiplier gain spread becomes the per-channel gain
        mismatch and the R-2R branch spread the per-bit DNL."""
        return HwAwareConfig(bits=bits, sigma_gain=hw.sigma_edge_gain,
                             sigma_bit=hw.sigma_dac_bit)


def _fake_quant(w: jax.Array, bits: int) -> jax.Array:
    """Symmetric per-tensor fake quantization with STE."""
    qmax = 2.0 ** (bits - 1) - 1.0
    scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8) / qmax
    q = jnp.round(w / scale) * scale
    return w + jax.lax.stop_gradient(q - w)  # STE


def _channel_gain(path_hash: int, shape: tuple[int, ...],
                  sigma: float, key: jax.Array) -> jax.Array:
    """Frozen per-channel gain for one chip instance (derived from key+path)."""
    k = jax.random.fold_in(key, path_hash)
    g = 1.0 + sigma * jax.random.normal(k, (shape[-1],), dtype=jnp.float32)
    return g


def _should_quantize(path: str, w: Any, cfg: HwAwareConfig) -> bool:
    if not isinstance(w, jax.Array) and not hasattr(w, "shape"):
        return False
    if w.ndim < cfg.min_ndim or w.size < cfg.min_size:
        return False
    if "embed" in path:  # embeddings stay high precision (chip analogy: SPI)
        return False
    return jnp.issubdtype(w.dtype, jnp.floating)


def apply_hardware(params: Any, cfg: HwAwareConfig,
                   chip_key: jax.Array) -> Any:
    """Map params -> "as seen by the hardware" params (differentiable, STE).

    chip_key fixes the mismatch instance: the same key across all training
    steps models one physical chip, exactly like the paper's in-situ setup.
    """
    flat = jax.tree_util.tree_flatten_with_path(params)
    leaves, treedef = flat
    out = []
    for path, w in leaves:
        pstr = jax.tree_util.keystr(path)
        if _should_quantize(pstr, w, cfg):
            wq = _fake_quant(w.astype(jnp.float32), cfg.bits)
            gain = _channel_gain(hash(pstr) & 0x7FFFFFFF, w.shape,
                                 cfg.sigma_gain, chip_key)
            wq = (wq * gain).astype(w.dtype)
            out.append(wq)
        else:
            out.append(w)
    return jax.tree_util.tree_unflatten(treedef, out)
