"""Ising energies and exact Boltzmann references (for validation).

Convention (standard p-bit / Boltzmann machine):
    E(m) = -1/2 sum_ij J_ij m_i m_j - sum_i h_i m_i,   P(m) ∝ exp(-beta E(m))
with symmetric J, zero diagonal.  The textbook p-bit update (pbit.py with an
ideal chip) has this as its stationary distribution under chromatic Gibbs.
"""
from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import numpy as np


def ising_energy(m: jax.Array, J: jax.Array, h: jax.Array) -> jax.Array:
    """E for batched spins m: (..., N). J symmetric (N, N), h (N,)."""
    quad = -0.5 * jnp.einsum("...i,ij,...j->...", m, J, m)
    return quad - m @ h


def all_states(n: int) -> np.ndarray:
    """(2^n, n) array of all ±1 configurations (n <= 22)."""
    assert n <= 22, "exact enumeration capped at 22 spins"
    bits = ((np.arange(2**n)[:, None] >> np.arange(n)[None, :]) & 1)
    return (2.0 * bits - 1.0).astype(np.float32)


def exact_boltzmann(J: np.ndarray, h: np.ndarray, beta: float) -> np.ndarray:
    """Exact P(m) over all 2^N states."""
    s = all_states(J.shape[0])
    e = np.asarray(ising_energy(jnp.asarray(s), jnp.asarray(J),
                                jnp.asarray(h)))
    logp = -beta * e
    logp -= logp.max()
    p = np.exp(logp)
    return p / p.sum()


def exact_visible_marginal(
    J: np.ndarray, h: np.ndarray, beta: float, visible_idx: np.ndarray
) -> np.ndarray:
    """Exact marginal over visible spins, shape (2^len(visible),)."""
    p = exact_boltzmann(J, h, beta)
    s = all_states(J.shape[0])
    vis = s[:, visible_idx]
    codes = ((vis > 0).astype(np.int64) *
             (2 ** np.arange(len(visible_idx)))[None, :]).sum(axis=1)
    out = np.zeros(2 ** len(visible_idx))
    np.add.at(out, codes, p)
    return out


def empirical_visible_dist(
    samples: np.ndarray, visible_idx: np.ndarray, n_visible: int | None = None
) -> np.ndarray:
    """Histogram of visible configurations from (S, N) ±1 samples."""
    nv = len(visible_idx)
    vis = samples[:, visible_idx]
    codes = ((vis > 0).astype(np.int64) *
             (2 ** np.arange(nv))[None, :]).sum(axis=1)
    out = np.zeros(2 ** nv)
    np.add.at(out, codes, 1.0)
    return out / max(len(samples), 1)


def kl_divergence(p: np.ndarray, q: np.ndarray, eps: float = 1e-9) -> float:
    """KL(p || q) with epsilon smoothing of q."""
    q = (q + eps) / (q + eps).sum()
    mask = p > 0
    return float(np.sum(p[mask] * np.log(p[mask] / q[mask])))
