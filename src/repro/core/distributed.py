"""Pod-scale Chimera lattices: spatial sharding + halo exchange.

The paper's chip is a 7x8-cell tile.  This module scales the same physics to
wafer/pod-size lattices (10^6..10^8 p-bits) by tiling the Chimera *cell grid*
over the device mesh: grid rows -> mesh axis "data" (and "pod"), grid cols ->
mesh axis "model".  Each device owns a (tile_r, tile_c, 4)-shaped SoA block
of vertical+horizontal spins and the couplers incident to them; the only
communication per half-sweep is a 1-cell halo exchange of boundary spins via
``jax.lax.ppermute`` — O(boundary), exactly like the chip's inter-cell wires.

Structure-of-arrays layout (no dense J at scale):
  m_v, m_h           (R, C, 4)    vertical / horizontal spins per cell
  W_vh, W_hv         (R, C, 4, 4) in-cell K44, directional (mismatch!)
  Wv_dn, Wv_up       (R, C, 4)    vertical inter-cell coupler below cell
                                  (directional: into r+1 resp. into r)
  Wh_rt, Wh_lt       (R, C, 4)    horizontal coupler to the right of cell
  h_v, h_h           (R, C, 4)
plus per-node neuron mismatch (tanh gain/offset, rand gain, comparator).

Chromatic order: color(r, c, side) = (r + c + side) % 2 — a half-sweep for
color k updates the vertical nodes of parity-k cells and the horizontal
nodes of parity-(1-k) cells, all in parallel.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.hardware import HardwareConfig


@dataclasses.dataclass(frozen=True)
class LatticeSpec:
    cell_rows: int
    cell_cols: int
    k: int = 4
    beta: float = 1.0
    chains: int = 1   # Gibbs replicas per device tile: couplings are read
                      # from HBM once per half-sweep and serve all chains
                      # (arithmetic intensity x chains — §Perf pbit cell)

    @property
    def n_spins(self) -> int:
        return self.cell_rows * self.cell_cols * 2 * self.k


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LatticeState:
    m_v: jax.Array
    m_h: jax.Array

    def tree_flatten(self):
        return (self.m_v, self.m_h), None

    @classmethod
    def tree_unflatten(cls, aux, ch):
        return cls(*ch)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LatticeChip:
    """Effective (post-mismatch) lattice couplings + neuron params."""
    W_vh: jax.Array
    W_hv: jax.Array
    Wv_dn: jax.Array
    Wv_up: jax.Array
    Wh_rt: jax.Array
    Wh_lt: jax.Array
    h_v: jax.Array
    h_h: jax.Array
    gain_v: jax.Array
    gain_h: jax.Array
    off_v: jax.Array
    off_h: jax.Array

    def tree_flatten(self):
        f = dataclasses.fields(self)
        return tuple(getattr(self, x.name) for x in f), None

    @classmethod
    def tree_unflatten(cls, aux, ch):
        return cls(*ch)


def make_sk_lattice(spec: LatticeSpec, key: jax.Array,
                    hw: HardwareConfig | None = None,
                    dtype=jnp.float32) -> LatticeChip:
    """Random SK-style lattice instance with per-site mismatch baked in.

    Pure function of (spec, key) — under pjit each device materializes only
    its own shard (random bits are generated sharded).
    """
    hw = hw or HardwareConfig()
    R, C, k = spec.cell_rows, spec.cell_cols, spec.k
    ks = jax.random.split(key, 12)

    def g(i, shape, scale=1.0):
        return scale * jax.random.normal(ks[i], shape, dtype)

    W_cell = g(0, (R, C, k, k), 0.8)                      # shared edge DAC
    mis = lambda i, shape: 1.0 + hw.sigma_edge_gain * g(i, shape)
    Wv = g(1, (R, C, k), 0.8)
    Wh = g(2, (R, C, k), 0.8)
    row = jnp.arange(R)[:, None, None]
    col = jnp.arange(C)[None, :, None]
    # no couplers past the lattice edge
    Wv = Wv * (row < R - 1)
    Wh = Wh * (col < C - 1)
    return LatticeChip(
        W_vh=W_cell * mis(3, (R, C, k, k)),
        W_hv=jnp.swapaxes(W_cell, -1, -2) * mis(4, (R, C, k, k)),
        Wv_dn=Wv * (1.0 + hw.sigma_edge_gain * g(5, (R, C, k))),
        Wv_up=Wv * (1.0 + hw.sigma_edge_gain * g(6, (R, C, k))),
        Wh_rt=Wh * (1.0 + hw.sigma_edge_gain * g(7, (R, C, k))),
        Wh_lt=Wh * (1.0 + hw.sigma_edge_gain * g(8, (R, C, k))),
        h_v=jnp.zeros((R, C, k), dtype),
        h_h=jnp.zeros((R, C, k), dtype),
        gain_v=1.0 + hw.sigma_tanh_gain * g(9, (R, C, k)),
        gain_h=1.0 + hw.sigma_tanh_gain * g(10, (R, C, k)),
        off_v=hw.sigma_tanh_offset * 0.01 * g(11, (R, C, k)),
        off_h=jnp.zeros((R, C, k), dtype),
    )


# ---------------------------------------------------------------------------
# Halo exchange
# ---------------------------------------------------------------------------
def _shift_rows(x: jax.Array, direction: int, axis_name: str | None,
                n_shards: int) -> jax.Array:
    """Neighbor-row view of x along the cell-row dim (dim 0).

    direction=+1: returns x_up  s.t. x_up[r] = x[r-1] (row from above),
    direction=-1: returns x_dn  s.t. x_dn[r] = x[r+1].
    Edge rows receive zeros (open boundary).  Cross-device rows travel by
    ppermute along `axis_name` when the grid is sharded.
    """
    if direction == +1:
        local = jnp.concatenate([jnp.zeros_like(x[:1]), x[:-1]], axis=0)
        boundary = x[-1:]  # my last row is my down-neighbor's halo
        perm_src_dst = [(i, i + 1) for i in range(n_shards - 1)]
        recv_into_first = True
    else:
        local = jnp.concatenate([x[1:], jnp.zeros_like(x[:1])], axis=0)
        boundary = x[:1]
        perm_src_dst = [(i + 1, i) for i in range(n_shards - 1)]
        recv_into_first = False
    if axis_name is None or n_shards == 1:
        return local
    halo = jax.lax.ppermute(boundary, axis_name, perm_src_dst)
    if recv_into_first:
        return local.at[:1].set(halo)
    return local.at[-1:].set(halo)


def _shift_cols(x: jax.Array, direction: int, axis_name: str | None,
                n_shards: int) -> jax.Array:
    xt = jnp.swapaxes(x, 0, 1)
    out = _shift_rows(xt, direction, axis_name, n_shards)
    return jnp.swapaxes(out, 0, 1)


# ---------------------------------------------------------------------------
# Physics
# ---------------------------------------------------------------------------
def _neuron(I, gain, off, beta, u):
    """I, u: (B, R, C, k); gain/off broadcast over the chain dim."""
    return jnp.where(jnp.tanh(beta * gain * (I + off)) + u >= 0.0, 1.0, -1.0)


def lattice_half_sweep(
    state: LatticeState,
    chip: LatticeChip,
    color: int,
    beta: jax.Array,
    u_v: jax.Array,
    u_h: jax.Array,
    parity: jax.Array,          # (R, C) global (r+c) % 2 of each local cell
    row_axis: str | None, n_row: int,
    col_axis: str | None, n_col: int,
) -> LatticeState:
    # spins are (B, R, C, k): chain-batched; the halo helpers shift the
    # cell-row/col dims (now dims 1/2), so transpose through them
    m_v, m_h = state.m_v, state.m_h

    def rows(x, d):   # shift the cell-row dim (axis 1 of (B, R, C, k))
        return jnp.moveaxis(
            _shift_rows(jnp.moveaxis(x, 1, 0), d, row_axis, n_row), 0, 1)

    def cols(x, d):   # shift the cell-col dim (axis 2 of (B, R, C, k))
        return jnp.moveaxis(
            _shift_rows(jnp.moveaxis(x, 2, 0), d, col_axis, n_col), 0, 2)

    # -- vertical nodes of parity==color cells -------------------------
    mv_up = rows(m_v, +1)                            # spin of (r-1, c)
    wv_up = _shift_rows(chip.Wv_dn, +1, row_axis, n_row)  # its coupler
    I_v = (
        jnp.einsum("rcij,brcj->brci", chip.W_vh, m_h)
        + wv_up * mv_up
        + chip.Wv_up * rows(m_v, -1)
        + chip.h_v
    )
    new_v = _neuron(I_v, chip.gain_v, chip.off_v, beta, u_v)
    upd_v = (parity == color)[..., None]
    m_v = jnp.where(upd_v, new_v, m_v).astype(m_v.dtype)

    # -- horizontal nodes of parity==(1-color) cells --------------------
    mh_lt = cols(m_h, +1)
    wh_lt = _shift_cols(chip.Wh_rt, +1, col_axis, n_col)
    I_h = (
        jnp.einsum("rcij,brcj->brci", chip.W_hv, m_v)
        + wh_lt * mh_lt
        + chip.Wh_lt * cols(m_h, -1)
        + chip.h_h
    )
    new_h = _neuron(I_h, chip.gain_h, chip.off_h, beta, u_h)
    upd_h = (parity == (1 - color))[..., None]
    m_h = jnp.where(upd_h, new_h, m_h).astype(m_h.dtype)
    return LatticeState(m_v, m_h)


def lattice_energy(state: LatticeState, chip: LatticeChip,
                   row_axis: str | None, n_row: int,
                   col_axis: str | None, n_col: int) -> jax.Array:
    """Global Ising energy (symmetrized couplings), psum over the mesh."""
    W_sym = 0.5 * (chip.W_vh + jnp.swapaxes(chip.W_hv, -1, -2))
    e_cell = -jnp.einsum("brci,rcij,brcj->b", state.m_v, W_sym, state.m_h)
    wv = 0.5 * (chip.Wv_dn + chip.Wv_up)
    mv_dn = jnp.moveaxis(
        _shift_rows(jnp.moveaxis(state.m_v, 1, 0), -1, row_axis, n_row),
        0, 1)
    e_vert = -jnp.sum(wv * state.m_v * mv_dn, axis=(1, 2, 3))
    wh = 0.5 * (chip.Wh_rt + chip.Wh_lt)
    mh_rt = jnp.moveaxis(
        _shift_rows(jnp.moveaxis(state.m_h, 2, 0), -1, col_axis, n_col),
        0, 2)
    e_horiz = -jnp.sum(wh * state.m_h * mh_rt, axis=(1, 2, 3))
    e_bias = -jnp.sum(chip.h_v * state.m_v, axis=(1, 2, 3)) - \
        jnp.sum(chip.h_h * state.m_h, axis=(1, 2, 3))
    e = e_cell + e_vert + e_horiz + e_bias
    if row_axis is not None:
        e = jax.lax.psum(e, row_axis)
    if col_axis is not None:
        e = jax.lax.psum(e, col_axis)
    return e


def make_lattice_anneal(
    spec: LatticeSpec,
    mesh: Mesh | None,
    *,
    row_axes: tuple[str, ...] = ("data",),
    col_axes: tuple[str, ...] = ("model",),
    n_sweeps: int = 100,
    record_every: int = 10,
):
    """Build the (optionally shard_map-distributed) annealing step.

    Returns fn(chip_sharded, key, betas) -> (final_state, energies).
    With mesh=None runs single-device (used by unit tests).
    """
    R, C = spec.cell_rows, spec.cell_cols

    if mesh is not None:
        row_axis = row_axes[0] if len(row_axes) == 1 else row_axes
        col_axis = col_axes[0] if len(col_axes) == 1 else col_axes
        n_row = int(np.prod([mesh.shape[a] for a in row_axes]))
        n_col = int(np.prod([mesh.shape[a] for a in col_axes]))
    else:
        row_axis = col_axis = None
        n_row = n_col = 1
    tr, tc = R // n_row, C // n_col

    def local_run(chip: LatticeChip, key: jax.Array, betas: jax.Array):
        if row_axis is not None:
            ri = jax.lax.axis_index(row_axis)
            ci = jax.lax.axis_index(col_axis)
        else:
            ri = ci = 0
        key = jax.random.fold_in(key, ri * 65536 + ci)
        gr = ri * tr + jnp.arange(tr)[:, None]
        gc = ci * tc + jnp.arange(tc)[None, :]
        parity = (gr + gc) % 2

        k0, k1 = jax.random.split(key)
        B = spec.chains
        m_v = jnp.where(
            jax.random.bernoulli(k0, 0.5, (B, tr, tc, spec.k)), 1.0, -1.0)
        m_h = jnp.where(
            jax.random.bernoulli(k1, 0.5, (B, tr, tc, spec.k)), 1.0, -1.0)
        state = LatticeState(m_v.astype(jnp.float32),
                             m_h.astype(jnp.float32))

        def sweep(carry, inp):
            st, k = carry
            beta, rec = inp
            for color in (0, 1):
                k, ku = jax.random.split(k)
                us = jax.random.uniform(ku, (2, B, tr, tc, spec.k),
                                        minval=-1.0, maxval=1.0)
                st = lattice_half_sweep(
                    st, chip, color, beta, us[0], us[1], parity,
                    row_axis, n_row, col_axis, n_col)
            e = jnp.where(
                rec,
                lattice_energy(st, chip, row_axis, n_row, col_axis,
                               n_col).mean(),
                0.0)
            return (st, k), e

        rec = (jnp.arange(n_sweeps) % record_every) == record_every - 1
        (state, _), energies = jax.lax.scan(sweep, (state, key),
                                            (betas, rec))
        return state, energies

    if mesh is None:
        return jax.jit(local_run)

    chip_specs = LatticeChip(
        *[P(row_axes, col_axes) for _ in range(12)])
    out_specs = (LatticeState(P(row_axes, col_axes), P(row_axes, col_axes)),
                 P())
    from repro.launch.mesh import shard_map as shard_map_compat
    fn = shard_map_compat(
        local_run, mesh=mesh,
        in_specs=(chip_specs, P(), P()),
        out_specs=out_specs,
        check_vma=False,
    )
    return jax.jit(fn)


def lattice_input_sharding(mesh: Mesh, row_axes=("data",),
                           col_axes=("model",)):
    return NamedSharding(mesh, P(row_axes, col_axes))
