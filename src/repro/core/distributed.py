"""Mesh-sharded sparse lattice: row partitioning, halo exchange, engine.

The paper's chip tiles a 7x8 Chimera cell grid with only inter-cell wires
crossing tile boundaries — exactly the communication pattern a device mesh
wants.  This module is the sharded execution layer behind
``api.SamplerSpec(mesh=..., partition=api.Partition(...))``:

  * `plan_row_partition` cuts the cell grid into contiguous *row bands*
    (one per device along the partition's rows axis) and precomputes, in
    numpy at Session compile: the padded per-device node slices, the
    (D, N_loc) neighbor tables re-indexed into [local | halo_up | halo_dn],
    the boundary send lists (the O(√N) chain-coupler spins), the
    per-device edge lists for moment accumulation, and the LFSR cell
    bands for chip-faithful noise.
  * `ShardedEngine` compiles the plan plus the spec's `api.Sync` policy
    into `shard_map`-wrapped launch loops: at each exchange point a
    device ppermutes its boundary spins to its row neighbors
    (`kernels/shard_sweep.py`), regenerates its own noise columns from
    the *global* (chain, node) coordinates, and runs the slot-layout
    sweeps locally — no dense W, no global gather, ever.  Under the
    default barrier policy (exchange every half-sweep) spins are
    bit-exact vs the single-device scan backends for the same noise
    stream; relaxed policies (halo_every=k, PASS-style async double
    buffering, launch-resident fused kernels) are deterministic, seeded
    approximations measured against it (docs/sharding.md §Sync
    policies).  The Gibbs-chain axis shards the same way (CD's
    embarrassingly parallel dimension); the (E,) edge-list moments are
    psum-reduced once per phase.  Chips enter every engine entry point as
    *traced operands* (`_chip_parts` is pure jnp on static tables), so
    runtime weight streaming works through the sharded path unchanged:
    one compiled executable per (graph-shape, partition, sync) bucket
    serves every `api.Program` (`Session.sample_program`).

The old structure-of-arrays pod lattice (`LatticeSpec`/`make_sk_lattice`)
remains as the O(N) *instance generator* for SK-style lattices, but its
private update loop is gone: `lattice_to_chip` converts the SoA couplings
into the shared `EffectiveChip` slot layout and `make_lattice_anneal`
drives the same `api.Session` engine every other workload uses.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import lfsr as lfsr_mod
from repro.core.chimera import ChimeraGraph, make_chimera
from repro.core.hardware import EffectiveChip, HardwareConfig
from repro.kernels.ref import halo_exchange_segments, sparse_neuron_input
from repro.kernels.shard_sweep import (
    fused_shard_exchange_resident,
    fused_shard_sweeps,
    halo_exchange,
    halo_half_sweep,
)


# ---------------------------------------------------------------------------
# Partition plan (numpy, built once at Session compile)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RowPartition:
    """Static plan: Chimera cell rows -> n_shards contiguous row bands.

    All arrays are numpy; shard-varying tables carry a leading
    (n_shards,) dim and are fed to `shard_map` as sharded inputs (never
    baked into the traced closure, which would replicate them).
    Padding entries (bands own unequal node counts on masked grids) point
    at real in-bounds nodes and are masked out of updates/scatters.
    """

    n_shards: int
    n_loc: int                 # padded nodes per band
    halo: int                  # padded boundary spins per direction
    node_starts: np.ndarray    # (n_shards + 1,) global node range bounds
    part_ids: np.ndarray       # (n_shards, n_loc) global node id
    valid: np.ndarray          # (n_shards, n_loc) bool
    inv_ids: np.ndarray        # (N,) global node -> shard * n_loc + p
    nbr_idx: np.ndarray        # (n_shards, D, n_loc) ext-local indices
    send_up: np.ndarray        # (n_shards, halo) local idx -> device above
    send_dn: np.ndarray        # (n_shards, halo) local idx -> device below
    n_boundary: int            # true boundary spins over internal cuts
    upd_masks: np.ndarray      # (n_shards, 2, n_loc) color masks & valid
    e_loc: int                 # padded edges per band
    edge_e0: np.ndarray        # (n_shards, e_loc) ext-local endpoint 0
    edge_e1: np.ndarray        # (n_shards, e_loc) ext-local endpoint 1
    edge_inv: np.ndarray       # (E,) global edge -> shard * e_loc + q
    # LFSR cell bands (built only when the spec's noise is "lfsr")
    c_loc: int = 0
    cell_ids: np.ndarray | None = None   # (n_shards, c_loc) global cell
    cell_valid: np.ndarray | None = None
    cell_inv: np.ndarray | None = None   # (n_cells,) -> shard * c_loc + q
    lfsr_perm: np.ndarray | None = None  # (n_shards, n_loc) local flat col


# plan_row_partition memo: serving's shard-loss re-plan and every compile-
# cache miss used to redo the full numpy plan; a ChimeraGraph is a pure
# function of (rows, cols, k, masked_cells), so those four plus the shard
# count key the plan exactly.  Plans are frozen dataclasses of read-only
# tables — every consumer treats them as immutable, so sharing one
# instance across Sessions is safe.
_PLAN_CACHE: dict = {}
PLAN_CACHE_STATS = {"hits": 0, "misses": 0}


def plan_cache_stats() -> dict:
    """Copy of the `plan_row_partition` memo hit/miss counters."""
    return dict(PLAN_CACHE_STATS)


def clear_plan_cache() -> None:
    """Drop memoized plans and zero the counters (tests)."""
    _PLAN_CACHE.clear()
    PLAN_CACHE_STATS["hits"] = 0
    PLAN_CACHE_STATS["misses"] = 0


def plan_row_partition(graph: ChimeraGraph, n_shards: int,
                       with_lfsr: bool = False) -> RowPartition:
    """Cut the cell grid into contiguous row bands (see RowPartition).

    Memoized on (graph identity, n_shards, with_lfsr): a degraded-mesh
    re-plan (`surviving_mesh` shrinking n_shards back to a previously
    planned size) and repeat Session compiles hit the cache instead of
    re-running the numpy planner (`plan_cache_stats()` exposes the
    counters).
    """
    key = (graph.rows, graph.cols, graph.k, tuple(graph.masked_cells),
           int(n_shards), bool(with_lfsr))
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        PLAN_CACHE_STATS["hits"] += 1
        return plan
    plan = _plan_row_partition(graph, n_shards, with_lfsr)
    PLAN_CACHE_STATS["misses"] += 1
    _PLAN_CACHE[key] = plan
    return plan


def _plan_row_partition(graph: ChimeraGraph, n_shards: int,
                        with_lfsr: bool = False) -> RowPartition:
    if n_shards < 1 or n_shards > graph.rows:
        raise ValueError(
            f"cannot cut {graph.rows} cell rows into {n_shards} bands")
    base, rem = divmod(graph.rows, n_shards)
    counts = [base + (d < rem) for d in range(n_shards)]
    r_start = np.concatenate([[0], np.cumsum(counts)])       # (n_shards+1,)
    node_r = np.asarray(graph.node_r)
    node_side = np.asarray(graph.node_side)
    # nodes are numbered by (r, c, side, k): each band owns a contiguous
    # id range regardless of cell masking
    node_starts = np.searchsorted(node_r, r_start).astype(np.int64)
    n_loc = max(1, int(np.max(np.diff(node_starts))))
    N = graph.n_nodes
    owner = np.searchsorted(node_starts[1:], np.arange(N), side="right")

    # boundary send lists: vertical (side-0) nodes of each band's first /
    # last cell row — the only nodes chain couplers carry across a cut
    ids_all = np.arange(N)
    send_up_ids, send_dn_ids = [], []
    for d in range(n_shards):
        sel = slice(node_starts[d], node_starts[d + 1])
        ids = ids_all[sel]
        vert = node_side[sel] == 0
        send_up_ids.append(ids[vert & (node_r[sel] == r_start[d])])
        send_dn_ids.append(ids[vert & (node_r[sel] == r_start[d + 1] - 1)])
    H = max(1, max((len(x) for x in send_up_ids + send_dn_ids), default=1))
    n_boundary = sum(len(send_dn_ids[d]) for d in range(n_shards - 1)) \
        + sum(len(send_up_ids[d]) for d in range(1, n_shards))

    nbr_g, _ = graph.neighbor_table()
    D = nbr_g.shape[0]
    part_ids = np.zeros((n_shards, n_loc), np.int32)
    valid = np.zeros((n_shards, n_loc), bool)
    local_nbr = np.zeros((n_shards, D, n_loc), np.int32)
    send_up = np.zeros((n_shards, H), np.int32)
    send_dn = np.zeros((n_shards, H), np.int32)
    for d in range(n_shards):
        s, e = int(node_starts[d]), int(node_starts[d + 1])
        n_d = e - s
        part_ids[d] = min(s, N - 1)
        part_ids[d, :n_d] = np.arange(s, e)
        valid[d, :n_d] = True
        send_up[d, :len(send_up_ids[d])] = send_up_ids[d] - s
        send_dn[d, :len(send_dn_ids[d])] = send_dn_ids[d] - s
        g_nbr = nbr_g[:, s:e].astype(np.int64)       # (D, n_d) global ids
        own = owner[g_nbr]
        loc = (g_nbr - s).astype(np.int64)           # local by default
        if d > 0:
            up = own == d - 1
            pos = np.searchsorted(send_dn_ids[d - 1], g_nbr[up])
            if not np.array_equal(send_dn_ids[d - 1][pos], g_nbr[up]):
                raise AssertionError("cross-band neighbor not on boundary")
            loc[up] = n_loc + pos
        if d < n_shards - 1:
            dn = own == d + 1
            pos = np.searchsorted(send_up_ids[d + 1], g_nbr[dn])
            if not np.array_equal(send_up_ids[d + 1][pos], g_nbr[dn]):
                raise AssertionError("cross-band neighbor not on boundary")
            loc[dn] = n_loc + H + pos
        if np.any(np.abs(own - d) > 1):
            raise AssertionError("neighbor more than one row band away")
        local_nbr[d, :, :n_d] = loc
    inv_ids = (owner * n_loc
               + (np.arange(N) - node_starts[owner])).astype(np.int32)

    color = np.asarray(graph.color)[part_ids]
    upd_masks = np.stack([(color == c) & valid for c in (0, 1)], axis=1)

    # per-band edge lists (owner = endpoint-0's band; endpoint 1 is local
    # or in the halo of the band below)
    e0g, e1g = graph.edges[:, 0].astype(np.int64), \
        graph.edges[:, 1].astype(np.int64)
    e_own = owner[e0g]
    e_loc = max(1, int(np.bincount(e_own, minlength=n_shards).max()))
    edge_e0 = np.zeros((n_shards, e_loc), np.int32)
    edge_e1 = np.zeros((n_shards, e_loc), np.int32)
    edge_inv = np.zeros((graph.n_edges,), np.int32)
    for d in range(n_shards):
        s = int(node_starts[d])
        sel = np.nonzero(e_own == d)[0]
        edge_e0[d, :len(sel)] = e0g[sel] - s
        le1 = e1g[sel] - s
        far = owner[e1g[sel]] == d + 1
        if np.any(far):
            pos = np.searchsorted(send_up_ids[d + 1], e1g[sel][far])
            le1[far] = n_loc + H + pos
        edge_e1[d, :len(sel)] = le1
        edge_inv[sel] = d * e_loc + np.arange(len(sel))

    kw: dict[str, Any] = {}
    if with_lfsr:
        kw = _plan_lfsr_cells(graph, n_shards, r_start, part_ids, valid,
                              node_starts)
    return RowPartition(
        n_shards=n_shards, n_loc=n_loc, halo=H, node_starts=node_starts,
        part_ids=part_ids, valid=valid, inv_ids=inv_ids, nbr_idx=local_nbr,
        send_up=send_up, send_dn=send_dn, n_boundary=int(n_boundary),
        upd_masks=upd_masks, e_loc=e_loc, edge_e0=edge_e0, edge_e1=edge_e1,
        edge_inv=edge_inv, **kw)


def _plan_lfsr_cells(graph, n_shards, r_start, part_ids, valid, node_starts):
    """Band the per-cell LFSRs the same way (cells sort by (r, c), exactly
    the order core/pbit.make_lfsr_noise enumerates them)."""
    cells = sorted(
        {(int(r), int(c)) for r, c in zip(graph.node_r, graph.node_c)})
    n_cells = len(cells)
    vert = np.stack([graph.cell_nodes(r, c, side=0) for r, c in cells])
    horiz = np.stack([graph.cell_nodes(r, c, side=1) for r, c in cells])
    perm_g = lfsr_mod.node_gather_perm(vert, horiz, graph.n_nodes)
    cell_rows = np.array([r for r, _ in cells])
    cell_starts = np.searchsorted(cell_rows, r_start)
    c_loc = max(1, int(np.max(np.diff(cell_starts))))
    cell_ids = np.zeros((n_shards, c_loc), np.int32)
    cell_valid = np.zeros((n_shards, c_loc), bool)
    lfsr_perm = np.zeros(part_ids.shape, np.int32)
    for d in range(n_shards):
        s, e = int(cell_starts[d]), int(cell_starts[d + 1])
        cell_ids[d] = min(s, n_cells - 1)
        cell_ids[d, :e - s] = np.arange(s, e)
        cell_valid[d, :e - s] = True
        pg = perm_g[part_ids[d]]
        kk, cell = pg // n_cells, pg % n_cells
        lp = kk * c_loc + (cell - s)
        lfsr_perm[d] = np.where(valid[d], lp, 0)
    cell_own = np.searchsorted(cell_starts[1:], np.arange(n_cells),
                               side="right")
    cell_inv = (cell_own * c_loc
                + (np.arange(n_cells) - cell_starts[cell_own])).astype(
                    np.int32)
    return dict(c_loc=c_loc, cell_ids=cell_ids, cell_valid=cell_valid,
                cell_inv=cell_inv, lfsr_perm=lfsr_perm)


def halo_bytes_per_sweep(plan: RowPartition, chains: int,
                         refresh_for_moments: bool = False,
                         sync=None):
    """Total float32 bytes crossing internal band cuts per full sweep.

    Under the default barrier policy: two half-sweeps, each moving every
    internal boundary spin in both directions, for every chain; +1
    exchange per sweep when moments are accumulated (the post-sweep
    refresh for boundary-edge correlations).  An `api.Sync` policy scales
    the multiplier by its exchange schedule — ``halo_every=k`` divides it
    by ~k, a launch-resident policy (``sweeps_per_launch=S`` with
    launch-boundary-only exchange) by 2S (docs/sharding.md §Sync
    policies; the relaxed policies drop the moment refresh, so the result
    may be fractional).  O(boundary) = O(√N · n_shards) either way —
    compare 4·N² bytes to replicate a dense W.
    """
    if sync is None:
        from repro.api.spec import Sync
        sync = Sync()
    return sync.exchanges_per_sweep(refresh_for_moments) \
        * plan.n_boundary * chains * 4


def surviving_mesh(mesh: Mesh, dead_ids) -> Mesh | None:
    """Re-plan a 1-D row mesh onto the devices that outlived a shard loss.

    The serving degradation ladder (`repro.serve.degrade`) calls this when
    heartbeats or the fault harness declare devices dead: survivors keep
    the original axis name, so every `Partition(rows=axis)` in cached
    specs stays valid and `plan_row_partition` simply re-cuts the row
    bands over the smaller device count.  Returns ``None`` when fewer
    than two devices survive — the caller then drops ``mesh=`` entirely
    and falls back to the bit-exact single-device path rather than paying
    halo-exchange overhead on a one-device "mesh".
    """
    dead = {int(d) for d in dead_ids}
    survivors = [d for d in np.asarray(mesh.devices).reshape(-1)
                 if int(d.id) not in dead]
    if not survivors:
        raise RuntimeError(
            f"no devices survive: mesh {tuple(int(d.id) for d in np.asarray(mesh.devices).reshape(-1))} "
            f"all marked dead ({sorted(dead)})")
    if len(survivors) < 2:
        return None
    axis = mesh.axis_names[0]
    return Mesh(np.asarray(survivors), (axis,))


# ---------------------------------------------------------------------------
# The sharded engine (compiled into api.Session closures)
# ---------------------------------------------------------------------------
class ShardedEngine:
    """Plan + mesh + sync policy -> device-local sweep implementations.

    Built once at `api.Session` compile when the spec carries a mesh.
    The public impls (`sample` / `stats` / `visible_hist`) keep the exact
    array contracts of the single-device engine (global (B, N) spins,
    global noise state) — the Session's closures call them unchanged, so
    every workload (CD, annealing, tempering, Max-Cut) shards without
    modification.

    The `api.Sync` policy is compiled into a *launch loop*: the sweep
    schedule is cut into launches of ``sweeps_per_launch`` sweeps, the
    scan runs over launches, and the L sweeps inside a launch unroll with
    the policy's exchange points placed statically — no collective ever
    sits behind a traced conditional.  Halo buffers (and, in async mode,
    the in-flight double buffer) thread through the scan carry, so
    between exchange points every band samples against a *stale* halo —
    the deterministic, seeded emulation of the chip's clockless fabric.
    ``Sync()`` (barrier, halo_every=1) reproduces the single-device
    trajectory bit for bit; under a launch-resident counter-noise policy
    the whole launch runs inside the sweep-resident Pallas kernel
    (`kernels/shard_sweep.py::fused_shard_sweeps`, backend
    "fused_sparse").
    """

    def __init__(self, graph: ChimeraGraph, mesh: Mesh, partition,
                 noise: str, decimation: int, chains: int, *,
                 sync=None, backend: str = "sparse",
                 interpret: bool = True, faults=None):
        if sync is None:
            from repro.api.spec import Sync
            sync = Sync()
        self.graph = graph
        self.mesh = mesh
        self.noise = noise
        self.decimation = decimation
        self.chains = chains
        self.sync = sync
        self.interpret = interpret
        # discrete fault injection (api.Faults).  Stuck spins arrive as
        # clamp args from the Session; what the engine itself owns are
        # the per-half-sweep hooks, regenerated per shard from *global*
        # coordinates so the sharded trajectory reproduces the
        # single-device fault draw bit for bit under the barrier policy:
        # transient flips (salted counter hash of global (chain, node))
        # and stuck LFSR register bits (per-cell masks gathered into the
        # shard's cell band).
        self.faults = faults
        self._fused = backend == "fused_sparse"
        self.rows_axes = partition.rows_axes
        self.chain_axes = partition.chain_axes
        self.n_row = int(np.prod([mesh.shape[a] for a in self.rows_axes],
                                 dtype=np.int64)) if self.rows_axes else 1
        self.n_chain = int(np.prod([mesh.shape[a] for a in self.chain_axes],
                                   dtype=np.int64)) if self.chain_axes else 1
        if chains % self.n_chain:
            raise ValueError(f"chains={chains} not divisible by the "
                             f"chain-axis size {self.n_chain}")
        self.b_loc = chains // self.n_chain
        # fused-resident-exchange: with mid-launch exchange points the
        # KERNEL owns the halo refresh.  On a real TPU mesh (single named
        # rows axis, compiled mode) one RDMA launch runs the whole
        # schedule; everywhere else (interpret mode, CPU hosts, or
        # REPRO_HALO_EMULATE=1) the engine emulates the same launch
        # bit-exactly: half-sweep windows of the resident kernel with a
        # ppermute between windows, inside one jitted graph.
        self._fused_exchange = self._fused and not sync.kernel_fusible
        self._halo_rdma = bool(
            self._fused_exchange and not interpret
            and jax.default_backend() == "tpu"
            and len(self.rows_axes) == 1
            and not os.environ.get("REPRO_HALO_EMULATE"))
        self.plan = plan_row_partition(graph, self.n_row,
                                       with_lfsr=(noise == "lfsr"))
        p = self.plan
        self._row_name = (self.rows_axes[0] if len(self.rows_axes) == 1
                          else (tuple(self.rows_axes) or None))
        self._chain_name = (self.chain_axes[0] if len(self.chain_axes) == 1
                            else (tuple(self.chain_axes) or None))
        # P-spec dimension entries (None = replicated over that dim)
        self._r = tuple(self.rows_axes) if self.rows_axes else None
        self._c = tuple(self.chain_axes) if self.chain_axes else None
        self._part_ids = jnp.asarray(p.part_ids)
        self._inv_ids = jnp.asarray(p.inv_ids)
        self._edge_inv = jnp.asarray(p.edge_inv)
        self._dev = {
            "nbr": jnp.asarray(p.nbr_idx),
            "send_up": jnp.asarray(p.send_up),
            "send_dn": jnp.asarray(p.send_dn),
            "upd": jnp.asarray(p.upd_masks),
            "cols": jnp.asarray(p.part_ids.astype(np.uint32)),
            "edge_e0": jnp.asarray(p.edge_e0),
            "edge_e1": jnp.asarray(p.edge_e1),
        }
        if noise == "lfsr":
            self._dev["lfsr_perm"] = jnp.asarray(p.lfsr_perm)
            self._cell_ids = jnp.asarray(p.cell_ids)
            self._cell_inv = jnp.asarray(p.cell_inv)
            if faults is not None and faults.lfsr_stuck:
                n_cells = graph.n_nodes // 8
                s0 = np.zeros((n_cells,), np.uint32)
                s1 = np.zeros((n_cells,), np.uint32)
                for cell, m0, m1 in faults.lfsr_stuck:
                    s0[int(cell)] |= np.uint32(m0)
                    s1[int(cell)] |= np.uint32(m1)
                self._dev["lfsr_s0"] = jnp.asarray(s0[p.cell_ids])
                self._dev["lfsr_s1"] = jnp.asarray(s1[p.cell_ids])
        if self._fused:
            # per-edge slot row into the kernel's (D, N_ext) correlation
            # scratch: edge q of band b lives at c_slots[edge_slot[b, q],
            # edge_e0[b, q]] (endpoint 0 is always local)
            es = np.zeros((p.n_shards, p.e_loc), np.int32)
            for b in range(p.n_shards):
                hit = p.nbr_idx[b][:, p.edge_e0[b]] == p.edge_e1[b][None, :]
                es[b] = np.argmax(hit, axis=0)
            self._dev["edge_slot"] = jnp.asarray(es)

    # -- spec helpers ----------------------------------------------------
    def _dev_specs(self):
        specs = {
            "nbr": P(self._r, None, None),
            "send_up": P(self._r, None),
            "send_dn": P(self._r, None),
            "upd": P(self._r, None, None),
            "cols": P(self._r, None),
            "edge_e0": P(self._r, None),
            "edge_e1": P(self._r, None),
        }
        if self.noise == "lfsr":
            specs["lfsr_perm"] = P(self._r, None)
            if "lfsr_s0" in self._dev:
                specs["lfsr_s0"] = P(self._r, None)
                specs["lfsr_s1"] = P(self._r, None)
        if self._fused:
            specs["edge_slot"] = P(self._r, None)
        return specs

    def _chip_specs(self):
        return {"w": P(self._r, None, None),
                **{k: P(self._r, None)
                   for k in ("h", "gain", "off", "rg", "co")}}

    def _shard_map(self, fn, in_specs, out_specs):
        from repro.launch.mesh import shard_map as shard_map_compat
        return shard_map_compat(fn, mesh=self.mesh, in_specs=in_specs,
                                out_specs=out_specs, check_vma=False)

    # -- global <-> parts layout ----------------------------------------
    def _chip_parts(self, chip: EffectiveChip) -> dict:
        """Slice the chip into per-device (n_shards, ...) shard layouts.

        Pure jnp gathers on static index tables, so this runs *inside*
        the Session's jitted closures with the chip as a traced operand —
        which is what threads runtime weight streaming through the
        sharded engine for free: a `Program` programmed in-jit
        (`Session.sample_program`) flows through here into the
        shard_map'd sweep as sharded input, and a swapped program is a
        new operand value, never a recompile.
        """
        if chip.nbr_w is None or chip.nbr_idx is None:
            raise ValueError(
                "sharded execution needs a chip carrying the slot layout "
                "(program through the Session — e.g. Session.make_program "
                "+ sample_program — or hardware.attach_sparse)")
        ids = self._part_ids
        return {
            "w": jnp.moveaxis(chip.nbr_w[:, ids], 1, 0),
            "h": chip.h[ids],
            "gain": chip.tanh_gain[ids],
            "off": chip.tanh_offset[ids],
            "rg": chip.rand_gain[ids],
            "co": chip.comp_offset[ids],
        }

    def _m_parts(self, m: jax.Array) -> jax.Array:
        return jnp.moveaxis(jnp.take(m, self._part_ids, axis=1), 1, 0)

    def _m_global(self, parts: jax.Array) -> jax.Array:
        flat = jnp.moveaxis(parts, 0, 1).reshape(parts.shape[1], -1)
        return jnp.take(flat, self._inv_ids, axis=1)

    def _ns_parts(self, ns: jax.Array):
        if self.noise == "lfsr":
            return jnp.moveaxis(jnp.take(ns, self._cell_ids, axis=1), 1, 0)
        return ns  # counter: replicated uint32[2]

    def _ns_global(self, ns, parts):
        if self.noise == "lfsr":
            flat = jnp.moveaxis(parts, 0, 1).reshape(parts.shape[1], -1)
            return jnp.take(flat, self._cell_inv, axis=1)
        return parts

    def _ns_spec(self):
        return P(self._r, self._c, None) if self.noise == "lfsr" else P()

    # -- device-local pieces --------------------------------------------
    def _chain_offset(self):
        """Global id of this device's first chain (uint32)."""
        idx = jnp.uint32(0)
        for ax in self.chain_axes:
            idx = idx * jnp.uint32(self.mesh.shape[ax]) \
                + jax.lax.axis_index(ax).astype(jnp.uint32)
        return idx * jnp.uint32(self.b_loc)

    def _noise_step(self, dev):
        """Device-local step fn regenerating the *global* noise stream's
        columns for this shard — bit-exact vs core/pbit's host noise."""
        if self.noise == "counter":
            cols = dev["cols"][0][None, :]

            def step(st, chain0):
                rows = chain0 + jnp.arange(self.b_loc, dtype=jnp.uint32)
                u = lfsr_mod.counter_uniform(st[0], st[1], rows[:, None],
                                             cols)
                return st + jnp.array([0, 1], jnp.uint32), u
            return step

        perm = dev["lfsr_perm"][0]
        s0 = dev["lfsr_s0"][0] if "lfsr_s0" in dev else None
        s1 = dev["lfsr_s1"][0] if "lfsr_s1" in dev else None

        def step(st, chain0):
            st = lfsr_mod.lfsr_step_n(st, self.decimation)
            if s0 is not None:
                # stuck register bits (api.Faults.lfsr_stuck): forced
                # after every decimated clock, before the read — same
                # order as the Session's single-device wrapper
                st = (st & ~s0) | s1
            u = jnp.take(lfsr_mod.flat_cell_uniforms(st), perm, axis=-1)
            return st, u
        return step

    def _flip_step(self, dev):
        """Transient-flip draw for this shard: Bernoulli(flip_prob) per
        (chain, node) per half-sweep from a salted counter stream over
        global coordinates (None when the fault model has no flips)."""
        f = self.faults
        if f is None or f.flip_prob <= 0.0:
            return None
        from repro.api.faults import FLIP_SALT
        cols = dev["cols"][0][None, :]
        thresh = jnp.uint32(round(float(f.flip_prob) * 65536.0))
        salt = jnp.uint32((int(f.flip_seed) ^ FLIP_SALT) & 0xFFFFFFFF)

        def flip(st, chain0):
            rows = chain0 + jnp.arange(self.b_loc, dtype=jnp.uint32)
            bits = lfsr_mod.counter_bits(st[0] ^ salt, st[1],
                                         rows[:, None], cols)
            return ((bits >> jnp.uint32(16)) & jnp.uint32(0xFFFF)) < thresh
        return flip

    def _local_sweeps(self, clamped, collect, accumulate, hist_w):
        """The per-device launch loop.  Returns
        run(dev, chip, m, ns, betas, measured?, cm?, cv?) -> mode outputs
        — ``dev`` is the *sharded* plan-table argument shard_map hands
        each device (never a closure capture, which would replicate
        device 0's tables everywhere).

        The sync policy shapes the loop at trace time: every halo
        exchange sits at a statically-placed exchange point, and halos
        are reused (stale) from the carry in between — no collective ever
        hides behind a traced conditional.  Async mode double-buffers the
        exchange: the values consumed at an exchange point were sent at
        the previous one, so the ppermute overlaps the intervening
        interior compute.  Four loop shapes, picked at compile:

          * fused — launch-resident counter-noise policies with
            launch-boundary-only exchange run each launch as one
            `fused_shard_sweeps` Pallas call (sample and stats paths;
            collect/hist fall back to the segment scan).
          * fused-resident-exchange — fused backends whose policy has
            mid-launch exchange points: the kernel owns the halo
            refresh.  TPU meshes run one `fused_shard_exchange_resident`
            RDMA launch per schedule chunk; interpret/CPU hosts run the
            bit-exact emulation — the same launch split at the exchange
            points into `half_offset`/`n_half` windows of the resident
            kernel with a ppermute between windows, all inside one
            jitted graph (no host round-trip).  Replaces the segment
            scan whenever the fused kernel is active (see
            docs/kernels.md, "In-kernel halo exchange").
          * segment scan — exchanges uniformly spaced at full-sweep
            boundaries (``halo_every`` even or inf): outer scan over
            inter-exchange segments, inner scan over the uniform sweeps
            between them.  Keeps the compiled body one-sweep-sized —
            Python-unrolling S sweeps makes XLA's CPU pipeline blow up
            super-linearly in S.
          * unrolled launch — odd ``halo_every`` (exchange points inside
            a sweep, e.g. the k=1 barrier's two per sweep): scan over
            launches with the L sweeps unrolled statically.  L=1
            reproduces the pre-policy engine graph exactly.
        """
        n_loc = self.plan.n_loc
        sync = self.sync
        L = sync.sweeps_per_launch
        k = sync.halo_every
        ex_pts = sync.exchange_points()
        async_ = sync.mode == "async"
        k1_exact = sync.bit_exact
        use_fused = self._fused and not collect and hist_w is None
        fused_ex = use_fused and ex_pts != (0,)
        if use_fused or ex_pts == (0,):
            seg_sweeps = L                  # exchange at launch starts only
        elif isinstance(k, int) and k % 2 == 0 and (2 * L) % k == 0:
            seg_sweeps = k // 2             # uniform inter-exchange segments
        else:
            seg_sweeps = None               # unrolled launch body

        def run(dev, chip, m, ns, betas, measured=None, cm=None, cv=None,
                vis_idx=None, vis_w=None):
            send_up, send_dn = dev["send_up"][0], dev["send_dn"][0]
            nbr = dev["nbr"][0]

            def exchange(m):
                return halo_exchange(m, send_up, send_dn, self._row_name,
                                     self.n_row)

            nstep = self._noise_step(dev)
            fstep = self._flip_step(dev)
            w, h = chip["w"][0], chip["h"][0]
            gain, off = chip["gain"][0], chip["off"][0]
            rg, co = chip["rg"][0], chip["co"][0]
            chain0 = self._chain_offset()
            masks = [dev["upd"][0, c] for c in (0, 1)]
            if clamped:
                masks = [mk & ~cm for mk in masks]

            S_total = int(betas.shape[0])
            if S_total % L:
                raise ValueError(
                    f"this Session's sync policy fuses sweeps_per_launch="
                    f"{L} sweeps per launch, which must divide the "
                    f"schedule length (got {S_total} sweeps); pad the "
                    f"schedule or change the Sync policy")

            def swap(m, hu, hd, pend):
                """One exchange point: barrier consumes the fresh values;
                async consumes the in-flight buffer and refills it."""
                fresh = exchange(m)
                if async_:
                    return pend[0], pend[1], fresh
                return fresh[0], fresh[1], pend

            def sweep_stats(m, ru, rd, w_t, accs):
                """Per-sweep moment / histogram accumulation against the
                halo view (ru, rd) the policy defines."""
                accs = list(accs)
                if accumulate:
                    m_ext = jnp.concatenate([m, ru, rd], axis=1)
                    corr = m_ext[:, dev["edge_e0"][0]] \
                        * m_ext[:, dev["edge_e1"][0]]
                    if self.n_chain == 1:
                        # dense-identical accumulation order (any B)
                        accs[0] = accs[0] + w_t * jnp.mean(m, axis=0)
                        accs[1] = accs[1] + w_t * jnp.mean(corr, axis=0)
                    else:
                        # raw ±1 sums; psum + one division at the end —
                        # bit-exact vs dense for power-of-two chains
                        accs[0] = accs[0] + w_t * jnp.sum(m, axis=0)
                        accs[1] = accs[1] + w_t * jnp.sum(corr, axis=0)
                else:  # histogram
                    bits = (jnp.take(m, vis_idx, axis=1) > 0).astype(
                        jnp.int32)
                    code = jnp.sum(bits * vis_w[None, :], axis=1)
                    if self.n_row > 1:
                        code = jax.lax.psum(code, self._row_name)
                    accs[0] = accs[0].at[code].add(w_t)
                return accs

            def launch(carry, xs_t):
                """Fused kernel launch (boundary-only or kernel-resident
                exchange), or L statically-unrolled sweeps (the
                odd-``halo_every`` non-fused shapes, incl. k=1)."""
                m, ns, hu, hd = carry[0], carry[1], carry[2], carry[3]
                base = 4
                pend = ()
                if async_:
                    pend, base = (carry[4], carry[5]), 6
                accs = list(carry[base:])
                betas_t = xs_t[0]
                meas_t = xs_t[1] if len(xs_t) > 1 else None
                outs = []

                if use_fused and not fused_ex:
                    if clamped and cv is not None:
                        m = jnp.where(cm, cv, m)
                    hu, hd, pend = swap(m, hu, hd, pend)
                    kwc = {}
                    if clamped and cv is not None:
                        kwc = dict(clamp_mask=cm, clamp_values=cv)
                    res = fused_shard_sweeps(
                        m, hu, hd, nbr, w, h, gain, off, rg, co,
                        masks[0], masks[1], betas_t, ns, chain0,
                        dev["cols"][0][0],
                        measured=meas_t if accumulate else None,
                        interpret=self.interpret, **kwc)
                    m, ns = res[0], res[1]
                    if accumulate:
                        s_k = res[2]
                        c_k = res[3][dev["edge_slot"][0],
                                     dev["edge_e0"][0]]
                        if self.n_chain == 1:
                            b = jnp.float32(m.shape[0])
                            s_k, c_k = s_k / b, c_k / b
                        accs[0] = accs[0] + s_k
                        accs[1] = accs[1] + c_k
                elif fused_ex:
                    # fused-resident-exchange: the kernel owns the halo
                    # refresh.  k=1 barrier (bit_exact) keeps the host
                    # post-sweep stats refresh, so the kernel only
                    # sweeps; every other policy accumulates in-kernel.
                    if clamped and cv is not None:
                        m = jnp.where(cm, cv, m)
                    kwc = {}
                    if clamped and cv is not None:
                        kwc = dict(clamp_mask=cm, clamp_values=cv)
                    exact_stats = accumulate and k1_exact
                    kern_meas = meas_t \
                        if (accumulate and not exact_stats) else None
                    if self._halo_rdma and not exact_stats:
                        # one RDMA launch per chunk; halos refresh via
                        # remote async copies inside the kernel.  Async
                        # consumes the pend buffer at point 0 and the
                        # kernel's drained final exchange refills it.
                        hu_in, hd_in = pend if async_ else (hu, hd)
                        res = fused_shard_exchange_resident(
                            m, hu_in, hd_in, nbr, w, h, gain, off, rg,
                            co, masks[0], masks[1], betas_t, ns, chain0,
                            dev["cols"][0][0], send_up, send_dn,
                            measured=kern_meas, ex_pts=ex_pts,
                            mode=sync.mode, axis_name=self._row_name,
                            n_row=self.n_row, **kwc)
                        m, ns, hu, hd = res[0], res[1], res[2], res[3]
                        if async_:
                            pend = (hu, hd)
                        if kern_meas is not None:
                            s_k = res[4]
                            c_k = res[5][dev["edge_slot"][0],
                                         dev["edge_e0"][0]]
                            if self.n_chain == 1:
                                b = jnp.float32(m.shape[0])
                                s_k, c_k = s_k / b, c_k / b
                            accs[0] = accs[0] + s_k
                            accs[1] = accs[1] + c_k
                    else:
                        # bit-exact emulation: split the launch at the
                        # exchange points into half-sweep windows of the
                        # same resident kernel, ppermute between them —
                        # one jitted graph, no host round-trip
                        s_l = c_l = None
                        if kern_meas is not None:
                            s_l = jnp.zeros((n_loc,), jnp.float32)
                            c_l = jnp.zeros(
                                (dev["edge_e0"].shape[1],), jnp.float32)
                        for h0, h1 in halo_exchange_segments(
                                ex_pts, 2 * L):
                            hu, hd, pend = swap(m, hu, hd, pend)
                            res = fused_shard_sweeps(
                                m, hu, hd, nbr, w, h, gain, off, rg,
                                co, masks[0], masks[1], betas_t, ns,
                                chain0, dev["cols"][0][0],
                                measured=kern_meas,
                                interpret=self.interpret,
                                half_offset=h0, n_half=h1 - h0, **kwc)
                            m, ns = res[0], res[1]
                            if kern_meas is not None:
                                s_l = s_l + res[2]
                                c_l = c_l + res[3][dev["edge_slot"][0],
                                                   dev["edge_e0"][0]]
                            if exact_stats and h1 % 2 == 0:
                                # post-sweep refresh for boundary edges
                                # — part of the bit-exact contract
                                ru, rd = exchange(m)
                                accs = sweep_stats(
                                    m, ru, rd, meas_t[h1 // 2 - 1],
                                    accs)
                        if kern_meas is not None:
                            if self.n_chain == 1:
                                b = jnp.float32(m.shape[0])
                                s_l, c_l = s_l / b, c_l / b
                            accs[0] = accs[0] + s_l
                            accs[1] = accs[1] + c_l
                else:
                    for s in range(L):
                        beta_t = betas_t[s]
                        if clamped and cv is not None:
                            m = jnp.where(cm, cv, m)
                        for c in (0, 1):
                            if 2 * s + c in ex_pts:
                                hu, hd, pend = swap(m, hu, hd, pend)
                            ns0 = ns
                            ns, u = nstep(ns, chain0)
                            m = halo_half_sweep(m, hu, hd, nbr, w, h,
                                                gain, off, rg, co,
                                                masks[c], beta_t, u)
                            if fstep is not None:
                                m = jnp.where(
                                    masks[c] & fstep(ns0, chain0), -m, m)
                        if accumulate:
                            if k1_exact:
                                # post-sweep refresh for boundary edges —
                                # part of the bit-exact contract
                                ru, rd = exchange(m)
                            else:
                                # relaxed policies read the (stale) halo
                                # the sweep itself saw
                                ru, rd = hu, hd
                            accs = sweep_stats(m, ru, rd, meas_t[s], accs)
                        elif hist_w is not None:
                            accs = sweep_stats(m, hu, hd, meas_t[s], accs)
                        elif collect:
                            outs.append(m)

                new_carry = (m, ns, hu, hd) + (pend if async_ else ()) \
                    + tuple(accs)
                return new_carry, (jnp.stack(outs) if collect else None)

            def segment(carry, xs_t):
                """One inter-exchange segment: swap once, then an inner
                scan over the uniform exchange-free sweeps — keeps the
                compiled body one-sweep-sized instead of unrolling."""
                m, ns, hu, hd = carry[0], carry[1], carry[2], carry[3]
                base = 4
                pend = ()
                if async_:
                    pend, base = (carry[4], carry[5]), 6
                accs = tuple(carry[base:])
                betas_t = xs_t[0]
                meas_t = xs_t[1] if len(xs_t) > 1 else None
                if clamped and cv is not None:
                    m = jnp.where(cm, cv, m)   # boundary sent post-clamp
                hu, hd, pend = swap(m, hu, hd, pend)

                def sweep_body(c2, xs_s):
                    m, ns = c2[0], c2[1]
                    accs2 = tuple(c2[2:])
                    beta_t = xs_s[0]
                    if clamped and cv is not None:
                        m = jnp.where(cm, cv, m)
                    for c in (0, 1):
                        ns0 = ns
                        ns, u = nstep(ns, chain0)
                        m = halo_half_sweep(m, hu, hd, nbr, w, h, gain,
                                            off, rg, co, masks[c],
                                            beta_t, u)
                        if fstep is not None:
                            m = jnp.where(
                                masks[c] & fstep(ns0, chain0), -m, m)
                    out = None
                    if accumulate or hist_w is not None:
                        accs2 = tuple(sweep_stats(m, hu, hd, xs_s[1],
                                                  accs2))
                    elif collect:
                        out = m
                    return (m, ns) + accs2, out

                xs_s = (betas_t,) if meas_t is None else (betas_t, meas_t)
                inner, outs = jax.lax.scan(sweep_body, (m, ns) + accs,
                                           xs_s)
                new_carry = (inner[0], inner[1], hu, hd) \
                    + (pend if async_ else ()) + tuple(inner[2:])
                return new_carry, outs

            chunk = L if (use_fused or seg_sweeps is None) else seg_sweeps
            body = launch if (use_fused or seg_sweeps is None) else segment
            betas_l = betas.reshape((S_total // chunk, chunk)
                                    + betas.shape[1:])
            xs = (betas_l,)
            if measured is not None:
                xs = (betas_l, measured.reshape(S_total // chunk, chunk))
            zh = jnp.zeros((m.shape[0], self.plan.halo), m.dtype)
            init = (m, ns, zh, zh)
            if async_:
                # prime the in-flight buffer with the initial boundary —
                # post-clamp, exactly what the first barrier exchange
                # would send — so the first consumption matches barrier
                m_pr = m
                if clamped and cv is not None:
                    m_pr = jnp.where(cm, cv, m)
                init = init + exchange(m_pr)
            if accumulate:
                init = init + (
                    jnp.zeros((n_loc,), jnp.float32),
                    jnp.zeros((dev["edge_e0"].shape[1],), jnp.float32))
            elif hist_w is not None:
                init = init + (jnp.zeros((2 ** hist_w,), jnp.float32),)
            final, traj = jax.lax.scan(body, init, xs)
            if collect and traj is not None:
                traj = traj.reshape((S_total,) + traj.shape[2:])
            base = 6 if async_ else 4
            return (final[0], final[1]) + final[base:], traj

        return run

    # ------------------------------------------------------------------
    # public impls (called inside the Session's jitted closures)
    # ------------------------------------------------------------------
    def sample(self, chip, m, ns, betas, cm=None, cv=None, collect=False):
        clamped = cm is not None
        has_cv = cv is not None
        run = self._local_sweeps(clamped, collect, False, None)

        def local(dev, chipp, m_p, ns_p, betas, *rest):
            kw = {}
            if clamped:
                kw["cm"] = rest[0][0]
                if has_cv:
                    kw["cv"] = rest[1][0]
            ns_l = ns_p[0] if self.noise == "lfsr" else ns_p
            (m_o, ns_o, *_), traj = run(dev, chipp, m_p[0], ns_l, betas,
                                        **kw)
            outs = [m_o[None], self._ns_out(ns_o)]
            if collect:
                outs.append(traj[None])
            return tuple(outs)

        betas = jnp.asarray(betas, jnp.float32)
        beta_spec = P() if betas.ndim == 1 else P(None, self._c)
        in_specs = [self._dev_specs(), self._chip_specs(),
                    P(self._r, self._c, None), self._ns_spec(), beta_spec]
        args = [self._dev, self._chip_parts(chip), self._m_parts(m),
                self._ns_parts(ns), betas]
        if clamped:
            in_specs.append(P(self._r, None))
            args.append(self._part_cols(cm))
            if has_cv:
                in_specs.append(P(self._r, self._c, None))
                args.append(self._m_parts(cv))
        out_specs = [P(self._r, self._c, None), self._ns_spec()]
        if collect:
            out_specs.append(P(self._r, None, self._c, None))
        out = self._shard_map(local, tuple(in_specs), tuple(out_specs))(
            *args)
        m_o = self._m_global(out[0])
        ns_o = self._ns_global(ns, out[1])
        traj = None
        if collect:
            t = jnp.moveaxis(out[2], 0, 2)          # (S, B, n_row, n_loc)
            t = t.reshape(t.shape[0], t.shape[1], -1)
            traj = jnp.take(t, self._inv_ids, axis=2)
        return m_o, ns_o, traj

    def stats(self, chip, m, ns, beta, n_sweeps, burn_in, cm=None, cv=None):
        clamped = cm is not None
        has_cv = cv is not None
        run = self._local_sweeps(clamped, False, True, None)
        betas = jnp.full((n_sweeps,), beta, jnp.float32)
        measured = (jnp.arange(n_sweeps) >= burn_in).astype(jnp.float32)
        denom = jnp.maximum(n_sweeps - burn_in, 1).astype(jnp.float32)

        def local(dev, chipp, m_p, ns_p, betas, measured, *rest):
            kw = {}
            if clamped:
                kw["cm"] = rest[0][0]
                if has_cv:
                    kw["cv"] = rest[1][0]
            ns_l = ns_p[0] if self.noise == "lfsr" else ns_p
            (m_o, ns_o, s_acc, c_acc), _ = run(dev, chipp, m_p[0], ns_l,
                                               betas, measured, **kw)
            if self.n_chain > 1:
                s_acc = jax.lax.psum(s_acc, self._chain_name)
                c_acc = jax.lax.psum(c_acc, self._chain_name)
            return m_o[None], self._ns_out(ns_o), s_acc[None], c_acc[None]

        in_specs = [self._dev_specs(), self._chip_specs(),
                    P(self._r, self._c, None), self._ns_spec(), P(), P()]
        args = [self._dev, self._chip_parts(chip), self._m_parts(m),
                self._ns_parts(ns), betas, measured]
        if clamped:
            in_specs.append(P(self._r, None))
            args.append(self._part_cols(cm))
            if has_cv:
                in_specs.append(P(self._r, self._c, None))
                args.append(self._m_parts(cv))
        out_specs = (P(self._r, self._c, None), self._ns_spec(),
                     P(self._r, None), P(self._r, None))
        m_o, ns_o, s_p, c_p = self._shard_map(
            local, tuple(in_specs), out_specs)(*args)
        scale = denom if self.n_chain == 1 else denom * self.chains
        s = jnp.take(s_p.reshape(-1), self._inv_ids) / scale
        c = jnp.take(c_p.reshape(-1), self._edge_inv) / scale
        return s, c, self._m_global(m_o), self._ns_global(ns, ns_o)

    def visible_hist(self, chip, m, ns, betas, burn_in, visible_idx,
                     cm=None, cv=None):
        clamped = cm is not None
        has_cv = cv is not None
        visible_idx = np.asarray(visible_idx)
        nv = int(visible_idx.shape[0])
        p = self.plan
        vi = np.zeros((p.n_shards, nv), np.int32)
        vw = np.zeros((p.n_shards, nv), np.int32)
        owner = np.searchsorted(p.node_starts[1:], visible_idx,
                                side="right")
        for k, (v, d) in enumerate(zip(visible_idx, owner)):
            vi[d, k] = v - p.node_starts[d]
            vw[d, k] = 2 ** k
        vi_j, vw_j = jnp.asarray(vi), jnp.asarray(vw)
        run = self._local_sweeps(clamped, False, False, nv)
        betas = jnp.asarray(betas, jnp.float32)
        n_sweeps = betas.shape[0]
        measured = (jnp.arange(n_sweeps) >= burn_in).astype(jnp.float32)

        def local(dev, chipp, m_p, ns_p, betas, measured, vi_p, vw_p,
                  *rest):
            kw = {}
            if clamped:
                kw["cm"] = rest[0][0]
                if has_cv:
                    kw["cv"] = rest[1][0]
            ns_l = ns_p[0] if self.noise == "lfsr" else ns_p
            (m_o, ns_o, hist), _ = run(dev, chipp, m_p[0], ns_l, betas,
                                       measured, vis_idx=vi_p[0],
                                       vis_w=vw_p[0], **kw)
            if self.n_chain > 1:
                hist = jax.lax.psum(hist, self._chain_name)
            return m_o[None], self._ns_out(ns_o), hist

        beta_spec = P() if betas.ndim == 1 else P(None, self._c)
        in_specs = [self._dev_specs(), self._chip_specs(),
                    P(self._r, self._c, None), self._ns_spec(), beta_spec,
                    P(), P(self._r, None), P(self._r, None)]
        args = [self._dev, self._chip_parts(chip), self._m_parts(m),
                self._ns_parts(ns), betas, measured, vi_j, vw_j]
        if clamped:
            in_specs.append(P(self._r, None))
            args.append(self._part_cols(cm))
            if has_cv:
                in_specs.append(P(self._r, self._c, None))
                args.append(self._m_parts(cv))
        out_specs = (P(self._r, self._c, None), self._ns_spec(), P())
        m_o, ns_o, hist = self._shard_map(
            local, tuple(in_specs), out_specs)(*args)
        return hist, self._m_global(m_o), self._ns_global(ns, ns_o)

    # -- small helpers ---------------------------------------------------
    def _part_cols(self, x):
        """(N,) node vector -> (n_shards, n_loc)."""
        return jnp.take(x, self._part_ids, axis=0)

    def _ns_out(self, ns_local):
        return ns_local[None] if self.noise == "lfsr" else ns_local


# ---------------------------------------------------------------------------
# Pod-scale SK lattices (SoA instance generator + Session-backed anneal)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LatticeSpec:
    cell_rows: int
    cell_cols: int
    k: int = 4
    beta: float = 1.0
    chains: int = 1   # Gibbs replicas per device tile: couplings are read
                      # from HBM once per half-sweep and serve all chains
                      # (arithmetic intensity x chains — §Perf pbit cell)

    @property
    def n_spins(self) -> int:
        return self.cell_rows * self.cell_cols * 2 * self.k


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LatticeChip:
    """SK-lattice couplings + neuron params, structure-of-arrays (O(N)).

    This is the *instance description*; `lattice_to_chip` converts it
    into the shared `EffectiveChip` slot layout the backends sample."""
    W_vh: jax.Array
    W_hv: jax.Array
    Wv_dn: jax.Array
    Wv_up: jax.Array
    Wh_rt: jax.Array
    Wh_lt: jax.Array
    h_v: jax.Array
    h_h: jax.Array
    gain_v: jax.Array
    gain_h: jax.Array
    off_v: jax.Array
    off_h: jax.Array

    def tree_flatten(self):
        f = dataclasses.fields(self)
        return tuple(getattr(self, x.name) for x in f), None

    @classmethod
    def tree_unflatten(cls, aux, ch):
        return cls(*ch)


def make_sk_lattice(spec: LatticeSpec, key: jax.Array,
                    hw: HardwareConfig | None = None,
                    dtype=jnp.float32) -> LatticeChip:
    """Random SK-style lattice instance with per-site mismatch baked in.

    Pure function of (spec, key) — under pjit each device materializes only
    its own shard (random bits are generated sharded).
    """
    hw = hw or HardwareConfig()
    R, C, k = spec.cell_rows, spec.cell_cols, spec.k
    ks = jax.random.split(key, 12)

    def g(i, shape, scale=1.0):
        return scale * jax.random.normal(ks[i], shape, dtype)

    W_cell = g(0, (R, C, k, k), 0.8)                      # shared edge DAC
    mis = lambda i, shape: 1.0 + hw.sigma_edge_gain * g(i, shape)
    Wv = g(1, (R, C, k), 0.8)
    Wh = g(2, (R, C, k), 0.8)
    row = jnp.arange(R)[:, None, None]
    col = jnp.arange(C)[None, :, None]
    # no couplers past the lattice edge
    Wv = Wv * (row < R - 1)
    Wh = Wh * (col < C - 1)
    return LatticeChip(
        W_vh=W_cell * mis(3, (R, C, k, k)),
        W_hv=jnp.swapaxes(W_cell, -1, -2) * mis(4, (R, C, k, k)),
        Wv_dn=Wv * (1.0 + hw.sigma_edge_gain * g(5, (R, C, k))),
        Wv_up=Wv * (1.0 + hw.sigma_edge_gain * g(6, (R, C, k))),
        Wh_rt=Wh * (1.0 + hw.sigma_edge_gain * g(7, (R, C, k))),
        Wh_lt=Wh * (1.0 + hw.sigma_edge_gain * g(8, (R, C, k))),
        h_v=jnp.zeros((R, C, k), dtype),
        h_h=jnp.zeros((R, C, k), dtype),
        gain_v=1.0 + hw.sigma_tanh_gain * g(9, (R, C, k)),
        gain_h=1.0 + hw.sigma_tanh_gain * g(10, (R, C, k)),
        off_v=hw.sigma_tanh_offset * 0.01 * g(11, (R, C, k)),
        off_h=jnp.zeros((R, C, k), dtype),
    )


def lattice_to_chip(spec: LatticeSpec, lat: LatticeChip,
                    graph: ChimeraGraph | None = None,
                    tables=None) -> EffectiveChip:
    """SoA lattice arrays -> the shared `EffectiveChip` slot layout.

    Directional: ``nbr_w[d, i] = W[i, nbr_idx[d, i]]`` (current INTO node
    i), so the converted chip samples the identical physics as the old
    SoA update loop — tests/test_lattice.py checks it against the dense
    reconstruction bit for bit.  O(D·N); no dense matrix anywhere.  The
    lattice's dtype carries through (dryrun's --pbit-dtype knob).
    """
    g = graph if graph is not None else make_chimera(
        spec.cell_rows, spec.cell_cols, spec.k)
    if tables is None:
        nbr_idx, _ = g.neighbor_table()
        slot_ij, slot_ji = g.edge_slots(nbr_idx)
    else:
        nbr_idx, slot_ij, slot_ji = tables
    dtype = lat.W_vh.dtype
    r_, c_, s_, k_ = g.node_r, g.node_c, g.node_side, g.node_k
    h = jnp.where(s_ == 0, lat.h_v[r_, c_, k_], lat.h_h[r_, c_, k_])
    gain = jnp.where(s_ == 0, lat.gain_v[r_, c_, k_], lat.gain_h[r_, c_, k_])
    off = jnp.where(s_ == 0, lat.off_v[r_, c_, k_], lat.off_h[r_, c_, k_])

    e0, e1 = g.edges[:, 0], g.edges[:, 1]
    r0, c0, k0 = r_[e0], c_[e0], k_[e0]
    k1 = k_[e1]
    incell = (r_[e1] == r0) & (c_[e1] == c0)
    vert = (s_[e0] == 0) & (s_[e1] == 0)
    # current INTO e0 from e1 / INTO e1 from e0 (see tests/test_lattice.py
    # for the dense index conventions these reproduce)
    w_in0 = jnp.where(
        incell, lat.W_vh[r0, c0, k0, k1],
        jnp.where(vert, lat.Wv_up[r0, c0, k0], lat.Wh_lt[r0, c0, k0]))
    w_in1 = jnp.where(
        incell, lat.W_hv[r0, c0, k1, k0],
        jnp.where(vert, lat.Wv_dn[r0, c0, k0], lat.Wh_rt[r0, c0, k0]))
    D = nbr_idx.shape[0]
    nbr_w = (jnp.zeros((D, g.n_nodes), dtype)
             .at[slot_ij, e0].set(w_in0)
             .at[slot_ji, e1].set(w_in1))
    ones = jnp.ones((g.n_nodes,), dtype)
    return EffectiveChip(
        W=None, h=h.astype(dtype), tanh_gain=gain.astype(dtype),
        tanh_offset=off.astype(dtype), rand_gain=ones,
        comp_offset=0.0 * ones, nbr_idx=jnp.asarray(nbr_idx, jnp.int32),
        nbr_w=nbr_w)


def sparse_energy(chip: EffectiveChip, m: jax.Array) -> jax.Array:
    """Symmetrized Ising energy per chain from the slot layout, O(B·N·D):
    E = -1/2 Σ_i m_i Σ_j W_ij m_j - Σ_i h_i m_i (directional W averaged
    over its two directions, exactly the old `lattice_energy`)."""
    I = sparse_neuron_input(m, chip.nbr_idx, chip.nbr_w,
                            jnp.float32(0.0))
    return -0.5 * jnp.sum(m * I, axis=1) - m @ chip.h


def make_lattice_anneal(
    spec: LatticeSpec,
    mesh: Mesh | None,
    *,
    row_axes: tuple[str, ...] = ("data",),
    col_axes: tuple[str, ...] = ("model",),
    n_sweeps: int = 100,
    record_every: int = 10,
):
    """Build the (optionally mesh-sharded) annealing step over the shared
    engine: cell rows partition over ``row_axes`` with ppermute halo
    exchange, exactly like every other sharded `api.Session` workload
    (the old private SoA update loop is retired; ``col_axes`` is accepted
    for signature compatibility — the spatial cut is 1-D over cell rows).

    Returns jitted run(lattice_chip, key, betas) ->
    (final_m (chains, N), energies (n_sweeps // record_every,)).
    """
    from repro import api

    if n_sweeps % record_every:
        raise ValueError(f"n_sweeps={n_sweeps} must be a multiple of "
                         f"record_every={record_every}")
    del col_axes
    g = make_chimera(spec.cell_rows, spec.cell_cols, spec.k)
    nbr_idx, _ = g.neighbor_table()
    tables = (nbr_idx, *g.edge_slots(nbr_idx))
    from repro.core.hardware import sample_mismatch_sparse
    sp = api.SamplerSpec(
        graph=g, hw=HardwareConfig.ideal(),
        mismatch=sample_mismatch_sparse(jax.random.PRNGKey(0), g.n_nodes,
                                        nbr_idx.shape[0],
                                        HardwareConfig.ideal()),
        noise="counter", backend="sparse", chains=spec.chains,
        beta=spec.beta, mesh=mesh,
        partition=(api.Partition(rows=row_axes) if mesh is not None
                   else None))
    session = api.Session(sp)
    n_rec = n_sweeps // record_every

    from repro.core import pbit

    def run(lat: LatticeChip, key: jax.Array, betas: jax.Array):
        chip = lattice_to_chip(spec, lat, g, tables)
        k1, k2 = jax.random.split(key)
        m = pbit.random_spins(k1, spec.chains, g.n_nodes)
        ns = session.noise_state(k2)
        segs = betas[:n_rec * record_every].reshape(n_rec, record_every)

        def seg(carry, b):
            m, ns = carry
            m, ns, _ = session.sample(chip, m, ns, b)
            return (m, ns), sparse_energy(chip, m).mean()

        (m, ns), energies = jax.lax.scan(seg, (m, ns), segs)
        return m, energies

    return jax.jit(run)


def lattice_input_sharding(mesh: Mesh, row_axes=("data",),
                           col_axes=("model",)):
    return NamedSharding(mesh, P(row_axes, col_axes))
