"""Fault tolerance & elasticity runtime.

What runs *here* (and is unit-tested on CPU) is the control-plane logic a
1000-node deployment needs; the data plane (actual preemption signals, ICI
failures) is delivered by the cluster scheduler and is simulated in tests.

Components
----------
* `StragglerWatchdog` — EWMA of step times; flags steps slower than
  `threshold`x the moving average.  At scale the action is "report the slow
  host to the scheduler and checkpoint"; here the action is a callback.
* `retry_step` — retries a step function on transient failure with
  capped, decorrelated-jitter backoff (the XLA analogue of NCCL
  timeout-and-retry), and falls back to `on_permanent` (normally:
  restore from checkpoint).  Jitter matters under multi-tenancy: many
  tenants retrying one flapped link with the same deterministic schedule
  re-herd at exactly the same instants.
* `ElasticState` — maps a checkpoint (mesh-agnostic, see checkpoint/) onto
  a *new* mesh after a node-count change; batch is re-split by the data
  pipeline's stateless (seed, step) addressing, so rescaling loses nothing.
* `Heartbeat` — liveness file per host; the launcher detects dead hosts by
  mtime, triggering the elastic path.
"""
from __future__ import annotations

import dataclasses
import json
import os
import random as _random
import time
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import numpy as np


class StragglerWatchdog:
    def __init__(self, threshold: float = 2.0, alpha: float = 0.1,
                 warmup: int = 5,
                 on_straggler: Optional[Callable[[int, float, float], None]]
                 = None):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = warmup
        self.on_straggler = on_straggler
        self.ewma: Optional[float] = None
        self.count = 0
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self.count += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = (self.count > self.warmup and
                dt > self.threshold * self.ewma)
        if slow:
            self.flagged.append((step, dt))
            if self.on_straggler:
                self.on_straggler(step, dt, self.ewma)
            # do not poison the average with the outlier
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow


class TransientError(RuntimeError):
    """Raised by step functions for retryable failures (link flap, etc.)."""


def retry_step(fn: Callable[[], Any], *, max_retries: int = 3,
               backoff_s: float = 0.1, max_backoff_s: float = 30.0,
               jitter: str = "decorrelated",
               rng: Optional[_random.Random] = None,
               on_permanent: Optional[Callable[[BaseException], Any]] = None,
               sleep=time.sleep) -> Any:
    """Run ``fn``, retrying `TransientError` with capped, jittered backoff.

    ``jitter="decorrelated"`` (the default) draws each delay uniformly
    from [backoff_s, 3 * previous_delay], capped at ``max_backoff_s`` —
    concurrent tenants retrying the same flapped link spread out instead
    of herding in lockstep at backoff_s * 2**attempt.  ``jitter="none"``
    keeps the deterministic exponential schedule (still capped).  ``rng``
    is an injectable `random.Random` for reproducible tests; delays never
    influence results, only pacing.
    """
    if jitter not in ("decorrelated", "none"):
        raise ValueError(
            f"jitter must be 'decorrelated' or 'none', got {jitter!r}")
    draw = (rng or _random).uniform
    last: Optional[BaseException] = None
    prev = backoff_s
    for attempt in range(max_retries + 1):
        try:
            return fn()
        except TransientError as e:  # pragma: no branch
            last = e
            if attempt < max_retries:
                if jitter == "none":
                    delay = min(backoff_s * (2 ** attempt), max_backoff_s)
                else:
                    delay = min(max_backoff_s,
                                draw(backoff_s, max(3.0 * prev, backoff_s)))
                    prev = delay
                sleep(delay)
    if on_permanent is not None:
        return on_permanent(last)
    raise last


class Heartbeat:
    def __init__(self, directory: str | Path, host_id: int):
        self.path = Path(directory) / f"host_{host_id}.alive"
        self.path.parent.mkdir(parents=True, exist_ok=True)

    def beat(self, step: int) -> None:
        # tmp + rename: a reader (or a crash) must never observe a
        # partially-written heartbeat — the liveness file is the one
        # thing that must stay parseable while its writer is dying
        tmp = self.path.with_name(self.path.name + ".tmp")
        tmp.write_text(json.dumps({"step": step, "t": time.time()}))
        os.replace(tmp, self.path)

    @staticmethod
    def dead_hosts(directory: str | Path, timeout_s: float,
                   now: Optional[float] = None) -> list[int]:
        if now is None:   # `or` would treat an explicit now=0.0 as unset
            now = time.time()
        dead = []
        for p in sorted(Path(directory).glob("host_*.alive")):
            host = int(p.stem.split("_")[1])
            try:
                t = float(json.loads(p.read_text())["t"])
            except (ValueError, KeyError, TypeError, OSError):
                # an unparsable heartbeat (torn write from a host dying
                # mid-beat, truncated file) is evidence of death, not an
                # excuse to crash the launcher's health sweep
                dead.append(host)
                continue
            if now - t > timeout_s:
                dead.append(host)
        return dead


@dataclasses.dataclass
class ElasticState:
    """Re-homes training state onto a new mesh (node count changed).

    Because checkpoints store logical arrays and the data pipeline is
    stateless, the procedure is: rebuild mesh -> recompute shardings from
    the same logical rules -> device_put.  Works for both shrink (lost pod)
    and grow (pod returned).
    """
    ckpt_dir: str

    def reshard(self, tree: Any, mesh, specs) -> Any:
        from jax.sharding import NamedSharding
        return jax.tree.map(
            lambda x, s: jax.device_put(np.asarray(x),
                                        NamedSharding(mesh, s)),
            tree, specs)

    def resume(self, mesh, make_specs, target_shapes) -> tuple[int, Any]:
        from repro.checkpoint import checkpoint as ckpt
        step, arrays, _ = ckpt.load(self.ckpt_dir)
        # arrays is flat {keystr: np.ndarray}; target_shapes gives pytree
        flat = jax.tree_util.tree_flatten_with_path(target_shapes)
        leaves, treedef = flat
        out = []
        specs = make_specs(target_shapes)
        spec_leaves = treedef.flatten_up_to(specs)
        from jax.sharding import NamedSharding
        for (path, leaf), spec in zip(leaves, spec_leaves):
            key = jax.tree_util.keystr(path)
            val = arrays[key]
            out.append(jax.device_put(val, NamedSharding(mesh, spec)))
        return step, jax.tree_util.tree_unflatten(treedef, out)
