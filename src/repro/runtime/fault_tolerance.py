"""Fault tolerance & elasticity runtime.

What runs *here* (and is unit-tested on CPU) is the control-plane logic a
1000-node deployment needs; the data plane (actual preemption signals, ICI
failures) is delivered by the cluster scheduler and is simulated in tests.

Components
----------
* `StragglerWatchdog` — EWMA of step times; flags steps slower than
  `threshold`x the moving average.  At scale the action is "report the slow
  host to the scheduler and checkpoint"; here the action is a callback.
* `retry_step` — retries a step function on transient failure with
  exponential backoff (the XLA analogue of NCCL timeout-and-retry), and
  falls back to `on_permanent` (normally: restore from checkpoint).
* `ElasticState` — maps a checkpoint (mesh-agnostic, see checkpoint/) onto
  a *new* mesh after a node-count change; batch is re-split by the data
  pipeline's stateless (seed, step) addressing, so rescaling loses nothing.
* `Heartbeat` — liveness file per host; the launcher detects dead hosts by
  mtime, triggering the elastic path.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import numpy as np


class StragglerWatchdog:
    def __init__(self, threshold: float = 2.0, alpha: float = 0.1,
                 warmup: int = 5,
                 on_straggler: Optional[Callable[[int, float, float], None]]
                 = None):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = warmup
        self.on_straggler = on_straggler
        self.ewma: Optional[float] = None
        self.count = 0
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self.count += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = (self.count > self.warmup and
                dt > self.threshold * self.ewma)
        if slow:
            self.flagged.append((step, dt))
            if self.on_straggler:
                self.on_straggler(step, dt, self.ewma)
            # do not poison the average with the outlier
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow


class TransientError(RuntimeError):
    """Raised by step functions for retryable failures (link flap, etc.)."""


def retry_step(fn: Callable[[], Any], *, max_retries: int = 3,
               backoff_s: float = 0.1,
               on_permanent: Optional[Callable[[BaseException], Any]] = None,
               sleep=time.sleep) -> Any:
    last: Optional[BaseException] = None
    for attempt in range(max_retries + 1):
        try:
            return fn()
        except TransientError as e:  # pragma: no branch
            last = e
            if attempt < max_retries:
                sleep(backoff_s * (2 ** attempt))
    if on_permanent is not None:
        return on_permanent(last)
    raise last


class Heartbeat:
    def __init__(self, directory: str | Path, host_id: int):
        self.path = Path(directory) / f"host_{host_id}.alive"
        self.path.parent.mkdir(parents=True, exist_ok=True)

    def beat(self, step: int) -> None:
        self.path.write_text(json.dumps({"step": step, "t": time.time()}))

    @staticmethod
    def dead_hosts(directory: str | Path, timeout_s: float,
                   now: Optional[float] = None) -> list[int]:
        if now is None:   # `or` would treat an explicit now=0.0 as unset
            now = time.time()
        dead = []
        for p in sorted(Path(directory).glob("host_*.alive")):
            t = json.loads(p.read_text())["t"]
            if now - t > timeout_s:
                dead.append(int(p.stem.split("_")[1]))
        return dead


@dataclasses.dataclass
class ElasticState:
    """Re-homes training state onto a new mesh (node count changed).

    Because checkpoints store logical arrays and the data pipeline is
    stateless, the procedure is: rebuild mesh -> recompute shardings from
    the same logical rules -> device_put.  Works for both shrink (lost pod)
    and grow (pod returned).
    """
    ckpt_dir: str

    def reshard(self, tree: Any, mesh, specs) -> Any:
        from jax.sharding import NamedSharding
        return jax.tree.map(
            lambda x, s: jax.device_put(np.asarray(x),
                                        NamedSharding(mesh, s)),
            tree, specs)

    def resume(self, mesh, make_specs, target_shapes) -> tuple[int, Any]:
        from repro.checkpoint import checkpoint as ckpt
        step, arrays, _ = ckpt.load(self.ckpt_dir)
        # arrays is flat {keystr: np.ndarray}; target_shapes gives pytree
        flat = jax.tree_util.tree_flatten_with_path(target_shapes)
        leaves, treedef = flat
        out = []
        specs = make_specs(target_shapes)
        spec_leaves = treedef.flatten_up_to(specs)
        from jax.sharding import NamedSharding
        for (path, leaf), spec in zip(leaves, spec_leaves):
            key = jax.tree_util.keystr(path)
            val = arrays[key]
            out.append(jax.device_put(val, NamedSharding(mesh, spec)))
        return step, jax.tree_util.tree_unflatten(treedef, out)
