"""Gradient compression with error feedback (distributed-optimization trick).

int8 per-tensor-block quantization of gradients before the cross-replica
all-reduce, with an error-feedback accumulator so the quantization error is
re-injected next step (Karimireddy et al.-style EF-SGD guarantee: same
fixed point as uncompressed training).

Used by the shard_map data-parallel trainer (launch/train.py --compress-grads):
  g_q, new_err = compress(g + err);  g_sync = psum(decompress(g_q)) / n
Bandwidth: 4x (f32) / 2x (bf16) reduction on the gradient all-reduce —
at 512 chips the gradient all-reduce of a 52B model drops from ~2.9 s to
~0.73 s of ICI time (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256  # quantization block (per-block scale)


class Compressed(NamedTuple):
    q: jax.Array        # int8 payload
    scale: jax.Array    # f32 per-block scales
    shape: tuple        # original shape (static)


def _pad_len(n: int) -> int:
    return (-n) % BLOCK


def compress(g: jax.Array) -> tuple[Compressed, jax.Array]:
    """Returns (compressed, error) with g ≈ decompress(compressed) + error."""
    shape = g.shape
    flat = g.astype(jnp.float32).reshape(-1)
    pad = _pad_len(flat.size)
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)
    err_full = flat - deq
    if pad:
        err_full = err_full[:-pad]
    return Compressed(q, scale[:, 0], shape), err_full.reshape(shape)


def decompress(c: Compressed) -> jax.Array:
    deq = (c.q.astype(jnp.float32) * c.scale[:, None]).reshape(-1)
    n = 1
    for d in c.shape:
        n *= d
    return deq[:n].reshape(c.shape)


def ef_compress_tree(grads: Any, err: Any) -> tuple[Any, Any]:
    """Error-feedback compression over a pytree.

    Returns (compressed_tree, new_err_tree); pair with `decompress_tree`
    after the all-reduce.
    """
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    comp, new_err = [], []
    for g, e in zip(flat_g, flat_e):
        c, ne = compress(g + e.astype(jnp.float32))
        comp.append(c)
        new_err.append(ne.astype(g.dtype))
    return treedef.unflatten(comp), treedef.unflatten(new_err)


def decompress_tree(comp: Any) -> Any:
    return jax.tree.map(
        decompress, comp, is_leaf=lambda x: isinstance(x, Compressed))


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
