"""Step functions + sharding wiring shared by dryrun.py / train.py / serve.py.

`make_train_step(cfg, mesh)` returns (fn, in_shardings, out_shardings,
abstract_inputs) for a *full* production train step: fwd + bwd + AdamW
update, remat'd scan, donated state.  `make_serve_step` is the one-token
decode with donated cache.  `make_prefill_step` fills a cache.

Everything is derived from the logical sharding rules in models/sharding.py;
nothing here is per-arch special-cased (that is the point — the 40-cell
dry-run sweep is one code path).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelCfg, ShapeCfg
from repro.core.hwaware import HwAwareConfig
from repro.models import transformer, whisper
from repro.models import sharding as shd
from repro.models.model import (
    build_model,
    decode_input_specs,
    train_input_specs,
)
from repro.optim import adamw


# ---------------------------------------------------------------------------
# Batch / cache sharding rules
# ---------------------------------------------------------------------------
def batch_specs(batch_tree: Any, mesh: Mesh) -> Any:
    def one(path, leaf):
        key = jax.tree_util.keystr(path)
        if "positions" in key and len(leaf.shape) == 3:
            names = (None, "batch", None)
        elif "frontend_embeds" in key:
            names = ("batch", None, None)
        elif len(leaf.shape) == 2:
            names = ("batch", None)
        else:
            names = ("batch",) + (None,) * (len(leaf.shape) - 1)
        return shd.spec(leaf.shape, names, mesh)

    return jax.tree_util.tree_map_with_path(one, batch_tree)


def cache_specs(cache_tree: Any, mesh: Mesh) -> Any:
    def one(path, leaf):
        key = jax.tree_util.keystr(path)
        nd = len(leaf.shape)
        lead = (None,) * (nd - _base_ndim(key))
        if key.endswith("'k']") or key.endswith("'v']"):
            names = lead + ("batch", "kv_seq", "kv_heads", None)
        elif "ssm" in key:
            names = lead + ("batch", "mlp", None)
        elif "conv" in key:
            names = lead + ("batch", None, "mlp")
        elif "wkv" in key:
            names = lead + ("batch", None, None, None)
        elif "shift" in key:
            names = lead + ("batch", None)
        else:
            names = (None,) * nd
        return shd.spec(leaf.shape, names, mesh)

    def _base_ndim(key: str) -> int:
        if key.endswith("'k']") or key.endswith("'v']"):
            return 4
        if "ssm" in key or "conv" in key:
            return 3
        if "wkv" in key:
            return 4
        if "shift" in key:
            return 2
        return 0

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def _ns(mesh, tree_of_specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_of_specs,
        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class LoweredStep:
    fn: Any                    # jitted, sharded
    abstract_args: tuple       # ShapeDtypeStructs to .lower(*args)
    in_shardings: Any
    out_shardings: Any


def abstract_train_state(cfg: ModelCfg, state_bits: int = 32
                         ) -> tuple[Any, Any]:
    model = build_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    opt = jax.eval_shape(lambda: adamw.init(params, state_bits))
    return params, opt


def _opt_moment_specs(moments: Any, mesh: Mesh) -> Any:
    """Specs for mu/nu.  f32 moments mirror the param rules; quantized
    QTensor payloads/scales shard their block dim over the FSDP axis
    (blockwise layout is shape-agnostic, so any divisible dim0 works)."""
    quantized = any(
        getattr(leaf, "dtype", None) == jnp.int8
        for leaf in jax.tree.leaves(moments))

    def one(path, leaf):
        if quantized:
            names = ("opt_blocks",) + (None,) * (len(leaf.shape) - 1)
            return shd.spec(leaf.shape, names, mesh)
        key = jax.tree_util.keystr(path)
        pnames = shd._leaf_axes(key, len(leaf.shape))
        return shd.spec(leaf.shape, pnames, mesh)

    return jax.tree_util.tree_map_with_path(one, moments)


def make_train_step(
    cfg: ModelCfg,
    shape: ShapeCfg,
    mesh: Mesh,
    opt_cfg: Optional[adamw.AdamWConfig] = None,
    hw_aware: Optional[HwAwareConfig] = None,
    microbatches: int = 1,
) -> LoweredStep:
    """microbatches > 1: gradient accumulation (scan over batch slices) —
    divides activation/carry memory by `microbatches` at ~zero FLOP cost."""
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    model = build_model(cfg, hw_aware=hw_aware)

    def grads_of(params, batch):
        return jax.value_and_grad(model.loss)(params, batch)

    def train_step(params, opt_state, batch):
        with shd.use_mesh(mesh):
            if microbatches == 1:
                loss, grads = grads_of(params, batch)
            else:
                def split(x):
                    return x.reshape((microbatches,
                                      x.shape[0] // microbatches)
                                     + x.shape[1:])
                mb = {k: (split(v) if k != "positions" else
                          jnp.moveaxis(split(jnp.moveaxis(v, 0, 1)), 2, 1))
                      for k, v in batch.items()}

                def acc_fn(acc, micro):
                    l, g = grads_of(params, micro)
                    return jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32),
                        acc, (l, g)), None

                zero = (jnp.zeros((), jnp.float32),
                        jax.tree.map(
                            lambda p: jnp.zeros(p.shape, jnp.float32),
                            params))
                (loss, grads), _ = jax.lax.scan(acc_fn, zero, mb)
                loss, grads = jax.tree.map(
                    lambda x: x / microbatches, (loss, grads))
            new_params, new_opt, metrics = adamw.apply(
                opt_cfg, grads, opt_state, params)
            metrics["loss"] = loss
        return new_params, new_opt, metrics

    params_a, opt_a = abstract_train_state(cfg, opt_cfg.state_bits)
    batch_a = train_input_specs(cfg, shape)
    pspec = shd.param_specs(params_a, mesh)
    ospec = adamw.OptState(
        step=P(),
        mu=_opt_moment_specs(opt_a.mu, mesh),
        nu=_opt_moment_specs(opt_a.nu, mesh))
    bspec = batch_specs(batch_a, mesh)
    in_sh = (_ns(mesh, pspec), _ns(mesh, ospec), _ns(mesh, bspec))
    out_sh = (_ns(mesh, pspec), _ns(mesh, ospec), None)
    fn = jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(0, 1))
    return LoweredStep(fn, (params_a, opt_a, batch_a), in_sh, out_sh)


# ---------------------------------------------------------------------------
# Serve: decode + prefill
# ---------------------------------------------------------------------------
def make_serve_step(cfg: ModelCfg, shape: ShapeCfg, mesh: Mesh
                    ) -> LoweredStep:
    model = build_model(cfg)

    def serve_step(params, tokens, pos, cache):
        with shd.use_mesh(mesh):
            logits, new_cache = model.decode_step(params, tokens, pos, cache)
        return logits, new_cache

    params_a = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = decode_input_specs(cfg, shape)
    pspec = shd.param_specs(params_a, mesh)
    cspec = cache_specs(specs["cache"], mesh)
    tok_spec = shd.spec(specs["tokens"].shape, ("batch", None), mesh)
    in_sh = (_ns(mesh, pspec), NamedSharding(mesh, tok_spec),
             NamedSharding(mesh, P()), _ns(mesh, cspec))
    out_sh = (NamedSharding(mesh, tok_spec), _ns(mesh, cspec))
    fn = jax.jit(serve_step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(3,))
    args = (params_a, specs["tokens"], specs["pos"], specs["cache"])
    return LoweredStep(fn, args, in_sh, out_sh)


def make_prefill_step(cfg: ModelCfg, shape: ShapeCfg, mesh: Mesh
                      ) -> LoweredStep:
    model = build_model(cfg)

    if cfg.enc_dec is not None:
        def prefill_step(params, batch):
            with shd.use_mesh(mesh):
                logits, _ = whisper.forward(params, cfg, batch["tokens"],
                                            batch["frontend_embeds"])
            return logits[:, -1:]
    else:
        def prefill_step(params, batch):
            with shd.use_mesh(mesh):
                logits, cache = transformer.prefill(
                    params, cfg, batch["tokens"], batch.get("positions"),
                    batch.get("frontend_embeds"))
            return logits, cache

    params_a = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    batch_a = train_input_specs(cfg, shape)
    batch_a.pop("labels")
    pspec = shd.param_specs(params_a, mesh)
    bspec = batch_specs(batch_a, mesh)
    in_sh = (_ns(mesh, pspec), _ns(mesh, bspec))
    fn = jax.jit(prefill_step, in_shardings=in_sh)
    return LoweredStep(fn, (params_a, batch_a), in_sh, None)


def make_step(cfg: ModelCfg, shape: ShapeCfg, mesh: Mesh,
              opt_bits: int = 32, microbatches: int = 1) -> LoweredStep:
    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig(state_bits=opt_bits)
        return make_train_step(cfg, shape, mesh, opt_cfg,
                               microbatches=microbatches)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, shape, mesh)
    return make_serve_step(cfg, shape, mesh)
