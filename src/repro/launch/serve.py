"""LANGUAGE-MODEL inference demo: batched prefill + decode for
decoder-only transformer archs with a continuous-batching loop.

This is NOT the p-bit sampling service.  The production serving layer
for the probabilistic chip — multi-tenant admission control, the
shape-bucketed compile cache, shard-loss degradation, fault-schedule
testing — lives in `repro.serve` and runs as ``python -m repro.serve``
(docs/serving.md).  This module stays as the LM-workload demo that
exercises the transformer stack.

CPU-sized example:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeCfg
from repro.configs.registry import get_config, get_reduced_config
from repro.launch import mesh as mesh_mod
from repro.models import transformer
from repro.models.model import build_model


def main() -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.serve",
        description="Language-model inference demo (decoder-only archs, "
                    "batched prefill + decode).  For the p-bit sampling "
                    "service, use `python -m repro.serve` instead "
                    "(docs/serving.md).")
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch) if args.reduced \
        else get_config(args.arch)
    assert cfg.enc_dec is None, "serve.py drives decoder-only archs"
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    k1, k2, k3 = jax.random.split(key, 3)
    params = model.init(k1)

    B, P = args.batch, args.prompt_len
    prompts = jax.random.randint(k2, (B, P), 0, cfg.vocab_size, jnp.int32)

    # prefill: fill the cache for the prompt, get first-token logits
    t0 = time.time()
    logits, pcache = jax.jit(lambda p, t: transformer.prefill(p, cfg, t)
                             )(params, prompts)
    # re-home the prefill cache into a max_seq decode cache
    cache = model.init_cache(B, args.max_seq)

    def graft(dst, src):
        if dst.ndim >= 3 and src.ndim == dst.ndim and \
                dst.shape[-2:] == src.shape[-2:] and \
                dst.shape[-3] >= src.shape[-3]:
            pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
            return jnp.pad(src.astype(dst.dtype), pad)
        return src.astype(dst.dtype)

    cache = jax.tree.map(graft, cache, pcache)
    print(f"prefill {B}x{P} in {time.time()-t0:.2f}s")

    decode = jax.jit(model.decode_step, donate_argnums=(3,))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out_toks = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, tok, jnp.int32(P + i), cache)
        if args.temperature > 0:
            k3, sub = jax.random.split(k3)
            tok = jax.random.categorical(
                sub, logits[:, -1] / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        tok = tok.astype(jnp.int32)
        out_toks.append(tok)
    dt = time.time() - t0
    gen = np.asarray(jnp.concatenate(out_toks, axis=1))
    print(f"decoded {args.gen-1} steps x {B} seqs in {dt:.2f}s "
          f"({(args.gen-1)*B/max(dt,1e-9):.1f} tok/s)")
    print("sample token ids:", gen[0, :16])


if __name__ == "__main__":
    main()
