import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (SPMD partitioner accepts it),
  * it fits: compiled.memory_analysis() per-device bytes < HBM,
  * the roofline terms: cost_analysis FLOPs/bytes + collective bytes parsed
    from the compiled HLO (benchmarks/roofline.py).

Results are cached as JSON per cell under --out (reruns skip clean cells).

Usage:
  python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both        # the full sweep
  python -m repro.launch.dryrun --pbit pbit-pod-2m       # paper's own arch
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.configs.base import LM_SHAPES, shape_applicable
from repro.configs.registry import ARCH_IDS, PBIT_CONFIGS, get_config
from repro.launch import mesh as mesh_mod
from repro.launch.steps import make_step


def _mem_stats(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "generated_code_bytes": int(ma.generated_code_size_in_bytes),
        }
    except Exception as e:  # backend-dependent
        return {"error": repr(e)}


def _cost_stats(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:
        return {"error": repr(e)}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Path, force: bool = False,
             opt_bits: int = 32, microbatches: int = 1) -> dict:
    mesh_tag = "multipod" if multi_pod else "pod"
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_tag}.json"
    if out_path.exists() and not force:
        rec = json.loads(out_path.read_text())
        if rec.get("status") in ("ok", "skip"):
            print(f"[cached] {arch} x {shape_name} x {mesh_tag}: "
                  f"{rec['status']}")
            return rec

    cfg = get_config(arch)
    shape = LM_SHAPES[shape_name]
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_tag}
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec.update(status="skip", reason=why)
        out_path.write_text(json.dumps(rec, indent=1))
        print(f"[skip]   {arch} x {shape_name}: {why}")
        return rec

    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        step = make_step(cfg, shape, mesh, opt_bits=opt_bits,
                         microbatches=microbatches)
        with mesh:
            lowered = step.fn.lower(*step.abstract_args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        hlo = compiled.as_text()
        from benchmarks.roofline import (collective_bytes_from_hlo,
                                         dot_flops_from_hlo)
        coll = collective_bytes_from_hlo(hlo)
        dflops = dot_flops_from_hlo(hlo)
        rec.update(
            dot_flops=dflops,
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            n_devices=mesh_mod.n_chips(mesh),
            memory=_mem_stats(compiled),
            cost=_cost_stats(compiled),
            collectives=coll,
            params=cfg.param_count(),
            active_params=cfg.active_param_count(),
            seq_len=shape.seq_len,
            global_batch=shape.global_batch,
            kind=shape.kind,
        )
        mem = rec["memory"]
        print(f"[ok]     {arch} x {shape_name} x {mesh_tag}: "
              f"compile={t_compile:.1f}s "
              f"args/dev={mem.get('argument_bytes', 0)/2**30:.2f}GiB "
              f"temp/dev={mem.get('temp_bytes', 0)/2**30:.2f}GiB "
              f"coll={coll.get('total_bytes', 0)/2**30:.3f}GiB")
    except Exception as e:
        rec.update(status="fail", error=repr(e),
                   trace=traceback.format_exc()[-4000:])
        print(f"[FAIL]   {arch} x {shape_name} x {mesh_tag}: {e!r}")
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def run_pbit(name: str, multi_pod: bool, out_dir: Path,
             force: bool = False, chains: int = 1,
             dtype: str = "float32") -> dict:
    """Dry-run the paper's own architecture: a distributed Chimera lattice."""
    from repro.core.distributed import (
        LatticeChip, LatticeSpec, make_lattice_anneal, make_sk_lattice,
        lattice_input_sharding)
    import jax.numpy as jnp

    mesh_tag = "multipod" if multi_pod else "pod"
    out_path = out_dir / f"{name}__anneal__{mesh_tag}.json"
    if out_path.exists() and not force:
        rec = json.loads(out_path.read_text())
        if rec.get("status") == "ok":
            print(f"[cached] {name} x {mesh_tag}: ok")
            return rec
    spec_d = PBIT_CONFIGS[name]
    spec = LatticeSpec(spec_d["cell_rows"], spec_d["cell_cols"],
                       chains=chains)
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    # the spatial cut is 1-D over cell rows (docs/sharding.md): use every
    # mesh axis so all chips hold a row band (512 rows >= 512 chips)
    row_axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    rec = {"arch": name, "shape": "anneal_1k_sweeps", "mesh": mesh_tag,
           "n_spins": spec.n_spins, "chains": chains, "dtype": dtype}
    t0 = time.time()
    try:
        run = make_lattice_anneal(spec, mesh, row_axes=row_axes,
                                  n_sweeps=1000, record_every=100)
        chip_a = jax.eval_shape(
            lambda k: make_sk_lattice(spec, k, dtype=jnp.dtype(dtype)),
            jax.random.PRNGKey(0))
        betas_a = jax.ShapeDtypeStruct((1000,), jnp.float32)
        key_a = jax.ShapeDtypeStruct((2,), jnp.uint32)
        with mesh:
            lowered = run.lower(chip_a, key_a, betas_a)
            compiled = lowered.compile()
        from benchmarks.roofline import (collective_bytes_from_hlo,
                                         dot_flops_from_hlo)
        hlo = compiled.as_text()
        rec.update(
            status="ok",
            compile_s=round(time.time() - t0, 2),
            n_devices=mesh_mod.n_chips(mesh),
            memory=_mem_stats(compiled),
            cost=_cost_stats(compiled),
            collectives=collective_bytes_from_hlo(hlo),
            dot_flops=dot_flops_from_hlo(hlo),
        )
        print(f"[ok]     {name} ({spec.n_spins/1e6:.1f}M spins) x "
              f"{mesh_tag}: compile={rec['compile_s']}s")
    except Exception as e:
        rec.update(status="fail", error=repr(e),
                   trace=traceback.format_exc()[-4000:])
        print(f"[FAIL]   {name} x {mesh_tag}: {e!r}")
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(LM_SHAPES))
    ap.add_argument("--pbit", choices=list(PBIT_CONFIGS))
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch x shape) cell")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opt-bits", type=int, default=32, choices=[8, 32])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--chains", type=int, default=1)
    ap.add_argument("--pbit-dtype", default="float32")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    n_fail = 0
    if args.pbit:
        for mp in meshes:
            rec = run_pbit(args.pbit, mp, out_dir, args.force,
                           args.chains, args.pbit_dtype)
            n_fail += rec["status"] == "fail"
    elif args.all:
        for arch in ARCH_IDS:
            for shape_name in LM_SHAPES:
                for mp in meshes:
                    rec = run_cell(arch, shape_name, mp, out_dir,
                                   args.force, args.opt_bits,
                                   args.microbatches)
                    n_fail += rec["status"] == "fail"
    else:
        assert args.arch and args.shape, "--arch/--shape or --all or --pbit"
        for mp in meshes:
            rec = run_cell(args.arch, args.shape, mp, out_dir, args.force,
                           args.opt_bits, args.microbatches)
            n_fail += rec["status"] == "fail"
    if n_fail:
        raise SystemExit(f"{n_fail} cells FAILED")


if __name__ == "__main__":
    main()
