"""End-to-end training driver.

Production loop: sharded train step (fwd+bwd+AdamW), stateless data
pipeline, async atomic checkpointing with resume-from-latest, straggler
watchdog, optional hardware-aware QAT (the paper's technique generalized),
optional int8 gradient compression (shard_map DP wrapper).

CPU-sized example (the (b) deliverable):
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --reduced \
      --steps 300 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
On hardware the same entry point takes --mesh pod/multipod and a full arch.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import ShapeCfg
from repro.configs.registry import get_config, get_reduced_config
from repro.core.hwaware import HwAwareConfig
from repro.data.pipeline import DataConfig, make_source
from repro.launch import mesh as mesh_mod
from repro.launch.steps import make_train_step
from repro.models.model import build_model
from repro.optim import adamw
from repro.runtime.fault_tolerance import StragglerWatchdog


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", choices=["host", "pod", "multipod"],
                    default="host")
    ap.add_argument("--data-model", type=int, nargs=2, default=[1, 1],
                    help="host mesh (data, model) shape")
    ap.add_argument("--hardware-aware", action="store_true",
                    help="train through the 8-bit DAC + mismatch model")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch) if args.reduced \
        else get_config(args.arch)
    shape = ShapeCfg("train_cli", args.seq, args.batch, "train")
    if args.mesh == "host":
        mesh = mesh_mod.make_host_mesh(*args.data_model)
    else:
        mesh = mesh_mod.make_production_mesh(
            multi_pod=args.mesh == "multipod")

    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(10, args.steps // 20))
    hw = HwAwareConfig() if args.hardware_aware else None
    step_obj = make_train_step(cfg, shape, mesh, opt_cfg, hw_aware=hw,
                               microbatches=args.microbatches)

    model = build_model(cfg)
    with mesh:
        params = jax.jit(
            model.init,
            out_shardings=step_obj.in_shardings[0])(jax.random.PRNGKey(
                args.seed))
        opt_state = jax.jit(
            adamw.init, out_shardings=step_obj.in_shardings[1])(params)

    start_step = 0
    writer = None
    if args.ckpt_dir:
        writer = ckpt.AsyncCheckpointer(args.ckpt_dir)
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            start_step, state, _ = ckpt.load(
                args.ckpt_dir, latest, target=(params, opt_state))
            params, opt_state = state
            print(f"resumed from step {start_step}")

    source = make_source(DataConfig(seed=args.seed,
                                    vocab_size=cfg.vocab_size))
    watchdog = StragglerWatchdog(
        on_straggler=lambda s, dt, ew: print(
            f"[watchdog] step {s} took {dt:.3f}s (ewma {ew:.3f}s)"))

    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"mesh={dict(mesh.shape)} batch={args.batch} seq={args.seq}")

    t_last = time.time()
    for step in range(start_step, args.steps):
        batch = source.batch(step, args.batch, args.seq)
        params, opt_state, metrics = step_obj.fn(params, opt_state, batch)
        if (step + 1) % args.log_every == 0 or step == start_step:
            loss = float(metrics["loss"])
            dt = (time.time() - t_last) / args.log_every
            t_last = time.time()
            watchdog.observe(step, dt)
            toks = args.batch * args.seq / max(dt, 1e-9)
            print(f"step {step+1:5d}  loss={loss:.4f}  "
                  f"lr={float(metrics['lr']):.2e}  "
                  f"gnorm={float(metrics['grad_norm']):.2f}  "
                  f"{dt*1e3:.0f} ms/step  {toks/1e3:.1f}k tok/s")
        if writer and (step + 1) % args.ckpt_every == 0:
            writer.save(step + 1, (params, opt_state))
    if writer:
        writer.save(args.steps, (params, opt_state))
        writer.wait()
        print(f"final checkpoint at {args.ckpt_dir}")


if __name__ == "__main__":
    main()
