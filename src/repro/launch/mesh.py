"""Production mesh definitions (TPU v5e pods).

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — the "pod"
axis carries only data parallelism (gradient all-reduce over DCI/optical),
"model" stays intra-pod where ICI bandwidth lives.

Functions, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # AxisType landed after jax 0.4.x; older versions imply Auto axes
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover
    AxisType = None


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """`jax.shard_map` across jax versions.

    jax >= 0.6 exposes it at the top level with `check_vma`; 0.4.x has
    `jax.experimental.shard_map.shard_map` with the same knob named
    `check_rep`.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shmap
    return _shmap(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)


def _mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)

# TPU v5e hardware constants (roofline + napkin math)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per axis neighbor)
ICI_LAT_S = 1e-6                # per-transfer ICI latency (hop setup cost)
HBM_BYTES = 16 * 2**30          # 16 GiB per chip


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many (possibly fake) local devices exist."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return _mesh((data, model), ("data", "model"))


def make_line_mesh(n: int | None = None, axis: str = "data") -> Mesh:
    """1-D mesh over n local devices — the shape the sharded p-bit
    lattice wants (cell rows partition over one axis; see
    docs/sharding.md).  n=None uses every local device."""
    n = len(jax.devices()) if n is None else n
    return _mesh((n,), (axis,))


def halo_vs_hbm_seconds(halo_bytes: int, hbm_bytes: int,
                        exchanges: float = 0.0) -> dict:
    """Napkin math for one sharded sweep (docs/sharding.md): time on the
    ICI link moving the halo vs time streaming the local state+weights
    from HBM.  Ratio << 1 means the halo exchange hides entirely behind
    the local half-sweep — the regime the O(√N) boundary guarantees.

    ``exchanges`` is the policy's per-sweep transfer count
    (`Sync.exchanges_per_sweep()`); each transfer pays a fixed
    ``ICI_LAT_S`` hop-setup latency on top of the bandwidth term.  Small
    halos are latency-bound — the cost the kernel-resident exchange
    amortizes by keeping the refresh inside one launch —
    ``ici_latency_share`` says how much of the ICI time that fixed cost
    is."""
    t_bw = halo_bytes / ICI_BW
    t_lat = exchanges * ICI_LAT_S
    t_ici = t_bw + t_lat
    t_hbm = hbm_bytes / HBM_BW
    return {"ici_s": t_ici, "hbm_s": t_hbm,
            "ici_latency_s": t_lat,
            "ici_latency_share": t_lat / max(t_ici, 1e-30),
            "ici_over_hbm": t_ici / max(t_hbm, 1e-30)}


def n_chips(mesh: Mesh) -> int:
    out = 1
    for v in mesh.shape.values():
        out *= v
    return out
