"""Deterministic, shardable token data pipeline.

Production posture: the pipeline is *stateless given (seed, step)* — any
worker can reproduce any step's global batch (what makes checkpoint-restart
and elastic rescale trivial: no data-loader state to save).  Per-host
sharding slices the global batch by `jax.process_index()`-style host ids.

Sources:
  * SyntheticLM  — power-law token stream with induced bigram structure
                   (so CE actually decreases while training the examples).
  * TextFile     — byte-level tokens from a local file, deterministic chunks.
"""
from __future__ import annotations

import dataclasses
import hashlib
from pathlib import Path
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelCfg, ShapeCfg


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 32000
    kind: str = "synthetic"          # "synthetic" | "file"
    path: Optional[str] = None


class SyntheticLM:
    """Markov-ish synthetic stream: next ~ mix(bigram(prev), powerlaw)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        self._perm = rng.permutation(V)          # bigram successor table
        ranks = np.arange(1, V + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._p = p / p.sum()

    def batch(self, step: int, batch: int, seq: int,
              host_id: int = 0, n_hosts: int = 1) -> dict:
        """Global batch for `step`, sliced for this host."""
        assert batch % n_hosts == 0
        local = batch // n_hosts
        seed = (self.cfg.seed * 1_000_003 + step) * 97 + host_id
        rng = np.random.default_rng(seed)
        base = rng.choice(self.cfg.vocab_size, size=(local, seq + 1),
                          p=self._p)
        # induce learnable structure: 50% of tokens follow the bigram table
        # (sequential so the bigram holds on the *emitted* stream)
        follow = rng.random((local, seq)) < 0.5
        toks = base.copy()
        for t in range(1, seq + 1):
            nxt = self._perm[toks[:, t - 1]]
            toks[:, t] = np.where(follow[:, t - 1], nxt, base[:, t])
        return {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }


class TextFile:
    """Byte-tokenized local file, deterministic chunk addressing."""

    def __init__(self, cfg: DataConfig):
        data = Path(cfg.path).read_bytes()
        self._arr = np.frombuffer(data, dtype=np.uint8)
        self.cfg = cfg

    def batch(self, step: int, batch: int, seq: int,
              host_id: int = 0, n_hosts: int = 1) -> dict:
        assert batch % n_hosts == 0
        local = batch // n_hosts
        n = len(self._arr) - seq - 1
        seed = (self.cfg.seed * 1_000_003 + step) * 97 + host_id
        rng = np.random.default_rng(seed)
        starts = rng.integers(0, max(n, 1), size=local)
        toks = np.stack([self._arr[s:s + seq + 1] for s in starts])
        return {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }


def make_source(cfg: DataConfig):
    if cfg.kind == "file":
        return TextFile(cfg)
    return SyntheticLM(cfg)


def batches(source, shape: ShapeCfg, start_step: int = 0,
            host_id: int = 0, n_hosts: int = 1) -> Iterator[tuple[int, dict]]:
    step = start_step
    while True:
        yield step, source.batch(step, shape.global_batch, shape.seq_len,
                                 host_id, n_hosts)
        step += 1
