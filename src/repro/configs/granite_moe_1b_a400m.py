"""IBM Granite 3.0 1B-A400M — MoE 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs.base import ModelCfg, MoECfg

CONFIG = ModelCfg(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    moe=MoECfg(num_experts=32, top_k=8, d_ff_expert=512),
    tie_embeddings=True,
)
