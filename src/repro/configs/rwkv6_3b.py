"""RWKV-6 (Finch) 3B — attention-free, data-dependent decay.
[arXiv:2404.05892; hf]"""
from repro.configs.base import ModelCfg, RWKVCfg

CONFIG = ModelCfg(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,              # 2560 / 64 wkv heads
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    rope_kind="none",
    rwkv=RWKVCfg(head_dim=64, decay_lora=64),
)
