"""Jamba v0.1 52B — hybrid Mamba+attention 1:7, MoE 16e top-2 every 2 layers.
[arXiv:2403.19887; hf]"""
from repro.configs.base import HybridCfg, ModelCfg, MoECfg

CONFIG = ModelCfg(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    moe=MoECfg(num_experts=16, top_k=2, d_ff_expert=14336, every=2),
    hybrid=HybridCfg(period=8, attn_index=4, d_state=16, d_conv=4, expand=2),
    rope_kind="none",  # Jamba uses no positional encoding (Mamba provides it)
)
