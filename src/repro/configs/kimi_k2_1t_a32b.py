"""Kimi K2 — trillion-parameter MoE, 384 experts top-8 + 1 shared expert,
first layer dense (paper-table). [arXiv:2501.kimi2; unverified]"""
from repro.configs.base import ModelCfg, MoECfg

CONFIG = ModelCfg(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=18432,                # the single dense layer's FFN
    vocab_size=163840,
    moe=MoECfg(num_experts=384, top_k=8, d_ff_expert=2048,
               num_shared=1, first_dense=1),
)
