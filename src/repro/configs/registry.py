"""``--arch <id>`` registry for all assigned architectures (+ the paper's own
p-bit lattice configs)."""
from __future__ import annotations

import importlib

from repro.configs.base import LM_SHAPES, ModelCfg, ShapeCfg, reduced

_ARCH_MODULES = {
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "qwen1.5-110b": "repro.configs.qwen1_5_110b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
}

ARCH_IDS = tuple(_ARCH_MODULES)

# The paper's own architecture: Chimera p-bit lattices (cells_rows x cells_cols)
PBIT_CONFIGS = {
    "pbit-chip-440": dict(cell_rows=7, cell_cols=8, masked=((6, 7),)),
    "pbit-pod-2m": dict(cell_rows=512, cell_cols=512, masked=()),
    "pbit-pod-33m": dict(cell_rows=2048, cell_cols=2048, masked=()),
}


def get_config(arch: str) -> ModelCfg:
    if arch not in _ARCH_MODULES:
        raise KeyError(
            f"unknown arch {arch!r}; available: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def get_reduced_config(arch: str) -> ModelCfg:
    return reduced(get_config(arch))


def get_shape(name: str) -> ShapeCfg:
    return LM_SHAPES[name]


def all_cells() -> list[tuple[str, str]]:
    """All 40 assigned (arch x shape) cells, including skipped ones."""
    return [(a, s) for a in ARCH_IDS for s in LM_SHAPES]
