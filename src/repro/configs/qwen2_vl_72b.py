"""Qwen2-VL 72B — M-RoPE, dynamic resolution; vision frontend is a STUB
(input_specs provides precomputed patch embeddings + 3D positions).
[arXiv:2409.12191; hf]"""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_kind="mrope",
    mrope_sections=(16, 24, 24),
    frontend="vision_stub",
)
