"""Config system: model architecture + input-shape + run configs.

Every assigned architecture is a frozen `ModelCfg` in its own module under
repro.configs; `repro.configs.registry` maps ``--arch <id>`` to it.  Shape
cells (`ShapeCfg`) are shared across LM archs per the assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0          # shared experts (Kimi K2 style)
    every: int = 1               # MoE every k-th layer (Jamba: 2)
    first_dense: int = 0         # leading dense layers (Kimi K2: 1)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class HybridCfg:
    """Mamba/attention interleave (Jamba: one attention layer per 8)."""
    period: int = 8
    attn_index: int = 4          # position of the attention layer in a period
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class RWKVCfg:
    head_dim: int = 64
    decay_lora: int = 64         # low-rank data-dependent decay proj


@dataclasses.dataclass(frozen=True)
class EncDecCfg:
    enc_layers: int
    enc_seq: int = 1500          # whisper 30 s @ 50 Hz after conv stub


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    attn_type: Literal["full", "local_global"] = "full"
    window: int = 4096
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    qkv_bias: bool = False
    rope_kind: Literal["rope", "mrope", "none"] = "rope"
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, ...] = (16, 24, 24)
    moe: Optional[MoECfg] = None
    hybrid: Optional[HybridCfg] = None
    rwkv: Optional[RWKVCfg] = None
    enc_dec: Optional[EncDecCfg] = None
    frontend: Literal["none", "audio_stub", "vision_stub"] = "none"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    scale_embed: bool = False    # gemma: embed * sqrt(d_model)
    post_norms: bool = False     # gemma2: sandwich (pre+post) layer norms
    dtype: str = "bfloat16"
    remat: bool = True
    # derived -----------------------------------------------------------
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def attn_free(self) -> bool:
        return self.rwkv is not None

    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid families)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate total parameter count (embeddings included)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd = self.hd()
        emb = V * D * (1 if self.tie_embeddings else 2)
        attn = D * hd * (self.num_heads + 2 * self.num_kv_heads) + \
            self.num_heads * hd * D
        dense_mlp = 3 * D * F

        def layer_mlp(i: int) -> int:
            if self.moe and i >= self.moe.first_dense and \
                    (i % self.moe.every == (self.moe.every - 1)):
                e = self.moe
                return (e.num_experts + e.num_shared) * 3 * D * e.d_ff_expert \
                    + D * e.num_experts
            return dense_mlp

        total = emb
        for i in range(L):
            if self.hybrid and (i % self.hybrid.period) != self.hybrid.attn_index:
                d_in = self.hybrid.expand * D
                total += 2 * D * d_in + d_in * D + \
                    d_in * (2 * self.hybrid.d_state + 2)  # proj + ssm
            elif self.rwkv:
                total += 6 * D * D  # r,k,v,g,w,o (approx)
            else:
                total += attn
            total += layer_mlp(i)
        if self.enc_dec:
            total += self.enc_dec.enc_layers * (attn + dense_mlp)
            total += L * attn  # cross attention
        return total

    def active_param_count(self) -> int:
        """Per-token active params (MoE counts top_k + shared experts)."""
        if not self.moe:
            return self.param_count()
        e = self.moe
        full = self.param_count()
        n_moe_layers = sum(
            1 for i in range(self.num_layers)
            if i >= e.first_dense and (i % e.every == (e.every - 1)))
        all_exp = n_moe_layers * e.num_experts * 3 * self.d_model * e.d_ff_expert
        act_exp = n_moe_layers * (e.top_k + e.num_shared) * 3 * \
            self.d_model * e.d_ff_expert
        return full - all_exp + act_exp


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


LM_SHAPES: dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelCfg, shape: ShapeCfg) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic():
        return False, "long_500k skipped: pure full-attention arch (see DESIGN.md)"
    return True, ""


def reduced(cfg: ModelCfg, **overrides) -> ModelCfg:
    """Tiny same-family config for CPU smoke tests."""
    moe = cfg.moe and MoECfg(
        num_experts=min(cfg.moe.num_experts, 4),
        top_k=min(cfg.moe.top_k, 2),
        d_ff_expert=64,
        num_shared=min(cfg.moe.num_shared, 1),
        every=cfg.moe.every,
        first_dense=min(cfg.moe.first_dense, 1),
    )
    hybrid = cfg.hybrid and HybridCfg(
        period=cfg.hybrid.period, attn_index=cfg.hybrid.attn_index,
        d_state=8, d_conv=4, expand=2)
    enc_dec = cfg.enc_dec and EncDecCfg(enc_layers=2, enc_seq=16)
    base = dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        num_layers=cfg.hybrid.period if cfg.hybrid else
        (4 if not cfg.moe else max(2, 1 + (cfg.moe.first_dense > 0))),
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        window=64,
        mrope_sections=(4, 6, 6),  # scaled to the reduced head_dim (32)
        moe=moe,
        hybrid=hybrid,
        enc_dec=enc_dec,
        dtype="float32",
        remat=False,
    )
    return dataclasses.replace(base, **overrides) if overrides else base
