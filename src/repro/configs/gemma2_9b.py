"""Gemma 2 9B — local/global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]"""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    attn_type="local_global",
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    tie_embeddings=True,
    scale_embed=True,
    post_norms=True,
)
