"""Whisper tiny — encoder-decoder audio transformer; conv frontend is a STUB
(input_specs provides precomputed frame embeddings). [arXiv:2212.04356]"""
from repro.configs.base import EncDecCfg, ModelCfg

CONFIG = ModelCfg(
    name="whisper-tiny",
    family="audio",
    num_layers=4,              # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    rope_kind="none",          # whisper uses learned positions
    enc_dec=EncDecCfg(enc_layers=4, enc_seq=1500),
    frontend="audio_stub",
    tie_embeddings=True,
    dtype="float32",           # tiny model; fp32 is fine even on TPU
)
