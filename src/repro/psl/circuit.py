"""Probabilistic-spin-logic circuit IR: composable gates -> sparse (J, h).

A PSL circuit (Camsari/Sutton/Datta, "p-bits for probabilistic spin
logic") is an Ising Hamiltonian whose *degenerate ground states* are
exactly the valid truth-table rows of a Boolean circuit.  Run forward
(inputs clamped) the free spins relax to the unique consistent output;
run backward (outputs clamped) they sample the preimage — division,
factorization, SAT — for free, because a Hamiltonian has no notion of
signal direction.

`PCircuit` is the mutable builder: gate modules (psl/gates.py) allocate
logical spins and *superpose* their clause Hamiltonians onto shared
spins — composition is literally addition of (J, h) terms, which
preserves ground states because every gate's valid rows are energy-
degenerate within the gate.  `synthesize()` freezes the accumulated
terms into a `LogicalIsing`: an edge-list `(E, 2)/(E,)` sparse coupling
set plus `(N,)` biases — the exact format `core/cd.py` master weights
and the sparse backends use.  Nothing dense is ever built at any stage.

The IR also records *clauses* (which gate touched which spins, and its
valid-row table) and *clamp roles* (named input/output port groups,
LSB-first bit vectors).  Clauses give an exact satisfiability oracle for
tests and decoders; ports tell the compile layer (psl/compile.py) what
to clamp in forward vs inverse mode.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Clause:
    """One gate instance: which logical spins it binds, and its truth
    table as ±1 rows (the gate's degenerate ground set)."""

    gate: str
    spins: tuple[int, ...]
    table: tuple[tuple[int, ...], ...]

    def satisfied(self, assignment: Sequence[int]) -> bool:
        row = tuple(1 if assignment[s] > 0 else -1 for s in self.spins)
        return row in self.table


@dataclasses.dataclass(frozen=True)
class LogicalIsing:
    """Synthesized circuit Hamiltonian in sparse edge-list form.

    ``edges``/``J`` are the (E, 2) int32 / (E,) float32 coupling list
    (i < j, lexicographically sorted — the same canonical order
    `ChimeraGraph.edges` uses), ``h`` the (N,) float32 biases.  Ports
    are named LSB-first bit vectors of logical spin ids.
    """

    n_spins: int
    names: tuple[str, ...]
    edges: np.ndarray          # (E, 2) int32, i < j
    J: np.ndarray              # (E,) float32
    h: np.ndarray              # (N,) float32
    inputs: tuple[str, ...]    # port names, declaration order
    outputs: tuple[str, ...]
    ports: tuple[tuple[str, tuple[int, ...]], ...]  # name -> spin ids
    clauses: tuple[Clause, ...]

    @property
    def n_edges(self) -> int:
        return int(self.edges.shape[0])

    @property
    def max_coupling(self) -> float:
        """max |J| over the synthesized couplers — the reference scale the
        embedder's chain strength auto-scales against."""
        return float(np.abs(self.J).max()) if self.J.size else 0.0

    def port(self, name: str) -> tuple[int, ...]:
        for pname, ids in self.ports:
            if pname == name:
                return ids
        raise KeyError(
            f"no port {name!r}; have {[p for p, _ in self.ports]}")

    def port_spins(self, names: Iterable[str]) -> tuple[int, ...]:
        out: list[int] = []
        for n in names:
            out.extend(self.port(n))
        return tuple(out)

    def degrees(self) -> np.ndarray:
        d = np.zeros(self.n_spins, np.int32)
        np.add.at(d, self.edges[:, 0], 1)
        np.add.at(d, self.edges[:, 1], 1)
        return d

    def dense(self) -> tuple[np.ndarray, np.ndarray]:
        """Dense (N, N)/(N,) reconstruction — small-N test oracle ONLY
        (the compile path never calls this)."""
        Jd = np.zeros((self.n_spins, self.n_spins), np.float32)
        Jd[self.edges[:, 0], self.edges[:, 1]] = self.J
        Jd[self.edges[:, 1], self.edges[:, 0]] = self.J
        return Jd, self.h.copy()

    def satisfied(self, assignment: Sequence[int]) -> bool:
        """Does a full ±1 assignment satisfy every clause?"""
        return all(c.satisfied(assignment) for c in self.clauses)

    def valid_assignments(self) -> np.ndarray:
        """All clause-consistent ±1 assignments, shape (n_valid, N).

        Exact enumeration (capped at 20 spins) — the ground-state oracle
        tests/test_psl.py checks the synthesized Hamiltonian against.
        """
        if self.n_spins > 20:
            raise ValueError(
                f"valid_assignments enumerates 2^N states; N="
                f"{self.n_spins} > 20")
        rows = [a for a in itertools.product((-1, 1), repeat=self.n_spins)
                if self.satisfied(a)]
        return np.asarray(rows, np.int8).reshape(len(rows), self.n_spins)


class PCircuit:
    """Mutable PSL circuit builder (gate modules compose onto this).

    Spins are allocated by `spin()`; gate helpers in psl/gates.py add
    couplings/biases/clauses; `mark_input`/`mark_output` declare named
    port groups (LSB-first).  `synthesize()` freezes to `LogicalIsing`;
    `compile()`/`to_spec()` go all the way to an embedded
    `api.SamplerSpec` (psl/compile.py).
    """

    def __init__(self, name: str = "pcircuit"):
        self.name = name
        self._names: list[str] = []
        self._J: dict[tuple[int, int], float] = {}
        self._h: dict[int, float] = {}
        self._ports: dict[str, tuple[int, ...]] = {}
        self._port_order: list[str] = []
        self._inputs: list[str] = []
        self._outputs: list[str] = []
        self._clauses: list[Clause] = []

    # -- spins ----------------------------------------------------------
    @property
    def n_spins(self) -> int:
        return len(self._names)

    def spin(self, name: str | None = None) -> int:
        """Allocate one logical spin; returns its id."""
        i = len(self._names)
        self._names.append(name if name is not None else f"s{i}")
        return i

    def spins(self, prefix: str, n: int) -> list[int]:
        """Allocate an n-bit vector (LSB-first): prefix0, prefix1, ..."""
        return [self.spin(f"{prefix}{k}") for k in range(n)]

    def _check(self, i: int) -> None:
        if not 0 <= i < self.n_spins:
            raise ValueError(
                f"spin id {i} out of range (have {self.n_spins})")

    # -- Hamiltonian terms (superposition: += is gate composition) ------
    def add_coupling(self, i: int, j: int, w: float) -> None:
        self._check(i), self._check(j)
        if i == j:
            raise ValueError(f"self-coupling on spin {i}")
        key = (min(i, j), max(i, j))
        self._J[key] = self._J.get(key, 0.0) + float(w)

    def add_bias(self, i: int, w: float) -> None:
        self._check(i)
        self._h[i] = self._h.get(i, 0.0) + float(w)

    def add_clause(self, gate: str, spins: Sequence[int],
                   table: Iterable[tuple[int, ...]]) -> None:
        for s in spins:
            self._check(s)
        self._clauses.append(
            Clause(gate, tuple(int(s) for s in spins),
                   tuple(tuple(int(v) for v in row) for row in table)))

    # -- clamp roles ----------------------------------------------------
    def _mark(self, name: str, ids: Sequence[int] | int,
              role: list[str]) -> None:
        if name in self._ports:
            raise ValueError(f"port {name!r} already declared")
        ids = (ids,) if isinstance(ids, (int, np.integer)) else tuple(ids)
        for i in ids:
            self._check(int(i))
        self._ports[name] = tuple(int(i) for i in ids)
        self._port_order.append(name)
        role.append(name)

    def mark_input(self, name: str, ids: Sequence[int] | int) -> None:
        """Declare a named input port (bit vector, LSB-first).  Forward
        mode clamps these chains; inverse mode reads them out."""
        self._mark(name, ids, self._inputs)

    def mark_output(self, name: str, ids: Sequence[int] | int) -> None:
        """Declare a named output port.  Forward mode reads these out;
        inverse/factorization mode clamps them."""
        self._mark(name, ids, self._outputs)

    # -- synthesis ------------------------------------------------------
    def synthesize(self) -> LogicalIsing:
        """Freeze to the sparse edge-list Hamiltonian (drops couplers
        that cancelled to exactly zero)."""
        items = sorted((k, v) for k, v in self._J.items() if v != 0.0)
        edges = (np.asarray([k for k, _ in items], np.int32)
                 .reshape(len(items), 2))
        J = np.asarray([v for _, v in items], np.float32)
        h = np.zeros(self.n_spins, np.float32)
        for i, v in self._h.items():
            h[i] = v
        return LogicalIsing(
            n_spins=self.n_spins,
            names=tuple(self._names),
            edges=edges, J=J, h=h,
            inputs=tuple(self._inputs), outputs=tuple(self._outputs),
            ports=tuple((n, self._ports[n]) for n in self._port_order),
            clauses=tuple(self._clauses))

    # -- straight-through compile sugar (psl/compile.py) ----------------
    def compile(self, graph, **kw):
        """Synthesize + minor-embed onto ``graph`` + build the sampler
        spec: returns a `psl.compile.CompiledCircuit`."""
        from repro.psl.compile import compile_circuit
        return compile_circuit(self, graph, **kw)

    def to_spec(self, graph, **kw):
        """The `api.SamplerSpec` of `compile()` — the one-call path from
        a logic netlist to a Session-ready spec."""
        return self.compile(graph, **kw).spec
