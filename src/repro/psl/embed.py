"""Minor-embedding of logical PSL spins onto a masked Chimera graph.

A logical Ising problem is all-to-all in the worst case; the chip's
Chimera fabric has degree 6.  The classic fix (Choi's TRIAD / D-Wave's
clique embedding) represents each logical spin as a *chain* of
physical spins locked together by strong ferromagnetic couplers, routed
so every logical pair's chains touch somewhere.

This embedder is the deterministic L-ladder clique layout on an M×M
window of unit cells, M = ceil(n_logical / k):

* logical spin i (block b = i // k, unit u = i % k) owns an L-shaped
  chain: the vertical-side unit-u nodes of the window column ``c0 + b``
  (all M cell rows) plus the horizontal-side unit-u nodes of the window
  row ``r0 + b`` (all M cell columns), joined by the in-cell K_{k,k}
  edge at the corner cell ``(r0 + b, c0 + b)``.  Chain length 2M,
  2M - 1 intra-chain couplers, and chains are disjoint by construction
  (distinct (block, unit) pairs).
* logical coupler (i, j), i < j, is realized on the in-cell edge
  horizontal(u_i) — vertical(u_j) of cell ``(r0 + b_i, c0 + b_j)``:
  i's horizontal ladder crosses j's vertical ladder exactly there.
  Distinct pairs land on distinct physical edges (same-block pairs
  share the corner cell with the junctions but use different K44
  edges, since units differ).

The window origin ``(r0, c0)`` is found by a deterministic first-fit
row-major scan over placements whose M×M cell window avoids every
masked cell — the same coordinate-LUT addressing the serving layer's
bucket embedder uses (`ChimeraGraph.coord_lut`).  No randomness
anywhere: the same (circuit, graph, options) always yields the same
embedding, byte for byte.

Chain strength auto-scales against the problem: ferromagnetic chain
couplers get ``chain_scale × max|J_logical|`` (default 2.0 — strong
enough that breaking a chain always costs more than violating any one
logical clause, cheap enough not to crush the logical energy scale
after 8-bit quantization).  Integer DAC codes are derived with one
shared ``code_unit = floor(127 / max(chain, |J|, |h|))`` so every
integer-valued logical weight stays *exact* in code space.  Biases are
placed whole on the chain's junction node.

`validate_embedding` re-checks the three invariants from scratch
(disjoint chains, chain connectivity through real graph edges, every
logical coupler realized) and is run on every `embed_circuit` result.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.chimera import ChimeraGraph
from repro.psl.circuit import LogicalIsing


@dataclasses.dataclass(frozen=True)
class ChainEmbedding:
    """One logical->physical embedding plus its programmed code arrays.

    ``J_codes``/``h_codes`` align with ``graph.edges``/node ids — ready
    for `api.program_edges` as-is.  ``chain_nodes[i]`` lists logical
    spin i's physical chain (junction node first: the bias site and the
    majority-vote tie-breaker).
    """

    graph: ChimeraGraph
    n_logical: int
    window: tuple[int, int, int]        # (r0, c0, M) in unit cells
    chain_nodes: tuple[tuple[int, ...], ...]
    chain_edge_idx: np.ndarray          # intra-chain rows into graph.edges
    coupler_edge_idx: np.ndarray        # (E_logical,) rows into graph.edges
    chain_strength: float               # in logical-J units
    code_unit: int                      # DAC codes per logical-J unit
    J_codes: np.ndarray                 # (E_graph,) int32
    h_codes: np.ndarray                 # (N_graph,) int32

    @property
    def chain_length(self) -> int:
        return len(self.chain_nodes[0]) if self.chain_nodes else 0

    @property
    def n_physical(self) -> int:
        """Physical spins used (chains are disjoint)."""
        return sum(len(ch) for ch in self.chain_nodes)

    @property
    def overhead_spins(self) -> int:
        """Physical spins spent beyond one-per-logical."""
        return self.n_physical - self.n_logical

    def chain_index(self) -> np.ndarray:
        """(n_logical, chain_length) int32 node-id matrix (for decoding)."""
        return np.asarray(self.chain_nodes, np.int32)

    def node_to_logical(self) -> np.ndarray:
        """(N_graph,) int32: owning logical spin per node, -1 if unused."""
        out = -np.ones(self.graph.n_nodes, np.int32)
        for i, ch in enumerate(self.chain_nodes):
            out[list(ch)] = i
        return out

    def stats(self) -> dict:
        """Embedding-quality numbers the bench tracks."""
        return {
            "n_logical": int(self.n_logical),
            "n_physical": int(self.n_physical),
            "chain_length": int(self.chain_length),
            "overhead_spins": int(self.overhead_spins),
            "graph_nodes": int(self.graph.n_nodes),
            "utilization": float(self.n_physical / self.graph.n_nodes),
            "chain_strength": float(self.chain_strength),
            "code_unit": int(self.code_unit),
            "window": [int(v) for v in self.window],
        }


# ---------------------------------------------------------------------------
# placement
# ---------------------------------------------------------------------------
def find_window(graph: ChimeraGraph, m_cells: int,
                origin: tuple[int, int] | None = None) -> tuple[int, int]:
    """First (row-major) M×M cell window avoiding every masked cell.

    An explicit ``origin`` skips the scan but is still checked — a
    pinned placement over a masked cell is an error, not a silently
    misprogrammed chip.
    """
    masked = set(graph.masked_cells)

    def ok(r0, c0):
        return all((r, c) not in masked
                   for r in range(r0, r0 + m_cells)
                   for c in range(c0, c0 + m_cells))

    if origin is not None:
        r0, c0 = int(origin[0]), int(origin[1])
        if r0 < 0 or c0 < 0 or r0 + m_cells > graph.rows \
                or c0 + m_cells > graph.cols or not ok(r0, c0):
            raise ValueError(
                f"window origin {origin} cannot host {m_cells}x{m_cells} "
                f"unmasked cells on this {graph.rows}x{graph.cols} graph")
        return r0, c0
    for r0 in range(graph.rows - m_cells + 1):
        for c0 in range(graph.cols - m_cells + 1):
            if ok(r0, c0):
                return r0, c0
    raise ValueError(
        f"no {m_cells}x{m_cells} unmasked cell window on this "
        f"{graph.rows}x{graph.cols} Chimera (masked: {graph.masked_cells})"
        f" — the circuit needs a bigger graph")


# ---------------------------------------------------------------------------
# the embedder
# ---------------------------------------------------------------------------
def embed_circuit(logical: LogicalIsing, graph: ChimeraGraph, *,
                  chain_scale: float = 2.0,
                  origin: tuple[int, int] | None = None) -> ChainEmbedding:
    """Embed a synthesized `LogicalIsing` onto ``graph``; deterministic."""
    n, k = logical.n_spins, graph.k
    if n == 0:
        raise ValueError("cannot embed an empty circuit")
    m_cells = math.ceil(n / k)
    r0, c0 = find_window(graph, m_cells, origin)
    lut = graph.coord_lut()

    chains: list[tuple[int, ...]] = []
    chain_edges: list[tuple[int, int]] = []
    for i in range(n):
        b, u = divmod(i, k)
        vert = [int(lut[r0 + r, c0 + b, 0, u]) for r in range(m_cells)]
        horiz = [int(lut[r0 + b, c0 + c, 1, u]) for c in range(m_cells)]
        nodes = vert + horiz
        if any(v < 0 for v in nodes):
            raise ValueError(
                f"window ({r0},{c0}) lost nodes to masking mid-chain "
                f"(logical spin {i})")
        # junction node first: the corner cell's vertical node is the
        # bias site and the decoder's tie-breaker
        junction = vert[b]
        chain = [junction] + [x for x in nodes if x != junction]
        chains.append(tuple(chain))
        for r in range(m_cells - 1):       # vertical inter-cell ladder
            chain_edges.append((vert[r], vert[r + 1]))
        for c in range(m_cells - 1):       # horizontal inter-cell ladder
            chain_edges.append((horiz[c], horiz[c + 1]))
        chain_edges.append((vert[b], horiz[b]))  # in-cell junction

    eidx = graph.edge_index()

    def edge_row(a: int, b: int, what: str) -> int:
        key = (min(a, b), max(a, b))
        row = eidx.get(key)
        if row is None:
            raise ValueError(f"{what}: physical edge {key} not in graph")
        return row

    chain_edge_idx = np.asarray(
        [edge_row(a, b, "chain coupler") for a, b in chain_edges], np.int64)

    coupler_rows = []
    for (i, j) in np.asarray(logical.edges):
        bi, ui = divmod(int(i), k)
        bj, uj = divmod(int(j), k)
        a = int(lut[r0 + bi, c0 + bj, 1, ui])   # i's horizontal ladder
        b = int(lut[r0 + bi, c0 + bj, 0, uj])   # j's vertical ladder
        coupler_rows.append(edge_row(a, b, f"logical coupler ({i},{j})"))
    coupler_edge_idx = np.asarray(coupler_rows, np.int64)

    # -- code scaling ----------------------------------------------------
    max_j = logical.max_coupling
    max_h = float(np.abs(logical.h).max()) if logical.h.size else 0.0
    chain_strength = chain_scale * max_j if max_j > 0 else chain_scale
    top = max(chain_strength, max_j, max_h, 1e-12)
    code_unit = int(127.0 // top)
    if code_unit < 1:
        raise ValueError(
            f"logical weights too large for 8-bit codes: max scale {top} "
            f"> 127; rescale the circuit")

    J_codes = np.zeros(graph.n_edges, np.int32)
    J_codes[chain_edge_idx] = int(round(chain_strength * code_unit))
    J_codes[coupler_edge_idx] = np.round(
        logical.J * code_unit).astype(np.int32)
    h_codes = np.zeros(graph.n_nodes, np.int32)
    roots = np.asarray([ch[0] for ch in chains])
    h_codes[roots] = np.round(logical.h * code_unit).astype(np.int32)

    emb = ChainEmbedding(
        graph=graph, n_logical=n, window=(r0, c0, m_cells),
        chain_nodes=tuple(chains), chain_edge_idx=chain_edge_idx,
        coupler_edge_idx=coupler_edge_idx, chain_strength=chain_strength,
        code_unit=code_unit, J_codes=J_codes, h_codes=h_codes)
    validate_embedding(emb, logical)
    return emb


# ---------------------------------------------------------------------------
# validity checker (re-derives the invariants from scratch)
# ---------------------------------------------------------------------------
def validate_embedding(emb: ChainEmbedding, logical: LogicalIsing) -> None:
    """Raise ValueError unless the embedding is a true minor embedding:
    disjoint chains, each chain connected via graph edges, every logical
    coupler realized on a physical edge between the right two chains."""
    g = emb.graph
    # 1. no physical spin serves two logical spins
    flat = [x for ch in emb.chain_nodes for x in ch]
    if len(flat) != len(set(flat)):
        raise ValueError("embedding reuses physical spins across chains")
    if min(flat) < 0 or max(flat) >= g.n_nodes:
        raise ValueError("embedding references nodes outside the graph")

    # adjacency restricted to the ferromagnetic chain couplers
    owner = emb.node_to_logical()
    ce = g.edges[emb.chain_edge_idx]
    for i, ch in enumerate(emb.chain_nodes):
        members = set(ch)
        adj: dict[int, list[int]] = {x: [] for x in ch}
        for a, b in ce:
            a, b = int(a), int(b)
            if a in members and b in members:
                adj[a].append(b)
                adj[b].append(a)
        # BFS over the chain's own couplers
        seen = {ch[0]}
        frontier = [ch[0]]
        while frontier:
            x = frontier.pop()
            for y in adj[x]:
                if y not in seen:
                    seen.add(y)
                    frontier.append(y)
        if seen != members:
            raise ValueError(
                f"chain {i} is not connected through ferromagnetic "
                f"couplers ({len(seen)}/{len(members)} reachable)")
        if any(owner[int(a)] == i and owner[int(b)] != i
               or owner[int(b)] == i and owner[int(a)] != i
               for a, b in ce):
            raise ValueError(
                f"a chain coupler of chain {i} leaves the chain")

    # 2. every logical coupler lands on an edge joining the right chains
    if emb.coupler_edge_idx.shape[0] != logical.n_edges:
        raise ValueError(
            f"{logical.n_edges} logical couplers but "
            f"{emb.coupler_edge_idx.shape[0]} realized")
    pe = g.edges[emb.coupler_edge_idx]
    for (li, lj), (a, b) in zip(np.asarray(logical.edges), pe):
        got = {int(owner[int(a)]), int(owner[int(b)])}
        if got != {int(li), int(lj)}:
            raise ValueError(
                f"logical coupler ({li},{lj}) realized on physical edge "
                f"({a},{b}) owned by chains {sorted(got)}")

    # 3. code arrays are consistent with the edge roles
    overlap = set(emb.chain_edge_idx.tolist()) \
        & set(emb.coupler_edge_idx.tolist())
    if overlap:
        raise ValueError(
            f"edges {sorted(overlap)} serve as both chain and logical "
            f"couplers")
