"""Probabilistic spin logic: netlists -> Chimera-embedded SamplerSpecs.

The compiler stack (docs/psl.md):

* `psl.circuit` — `PCircuit` builder + frozen `LogicalIsing` IR;
* `psl.gates` — verified gate Hamiltonians (COPY/NOT/AND/OR/XOR,
  half/full adder) and composed modules (ripple adder, multiplier);
* `psl.embed` — deterministic clique-ladder minor embedding onto any
  masked `ChimeraGraph`, chain-strength auto-scaling, validity checks;
* `psl.compile` — `compile_circuit` / `PCircuit.to_spec` emitting an
  `api.SamplerSpec` run by an unmodified `api.Session`;
* `psl.readout` — chain-majority decoding with broken-chain stats.
"""
from repro.psl.circuit import Clause, LogicalIsing, PCircuit
from repro.psl.compile import CompiledCircuit, compile_circuit
from repro.psl.embed import ChainEmbedding, embed_circuit, validate_embedding
from repro.psl.gates import (
    and_circuit,
    and_gate,
    copy_circuit,
    copy_gate,
    full_adder,
    full_adder_circuit,
    half_adder,
    multiplier,
    multiplier_circuit,
    not_circuit,
    not_gate,
    or_circuit,
    or_gate,
    ripple_adder,
    ripple_adder_circuit,
    xor_circuit,
    xor_gate,
)
from repro.psl.readout import (
    Readout,
    bits_to_int,
    clamp_arrays,
    decode_result,
    decode_states,
    int_to_spins,
)

__all__ = [
    "Clause",
    "LogicalIsing",
    "PCircuit",
    "CompiledCircuit",
    "compile_circuit",
    "ChainEmbedding",
    "embed_circuit",
    "validate_embedding",
    "and_circuit",
    "and_gate",
    "copy_circuit",
    "copy_gate",
    "full_adder",
    "full_adder_circuit",
    "half_adder",
    "multiplier",
    "multiplier_circuit",
    "not_circuit",
    "not_gate",
    "or_circuit",
    "or_gate",
    "ripple_adder",
    "ripple_adder_circuit",
    "xor_circuit",
    "xor_gate",
    "Readout",
    "bits_to_int",
    "clamp_arrays",
    "decode_result",
    "decode_states",
    "int_to_spins",
]
