"""Chain-majority readout: physical samples -> logical bit-strings.

The inverse of the embedding pass.  A sampled physical state assigns
±1 to every node of every chain; a healthy chain is unanimous, a
*broken* chain (thermal excitation beat the ferromagnetic chain
couplers) is not.  The decoder takes the majority vote per chain —
ties (possible: chains have even length 2M) go to the junction node,
which is chain_nodes[i][0] by the embedder's construction and also the
bias site, so the tie-breaker is the one physical spin that feels h
directly.

Decoding is pure NumPy on host-side sample arrays — it runs after
sampling, on any leading batch shape (chains, sweeps × chains, ...).
Broken-chain statistics ride along: they are the embedding-quality
signal (chain strength too low ⇒ broken fraction up ⇒ logical error
rate up) that the bench tracks and tests assert on.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

import numpy as np

from repro.psl.circuit import LogicalIsing
from repro.psl.embed import ChainEmbedding


def bits_to_int(bits: np.ndarray) -> np.ndarray:
    """(..., nbits) ±1 spins, LSB-first -> (...) integers."""
    bits = np.asarray(bits)
    weights = 1 << np.arange(bits.shape[-1], dtype=np.int64)
    return ((bits > 0).astype(np.int64) * weights).sum(axis=-1)


def int_to_spins(value: int, nbits: int) -> np.ndarray:
    """Integer -> (nbits,) ±1 spins, LSB-first."""
    if not 0 <= value < (1 << nbits):
        raise ValueError(f"{value} does not fit in {nbits} bits")
    return np.asarray([1 if (value >> i) & 1 else -1
                       for i in range(nbits)], np.int8)


def decode_states(emb: ChainEmbedding, states: np.ndarray
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Physical (..., N_graph) ±1 states -> logical (..., n_logical).

    Returns ``(logical, broken)``: majority-voted ±1 logical spins
    (ties resolved by the junction node) and a same-shaped bool mask of
    chains that were not unanimous.
    """
    states = np.asarray(states)
    idx = emb.chain_index()                       # (L, C)
    member = states[..., idx]                     # (..., L, C)
    vote = member.sum(axis=-1)
    junction = member[..., 0]
    logical = np.where(vote > 0, 1, np.where(vote < 0, -1, junction))
    broken = np.abs(vote) != idx.shape[1]
    return logical.astype(np.int8), broken


@dataclasses.dataclass(frozen=True)
class Readout:
    """Decoded samples of one compiled circuit.

    ``logical``/``broken`` are (n_samples, n_logical); port accessors
    convert named LSB-first bit groups to integers per sample.
    """

    logical_model: LogicalIsing
    logical: np.ndarray
    broken: np.ndarray

    @property
    def n_samples(self) -> int:
        return int(self.logical.shape[0])

    @property
    def broken_chain_fraction(self) -> float:
        """Fraction of (sample, chain) readouts with a broken chain."""
        return float(self.broken.mean()) if self.broken.size else 0.0

    def broken_per_chain(self) -> np.ndarray:
        """(n_logical,) broken fraction per chain — the weak-link map."""
        return self.broken.mean(axis=0)

    def port_values(self, name: str) -> np.ndarray:
        """(n_samples,) integers read from one named port."""
        ids = list(self.logical_model.port(name))
        return bits_to_int(self.logical[:, ids])

    def port_counts(self, name: str) -> dict[int, int]:
        vals, counts = np.unique(self.port_values(name), return_counts=True)
        return {int(v): int(c) for v, c in zip(vals, counts)}

    def port_mode(self, name: str) -> int:
        """Most frequent value on a port (the inference answer)."""
        counts = self.port_counts(name)
        return max(counts, key=lambda v: (counts[v], -v))

    def valid_mask(self) -> np.ndarray:
        """(n_samples,) bool: sample satisfies every circuit clause."""
        return np.asarray([self.logical_model.satisfied(row)
                           for row in self.logical])

    def infer(self, name: str) -> int:
        """Clause-filtered majority readout — the inference contract.

        Majority vote over the samples that satisfy every circuit
        clause; falls back to the raw majority when no sample is fully
        consistent.  The filter is what makes inference robust: an
        annealed chain can freeze into a metastable clause-violating
        state (measured on the full adder: raw mode 3–7/8 rows
        depending on the schedule, filtered 8/8 across every schedule
        tried), but conditioned on clause consistency the clamped
        problem has a unique forward answer.
        """
        valid = self.valid_mask()
        vals = self.port_values(name)
        if valid.any():
            vals = vals[valid]
        counts: dict[int, int] = {}
        for v in vals:
            counts[int(v)] = counts.get(int(v), 0) + 1
        return max(counts, key=lambda v: (counts[v], -v))

    def joint_counts(self, names: list[str]) -> dict[tuple[int, ...], int]:
        """Histogram over tuples of port values — e.g. (a, b) factor
        pairs in inverse mode.  Counts every sample, valid or not."""
        cols = np.stack([self.port_values(n) for n in names], axis=-1)
        out: dict[tuple[int, ...], int] = {}
        for row in cols:
            key = tuple(int(v) for v in row)
            out[key] = out.get(key, 0) + 1
        return out

    def summary(self) -> dict:
        valid = self.valid_mask()
        return {
            "n_samples": self.n_samples,
            "broken_chain_fraction": self.broken_chain_fraction,
            "clause_valid_fraction": float(valid.mean()),
        }


def decode_result(logical_model: LogicalIsing, emb: ChainEmbedding,
                  states: np.ndarray) -> Readout:
    """Decode (..., N_graph) sampled states into a flat `Readout`."""
    states = np.asarray(states)
    logical, broken = decode_states(emb, states)
    return Readout(
        logical_model=logical_model,
        logical=logical.reshape(-1, emb.n_logical),
        broken=broken.reshape(-1, emb.n_logical))


def clamp_arrays(emb: ChainEmbedding, logical_model: LogicalIsing,
                 assignments: Mapping[str, int], n_chains: int
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Port assignments -> (clamp_mask (N,), clamp_values (B, N)).

    Clamping a logical spin pins its *entire chain* to the value — the
    chain is one logical variable, and a partially clamped chain would
    fight its own ferromagnetic couplers.  Exactly the Session.sample
    clamp contract (the CD positive phase uses the same arrays).
    """
    n = emb.graph.n_nodes
    mask = np.zeros(n, bool)
    values = np.zeros(n, np.float32)
    for port, value in assignments.items():
        ids = logical_model.port(port)
        spins = int_to_spins(int(value), len(ids))
        for spin_id, s in zip(ids, spins):
            for node in emb.chain_nodes[spin_id]:
                mask[node] = True
                values[node] = float(s)
    return mask, np.broadcast_to(values, (n_chains, n)).copy()
