"""Compile PSL circuits into Session-ready `api.SamplerSpec`s.

`compile_circuit(circuit, graph)` is the top of the stack: synthesize
the logical Hamiltonian (psl/circuit.py), minor-embed it (psl/embed.py),
and wrap the result in a frozen `CompiledCircuit` holding the
`api.SamplerSpec` plus everything needed to program, clamp, and decode.
`PCircuit.to_spec(graph)` is sugar for ``compile_circuit(...).spec``.

Execution goes through an *unmodified* `api.Session`:

* programming — `Session.program_edges(emb.J_codes, emb.h_codes)`:
  the embedder's code arrays already align with ``graph.edges``;
* forward mode — clamp the input ports' chains (`run_forward`), anneal,
  majority-decode the outputs;
* inverse mode — clamp the output ports' chains (`run_inverse`) and
  read the *input* distributions: the Hamiltonian has no direction, so
  a multiplier becomes a factorizer by swapping which ports are pinned.

Defaults are chosen for exactness-of-representation first: an ideal
`HardwareConfig` (the compiled Hamiltonian *is* the logical one up to
the integer code scale), a zero-sigma `SparseMismatch` (O(D·N), so
specs default to the sparse backends that scale), ``w_scale = 1 /
code_unit`` so one logical-J unit is exactly 1.0 in neuron-input units
(betas therefore mean the same thing for every circuit regardless of
quantization), and a geometric anneal that ends cold enough to freeze
the ground state.  Every default can be overridden per call — mismatch
and hardware models pass straight through to the spec, so a compiled
circuit can also be run on a *non-ideal* virtual chip.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import numpy as np

from repro.core.chimera import ChimeraGraph
from repro.core.hardware import (
    HardwareConfig,
    Mismatch,
    SparseMismatch,
    sample_mismatch,
    sample_mismatch_sparse,
)
from repro.psl.circuit import LogicalIsing, PCircuit
from repro.psl.embed import ChainEmbedding, embed_circuit
from repro.psl.readout import Readout, clamp_arrays, decode_result

DEFAULT_SWEEPS = 300
DEFAULT_CHAINS = 64
DEFAULT_BETA_START = 0.1
DEFAULT_BETA_END = 2.5


def _default_mismatch(graph: ChimeraGraph, hw: HardwareConfig,
                      dense: bool, key):
    """Zero-key mismatch draw (deterministic); ideal hw ⇒ all-zero
    sigmas, so the draw is exactly the textbook chip."""
    import jax

    key = jax.random.PRNGKey(0) if key is None else key
    if dense:
        return sample_mismatch(key, graph.n_nodes, hw)
    nbr_idx, _ = graph.neighbor_table()
    return sample_mismatch_sparse(key, graph.n_nodes, nbr_idx.shape[0], hw)


@dataclasses.dataclass(frozen=True)
class CompiledCircuit:
    """A PSL circuit compiled onto one graph: spec + embedding + decode.

    Frozen value object; the lazily-built `api.Session` and programmed
    chip are cached out-of-band (they are jax state, not part of the
    circuit's identity).
    """

    name: str
    logical: LogicalIsing
    embedding: ChainEmbedding
    spec: Any  # api.SamplerSpec

    def __post_init__(self):
        object.__setattr__(self, "_cache", {})

    # -- execution helpers ----------------------------------------------
    def session(self):
        """The compiled `api.Session` (built once, cached)."""
        if "session" not in self._cache:
            from repro import api
            self._cache["session"] = api.Session(self.spec)
        return self._cache["session"]

    def chip(self):
        """The programmed `EffectiveChip` (built once, cached)."""
        if "chip" not in self._cache:
            self._cache["chip"] = self.session().program_edges(
                self.embedding.J_codes, self.embedding.h_codes)
        return self._cache["chip"]

    def clamp(self, assignments: Mapping[str, int]
              ) -> tuple[np.ndarray, np.ndarray]:
        """Port assignments -> Session clamp arrays (whole chains)."""
        return clamp_arrays(self.embedding, self.logical, assignments,
                            self.spec.chains)

    def run(self, key, assignments: Mapping[str, int] | None = None,
            betas=None) -> Readout:
        """Anneal once and decode the final states of every Gibbs chain.

        ``assignments`` maps port names to integer values; named ports'
        chains are clamped, everything else free-runs.  Forward logic
        clamps inputs, inverse logic clamps outputs — the sampler does
        not know the difference.
        """
        import jax
        import jax.numpy as jnp

        session = self.session()
        chip = self.chip()
        k1, k2 = jax.random.split(key)
        m0 = session.random_spins(k1)
        ns = session.noise_state(k2)
        if assignments:
            cm, cv = self.clamp(assignments)
            m, _, _ = session.sample(chip, m0, ns, betas,
                                     clamp_mask=jnp.asarray(cm),
                                     clamp_values=jnp.asarray(cv))
        else:
            m, _, _ = session.sample(chip, m0, ns, betas)
        return decode_result(self.logical, self.embedding, np.asarray(m))

    def run_forward(self, key, inputs: Mapping[str, int] | None = None,
                    betas=None) -> Readout:
        """Clamp every declared input port (values required for all)."""
        inputs = dict(inputs or {})
        missing = [p for p in self.logical.inputs if p not in inputs]
        if missing:
            raise ValueError(
                f"forward run needs every input port; missing {missing}")
        return self.run(key, inputs, betas)

    def run_inverse(self, key, outputs: Mapping[str, int] | None = None,
                    betas=None) -> Readout:
        """Clamp every declared output port — invertible-logic mode."""
        outputs = dict(outputs or {})
        missing = [p for p in self.logical.outputs if p not in outputs]
        if missing:
            raise ValueError(
                f"inverse run needs every output port; missing {missing}")
        return self.run(key, outputs, betas)


def compile_circuit(
    circuit: PCircuit | LogicalIsing,
    graph: ChimeraGraph,
    *,
    chain_scale: float = 2.0,
    origin: tuple[int, int] | None = None,
    backend: str = "auto",
    noise: str = "counter",
    chains: int = DEFAULT_CHAINS,
    n_sweeps: int = DEFAULT_SWEEPS,
    beta_start: float = DEFAULT_BETA_START,
    beta_end: float = DEFAULT_BETA_END,
    schedule=None,
    hw: HardwareConfig | None = None,
    mismatch: Mismatch | SparseMismatch | None = None,
    mismatch_key=None,
    interpret: bool | None = None,
    w_scale: float | None = None,
) -> CompiledCircuit:
    """Netlist -> Chimera-embedded `CompiledCircuit` (see module doc).

    ``backend="ref"`` (or any dense backend) switches the default
    mismatch to the dense model, since a sparse-native spec rejects
    dense backends by construction.  ``schedule`` overrides the default
    geometric `api.Anneal`; ``w_scale`` overrides the exact
    1/code_unit logical-unit scale.
    """
    from repro import api

    name = getattr(circuit, "name", "pcircuit")
    logical = circuit.synthesize() if isinstance(circuit, PCircuit) \
        else circuit
    emb = embed_circuit(logical, graph, chain_scale=chain_scale,
                        origin=origin)

    hw = HardwareConfig.ideal() if hw is None else hw
    if mismatch is None:
        dense = backend in ("ref", "pallas", "fused")
        mismatch = _default_mismatch(graph, hw, dense, mismatch_key)
    if schedule is None:
        schedule = api.Anneal(beta_start, beta_end, n_sweeps=n_sweeps)
    if w_scale is None:
        # one logical-J unit == 1.0 neuron-input unit, exactly: betas
        # are in logical-energy units for every circuit
        w_scale = 1.0 / emb.code_unit
    spec = api.SamplerSpec(
        graph=graph, hw=hw, mismatch=mismatch, noise=noise,
        backend=backend, schedule=schedule, chains=chains,
        beta=beta_end, w_scale=w_scale, interpret=interpret)
    return CompiledCircuit(name=name, logical=logical, embedding=emb,
                           spec=spec)
