"""PSL gate library: invertible Boolean gates as Ising ground-state sets.

Every gate here is a small (J, h) whose *degenerate ground states* are
exactly the gate's valid truth-table rows under the repo's energy
convention (core/energy.py):

    E(m) = -1/2 sum_ij J_ij m_i m_j - sum_i h_i m_i

The constants were solved as a linear program (pin valid rows to a
common E0, force invalid rows >= E0 + gap, symmetric in the commutative
inputs) and verified by exhaustive enumeration — tests/test_psl.py
re-derives the ground sets from scratch for every gate.  Gaps: COPY/NOT
2, AND/OR 4, half adder 2, full adder 2 (all in logical-J units).

Gate functions take the target `PCircuit` plus input spin ids, allocate
output/ancilla spins, superpose their (J, h) clause, and return the
output ids — so `ripple_adder` and `multiplier` are nothing but plain
Python composition over shared spins.  Bit vectors are LSB-first
everywhere.  XOR is the one gate needing an ancilla: 3-spin parity has
no pairwise Ising realization, so it is a half adder whose carry is
left free.
"""
from __future__ import annotations

from repro.psl.circuit import PCircuit

# ---------------------------------------------------------------------------
# truth tables (±1 rows, spin order as in each gate's docstring)
# ---------------------------------------------------------------------------
def _rows(n_in, fn):
    out = []
    for code in range(2 ** n_in):
        bits = [(code >> i) & 1 for i in range(n_in)]
        row = bits + list(fn(*bits))
        out.append(tuple(2 * b - 1 for b in row))
    return tuple(out)


COPY_TABLE = _rows(1, lambda a: (a,))
NOT_TABLE = _rows(1, lambda a: (1 - a,))
AND_TABLE = _rows(2, lambda a, b: (a & b,))
OR_TABLE = _rows(2, lambda a, b: (a | b,))
XOR_TABLE = _rows(2, lambda a, b: (a ^ b,))
HALF_ADDER_TABLE = _rows(2, lambda a, b: (a ^ b, a & b))
FULL_ADDER_TABLE = _rows(
    3, lambda a, b, c: ((a + b + c) & 1, (a + b + c) >> 1))


# ---------------------------------------------------------------------------
# primitive gates
# ---------------------------------------------------------------------------
def copy_gate(c: PCircuit, a: int, y: int | None = None) -> int:
    """Y = A: one ferromagnetic bond (J = +1, gap 2)."""
    y = c.spin() if y is None else y
    c.add_coupling(a, y, 1.0)
    c.add_clause("COPY", (a, y), COPY_TABLE)
    return y


def not_gate(c: PCircuit, a: int, y: int | None = None) -> int:
    """Y = ¬A: one antiferromagnetic bond (J = -1, gap 2)."""
    y = c.spin() if y is None else y
    c.add_coupling(a, y, -1.0)
    c.add_clause("NOT", (a, y), NOT_TABLE)
    return y


def and_gate(c: PCircuit, a: int, b: int, y: int | None = None) -> int:
    """Y = A∧B.  J = (AB: -1, AY: 2, BY: 2), h = (1, 1, -2); gap 4."""
    y = c.spin() if y is None else y
    c.add_coupling(a, b, -1.0)
    c.add_coupling(a, y, 2.0)
    c.add_coupling(b, y, 2.0)
    c.add_bias(a, 1.0)
    c.add_bias(b, 1.0)
    c.add_bias(y, -2.0)
    c.add_clause("AND", (a, b, y), AND_TABLE)
    return y


def or_gate(c: PCircuit, a: int, b: int, y: int | None = None) -> int:
    """Y = A∨B: the AND gate with all biases negated (De Morgan); gap 4."""
    y = c.spin() if y is None else y
    c.add_coupling(a, b, -1.0)
    c.add_coupling(a, y, 2.0)
    c.add_coupling(b, y, 2.0)
    c.add_bias(a, -1.0)
    c.add_bias(b, -1.0)
    c.add_bias(y, 2.0)
    c.add_clause("OR", (a, b, y), OR_TABLE)
    return y


def half_adder(c: PCircuit, a: int, b: int,
               s: int | None = None, cy: int | None = None
               ) -> tuple[int, int]:
    """(S, C) = (A⊕B, A∧B).

    J = (AB: -1, AS: 1, BS: 1, AC: 2, BC: 2, SC: -2),
    h = (A: 1, B: 1, S: -1, C: -2); gap 2.
    """
    s = c.spin() if s is None else s
    cy = c.spin() if cy is None else cy
    c.add_coupling(a, b, -1.0)
    c.add_coupling(a, s, 1.0)
    c.add_coupling(b, s, 1.0)
    c.add_coupling(a, cy, 2.0)
    c.add_coupling(b, cy, 2.0)
    c.add_coupling(s, cy, -2.0)
    c.add_bias(a, 1.0)
    c.add_bias(b, 1.0)
    c.add_bias(s, -1.0)
    c.add_bias(cy, -2.0)
    c.add_clause("HALF_ADDER", (a, b, s, cy), HALF_ADDER_TABLE)
    return s, cy


def xor_gate(c: PCircuit, a: int, b: int, y: int | None = None) -> int:
    """Y = A⊕B.  Pairwise Ising cannot express 3-spin parity (its valid
    rows are not linearly separable from the invalid ones in the
    (m_im_j, m_i) feature space), so XOR is a half adder whose carry
    ancilla is left free — the clause recorded is still pure XOR."""
    y = c.spin() if y is None else y
    half_adder(c, a, b, s=y)
    c.add_clause("XOR", (a, b, y), XOR_TABLE)
    return y


def full_adder(c: PCircuit, a: int, b: int, cin: int,
               s: int | None = None, cout: int | None = None
               ) -> tuple[int, int]:
    """(S, Cout) = A + B + Cin.

    Zero-bias, input-symmetric solution (the valid-row set is closed
    under global spin flip, so h = 0): J(input, input) = -3,
    J(input, S) = 3, J(input, Cout) = 4, J(S, Cout) = -4; gap 2.
    """
    s = c.spin() if s is None else s
    cout = c.spin() if cout is None else cout
    ins = (a, b, cin)
    for i in range(3):
        for j in range(i + 1, 3):
            c.add_coupling(ins[i], ins[j], -3.0)
    for x in ins:
        c.add_coupling(x, s, 3.0)
        c.add_coupling(x, cout, 4.0)
    c.add_coupling(s, cout, -4.0)
    c.add_clause("FULL_ADDER", (a, b, cin, s, cout), FULL_ADDER_TABLE)
    return s, cout


# ---------------------------------------------------------------------------
# composed modules (plain Python over shared spins)
# ---------------------------------------------------------------------------
def ripple_adder(c: PCircuit, a_bits, b_bits, cin: int | None = None
                 ) -> tuple[list[int], int]:
    """n-bit ripple-carry adder: (sum_bits, carry_out), LSB-first.

    Stage 0 is a half adder unless a carry-in spin is supplied.
    """
    if len(a_bits) != len(b_bits):
        raise ValueError(
            f"addend widths differ: {len(a_bits)} vs {len(b_bits)}")
    s_bits: list[int] = []
    carry = cin
    for a, b in zip(a_bits, b_bits):
        if carry is None:
            s, carry = half_adder(c, a, b)
        else:
            s, carry = full_adder(c, a, b, carry)
        s_bits.append(s)
    return s_bits, carry


def multiplier(c: PCircuit, a_bits, b_bits) -> list[int]:
    """Array multiplier: AND partial products + column carry-save
    reduction with half/full adders.  Returns the (na+nb)-bit product,
    LSB-first.  Run in reverse — product clamped, factor chains free —
    this is the chip's factorization demo.
    """
    na, nb = len(a_bits), len(b_bits)
    cols: list[list[int]] = [[] for _ in range(na + nb)]
    for i, a in enumerate(a_bits):
        for j, b in enumerate(b_bits):
            cols[i + j].append(and_gate(c, a, b))
    for col in range(len(cols)):
        while len(cols[col]) > 1:
            if col + 1 >= len(cols):
                cols.append([])
            if len(cols[col]) >= 3:
                x, y, z = cols[col][:3]
                del cols[col][:3]
                s, cy = full_adder(c, x, y, z)
            else:
                x, y = cols[col][:2]
                del cols[col][:2]
                s, cy = half_adder(c, x, y)
            cols[col].append(s)
            cols[col + 1].append(cy)
    prod = [col[0] for col in cols[:na + nb] if col]
    assert len(prod) == na + nb and all(
        len(col) == 0 for col in cols[na + nb:]), \
        "column reduction overflowed the product width"
    return prod


# ---------------------------------------------------------------------------
# ready-made circuits (ports declared, LSB-first)
# ---------------------------------------------------------------------------
def _gate_circuit(name: str, gate_fn, n_in: int = 2) -> PCircuit:
    c = PCircuit(name)
    ins = [c.spin(chr(ord("a") + i)) for i in range(n_in)]
    y = gate_fn(c, *ins)
    for i, s in enumerate(ins):
        c.mark_input(chr(ord("a") + i), s)
    c.mark_output("y", y)
    return c


def copy_circuit() -> PCircuit:
    return _gate_circuit("copy", copy_gate, n_in=1)


def not_circuit() -> PCircuit:
    return _gate_circuit("not", not_gate, n_in=1)


def and_circuit() -> PCircuit:
    return _gate_circuit("and", and_gate)


def or_circuit() -> PCircuit:
    return _gate_circuit("or", or_gate)


def xor_circuit() -> PCircuit:
    return _gate_circuit("xor", xor_gate)


def full_adder_circuit() -> PCircuit:
    """Ports: a, b, cin (inputs) -> s, cout (outputs), 1 bit each."""
    c = PCircuit("full_adder")
    a, b, cin = c.spin("a"), c.spin("b"), c.spin("cin")
    s, cout = full_adder(c, a, b, cin)
    c.mark_input("a", a)
    c.mark_input("b", b)
    c.mark_input("cin", cin)
    c.mark_output("s", s)
    c.mark_output("cout", cout)
    return c


def ripple_adder_circuit(n: int, with_cin: bool = False) -> PCircuit:
    """n-bit adder.  Ports: a, b (n bits), optional cin (1 bit) ->
    sum (n bits), cout (1 bit)."""
    c = PCircuit(f"adder{n}")
    a = c.spins("a", n)
    b = c.spins("b", n)
    cin = c.spin("cin") if with_cin else None
    s_bits, cout = ripple_adder(c, a, b, cin)
    c.mark_input("a", a)
    c.mark_input("b", b)
    if with_cin:
        c.mark_input("cin", cin)
    c.mark_output("sum", s_bits)
    c.mark_output("cout", cout)
    return c


def multiplier_circuit(n: int) -> PCircuit:
    """n×n-bit multiplier.  Ports: a, b (n bits) -> prod (2n bits).
    Clamp prod and read a/b for factorization."""
    c = PCircuit(f"mult{n}")
    a = c.spins("a", n)
    b = c.spins("b", n)
    prod = multiplier(c, a, b)
    c.mark_input("a", a)
    c.mark_input("b", b)
    c.mark_output("prod", prod)
    return c
