"""Atomic, async, mesh-agnostic checkpointing.

Format: one directory per step —
    step_000123/
      meta.json            (step, flat key list, shapes/dtypes, extra)
      arrays.npz           (flattened pytree, logically-global arrays)
      .complete            (commit marker; written LAST)

Writes go to ``<dir>.tmp`` then os.replace -> atomic; readers only trust
directories with the commit marker, so a killed writer never corrupts the
latest checkpoint (crash-consistency is tested by killing mid-write in
tests/test_checkpoint.py).

Checkpoints are *mesh-agnostic*: arrays are saved as logical (unsharded)
values and restored under whatever sharding the new mesh dictates — the
elastic-rescale path (runtime/elastic.py) is just load() + device_put.

`AsyncCheckpointer` overlaps serialization with the next train step
(one-deep queue, matching typical at-scale checkpoint cadence).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

_MARKER = ".complete"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":   # npz can't store ml_dtypes
            arr = arr.view(np.uint16)
            out["__bf16__" + jax.tree_util.keystr(path)] = arr
        else:
            out[jax.tree_util.keystr(path)] = arr
    return out


def _unflatten_arrays(arrays: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    import ml_dtypes
    out = {}
    for k, v in arrays.items():
        if k.startswith("__bf16__"):
            out[k[len("__bf16__"):]] = v.view(ml_dtypes.bfloat16)
        else:
            out[k] = v
    return out


def save(directory: str | Path, step: int, tree: Any,
         extra: Optional[dict] = None) -> Path:
    """Blocking atomic save. Returns the committed checkpoint path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:09d}"
    tmp = directory / f"step_{step:09d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    arrays = _flatten(tree)
    np.savez(tmp / "arrays.npz", **arrays)
    meta = {
        "step": step,
        "keys": list(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "extra": extra or {},
    }
    (tmp / "meta.json").write_text(json.dumps(meta))
    (tmp / _MARKER).touch()
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.iterdir()
             if p.name.startswith("step_") and not p.name.endswith(".tmp")
             and (p / _MARKER).exists()]
    return max(steps) if steps else None


def load(directory: str | Path, step: Optional[int] = None,
         target: Any = None) -> tuple[int, Any, dict]:
    """Load (step, tree, extra). With `target`, restores pytree structure
    (and device_puts onto target's shardings if it holds concrete arrays)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {directory}")
    path = directory / f"step_{step:09d}"
    if not (path / _MARKER).exists():
        raise FileNotFoundError(f"checkpoint {path} incomplete")
    meta = json.loads((path / "meta.json").read_text())
    arrays = _unflatten_arrays(dict(np.load(path / "arrays.npz")))
    if target is None:
        return step, arrays, meta["extra"]
    flat = jax.tree_util.tree_flatten_with_path(target)
    leaves, treedef = flat
    out = []
    for p, leaf in leaves:
        key = jax.tree_util.keystr(p)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        val = arrays[key]
        if hasattr(leaf, "sharding") and hasattr(leaf, "shape"):
            val = jax.device_put(val.astype(leaf.dtype), leaf.sharding)
        out.append(val)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return step, tree, meta["extra"]


def gc_old(directory: str | Path, keep: int = 3) -> None:
    directory = Path(directory)
    if not directory.exists():
        return
    steps = sorted(
        p for p in directory.iterdir()
        if p.name.startswith("step_") and (p / _MARKER).exists())
    for p in steps[:-keep]:
        shutil.rmtree(p)


class AsyncCheckpointer:
    """One-deep background writer: save() returns immediately; a second
    save blocks until the first commit finishes (bounded staleness)."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        # snapshot to host before returning control to the train loop
        host_tree = jax.tree.map(np.asarray, tree)

        def _run():
            try:
                save(self.directory, step, host_tree, extra)
                gc_old(self.directory, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
