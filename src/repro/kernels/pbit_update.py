"""Pallas TPU kernel: fused chromatic-Gibbs half-sweep (paper eqns 1+2).

One half-sweep is  m_c <- sgn( tanh(beta*g*(m @ W_c^T + h + o)) + rg*u + co )
for one color class.  On the chip this is a single analog settle; on TPU we
fuse the synapse matmul (MXU), the neuron nonlinearity (VPU) and the
comparator into one kernel so the (B, N) neuron currents never round-trip
through HBM.

Tiling: grid (B/tb, N/tn, N/tk) with a float32 VMEM accumulator; the K loop
(contraction over source spins) is the innermost, sequential grid dim.  All
tiles are MXU-aligned (multiples of 8x128 lanes; defaults 128/128/512).
Beta enters as a (B, 1) column so every chain can run its own inverse
temperature (parallel-tempering replicas) with no SMEM scalar plumbing;
scalars are broadcast to the column outside the kernel.

Validated in interpret mode against kernels/ref.py over shape/dtype sweeps
(tests/test_kernels.py); the on-silicon path is the same code with
interpret=False.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.util import pad_axis as _pad_to

try:  # compiler params class moved across jax versions
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
    _COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                               getattr(pltpu, "TPUCompilerParams", None))
except ImportError:  # pragma: no cover
    pltpu = None
    _VMEM = None
    _COMPILER_PARAMS = None


def _kernel(m_k_ref, w_ref, m_io_ref, h_ref, gain_ref, off_ref,
            rg_ref, co_ref, mask_ref, u_ref, beta_ref, out_ref, acc_ref,
            *, n_k: int):
    """Grid: (i: batch tiles, j: node tiles, k: contraction tiles)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # synapse: partial current I[b, jtile] += m[b, ktile] @ W[jtile, ktile]^T
    acc_ref[...] += jax.lax.dot_general(
        m_k_ref[...], w_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _neuron():
        I = acc_ref[...] + h_ref[...]                      # (tb, tn)
        # beta is a per-chain column (tempering replicas run one beta each);
        # (tb, 1) * (1, tn) broadcasts to the tile
        act = jnp.tanh(beta_ref[...] * gain_ref[...] * (I + off_ref[...]))
        decision = act + rg_ref[...] * u_ref[...] + co_ref[...]
        new = jnp.where(decision >= 0.0, 1.0, -1.0)
        keep = mask_ref[...] != 0
        out_ref[...] = jnp.where(
            keep, new, m_io_ref[...].astype(jnp.float32)
        ).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_n", "block_k", "interpret"),
)
def pbit_half_sweep_pallas(
    m: jax.Array,
    W: jax.Array,
    h: jax.Array,
    gain: jax.Array,
    off: jax.Array,
    rand_gain: jax.Array,
    comp_off: jax.Array,
    update_mask: jax.Array,
    beta: jax.Array,
    u: jax.Array,
    *,
    block_b: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Fused half-sweep.  Shapes/semantics identical to kernels/ref.py.

    Pads B to block_b and N to lcm-ish(block_n, block_k) multiples;
    zero-padded source spins contribute nothing to the matmul, and padded
    output nodes are masked off and sliced away.  ``beta`` may be a scalar
    or a (B,) per-chain vector (parallel-tempering replicas).
    """
    B, N = m.shape
    out_dtype = m.dtype
    nmult = max(block_n, block_k)

    beta_col = jnp.broadcast_to(
        jnp.asarray(beta, jnp.float32).reshape(-1, 1), (B, 1))
    bp = _pad_to(beta_col, block_b, 0)
    mp = _pad_to(_pad_to(m, block_b, 0), nmult, 1)
    Wp = _pad_to(_pad_to(W, nmult, 0), nmult, 1)
    up = _pad_to(_pad_to(u, block_b, 0), nmult, 1)
    row = lambda x, v=0.0: _pad_to(x.reshape(1, -1).astype(jnp.float32),
                                   nmult, 1, v)
    hp, gp, op_, rgp, cop = (row(x) for x in
                             (h, gain, off, rand_gain, comp_off))
    maskp = _pad_to(update_mask.reshape(1, -1).astype(jnp.int8), nmult, 1, 0)

    Bp, Np = mp.shape
    n_b, n_n, n_k = Bp // block_b, Np // block_n, Np // block_k

    vec = lambda: pl.BlockSpec((1, block_n), lambda i, j, k: (0, j))
    grid = (n_b, n_n, n_k)
    in_specs = [
            pl.BlockSpec((block_b, block_k), lambda i, j, k: (i, k)),  # m (matmul)
            pl.BlockSpec((block_n, block_k), lambda i, j, k: (j, k)),  # W
            pl.BlockSpec((block_b, block_n), lambda i, j, k: (i, j)),  # m (carry)
            vec(), vec(), vec(), vec(), vec(),                         # h,g,off,rg,co
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),        # mask (int8)
            pl.BlockSpec((block_b, block_n), lambda i, j, k: (i, j)),  # u
            pl.BlockSpec((block_b, 1), lambda i, j, k: (i, 0)),        # beta col
    ]
    out_specs = pl.BlockSpec((block_b, block_n), lambda i, j, k: (i, j))
    kw = {}
    if not interpret and _COMPILER_PARAMS is not None:
        kw["compiler_params"] = _COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=jax.ShapeDtypeStruct((Bp, Np), out_dtype),
        scratch_shapes=[_VMEM((block_b, block_n), jnp.float32)],
        interpret=interpret,
        **kw,
    )(mp, Wp, mp, hp, gp, op_, rgp, cop, maskp, up, bp)
    return out[:B, :N]
