"""Device-local compute for the mesh-sharded sparse lattice.

The sharded execution layer (core/distributed.ShardedEngine) cuts the
Chimera cell grid into contiguous *row bands*, one per device along the
partition's rows axis.  Each device owns a padded (B, N_loc) spin block
plus the (D, N_loc) slice of the slot tables; the only non-local spins a
half-sweep ever reads are the chain-coupler boundary spins of the two row
neighbors — the ``halo_up`` / ``halo_dn`` blocks exchanged by
``jax.lax.ppermute`` in `halo_exchange`.

`halo_half_sweep` is `kernels/ref.py::pbit_sparse_half_sweep_ref` with the
gather source extended from the local block to [local | halo_up | halo_dn]:
slots accumulate in the identical ascending-d order and every elementwise
op matches term for term, so a sharded sweep is *bit-exact* against the
single-device sparse scan (and therefore against the dense ref) for the
same noise stream — the contract tests/test_shard_session.py enforces.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def halo_exchange(
    m_loc: jax.Array,
    send_up: jax.Array,
    send_dn: jax.Array,
    axis_name,
    n_shards: int,
) -> tuple[jax.Array, jax.Array]:
    """Exchange boundary spins with the row neighbors.

    m_loc: (B, N_loc) local spins; send_up/send_dn: (H,) local indices of
    the vertical nodes in the band's first/last cell row (padded with 0 —
    padding halo slots are never referenced by any neighbor table entry).
    Returns (halo_up, halo_dn), each (B, H): the down-boundary of the
    device above and the up-boundary of the device below.  Edge devices
    receive zeros (open lattice boundary, matching the dense path where
    those couplers simply do not exist).  O(B·H) bytes per device pair —
    the O(√N) inter-cell wires of the chip, nothing else ever moves.
    """
    up_src = jnp.take(m_loc, send_dn, axis=1)  # my last row -> device below
    dn_src = jnp.take(m_loc, send_up, axis=1)  # my first row -> device above
    if axis_name is None or n_shards <= 1:
        return jnp.zeros_like(up_src), jnp.zeros_like(dn_src)
    halo_up = jax.lax.ppermute(
        up_src, axis_name, [(i, i + 1) for i in range(n_shards - 1)])
    halo_dn = jax.lax.ppermute(
        dn_src, axis_name, [(i + 1, i) for i in range(n_shards - 1)])
    return halo_up, halo_dn


def halo_neuron_input(
    m_loc: jax.Array,
    halo_up: jax.Array,
    halo_dn: jax.Array,
    nbr_idx: jax.Array,
    nbr_w: jax.Array,
    h: jax.Array,
) -> jax.Array:
    """Eqn 1 on the local slot tables: I = Σ_d w_d ⊙ m_ext[:, idx_d] + h.

    nbr_idx: (D, N_loc) indices into the *extended* array
    [local | halo_up | halo_dn]; nbr_w: (D, N_loc) local slot weights.
    Ascending-d accumulation, zero init, ``+ h`` last — the exact op
    order of `kernels/ref.py::sparse_neuron_input`, which is what keeps
    the sharded path bit-exact vs the single-device backends.
    """
    m_ext = jnp.concatenate([m_loc, halo_up, halo_dn], axis=1)
    D = nbr_idx.shape[0]
    acc = jnp.zeros(m_loc.shape, jnp.float32)
    for d in range(D):
        acc = acc + nbr_w[d][None, :] * jnp.take(m_ext, nbr_idx[d], axis=1)
    return acc + h


def halo_half_sweep(m_loc, halo_up, halo_dn, nbr_idx, nbr_w, h, gain, off,
                    rand_gain, comp_off, update_mask, beta, u):
    """`pbit_sparse_half_sweep_ref` with the halo-extended gather source.

    m_loc/u: (B, N_loc); update_mask: (N_loc,) bool (padding lanes False);
    beta: scalar or (B,) per-chain inverse temperature.
    """
    beta = jnp.asarray(beta, jnp.float32)
    if beta.ndim == 1:
        beta = beta[:, None]
    I = halo_neuron_input(m_loc, halo_up, halo_dn, nbr_idx, nbr_w, h)
    act = jnp.tanh(beta * gain * (I + off))
    decision = act + rand_gain * u + comp_off
    new = jnp.where(decision >= 0.0, 1.0, -1.0).astype(m_loc.dtype)
    return jnp.where(update_mask, new, m_loc)
