"""Device-local compute for the mesh-sharded sparse lattice.

The sharded execution layer (core/distributed.ShardedEngine) cuts the
Chimera cell grid into contiguous *row bands*, one per device along the
partition's rows axis.  Each device owns a padded (B, N_loc) spin block
plus the (D, N_loc) slice of the slot tables; the only non-local spins a
half-sweep ever reads are the chain-coupler boundary spins of the two row
neighbors — the ``halo_up`` / ``halo_dn`` blocks exchanged by
``jax.lax.ppermute`` in `halo_exchange`.

Both device-local sweep bodies are the SAME code as the single-device
backends:

  * `halo_half_sweep` is `kernels/ref.py::sparse_neuron_input` +
    `field_decision_update` with the gather source extended from the
    local block to [local | halo_up | halo_dn] — one shared term list,
    so a sharded half-sweep is *bit-exact* against the single-device
    sparse scan (and therefore the dense ref) for the same noise stream.
  * `fused_shard_sweeps` runs S *resident* sweeps on the same extended
    block through `kernels/sweep_fused.py::sweep_sparse_pallas`: halo
    columns are frozen (excluded from the update masks) and the
    in-kernel counter RNG is shifted to this shard's global
    (chain, node) coordinates via ``coord_offset``, so the kernel
    consumes exactly the columns of the noise stream the scan path
    would.  This is the per-shard engine behind launch-resident
    `api.Sync` policies (docs/sharding.md §Sync policies).
  * `fused_shard_exchange_resident` goes one step further on real TPU
    meshes: the halo exchange itself moves INSIDE the launch
    (`sweep_sparse_exchange_pallas` RDMA refresh at every exchange
    point), so `halo_every < sweeps_per_launch` no longer forces the
    engine back to per-segment dispatch.  Host CI proves the identical
    contract through the segmented emulation (`fused_shard_sweeps` with
    ``half_offset``/``n_half`` windows + ppermute between windows, one
    jitted graph — docs/kernels.md §In-kernel halo exchange).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ref import field_decision_update, sparse_neuron_input
from repro.kernels.sweep_fused import (
    sweep_sparse_pallas,
    sweep_sparse_stream_pallas,
)


def halo_exchange(
    m_loc: jax.Array,
    send_up: jax.Array,
    send_dn: jax.Array,
    axis_name,
    n_shards: int,
) -> tuple[jax.Array, jax.Array]:
    """Exchange boundary spins with the row neighbors.

    m_loc: (B, N_loc) local spins; send_up/send_dn: (H,) local indices of
    the vertical nodes in the band's first/last cell row (padded with 0 —
    padding halo slots are never referenced by any neighbor table entry).
    Returns (halo_up, halo_dn), each (B, H): the down-boundary of the
    device above and the up-boundary of the device below.  Edge devices
    receive zeros (open lattice boundary, matching the dense path where
    those couplers simply do not exist).  O(B·H) bytes per device pair —
    the O(√N) inter-cell wires of the chip, nothing else ever moves.
    """
    up_src = jnp.take(m_loc, send_dn, axis=1)  # my last row -> device below
    dn_src = jnp.take(m_loc, send_up, axis=1)  # my first row -> device above
    if axis_name is None or n_shards <= 1:
        return jnp.zeros_like(up_src), jnp.zeros_like(dn_src)
    halo_up = jax.lax.ppermute(
        up_src, axis_name, [(i, i + 1) for i in range(n_shards - 1)])
    halo_dn = jax.lax.ppermute(
        dn_src, axis_name, [(i + 1, i) for i in range(n_shards - 1)])
    return halo_up, halo_dn


def halo_neuron_input(
    m_loc: jax.Array,
    halo_up: jax.Array,
    halo_dn: jax.Array,
    nbr_idx: jax.Array,
    nbr_w: jax.Array,
    h: jax.Array,
) -> jax.Array:
    """Eqn 1 on the local slot tables: I = Σ_d w_d ⊙ m_ext[:, idx_d] + h.

    nbr_idx: (D, N_loc) indices into the *extended* array
    [local | halo_up | halo_dn]; nbr_w: (D, N_loc) local slot weights.
    Literally `kernels/ref.py::sparse_neuron_input` on the extended
    gather source — the one shared accumulation body (ascending-d order,
    zero init, ``+ h`` last) that keeps the sharded path bit-exact vs the
    single-device backends.
    """
    m_ext = jnp.concatenate([m_loc, halo_up, halo_dn], axis=1)
    return sparse_neuron_input(m_ext, nbr_idx, nbr_w, h)


def halo_half_sweep(m_loc, halo_up, halo_dn, nbr_idx, nbr_w, h, gain, off,
                    rand_gain, comp_off, update_mask, beta, u):
    """The sparse half-sweep with the halo-extended gather source.

    m_loc/u: (B, N_loc); update_mask: (N_loc,) bool (padding lanes False);
    beta: scalar or (B,) per-chain inverse temperature.  The decision tail
    is the shared `kernels/ref.py::field_decision_update`.
    """
    I = halo_neuron_input(m_loc, halo_up, halo_dn, nbr_idx, nbr_w, h)
    return field_decision_update(m_loc, I, gain, off, rand_gain, comp_off,
                                 update_mask, beta, u)


def fused_shard_sweeps(
    m_loc: jax.Array,            # (B, N_loc) local spins
    halo_up: jax.Array,          # (B, H) frozen for the whole launch
    halo_dn: jax.Array,          # (B, H)
    nbr_idx: jax.Array,          # (D, N_loc) ext-local neighbor table
    nbr_w: jax.Array,            # (D, N_loc) slot weights
    h: jax.Array,
    gain: jax.Array,
    off: jax.Array,
    rand_gain: jax.Array,
    comp_off: jax.Array,
    mask0: jax.Array,            # (N_loc,) bool color-0 update set
    mask1: jax.Array,            # (N_loc,) bool
    betas: jax.Array,            # (S,) or (S, B) per-launch schedule slice
    noise_state: jax.Array,      # (2,) uint32 counter state
    row0: jax.Array,             # uint32 global id of this device's chain 0
    col0: jax.Array,             # uint32 global id of local node 0
    clamp_mask: jax.Array | None = None,    # (N_loc,) bool
    clamp_values: jax.Array | None = None,  # (B, N_loc)
    measured: jax.Array | None = None,      # (S,) moment weights
    next_nbr_w: jax.Array | None = None,    # (D, N_loc) next program weights
    next_h: jax.Array | None = None,        # (N_loc,) next program biases
    *,
    block_b: int = 128,
    interpret: bool = True,
    half_offset: int = 0,
    n_half: int | None = None,
):
    """One sweep-resident launch on the halo-extended local block.

    Runs S full sweeps inside a single `sweep_sparse_pallas` call: spins
    stay in VMEM, counter noise is generated in-kernel at the shard's
    global (chain, node) coordinates, and (optionally) CD moments
    accumulate in the kernel's scratch.  Halo columns ride along in the
    extended array but are excluded from every update mask, so they stay
    frozen at the launch-boundary exchange values — exactly the staleness
    the launch-resident `api.Sync` policies define.  Bands are contiguous
    global id ranges, so a single scalar ``col0`` places the whole block
    in the global noise grid.

    ``next_nbr_w``/``next_h`` switch the launch to the double-buffered
    weight-streaming engine (`sweep_sparse_stream_pallas`): each shard's
    slice of the NEXT program stages into a second VMEM slot while the
    current program's sweeps run (mutually exclusive with ``measured`` —
    a swapped program invalidates mid-grid moments).

    Returns (m', noise_state'), with ``measured``
    (m', noise_state', s_sum[N_loc], c_slots[D, N_ext]) — raw sums over
    (chains × measured sweeps); ``c_slots[d, i] = Σ m_i·m_ext[idx[d, i]]``
    with i ext-local (boundary edges read the frozen halo) — or, with a
    next program, (m', noise_state', staged_w[D, N_loc], staged_h[N_loc])
    ready to be the following launch's resident program slice.

    ``half_offset``/``n_half`` run only that half-sweep window of the
    launch (`sweep_sparse_pallas` segmented-window contract): the fused-
    resident-exchange loop shape calls one window per halo segment,
    re-exchanging halos in between, all inside one jitted graph — the
    bit-exact emulation of the in-kernel RDMA refresh.
    """
    B, n_loc = m_loc.shape
    H = halo_up.shape[1]
    pad2 = 2 * H
    m_ext = jnp.concatenate([m_loc, halo_up, halo_dn], axis=1)
    zb = jnp.zeros((pad2,), bool)
    zf = jnp.zeros((pad2,), jnp.float32)

    def row(x):
        return jnp.concatenate([jnp.asarray(x, jnp.float32), zf])

    idx_e = jnp.pad(jnp.asarray(nbr_idx, jnp.int32), ((0, 0), (0, pad2)))
    w_e = jnp.pad(jnp.asarray(nbr_w, jnp.float32), ((0, 0), (0, pad2)))
    betas = jnp.asarray(betas, jnp.float32)
    if betas.ndim == 1:
        betas = jnp.broadcast_to(betas[:, None], (betas.shape[0], B))
    cm_e = cv_e = None
    if clamp_mask is not None and clamp_values is not None:
        cm_e = jnp.concatenate([clamp_mask, zb])
        cv_e = jnp.pad(jnp.asarray(clamp_values, jnp.float32),
                       ((0, 0), (0, pad2)))
    coords = jnp.stack([jnp.asarray(row0, jnp.uint32),
                        jnp.asarray(col0, jnp.uint32)])
    if next_nbr_w is not None:
        if measured is not None:
            raise ValueError(
                "program streaming excludes in-kernel moment "
                "accumulation (see sweep_sparse_stream_pallas)")
        nw_e = jnp.pad(jnp.asarray(next_nbr_w, jnp.float32),
                       ((0, 0), (0, pad2)))
        m_out, ns, staged_w, staged_h = sweep_sparse_stream_pallas(
            m_ext, idx_e, w_e, row(h), row(gain), row(off), row(rand_gain),
            row(comp_off), jnp.concatenate([mask0, zb]),
            jnp.concatenate([mask1, zb]), betas, noise_state,
            nw_e, row(next_h), clamp_mask=cm_e, clamp_values=cv_e,
            coord_offset=coords, block_b=block_b, interpret=interpret,
            half_offset=half_offset, n_half=n_half)
        return (m_out[:, :n_loc], ns, staged_w[:, :n_loc],
                staged_h[:n_loc])
    outs = sweep_sparse_pallas(
        m_ext, idx_e, w_e, row(h), row(gain), row(off), row(rand_gain),
        row(comp_off), jnp.concatenate([mask0, zb]),
        jnp.concatenate([mask1, zb]), betas, noise_state,
        clamp_mask=cm_e, clamp_values=cv_e, measured=measured,
        coord_offset=coords, noise_mode="counter",
        accumulate=measured is not None, block_b=block_b,
        interpret=interpret, half_offset=half_offset, n_half=n_half)
    m_out = outs[0][:, :n_loc]
    if measured is None:
        return m_out, outs[1]
    return m_out, outs[1], outs[2][:n_loc], outs[3]


def fused_shard_exchange_resident(
    m_loc: jax.Array,            # (B, N_loc) local spins
    halo_up: jax.Array,          # (B, H) primed pre-launch values
    halo_dn: jax.Array,          # (B, H)
    nbr_idx: jax.Array,          # (D, N_loc) ext-local neighbor table
    nbr_w: jax.Array,            # (D, N_loc)
    h: jax.Array,
    gain: jax.Array,
    off: jax.Array,
    rand_gain: jax.Array,
    comp_off: jax.Array,
    mask0: jax.Array,
    mask1: jax.Array,
    betas: jax.Array,            # (S,) or (S, B)
    noise_state: jax.Array,      # (2,) uint32
    row0: jax.Array,
    col0: jax.Array,
    send_up: jax.Array,          # (H,) local cols of the first-row verts
    send_dn: jax.Array,          # (H,) local cols of the last-row verts
    clamp_mask: jax.Array | None = None,
    clamp_values: jax.Array | None = None,
    measured: jax.Array | None = None,
    next_nbr_w: jax.Array | None = None,
    next_h: jax.Array | None = None,
    *,
    ex_pts: tuple,
    mode: str = "barrier",
    axis_name: str = "row",
    n_row: int,
    interpret: bool = False,
):
    """`fused_shard_sweeps` with the halo exchange INSIDE the kernel.

    The hardware path of the fused-resident-exchange loop shape: one
    `sweep_sparse_exchange_pallas` launch runs the whole schedule and
    refreshes halos at every `ex_pts` half-sweep over RDMA, so nothing
    leaves the kernel between exchanges.  Bit-for-bit the same contract
    as the segmented emulation (`fused_shard_sweeps` windows + ppermute):
    identical noise counters, identical exchange-point staleness.  TPU
    meshes only — interpret mode raises, CI proves the contract through
    the emulation.  Pending on-TPU validation (see ROADMAP.md).
    """
    from repro.kernels.sweep_fused import sweep_sparse_exchange_pallas

    B, n_loc = m_loc.shape
    H = halo_up.shape[1]
    pad2 = 2 * H
    m_ext = jnp.concatenate([m_loc, halo_up, halo_dn], axis=1)
    zb = jnp.zeros((pad2,), bool)
    zf = jnp.zeros((pad2,), jnp.float32)
    row = lambda x: jnp.concatenate([jnp.asarray(x, jnp.float32), zf])
    idx_e = jnp.pad(jnp.asarray(nbr_idx, jnp.int32), ((0, 0), (0, pad2)))
    w_e = jnp.pad(jnp.asarray(nbr_w, jnp.float32), ((0, 0), (0, pad2)))
    betas = jnp.asarray(betas, jnp.float32)
    if betas.ndim == 1:
        betas = jnp.broadcast_to(betas[:, None], (betas.shape[0], B))
    cm_e = cv_e = None
    if clamp_mask is not None and clamp_values is not None:
        cm_e = jnp.concatenate([clamp_mask, zb])
        cv_e = jnp.pad(jnp.asarray(clamp_values, jnp.float32),
                       ((0, 0), (0, pad2)))
    coords = jnp.stack([jnp.asarray(row0, jnp.uint32),
                        jnp.asarray(col0, jnp.uint32)])
    nw_e = nh_e = None
    if next_nbr_w is not None:
        nw_e = jnp.pad(jnp.asarray(next_nbr_w, jnp.float32),
                       ((0, 0), (0, pad2)))
        nh_e = row(next_h)
    outs = sweep_sparse_exchange_pallas(
        m_ext, idx_e, w_e, row(h), row(gain), row(off), row(rand_gain),
        row(comp_off), jnp.concatenate([mask0, zb]),
        jnp.concatenate([mask1, zb]), betas, noise_state,
        send_up, send_dn, clamp_mask=cm_e, clamp_values=cv_e,
        measured=measured, coord_offset=coords, next_nbr_w=nw_e,
        next_h=nh_e, n_loc=n_loc, halo=H, ex_pts=ex_pts, mode=mode,
        axis_name=axis_name, n_row=n_row, interpret=interpret)
    m_out = outs[0][:, :n_loc]
    # halo columns as the kernel left them: barrier — the last-installed
    # exchange; async — the drained final exchange, i.e. the engine's
    # pend buffer for the next launch's first consume
    hu_out = outs[0][:, n_loc:n_loc + H]
    hd_out = outs[0][:, n_loc + H:n_loc + 2 * H]
    head = (m_out, outs[1], hu_out, hd_out)
    if measured is not None:
        return head + (outs[2][:n_loc], outs[3])
    if next_nbr_w is not None:
        return head + (outs[2][:, :n_loc], outs[3][:n_loc])
    return head
