"""Pallas TPU kernel: sweep-resident sampling engine (dense + block-sparse).

The chip's figure of merit is flips per nanosecond: all 440 neurons settle
in parallel with per-cell LFSR noise generated *in place*.  The per-half-
sweep kernel (pbit_update.py) still round-trips spins and noise through HBM
twice per sweep and leaves moment accumulation to separate jnp ops.  This
kernel closes that gap: one invocation executes S full chromatic sweeps
(both color half-sweeps) with

  * spins resident in VMEM for the whole S-sweep block,
  * noise generated inside the kernel — either counter mode (a stateless
    uint32 hash shared bit-for-bit with the host reference in
    core/lfsr.py::counter_uniform) or chip-faithful mode (the Galois LFSR of
    core/lfsr.py advanced in-kernel, including the bit-reversed-byte sharing
    trick, bit-exact with the host LFSR stream),
  * optional on-line first/second moment accumulation (spin sums and either
    the full m^T m Gram matrix or, in sparse mode, the per-slot edge
    correlations) in VMEM scratch, so CD's `gibbs_stats` never materializes
    per-sweep state in HBM,
  * optional on-line visible-pattern histogramming (one-hot reduction over
    2^n_visible bins per sweep), so `sample_visible_dist` never collects a
    trajectory.

Two weight layouts share the kernel body:

  * dense  (`sweep_fused_pallas`)  — W (N, N) in VMEM, neuron input is a
    (tb, N) x (N, N) matmul.  W alone is 4·N² bytes, which bounds the
    resident engine to roughly N <= 1.5k fp32 on a 16 MB-VMEM core.
  * sparse (`sweep_sparse_pallas`) — the Chimera-native fixed-degree slot
    layout (ChimeraGraph.neighbor_table): nbr_idx/nbr_w (D, N) with D = 6
    on the chip's graph.  Neuron input is D lane-gathers + multiply-adds —
    2·B·N·D FLOPs instead of 2·B·N², and 8·D·N weight bytes instead of
    4·N², so ≥32k-spin lattices stay VMEM-resident.  Slots accumulate in
    ascending-neighbor order, making the result bit-exact against both the
    sparse jnp ref and (zeros being additive identities) the dense path.

`sweep_sparse_stream_pallas` adds runtime weight streaming to the sparse
engine: the NEXT program's (D, N)/(N,) weights ride the same launch,
stage into a second VMEM slot at grid step 0 (overlapping the current
program's S sweeps — the SpikeHard DMA model), and come back as staged
outputs aliased in place over the inputs, ready to be the next launch's
resident program.

Grid: (B/tb,) over batch tiles; each program owns its rows for all S
sweeps.  Moment/histogram scratch accumulates across the (sequential)
batch-tile grid and is flushed to the output on the last program, the same
revisiting pattern as the K-loop accumulator in pbit_update.py.

Validated bit-for-bit in interpret mode against a scan of the
kernels/ref.py oracles with host-side noise (tests/test_sweep_fused.py,
tests/test_sparse.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import lfsr as lfsr_mod
from repro.kernels.util import pad_axis as _pad_axis
from repro.kernels.util import round_up as _round_up

try:  # compiler params class moved across jax versions
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
    _COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                               getattr(pltpu, "TPUCompilerParams", None))
except ImportError:  # pragma: no cover
    pltpu = None
    _VMEM = None
    _COMPILER_PARAMS = None

NOISE_COUNTER = "counter"
NOISE_LFSR = "lfsr"

MAX_HIST_VISIBLE = 12  # one-hot reduction over 2^nv bins; keep it VMEM-sane


def _kernel(*refs, S: int, tb: int, Np: int, n_b: int, B: int,
            noise_mode: str, has_clamp: bool, accumulate: bool,
            collect_hist: bool, decimation: int, sparse: bool, D: int,
            NBp: int, has_coords: bool, stream: bool = False,
            half_offset: int = 0, n_half: int | None = None):
    it = iter(refs)
    m0_ref = next(it)
    if sparse:
        idx_ref = next(it)                    # (Dp, Np) neighbor table
        w_ref = next(it)                      # (Dp, Np) slot weights
    else:
        w_ref = next(it)                      # (Np, Np) dense couplings
    h_ref, g_ref, off_ref, rg_ref, co_ref = (next(it) for _ in range(5))
    mask0_ref, mask1_ref = next(it), next(it)
    betas_ref = next(it)
    clampm_ref = next(it) if has_clamp else None
    clampv_ref = next(it) if has_clamp else None
    meas_ref = next(it) if (accumulate or collect_hist) else None
    vis_ref = next(it) if collect_hist else None   # (1, NVp) visible cols
    pow_ref = next(it) if collect_hist else None   # (1, NVp) 2^k bin powers
    perm_ref = next(it) if noise_mode == NOISE_LFSR else None
    coords_ref = next(it) if has_coords else None
    noise_in_ref = next(it)
    if stream:
        next_w_ref = next(it)                 # (Dp, Np) next program weights
        next_h_ref = next(it)                 # (1, Np) next program biases
    m_out_ref = next(it)
    noise_out_ref = next(it)
    if accumulate:
        ssum_out_ref, csum_out_ref = next(it), next(it)
    if collect_hist:
        hist_out_ref = next(it)
    if stream:
        staged_w_out_ref, staged_h_out_ref = next(it), next(it)
    if accumulate:
        ssum_ref, csum_ref = next(it), next(it)
    if collect_hist:
        hist_ref = next(it)
    if stream:
        slot_w_ref, slot_h_ref = next(it), next(it)

    i = pl.program_id(0)

    if accumulate:
        @pl.when(i == 0)
        def _zero_moments():
            ssum_ref[...] = jnp.zeros_like(ssum_ref)
            csum_ref[...] = jnp.zeros_like(csum_ref)
    if collect_hist:
        @pl.when(i == 0)
        def _zero_hist():
            hist_ref[...] = jnp.zeros_like(hist_ref)
    if stream:
        # double-buffered program upload (the SpikeHard DMA model): the
        # NEXT program's weights stream into the second VMEM slot up
        # front, before this launch's S resident sweeps touch the loop —
        # independent of the sweep dataflow, so the copy overlaps compute
        # on hardware.  Flushed to the staged outputs on the last block;
        # the host feeds them straight back as the following launch's
        # resident program (zero-copy: the next-program inputs alias the
        # staged outputs via input_output_aliases).
        @pl.when(i == 0)
        def _stage_next_program():
            slot_w_ref[...] = next_w_ref[...]
            slot_h_ref[...] = next_h_ref[...]

    if not sparse:
        w = w_ref[...]
    hrow, grow = h_ref[...], g_ref[...]
    offrow, rgrow, corow = off_ref[...], rg_ref[...], co_ref[...]
    masks = (mask0_ref[...] != 0, mask1_ref[...] != 0)

    if noise_mode == NOISE_COUNTER:
        seed = noise_in_ref[0, 0]
        ctr0 = noise_in_ref[0, 1]
        # (row0, col0) shift the hash coordinates to this block's place in
        # the GLOBAL (chain, node) grid — the sharded engine passes its
        # chain offset / first global node id so every shard regenerates
        # exactly its columns of the single-device stream
        row0 = coords_ref[0, 0] if has_coords else jnp.uint32(0)
        col0 = coords_ref[0, 1] if has_coords else jnp.uint32(0)
        rows = (jax.lax.broadcasted_iota(jnp.uint32, (tb, Np), 0)
                + (i * tb).astype(jnp.uint32) + row0)
        cols = jax.lax.broadcasted_iota(jnp.uint32, (tb, Np), 1) + col0
        noise_carry0 = jnp.zeros((), jnp.uint32)  # unused
    else:
        noise_carry0 = noise_in_ref[...]          # (tb, Cp) LFSR states
        perm_cols = perm_ref[0, :]                # node -> flat LFSR column

    def neuron_current(m):
        """Eqn 1 over the resident tile: matmul (dense) or D-slot gather."""
        if sparse:
            acc = jnp.zeros((tb, Np), jnp.float32)
            for d in range(D):
                acc = acc + w_ref[pl.ds(d, 1), :] * jnp.take(
                    m, idx_ref[d, :], axis=-1)
            return acc + hrow
        return jax.lax.dot_general(
            m, w, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) + hrow

    # Launch-relative half-sweep window.  The fused-exchange engine splits
    # one logical launch into segments at halo exchange points, so a
    # segment may start mid-sweep (odd half_offset: the color-1 half that
    # FINISHES sweep half_offset//2) and end mid-sweep (a trailing color-0
    # half whose sweep the next segment completes).  The noise counter
    # advances by LOCAL halves — the engine threads noise_state between
    # segments, so ctr0 already encodes half_offset — while betas /
    # measured keep full-launch sweep indices.  Defaults (half_offset=0,
    # n_half=None) reproduce the classic whole-launch loop exactly.
    n_half_eff = 2 * S if n_half is None else n_half
    lead = half_offset % 2
    n_full = max(n_half_eff - lead, 0) // 2
    tail = max(n_half_eff - lead, 0) % 2
    s0 = (half_offset + lead) // 2

    def impose_clamp(m):
        if has_clamp:
            return jnp.where(clampm_ref[...] != 0, clampv_ref[...], m)
        return m

    def half_update(m, st, s_idx, c, half_j):
        """One color half-sweep of (launch-relative) sweep s_idx."""
        if noise_mode == NOISE_COUNTER:
            ctr = ctr0 + half_j
            u = lfsr_mod.counter_uniform(seed, ctr, rows, cols)
        else:
            st = lfsr_mod.lfsr_step_n(st, decimation)
            u = jnp.take(lfsr_mod.flat_cell_uniforms(st), perm_cols,
                         axis=-1)
        beta_col = betas_ref[pl.ds(s_idx, 1), :].reshape(tb, 1)
        I = neuron_current(m)
        act = jnp.tanh(beta_col * grow * (I + offrow))
        decision = act + rgrow * u + corow
        new = jnp.where(decision >= 0.0, 1.0, -1.0)
        return jnp.where(masks[c], new, m), st

    def sweep_stats(m, s_idx):
        """Accumulate moments/histogram after sweep s_idx completes."""
        wgt = meas_ref[pl.ds(s_idx, 1), :]                      # (1, 1)
        # padded batch rows update like real chains; keep them out of
        # the statistics
        row_ids = (jax.lax.broadcasted_iota(jnp.int32, (tb, 1), 0)
                   + i * tb)
        if accumulate:
            mv = jnp.where(row_ids < B, m, 0.0)
            ssum_ref[...] += wgt * jnp.sum(mv, axis=0, keepdims=True)
            if sparse:
                for d in range(D):
                    corr = jnp.sum(
                        mv * jnp.take(mv, idx_ref[d, :], axis=-1),
                        axis=0, keepdims=True)                   # (1, Np)
                    csum_ref[pl.ds(d, 1), :] += wgt[0, 0] * corr
            else:
                csum_ref[...] += wgt[0, 0] * jax.lax.dot_general(
                    mv, mv, dimension_numbers=(((0,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)          # m^T m
        if collect_hist:
            mv_vis = jnp.take(m, vis_ref[0, :], axis=-1)        # (tb, NVp)
            codes = jnp.sum(
                jnp.where(mv_vis > 0, pow_ref[...], 0),
                axis=1, keepdims=True)                           # (tb, 1)
            bin_ids = jax.lax.broadcasted_iota(jnp.int32, (tb, NBp), 1)
            onehot = ((codes == bin_ids)
                      & (row_ids < B)).astype(jnp.float32)
            hist_ref[...] += wgt[0, 0] * jnp.sum(onehot, axis=0,
                                                 keepdims=True)

    m_cur = m0_ref[...].astype(jnp.float32)
    st_cur = noise_carry0
    if lead:
        # clamp re-imposition is idempotent (clamped nodes are excluded
        # from the color masks), so repeating it at a mid-sweep segment
        # boundary is bit-identical to the unsplit launch
        m_cur = impose_clamp(m_cur)
        m_cur, st_cur = half_update(m_cur, st_cur, half_offset // 2, 1,
                                    jnp.uint32(0))
        if accumulate or collect_hist:
            sweep_stats(m_cur, half_offset // 2)

    def one_sweep(jj, carry):
        m, st = carry
        m = impose_clamp(m)
        for c in (0, 1):
            hj = (jnp.uint32(lead) + jnp.uint32(2) * jj.astype(jnp.uint32)
                  + jnp.uint32(c))
            m, st = half_update(m, st, s0 + jj, c, hj)
        if accumulate or collect_hist:
            sweep_stats(m, s0 + jj)
        return m, st

    m_fin, st_fin = jax.lax.fori_loop(0, n_full, one_sweep, (m_cur, st_cur))
    if tail:
        m_fin = impose_clamp(m_fin)
        m_fin, st_fin = half_update(m_fin, st_fin, s0 + n_full, 0,
                                    jnp.uint32(lead + 2 * n_full))
    m_out_ref[...] = m_fin.astype(m_out_ref.dtype)

    if noise_mode == NOISE_COUNTER:
        noise_out_ref[0, 0] = seed
        noise_out_ref[0, 1] = ctr0 + jnp.uint32(n_half_eff)
    else:
        noise_out_ref[...] = st_fin

    if accumulate:
        @pl.when(i == n_b - 1)
        def _flush_moments():
            ssum_out_ref[...] = ssum_ref[...]
            csum_out_ref[...] = csum_ref[...]
    if collect_hist:
        @pl.when(i == n_b - 1)
        def _flush_hist():
            hist_out_ref[...] = hist_ref[...]
    if stream:
        @pl.when(i == n_b - 1)
        def _flush_staged_program():
            staged_w_out_ref[...] = slot_w_ref[...]
            staged_h_out_ref[...] = slot_h_ref[...]


def _launch(
    m, dense_W, nbr_idx, nbr_w, h, gain, off, rand_gain, comp_off,
    mask0, mask1, betas, noise_state, clamp_mask, clamp_values, measured,
    visible_idx, *, sparse, noise_mode, decimation, gather_perm,
    accumulate, collect_hist, n_visible, block_b, interpret,
    coord_offset=None, next_nbr_w=None, next_h=None,
    half_offset=0, n_half=None,
):
    """Shared plumbing for the dense and sparse sweep-resident engines."""
    B, N = m.shape
    S = betas.shape[0]
    # normalize the half-sweep window: n_half=None means "to launch end"
    n_half = 2 * S - half_offset if n_half is None else n_half
    if not (0 <= half_offset and 0 <= n_half
            and half_offset + n_half <= 2 * S):
        raise ValueError(
            f"half-sweep window [{half_offset}, {half_offset + n_half}) "
            f"falls outside the launch's 2*S={2 * S} half-sweeps")
    out_dtype = m.dtype
    stream = next_nbr_w is not None
    if stream:
        if not sparse or noise_mode != NOISE_COUNTER:
            raise ValueError(
                "program streaming runs on the sparse counter-noise "
                "engine (the launch-resident serving configuration)")
        if next_h is None:
            raise ValueError("next_nbr_w without next_h")
        if accumulate or collect_hist or measured is not None:
            raise ValueError(
                "program streaming excludes in-kernel moment/histogram "
                "accumulation — a swapped program invalidates the "
                "accumulators mid-grid")
    # clamp_mask alone (freeze nodes at their current spins) is fully
    # handled by excluding the nodes from mask0/mask1; the kernel only
    # needs the clamp inputs when values are re-imposed every sweep
    has_clamp = clamp_mask is not None and clamp_values is not None
    accumulate = accumulate and measured is not None
    collect_hist = collect_hist and measured is not None
    if noise_mode not in (NOISE_COUNTER, NOISE_LFSR):
        raise ValueError(f"unknown noise_mode {noise_mode!r}")
    if collect_hist:
        if visible_idx is None:
            raise ValueError("collect_hist needs visible_idx")
        if not (0 < n_visible <= MAX_HIST_VISIBLE):
            raise ValueError(
                f"collect_hist supports 1..{MAX_HIST_VISIBLE} visible "
                f"nodes, got {n_visible}")
    if sparse:
        D = nbr_idx.shape[0]
    NB = 2 ** n_visible if collect_hist else 0

    if S == 0:  # empty schedule: identity, like a zero-length scan
        outs = [m, jnp.asarray(noise_state, jnp.uint32)]
        if accumulate:
            c_shape = (D, N) if sparse else (N, N)
            outs += [jnp.zeros((N,), jnp.float32),
                     jnp.zeros(c_shape, jnp.float32)]
        if collect_hist:
            outs.append(jnp.zeros((NB,), jnp.float32))
        if stream:
            outs += [jnp.asarray(next_nbr_w, jnp.float32),
                     jnp.asarray(next_h, jnp.float32)]
        return tuple(outs)

    Np = _round_up(N, 128)
    tb = min(block_b, _round_up(B, 8))
    Bp = _round_up(B, tb)
    n_b = Bp // tb

    mp = _pad_axis(_pad_axis(m, tb, 0), 128, 1)
    row = lambda x, v=0.0: _pad_axis(
        jnp.asarray(x).reshape(1, -1).astype(jnp.float32), 128, 1, v)
    hp, gp, op_, rgp, cop = (row(x) for x in
                             (h, gain, off, rand_gain, comp_off))
    m0p = _pad_axis(jnp.asarray(mask0).reshape(1, -1).astype(jnp.int8),
                    128, 1, 0)
    m1p = _pad_axis(jnp.asarray(mask1).reshape(1, -1).astype(jnp.int8),
                    128, 1, 0)
    betasp = _pad_axis(jnp.asarray(betas, jnp.float32), tb, 1)

    vec = lambda: pl.BlockSpec((1, Np), lambda i: (0, 0))
    in_specs = [pl.BlockSpec((tb, Np), lambda i: (i, 0))]       # m
    args = [mp]
    if sparse:
        Dp = _round_up(D, 8)
        idxp = _pad_axis(_pad_axis(
            jnp.asarray(nbr_idx, jnp.int32), Dp, 0), 128, 1)
        wp = _pad_axis(_pad_axis(
            jnp.asarray(nbr_w, jnp.float32), Dp, 0), 128, 1)
        in_specs += [pl.BlockSpec((Dp, Np), lambda i: (0, 0)),  # nbr_idx
                     pl.BlockSpec((Dp, Np), lambda i: (0, 0))]  # nbr_w
        args += [idxp, wp]
    else:
        Wp = _pad_axis(_pad_axis(dense_W, 128, 0), 128, 1)
        in_specs.append(pl.BlockSpec((Np, Np), lambda i: (0, 0)))  # W
        args.append(Wp)
    in_specs += [vec(), vec(), vec(), vec(), vec(),             # h,g,off,rg,co
                 vec(), vec(),                                  # color masks
                 pl.BlockSpec((S, tb), lambda i: (0, i))]       # betas
    args += [hp, gp, op_, rgp, cop, m0p, m1p, betasp]

    if has_clamp:
        in_specs.append(vec())
        args.append(_pad_axis(
            jnp.asarray(clamp_mask).reshape(1, -1).astype(jnp.int8),
            128, 1, 0))
        in_specs.append(pl.BlockSpec((tb, Np), lambda i: (i, 0)))
        args.append(_pad_axis(_pad_axis(
            jnp.asarray(clamp_values, jnp.float32), tb, 0), 128, 1))
    if accumulate or collect_hist:
        in_specs.append(pl.BlockSpec((S, 1), lambda i: (0, 0)))
        args.append(jnp.asarray(measured, jnp.float32).reshape(S, 1))
    NBp = 0
    if collect_hist:
        NVp = _round_up(n_visible, 128)
        NBp = _round_up(NB, 128)
        visp = _pad_axis(
            jnp.asarray(visible_idx, jnp.int32).reshape(1, -1), 128, 1, 0)
        powp = _pad_axis(jnp.asarray(
            2 ** np.arange(n_visible, dtype=np.int32)).reshape(1, -1),
            128, 1, 0)
        in_specs += [pl.BlockSpec((1, NVp), lambda i: (0, 0)),
                     pl.BlockSpec((1, NVp), lambda i: (0, 0))]
        args += [visp, powp]

    has_coords = coord_offset is not None
    if has_coords:
        if noise_mode != NOISE_COUNTER:
            raise ValueError(
                "coord_offset shifts the counter hash's (chain, node) "
                "coordinates; the lfsr mode carries its cell band in the "
                "state instead")
        in_specs.append(pl.BlockSpec((1, 2), lambda i: (0, 0)))
        args.append(jnp.asarray(coord_offset, jnp.uint32).reshape(1, 2))
    if noise_mode == NOISE_COUNTER:
        in_specs.append(pl.BlockSpec((1, 2), lambda i: (0, 0)))
        args.append(jnp.asarray(noise_state, jnp.uint32).reshape(1, 2))
        noise_out_shape = jax.ShapeDtypeStruct((1, 2), jnp.uint32)
        noise_out_spec = pl.BlockSpec((1, 2), lambda i: (0, 0))
    else:
        if gather_perm is None:
            raise ValueError("lfsr noise_mode needs gather_perm "
                             "(see core/lfsr.py::node_gather_perm)")
        C = noise_state.shape[-1]
        Cp = _round_up(C, 128)
        # remap flat columns from the C-cell layout to the padded-Cp layout
        p = np.asarray(gather_perm, np.int64)
        p = (p // C) * Cp + (p % C)
        perm_padded = np.concatenate(
            [p, np.zeros(Np - N, np.int64)]).astype(np.int32)
        in_specs.append(pl.BlockSpec((1, Np), lambda i: (0, 0)))
        args.append(jnp.asarray(perm_padded).reshape(1, Np))
        stp = _pad_axis(_pad_axis(jnp.asarray(noise_state, jnp.uint32),
                                  tb, 0, 1), 128, 1, 1)
        in_specs.append(pl.BlockSpec((tb, Cp), lambda i: (i, 0)))
        args.append(stp)
        noise_out_shape = jax.ShapeDtypeStruct((Bp, Cp), jnp.uint32)
        noise_out_spec = pl.BlockSpec((tb, Cp), lambda i: (i, 0))

    aliases = {}
    if stream:
        # the next program rides the SAME launch as the current sweeps:
        # two O(D·N) operands appended after the noise state, aliased to
        # the staged outputs (in-place buffer handoff — the upload costs
        # no extra HBM round-trip, matching the chip's SPI-write-during-
        # anneal overlap)
        i_next = len(args)
        in_specs += [pl.BlockSpec((Dp, Np), lambda i: (0, 0)),
                     pl.BlockSpec((1, Np), lambda i: (0, 0))]
        args += [_pad_axis(_pad_axis(
            jnp.asarray(next_nbr_w, jnp.float32), Dp, 0), 128, 1),
            row(next_h)]
        aliases = {i_next: 2, i_next + 1: 3}

    out_shape = [jax.ShapeDtypeStruct((Bp, Np), out_dtype), noise_out_shape]
    out_specs = [pl.BlockSpec((tb, Np), lambda i: (i, 0)), noise_out_spec]
    scratch = []
    if accumulate:
        c_shape = (Dp, Np) if sparse else (Np, Np)
        out_shape += [jax.ShapeDtypeStruct((1, Np), jnp.float32),
                      jax.ShapeDtypeStruct(c_shape, jnp.float32)]
        out_specs += [pl.BlockSpec((1, Np), lambda i: (0, 0)),
                      pl.BlockSpec(c_shape, lambda i: (0, 0))]
        scratch += [_VMEM((1, Np), jnp.float32), _VMEM(c_shape, jnp.float32)]
    if collect_hist:
        out_shape.append(jax.ShapeDtypeStruct((1, NBp), jnp.float32))
        out_specs.append(pl.BlockSpec((1, NBp), lambda i: (0, 0)))
        scratch.append(_VMEM((1, NBp), jnp.float32))
    if stream:
        out_shape += [jax.ShapeDtypeStruct((Dp, Np), jnp.float32),
                      jax.ShapeDtypeStruct((1, Np), jnp.float32)]
        out_specs += [pl.BlockSpec((Dp, Np), lambda i: (0, 0)),
                      pl.BlockSpec((1, Np), lambda i: (0, 0))]
        scratch += [_VMEM((Dp, Np), jnp.float32),
                    _VMEM((1, Np), jnp.float32)]

    kw = {}
    if not interpret and _COMPILER_PARAMS is not None:
        kw["compiler_params"] = _COMPILER_PARAMS(
            dimension_semantics=("arbitrary",))
    if aliases:
        kw["input_output_aliases"] = aliases
    outs = pl.pallas_call(
        functools.partial(
            _kernel, S=S, tb=tb, Np=Np, n_b=n_b, B=B,
            noise_mode=noise_mode, has_clamp=has_clamp,
            accumulate=accumulate, collect_hist=collect_hist,
            decimation=decimation, sparse=sparse,
            D=D if sparse else 0, NBp=NBp, has_coords=has_coords,
            stream=stream, half_offset=half_offset, n_half=n_half),
        grid=(n_b,),
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shape),
        scratch_shapes=scratch,
        interpret=interpret,
        **kw,
    )(*args)

    result = [outs[0][:B, :N]]
    if noise_mode == NOISE_COUNTER:
        result.append(outs[1].reshape(2))
    else:
        result.append(outs[1][:B, :noise_state.shape[-1]])
    k = 2
    if accumulate:
        result.append(outs[k][0, :N])
        result.append(outs[k + 1][:D, :N] if sparse else outs[k + 1][:N, :N])
        k += 2
    if collect_hist:
        result.append(outs[k][0, :NB])
        k += 1
    if stream:
        result.append(outs[k][:D, :N])
        result.append(outs[k + 1][0, :N])
    return tuple(result)


@functools.partial(
    jax.jit,
    static_argnames=("noise_mode", "decimation", "gather_perm", "accumulate",
                     "collect_hist", "n_visible", "block_b", "interpret"),
)
def sweep_fused_pallas(
    m: jax.Array,                 # (B, N) spins in {-1, +1}
    W: jax.Array,                 # (N, N) directional couplings
    h: jax.Array,
    gain: jax.Array,
    off: jax.Array,
    rand_gain: jax.Array,
    comp_off: jax.Array,
    mask0: jax.Array,             # (N,) bool — color-0 update set (minus clamps)
    mask1: jax.Array,             # (N,) bool — color-1 update set (minus clamps)
    betas: jax.Array,             # (S, B) per-sweep, per-chain inverse temps
    noise_state: jax.Array,       # counter: (2,) uint32; lfsr: (B, C) uint32
    clamp_mask: jax.Array | None = None,     # (N,) bool
    clamp_values: jax.Array | None = None,   # (B, N)
    measured: jax.Array | None = None,       # (S,) statistic weights, or None
    visible_idx: jax.Array | None = None,    # (n_visible,) histogram nodes
    coord_offset: jax.Array | None = None,   # (2,) uint32 (row0, col0)
    *,
    noise_mode: str = NOISE_COUNTER,
    decimation: int = 8,
    gather_perm: tuple | None = None,   # node -> flat LFSR column (length N)
    accumulate: bool = False,
    collect_hist: bool = False,
    n_visible: int = 0,
    block_b: int = 128,
    interpret: bool = True,
):
    """Run S resident sweeps, dense layout.

    Returns ``(m', noise_state'[, s_sum, c_sum][, hist])``.
    s_sum: (N,) sum of spins over (chains x measured sweeps); c_sum: (N, N)
    accumulated Gram matrix sum_meas m^T m — extract edge correlations as
    ``c_sum[e0, e1]``.  hist: (2^n_visible,) weighted counts of visible bit
    patterns (energy.empirical_visible_dist code order).  All need dividing
    by their sample counts.  ``coord_offset`` (counter mode only) shifts
    the in-kernel hash to global (chain, node) coordinates — the sharded
    per-shard launch passes (chain0, node0) so each shard regenerates its
    own columns of the single-device noise stream.
    """
    return _launch(
        m, W, None, None, h, gain, off, rand_gain, comp_off, mask0, mask1,
        betas, noise_state, clamp_mask, clamp_values, measured, visible_idx,
        sparse=False, noise_mode=noise_mode, decimation=decimation,
        gather_perm=gather_perm, accumulate=accumulate,
        collect_hist=collect_hist, n_visible=n_visible, block_b=block_b,
        interpret=interpret, coord_offset=coord_offset)


@functools.partial(
    jax.jit,
    static_argnames=("noise_mode", "decimation", "gather_perm", "accumulate",
                     "collect_hist", "n_visible", "block_b", "interpret",
                     "half_offset", "n_half"),
)
def sweep_sparse_pallas(
    m: jax.Array,                 # (B, N) spins in {-1, +1}
    nbr_idx: jax.Array,           # (D, N) int32 neighbor table
    nbr_w: jax.Array,             # (D, N) per-slot couplings
    h: jax.Array,
    gain: jax.Array,
    off: jax.Array,
    rand_gain: jax.Array,
    comp_off: jax.Array,
    mask0: jax.Array,
    mask1: jax.Array,
    betas: jax.Array,             # (S, B)
    noise_state: jax.Array,
    clamp_mask: jax.Array | None = None,
    clamp_values: jax.Array | None = None,
    measured: jax.Array | None = None,
    visible_idx: jax.Array | None = None,
    coord_offset: jax.Array | None = None,
    *,
    noise_mode: str = NOISE_COUNTER,
    decimation: int = 8,
    gather_perm: tuple | None = None,
    accumulate: bool = False,
    collect_hist: bool = False,
    n_visible: int = 0,
    block_b: int = 128,
    interpret: bool = True,
    half_offset: int = 0,
    n_half: int | None = None,
):
    """Run S resident sweeps on the Chimera-native fixed-degree layout.

    Same contract as `sweep_fused_pallas` except weights are the (D, N)
    slot layout and the second-moment output is the per-slot edge
    correlation ``c_slots[d, i] = Σ m_i · m_{nbr_idx[d, i]}`` instead of a
    Gram matrix — read edge (i, j) at ``c_slots[slot_of(i→j), i]`` (see
    ChimeraGraph.edge_slots).  Never materializes anything O(N²).

    ``half_offset``/``n_half`` select a half-sweep window of the launch:
    run ``n_half`` color half-sweeps starting at (launch-relative) half
    ``half_offset``, with betas/measured still indexed by full-launch
    sweep number.  The fused-exchange engine uses this to split one
    logical launch at halo exchange points inside a single jitted graph;
    chaining windows (threading ``noise_state`` between calls) is
    bit-identical to the unsplit launch, and per-window moment partials
    sum exactly to the whole-launch moments.
    """
    return _launch(
        m, None, nbr_idx, nbr_w, h, gain, off, rand_gain, comp_off,
        mask0, mask1, betas, noise_state, clamp_mask, clamp_values,
        measured, visible_idx,
        sparse=True, noise_mode=noise_mode, decimation=decimation,
        gather_perm=gather_perm, accumulate=accumulate,
        collect_hist=collect_hist, n_visible=n_visible, block_b=block_b,
        interpret=interpret, coord_offset=coord_offset,
        half_offset=half_offset, n_half=n_half)


@functools.partial(
    jax.jit,
    static_argnames=("decimation", "block_b", "interpret",
                     "half_offset", "n_half"),
)
def sweep_sparse_stream_pallas(
    m: jax.Array,                 # (B, N) spins in {-1, +1}
    nbr_idx: jax.Array,           # (D, N) int32 neighbor table
    nbr_w: jax.Array,             # (D, N) CURRENT program's slot weights
    h: jax.Array,                 # (N,)   CURRENT program's biases
    gain: jax.Array,
    off: jax.Array,
    rand_gain: jax.Array,
    comp_off: jax.Array,
    mask0: jax.Array,
    mask1: jax.Array,
    betas: jax.Array,             # (S, B)
    noise_state: jax.Array,       # (2,) uint32 counter state
    next_nbr_w: jax.Array,        # (D, N) NEXT program's slot weights
    next_h: jax.Array,            # (N,)   NEXT program's biases
    clamp_mask: jax.Array | None = None,
    clamp_values: jax.Array | None = None,
    coord_offset: jax.Array | None = None,
    *,
    decimation: int = 8,
    block_b: int = 128,
    interpret: bool = True,
    half_offset: int = 0,
    n_half: int | None = None,
):
    """`sweep_sparse_pallas` with a double-buffered program upload: run S
    resident sweeps of the CURRENT program while the NEXT program's
    weights stream into a second VMEM slot.

    Returns ``(m', noise_state', staged_w, staged_h)`` where
    ``staged_w``/``staged_h`` are the next program, already device-
    resident: feed them back as this call's ``nbr_w``/``h`` on the next
    launch.  The next-program inputs alias the staged outputs
    (`input_output_aliases`), so the handoff is an in-place buffer swap,
    and the stage copy runs at grid step 0 — independent of the sweep
    loop, overlapping compute on hardware (the SpikeHard DMA model: the
    chip accepts the next problem's SPI write while the current anneal
    runs).  Counter noise only, no in-kernel accumulation (a swapped
    program would invalidate mid-grid moments).  Per-program results are
    bit-identical to serialized `sweep_sparse_pallas` launches — the
    benchmark ``weight_streaming`` section measures the upload overlap.
    """
    return _launch(
        m, None, nbr_idx, nbr_w, h, gain, off, rand_gain, comp_off,
        mask0, mask1, betas, noise_state, clamp_mask, clamp_values,
        None, None,
        sparse=True, noise_mode=NOISE_COUNTER, decimation=decimation,
        gather_perm=None, accumulate=False, collect_hist=False,
        n_visible=0, block_b=block_b, interpret=interpret,
        coord_offset=coord_offset, next_nbr_w=next_nbr_w, next_h=next_h,
        half_offset=half_offset, n_half=n_half)


# ---------------------------------------------------------------------------
# Kernel-resident halo exchange (hardware RDMA path)
# ---------------------------------------------------------------------------
#
# One resident launch per shard refreshes its halos MID-FLIGHT: at every
# `Sync.exchange_points()` half-sweep the kernel gathers its O(√N) boundary
# spins into a VMEM send buffer and `pltpu.make_async_remote_copy`s them
# into the row neighbor's second halo VMEM slot, double-buffered on
# exchange parity exactly like the PR-9 program stream.  `mode="barrier"`
# waits for the incoming copy before the next half-sweep consumes it;
# `mode="async"` installs the PREVIOUS exchange's values and lets the
# in-flight copy overlap the segment's compute — the same staleness
# contract as the host engine's pend-buffer.  Host CI cannot run RDMA:
# `REPRO_PALLAS_INTERPRET` runs the bit-exact emulation instead
# (ShardedEngine's fused-resident-exchange loop shape: the same launch
# split at exchange points via `half_offset`/`n_half`, ppermute between
# segments, one jitted graph).  This kernel compiles only on real TPU
# meshes and is pending on-TPU validation (ROADMAP).

_HALO_UP, _HALO_DN = 0, 1  # recv-buffer direction slots


def _exchange_kernel(*refs, S, tb, Np, B, n_loc, H, Hp, segments, mode,
                     has_clamp, accumulate, D, axis_name, n_row,
                     collective_id, stream):
    it = iter(refs)
    m0_ref = next(it)                         # (tb, Np) [local|hu|hd]
    idx_ref, w_ref = next(it), next(it)       # (Dp, Np)
    h_ref, g_ref, off_ref, rg_ref, co_ref = (next(it) for _ in range(5))
    mask0_ref, mask1_ref = next(it), next(it)
    betas_ref = next(it)                      # (S, tb)
    sendu_ref, sendd_ref = next(it), next(it)  # (1, Hp) boundary gathers
    clampm_ref = next(it) if has_clamp else None
    clampv_ref = next(it) if has_clamp else None
    meas_ref = next(it) if accumulate else None
    coords_ref = next(it)
    noise_in_ref = next(it)
    if stream:
        next_w_ref, next_h_ref = next(it), next(it)
    m_out_ref = next(it)
    noise_out_ref = next(it)
    if accumulate:
        ssum_out_ref, csum_out_ref = next(it), next(it)
    if stream:
        staged_w_out_ref, staged_h_out_ref = next(it), next(it)
    sbuf_ref = next(it)                       # (2, 2, tb, Hp) send slots
    rbuf_ref = next(it)                       # (2, 2, tb, Hp) recv slots
    send_sem = next(it)                       # DMA (2, 2) [dir, parity]
    recv_sem = next(it)                       # DMA (2, 2)
    if accumulate:
        ssum_ref, csum_ref = next(it), next(it)
    if stream:
        slot_w_ref, slot_h_ref = next(it), next(it)

    my = jax.lax.axis_index(axis_name)
    up_ok = my > 0                  # row above exists
    dn_ok = my < n_row - 1          # row below exists
    n_nbr = up_ok.astype(jnp.int32) + dn_ok.astype(jnp.int32)

    if accumulate:
        ssum_ref[...] = jnp.zeros_like(ssum_ref)
        csum_ref[...] = jnp.zeros_like(csum_ref)
    if stream:
        # double-buffered program upload staged up front, overlapping the
        # resident sweeps (shared launch with the halo refresh)
        slot_w_ref[...] = next_w_ref[...]
        slot_h_ref[...] = next_h_ref[...]

    hrow, grow = h_ref[...], g_ref[...]
    offrow, rgrow, corow = off_ref[...], rg_ref[...], co_ref[...]
    masks = (mask0_ref[...] != 0, mask1_ref[...] != 0)
    seed = noise_in_ref[0, 0]
    ctr0 = noise_in_ref[0, 1]
    row0 = coords_ref[0, 0]
    col0 = coords_ref[0, 1]
    rows = jax.lax.broadcasted_iota(jnp.uint32, (tb, Np), 0) + row0
    cols = jax.lax.broadcasted_iota(jnp.uint32, (tb, Np), 1) + col0

    # neighbor barrier before the first RDMA: nobody writes into a peer
    # still draining its previous launch
    barrier = pltpu.get_barrier_semaphore()

    @pl.when(up_ok)
    def _sig_up():
        pltpu.semaphore_signal(
            barrier, inc=1, device_id=(my - 1,),
            device_id_type=pltpu.DeviceIdType.LOGICAL)

    @pl.when(dn_ok)
    def _sig_dn():
        pltpu.semaphore_signal(
            barrier, inc=1, device_id=(my + 1,),
            device_id_type=pltpu.DeviceIdType.LOGICAL)

    pltpu.semaphore_wait(barrier, n_nbr)

    def start_exchange(m, parity):
        """Gather boundary spins and fire both neighbor RDMAs."""
        sbuf_ref[0, parity] = jnp.take(m, sendu_ref[0, :], axis=1)
        sbuf_ref[1, parity] = jnp.take(m, sendd_ref[0, :], axis=1)

        @pl.when(up_ok)
        def _send_up():
            # my first-row boundary becomes the UP neighbor's halo_dn
            pltpu.make_async_remote_copy(
                src_ref=sbuf_ref.at[0, parity],
                dst_ref=rbuf_ref.at[_HALO_DN, parity],
                send_sem=send_sem.at[0, parity],
                recv_sem=recv_sem.at[_HALO_DN, parity],
                device_id=(my - 1,),
                device_id_type=pltpu.DeviceIdType.LOGICAL).start()

        @pl.when(dn_ok)
        def _send_dn():
            # my last-row boundary becomes the DOWN neighbor's halo_up
            pltpu.make_async_remote_copy(
                src_ref=sbuf_ref.at[1, parity],
                dst_ref=rbuf_ref.at[_HALO_UP, parity],
                send_sem=send_sem.at[1, parity],
                recv_sem=recv_sem.at[_HALO_UP, parity],
                device_id=(my + 1,),
                device_id_type=pltpu.DeviceIdType.LOGICAL).start()

    def install_halos(m, parity):
        """Wait the incoming copies of `parity` and refresh halo columns."""
        @pl.when(up_ok)
        def _wait_up():
            pltpu.semaphore_wait(recv_sem.at[_HALO_UP, parity], 1)

        @pl.when(dn_ok)
        def _wait_dn():
            pltpu.semaphore_wait(recv_sem.at[_HALO_DN, parity], 1)
        hu = jnp.where(up_ok, rbuf_ref[_HALO_UP, parity][:, :H], 0.0)
        hd = jnp.where(dn_ok, rbuf_ref[_HALO_DN, parity][:, :H], 0.0)
        m = jax.lax.dynamic_update_slice(m, hu, (0, n_loc))
        return jax.lax.dynamic_update_slice(m, hd, (0, n_loc + H))

    def wait_sends(parity):
        @pl.when(up_ok)
        def _ws_up():
            pltpu.semaphore_wait(send_sem.at[0, parity], 1)

        @pl.when(dn_ok)
        def _ws_dn():
            pltpu.semaphore_wait(send_sem.at[1, parity], 1)

    def half_update(m, s_idx, c, half_j):
        ctr = ctr0 + half_j
        u = lfsr_mod.counter_uniform(seed, ctr, rows, cols)
        beta_col = betas_ref[pl.ds(s_idx, 1), :].reshape(tb, 1)
        acc = jnp.zeros((tb, Np), jnp.float32)
        for d in range(D):
            acc = acc + w_ref[pl.ds(d, 1), :] * jnp.take(
                m, idx_ref[d, :], axis=-1)
        act = jnp.tanh(beta_col * grow * (acc + hrow + offrow))
        decision = act + rgrow * u + corow
        new = jnp.where(decision >= 0.0, 1.0, -1.0)
        return jnp.where(masks[c], new, m)

    def impose_clamp(m):
        if has_clamp:
            return jnp.where(clampm_ref[...] != 0, clampv_ref[...], m)
        return m

    def sweep_stats(m, s_idx):
        wgt = meas_ref[pl.ds(s_idx, 1), :]
        row_ids = jax.lax.broadcasted_iota(jnp.int32, (tb, 1), 0)
        mv = jnp.where(row_ids < B, m, 0.0)
        ssum_ref[...] += wgt * jnp.sum(mv, axis=0, keepdims=True)
        for d in range(D):
            corr = jnp.sum(mv * jnp.take(mv, idx_ref[d, :], axis=-1),
                           axis=0, keepdims=True)
            csum_ref[pl.ds(d, 1), :] += wgt[0, 0] * corr

    m = m0_ref[...].astype(jnp.float32)
    n_ex = len(segments)
    for e, (h0, h1) in enumerate(segments):
        parity = e % 2
        if e >= 2:
            # reusing this parity's send slots: previous copy must be out
            wait_sends(parity)
        start_exchange(m, parity)
        if mode == "barrier":
            m = install_halos(m, parity)
        elif e > 0:
            # async: consume the PREVIOUS exchange's values; exchange e
            # stays in flight behind this segment's compute
            m = install_halos(m, (e - 1) % 2)
        # run the [h0, h1) half-sweep window (lead / full / tail — the
        # same structure as _kernel's segmented window)
        lead = h0 % 2
        n_full = (h1 - h0 - lead) // 2
        tail = (h1 - h0 - lead) % 2
        s0 = (h0 + lead) // 2
        if lead:
            m = impose_clamp(m)
            m = half_update(m, h0 // 2, 1, jnp.uint32(h0))
            if accumulate:
                sweep_stats(m, h0 // 2)

        def one_sweep(jj, m, s0=s0, base=h0 + lead):
            m = impose_clamp(m)
            for c in (0, 1):
                hj = (jnp.uint32(base)
                      + jnp.uint32(2) * jj.astype(jnp.uint32)
                      + jnp.uint32(c))
                m = half_update(m, s0 + jj, c, hj)
            if accumulate:
                sweep_stats(m, s0 + jj)
            return m

        m = jax.lax.fori_loop(0, n_full, one_sweep, m)
        if tail:
            m = impose_clamp(m)
            m = half_update(m, s0 + n_full, 0, jnp.uint32(h1 - 1))

    # drain every DMA still in flight before the kernel exits
    if mode != "barrier":
        # async: the final exchange is the NEXT launch's first consume
        # (the engine's pend buffer) — install it into the halo columns
        # so m_out carries it across the launch boundary
        m = install_halos(m, (n_ex - 1) % 2)
    for parity in range(min(n_ex, 2)):
        # sends not yet retired by the e>=2 slot-reuse waits: the last
        # exchange on each parity
        wait_sends(parity)

    m_out_ref[...] = m.astype(m_out_ref.dtype)
    noise_out_ref[0, 0] = seed
    noise_out_ref[0, 1] = ctr0 + jnp.uint32(2 * S)
    if accumulate:
        ssum_out_ref[...] = ssum_ref[...]
        csum_out_ref[...] = csum_ref[...]
    if stream:
        staged_w_out_ref[...] = slot_w_ref[...]
        staged_h_out_ref[...] = slot_h_ref[...]


def sweep_sparse_exchange_pallas(
    m_ext: jax.Array,             # (B, N_ext) [local | halo_up | halo_dn]
    nbr_idx: jax.Array,           # (D, N_ext) ext-local neighbor table
    nbr_w: jax.Array,             # (D, N_ext)
    h: jax.Array,
    gain: jax.Array,
    off: jax.Array,
    rand_gain: jax.Array,
    comp_off: jax.Array,
    mask0: jax.Array,             # (N_ext,) halo columns excluded
    mask1: jax.Array,
    betas: jax.Array,             # (S, B)
    noise_state: jax.Array,       # (2,) uint32
    send_up: jax.Array,           # (H,) local cols of the first-row verts
    send_dn: jax.Array,           # (H,) local cols of the last-row verts
    clamp_mask: jax.Array | None = None,
    clamp_values: jax.Array | None = None,
    measured: jax.Array | None = None,
    coord_offset: jax.Array | None = None,
    next_nbr_w: jax.Array | None = None,
    next_h: jax.Array | None = None,
    *,
    n_loc: int,
    halo: int,
    ex_pts: tuple,                # launch-relative half-sweep indices
    mode: str = "barrier",
    axis_name: str = "row",
    n_row: int,
    collective_id: int = 7,
    interpret: bool = False,
):
    """S resident sweeps with IN-KERNEL halo refresh at every exchange
    point — the hardware twin of the engine's fused-resident-exchange
    emulation (identical noise counters, identical exchange-point
    staleness), pending on-TPU validation.

    Must run under ``shard_map`` over a 1-D ``axis_name`` mesh of
    ``n_row`` devices.  Single batch tile (the exchange needs the whole
    shard's boundary at once).  Raises in interpret mode: host CI runs
    the segmented emulation (`ShardedEngine._local_sweeps`), which this
    kernel must match bit-for-bit on hardware.
    """
    if interpret:
        raise NotImplementedError(
            "in-kernel RDMA halo exchange needs a real TPU mesh; "
            "interpret mode runs the bit-exact segmented emulation "
            "(ShardedEngine's fused-resident-exchange loop shape)")
    if pltpu is None or _COMPILER_PARAMS is None:
        raise RuntimeError("pallas TPU backend unavailable")
    from repro.kernels.ref import halo_exchange_segments

    B, N = m_ext.shape
    S = betas.shape[0]
    H = halo
    D = nbr_idx.shape[0]
    segments = halo_exchange_segments(ex_pts, 2 * S)
    accumulate = measured is not None
    has_clamp = clamp_mask is not None and clamp_values is not None
    stream = next_nbr_w is not None
    if stream and accumulate:
        raise ValueError("program streaming excludes in-kernel moments")

    Np = _round_up(N, 128)
    Hp = _round_up(max(H, 1), 128)
    tb = _round_up(B, 8)
    Dp = _round_up(D, 8)

    row = lambda x: _pad_axis(
        jnp.asarray(x).reshape(1, -1).astype(jnp.float32), 128, 1)
    mp = _pad_axis(_pad_axis(m_ext, tb, 0), 128, 1)
    idxp = _pad_axis(_pad_axis(jnp.asarray(nbr_idx, jnp.int32), Dp, 0),
                     128, 1)
    wp = _pad_axis(_pad_axis(jnp.asarray(nbr_w, jnp.float32), Dp, 0),
                   128, 1)
    m0p = _pad_axis(jnp.asarray(mask0).reshape(1, -1).astype(jnp.int8),
                    128, 1, 0)
    m1p = _pad_axis(jnp.asarray(mask1).reshape(1, -1).astype(jnp.int8),
                    128, 1, 0)
    betasp = _pad_axis(jnp.asarray(betas, jnp.float32), tb, 1)
    sup = _pad_axis(jnp.asarray(send_up, jnp.int32).reshape(1, -1), 128, 1)
    sdn = _pad_axis(jnp.asarray(send_dn, jnp.int32).reshape(1, -1), 128, 1)

    full = lambda shape: pl.BlockSpec(shape, lambda: tuple(
        0 for _ in shape))
    in_specs = [full((tb, Np)), full((Dp, Np)), full((Dp, Np))]
    args = [mp, idxp, wp]
    in_specs += [full((1, Np))] * 7 + [full((S, tb)),
                                       full((1, Hp)), full((1, Hp))]
    args += [row(h), row(gain), row(off), row(rand_gain), row(comp_off),
             m0p, m1p, betasp, sup, sdn]
    if has_clamp:
        in_specs += [full((1, Np)), full((tb, Np))]
        args += [_pad_axis(jnp.asarray(clamp_mask).reshape(1, -1)
                           .astype(jnp.int8), 128, 1, 0),
                 _pad_axis(_pad_axis(
                     jnp.asarray(clamp_values, jnp.float32), tb, 0),
                     128, 1)]
    if accumulate:
        in_specs.append(full((S, 1)))
        args.append(jnp.asarray(measured, jnp.float32).reshape(S, 1))
    in_specs.append(full((1, 2)))
    args.append(jnp.zeros((1, 2), jnp.uint32) if coord_offset is None
                else jnp.asarray(coord_offset, jnp.uint32).reshape(1, 2))
    in_specs.append(full((1, 2)))
    args.append(jnp.asarray(noise_state, jnp.uint32).reshape(1, 2))
    if stream:
        in_specs += [full((Dp, Np)), full((1, Np))]
        args += [_pad_axis(_pad_axis(
            jnp.asarray(next_nbr_w, jnp.float32), Dp, 0), 128, 1),
            row(next_h)]

    out_shape = [jax.ShapeDtypeStruct((tb, Np), m_ext.dtype),
                 jax.ShapeDtypeStruct((1, 2), jnp.uint32)]
    out_specs = [full((tb, Np)), full((1, 2))]
    if accumulate:
        out_shape += [jax.ShapeDtypeStruct((1, Np), jnp.float32),
                      jax.ShapeDtypeStruct((Dp, Np), jnp.float32)]
        out_specs += [full((1, Np)), full((Dp, Np))]
    if stream:
        out_shape += [jax.ShapeDtypeStruct((Dp, Np), jnp.float32),
                      jax.ShapeDtypeStruct((1, Np), jnp.float32)]
        out_specs += [full((Dp, Np)), full((1, Np))]

    scratch = [_VMEM((2, 2, tb, Hp), jnp.float32),   # send slots
               _VMEM((2, 2, tb, Hp), jnp.float32),   # recv slots
               pltpu.SemaphoreType.DMA((2, 2)),
               pltpu.SemaphoreType.DMA((2, 2))]
    if accumulate:
        scratch += [_VMEM((1, Np), jnp.float32), _VMEM((Dp, Np),
                                                       jnp.float32)]
    if stream:
        scratch += [_VMEM((Dp, Np), jnp.float32), _VMEM((1, Np),
                                                        jnp.float32)]

    kw = {"compiler_params": _COMPILER_PARAMS(
        dimension_semantics=(), has_side_effects=True,
        collective_id=collective_id)}
    if stream:
        # stream excludes accumulate, so staged outputs sit at 2/3
        kw["input_output_aliases"] = {len(args) - 2: 2, len(args) - 1: 3}
    outs = pl.pallas_call(
        functools.partial(
            _exchange_kernel, S=S, tb=tb, Np=Np, B=B, n_loc=n_loc, H=H,
            Hp=Hp, segments=segments, mode=mode, has_clamp=has_clamp,
            accumulate=accumulate, D=D, axis_name=axis_name, n_row=n_row,
            collective_id=collective_id, stream=stream),
        grid=(),
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shape),
        scratch_shapes=scratch,
        interpret=False,
        **kw,
    )(*args)

    result = [outs[0][:B, :N], outs[1].reshape(2)]
    k = 2
    if accumulate:
        result += [outs[k][0, :N], outs[k + 1][:D, :N]]
        k += 2
    if stream:
        result += [outs[k][:D, :N], outs[k + 1][0, :N]]
    return tuple(result)
