"""Pallas TPU kernel: sweep-resident sampling engine (dense + block-sparse).

The chip's figure of merit is flips per nanosecond: all 440 neurons settle
in parallel with per-cell LFSR noise generated *in place*.  The per-half-
sweep kernel (pbit_update.py) still round-trips spins and noise through HBM
twice per sweep and leaves moment accumulation to separate jnp ops.  This
kernel closes that gap: one invocation executes S full chromatic sweeps
(both color half-sweeps) with

  * spins resident in VMEM for the whole S-sweep block,
  * noise generated inside the kernel — either counter mode (a stateless
    uint32 hash shared bit-for-bit with the host reference in
    core/lfsr.py::counter_uniform) or chip-faithful mode (the Galois LFSR of
    core/lfsr.py advanced in-kernel, including the bit-reversed-byte sharing
    trick, bit-exact with the host LFSR stream),
  * optional on-line first/second moment accumulation (spin sums and either
    the full m^T m Gram matrix or, in sparse mode, the per-slot edge
    correlations) in VMEM scratch, so CD's `gibbs_stats` never materializes
    per-sweep state in HBM,
  * optional on-line visible-pattern histogramming (one-hot reduction over
    2^n_visible bins per sweep), so `sample_visible_dist` never collects a
    trajectory.

Two weight layouts share the kernel body:

  * dense  (`sweep_fused_pallas`)  — W (N, N) in VMEM, neuron input is a
    (tb, N) x (N, N) matmul.  W alone is 4·N² bytes, which bounds the
    resident engine to roughly N <= 1.5k fp32 on a 16 MB-VMEM core.
  * sparse (`sweep_sparse_pallas`) — the Chimera-native fixed-degree slot
    layout (ChimeraGraph.neighbor_table): nbr_idx/nbr_w (D, N) with D = 6
    on the chip's graph.  Neuron input is D lane-gathers + multiply-adds —
    2·B·N·D FLOPs instead of 2·B·N², and 8·D·N weight bytes instead of
    4·N², so ≥32k-spin lattices stay VMEM-resident.  Slots accumulate in
    ascending-neighbor order, making the result bit-exact against both the
    sparse jnp ref and (zeros being additive identities) the dense path.

`sweep_sparse_stream_pallas` adds runtime weight streaming to the sparse
engine: the NEXT program's (D, N)/(N,) weights ride the same launch,
stage into a second VMEM slot at grid step 0 (overlapping the current
program's S sweeps — the SpikeHard DMA model), and come back as staged
outputs aliased in place over the inputs, ready to be the next launch's
resident program.

Grid: (B/tb,) over batch tiles; each program owns its rows for all S
sweeps.  Moment/histogram scratch accumulates across the (sequential)
batch-tile grid and is flushed to the output on the last program, the same
revisiting pattern as the K-loop accumulator in pbit_update.py.

Validated bit-for-bit in interpret mode against a scan of the
kernels/ref.py oracles with host-side noise (tests/test_sweep_fused.py,
tests/test_sparse.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import lfsr as lfsr_mod
from repro.kernels.util import pad_axis as _pad_axis
from repro.kernels.util import round_up as _round_up

try:  # compiler params class moved across jax versions
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
    _COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                               getattr(pltpu, "TPUCompilerParams", None))
except ImportError:  # pragma: no cover
    pltpu = None
    _VMEM = None
    _COMPILER_PARAMS = None

NOISE_COUNTER = "counter"
NOISE_LFSR = "lfsr"

MAX_HIST_VISIBLE = 12  # one-hot reduction over 2^nv bins; keep it VMEM-sane


def _kernel(*refs, S: int, tb: int, Np: int, n_b: int, B: int,
            noise_mode: str, has_clamp: bool, accumulate: bool,
            collect_hist: bool, decimation: int, sparse: bool, D: int,
            NBp: int, has_coords: bool, stream: bool = False):
    it = iter(refs)
    m0_ref = next(it)
    if sparse:
        idx_ref = next(it)                    # (Dp, Np) neighbor table
        w_ref = next(it)                      # (Dp, Np) slot weights
    else:
        w_ref = next(it)                      # (Np, Np) dense couplings
    h_ref, g_ref, off_ref, rg_ref, co_ref = (next(it) for _ in range(5))
    mask0_ref, mask1_ref = next(it), next(it)
    betas_ref = next(it)
    clampm_ref = next(it) if has_clamp else None
    clampv_ref = next(it) if has_clamp else None
    meas_ref = next(it) if (accumulate or collect_hist) else None
    vis_ref = next(it) if collect_hist else None   # (1, NVp) visible cols
    pow_ref = next(it) if collect_hist else None   # (1, NVp) 2^k bin powers
    perm_ref = next(it) if noise_mode == NOISE_LFSR else None
    coords_ref = next(it) if has_coords else None
    noise_in_ref = next(it)
    if stream:
        next_w_ref = next(it)                 # (Dp, Np) next program weights
        next_h_ref = next(it)                 # (1, Np) next program biases
    m_out_ref = next(it)
    noise_out_ref = next(it)
    if accumulate:
        ssum_out_ref, csum_out_ref = next(it), next(it)
    if collect_hist:
        hist_out_ref = next(it)
    if stream:
        staged_w_out_ref, staged_h_out_ref = next(it), next(it)
    if accumulate:
        ssum_ref, csum_ref = next(it), next(it)
    if collect_hist:
        hist_ref = next(it)
    if stream:
        slot_w_ref, slot_h_ref = next(it), next(it)

    i = pl.program_id(0)

    if accumulate:
        @pl.when(i == 0)
        def _zero_moments():
            ssum_ref[...] = jnp.zeros_like(ssum_ref)
            csum_ref[...] = jnp.zeros_like(csum_ref)
    if collect_hist:
        @pl.when(i == 0)
        def _zero_hist():
            hist_ref[...] = jnp.zeros_like(hist_ref)
    if stream:
        # double-buffered program upload (the SpikeHard DMA model): the
        # NEXT program's weights stream into the second VMEM slot up
        # front, before this launch's S resident sweeps touch the loop —
        # independent of the sweep dataflow, so the copy overlaps compute
        # on hardware.  Flushed to the staged outputs on the last block;
        # the host feeds them straight back as the following launch's
        # resident program (zero-copy: the next-program inputs alias the
        # staged outputs via input_output_aliases).
        @pl.when(i == 0)
        def _stage_next_program():
            slot_w_ref[...] = next_w_ref[...]
            slot_h_ref[...] = next_h_ref[...]

    if not sparse:
        w = w_ref[...]
    hrow, grow = h_ref[...], g_ref[...]
    offrow, rgrow, corow = off_ref[...], rg_ref[...], co_ref[...]
    masks = (mask0_ref[...] != 0, mask1_ref[...] != 0)

    if noise_mode == NOISE_COUNTER:
        seed = noise_in_ref[0, 0]
        ctr0 = noise_in_ref[0, 1]
        # (row0, col0) shift the hash coordinates to this block's place in
        # the GLOBAL (chain, node) grid — the sharded engine passes its
        # chain offset / first global node id so every shard regenerates
        # exactly its columns of the single-device stream
        row0 = coords_ref[0, 0] if has_coords else jnp.uint32(0)
        col0 = coords_ref[0, 1] if has_coords else jnp.uint32(0)
        rows = (jax.lax.broadcasted_iota(jnp.uint32, (tb, Np), 0)
                + (i * tb).astype(jnp.uint32) + row0)
        cols = jax.lax.broadcasted_iota(jnp.uint32, (tb, Np), 1) + col0
        noise_carry0 = jnp.zeros((), jnp.uint32)  # unused
    else:
        noise_carry0 = noise_in_ref[...]          # (tb, Cp) LFSR states
        perm_cols = perm_ref[0, :]                # node -> flat LFSR column

    def neuron_current(m):
        """Eqn 1 over the resident tile: matmul (dense) or D-slot gather."""
        if sparse:
            acc = jnp.zeros((tb, Np), jnp.float32)
            for d in range(D):
                acc = acc + w_ref[pl.ds(d, 1), :] * jnp.take(
                    m, idx_ref[d, :], axis=-1)
            return acc + hrow
        return jax.lax.dot_general(
            m, w, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) + hrow

    def one_sweep(s, carry):
        m, st = carry
        if has_clamp:
            m = jnp.where(clampm_ref[...] != 0, clampv_ref[...], m)
        beta_col = betas_ref[pl.ds(s, 1), :].reshape(tb, 1)
        for c in (0, 1):
            if noise_mode == NOISE_COUNTER:
                ctr = ctr0 + jnp.uint32(2) * s.astype(jnp.uint32) \
                    + jnp.uint32(c)
                u = lfsr_mod.counter_uniform(seed, ctr, rows, cols)
            else:
                st = lfsr_mod.lfsr_step_n(st, decimation)
                u = jnp.take(lfsr_mod.flat_cell_uniforms(st), perm_cols,
                             axis=-1)
            I = neuron_current(m)
            act = jnp.tanh(beta_col * grow * (I + offrow))
            decision = act + rgrow * u + corow
            new = jnp.where(decision >= 0.0, 1.0, -1.0)
            m = jnp.where(masks[c], new, m)
        if accumulate or collect_hist:
            wgt = meas_ref[pl.ds(s, 1), :]                      # (1, 1)
            # padded batch rows update like real chains; keep them out of
            # the statistics
            row_ids = (jax.lax.broadcasted_iota(jnp.int32, (tb, 1), 0)
                       + i * tb)
        if accumulate:
            mv = jnp.where(row_ids < B, m, 0.0)
            ssum_ref[...] += wgt * jnp.sum(mv, axis=0, keepdims=True)
            if sparse:
                for d in range(D):
                    corr = jnp.sum(
                        mv * jnp.take(mv, idx_ref[d, :], axis=-1),
                        axis=0, keepdims=True)                   # (1, Np)
                    csum_ref[pl.ds(d, 1), :] += wgt[0, 0] * corr
            else:
                csum_ref[...] += wgt[0, 0] * jax.lax.dot_general(
                    mv, mv, dimension_numbers=(((0,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)          # m^T m
        if collect_hist:
            mv_vis = jnp.take(m, vis_ref[0, :], axis=-1)        # (tb, NVp)
            codes = jnp.sum(
                jnp.where(mv_vis > 0, pow_ref[...], 0),
                axis=1, keepdims=True)                           # (tb, 1)
            bin_ids = jax.lax.broadcasted_iota(jnp.int32, (tb, NBp), 1)
            onehot = ((codes == bin_ids)
                      & (row_ids < B)).astype(jnp.float32)
            hist_ref[...] += wgt[0, 0] * jnp.sum(onehot, axis=0,
                                                 keepdims=True)
        return m, st

    m_fin, st_fin = jax.lax.fori_loop(
        0, S, one_sweep, (m0_ref[...].astype(jnp.float32), noise_carry0))
    m_out_ref[...] = m_fin.astype(m_out_ref.dtype)

    if noise_mode == NOISE_COUNTER:
        noise_out_ref[0, 0] = seed
        noise_out_ref[0, 1] = ctr0 + jnp.uint32(2 * S)
    else:
        noise_out_ref[...] = st_fin

    if accumulate:
        @pl.when(i == n_b - 1)
        def _flush_moments():
            ssum_out_ref[...] = ssum_ref[...]
            csum_out_ref[...] = csum_ref[...]
    if collect_hist:
        @pl.when(i == n_b - 1)
        def _flush_hist():
            hist_out_ref[...] = hist_ref[...]
    if stream:
        @pl.when(i == n_b - 1)
        def _flush_staged_program():
            staged_w_out_ref[...] = slot_w_ref[...]
            staged_h_out_ref[...] = slot_h_ref[...]


def _launch(
    m, dense_W, nbr_idx, nbr_w, h, gain, off, rand_gain, comp_off,
    mask0, mask1, betas, noise_state, clamp_mask, clamp_values, measured,
    visible_idx, *, sparse, noise_mode, decimation, gather_perm,
    accumulate, collect_hist, n_visible, block_b, interpret,
    coord_offset=None, next_nbr_w=None, next_h=None,
):
    """Shared plumbing for the dense and sparse sweep-resident engines."""
    B, N = m.shape
    S = betas.shape[0]
    out_dtype = m.dtype
    stream = next_nbr_w is not None
    if stream:
        if not sparse or noise_mode != NOISE_COUNTER:
            raise ValueError(
                "program streaming runs on the sparse counter-noise "
                "engine (the launch-resident serving configuration)")
        if next_h is None:
            raise ValueError("next_nbr_w without next_h")
        if accumulate or collect_hist or measured is not None:
            raise ValueError(
                "program streaming excludes in-kernel moment/histogram "
                "accumulation — a swapped program invalidates the "
                "accumulators mid-grid")
    # clamp_mask alone (freeze nodes at their current spins) is fully
    # handled by excluding the nodes from mask0/mask1; the kernel only
    # needs the clamp inputs when values are re-imposed every sweep
    has_clamp = clamp_mask is not None and clamp_values is not None
    accumulate = accumulate and measured is not None
    collect_hist = collect_hist and measured is not None
    if noise_mode not in (NOISE_COUNTER, NOISE_LFSR):
        raise ValueError(f"unknown noise_mode {noise_mode!r}")
    if collect_hist:
        if visible_idx is None:
            raise ValueError("collect_hist needs visible_idx")
        if not (0 < n_visible <= MAX_HIST_VISIBLE):
            raise ValueError(
                f"collect_hist supports 1..{MAX_HIST_VISIBLE} visible "
                f"nodes, got {n_visible}")
    if sparse:
        D = nbr_idx.shape[0]
    NB = 2 ** n_visible if collect_hist else 0

    if S == 0:  # empty schedule: identity, like a zero-length scan
        outs = [m, jnp.asarray(noise_state, jnp.uint32)]
        if accumulate:
            c_shape = (D, N) if sparse else (N, N)
            outs += [jnp.zeros((N,), jnp.float32),
                     jnp.zeros(c_shape, jnp.float32)]
        if collect_hist:
            outs.append(jnp.zeros((NB,), jnp.float32))
        if stream:
            outs += [jnp.asarray(next_nbr_w, jnp.float32),
                     jnp.asarray(next_h, jnp.float32)]
        return tuple(outs)

    Np = _round_up(N, 128)
    tb = min(block_b, _round_up(B, 8))
    Bp = _round_up(B, tb)
    n_b = Bp // tb

    mp = _pad_axis(_pad_axis(m, tb, 0), 128, 1)
    row = lambda x, v=0.0: _pad_axis(
        jnp.asarray(x).reshape(1, -1).astype(jnp.float32), 128, 1, v)
    hp, gp, op_, rgp, cop = (row(x) for x in
                             (h, gain, off, rand_gain, comp_off))
    m0p = _pad_axis(jnp.asarray(mask0).reshape(1, -1).astype(jnp.int8),
                    128, 1, 0)
    m1p = _pad_axis(jnp.asarray(mask1).reshape(1, -1).astype(jnp.int8),
                    128, 1, 0)
    betasp = _pad_axis(jnp.asarray(betas, jnp.float32), tb, 1)

    vec = lambda: pl.BlockSpec((1, Np), lambda i: (0, 0))
    in_specs = [pl.BlockSpec((tb, Np), lambda i: (i, 0))]       # m
    args = [mp]
    if sparse:
        Dp = _round_up(D, 8)
        idxp = _pad_axis(_pad_axis(
            jnp.asarray(nbr_idx, jnp.int32), Dp, 0), 128, 1)
        wp = _pad_axis(_pad_axis(
            jnp.asarray(nbr_w, jnp.float32), Dp, 0), 128, 1)
        in_specs += [pl.BlockSpec((Dp, Np), lambda i: (0, 0)),  # nbr_idx
                     pl.BlockSpec((Dp, Np), lambda i: (0, 0))]  # nbr_w
        args += [idxp, wp]
    else:
        Wp = _pad_axis(_pad_axis(dense_W, 128, 0), 128, 1)
        in_specs.append(pl.BlockSpec((Np, Np), lambda i: (0, 0)))  # W
        args.append(Wp)
    in_specs += [vec(), vec(), vec(), vec(), vec(),             # h,g,off,rg,co
                 vec(), vec(),                                  # color masks
                 pl.BlockSpec((S, tb), lambda i: (0, i))]       # betas
    args += [hp, gp, op_, rgp, cop, m0p, m1p, betasp]

    if has_clamp:
        in_specs.append(vec())
        args.append(_pad_axis(
            jnp.asarray(clamp_mask).reshape(1, -1).astype(jnp.int8),
            128, 1, 0))
        in_specs.append(pl.BlockSpec((tb, Np), lambda i: (i, 0)))
        args.append(_pad_axis(_pad_axis(
            jnp.asarray(clamp_values, jnp.float32), tb, 0), 128, 1))
    if accumulate or collect_hist:
        in_specs.append(pl.BlockSpec((S, 1), lambda i: (0, 0)))
        args.append(jnp.asarray(measured, jnp.float32).reshape(S, 1))
    NBp = 0
    if collect_hist:
        NVp = _round_up(n_visible, 128)
        NBp = _round_up(NB, 128)
        visp = _pad_axis(
            jnp.asarray(visible_idx, jnp.int32).reshape(1, -1), 128, 1, 0)
        powp = _pad_axis(jnp.asarray(
            2 ** np.arange(n_visible, dtype=np.int32)).reshape(1, -1),
            128, 1, 0)
        in_specs += [pl.BlockSpec((1, NVp), lambda i: (0, 0)),
                     pl.BlockSpec((1, NVp), lambda i: (0, 0))]
        args += [visp, powp]

    has_coords = coord_offset is not None
    if has_coords:
        if noise_mode != NOISE_COUNTER:
            raise ValueError(
                "coord_offset shifts the counter hash's (chain, node) "
                "coordinates; the lfsr mode carries its cell band in the "
                "state instead")
        in_specs.append(pl.BlockSpec((1, 2), lambda i: (0, 0)))
        args.append(jnp.asarray(coord_offset, jnp.uint32).reshape(1, 2))
    if noise_mode == NOISE_COUNTER:
        in_specs.append(pl.BlockSpec((1, 2), lambda i: (0, 0)))
        args.append(jnp.asarray(noise_state, jnp.uint32).reshape(1, 2))
        noise_out_shape = jax.ShapeDtypeStruct((1, 2), jnp.uint32)
        noise_out_spec = pl.BlockSpec((1, 2), lambda i: (0, 0))
    else:
        if gather_perm is None:
            raise ValueError("lfsr noise_mode needs gather_perm "
                             "(see core/lfsr.py::node_gather_perm)")
        C = noise_state.shape[-1]
        Cp = _round_up(C, 128)
        # remap flat columns from the C-cell layout to the padded-Cp layout
        p = np.asarray(gather_perm, np.int64)
        p = (p // C) * Cp + (p % C)
        perm_padded = np.concatenate(
            [p, np.zeros(Np - N, np.int64)]).astype(np.int32)
        in_specs.append(pl.BlockSpec((1, Np), lambda i: (0, 0)))
        args.append(jnp.asarray(perm_padded).reshape(1, Np))
        stp = _pad_axis(_pad_axis(jnp.asarray(noise_state, jnp.uint32),
                                  tb, 0, 1), 128, 1, 1)
        in_specs.append(pl.BlockSpec((tb, Cp), lambda i: (i, 0)))
        args.append(stp)
        noise_out_shape = jax.ShapeDtypeStruct((Bp, Cp), jnp.uint32)
        noise_out_spec = pl.BlockSpec((tb, Cp), lambda i: (i, 0))

    aliases = {}
    if stream:
        # the next program rides the SAME launch as the current sweeps:
        # two O(D·N) operands appended after the noise state, aliased to
        # the staged outputs (in-place buffer handoff — the upload costs
        # no extra HBM round-trip, matching the chip's SPI-write-during-
        # anneal overlap)
        i_next = len(args)
        in_specs += [pl.BlockSpec((Dp, Np), lambda i: (0, 0)),
                     pl.BlockSpec((1, Np), lambda i: (0, 0))]
        args += [_pad_axis(_pad_axis(
            jnp.asarray(next_nbr_w, jnp.float32), Dp, 0), 128, 1),
            row(next_h)]
        aliases = {i_next: 2, i_next + 1: 3}

    out_shape = [jax.ShapeDtypeStruct((Bp, Np), out_dtype), noise_out_shape]
    out_specs = [pl.BlockSpec((tb, Np), lambda i: (i, 0)), noise_out_spec]
    scratch = []
    if accumulate:
        c_shape = (Dp, Np) if sparse else (Np, Np)
        out_shape += [jax.ShapeDtypeStruct((1, Np), jnp.float32),
                      jax.ShapeDtypeStruct(c_shape, jnp.float32)]
        out_specs += [pl.BlockSpec((1, Np), lambda i: (0, 0)),
                      pl.BlockSpec(c_shape, lambda i: (0, 0))]
        scratch += [_VMEM((1, Np), jnp.float32), _VMEM(c_shape, jnp.float32)]
    if collect_hist:
        out_shape.append(jax.ShapeDtypeStruct((1, NBp), jnp.float32))
        out_specs.append(pl.BlockSpec((1, NBp), lambda i: (0, 0)))
        scratch.append(_VMEM((1, NBp), jnp.float32))
    if stream:
        out_shape += [jax.ShapeDtypeStruct((Dp, Np), jnp.float32),
                      jax.ShapeDtypeStruct((1, Np), jnp.float32)]
        out_specs += [pl.BlockSpec((Dp, Np), lambda i: (0, 0)),
                      pl.BlockSpec((1, Np), lambda i: (0, 0))]
        scratch += [_VMEM((Dp, Np), jnp.float32),
                    _VMEM((1, Np), jnp.float32)]

    kw = {}
    if not interpret and _COMPILER_PARAMS is not None:
        kw["compiler_params"] = _COMPILER_PARAMS(
            dimension_semantics=("arbitrary",))
    if aliases:
        kw["input_output_aliases"] = aliases
    outs = pl.pallas_call(
        functools.partial(
            _kernel, S=S, tb=tb, Np=Np, n_b=n_b, B=B,
            noise_mode=noise_mode, has_clamp=has_clamp,
            accumulate=accumulate, collect_hist=collect_hist,
            decimation=decimation, sparse=sparse,
            D=D if sparse else 0, NBp=NBp, has_coords=has_coords,
            stream=stream),
        grid=(n_b,),
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shape),
        scratch_shapes=scratch,
        interpret=interpret,
        **kw,
    )(*args)

    result = [outs[0][:B, :N]]
    if noise_mode == NOISE_COUNTER:
        result.append(outs[1].reshape(2))
    else:
        result.append(outs[1][:B, :noise_state.shape[-1]])
    k = 2
    if accumulate:
        result.append(outs[k][0, :N])
        result.append(outs[k + 1][:D, :N] if sparse else outs[k + 1][:N, :N])
        k += 2
    if collect_hist:
        result.append(outs[k][0, :NB])
        k += 1
    if stream:
        result.append(outs[k][:D, :N])
        result.append(outs[k + 1][0, :N])
    return tuple(result)


@functools.partial(
    jax.jit,
    static_argnames=("noise_mode", "decimation", "gather_perm", "accumulate",
                     "collect_hist", "n_visible", "block_b", "interpret"),
)
def sweep_fused_pallas(
    m: jax.Array,                 # (B, N) spins in {-1, +1}
    W: jax.Array,                 # (N, N) directional couplings
    h: jax.Array,
    gain: jax.Array,
    off: jax.Array,
    rand_gain: jax.Array,
    comp_off: jax.Array,
    mask0: jax.Array,             # (N,) bool — color-0 update set (minus clamps)
    mask1: jax.Array,             # (N,) bool — color-1 update set (minus clamps)
    betas: jax.Array,             # (S, B) per-sweep, per-chain inverse temps
    noise_state: jax.Array,       # counter: (2,) uint32; lfsr: (B, C) uint32
    clamp_mask: jax.Array | None = None,     # (N,) bool
    clamp_values: jax.Array | None = None,   # (B, N)
    measured: jax.Array | None = None,       # (S,) statistic weights, or None
    visible_idx: jax.Array | None = None,    # (n_visible,) histogram nodes
    coord_offset: jax.Array | None = None,   # (2,) uint32 (row0, col0)
    *,
    noise_mode: str = NOISE_COUNTER,
    decimation: int = 8,
    gather_perm: tuple | None = None,   # node -> flat LFSR column (length N)
    accumulate: bool = False,
    collect_hist: bool = False,
    n_visible: int = 0,
    block_b: int = 128,
    interpret: bool = True,
):
    """Run S resident sweeps, dense layout.

    Returns ``(m', noise_state'[, s_sum, c_sum][, hist])``.
    s_sum: (N,) sum of spins over (chains x measured sweeps); c_sum: (N, N)
    accumulated Gram matrix sum_meas m^T m — extract edge correlations as
    ``c_sum[e0, e1]``.  hist: (2^n_visible,) weighted counts of visible bit
    patterns (energy.empirical_visible_dist code order).  All need dividing
    by their sample counts.  ``coord_offset`` (counter mode only) shifts
    the in-kernel hash to global (chain, node) coordinates — the sharded
    per-shard launch passes (chain0, node0) so each shard regenerates its
    own columns of the single-device noise stream.
    """
    return _launch(
        m, W, None, None, h, gain, off, rand_gain, comp_off, mask0, mask1,
        betas, noise_state, clamp_mask, clamp_values, measured, visible_idx,
        sparse=False, noise_mode=noise_mode, decimation=decimation,
        gather_perm=gather_perm, accumulate=accumulate,
        collect_hist=collect_hist, n_visible=n_visible, block_b=block_b,
        interpret=interpret, coord_offset=coord_offset)


@functools.partial(
    jax.jit,
    static_argnames=("noise_mode", "decimation", "gather_perm", "accumulate",
                     "collect_hist", "n_visible", "block_b", "interpret"),
)
def sweep_sparse_pallas(
    m: jax.Array,                 # (B, N) spins in {-1, +1}
    nbr_idx: jax.Array,           # (D, N) int32 neighbor table
    nbr_w: jax.Array,             # (D, N) per-slot couplings
    h: jax.Array,
    gain: jax.Array,
    off: jax.Array,
    rand_gain: jax.Array,
    comp_off: jax.Array,
    mask0: jax.Array,
    mask1: jax.Array,
    betas: jax.Array,             # (S, B)
    noise_state: jax.Array,
    clamp_mask: jax.Array | None = None,
    clamp_values: jax.Array | None = None,
    measured: jax.Array | None = None,
    visible_idx: jax.Array | None = None,
    coord_offset: jax.Array | None = None,
    *,
    noise_mode: str = NOISE_COUNTER,
    decimation: int = 8,
    gather_perm: tuple | None = None,
    accumulate: bool = False,
    collect_hist: bool = False,
    n_visible: int = 0,
    block_b: int = 128,
    interpret: bool = True,
):
    """Run S resident sweeps on the Chimera-native fixed-degree layout.

    Same contract as `sweep_fused_pallas` except weights are the (D, N)
    slot layout and the second-moment output is the per-slot edge
    correlation ``c_slots[d, i] = Σ m_i · m_{nbr_idx[d, i]}`` instead of a
    Gram matrix — read edge (i, j) at ``c_slots[slot_of(i→j), i]`` (see
    ChimeraGraph.edge_slots).  Never materializes anything O(N²).
    """
    return _launch(
        m, None, nbr_idx, nbr_w, h, gain, off, rand_gain, comp_off,
        mask0, mask1, betas, noise_state, clamp_mask, clamp_values,
        measured, visible_idx,
        sparse=True, noise_mode=noise_mode, decimation=decimation,
        gather_perm=gather_perm, accumulate=accumulate,
        collect_hist=collect_hist, n_visible=n_visible, block_b=block_b,
        interpret=interpret, coord_offset=coord_offset)


@functools.partial(
    jax.jit,
    static_argnames=("decimation", "block_b", "interpret"),
)
def sweep_sparse_stream_pallas(
    m: jax.Array,                 # (B, N) spins in {-1, +1}
    nbr_idx: jax.Array,           # (D, N) int32 neighbor table
    nbr_w: jax.Array,             # (D, N) CURRENT program's slot weights
    h: jax.Array,                 # (N,)   CURRENT program's biases
    gain: jax.Array,
    off: jax.Array,
    rand_gain: jax.Array,
    comp_off: jax.Array,
    mask0: jax.Array,
    mask1: jax.Array,
    betas: jax.Array,             # (S, B)
    noise_state: jax.Array,       # (2,) uint32 counter state
    next_nbr_w: jax.Array,        # (D, N) NEXT program's slot weights
    next_h: jax.Array,            # (N,)   NEXT program's biases
    clamp_mask: jax.Array | None = None,
    clamp_values: jax.Array | None = None,
    coord_offset: jax.Array | None = None,
    *,
    decimation: int = 8,
    block_b: int = 128,
    interpret: bool = True,
):
    """`sweep_sparse_pallas` with a double-buffered program upload: run S
    resident sweeps of the CURRENT program while the NEXT program's
    weights stream into a second VMEM slot.

    Returns ``(m', noise_state', staged_w, staged_h)`` where
    ``staged_w``/``staged_h`` are the next program, already device-
    resident: feed them back as this call's ``nbr_w``/``h`` on the next
    launch.  The next-program inputs alias the staged outputs
    (`input_output_aliases`), so the handoff is an in-place buffer swap,
    and the stage copy runs at grid step 0 — independent of the sweep
    loop, overlapping compute on hardware (the SpikeHard DMA model: the
    chip accepts the next problem's SPI write while the current anneal
    runs).  Counter noise only, no in-kernel accumulation (a swapped
    program would invalidate mid-grid moments).  Per-program results are
    bit-identical to serialized `sweep_sparse_pallas` launches — the
    benchmark ``weight_streaming`` section measures the upload overlap.
    """
    return _launch(
        m, None, nbr_idx, nbr_w, h, gain, off, rand_gain, comp_off,
        mask0, mask1, betas, noise_state, clamp_mask, clamp_values,
        None, None,
        sparse=True, noise_mode=NOISE_COUNTER, decimation=decimation,
        gather_perm=None, accumulate=False, collect_hist=False,
        n_visible=0, block_b=block_b, interpret=interpret,
        coord_offset=coord_offset, next_nbr_w=next_nbr_w, next_h=next_h)
