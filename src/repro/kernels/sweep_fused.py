"""Pallas TPU kernel: sweep-resident sampling engine.

The chip's figure of merit is flips per nanosecond: all 440 neurons settle
in parallel with per-cell LFSR noise generated *in place*.  The per-half-
sweep kernel (pbit_update.py) still round-trips spins and noise through HBM
twice per sweep and leaves moment accumulation to separate jnp ops.  This
kernel closes that gap: one invocation executes S full chromatic sweeps
(both color half-sweeps) with

  * spins resident in VMEM for the whole S-sweep block,
  * noise generated inside the kernel — either counter mode (a stateless
    uint32 hash shared bit-for-bit with the host reference in
    core/lfsr.py::counter_uniform) or chip-faithful mode (the Galois LFSR of
    core/lfsr.py advanced in-kernel, including the bit-reversed-byte sharing
    trick, bit-exact with the host LFSR stream),
  * optional on-line first/second moment accumulation (spin sums and the
    full m^T m Gram matrix, MXU food) in VMEM scratch, so CD's
    `gibbs_stats` never materializes per-sweep state in HBM.

Grid: (B/tb,) over batch tiles; each program owns its rows for all S
sweeps.  W lives fully in VMEM, which bounds the problem size to roughly
N <= 1.5k fp32 on a 16 MB-VMEM core — the chip itself is N=440.  Larger N
should fall back to the tiled per-half-sweep kernel (see docs/kernels.md).
Moment scratch accumulates across the (sequential) batch-tile grid and is
flushed to the output on the last program, the same revisiting pattern as
the K-loop accumulator in pbit_update.py.

Validated bit-for-bit in interpret mode against a scan of the
kernels/ref.py oracle with host-side noise (tests/test_sweep_fused.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import lfsr as lfsr_mod
from repro.kernels.util import pad_axis as _pad_axis
from repro.kernels.util import round_up as _round_up

try:  # compiler params class moved across jax versions
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
    _COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                               getattr(pltpu, "TPUCompilerParams", None))
except ImportError:  # pragma: no cover
    pltpu = None
    _VMEM = None
    _COMPILER_PARAMS = None

NOISE_COUNTER = "counter"
NOISE_LFSR = "lfsr"


def _kernel(*refs, S: int, tb: int, Np: int, n_b: int, B: int,
            noise_mode: str, has_clamp: bool, accumulate: bool,
            decimation: int):
    it = iter(refs)
    m0_ref = next(it)
    w_ref = next(it)
    h_ref, g_ref, off_ref, rg_ref, co_ref = (next(it) for _ in range(5))
    mask0_ref, mask1_ref = next(it), next(it)
    betas_ref = next(it)
    clampm_ref = next(it) if has_clamp else None
    clampv_ref = next(it) if has_clamp else None
    meas_ref = next(it) if accumulate else None
    perm_ref = next(it) if noise_mode == NOISE_LFSR else None
    noise_in_ref = next(it)
    m_out_ref = next(it)
    noise_out_ref = next(it)
    if accumulate:
        ssum_out_ref, csum_out_ref = next(it), next(it)
        ssum_ref, csum_ref = next(it), next(it)

    i = pl.program_id(0)

    if accumulate:
        @pl.when(i == 0)
        def _zero_moments():
            ssum_ref[...] = jnp.zeros_like(ssum_ref)
            csum_ref[...] = jnp.zeros_like(csum_ref)

    w = w_ref[...]
    hrow, grow = h_ref[...], g_ref[...]
    offrow, rgrow, corow = off_ref[...], rg_ref[...], co_ref[...]
    masks = (mask0_ref[...] != 0, mask1_ref[...] != 0)

    if noise_mode == NOISE_COUNTER:
        seed = noise_in_ref[0, 0]
        ctr0 = noise_in_ref[0, 1]
        rows = (jax.lax.broadcasted_iota(jnp.uint32, (tb, Np), 0)
                + (i * tb).astype(jnp.uint32))
        cols = jax.lax.broadcasted_iota(jnp.uint32, (tb, Np), 1)
        noise_carry0 = jnp.zeros((), jnp.uint32)  # unused
    else:
        noise_carry0 = noise_in_ref[...]          # (tb, Cp) LFSR states
        perm_cols = perm_ref[0, :]                # node -> flat LFSR column

    def one_sweep(s, carry):
        m, st = carry
        if has_clamp:
            m = jnp.where(clampm_ref[...] != 0, clampv_ref[...], m)
        beta_col = betas_ref[pl.ds(s, 1), :].reshape(tb, 1)
        for c in (0, 1):
            if noise_mode == NOISE_COUNTER:
                ctr = ctr0 + jnp.uint32(2) * s.astype(jnp.uint32) \
                    + jnp.uint32(c)
                u = lfsr_mod.counter_uniform(seed, ctr, rows, cols)
            else:
                st = lfsr_mod.lfsr_step_n(st, decimation)
                u = jnp.take(lfsr_mod.flat_cell_uniforms(st), perm_cols,
                             axis=-1)
            I = jax.lax.dot_general(
                m, w, dimension_numbers=(((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) + hrow
            act = jnp.tanh(beta_col * grow * (I + offrow))
            decision = act + rgrow * u + corow
            new = jnp.where(decision >= 0.0, 1.0, -1.0)
            m = jnp.where(masks[c], new, m)
        if accumulate:
            wgt = meas_ref[pl.ds(s, 1), :]                      # (1, 1)
            # padded batch rows update like real chains; keep them out of
            # the moments
            row_ids = (jax.lax.broadcasted_iota(jnp.int32, (tb, 1), 0)
                       + i * tb)
            mv = jnp.where(row_ids < B, m, 0.0)
            ssum_ref[...] += wgt * jnp.sum(mv, axis=0, keepdims=True)
            csum_ref[...] += wgt[0, 0] * jax.lax.dot_general(
                mv, mv, dimension_numbers=(((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)             # m^T m
        return m, st

    m_fin, st_fin = jax.lax.fori_loop(
        0, S, one_sweep, (m0_ref[...].astype(jnp.float32), noise_carry0))
    m_out_ref[...] = m_fin.astype(m_out_ref.dtype)

    if noise_mode == NOISE_COUNTER:
        noise_out_ref[0, 0] = seed
        noise_out_ref[0, 1] = ctr0 + jnp.uint32(2 * S)
    else:
        noise_out_ref[...] = st_fin

    if accumulate:
        @pl.when(i == n_b - 1)
        def _flush_moments():
            ssum_out_ref[...] = ssum_ref[...]
            csum_out_ref[...] = csum_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("noise_mode", "decimation", "gather_perm", "accumulate",
                     "block_b", "interpret"),
)
def sweep_fused_pallas(
    m: jax.Array,                 # (B, N) spins in {-1, +1}
    W: jax.Array,                 # (N, N) directional couplings
    h: jax.Array,
    gain: jax.Array,
    off: jax.Array,
    rand_gain: jax.Array,
    comp_off: jax.Array,
    mask0: jax.Array,             # (N,) bool — color-0 update set (minus clamps)
    mask1: jax.Array,             # (N,) bool — color-1 update set (minus clamps)
    betas: jax.Array,             # (S, B) per-sweep, per-chain inverse temps
    noise_state: jax.Array,       # counter: (2,) uint32; lfsr: (B, C) uint32
    clamp_mask: jax.Array | None = None,     # (N,) bool
    clamp_values: jax.Array | None = None,   # (B, N)
    measured: jax.Array | None = None,       # (S,) moment weights, or None
    *,
    noise_mode: str = NOISE_COUNTER,
    decimation: int = 8,
    gather_perm: tuple | None = None,   # node -> flat LFSR column (length N)
    accumulate: bool = False,
    block_b: int = 128,
    interpret: bool = True,
):
    """Run S resident sweeps.  Returns (m', noise_state'[, s_sum, c_sum]).

    s_sum: (N,) sum of spins over (chains x measured sweeps); c_sum: (N, N)
    accumulated Gram matrix sum_meas m^T m — extract edge correlations as
    ``c_sum[e0, e1]``.  Both need dividing by (B * sum(measured)).
    """
    B, N = m.shape
    S = betas.shape[0]
    out_dtype = m.dtype
    # clamp_mask alone (freeze nodes at their current spins) is fully
    # handled by excluding the nodes from mask0/mask1; the kernel only
    # needs the clamp inputs when values are re-imposed every sweep
    has_clamp = clamp_mask is not None and clamp_values is not None
    accumulate = accumulate and measured is not None
    if noise_mode not in (NOISE_COUNTER, NOISE_LFSR):
        raise ValueError(f"unknown noise_mode {noise_mode!r}")
    if S == 0:  # empty schedule: identity, like a zero-length scan
        noise_out = jnp.asarray(noise_state, jnp.uint32)
        if accumulate:
            return (m, noise_out, jnp.zeros((N,), jnp.float32),
                    jnp.zeros((N, N), jnp.float32))
        return m, noise_out

    Np = _round_up(N, 128)
    tb = min(block_b, _round_up(B, 8))
    Bp = _round_up(B, tb)
    n_b = Bp // tb

    mp = _pad_axis(_pad_axis(m, tb, 0), 128, 1)
    Wp = _pad_axis(_pad_axis(W, 128, 0), 128, 1)
    row = lambda x, v=0.0: _pad_axis(
        jnp.asarray(x).reshape(1, -1).astype(jnp.float32), 128, 1, v)
    hp, gp, op_, rgp, cop = (row(x) for x in
                             (h, gain, off, rand_gain, comp_off))
    m0p = _pad_axis(jnp.asarray(mask0).reshape(1, -1).astype(jnp.int8),
                    128, 1, 0)
    m1p = _pad_axis(jnp.asarray(mask1).reshape(1, -1).astype(jnp.int8),
                    128, 1, 0)
    betasp = _pad_axis(jnp.asarray(betas, jnp.float32), tb, 1)

    vec = lambda: pl.BlockSpec((1, Np), lambda i: (0, 0))
    in_specs = [
        pl.BlockSpec((tb, Np), lambda i: (i, 0)),      # m
        pl.BlockSpec((Np, Np), lambda i: (0, 0)),      # W (VMEM-resident)
        vec(), vec(), vec(), vec(), vec(),             # h, g, off, rg, co
        vec(), vec(),                                  # color masks (int8)
        pl.BlockSpec((S, tb), lambda i: (0, i)),       # betas
    ]
    args = [mp, Wp, hp, gp, op_, rgp, cop, m0p, m1p, betasp]

    if has_clamp:
        in_specs.append(vec())
        args.append(_pad_axis(
            jnp.asarray(clamp_mask).reshape(1, -1).astype(jnp.int8),
            128, 1, 0))
        in_specs.append(pl.BlockSpec((tb, Np), lambda i: (i, 0)))
        args.append(_pad_axis(_pad_axis(
            jnp.asarray(clamp_values, jnp.float32), tb, 0), 128, 1))
    if accumulate:
        in_specs.append(pl.BlockSpec((S, 1), lambda i: (0, 0)))
        args.append(jnp.asarray(measured, jnp.float32).reshape(S, 1))

    if noise_mode == NOISE_COUNTER:
        in_specs.append(pl.BlockSpec((1, 2), lambda i: (0, 0)))
        args.append(jnp.asarray(noise_state, jnp.uint32).reshape(1, 2))
        noise_out_shape = jax.ShapeDtypeStruct((1, 2), jnp.uint32)
        noise_out_spec = pl.BlockSpec((1, 2), lambda i: (0, 0))
    else:
        if gather_perm is None:
            raise ValueError("lfsr noise_mode needs gather_perm "
                             "(see core/lfsr.py::node_gather_perm)")
        C = noise_state.shape[-1]
        Cp = _round_up(C, 128)
        # remap flat columns from the C-cell layout to the padded-Cp layout
        p = np.asarray(gather_perm, np.int64)
        p = (p // C) * Cp + (p % C)
        perm_padded = np.concatenate(
            [p, np.zeros(Np - N, np.int64)]).astype(np.int32)
        in_specs.append(pl.BlockSpec((1, Np), lambda i: (0, 0)))
        args.append(jnp.asarray(perm_padded).reshape(1, Np))
        stp = _pad_axis(_pad_axis(jnp.asarray(noise_state, jnp.uint32),
                                  tb, 0, 1), 128, 1, 1)
        in_specs.append(pl.BlockSpec((tb, Cp), lambda i: (i, 0)))
        args.append(stp)
        noise_out_shape = jax.ShapeDtypeStruct((Bp, Cp), jnp.uint32)
        noise_out_spec = pl.BlockSpec((tb, Cp), lambda i: (i, 0))

    out_shape = [jax.ShapeDtypeStruct((Bp, Np), out_dtype), noise_out_shape]
    out_specs = [pl.BlockSpec((tb, Np), lambda i: (i, 0)), noise_out_spec]
    scratch = []
    if accumulate:
        out_shape += [jax.ShapeDtypeStruct((1, Np), jnp.float32),
                      jax.ShapeDtypeStruct((Np, Np), jnp.float32)]
        out_specs += [pl.BlockSpec((1, Np), lambda i: (0, 0)),
                      pl.BlockSpec((Np, Np), lambda i: (0, 0))]
        scratch = [_VMEM((1, Np), jnp.float32),
                   _VMEM((Np, Np), jnp.float32)]

    kw = {}
    if not interpret and _COMPILER_PARAMS is not None:
        kw["compiler_params"] = _COMPILER_PARAMS(
            dimension_semantics=("arbitrary",))
    outs = pl.pallas_call(
        functools.partial(
            _kernel, S=S, tb=tb, Np=Np, n_b=n_b, B=B,
            noise_mode=noise_mode, has_clamp=has_clamp,
            accumulate=accumulate, decimation=decimation),
        grid=(n_b,),
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shape),
        scratch_shapes=scratch,
        interpret=interpret,
        **kw,
    )(*args)

    m_out = outs[0][:B, :N]
    if noise_mode == NOISE_COUNTER:
        noise_out = outs[1].reshape(2)
    else:
        noise_out = outs[1][:B, :noise_state.shape[-1]]
    if accumulate:
        return m_out, noise_out, outs[2][0, :N], outs[3][:N, :N]
    return m_out, noise_out
