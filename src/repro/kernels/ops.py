"""Jitted public wrappers around the Pallas kernels.

`make_kernel_half_sweep` adapts the per-half-sweep kernel to the sampler's
`half_sweep(m, chip, update_mask, beta, u)` signature (see core/pbit.py).
`sparse_half_sweep` is the same adapter for the Chimera-native fixed-degree
slot layout (jnp gather path — the "sparse" backend).
`fused_sweeps` adapts the sweep-resident engine (kernels/sweep_fused.py) —
dense or block-sparse — to the chip + graph-color view the backend API in
core/pbit.py works with, so the whole CD / annealing / tempering stack can
run through any kernel with one flag (see docs/kernels.md).
`fused_visible_hist` is the streaming visible-pattern histogram entry point
used by cd.sample_visible_dist.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.hardware import EffectiveChip
from repro.kernels.pbit_update import pbit_half_sweep_pallas
from repro.kernels.ref import pbit_half_sweep_ref, pbit_sparse_half_sweep_ref
from repro.kernels.sweep_fused import sweep_fused_pallas, sweep_sparse_pallas


def default_interpret() -> bool:
    """interpret=True unless we are actually on TPU."""
    if os.environ.get("REPRO_PALLAS_INTERPRET"):
        return os.environ["REPRO_PALLAS_INTERPRET"] == "1"
    return jax.default_backend() != "tpu"


def make_kernel_half_sweep(block_b: int = 128, block_n: int = 128,
                           block_k: int = 512,
                           interpret: bool | None = None):
    interp = default_interpret() if interpret is None else interpret

    def half_sweep(m, chip: EffectiveChip, update_mask, beta, u):
        return pbit_half_sweep_pallas(
            m, chip.W, chip.h, chip.tanh_gain, chip.tanh_offset,
            chip.rand_gain, chip.comp_offset, update_mask, beta, u,
            block_b=block_b, block_n=block_n, block_k=block_k,
            interpret=interp)

    return half_sweep


def ref_half_sweep(m, chip: EffectiveChip, update_mask, beta, u):
    return pbit_half_sweep_ref(
        m, chip.W, chip.h, chip.tanh_gain, chip.tanh_offset,
        chip.rand_gain, chip.comp_offset, update_mask, beta, u)


def _require_sparse(chip: EffectiveChip) -> None:
    if chip.nbr_w is None or chip.nbr_idx is None:
        raise ValueError(
            "sparse backend needs a chip carrying the neighbor-table "
            "layout; program with neighbors=graph.neighbor_table()[0], use "
            "hardware.attach_sparse, or hardware.program_weights_sparse")


def sparse_half_sweep(m, chip: EffectiveChip, update_mask, beta, u):
    """jnp half-sweep on the fixed-degree slot layout (no dense W)."""
    _require_sparse(chip)
    return pbit_sparse_half_sweep_ref(
        m, chip.nbr_idx, chip.nbr_w, chip.h, chip.tanh_gain,
        chip.tanh_offset, chip.rand_gain, chip.comp_offset,
        update_mask, beta, u)


def _fused_common(chip, color, betas, B, noise_spec, clamp_mask, sparse):
    if noise_spec is None or noise_spec.kind not in ("counter", "lfsr"):
        kind = None if noise_spec is None else noise_spec.kind
        raise ValueError(
            f"fused backend needs in-kernel noise ('counter' or 'lfsr'), "
            f"got {kind!r}; build the noise fn with make_counter_noise or "
            f"make_lfsr_noise")
    if sparse:
        _require_sparse(chip)
    elif chip.W is None:
        raise ValueError(
            "dense fused backend needs a chip with a dense W; this chip is "
            "sparse-native (W=None) — use backend='fused_sparse' or "
            "'sparse'")
    betas = jnp.asarray(betas, jnp.float32)
    if betas.ndim == 1:
        betas = jnp.broadcast_to(betas[:, None], (betas.shape[0], B))
    mask0 = (color == 0)
    mask1 = (color == 1)
    if clamp_mask is not None:
        mask0 = mask0 & ~clamp_mask
        mask1 = mask1 & ~clamp_mask
    return betas, mask0, mask1


def fused_sweeps(
    m: jax.Array,
    chip: EffectiveChip,
    color: jax.Array,
    betas: jax.Array,               # (S,) or (S, B)
    noise_state: jax.Array,
    noise_spec,                     # core/pbit.py NoiseSpec
    clamp_mask: jax.Array | None = None,
    clamp_values: jax.Array | None = None,
    measured: jax.Array | None = None,
    *,
    sparse: bool = False,
    block_b: int = 128,
    interpret: bool | None = None,
):
    """Run S resident sweeps through the fused engine.

    Returns (m', noise_state') or, when ``measured`` is given,
    (m', noise_state', s_sum[N], c_sum) — raw sums over
    (chains x measured sweeps); divide by B * sum(measured).  c_sum is the
    (N, N) Gram matrix on the dense path and the (D, N) per-slot edge
    correlations on the sparse path (read edge (i, j) at
    ``c_sum[slot_of(i→j), i]``, see ChimeraGraph.edge_slots).
    """
    interp = default_interpret() if interpret is None else interpret
    betas, mask0, mask1 = _fused_common(
        chip, color, betas, m.shape[0], noise_spec, clamp_mask, sparse)
    kw = dict(
        clamp_mask=clamp_mask, clamp_values=clamp_values, measured=measured,
        noise_mode=noise_spec.kind, decimation=noise_spec.decimation,
        gather_perm=noise_spec.gather_perm,
        accumulate=measured is not None,
        block_b=block_b, interpret=interp)
    if sparse:
        return sweep_sparse_pallas(
            m, chip.nbr_idx, chip.nbr_w, chip.h, chip.tanh_gain,
            chip.tanh_offset, chip.rand_gain, chip.comp_offset,
            mask0, mask1, betas, noise_state, **kw)
    return sweep_fused_pallas(
        m, chip.W, chip.h, chip.tanh_gain, chip.tanh_offset,
        chip.rand_gain, chip.comp_offset, mask0, mask1, betas, noise_state,
        **kw)


def fused_visible_hist(
    m: jax.Array,
    chip: EffectiveChip,
    color: jax.Array,
    betas: jax.Array,
    noise_state: jax.Array,
    noise_spec,
    visible_idx,
    measured: jax.Array,            # (S,) histogram weights (burn-in mask)
    *,
    sparse: bool = False,
    block_b: int = 128,
    interpret: bool | None = None,
):
    """S resident sweeps + in-kernel visible-pattern histogram.

    Returns (m', noise_state', hist[2^nv]) — hist counts each measured
    sweep's visible bit pattern per chain (energy.empirical_visible_dist
    code order); the (S, B, N) trajectory never exists anywhere.
    """
    interp = default_interpret() if interpret is None else interpret
    betas, mask0, mask1 = _fused_common(
        chip, color, betas, m.shape[0], noise_spec, None, sparse)
    nv = int(len(visible_idx))
    kw = dict(
        measured=measured, visible_idx=jnp.asarray(visible_idx, jnp.int32),
        noise_mode=noise_spec.kind, decimation=noise_spec.decimation,
        gather_perm=noise_spec.gather_perm,
        collect_hist=True, n_visible=nv,
        block_b=block_b, interpret=interp)
    if sparse:
        return sweep_sparse_pallas(
            m, chip.nbr_idx, chip.nbr_w, chip.h, chip.tanh_gain,
            chip.tanh_offset, chip.rand_gain, chip.comp_offset,
            mask0, mask1, betas, noise_state, **kw)
    return sweep_fused_pallas(
        m, chip.W, chip.h, chip.tanh_gain, chip.tanh_offset,
        chip.rand_gain, chip.comp_offset, mask0, mask1, betas, noise_state,
        **kw)
