"""Jitted public wrappers around the Pallas kernels.

`make_kernel_half_sweep` adapts the per-half-sweep kernel to the sampler's
`half_sweep(m, chip, update_mask, beta, u)` signature (see core/pbit.py).
`fused_sweeps` adapts the sweep-resident engine (kernels/sweep_fused.py) to
the chip + graph-color view the backend API in core/pbit.py works with, so
the whole CD / annealing / tempering stack can run through either kernel
with one flag (see docs/kernels.md).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.hardware import EffectiveChip
from repro.kernels.pbit_update import pbit_half_sweep_pallas
from repro.kernels.ref import pbit_half_sweep_ref
from repro.kernels.sweep_fused import sweep_fused_pallas


def default_interpret() -> bool:
    """interpret=True unless we are actually on TPU."""
    if os.environ.get("REPRO_PALLAS_INTERPRET"):
        return os.environ["REPRO_PALLAS_INTERPRET"] == "1"
    return jax.default_backend() != "tpu"


def make_kernel_half_sweep(block_b: int = 128, block_n: int = 128,
                           block_k: int = 512,
                           interpret: bool | None = None):
    interp = default_interpret() if interpret is None else interpret

    def half_sweep(m, chip: EffectiveChip, update_mask, beta, u):
        return pbit_half_sweep_pallas(
            m, chip.W, chip.h, chip.tanh_gain, chip.tanh_offset,
            chip.rand_gain, chip.comp_offset, update_mask, beta, u,
            block_b=block_b, block_n=block_n, block_k=block_k,
            interpret=interp)

    return half_sweep


def ref_half_sweep(m, chip: EffectiveChip, update_mask, beta, u):
    return pbit_half_sweep_ref(
        m, chip.W, chip.h, chip.tanh_gain, chip.tanh_offset,
        chip.rand_gain, chip.comp_offset, update_mask, beta, u)


def fused_sweeps(
    m: jax.Array,
    chip: EffectiveChip,
    color: jax.Array,
    betas: jax.Array,               # (S,) or (S, B)
    noise_state: jax.Array,
    noise_spec,                     # core/pbit.py NoiseSpec
    clamp_mask: jax.Array | None = None,
    clamp_values: jax.Array | None = None,
    measured: jax.Array | None = None,
    *,
    block_b: int = 128,
    interpret: bool | None = None,
):
    """Run S resident sweeps through the fused engine.

    Returns (m', noise_state') or, when ``measured`` is given,
    (m', noise_state', s_sum[N], c_sum[N, N]) — raw sums over
    (chains x measured sweeps); divide by B * sum(measured).
    """
    interp = default_interpret() if interpret is None else interpret
    if noise_spec is None or noise_spec.kind not in ("counter", "lfsr"):
        kind = None if noise_spec is None else noise_spec.kind
        raise ValueError(
            f"fused backend needs in-kernel noise ('counter' or 'lfsr'), "
            f"got {kind!r}; build the noise fn with make_counter_noise or "
            f"make_lfsr_noise")
    B = m.shape[0]
    betas = jnp.asarray(betas, jnp.float32)
    if betas.ndim == 1:
        betas = jnp.broadcast_to(betas[:, None], (betas.shape[0], B))
    mask0 = (color == 0)
    mask1 = (color == 1)
    if clamp_mask is not None:
        mask0 = mask0 & ~clamp_mask
        mask1 = mask1 & ~clamp_mask
    return sweep_fused_pallas(
        m, chip.W, chip.h, chip.tanh_gain, chip.tanh_offset,
        chip.rand_gain, chip.comp_offset, mask0, mask1, betas, noise_state,
        clamp_mask=clamp_mask, clamp_values=clamp_values, measured=measured,
        noise_mode=noise_spec.kind, decimation=noise_spec.decimation,
        gather_perm=noise_spec.gather_perm,
        accumulate=measured is not None,
        block_b=block_b, interpret=interp)
