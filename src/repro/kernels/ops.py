"""Jitted public wrappers around the Pallas kernels.

`make_kernel_half_sweep` adapts the fused kernel to the sampler's
`half_sweep(m, chip, update_mask, beta, u)` signature (see core/pbit.py) so
the whole CD / annealing stack can run through Pallas with one flag.
"""
from __future__ import annotations

import functools
import os

import jax

from repro.core.hardware import EffectiveChip
from repro.kernels.pbit_update import pbit_half_sweep_pallas
from repro.kernels.ref import pbit_half_sweep_ref


def default_interpret() -> bool:
    """interpret=True unless we are actually on TPU."""
    if os.environ.get("REPRO_PALLAS_INTERPRET"):
        return os.environ["REPRO_PALLAS_INTERPRET"] == "1"
    return jax.default_backend() != "tpu"


def make_kernel_half_sweep(block_b: int = 128, block_n: int = 128,
                           block_k: int = 512,
                           interpret: bool | None = None):
    interp = default_interpret() if interpret is None else interpret

    def half_sweep(m, chip: EffectiveChip, update_mask, beta, u):
        return pbit_half_sweep_pallas(
            m, chip.W, chip.h, chip.tanh_gain, chip.tanh_offset,
            chip.rand_gain, chip.comp_offset, update_mask, beta, u,
            block_b=block_b, block_n=block_n, block_k=block_k,
            interpret=interp)

    return half_sweep


def ref_half_sweep(m, chip: EffectiveChip, update_mask, beta, u):
    return pbit_half_sweep_ref(
        m, chip.W, chip.h, chip.tanh_gain, chip.tanh_offset,
        chip.rand_gain, chip.comp_offset, update_mask, beta, u)
