"""Shared helpers for the Pallas kernels in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def round_up(x: int, mult: int) -> int:
    return x + (-x) % mult


def pad_axis(x: jax.Array, mult: int, axis: int, value=0) -> jax.Array:
    """Zero-pad (or ``value``-pad) one axis up to a multiple of ``mult``."""
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=value)
