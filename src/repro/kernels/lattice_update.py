"""Pallas TPU kernel: chain-batched Chimera-lattice half-sweep (SoA layout).

A standalone VPU kernel for the structure-of-arrays cell layout: for every
cell, the in-cell K44 coupling (4x4), the vertical/horizontal inter-cell
couplers, bias, tanh neuron and comparator — fused over a
(chains, rows, cols, 4) tile so spins, noise and couplings stream through
VMEM exactly once per half-sweep.

Layout choice (TPU-native): the trailing two dims are (cols*4) flattened to
a multiple of 128 lanes; chains ride the sublane dim.  The 4x4 cell einsum
is expressed as 4 shifted multiply-adds (k is tiny; an MXU matmul would
waste the 128x128 systolic array), so the kernel is pure VPU — matching the
chip, where the synapse is analog current summation, not a MAC array.

Halo handling: the caller passes spin planes already extended with their
neighbor rows/cols, so the kernel body is boundary-free.  Its original SoA
driver in core/distributed.py is retired (the sharded path runs the slot
layout, kernels/shard_sweep.py + docs/sharding.md); this kernel is the
starting point for the ROADMAP's sweep-resident *sharded* follow-on, where
the interior/boundary split lets S local sweeps fuse per launch.

Oracle: kernels/ref.py::lattice_vertical_update_ref; swept in
tests/test_kernels.py::test_lattice_kernel_*.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except ImportError:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _kernel(mv_ref, mh_ref, mv_up_ref, mv_dn_ref,
            w_vh_ref, wv_up_ref, wv_dnin_ref, h_ref,
            gain_ref, u_ref, par_ref, out_ref, *, color: int, k: int):
    """Vertical-node update for one (chains, rows, cols*k) tile.

    I_v[b, r, c, i] = sum_j W_vh[r, c, i, j] * m_h[b, r, c, j]
                      + wv_dnin[r, c, i] * m_v_up[b, r, c, i]
                      + wv_up[r, c, i]   * m_v_dn[b, r, c, i] + h[r, c, i]
    m_v' = sgn(tanh(gain * I_v) + u) where cell parity == color.
    """
    mv = mv_ref[...]                    # (B, R, C, k)
    mh = mh_ref[...]
    acc = h_ref[...] + wv_dnin_ref[...] * mv_up_ref[...] + \
        wv_up_ref[...] * mv_dn_ref[...]
    # in-cell K_{k,k}: k shifted MALs instead of a 4-wide MXU matmul
    for j in range(k):
        acc = acc + w_vh_ref[..., j] * mh[..., j:j + 1]
    act = jnp.tanh(gain_ref[...] * acc)
    new = jnp.where(act + u_ref[...] >= 0.0, 1.0, -1.0)
    upd = (par_ref[...] == color)
    out_ref[...] = jnp.where(upd, new, mv).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("color", "block_r", "interpret"))
def lattice_vertical_update_pallas(
    m_v: jax.Array,        # (B, R, C, k) f32
    m_h: jax.Array,        # (B, R, C, k)
    m_v_up: jax.Array,     # (B, R, C, k) — neighbor spin from (r-1, c)
    m_v_dn: jax.Array,     # (B, R, C, k) — neighbor spin from (r+1, c)
    W_vh: jax.Array,       # (R, C, k, k)
    wv_up: jax.Array,      # (R, C, k) coupler into r from r+1
    wv_dnin: jax.Array,    # (R, C, k) coupler into r from r-1
    h: jax.Array,          # (R, C, k)
    gain: jax.Array,       # (R, C, k)  (beta folded in by the caller)
    u: jax.Array,          # (B, R, C, k) uniform noise
    parity: jax.Array,     # (R, C) int32 global cell parity
    *,
    color: int,
    block_r: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """One fused vertical-node half-step of the chain-batched lattice."""
    B, R, C, k = m_v.shape
    assert R % block_r == 0, (R, block_r)
    grid = (R // block_r,)

    tile4 = lambda: pl.BlockSpec((B, block_r, C, k), lambda r: (0, r, 0, 0))
    tilew = lambda: pl.BlockSpec((block_r, C, k), lambda r: (r, 0, 0))

    in_specs = [
        tile4(), tile4(), tile4(), tile4(),                   # spins
        pl.BlockSpec((block_r, C, k, k), lambda r: (r, 0, 0, 0)),  # W_vh
        tilew(), tilew(), tilew(), tilew(),                   # couplers/bias/gain
        tile4(),                                              # noise
        pl.BlockSpec((B, block_r, C, 1), lambda r: (0, r, 0, 0)),  # parity
    ]
    par4 = jnp.broadcast_to(
        parity.astype(jnp.int32)[None, :, :, None], (B, R, C, 1))
    out = pl.pallas_call(
        functools.partial(_kernel, color=color, k=k),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((B, block_r, C, k), lambda r: (0, r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, R, C, k), m_v.dtype),
        interpret=interpret,
    )(m_v, m_h, m_v_up, m_v_dn, W_vh, wv_up, wv_dnin, h, gain, u, par4)
    return out
