"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax.numpy as jnp


def pbit_half_sweep_ref(m, W, h, gain, off, rand_gain, comp_off,
                        update_mask, beta, u):
    """Fused chromatic-Gibbs half-sweep, reference semantics.

    m: (B, N) spins in {-1, +1};  W: (N, N) directional couplings
    (I_i = sum_j W[i, j] m_j);  h/gain/off/rand_gain/comp_off: (N,);
    update_mask: (N,) bool;  beta: scalar or (B,) per-chain inverse
    temperature (parallel tempering replicas);  u: (B, N) uniform noise.
    """
    beta = jnp.asarray(beta, jnp.float32)
    if beta.ndim == 1:
        beta = beta[:, None]
    I = m @ W.T + h
    act = jnp.tanh(beta * gain * (I + off))
    decision = act + rand_gain * u + comp_off
    new = jnp.where(decision >= 0.0, 1.0, -1.0).astype(m.dtype)
    return jnp.where(update_mask, new, m)


def sparse_neuron_input(m, nbr_idx, nbr_w, h):
    """Eqn 1 on the fixed-degree slot layout: I = Σ_d w_d ⊙ m[:, idx_d] + h.

    m: (B, N); nbr_idx/nbr_w: (D, N) neighbor table (ChimeraGraph.
    neighbor_table + hardware.attach_sparse).  O(B·N·D) instead of the dense
    O(B·N²) matmul.  Slots accumulate in ascending-d order — the identical
    op order the sparse Pallas kernel uses, so ref and kernel agree bit for
    bit; with neighbors sorted ascending it also reproduces the dense
    sequential row reduction exactly (zeros are additive identities).
    """
    D = nbr_idx.shape[0]
    acc = jnp.zeros(m.shape, jnp.float32)
    for d in range(D):
        acc = acc + nbr_w[d][None, :] * jnp.take(m, nbr_idx[d], axis=1)
    return acc + h


def pbit_sparse_half_sweep_ref(m, nbr_idx, nbr_w, h, gain, off, rand_gain,
                               comp_off, update_mask, beta, u):
    """`pbit_half_sweep_ref` with the degree-D gather replacing the matmul."""
    beta = jnp.asarray(beta, jnp.float32)
    if beta.ndim == 1:
        beta = beta[:, None]
    I = sparse_neuron_input(m, nbr_idx, nbr_w, h)
    act = jnp.tanh(beta * gain * (I + off))
    decision = act + rand_gain * u + comp_off
    new = jnp.where(decision >= 0.0, 1.0, -1.0).astype(m.dtype)
    return jnp.where(update_mask, new, m)


def lattice_vertical_update_ref(m_v, m_h, m_v_up, m_v_dn, W_vh, wv_up,
                                wv_dnin, h, gain, u, parity, color):
    """Oracle for kernels/lattice_update.py (pure jnp)."""
    I = (jnp.einsum("rcij,brcj->brci", W_vh, m_h)
         + wv_dnin * m_v_up + wv_up * m_v_dn + h)
    act = jnp.tanh(gain * I)
    new = jnp.where(act + u >= 0.0, 1.0, -1.0)
    upd = (parity == color)[None, :, :, None]
    return jnp.where(upd, new, m_v).astype(m_v.dtype)
