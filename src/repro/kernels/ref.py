"""Pure-jnp oracles for every Pallas kernel in this package.

`field_decision_update` is THE half-sweep field-accumulation body: eqn 2
(tanh activation, additive RNG, comparator sign, masked write) in one
place.  The dense ref, the sparse ref, and the sharded halo path
(kernels/shard_sweep.py) all call it, so a change to the neuron model —
or to the sync-policy machinery that replays it per shard — edits exactly
one term list.
"""
from __future__ import annotations

import jax.numpy as jnp


def field_decision_update(m, I, gain, off, rand_gain, comp_off,
                          update_mask, beta, u):
    """Eqn 2 on a precomputed neuron input I: the shared half-sweep tail.

    m/I/u: (B, N);  gain/off/rand_gain/comp_off: (N,);  update_mask: (N,)
    bool;  beta: scalar or (B,) per-chain inverse temperature.  Exact op
    order is load-bearing: every backend (ref, Pallas, sparse, sharded)
    reproduces this sequence term for term, which is what makes them
    bit-exact against each other.
    """
    beta = jnp.asarray(beta, jnp.float32)
    if beta.ndim == 1:
        beta = beta[:, None]
    act = jnp.tanh(beta * gain * (I + off))
    decision = act + rand_gain * u + comp_off
    new = jnp.where(decision >= 0.0, 1.0, -1.0).astype(m.dtype)
    return jnp.where(update_mask, new, m)


def pbit_half_sweep_ref(m, W, h, gain, off, rand_gain, comp_off,
                        update_mask, beta, u):
    """Fused chromatic-Gibbs half-sweep, reference semantics.

    m: (B, N) spins in {-1, +1};  W: (N, N) directional couplings
    (I_i = sum_j W[i, j] m_j);  h/gain/off/rand_gain/comp_off: (N,);
    update_mask: (N,) bool;  beta: scalar or (B,) per-chain inverse
    temperature (parallel tempering replicas);  u: (B, N) uniform noise.
    """
    I = m @ W.T + h
    return field_decision_update(m, I, gain, off, rand_gain, comp_off,
                                 update_mask, beta, u)


def scatter_edge_slots(codes, edges, slot_ij, slot_ji, degree, n_nodes):
    """Scatter (E,) edge-list values into the (D, N) slot layout, both
    directions: out[slot_ij[e], edges[e, 0]] = out[slot_ji[e], edges[e, 1]]
    = codes[e].

    This is the hot half of runtime weight streaming — it runs inside the
    compiled sampling/CD closures with ``codes`` as a traced operand
    (edges/slot tables are static), turning a program swap into one
    O(E) scatter instead of a retrace.  ``codes`` may carry leading batch
    axes (a stacked program fleet): the scatter applies to the trailing
    edge axis.
    """
    codes = jnp.asarray(codes)
    out = jnp.zeros(codes.shape[:-1] + (degree, n_nodes), codes.dtype)
    return (out.at[..., slot_ij, edges[:, 0]].set(codes)
            .at[..., slot_ji, edges[:, 1]].set(codes))


def sparse_neuron_input(m, nbr_idx, nbr_w, h):
    """Eqn 1 on the fixed-degree slot layout: I = Σ_d w_d ⊙ m[:, idx_d] + h.

    m: (B, M) gather source; nbr_idx/nbr_w: (D, N) neighbor table
    (ChimeraGraph.neighbor_table + hardware.attach_sparse).  The output is
    (B, N) — normally M == N, but the sharded engine passes the
    halo-extended source [local | halo_up | halo_dn] (M = N + 2H) with a
    table re-indexed into it, which is how one body serves both the
    single-device and the sharded path.  O(B·N·D) instead of the dense
    O(B·N²) matmul.  Slots accumulate in ascending-d order — the identical
    op order the sparse Pallas kernel uses, so ref and kernel agree bit for
    bit; with neighbors sorted ascending it also reproduces the dense
    sequential row reduction exactly (zeros are additive identities).
    """
    D = nbr_idx.shape[0]
    acc = jnp.zeros((m.shape[0], nbr_idx.shape[1]), jnp.float32)
    for d in range(D):
        acc = acc + nbr_w[d][None, :] * jnp.take(m, nbr_idx[d], axis=1)
    return acc + h


def pbit_sparse_half_sweep_ref(m, nbr_idx, nbr_w, h, gain, off, rand_gain,
                               comp_off, update_mask, beta, u):
    """`pbit_half_sweep_ref` with the degree-D gather replacing the matmul."""
    I = sparse_neuron_input(m, nbr_idx, nbr_w, h)
    return field_decision_update(m, I, gain, off, rand_gain, comp_off,
                                 update_mask, beta, u)


def halo_exchange_segments(ex_pts, n_half):
    """Exchange points -> half-sweep windows [(h0, h1), ...] of a launch.

    THE segmentation rule of the fused-resident-exchange loop shape: a
    launch of ``n_half`` half-sweeps splits at its `Sync.exchange_points()`
    into contiguous windows, each preceded by one halo refresh.  The
    in-kernel RDMA path (`sweep_sparse_exchange_pallas`) and the host
    emulation (`ShardedEngine._local_sweeps` windows of
    `fused_shard_sweeps`) both consume this, which is what makes their
    exchange placement identical by construction.
    """
    pts = tuple(ex_pts)
    if not pts or pts[0] != 0:
        raise ValueError(f"exchange points must start at 0, got {pts}")
    if any(not 0 <= p < n_half for p in pts):
        raise ValueError(
            f"exchange points {pts} outside the launch's {n_half} "
            f"half-sweeps")
    return tuple(zip(pts, pts[1:] + (n_half,)))


def lattice_vertical_update_ref(m_v, m_h, m_v_up, m_v_dn, W_vh, wv_up,
                                wv_dnin, h, gain, u, parity, color):
    """Oracle for kernels/lattice_update.py (pure jnp)."""
    I = (jnp.einsum("rcij,brcj->brci", W_vh, m_h)
         + wv_dnin * m_v_up + wv_up * m_v_dn + h)
    act = jnp.tanh(gain * I)
    new = jnp.where(act + u >= 0.0, 1.0, -1.0)
    upd = (parity == color)[None, :, :, None]
    return jnp.where(upd, new, m_v).astype(m_v.dtype)
