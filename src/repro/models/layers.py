"""Shared building blocks: init helpers, norms, rotary embeddings, MLPs."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelCfg
from repro.models.sharding import constrain


def dtype_of(cfg: ModelCfg):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = np.prod([shape[i] for i in range(len(shape))
                      if i <= in_axis]) if in_axis >= 0 else shape[0]
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (scale * jax.random.truncated_normal(
        key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float
               ) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: tuple[int, ...]) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    positions3: (3, B, S) — temporal / height / width position ids.
    `sections` partitions the hd/2 frequency slots among the 3 components.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    # per-frequency component selector
    comp = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                      total_repeat_length=hd // 2)     # (hd/2,)
    pos = jnp.moveaxis(positions3.astype(jnp.float32), 0, -1)  # (B, S, 3)
    sel = jnp.broadcast_to(comp[None, None, :],
                           (pos.shape[0], pos.shape[1], hd // 2))
    pos_per_freq = jnp.take_along_axis(pos, sel, axis=-1)  # (B, S, hd/2)
    ang = pos_per_freq * freqs
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (llama/gemma style)
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), 0, dtype),
        "w_up": dense_init(k2, (d_model, d_ff), 0, dtype),
        "w_down": dense_init(k3, (d_ff, d_model), 0, dtype),
    }


def mlp(params: dict, x: jax.Array, act=jax.nn.silu) -> jax.Array:
    h = act(x @ params["w_gate"]) * (x @ params["w_up"])
    names = ("batch",) + (None,) * (h.ndim - 2) + ("mlp",)
    h = constrain(h, names)
    return h @ params["w_down"]


def init_norm(d: int) -> jax.Array:
    return jnp.zeros((d,), jnp.float32)


def embed_tokens(cfg: ModelCfg, tok_embed: jax.Array, tokens: jax.Array
                 ) -> jax.Array:
    x = tok_embed[tokens]
    if cfg.scale_embed:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(cfg: ModelCfg, params: dict, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = x @ params["tok_embed"].T.astype(x.dtype)
    else:
        logits = x @ params["lm_head"]
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over all positions. logits (B, S, V) f32, labels (B, S)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1).squeeze(-1)
    return jnp.mean(logz - gold)
