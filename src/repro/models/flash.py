"""Memory-efficient attention (flash-style) in pure JAX with custom VJP.

Why not plain `lax.scan` + `jax.checkpoint`: reverse-mode through a scan
stores every iteration's carry, and the running-softmax carry includes the
(B, KV, G, Sq, hd) f32 accumulator — ~5 GiB per layer at train_4k scale,
which is what blew the dry-run memory analysis to 30 GiB/device.

This implementation is the TPU-native answer:
  * forward: scan over KV chunks with running (max, denom, acc); saves only
    (q, k, v, o, m, l) — O(S·d), no S² residuals;
  * backward: custom VJP that *recomputes* chunk scores (flash-2 schedule):
    dq accumulates as the scan carry, dk/dv are emitted per chunk as ys;
  * static triangular schedule: the query axis is split into chunks in a
    Python loop, and each q-chunk only visits the KV chunks its causal /
    sliding-window mask allows.  Because the schedule is static, the skipped
    chunks cost zero FLOPs in the compiled HLO — causal attention compiles
    to ~S²/2 MACs, not S² (this is visible in cost_analysis and is the
    "compute term" win recorded in EXPERIMENTS.md §Perf).

Supports GQA (KV-grouped heads), attention softcap (gemma2) including its
derivative, and sliding windows.  Oracle: tests/test_flash.py checks fwd+bwd
against the direct softmax attention to ~1e-5.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -2.0e38
Q_CHUNK = 1024
KV_CHUNK = 512


def _pick_chunk(size: int, target: int) -> int:
    """Largest divisor of `size` that is <= target (handles Sk=1500 cross
    attention and other non-power-of-two sequence lengths)."""
    c = min(target, size)
    while size % c:
        c -= 1
    return c


def _mask(q_lo: jax.Array, cq: int, k_lo: jax.Array, ck: int, causal: bool,
          window: Optional[int]) -> jax.Array:
    """(cq, ck) keep-mask from *scalar* chunk offsets.

    Offsets stay scalars until inside the scan body so XLA cannot
    constant-fold the masks of every chunk into one (n, cq, ck) pred buffer
    (a 0.5 GiB surprise at train_4k scale before this was rewritten).
    """
    qp = q_lo + jnp.arange(cq)
    kp = k_lo + jnp.arange(ck)
    d = qp[:, None] - kp[None, :]
    ok = jnp.ones((cq, ck), bool)
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    return ok


def _scores(q, k, scale, softcap):
    """q: (B,cq,KV,G,hd) k: (B,ck,KV,hd) -> f32 (B,KV,G,cq,ck)."""
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    return s


def _dscores(q, k, scale, softcap, ds_capped):
    """Backprop through scale (+softcap) given d(capped scores)."""
    if softcap is None:
        return ds_capped * scale
    raw = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                     preferred_element_type=jnp.float32) * scale
    t = jnp.tanh(raw / softcap)
    return ds_capped * (1.0 - t * t) * scale


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _mea_chunk(q, k, v, scale, softcap, causal, window, positions):
    """One q-chunk attended over its full (statically sliced) KV range."""
    o, _, _ = _mea_fwd_impl(q, k, v, scale, softcap, causal, window,
                            positions)
    return o


def _mea_fwd_impl(q, k, v, scale, softcap, causal, window, positions):
    qpos, kpos = positions  # scalar offsets of q[0] / k[0]
    B, cq_, KV, G, hd = q.shape
    Sk = k.shape[1]
    ck = _pick_chunk(Sk, KV_CHUNK)
    n = Sk // ck

    ks = jnp.moveaxis(k.reshape(B, n, ck, KV, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, n, ck, KV, hd), 1, 0)
    q_lo, k_lo = qpos, kpos  # scalar chunk offsets
    cq = q.shape[1]

    def body(carry, inp):
        m_p, l_p, acc = carry
        k_c, v_c, i = inp
        s = _scores(q, k_c, scale, softcap)
        keep = _mask(q_lo, cq, k_lo + i * ck, ck, causal, window)
        s = jnp.where(keep, s, NEG_INF)
        m_n = jnp.maximum(m_p, s.max(axis=-1))
        corr = jnp.exp(m_p - m_n)
        p = jnp.exp(s - m_n[..., None])
        l_n = l_p * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(v_c.dtype), v_c,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_n, l_n, acc), None

    init = (jnp.full((B, KV, G, cq), NEG_INF, jnp.float32),
            jnp.zeros((B, KV, G, cq), jnp.float32),
            jnp.zeros((B, KV, G, cq, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init,
                                  (ks, vs, jnp.arange(n, dtype=jnp.int32)))
    o = acc / jnp.maximum(l, 1e-37)[..., None]
    o = jnp.moveaxis(o, -2, 1).astype(q.dtype)      # (B,cq,KV,G,hd)
    return o, m, l


def _mea_fwd(q, k, v, scale, softcap, causal, window, positions):
    o, m, l = _mea_fwd_impl(q, k, v, scale, softcap, causal, window,
                            positions)
    return o, (q, k, v, o, m, l)


def _mea_bwd(scale, softcap, causal, window, positions, res, do):
    q, k, v, o, m, l = res
    q_lo, k_lo = positions
    B, cq, KV, G, hd = q.shape
    Sk = k.shape[1]
    ck = _pick_chunk(Sk, KV_CHUNK)
    n = Sk // ck

    do_t = jnp.moveaxis(do.astype(jnp.float32), 1, -2)   # (B,KV,G,cq,hd)
    o_t = jnp.moveaxis(o.astype(jnp.float32), 1, -2)
    D = jnp.sum(do_t * o_t, axis=-1)                     # (B,KV,G,cq)
    linv = 1.0 / jnp.maximum(l, 1e-37)

    ks = jnp.moveaxis(k.reshape(B, n, ck, KV, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, n, ck, KV, hd), 1, 0)

    def body(dq_acc, inp):
        k_c, v_c, i = inp
        s = _scores(q, k_c, scale, softcap)
        keep = _mask(q_lo, cq, k_lo + i * ck, ck, causal, window)
        s = jnp.where(keep, s, NEG_INF)
        p = jnp.exp(s - m[..., None]) * linv[..., None]  # (B,KV,G,cq,ck)
        dp = jnp.einsum("bkgqh,bskh->bkgqs", do_t, v_c,
                        preferred_element_type=jnp.float32)
        ds_cap = p * (dp - D[..., None])
        ds = _dscores(q, k_c, scale, softcap, ds_cap)
        dq_c = jnp.einsum("bkgqs,bskh->bqkgh", ds, k_c,
                          preferred_element_type=jnp.float32)
        dk_c = jnp.einsum("bkgqs,bqkgh->bskh", ds, q.astype(jnp.float32),
                          preferred_element_type=jnp.float32)
        dv_c = jnp.einsum("bkgqs,bkgqh->bskh", p, do_t,
                          preferred_element_type=jnp.float32)
        return dq_acc + dq_c, (dk_c.astype(k.dtype), dv_c.astype(v.dtype))

    dq0 = jnp.zeros((B, cq, KV, G, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0,
                                  (ks, vs, jnp.arange(n, dtype=jnp.int32)))
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, Sk, KV, hd)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, Sk, KV, hd)
    return dq.astype(q.dtype), dk, dv


_mea_chunk.defvjp(_mea_fwd, _mea_bwd)


def flash_attention(
    q: jax.Array,                 # (B, Sq, H, hd)
    k: jax.Array,                 # (B, Sk, KV, hd)
    v: jax.Array,
    *,
    num_kv_heads: int,
    scale: float,
    softcap: Optional[float] = None,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,            # absolute position of q[0]
    seq_shard: bool = False,      # sequence-parallel: shard q chunks over
                                  # "model" when heads can't take the axis
) -> jax.Array:
    """Static triangular q-chunk schedule over the custom-VJP inner kernel."""
    from repro.models.sharding import constrain  # local import: no cycle

    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    KV = num_kv_heads
    G = H // KV
    cq = _pick_chunk(Sq, Q_CHUNK)
    nq = Sq // cq
    qg = q.reshape(B, Sq, KV, G, hd)

    if seq_shard:
        # one reshard for the whole tensor (per-chunk constraints caused
        # GSPMD to bounce layouts every chunk — §Perf iteration 1)
        qg = constrain(qg, ("batch", "qseq", None, None, None))
        k = constrain(k, ("batch", None, None, None))
        v = constrain(v, ("batch", None, None, None))

    outs = []
    for i in range(nq):
        q_c = jax.lax.slice_in_dim(qg, i * cq, (i + 1) * cq, axis=1)
        q_lo, q_hi = q_offset + i * cq, q_offset + (i + 1) * cq
        # static KV range this chunk can see
        lo, hi = 0, Sk
        if causal:
            hi = min(hi, q_hi)
        if window is not None:
            lo = max(lo, q_lo - window + 1)
        # align to the kv chunk so the inner scan divides evenly
        ckv = _pick_chunk(Sk, KV_CHUNK)
        lo = (lo // ckv) * ckv
        hi = min(int(-(-hi // ckv) * ckv), Sk)
        hi = max(hi, lo + ckv) if Sk >= ckv else Sk
        k_c = jax.lax.slice_in_dim(k, lo, hi, axis=1)
        v_c = jax.lax.slice_in_dim(v, lo, hi, axis=1)
        o = _mea_chunk(q_c, k_c, v_c, scale, softcap, causal, window,
                       (q_lo, lo))
        outs.append(o)
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    if seq_shard:
        out = constrain(out, ("batch", "qseq", None, None, None))
    return out.reshape(B, Sq, H, hd)
