"""RWKV-6 (Finch) block: token shift + data-dependent-decay WKV recurrence.

Time mixing per head (hd = 64):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
with w_t = exp(-exp(wx_t)) produced by a low-rank ("LoRA") projection of the
token-shifted input — the *data-dependent decay* that distinguishes RWKV-6
from RWKV-4/5.

Training evaluates the recurrence with a chunked two-level schedule:
sequential scan over chunks carrying S (B, H, hd, hd), parallel intra-chunk
einsums — O(S·hd²) work, O(1) state, so the long_500k decode cell is a
single constant-memory step (family "ssm" in the assignment).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelCfg, RWKVCfg
from repro.models.layers import dense_init
from repro.models.sharding import constrain

CHUNK = 64


def init_rwkv_tmix(key, cfg: ModelCfg, dtype) -> dict:
    D = cfg.d_model
    rc = cfg.rwkv
    H, hd = D // rc.head_dim, rc.head_dim
    ks = jax.random.split(key, 8)
    return {
        "mu": 0.5 * jnp.ones((5, D), jnp.float32),   # shift mix r,k,v,g,w
        "w_r": dense_init(ks[0], (D, D), 0, dtype),
        "w_k": dense_init(ks[1], (D, D), 0, dtype),
        "w_v": dense_init(ks[2], (D, D), 0, dtype),
        "w_g": dense_init(ks[3], (D, D), 0, dtype),
        "w_o": dense_init(ks[4], (D, D), 0, dtype),
        "decay_a": dense_init(ks[5], (D, rc.decay_lora), 0, jnp.float32),
        "decay_b": dense_init(ks[6], (rc.decay_lora, D), 0, jnp.float32),
        "decay_bias": jnp.full((D,), -5.0, jnp.float32),
        "u_bonus": dense_init(ks[7], (H, hd), 0, jnp.float32),
        "ln_x": jnp.ones((D,), jnp.float32),         # group-norm scale
    }


def init_rwkv_cmix(key, cfg: ModelCfg, dtype) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu": 0.5 * jnp.ones((2, D), jnp.float32),
        "w_k": dense_init(ks[0], (D, F), 0, dtype),
        "w_v": dense_init(ks[1], (F, D), 0, dtype),
        "w_r": dense_init(ks[2], (D, D), 0, dtype),
    }


def _token_shift(x: jax.Array, last: jax.Array | None):
    """x_{t-1} per position; `last` is the (f32) carry for decode."""
    if last is None:
        prev = jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
    else:
        prev = jnp.concatenate([last[:, None].astype(x.dtype), x[:, :-1]],
                               axis=1)
    return prev


def _wkv_chunked(r, k, v, w, u, S0):
    """Chunked WKV.  r,k,v: (B, T, H, hd); w: (B, T, H, hd) decay in (0,1);
    u: (H, hd); S0: (B, H, hd, hd).  Returns (y (B,T,H,hd), S_final).

    Within a chunk of length c, with W_t = prod_{s<=t} diag(w_s) (cumprod):
      y_t = r_t (W_{t-1} S0) + sum_{s<t} r_t diag(W_{t-1}/W_s) k_s v_s^T
            + (r_t * u * k_t) v_t^T
    evaluated with einsums; S0 then advances by the whole chunk.
    """
    B, T, H, hd = r.shape
    c = min(CHUNK, T)
    while T % c:   # largest divisor of T <= CHUNK (odd decode lengths)
        c -= 1
    n = T // c
    rc_ = r.reshape(B, n, c, H, hd)
    kc_ = k.reshape(B, n, c, H, hd)
    vc_ = v.reshape(B, n, c, H, hd)
    wc_ = w.reshape(B, n, c, H, hd)

    def chunk(S, inp):
        rc, kc, vc, wc = inp                      # (B, c, H, hd)
        logw = jnp.log(jnp.clip(wc, 1e-20, 1.0))
        cs = jnp.cumsum(logw, axis=1)                        # log W_t (<= 0)
        Wprev = jnp.exp(cs - logw)                           # W_{t-1} <= 1
        # carry-in term: r_t diag(W_{t-1}) S0
        rw = rc * Wprev                                      # (B,c,H,hd)
        y_in = jnp.einsum("bthi,bhij->bthj", rw, S)
        # intra-chunk: sum_{s<t} (r_t W_{t-1} / W_s · k_s) v_s
        # 1/W_s is clamped at e^30: contributions where the decay ratio has
        # shrunk below e^-30 are numerically zero anyway (see module doc).
        kw = kc * jnp.exp(jnp.minimum(-cs, 30.0))
        att = jnp.einsum("bthi,bshi->bhts", rw, kw)          # (B,H,c,c)
        mask = jnp.tril(jnp.ones((c, c), bool), -1)
        att = jnp.where(mask, att, 0.0)
        y_intra = jnp.einsum("bhts,bshj->bthj", att, vc)
        # diagonal bonus term
        y_diag = jnp.einsum("bthi,bthj->bthj", rc * u * kc, vc)
        y = y_in + y_intra + y_diag
        # advance state: S' = diag(W_c) S + sum_s diag(W_c/W_s) k_s v_s^T
        Wc = jnp.exp(cs[:, -1])                              # (B,H,hd)
        ratio = jnp.exp(cs[:, -1][:, None] - cs)             # <= 1
        S_new = Wc[..., None] * S + jnp.einsum(
            "bshi,bshj->bhij", ratio * kc, vc)
        return S_new, y

    S_fin, y_chunks = jax.lax.scan(
        chunk, S0,
        (jnp.moveaxis(rc_, 1, 0), jnp.moveaxis(kc_, 1, 0),
         jnp.moveaxis(vc_, 1, 0), jnp.moveaxis(wc_, 1, 0)))
    y = jnp.moveaxis(y_chunks, 0, 1).reshape(B, T, H, hd)
    return y, S_fin


def rwkv_time_mix(params: dict, cfg: ModelCfg, x: jax.Array,
                  state: dict | None = None, return_state: bool = False):
    """x: (B, S, D); state: {"shift": (B, D), "wkv": (B, H, hd, hd)}."""
    B, T, D = x.shape
    rc = cfg.rwkv
    H, hd = D // rc.head_dim, rc.head_dim
    prev = _token_shift(x, None if state is None else state["shift"])
    mu = params["mu"].astype(x.dtype)
    xr, xk, xv, xg, xw = (x + mu[i] * (prev - x) for i in range(5))

    r = (xr @ params["w_r"]).reshape(B, T, H, hd).astype(jnp.float32)
    k = (xk @ params["w_k"]).reshape(B, T, H, hd).astype(jnp.float32)
    v = (xv @ params["w_v"]).reshape(B, T, H, hd).astype(jnp.float32)
    g = jax.nn.silu(xg @ params["w_g"])
    wx = (xw.astype(jnp.float32) @ params["decay_a"]) @ params["decay_b"]
    w = jnp.exp(-jnp.exp(wx + params["decay_bias"]))     # (B,T,D) in (0,1)
    w = w.reshape(B, T, H, hd)

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32) if state is None \
        else state["wkv"]
    y, S_fin = _wkv_chunked(r, k, v, w, params["u_bonus"], S0)
    # per-head group norm
    mean = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 1e-5)
    y = y.reshape(B, T, D) * params["ln_x"]
    out = (y.astype(x.dtype) * g) @ params["w_o"]
    new_state = None
    if return_state:
        new_state = {"shift": x[:, -1].astype(jnp.float32), "wkv": S_fin}
    return out, new_state


def rwkv_channel_mix(params: dict, cfg: ModelCfg, x: jax.Array,
                     state: jax.Array | None = None,
                     return_state: bool = False):
    prev = _token_shift(x, state)
    mu = params["mu"].astype(x.dtype)
    xk = x + mu[0] * (prev - x)
    xr = x + mu[1] * (prev - x)
    kk = jnp.square(jax.nn.relu(xk @ params["w_k"]))
    kk = constrain(kk, ("batch", "seq", "mlp"))
    out = jax.nn.sigmoid(xr @ params["w_r"]) * (kk @ params["w_v"])
    return out, (x[:, -1].astype(jnp.float32) if return_state else None)


def rwkv_state_shapes(cfg: ModelCfg, batch: int) -> dict:
    D = cfg.d_model
    rc = cfg.rwkv
    H, hd = D // rc.head_dim, rc.head_dim
    return {
        "shift_t": (batch, D),
        "wkv": (batch, H, hd, hd),
        "shift_c": (batch, D),
    }
