"""Model facade: build_model(cfg) -> uniform init/loss/decode + input_specs.

`input_specs(cfg, shape, ...)` returns ShapeDtypeStruct stand-ins for every
model input of an (arch x shape) cell — the contract the multi-pod dry-run
lowers against (no allocation).  Modality stubs live here: [audio] gets
(B, enc_seq, D) frame embeddings, [vlm] gets patch embeddings + 3D M-RoPE
position ids.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelCfg, ShapeCfg
from repro.core.hwaware import HwAwareConfig, apply_hardware
from repro.models import transformer, whisper
from repro.models.layers import dtype_of

VLM_PATCHES = 1024  # vision stub: patches occupying the first positions


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelCfg
    init: Callable[[jax.Array], Any]
    loss: Callable[[Any, dict], jax.Array]
    init_cache: Callable[[int, int], Any]
    decode_step: Callable[[Any, jax.Array, jax.Array, Any], tuple]


def build_model(cfg: ModelCfg,
                hw_aware: Optional[HwAwareConfig] = None,
                chip_key: Optional[jax.Array] = None) -> Model:
    """hw_aware: the paper's generalized in-situ learning — the loss sees
    params through the 8-bit DAC + mismatch model (core/hwaware.py)."""

    def maybe_hw(params):
        if hw_aware is None:
            return params
        key = chip_key if chip_key is not None else jax.random.PRNGKey(0)
        return apply_hardware(params, hw_aware, key)

    if cfg.enc_dec is not None:
        return Model(
            cfg=cfg,
            init=lambda key: whisper.init_encdec(key, cfg),
            loss=lambda p, b: whisper.encdec_loss(maybe_hw(p), cfg, b),
            init_cache=lambda b, s: whisper.init_cache(cfg, b, s),
            decode_step=lambda p, t, pos, c: whisper.decode_step(
                maybe_hw(p), cfg, t, pos, c),
        )
    return Model(
        cfg=cfg,
        init=lambda key: transformer.init_lm(key, cfg),
        loss=lambda p, b: transformer.lm_loss(maybe_hw(p), cfg, b),
        init_cache=lambda b, s: transformer.init_cache(cfg, b, s),
        decode_step=lambda p, t, pos, c: transformer.decode_step(
            maybe_hw(p), cfg, t, pos, c),
    )


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, shardable, no allocation)
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_input_specs(cfg: ModelCfg, shape: ShapeCfg) -> dict:
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": _sds((B, S), jnp.int32),
        "labels": _sds((B, S), jnp.int32),
    }
    if cfg.frontend == "vision_stub":
        specs["frontend_embeds"] = _sds(
            (B, min(VLM_PATCHES, S), cfg.d_model), dtype_of(cfg))
        specs["positions"] = _sds((3, B, S), jnp.int32)
    elif cfg.frontend == "audio_stub":
        specs["frontend_embeds"] = _sds(
            (B, cfg.enc_dec.enc_seq, cfg.d_model), dtype_of(cfg))
    return specs


def decode_input_specs(cfg: ModelCfg, shape: ShapeCfg) -> dict:
    """serve_step inputs: one new token + a seq_len KV/state cache."""
    B, S = shape.global_batch, shape.seq_len
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    return {
        "tokens": _sds((B, 1), jnp.int32),
        "pos": _sds((), jnp.int32),
        "cache": jax.tree.map(lambda x: _sds(x.shape, x.dtype), cache),
    }


def make_dummy_batch(cfg: ModelCfg, shape: ShapeCfg, key: jax.Array) -> dict:
    """Concrete random batch matching train_input_specs (smoke tests)."""
    specs = train_input_specs(cfg, shape)
    k1, k2 = jax.random.split(key)
    batch = {
        "tokens": jax.random.randint(
            k1, specs["tokens"].shape, 0, cfg.vocab_size, jnp.int32),
        "labels": jax.random.randint(
            k2, specs["labels"].shape, 0, cfg.vocab_size, jnp.int32),
    }
    if "frontend_embeds" in specs:
        batch["frontend_embeds"] = 0.02 * jax.random.normal(
            k1, specs["frontend_embeds"].shape, jnp.float32
        ).astype(specs["frontend_embeds"].dtype)
    if "positions" in specs:
        B, S = batch["tokens"].shape
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S))
    return batch
