"""Mamba (S6) block for the Jamba hybrid: selective SSM with chunked
associative scan.

The diagonal selective recurrence  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t
is evaluated chunk-by-chunk (sequential lax.scan over chunks carrying h)
with a parallel `associative_scan` inside each chunk: peak memory is
O(B · chunk · d_inner · d_state) instead of O(B · S · d_inner · d_state),
which is what lets jamba train_4k fit HBM in the dry-run, and the
chunk-level parallelism keeps the VPU busy (a 4096-step scalar scan would
be latency-bound).

Decode is the O(1) recurrent step with (conv_state, ssm_state) carried in
the cache — the reason jamba runs the long_500k cell at all.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HybridCfg
from repro.models.layers import dense_init
from repro.models.sharding import constrain

CHUNK = 128


def init_mamba(key, d_model: int, hc: HybridCfg, dtype) -> dict:
    d_in = hc.expand * d_model
    dt_rank = max(1, d_model // 16)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, hc.d_state + 1, dtype=jnp.float32),
                 (d_in, 1))
    return {
        "in_proj": dense_init(ks[0], (d_model, 2 * d_in), 0, dtype),
        "conv_w": dense_init(ks[1], (d_in, hc.d_conv), 1, dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": dense_init(ks[2], (d_in, dt_rank + 2 * hc.d_state), 0,
                             dtype),
        "dt_w": dense_init(ks[3], (dt_rank, d_in), 0, dtype),
        "dt_b": jnp.log(jnp.expm1(
            jnp.clip(jnp.exp(jax.random.uniform(
                ks[4], (d_in,), minval=np.log(1e-3), maxval=np.log(1e-1))),
                1e-4, None))).astype(jnp.float32),
        "A_log": jnp.log(A),
        "D_skip": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[5], (d_in, d_model), 0, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None):
    """Depthwise causal conv1d. x: (B, S, d_in), w: (d_in, K).

    Returns (y, new_state) where state is the trailing K-1 inputs.
    """
    B, S, d_in = x.shape
    K = w.shape[1]
    if state is None:
        pad = jnp.zeros((B, K - 1, d_in), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                 # (B, S+K-1, d)
    # windowed dot: y[:, t] = sum_k xp[:, t+k] * w[:, k]
    y = sum(xp[:, i:i + S] * w[:, i] for i in range(K)) + b
    new_state = xp[:, -(K - 1):].astype(jnp.float32) if K > 1 else \
        jnp.zeros((B, 0, d_in), jnp.float32)
    return y, new_state


def _scan_impl(a: jax.Array, bx: jax.Array, h0: jax.Array
               ) -> tuple[jax.Array, jax.Array]:
    """h_t = a_t * h_{t-1} + bx_t via chunked associative scan."""
    B, S, d_in, N = a.shape
    ch = min(CHUNK, S)
    assert S % ch == 0, (S, ch)
    n_chunks = S // ch
    a_c = a.reshape(B, n_chunks, ch, d_in, N)
    b_c = bx.reshape(B, n_chunks, ch, d_in, N)

    def combine(l, r):
        (al, bl), (ar, br) = l, r
        return al * ar, br + ar * bl

    def chunk_body(h, inp):
        ac, bc = inp                                    # (B, ch, d_in, N)
        a_cum, b_cum = jax.lax.associative_scan(
            combine, (ac, bc), axis=1)
        h_all = b_cum + a_cum * h[:, None]
        return h_all[:, -1], h_all

    h_fin, h_chunks = jax.lax.scan(
        chunk_body, h0,
        (jnp.moveaxis(a_c, 1, 0), jnp.moveaxis(b_c, 1, 0)))
    h_all = jnp.moveaxis(h_chunks, 0, 1).reshape(B, S, d_in, N)
    return h_all, h_fin


@jax.custom_vjp
def _selective_scan(a, bx, h0):
    return _scan_impl(a, bx, h0)


def _sscan_fwd(a, bx, h0):
    out = _scan_impl(a, bx, h0)
    return out, (a, out[0], h0)


def _sscan_bwd(res, grads):
    """Closed-form diagonal-SSM backward (no autodiff through the
    associative scan — differentiating it stores every log-depth level of
    every chunk, ~0.7 TiB/device at jamba train_4k scale).

    dh_t = g_t + a_{t+1} dh_{t+1};  da_t = dh_t h_{t-1};  dbx_t = dh_t;
    dh0  = a_1 dh_1 — i.e. the same first-order recurrence run in reverse,
    so we reuse the chunked forward scan on time-reversed inputs.
    """
    a, h_all, h0 = res
    g_all, g_fin = grads
    B, S, d_in, N = a.shape
    # incoming gradient on h_T adds to the last position's g
    g_all = g_all.at[:, -1].add(g_fin)
    # reverse recurrence: dh'_s = g'_s + a'_s dh'_{s-1} with
    # a'_s = a_{T-s+1} (shifted), run with the forward machinery:
    a_rev = jnp.flip(a, axis=1)
    # reversed-time coefficient is the *previous* reversed a:
    # dh'_s = a_rev[s-1] * dh'_{s-1} + g_rev[s]  (a'_1 multiplies the zero
    # initial state, so dh'_1 = g_T as required)
    a_shift = jnp.concatenate(
        [jnp.ones_like(a_rev[:, :1]), a_rev[:, :-1]], axis=1)
    dh_rev, _ = _scan_impl(a_shift, jnp.flip(g_all, axis=1),
                           jnp.zeros_like(h0))
    dh = jnp.flip(dh_rev, axis=1)                       # (B, S, d_in, N)
    h_prev = jnp.concatenate([h0[:, None], h_all[:, :-1]], axis=1)
    da = dh * h_prev
    dbx = dh
    dh0 = a[:, 0] * dh[:, 0]
    return da, dbx, dh0


_selective_scan.defvjp(_sscan_fwd, _sscan_bwd)


def _selective_scan_chunked(a: jax.Array, bx: jax.Array,
                            h0: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Public entry: custom-VJP chunked scan (see _sscan_bwd)."""
    return _selective_scan(a, bx, h0)


SEQ_CHUNK = 512


def mamba_forward(params: dict, hc: HybridCfg, x: jax.Array,
                  state: dict | None = None, return_state: bool = False):
    """x: (B, S, D).  state (decode): {"conv": (B, K-1, d_in),
    "ssm": (B, d_in, N)}.  Returns (y, new_state|None).

    Long sequences run chunk-by-chunk (checkpointed scan carrying the conv
    + SSM states): peak residual memory is O(chunk · d_inner · d_state)
    instead of O(S · d_inner · d_state) — the difference between 150 GiB
    and HBM-sized temps for jamba train_4k.
    """
    B, S, D = x.shape
    d_in = hc.expand * D
    N = hc.d_state

    if S > SEQ_CHUNK and S % SEQ_CHUNK == 0:
        n = S // SEQ_CHUNK
        if state is None:
            state = {
                "conv": jnp.zeros((B, hc.d_conv - 1, d_in), jnp.float32),
                "ssm": jnp.zeros((B, d_in, N), jnp.float32),
            }
        xc = jnp.moveaxis(x.reshape(B, n, SEQ_CHUNK, D), 1, 0)

        @jax.checkpoint
        def body(st, xi):
            yi, st_new = _mamba_impl(params, hc, xi, st, True)
            return st_new, yi

        st_fin, ys = jax.lax.scan(body, state, xc)
        y = jnp.moveaxis(ys, 0, 1).reshape(B, S, D)
        return y, (st_fin if return_state else None)
    return _mamba_impl(params, hc, x, state, return_state)


def _mamba_impl(params: dict, hc: HybridCfg, x: jax.Array,
                state: dict | None, return_state: bool):
    B, S, D = x.shape
    d_in = hc.expand * D
    N = hc.d_state

    xz = x @ params["in_proj"]                             # (B, S, 2*d_in)
    xz = constrain(xz, ("batch", "seq", "mlp"))
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, conv_state = _causal_conv(xs, params["conv_w"], params["conv_b"],
                                  None if state is None else state["conv"])
    xs = jax.nn.silu(xs)

    proj = xs @ params["x_proj"]                           # (B,S,R+2N)
    dt_rank = params["dt_w"].shape[0]
    dt, Bp, Cp = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt @ params["dt_w"] +
                         params["dt_b"].astype(dt.dtype))  # (B,S,d_in)
    A = -jnp.exp(params["A_log"])                          # (d_in, N)

    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A)     # (B,S,d_in,N)
    bx = (dt * xs).astype(jnp.float32)[..., None] * \
        Bp.astype(jnp.float32)[..., None, :]               # (B,S,d_in,N)
    h0 = jnp.zeros((B, d_in, N), jnp.float32) if state is None \
        else state["ssm"]
    h_all, h_fin = _selective_scan_chunked(a, bx, h0)
    y = jnp.einsum("bsdn,bsn->bsd", h_all,
                   Cp.astype(jnp.float32))                 # (B,S,d_in)
    y = y + params["D_skip"] * xs.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = constrain(y, ("batch", "seq", "mlp"))
    out = y @ params["out_proj"]
    new_state = {"conv": conv_state, "ssm": h_fin} if return_state else None
    return out, new_state


def mamba_state_shape(hc: HybridCfg, d_model: int, batch: int):
    d_in = hc.expand * d_model
    return {
        "conv": (batch, hc.d_conv - 1, d_in),
        "ssm": (batch, d_in, hc.d_state),
    }
