"""Decoder-only LM assembly: dense / MoE / hybrid(Mamba) / RWKV families.

Layers are grouped into *periods* (1 for uniform stacks, 2 for gemma2's
local/global alternation, 8 for jamba's mamba:attn = 7:1) and the groups are
`lax.scan`-stacked: parameters carry a leading G = L/P dim, so HLO size is
O(period), not O(depth) — a 95-layer deepseek compiles as fast as a 4-layer
toy.  `remat` wraps the scanned body for training.

A `first_dense` prefix (kimi-k2's dense layer 0) is kept unstacked.

Decode threads a cache pytree through the same group scan (cache slices are
scan xs/ys), so train/prefill/decode all share one layer implementation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelCfg
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models.layers import (
    cross_entropy,
    dense_init,
    dtype_of,
    embed_tokens,
    init_mlp,
    init_norm,
    mlp,
    rms_norm,
    unembed,
)
from repro.models.sharding import constrain


# ---------------------------------------------------------------------------
# Layer plans
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LayerPlan:
    kind: str            # "attn" | "mamba" | "rwkv"
    mlp: str             # "dense" | "moe" | "cmix"
    window: Optional[int] = None


def period_plan(cfg: ModelCfg) -> list[LayerPlan]:
    """Per-period layer plans (absolute layer i = group*P + p + prefix)."""
    if cfg.rwkv is not None:
        return [LayerPlan("rwkv", "cmix")]
    if cfg.hybrid is not None:
        plans = []
        for p in range(cfg.hybrid.period):
            kind = "attn" if p == cfg.hybrid.attn_index else "mamba"
            use_moe = (cfg.moe is not None and
                       p % cfg.moe.every == cfg.moe.every - 1)
            plans.append(LayerPlan(kind, "moe" if use_moe else "dense"))
        return plans
    if cfg.attn_type == "local_global":
        return [LayerPlan("attn", "dense", window=cfg.window),
                LayerPlan("attn", "dense", window=None)]
    use_moe = cfg.moe is not None
    return [LayerPlan("attn", "moe" if use_moe else "dense")]


def prefix_plans(cfg: ModelCfg) -> list[LayerPlan]:
    if cfg.moe is not None and cfg.moe.first_dense > 0:
        return [LayerPlan("attn", "dense")] * cfg.moe.first_dense
    return []


def n_groups(cfg: ModelCfg) -> int:
    P = len(period_plan(cfg))
    pre = len(prefix_plans(cfg))
    assert (cfg.num_layers - pre) % P == 0, (cfg.num_layers, pre, P)
    return (cfg.num_layers - pre) // P


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_layer(key, cfg: ModelCfg, plan: LayerPlan) -> dict:
    dtype = dtype_of(cfg)
    k1, k2 = jax.random.split(key)
    p: dict[str, Any] = {"norm1": init_norm(cfg.d_model),
                         "norm2": init_norm(cfg.d_model)}
    if cfg.post_norms:
        p["norm1_post"] = init_norm(cfg.d_model)
        p["norm2_post"] = init_norm(cfg.d_model)
    if plan.kind == "attn":
        p["attn"] = attn_mod.init_attention(k1, cfg, dtype)
    elif plan.kind == "mamba":
        p["mamba"] = mamba_mod.init_mamba(k1, cfg.d_model, cfg.hybrid, dtype)
    elif plan.kind == "rwkv":
        p["tmix"] = rwkv_mod.init_rwkv_tmix(k1, cfg, dtype)
    if plan.mlp == "dense":
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
    elif plan.mlp == "moe":
        p["moe"] = moe_mod.init_moe(k2, cfg.d_model, cfg.moe, dtype)
    elif plan.mlp == "cmix":
        p["cmix"] = rwkv_mod.init_rwkv_cmix(k2, cfg, dtype)
    return p


def init_lm(key, cfg: ModelCfg) -> dict:
    dtype = dtype_of(cfg)
    keys = jax.random.split(key, 4)
    plans = period_plan(cfg)
    G = n_groups(cfg)

    def init_group(k):
        kk = jax.random.split(k, len(plans))
        return {f"layer_{p}": _init_layer(kk[p], cfg, plan)
                for p, plan in enumerate(plans)}

    group_keys = jax.random.split(keys[0], G)
    blocks = jax.vmap(init_group)(group_keys)   # stacked leading G dim

    params: dict[str, Any] = {
        "tok_embed": dense_init(keys[1], (cfg.vocab_size, cfg.d_model), 0,
                                dtype),
        "blocks": blocks,
        "final_norm": init_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            keys[2], (cfg.d_model, cfg.vocab_size), 0, dtype)
    pre = prefix_plans(cfg)
    if pre:
        kk = jax.random.split(keys[3], len(pre))
        params["prefix"] = [
            _init_layer(kk[i], cfg, plan) for i, plan in enumerate(pre)]
    return params


# ---------------------------------------------------------------------------
# Layer application (shared by train / prefill / decode)
# ---------------------------------------------------------------------------
def _norm(p, name, cfg, x):
    return rms_norm(x, p[name], cfg.norm_eps)


def _residual(p, cfg, x, sub_out, post_name):
    if cfg.post_norms:
        sub_out = _norm(p, post_name, cfg, sub_out)
    return x + sub_out


def apply_layer(
    p: dict,
    cfg: ModelCfg,
    plan: LayerPlan,
    x: jax.Array,
    positions: jax.Array,
    cache: Optional[dict] = None,
    pos: Optional[jax.Array] = None,
    collect_kv: bool = False,
) -> tuple[jax.Array, jax.Array, Optional[dict]]:
    """Returns (x, aux_loss, new_cache).

    cache!=None => one-token decode; collect_kv => full-sequence prefill
    that also returns the layer's decode cache.
    """
    aux = jnp.zeros((), jnp.float32)
    new_cache: Optional[dict] = None
    want_state = (cache is not None) or collect_kv
    h = _norm(p, "norm1", cfg, x)
    if plan.kind == "attn":
        if cache is None:
            out, kv = attn_mod.attention(p["attn"], cfg, h, positions,
                                         causal=True, window=plan.window,
                                         return_kv=collect_kv)
            if collect_kv:
                new_cache = {"k": kv[0], "v": kv[1]}
        else:
            out, ck, cv = attn_mod.decode_attention(
                p["attn"], cfg, h, cache["k"], cache["v"], pos,
                window=plan.window)
            new_cache = {"k": ck, "v": cv}
    elif plan.kind == "mamba":
        out, st = mamba_mod.mamba_forward(
            p["mamba"], cfg.hybrid, h,
            state=cache, return_state=want_state)
        new_cache = st
    else:  # rwkv
        st_in = None
        if cache is not None:
            st_in = {"shift": cache["shift_t"], "wkv": cache["wkv"]}
        out, st = rwkv_mod.rwkv_time_mix(
            p["tmix"], cfg, h, state=st_in, return_state=want_state)
        if st is not None:
            new_cache = {"shift_t": st["shift"], "wkv": st["wkv"]}
    x = _residual(p, cfg, x, out, "norm1_post")

    h = _norm(p, "norm2", cfg, x)
    if plan.mlp == "dense":
        out = mlp(p["mlp"], h, act=jax.nn.gelu if cfg.scale_embed
                  else jax.nn.silu)
    elif plan.mlp == "moe":
        out, aux = moe_mod.moe_layer(p["moe"], cfg.moe, h)
    else:  # cmix
        out, shift_c = rwkv_mod.rwkv_channel_mix(
            p["cmix"], cfg, h,
            state=None if cache is None else cache["shift_c"],
            return_state=want_state)
        if new_cache is not None and shift_c is not None:
            new_cache["shift_c"] = shift_c
    x = _residual(p, cfg, x, out, "norm2_post")
    x = constrain(x, ("batch", "seq", None))
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# Full forward (train / prefill)
# ---------------------------------------------------------------------------
def forward_hidden(
    params: dict,
    cfg: ModelCfg,
    tokens: jax.Array,                       # (B, S)
    positions: Optional[jax.Array] = None,   # (B, S) or (3, B, S) for mrope
    frontend_embeds: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (final hidden (B, S, D), aux_loss) — no unembed."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.rope_kind == "mrope":
            positions = jnp.broadcast_to(positions, (3, B, S))
    x = embed_tokens(cfg, params["tok_embed"], tokens)
    if frontend_embeds is not None:
        # modality stub: precomputed patch/frame embeddings own the first
        # S_f positions (paper-assigned rule: frontend is out of scope)
        sf = frontend_embeds.shape[1]
        x = jax.lax.dynamic_update_slice(
            x, frontend_embeds.astype(x.dtype), (0, 0, 0))
    x = constrain(x, ("batch", "seq", None))

    plans = period_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    for p, plan in zip(params.get("prefix", []), prefix_plans(cfg)):
        x, aux, _ = apply_layer(p, cfg, plan, x, positions)
        aux_total = aux_total + aux

    def group_body(carry, gparams):
        x, aux_acc = carry
        for i, plan in enumerate(plans):
            x, aux, _ = apply_layer(gparams[f"layer_{i}"], cfg, plan, x,
                                    positions)
            aux_acc = aux_acc + aux
        return (x, aux_acc), None

    body = group_body
    if cfg.remat:
        # REPRO_REMAT=dots saves matmul outputs: skips recomputing the
        # layer's dots AND their TP all-reduces in backward, for ~1 extra
        # activation-set of memory (§Perf)
        import os
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if os.environ.get("REPRO_REMAT") == "dots"
                  else jax.checkpoint_policies.save_only_these_names())
        body = jax.checkpoint(group_body, policy=policy)
    (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                     params["blocks"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_total


def forward(params: dict, cfg: ModelCfg, tokens: jax.Array,
            positions: Optional[jax.Array] = None,
            frontend_embeds: Optional[jax.Array] = None
            ) -> tuple[jax.Array, jax.Array]:
    """Returns (logits (B, S, V) f32, aux_loss)."""
    x, aux = forward_hidden(params, cfg, tokens, positions, frontend_embeds)
    return unembed(cfg, params, x), aux


CE_CHUNK = 512


def chunked_ce(params: dict, cfg: ModelCfg, x: jax.Array,
               labels: jax.Array) -> jax.Array:
    """Cross-entropy without materializing (B, S, V) logits.

    Scans over sequence chunks: peak logits buffer is (B, CE_CHUNK, V) —
    at gemma2 vocab (256k) and S=4k this is 64x less temp memory, which is
    what keeps the train_4k dry-run cells inside HBM.
    """
    B, S, D = x.shape
    c = min(CE_CHUNK, S)
    if S % c != 0:
        logits = unembed(cfg, params, x)
        return cross_entropy(logits, labels)
    n = S // c
    xc = jnp.moveaxis(x.reshape(B, n, c, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, c), 1, 0)

    @jax.checkpoint  # recompute the chunk's logits in backward
    def body(acc, inp):
        xx, ll = inp
        logits = unembed(cfg, params, xx)       # (B, c, V) f32
        logits = constrain(logits, ("batch", None, "vocab"))
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None],
                                   axis=-1).squeeze(-1)
        return acc + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (B * S)


def lm_loss(params: dict, cfg: ModelCfg, batch: dict) -> jax.Array:
    x, aux = forward_hidden(
        params, cfg, batch["tokens"], batch.get("positions"),
        batch.get("frontend_embeds"))
    return chunked_ce(params, cfg, x, batch["labels"]) + 0.01 * aux


def prefill(params: dict, cfg: ModelCfg, tokens: jax.Array,
            positions: Optional[jax.Array] = None,
            frontend_embeds: Optional[jax.Array] = None
            ) -> tuple[jax.Array, dict]:
    """Inference prefill: last-token logits + the filled decode cache."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.rope_kind == "mrope":
            positions = jnp.broadcast_to(positions, (3, B, S))
    x = embed_tokens(cfg, params["tok_embed"], tokens)
    if frontend_embeds is not None:
        x = jax.lax.dynamic_update_slice(
            x, frontend_embeds.astype(x.dtype), (0, 0, 0))
    x = constrain(x, ("batch", "seq", None))
    plans = period_plan(cfg)

    prefix_cache = []
    for p, plan in zip(params.get("prefix", []), prefix_plans(cfg)):
        x, _, kv = apply_layer(p, cfg, plan, x, positions, collect_kv=True)
        prefix_cache.append(kv)

    def group_body(x, gparams):
        kvs = {}
        for i, plan in enumerate(plans):
            x, _, kv = apply_layer(gparams[f"layer_{i}"], cfg, plan, x,
                                   positions, collect_kv=True)
            kvs[f"layer_{i}"] = kv
        return x, kvs

    x, block_cache = jax.lax.scan(group_body, x, params["blocks"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(cfg, params, x[:, -1:])
    cache = {"blocks": block_cache}
    if prefix_cache:
        cache["prefix"] = prefix_cache
    return logits, cache


# ---------------------------------------------------------------------------
# Decode (one token against a cache)
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelCfg, batch: int, max_seq: int,
               dtype=None) -> dict:
    """Zero cache pytree; shapes define the serve_step input_specs."""
    dtype = dtype or dtype_of(cfg)
    plans = period_plan(cfg)
    G = n_groups(cfg)
    hd = cfg.hd()

    def layer_cache(plan: LayerPlan, stacked: bool):
        lead = (G,) if stacked else ()
        if plan.kind == "attn":
            shp = lead + (batch, max_seq, cfg.num_kv_heads, hd)
            c = {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
        elif plan.kind == "mamba":
            shapes = mamba_mod.mamba_state_shape(cfg.hybrid, cfg.d_model,
                                                 batch)
            c = {k: jnp.zeros(lead + s, jnp.float32)
                 for k, s in shapes.items()}
        else:
            shapes = rwkv_mod.rwkv_state_shapes(cfg, batch)
            c = {k: jnp.zeros(lead + s, jnp.float32)
                 for k, s in shapes.items()}
        if plan.mlp == "cmix":
            c["shift_c"] = jnp.zeros(lead + (batch, cfg.d_model),
                                     jnp.float32)
        return c

    cache = {
        "blocks": {f"layer_{i}": layer_cache(pl, True)
                   for i, pl in enumerate(plans)},
    }
    pre = prefix_plans(cfg)
    if pre:
        cache["prefix"] = [layer_cache(pl, False) for pl in pre]
    return cache


def decode_step(
    params: dict,
    cfg: ModelCfg,
    tokens: jax.Array,        # (B, 1)
    pos: jax.Array,           # scalar int32
    cache: dict,
) -> tuple[jax.Array, dict]:
    """One serve step: logits for the next token + updated cache."""
    x = embed_tokens(cfg, params["tok_embed"], tokens)
    plans = period_plan(cfg)
    positions = jnp.full((tokens.shape[0], 1), pos, jnp.int32)

    new_prefix = []
    for p, plan, c in zip(params.get("prefix", []), prefix_plans(cfg),
                          cache.get("prefix", [])):
        x, _, nc = apply_layer(p, cfg, plan, x, positions, cache=c, pos=pos)
        new_prefix.append(nc)

    def group_body(x, scanned):
        gparams, gcache = scanned
        new_gcache = {}
        for i, plan in enumerate(plans):
            x, _, nc = apply_layer(
                gparams[f"layer_{i}"], cfg, plan, x, positions,
                cache=gcache[f"layer_{i}"], pos=pos)
            new_gcache[f"layer_{i}"] = nc
        return x, new_gcache

    x, new_blocks = jax.lax.scan(
        group_body, x, (params["blocks"], cache["blocks"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(cfg, params, x)
    new_cache = {"blocks": new_blocks}
    if new_prefix:
        new_cache["prefix"] = new_prefix
    return logits, new_cache
