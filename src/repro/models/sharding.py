"""Logical-axis sharding rules (MaxText-style), divisibility-checked.

Every tensor dimension carries a *logical* name; `LOGICAL_RULES` maps names
to mesh axes.  A dimension is sharded only if its size divides the mesh axis
product — otherwise it silently falls back to replication (e.g. 40 RWKV
heads on a 16-way model axis, or whisper's 51865 vocab).  This keeps one
rule-set valid for all 10 architectures on any mesh, which is what lets
`dryrun.py` sweep 40 cells x 2 meshes without per-cell hand-sharding.
"""
from __future__ import annotations

import contextlib
import os
import threading
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Parallelism preset (EXPERIMENTS §Perf):
#   "2d"   — FSDP(data) x TP(model): the baseline below.
#   "fsdp" — ZeRO-style: batch over EVERY axis, params sharded over
#            (data, model), no tensor parallelism.  Kills the TP activation
#            all-reduces that dominate dense train_4k cells (96% of
#            collective bytes on deepseek-67b) and sidesteps head-count
#            divisibility (gemma2-2b).  Needs global_batch % n_devices == 0.
PARALLELISM = os.environ.get("REPRO_PARALLELISM", "2d")

# logical axis -> mesh axes (tuple = sharded over several mesh axes)
LOGICAL_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    # decode KV caches shard their sequence dim over "model" (flash-decode
    # style): the batch dim already owns (pod, data), and at 32k-512k the
    # cache, not the weights, is the per-device memory budget.
    "kv_seq": ("model",),
    # sequence-parallel attention (beyond-paper opt, EXPERIMENTS §Perf):
    # when an arch's head count cannot shard over "model" (gemma2-2b: 8
    # heads on a 16-way axis), the query/seq dim takes the axis instead.
    "qseq": ("model",),
    # unsharded logical axes
    "embed": (),
    "seq": (),
    "layers": (),
    "hd": (),
    "state": (),
    "conv": (),
    "cap": (),
    "pos3": (),
    # quantized-optimizer block payloads: shape-agnostic flat blocks shard
    # over every non-batch axis
    "opt_blocks": ("data", "model"),
}

if PARALLELISM == "fsdp":
    LOGICAL_RULES.update({
        "batch": ("pod", "data", "model"),
        "fsdp": ("data", "model"),
        "heads": (), "kv_heads": (), "mlp": (), "vocab": (),
        "experts": ("data", "model"),  # EP still shards expert weights
        "kv_seq": (), "qseq": (),
    })

_local = threading.local()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Ambient mesh for `constrain` (None = single-device, no constraints)."""
    prev = getattr(_local, "mesh", None)
    _local.mesh = mesh
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _local.mesh = prev


def current_mesh() -> Optional[Mesh]:
    return getattr(_local, "mesh", None)


def _axes_for(mesh: Mesh, dim: int, name: Optional[str]):
    """Mesh axes for one dim, or None if not divisible / unmapped."""
    if name is None:
        return None
    axes = tuple(a for a in LOGICAL_RULES.get(name, ())
                 if a in mesh.shape)
    if not axes:
        return None
    size = int(np.prod([mesh.shape[a] for a in axes]))
    if dim % size != 0:
        # try a prefix of the axes (e.g. batch=(pod,data) -> (pod,))
        for cut in range(len(axes) - 1, 0, -1):
            sub = axes[:cut]
            s = int(np.prod([mesh.shape[a] for a in sub]))
            if dim % s == 0:
                return sub if len(sub) > 1 else sub[0]
        return None
    return axes if len(axes) > 1 else axes[0]


def spec(shape: Sequence[int], names: Sequence[Optional[str]],
         mesh: Optional[Mesh] = None) -> P:
    mesh = mesh or current_mesh()
    if mesh is None:
        return P()
    assert len(shape) == len(names), (shape, names)
    used: set[str] = set()
    parts = []
    for dim, nm in zip(shape, names):
        ax = _axes_for(mesh, dim, nm)
        # one mesh axis may shard at most one dim
        flat = ax if isinstance(ax, tuple) else (ax,) if ax else ()
        if any(a in used for a in flat):
            ax = None
        else:
            used.update(flat)
        parts.append(ax)
    return P(*parts)


def constrain(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint under the ambient mesh (no-op without one)."""
    mesh = current_mesh()
    if mesh is None or np.prod(list(mesh.shape.values())) == 1:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec(x.shape, names, mesh)))


def named_sharding(mesh: Mesh, shape: Sequence[int],
                   names: Sequence[Optional[str]]) -> NamedSharding:
    return NamedSharding(mesh, spec(shape, names, mesh))


# ---------------------------------------------------------------------------
# Parameter specs: leaf-name based rules.
# Init code names every leaf so these rules are total; anything unknown is
# replicated (safe default).
# ---------------------------------------------------------------------------
_PARAM_RULES: list[tuple[str, tuple[Optional[str], ...]]] = [
    ("tok_embed", ("vocab", "fsdp")),
    ("pos_embed", (None, None)),
    ("lm_head", ("fsdp", "vocab")),
    ("wq", ("fsdp", "heads", None)),
    ("wk", ("fsdp", "kv_heads", None)),
    ("wv", ("fsdp", "kv_heads", None)),
    ("wo", ("heads", None, "fsdp")),
    ("bq", ("heads", None)),
    ("bk", ("kv_heads", None)),
    ("bv", ("kv_heads", None)),
    ("w_gate", ("fsdp", "mlp")),
    ("w_up", ("fsdp", "mlp")),
    ("w_down", ("mlp", "fsdp")),
    ("router", ("fsdp", None)),
    ("we_gate", ("experts", "fsdp", None)),
    ("we_up", ("experts", "fsdp", None)),
    ("we_down", ("experts", None, "fsdp")),
    ("ws_gate", ("fsdp", "mlp")),     # shared expert
    ("ws_up", ("fsdp", "mlp")),
    ("ws_down", ("mlp", "fsdp")),
    ("in_proj", ("fsdp", "mlp")),
    ("conv_w", ("mlp", None)),
    ("conv_b", ("mlp",)),
    ("x_proj", ("mlp", None)),
    ("dt_w", (None, "mlp")),
    ("dt_b", ("mlp",)),
    ("A_log", ("mlp", None)),
    ("D_skip", ("mlp",)),
    ("out_proj", ("mlp", "fsdp")),
    ("w_r", ("fsdp", "mlp")),
    ("w_k", ("fsdp", "mlp")),
    ("w_v", ("fsdp", "mlp")),
    ("w_g", ("fsdp", "mlp")),
    ("w_o", ("mlp", "fsdp")),
    ("decay_a", ("fsdp", None)),
    ("decay_b", (None, "fsdp")),
]


def _leaf_axes(path: str, ndim: int) -> tuple[Optional[str], ...]:
    for key, names in _PARAM_RULES:
        if path.endswith(key) or f"{key}'" in path or f"{key}]" in path:
            if len(names) == ndim:
                return names
            if len(names) == ndim - 1:       # scan-stacked: leading layer dim
                return (None,) + names
            if len(names) == ndim - 2:       # stacked + grouped
                return (None, None) + names
    return (None,) * ndim


def param_specs(params, mesh: Optional[Mesh] = None):
    """Pytree of PartitionSpec for a params pytree (name-rule based)."""
    mesh = mesh or current_mesh()
    flat = jax.tree_util.tree_flatten_with_path(params)
    leaves, treedef = flat
    out = []
    for path, w in leaves:
        pstr = jax.tree_util.keystr(path)
        names = _leaf_axes(pstr, w.ndim)
        out.append(spec(w.shape, names, mesh) if mesh else P())
    return jax.tree_util.tree_unflatten(treedef, out)


def param_shardings(params, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(params, mesh),
        is_leaf=lambda s: isinstance(s, P))
