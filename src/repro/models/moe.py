"""Mixture-of-Experts: top-k routing, GShard-style one-hot dispatch, EP.

Dispatch design (learned the hard way — see EXPERIMENTS.md §Perf):
a sort/scatter dispatch is FLOP-free but GSPMD cannot shard data-dependent
scatters across the token axis, so the partitioner *replicated* the global
(T·k, D) gather/scatter buffers — 43 GiB/device at kimi-k2 train_4k.  The
GShard/Switch one-hot-einsum dispatch keeps every tensor's axes explicit
(batch b, token t, expert e, capacity c), so the batch dim shards over
(pod, data) and the expert dim over model with zero replication.

Tokens are processed in chunks of TOK_CHUNK along the sequence (lax.scan):
capacity is per (batch-row, chunk) — C = ceil(chunk·k·cf/E) — which bounds
the dispatch one-hot to O(chunk·E·C) instead of O(S·E·C).  The one-hot
einsums add ~12-25% FLOPs over the raw expert matmuls (kimi geometry);
that overhead is visible in the roofline's useful-FLOP fraction and is the
price of an all-XLA, partitioner-friendly MoE.

Overflowed tokens (rank ≥ C) drop (their one-hot row is all-zero), standard
at-scale behavior; combine weights renormalize over the kept experts.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoECfg
from repro.models.layers import dense_init, init_mlp, mlp
from repro.models.sharding import constrain

TOK_CHUNK = 512


def init_moe(key, d_model: int, m: MoECfg, dtype) -> dict:
    ks = jax.random.split(key, 5)
    E, F = m.num_experts, m.d_ff_expert
    p = {
        "router": dense_init(ks[0], (d_model, E), 0, jnp.float32),
        "we_gate": dense_init(ks[1], (E, d_model, F), 1, dtype),
        "we_up": dense_init(ks[2], (E, d_model, F), 1, dtype),
        "we_down": dense_init(ks[3], (E, F, d_model), 1, dtype),
    }
    if m.num_shared:
        shared = init_mlp(ks[4], d_model, m.num_shared * F, dtype)
        p["shared"] = {"ws_gate": shared["w_gate"], "ws_up": shared["w_up"],
                       "ws_down": shared["w_down"]}
    return p


def _capacity(chunk: int, m: MoECfg) -> int:
    c = int(np.ceil(chunk * m.top_k * m.capacity_factor / m.num_experts))
    return max(8, -(-c // 8) * 8)


def _route_chunk(params, m: MoECfg, xc: jax.Array, C: int):
    """xc: (B, c, D) -> (expert buffers out, aux stats).

    All einsums carry explicit (b, e) axes: b shards over (pod, data),
    e over model.
    """
    B, c, D = xc.shape
    E, k = m.num_experts, m.top_k

    logits = xc.astype(jnp.float32) @ params["router"]        # (B, c, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                       # (B, c, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    oh = jax.nn.one_hot(idx, E, dtype=jnp.float32)            # (B, c, k, E)
    assign = oh.sum(2)                                        # (B, c, E)
    gate_e = jnp.einsum("bcke,bck->bce", oh, gate)            # (B, c, E)
    # rank of each token within its expert, per (batch-row, chunk) group
    rank = jnp.cumsum(assign, axis=1) - assign                # exclusive
    rank = jnp.where(assign > 0, rank, C)                     # drop non-hits
    disp = jax.nn.one_hot(rank.astype(jnp.int32), C,
                          dtype=xc.dtype)                     # (B, c, E, C)
    disp = disp * assign[..., None].astype(xc.dtype)
    disp = constrain(disp, ("batch", None, "experts", None))

    # dispatch: (B, E, C, D)
    buf = jnp.einsum("btec,btd->becd", disp, xc)
    buf = constrain(buf, ("batch", "experts", "cap", None))
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, params["we_gate"]))
    h = h * jnp.einsum("becd,edf->becf", buf, params["we_up"])
    h = constrain(h, ("batch", "experts", "cap", "mlp"))
    out = jnp.einsum("becf,efd->becd", h, params["we_down"])
    out = constrain(out, ("batch", "experts", "cap", None))
    # combine, weighted by the (renormalized) gates
    comb = disp * gate_e[..., None].astype(xc.dtype)
    y = jnp.einsum("btec,becd->btd", comb, out)

    # load-balance stats (Switch aux loss terms)
    me = probs.mean(axis=(0, 1))                              # (E,)
    ce = assign.mean(axis=(0, 1)) / k
    return y, me, ce


def moe_layer(params: dict, m: MoECfg, x: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss)."""
    B, S, D = x.shape
    E = m.num_experts
    c = min(TOK_CHUNK, S)
    C = _capacity(c, m)

    if S % c != 0 or S == c:
        y, me, ce = _route_chunk(params, m, x, _capacity(S, m))
        aux = E * jnp.sum(me * ce)
    else:
        n = S // c
        xc = jnp.moveaxis(x.reshape(B, n, c, D), 1, 0)        # (n, B, c, D)

        def body(carry, xi):
            yi, me, ce = _route_chunk(params, m, xi, C)
            return carry + jnp.stack([me, ce]), yi

        stats0 = jnp.zeros((2, E), jnp.float32)
        stats, ys = jax.lax.scan(body, stats0, xc)
        me, ce = stats[0] / n, stats[1] / n
        aux = E * jnp.sum(me * ce)
        y = jnp.moveaxis(ys, 0, 1).reshape(B, S, D)

    if "shared" in params:
        sp = params["shared"]
        y = y + mlp({"w_gate": sp["ws_gate"], "w_up": sp["ws_up"],
                     "w_down": sp["ws_down"]}, x)
    return constrain(y, ("batch", "seq", None)), aux
