"""Whisper-style encoder-decoder (audio family).

The conv/mel frontend is a STUB per the assignment: `input_specs()` feeds
precomputed frame embeddings (B, enc_seq, D) directly into the encoder,
which is a bidirectional transformer with learned positions.  The decoder
adds cross-attention to every layer; decode caches both the self-attn KV and
the (static) encoder KV.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelCfg
from repro.models import attention as attn_mod
from repro.models.layers import (
    cross_entropy,
    dense_init,
    dtype_of,
    init_mlp,
    init_norm,
    mlp,
    rms_norm,
    unembed,
)
from repro.models.sharding import constrain


def _init_block(key, cfg: ModelCfg, cross: bool) -> dict:
    dtype = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    p = {
        "norm1": init_norm(cfg.d_model),
        "attn": attn_mod.init_attention(ks[0], cfg, dtype),
        "norm2": init_norm(cfg.d_model),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }
    if cross:
        p["norm_x"] = init_norm(cfg.d_model)
        p["xattn"] = attn_mod.init_attention(ks[2], cfg, dtype)
    return p


def init_encdec(key, cfg: ModelCfg) -> dict:
    ed = cfg.enc_dec
    dtype = dtype_of(cfg)
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], ed.enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "tok_embed": dense_init(ks[2], (cfg.vocab_size, cfg.d_model), 0,
                                dtype),
        "pos_embed": dense_init(ks[3], (4096, cfg.d_model), 0, dtype),
        "enc_pos_embed": dense_init(ks[4], (ed.enc_seq, cfg.d_model), 0,
                                    dtype),
        "encoder": [
            _init_block(k, cfg, cross=False) for k in enc_keys],
        "decoder": [
            _init_block(k, cfg, cross=True) for k in dec_keys],
        "enc_norm": init_norm(cfg.d_model),
        "final_norm": init_norm(cfg.d_model),
    }


def _norm(p, name, cfg, x):
    return rms_norm(x, p[name], cfg.norm_eps)


def encode(params: dict, cfg: ModelCfg, enc_embeds: jax.Array) -> jax.Array:
    """enc_embeds: (B, enc_seq, D) precomputed frame embeddings (stub)."""
    B, S, _ = enc_embeds.shape
    x = enc_embeds.astype(dtype_of(cfg)) + params["enc_pos_embed"][None, :S]
    x = constrain(x, ("batch", "seq", None))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    for p in params["encoder"]:
        h, _ = attn_mod.attention(p["attn"], cfg,
                                  _norm(p, "norm1", cfg, x),
                                  positions, causal=False)
        x = x + h
        x = x + mlp(p["mlp"], _norm(p, "norm2", cfg, x), act=jax.nn.gelu)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(params: dict, cfg: ModelCfg, tokens: jax.Array,
            enc_embeds: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Teacher-forced decoder pass. Returns (logits, aux=0)."""
    enc_out = encode(params, cfg, enc_embeds)
    B, S = tokens.shape
    # learned positions wrap past the table size (whisper's real context is
    # 448; the 32k assignment shapes exercise the system, not the model)
    pe = params["pos_embed"][jnp.arange(S) % params["pos_embed"].shape[0]]
    x = params["tok_embed"][tokens] + pe[None]
    x = constrain(x, ("batch", "seq", None))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    for p in params["decoder"]:
        h, _ = attn_mod.attention(p["attn"], cfg,
                                  _norm(p, "norm1", cfg, x),
                                  positions, causal=True)
        x = x + h
        kv = attn_mod.cross_kv(p["xattn"], cfg, enc_out)
        h, _ = attn_mod.attention(p["xattn"], cfg,
                                  _norm(p, "norm_x", cfg, x),
                                  positions, kv=kv)
        x = x + h
        x = x + mlp(p["mlp"], _norm(p, "norm2", cfg, x), act=jax.nn.gelu)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(cfg, params, x), jnp.zeros((), jnp.float32)


def encdec_loss(params: dict, cfg: ModelCfg, batch: dict) -> jax.Array:
    logits, _ = forward(params, cfg, batch["tokens"],
                        batch["frontend_embeds"])
    return cross_entropy(logits, batch["labels"])


def init_cache(cfg: ModelCfg, batch: int, max_seq: int,
               dtype=None) -> dict:
    """Self-attn KV per decoder layer + static encoder KV per layer."""
    dtype = dtype or dtype_of(cfg)
    hd = cfg.hd()
    kv = cfg.num_kv_heads
    es = cfg.enc_dec.enc_seq
    return {
        "self": [
            {"k": jnp.zeros((batch, max_seq, kv, hd), dtype),
             "v": jnp.zeros((batch, max_seq, kv, hd), dtype)}
            for _ in range(cfg.num_layers)],
        "cross": [
            {"k": jnp.zeros((batch, es, kv, hd), dtype),
             "v": jnp.zeros((batch, es, kv, hd), dtype)}
            for _ in range(cfg.num_layers)],
    }


def decode_step(params: dict, cfg: ModelCfg, tokens: jax.Array,
                pos: jax.Array, cache: dict) -> tuple[jax.Array, dict]:
    B = tokens.shape[0]
    x = params["tok_embed"][tokens] + \
        params["pos_embed"][pos % params["pos_embed"].shape[0]][None, None]
    new_self = []
    for p, cs, cx in zip(params["decoder"], cache["self"], cache["cross"]):
        h, ck, cv = attn_mod.decode_attention(
            p["attn"], cfg, _norm(p, "norm1", cfg, x), cs["k"], cs["v"],
            pos)
        x = x + h
        new_self.append({"k": ck, "v": cv})
        h, _, _ = attn_mod.decode_attention(
            p["xattn"], cfg, _norm(p, "norm_x", cfg, x), cx["k"], cx["v"],
            pos, cross=True)
        x = x + h
        x = x + mlp(p["mlp"], _norm(p, "norm2", cfg, x), act=jax.nn.gelu)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(cfg, params, x), {"self": new_self,
                                     "cross": cache["cross"]}
