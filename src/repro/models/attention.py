"""GQA attention: full / sliding-window, softcap, QKV bias, RoPE / M-RoPE.

Two execution paths:
  * direct   — materializes (B, H, S, S) scores; used for short sequences.
  * chunked  — flash-style running-softmax over KV chunks (lax.scan), O(S)
    memory; used for train_4k and prefill_32k so the dry-run's
    memory_analysis stays within HBM without a hand-written attention
    kernel.  FLOPs are identical, so roofline compute terms are unaffected.

Decode path updates the KV cache in place (dynamic_update_slice) and attends
one query against the full cache — O(S·d) per token, which is what makes
decode shapes legal even at 32k/512k cache lengths.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelCfg
from repro.models.layers import (
    apply_mrope,
    apply_rope,
    dense_init,
    softcap,
)
from repro.models import flash as flash_mod
from repro.models.sharding import constrain

import os

NEG_INF = -2.0e38
CHUNK_Q = 1024
CHUNK_KV = 512
DIRECT_MAX_SEQ = 2048  # direct path above this switches to flash/chunked
ATTN_IMPL = "flash"    # "flash" (custom-VJP, triangular) | "chunked" (scan)
# sequence-parallel attention for archs whose head count cannot shard over
# the model axis (beyond-paper optimization; see EXPERIMENTS.md §Perf)
SEQ_SHARD_ATTN = os.environ.get("REPRO_SEQ_SHARD_ATTN", "0") == "1"


def _want_seq_shard(cfg: ModelCfg) -> bool:
    if not SEQ_SHARD_ATTN:
        return False
    from repro.models.sharding import current_mesh
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.shape:
        return False
    return cfg.num_heads % mesh.shape["model"] != 0


def init_attention(key, cfg: ModelCfg, dtype) -> dict:
    hd = cfg.hd()
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.num_heads, hd), 0, dtype),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.num_kv_heads, hd), 0, dtype),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.num_kv_heads, hd), 0, dtype),
        "wo": dense_init(ks[3], (cfg.num_heads, hd, cfg.d_model), 1, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads, hd), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads, hd), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads, hd), dtype)
    return p


def _project_qkv(params, cfg: ModelCfg, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if cfg.rope_kind == "rope":
        pos2 = positions if positions.ndim == 2 else positions[0]
        q = apply_rope(q, pos2, cfg.rope_theta)
        k = apply_rope(k, pos2, cfg.rope_theta)
    elif cfg.rope_kind == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    q = constrain(q, ("batch", "seq", "heads", None))
    k = constrain(k, ("batch", "seq", "kv_heads", None))
    v = constrain(v, ("batch", "seq", "kv_heads", None))
    return q, k, v


def _mask_bias(q_pos, k_pos, causal: bool, window: Optional[int]):
    """(…, Sq, Sk) additive mask."""
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    d = q_pos[:, None] - k_pos[None, :]
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    return jnp.where(ok, 0.0, NEG_INF)


def _scores(q, k, cfg: ModelCfg, scale):
    """q: (B, Sq, KV, G, hd)  k: (B, Sk, KV, hd) -> (B, KV, G, Sq, Sk)."""
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k) * scale
    return softcap(s.astype(jnp.float32), cfg.attn_softcap)


def _attend_direct(q, k, v, cfg, scale, q_pos, k_pos, causal, window):
    B, Sq, H, hd = q.shape
    KV = cfg.num_kv_heads
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    s = _scores(qg, k, cfg, scale)
    s = s + _mask_bias(q_pos, k_pos, causal, window)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return o.reshape(B, Sq, H, hd)


def _attend_chunked(q, k, v, cfg, scale, q_pos, k_pos, causal, window):
    """Flash-style: scan over KV chunks with running (max, denom, acc)."""
    B, Sq, H, hd = q.shape
    KV = cfg.num_kv_heads
    G = H // KV
    Sk = k.shape[1]
    ck = min(CHUNK_KV, Sk)
    n_chunks = Sk // ck
    assert Sk % ck == 0, (Sk, ck)
    qg = q.reshape(B, Sq, KV, G, hd)

    ks = k.reshape(B, n_chunks, ck, KV, hd)
    vs = v.reshape(B, n_chunks, ck, KV, hd)
    kpos = k_pos.reshape(n_chunks, ck)

    @jax.checkpoint  # recompute chunk scores in backward: O(chunk) residuals
    def body(carry, inp):
        m_prev, l_prev, acc = carry
        k_c, v_c, kp = inp                       # (B, ck, KV, hd), (ck,)
        s = _scores(qg, k_c, cfg, scale)         # (B, KV, G, Sq, ck) f32
        s = s + _mask_bias(q_pos, kp, causal, window)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])        # (B, KV, G, Sq, ck)
        l_new = l_prev * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(v_c.dtype), v_c)
        acc = acc * corr[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, acc), None

    init = (
        jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32),
        jnp.zeros((B, KV, G, Sq), jnp.float32),
        jnp.zeros((B, KV, G, Sq, hd), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        body, init,
        (jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0), kpos))
    o = acc / jnp.maximum(l, 1e-37)[..., None]
    o = jnp.moveaxis(o, -2, 1)                   # (B, Sq, KV, G, hd)
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def attention(
    params: dict,
    cfg: ModelCfg,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    kv: Optional[tuple[jax.Array, jax.Array]] = None,  # cross-attention
    return_kv: bool = False,
):
    """Full-sequence attention (train / prefill / encoder / cross).

    Returns `out`, or `(out, (k, v))` when return_kv (prefill cache fill).
    """
    B, S, _ = x.shape
    hd = cfg.hd()
    scale = 1.0 / np.sqrt(hd)
    if kv is not None:  # cross-attention: queries only; K/V precomputed
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
        if cfg.qkv_bias:
            q = q + params["bq"]
        k, v = kv
        causal = False
    else:
        q, k, v = _project_qkv(params, cfg, x, positions)
    q_pos = jnp.arange(S)
    k_pos = jnp.arange(k.shape[1])
    if max(S, k.shape[1]) <= DIRECT_MAX_SEQ:
        o = _attend_direct(q, k, v, cfg, scale, q_pos, k_pos, causal,
                           window)
    elif ATTN_IMPL == "flash":
        o = flash_mod.flash_attention(
            q, k, v, num_kv_heads=cfg.num_kv_heads, scale=scale,
            softcap=cfg.attn_softcap, causal=causal, window=window,
            seq_shard=_want_seq_shard(cfg))
    else:  # "chunked": the scan baseline kept for §Perf comparison
        o = _attend_chunked(q, k, v, cfg, scale, q_pos, k_pos, causal,
                            window)
    o = constrain(o, ("batch", "seq", "heads", None))
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    if return_kv:
        return out, (k, v)
    return out, None


def cross_kv(params: dict, cfg: ModelCfg, enc_out: jax.Array):
    """Precompute encoder K/V for cross-attention (cached at prefill)."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"])
    if cfg.qkv_bias:
        k, v = k + params["bk"], v + params["bv"]
    return k, v


def decode_attention(
    params: dict,
    cfg: ModelCfg,
    x: jax.Array,                 # (B, 1, D)
    cache_k: jax.Array,           # (B, S, KV, hd)
    cache_v: jax.Array,
    pos: jax.Array,               # scalar int32: write/attend position
    *,
    window: Optional[int] = None,
    cross: bool = False,
):
    """One-token decode against a KV cache.

    Returns (out (B, 1, D), new_k, new_v).  With cross=True the cache is the
    (static) encoder K/V and nothing is written.
    """
    B = x.shape[0]
    hd = cfg.hd()
    KV = cfg.num_kv_heads
    G = cfg.num_heads // KV
    scale = 1.0 / np.sqrt(hd)

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if cfg.qkv_bias:
        q = q + params["bq"]
    if not cross:
        k_new = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
        v_new = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
        if cfg.qkv_bias:
            k_new, v_new = k_new + params["bk"], v_new + params["bv"]
        posb = jnp.full((B, 1), pos, jnp.int32)
        if cfg.rope_kind == "rope":
            q = apply_rope(q, posb, cfg.rope_theta)
            k_new = apply_rope(k_new, posb, cfg.rope_theta)
        elif cfg.rope_kind == "mrope":
            pos3 = jnp.broadcast_to(pos, (3, B, 1)).astype(jnp.int32)
            q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
            k_new = apply_mrope(k_new, pos3, cfg.rope_theta,
                                cfg.mrope_sections)
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k_new.astype(cache_k.dtype), (0, pos, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v_new.astype(cache_v.dtype), (0, pos, 0, 0))

    S = cache_k.shape[1]
    qg = q.reshape(B, 1, KV, G, hd)
    s = _scores(qg, cache_k, cfg, scale)[:, :, :, 0, :]   # (B, KV, G, S)
    k_pos = jnp.arange(S)
    ok = k_pos <= pos if not cross else jnp.ones((S,), bool)
    if window is not None and not cross:
        ok &= (pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p.astype(cache_v.dtype), cache_v)
    o = o.reshape(B, 1, cfg.num_heads, hd)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    return out, cache_k, cache_v
