"""Unified solver API: declarative `SamplerSpec` -> compiled `Session`.

The single entry point every workload uses to construct samplers:

    spec = api.SamplerSpec(graph=g, hw=hw, mismatch=mism,
                           noise="counter", backend="auto",
                           schedule=api.Anneal(0.05, 3.0, n_sweeps=600),
                           chains=64)
    session = api.Session(spec)       # env + backend resolved HERE, once
    chip = session.program(J_codes, h_codes)
    state = session.init_state(key)
    m, ns, _ = session.sample(chip, state.m, state.noise_state)

See docs/api.md for the lifecycle and the old-call -> new-call migration
table; `core.cd.PBitMachine.session(...)` builds specs/sessions from the
familiar machine object.
"""
from repro.api.faults import Faults, sample_faults
from repro.api.program import Program, stack_programs
from repro.api.spec import (
    BACKENDS,
    FUSED_BACKENDS,
    IN_KERNEL_NOISE,
    NOISE_KINDS,
    SPARSE_BACKENDS,
    Anneal,
    Constant,
    Partition,
    SamplerSpec,
    Schedule,
    Sync,
    Tempered,
    dense_vmem_feasible,
    resolve_backend,
    resolve_interpret,
    spec_fingerprint,
)
from repro.api.session import (
    Session,
    SessionState,
    program,
    program_chip,
    program_edges,
    program_master,
)

__all__ = [
    "BACKENDS", "FUSED_BACKENDS", "IN_KERNEL_NOISE", "NOISE_KINDS",
    "SPARSE_BACKENDS",
    "Schedule", "Constant", "Anneal", "Tempered",
    "Partition", "Sync", "SamplerSpec", "Session", "SessionState",
    "Faults", "sample_faults", "Program", "stack_programs",
    "program", "program_chip", "program_edges", "program_master",
    "dense_vmem_feasible", "resolve_backend", "resolve_interpret",
    "spec_fingerprint",
]
