"""Declarative sampler specification: one frozen object describes a solver.

The chip serves every workload — Boltzmann-machine learning, SK annealing,
Max-Cut, parallel tempering — through one program/sample interface.  This
module is the software contract for that interface: a `SamplerSpec` names
*what* to sample (graph + chip programming model), *how* (noise source,
execution backend, beta `Schedule`), and `api.Session` compiles it once
into jitted closures (see session.py).

Everything that used to be re-threaded by hand through five entry points
(`backend=`, `noise=`, hand-built beta arrays, env-var lookups at call
time) is a spec field, resolved exactly once at `Session` construction:

  * ``backend`` — ``ref | pallas | fused | sparse | fused_sparse | auto``.
    ``auto`` consults ``REPRO_PBIT_BACKEND`` (the env var becomes a spec
    *default*, read at compile, never at call time) and otherwise picks
    per the docs/kernels.md VMEM model: ``fused_sparse`` when the spec
    carries the Chimera slot layout and the noise can be generated
    in-kernel, ``sparse`` when it carries the layout but noise is
    host-side, ``fused`` for a dense-only spec whose W is VMEM-resident,
    else ``ref``.  This is the single seam where the ROADMAP
    mesh-sharding follow-on will plug in (partition decisions live here).
  * ``noise`` — ``philox | counter | lfsr`` (see core/pbit.py).
  * ``schedule`` — a first-class `Schedule`: `Constant`, `Anneal`
    (geometric/linear), or `Tempered` (per-chain ladder -> (S, B) betas).
  * ``interpret`` — Pallas interpret mode; ``None`` resolves
    ``REPRO_PALLAS_INTERPRET`` at compile.
  * ``mesh`` + ``partition`` — multi-device execution.  A `Partition`
    names the mesh axis the Chimera *cell rows* shard over (contiguous
    row bands per device, chain-coupler boundary spins halo-exchanged by
    ``ppermute`` each half-sweep — O(√N) bytes, never a dense W or a
    global gather) and/or the axis the Gibbs *chains* shard over (CD's
    embarrassingly parallel dimension; the (E,) edge-list moments are
    psum-reduced once per phase).  ``mesh=None`` (the default) is
    bit-exact to the single-device path; a sharded Session reproduces
    the single-device spin trajectory exactly for the same noise stream
    (see docs/sharding.md).
  * ``sync`` — a `Sync` policy for sharded execution: how often row
    bands exchange halos (``halo_every``), barrier vs PASS-style async
    double-buffering (``mode``), and how many sweeps fuse into one
    device-local launch (``sweeps_per_launch``).  The default barrier
    keeps the bit-exactness contract; relaxed policies are documented,
    measured approximations (docs/sharding.md §Sync policies).
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Any

import jax
import jax.numpy as jnp

from repro.api.faults import Faults
from repro.core.chimera import ChimeraGraph
from repro.core.hardware import HardwareConfig, Mismatch, SparseMismatch

BACKENDS = ("ref", "pallas", "fused", "sparse", "fused_sparse")
FUSED_BACKENDS = ("fused", "fused_sparse")
SPARSE_BACKENDS = ("sparse", "fused_sparse")
NOISE_KINDS = ("philox", "counter", "lfsr")
IN_KERNEL_NOISE = ("counter", "lfsr")

# docs/kernels.md VMEM model: the resident engine needs the weights plus
# two (block_b, N) activation tiles simultaneously live in a 16 MB core.
VMEM_BYTES = 16 * 2 ** 20
_RESIDENT_BLOCK_B = 128


def dense_vmem_feasible(n_nodes: int) -> bool:
    """Can a dense (N, N) float32 W stay VMEM-resident (kernels.md model)?"""
    return 4 * n_nodes * n_nodes + 2 * (_RESIDENT_BLOCK_B * n_nodes * 4) \
        <= VMEM_BYTES


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Schedule:
    """Base class: a declarative inverse-temperature schedule.

    ``betas(chains)`` materializes the (S,) shared — or (S, B) per-chain —
    float32 array the sampling engine scans over.  Schedules are frozen,
    hashable value objects so they can key compiled-closure caches.
    ``n_sweeps`` is keyword-only so subclasses keep natural positional
    order: ``Anneal(0.05, 3.0, n_sweeps=600)``.
    """

    n_sweeps: int = dataclasses.field(default=1, kw_only=True)

    def betas(self, chains: int | None = None) -> jax.Array:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Constant(Schedule):
    """Fixed beta for every sweep — the Boltzmann-sampling workloads."""

    beta: float = 1.0

    def betas(self, chains: int | None = None) -> jax.Array:
        return jnp.full((self.n_sweeps,), self.beta, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Anneal(Schedule):
    """Simulated-annealing ramp (the chip's V_temp sweep, paper Fig. 9a)."""

    beta_start: float = 0.05
    beta_end: float = 3.0
    kind: str = "geometric"  # or "linear"

    def __post_init__(self):
        if self.kind not in ("geometric", "linear"):
            raise ValueError(
                f"Anneal.kind must be 'geometric' or 'linear', "
                f"got {self.kind!r}")

    def betas(self, chains: int | None = None) -> jax.Array:
        t = jnp.linspace(0.0, 1.0, self.n_sweeps)
        if self.kind == "geometric":
            return (self.beta_start
                    * (self.beta_end / self.beta_start) ** t).astype(
                        jnp.float32)
        return (self.beta_start
                + (self.beta_end - self.beta_start) * t).astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class Tempered(Schedule):
    """Per-chain beta ladder -> (S, B) matrix (parallel-tempering replicas).

    ``ladder`` is one beta per chain; every sweep runs the whole ladder.
    The replica-exchange *controller* (core/tempering.py) permutes the
    ladder between swap rounds by passing explicit betas to
    ``Session.sample`` — the schedule fixes the shape contract.
    """

    ladder: tuple = (1.0,)

    @staticmethod
    def geometric(beta_min: float, beta_max: float, n_replicas: int,
                  n_sweeps: int = 1) -> "Tempered":
        r = jnp.arange(n_replicas) / max(n_replicas - 1, 1)
        ladder = beta_min * (beta_max / beta_min) ** r
        return Tempered(n_sweeps=n_sweeps,
                        ladder=tuple(float(b) for b in ladder))

    def betas(self, chains: int | None = None) -> jax.Array:
        ladder = jnp.asarray(self.ladder, jnp.float32)
        if chains is not None and ladder.shape[0] != chains:
            raise ValueError(
                f"Tempered ladder has {ladder.shape[0]} rungs but the spec "
                f"runs {chains} chains; one beta per chain is required")
        return jnp.broadcast_to(ladder, (self.n_sweeps, ladder.shape[0]))


# ---------------------------------------------------------------------------
# Partitioning
# ---------------------------------------------------------------------------
def _norm_axes(axes) -> tuple[str, ...]:
    """None -> (); "data" -> ("data",); tuples pass through."""
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


@dataclasses.dataclass(frozen=True)
class Partition:
    """Declarative device-partition choice, resolved at Session compile.

    ``rows`` names the mesh axis (or axes, flattened in order) the Chimera
    *cell rows* shard over: each device owns a contiguous band of cell
    rows plus the O(D·N_local) slice of the slot tables, and only the
    chain-coupler boundary spins (the vertical nodes of the band's first
    and last cell row — O(√N)) travel between row neighbors, by
    ``jax.lax.ppermute``, once per half-sweep.  This is exactly the
    chip's tiling: in-cell K44 and horizontal couplers never leave a
    device; only inter-cell vertical wires cross the cut.

    ``chains`` names the axis the Gibbs chains shard over — CD's
    embarrassingly parallel dimension.  Spins are bit-exact vs
    single-device for any chain count; the accumulated moments are
    bit-exact when ``chains`` is a power of two (the ±1 partial sums and
    their dyadic scalings are then exact in float32 — see
    docs/sharding.md) and 1-ulp-close otherwise.

    Both may be set at once (a 2-D mesh: rows x chains).  Sharded
    execution runs the slot-layout scan path ("sparse" backend
    semantics) or, with counter noise and a `Sync` whose exchange
    cadence the kernel can own (``halo_every <= sweeps_per_launch``, or
    no mid-launch exchange at all), the sweep-resident fused kernel with
    kernel-resident halo exchange (docs/kernels.md §In-kernel halo
    exchange).  Either way it needs noise that regenerates per
    (chain, node) coordinate, so ``noise`` must be "counter" or "lfsr".
    """

    rows: str | tuple[str, ...] | None = "data"
    chains: str | tuple[str, ...] | None = None

    @property
    def rows_axes(self) -> tuple[str, ...]:
        return _norm_axes(self.rows)

    @property
    def chain_axes(self) -> tuple[str, ...]:
        return _norm_axes(self.chains)


# ---------------------------------------------------------------------------
# Synchronization policy (sharded execution)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Sync:
    """How often row-band shards synchronize — a compiled sampler property.

    The chip's analog fabric has no global clock (PASS, arXiv:2409.10325,
    makes asynchrony the headline feature of a p-bit processor); how
    faithfully the sharded engine emulates a global barrier is a policy,
    not an accident of the backend:

    * ``halo_every=k`` — exchange the chain-coupler boundary spins before
      every k-th half-sweep (within-launch index; a launch boundary always
      refreshes).  ``k=1`` (the default) is today's bit-exact barrier
      path; ``k>1`` lets bands run on halos up to ``k-1`` half-sweeps
      stale; ``math.inf`` exchanges only at launch boundaries.
    * ``mode`` — ``"barrier"`` consumes each exchange immediately (the
      deterministic emulation of a synchronized swap); ``"async"``
      double-buffers it PASS-style: the values consumed at exchange point
      t are the ones *sent* at point t-1, so the transfer is in flight
      across the intervening compute (fire-and-forget staleness, still
      deterministic and seeded).
    * ``sweeps_per_launch=S`` — fuse S full sweeps into one device-local
      launch between exchange points.  With counter noise the engine
      runs the launch through the sweep-resident Pallas kernel
      (`kernels/shard_sweep.py::fused_shard_sweeps`) — spins
      VMEM-resident, in-kernel RNG.  Mid-launch exchange points no
      longer break the fusion: any ``halo_every <= sweeps_per_launch``
      runs with the halo refresh INSIDE the kernel (RDMA on TPU meshes,
      a bit-exact segmented emulation elsewhere — docs/kernels.md
      §In-kernel halo exchange).

    ``halo_every=1`` keeps the sharded == single-device bit-exactness
    contract; anything looser is a *documented, measured* approximation —
    tests/test_sync_policies.py bounds the KL gap, the ``sync_policies``
    section of BENCH_kernel.json tracks the wall-clock win
    (docs/sharding.md §Sync policies).
    """

    halo_every: int | float = 1
    mode: str = "barrier"
    sweeps_per_launch: int = 1

    def __post_init__(self):
        k = self.halo_every
        if not (k == math.inf or (isinstance(k, int) and k >= 1)):
            raise ValueError(
                f"Sync.halo_every must be an int >= 1 or math.inf, got "
                f"{k!r}")
        if self.mode not in ("barrier", "async"):
            raise ValueError(
                f"Sync.mode must be 'barrier' or 'async', got {self.mode!r}")
        if not (isinstance(self.sweeps_per_launch, int)
                and self.sweeps_per_launch >= 1):
            raise ValueError(
                f"Sync.sweeps_per_launch must be an int >= 1, got "
                f"{self.sweeps_per_launch!r}")

    @property
    def bit_exact(self) -> bool:
        """Does this policy preserve the sharded == single-device spin
        trajectory exactly?  Only the per-half-sweep barrier does."""
        return self.mode == "barrier" and self.halo_every == 1

    @property
    def launch_resident(self) -> bool:
        return self.sweeps_per_launch > 1

    def exchange_points(self) -> tuple[int, ...]:
        """Within-launch half-sweep indices at which halos refresh.

        A launch spans ``2 * sweeps_per_launch`` half-sweeps; index 0 (the
        launch boundary) always refreshes."""
        n_half = 2 * self.sweeps_per_launch
        if self.halo_every == math.inf:
            return (0,)
        k = int(self.halo_every)
        return tuple(hs for hs in range(n_half) if hs % k == 0)

    @property
    def kernel_fusible(self) -> bool:
        """No mid-launch exchange -> a launch can run inside one Pallas
        kernel (the fused per-shard path also needs counter noise)."""
        return self.exchange_points() == (0,)

    @property
    def fused_compatible(self) -> bool:
        """Can a fused backend run this policy?  True when there is no
        mid-launch exchange (`kernel_fusible`) or when the kernel can own
        the refresh itself — the kernel-resident halo exchange supports
        any ``halo_every <= sweeps_per_launch``.  The infeasible window
        is ``sweeps_per_launch < halo_every < 2 * sweeps_per_launch``:
        exchange points too sparse for the resident segments yet not at
        launch boundaries only."""
        if self.kernel_fusible:
            return True
        return (isinstance(self.halo_every, int)
                and self.halo_every <= self.sweeps_per_launch)

    def exchanges_per_sweep(self, refresh_for_moments: bool = False
                            ) -> float:
        """Average halo exchanges per full sweep under this policy (the
        halo-bytes model's multiplier; docs/sharding.md)."""
        per = len(self.exchange_points()) / self.sweeps_per_launch
        if refresh_for_moments and self.bit_exact:
            per += 1.0  # post-sweep refresh for boundary-edge correlations
        return per


# ---------------------------------------------------------------------------
# The spec
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)
class SamplerSpec:
    """Frozen, pytree-registered description of one solver instance.

    The mismatch arrays are the pytree leaves (a spec can be device_put /
    donated / tree-mapped); everything else — graph, hardware sigmas,
    noise/backend/schedule choices — is static aux data fixed at trace
    time.  ``Session(spec)`` validates and compiles it; specs themselves
    hold no jax state and read no environment variables.
    """

    graph: ChimeraGraph
    hw: HardwareConfig
    mismatch: Mismatch | SparseMismatch
    noise: str = "philox"
    backend: str = "auto"
    schedule: Schedule | None = None
    chains: int = 256
    beta: float = 1.0           # base inverse temperature (stats / CD / hist)
    w_scale: float = 0.05       # weight-LSB -> coupling units
    decimation: int = 8         # LFSR clocks per half-sweep
    attach_sparse: bool = True  # carry the Chimera slot layout on dense chips
    interpret: bool | None = None  # Pallas interpret; None -> env at compile
    mesh: Any = None            # jax.sharding.Mesh; None -> single device
    partition: Partition | None = None  # how to cut over mesh; None -> default
    sync: Sync | None = None    # shard sync policy; None -> Sync() barrier
    faults: Faults | None = None  # discrete fault injection; None -> healthy

    # -- pytree ----------------------------------------------------------
    def tree_flatten(self):
        aux = tuple(
            getattr(self, f.name) for f in dataclasses.fields(self)
            if f.name != "mismatch")
        return (self.mismatch,), aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        names = [f.name for f in dataclasses.fields(cls)
                 if f.name != "mismatch"]
        return cls(mismatch=children[0], **dict(zip(names, aux)))

    # -- derived properties ---------------------------------------------
    @property
    def sparse_native(self) -> bool:
        """Only the O(D·N) slot model exists (no dense W can ever be built)."""
        return isinstance(self.mismatch, SparseMismatch)

    @property
    def has_slot_layout(self) -> bool:
        """Will programmed chips carry the (D, N) neighbor-table view?"""
        return self.sparse_native or self.attach_sparse

    def replace(self, **kw) -> "SamplerSpec":
        return dataclasses.replace(self, **kw)

    def partitioning(self) -> Partition | None:
        """The effective Partition: default rows-over-"data" when a mesh
        is given without an explicit partition; None when unsharded."""
        if self.mesh is None:
            return None
        return self.partition if self.partition is not None else Partition()

    def sync_policy(self) -> Sync | None:
        """The effective Sync policy: the bit-exact per-half-sweep barrier
        when a mesh is given without an explicit sync; None unsharded."""
        if self.mesh is None:
            return None
        return self.sync if self.sync is not None else Sync()

    # -- compile-cache fingerprint ---------------------------------------
    def fingerprint(self) -> tuple:
        """Canonicalized compile-cache key for this spec (hashable tuple).

        Two specs with equal fingerprints compile to interchangeable
        Sessions: the *resolved* backend/interpret (so ``backend="auto"``
        and the explicit name it resolves to share an entry), the graph
        shape bucket (rows/cols/k/mask — node ids and edge lists are
        derived from these deterministically), the effective partition +
        sync + mesh device assignment, the schedule/chains/beta/decimation
        statics, and the mismatch *structure* (type + per-leaf
        dtype/shape — the dense/sparse programming route and every array
        extent in the trace, but never the drawn values).  This is a pure
        shape-bucket key: chips, `Program`s, and mismatch draws are
        runtime operands of the compiled closures
        (`Session.sample_program`, the CD step's `with_mismatch` entry),
        so two specs differing only in drawn values — two chip instances
        of one SKU — share one executable and stream their programs into
        it.  The analog `HardwareConfig` scalars still bake into the
        programming arithmetic as closure constants and are deliberately
        NOT keyed: a cache mixing HardwareConfigs must key on hw
        separately (the serving layer holds a single service-wide
        HardwareConfig, so its bucket key stays safe).  The serving layer
        (`repro.serve`) keys its LRU Session cache on this: a 13-spin
        adder and a 440-spin chip embedded into the same shape bucket hit
        the same compiled executable and differ only in the streamed
        program.  Env vars are consulted exactly as Session compile would
        (via `resolve_backend`/`resolve_interpret`), so the key is
        computed in the same environment the Session is built in.
        """
        g = self.graph
        graph_sig = ("chimera", int(g.rows), int(g.cols), int(g.k),
                     tuple(sorted(tuple(c) for c in (g.masked_cells or ()))),
                     int(g.n_nodes), int(g.edges.shape[0]))
        mm_sig = (type(self.mismatch).__name__,
                  tuple((jax.tree_util.keystr(path), str(leaf.dtype),
                         tuple(int(d) for d in leaf.shape))
                        for path, leaf in
                        jax.tree_util.tree_flatten_with_path(
                            self.mismatch)[0]))
        mesh_sig = None
        if self.mesh is not None:
            mesh_sig = (tuple(self.mesh.axis_names),
                        tuple(int(self.mesh.shape[a])
                              for a in self.mesh.axis_names),
                        tuple(int(d.id) for d in self.mesh.devices.flat))
        part = self.partitioning()
        part_sig = None if part is None else (part.rows_axes, part.chain_axes)
        sync = self.sync_policy()
        sync_sig = None if sync is None else (
            sync.halo_every, sync.mode, sync.sweeps_per_launch)
        sched_sig = None
        if self.schedule is not None:
            sched_sig = (type(self.schedule).__name__,
                         tuple(sorted(dataclasses.asdict(
                             self.schedule).items())))
        return (graph_sig, mm_sig, self.noise,
                resolve_backend(self), int(self.chains), float(self.beta),
                float(self.w_scale), int(self.decimation),
                bool(self.attach_sparse), resolve_interpret(self),
                mesh_sig, part_sig, sync_sig, sched_sig,
                None if self.faults is None else repr(self.faults))

    # -- validation ------------------------------------------------------
    def validate(self) -> "SamplerSpec":
        """Static sanity checks; raises ValueError naming the fix."""
        if self.noise not in NOISE_KINDS:
            raise ValueError(
                f"unknown noise {self.noise!r}; pick from {NOISE_KINDS}")
        if self.backend not in BACKENDS + ("auto",) and \
                self.backend is not None:
            raise ValueError(
                f"unknown backend {self.backend!r}; pick from "
                f"{BACKENDS + ('auto',)}")
        if self.backend in FUSED_BACKENDS and \
                self.noise not in IN_KERNEL_NOISE:
            raise ValueError(
                f"backend {self.backend!r} generates noise in-kernel and "
                f"needs noise='counter' or 'lfsr', got {self.noise!r}")
        if self.backend in SPARSE_BACKENDS and not self.has_slot_layout:
            raise ValueError(
                f"backend {self.backend!r} needs the Chimera slot layout; "
                f"use attach_sparse=True or a sparse-native mismatch")
        if self.sparse_native and self.backend in ("ref", "pallas", "fused"):
            raise ValueError(
                f"this spec is sparse-native (no dense W exists); backend "
                f"{self.backend!r} cannot run it — use 'sparse', "
                f"'fused_sparse', or 'auto'")
        if self.chains < 1:
            raise ValueError(f"chains must be >= 1, got {self.chains}")
        if self.schedule is not None:
            self.schedule.betas(self.chains)  # raises on ladder mismatch
        self._validate_partition()
        self._validate_faults()
        return self

    def _validate_partition(self) -> None:
        if self.partition is not None and self.mesh is None:
            raise ValueError(
                "partition= set but mesh=None; pass the device mesh the "
                "partition shards over (e.g. launch.mesh.make_host_mesh)")
        if self.sync is not None and self.mesh is None:
            raise ValueError(
                "sync= is a sharded-execution policy (how often row bands "
                "exchange halos) but mesh=None; pass mesh= or drop sync=")
        part = self.partitioning()
        if part is None:
            return
        mesh_axes = tuple(self.mesh.axis_names)
        rows, chains = part.rows_axes, part.chain_axes
        if not rows and not chains:
            raise ValueError(
                "mesh= set but the Partition shards nothing; set "
                "Partition(rows=...) and/or Partition(chains=...)")
        for ax in rows + chains:
            if ax not in mesh_axes:
                raise ValueError(
                    f"partition axis {ax!r} not in mesh axes {mesh_axes}")
        if set(rows) & set(chains):
            raise ValueError(
                f"partition axes must be disjoint; {set(rows) & set(chains)}"
                f" appear in both rows and chains")
        if self.noise not in IN_KERNEL_NOISE:
            raise ValueError(
                f"sharded execution regenerates noise per (chain, node) "
                f"coordinate and needs noise='counter' or 'lfsr', got "
                f"{self.noise!r}")
        if not self.has_slot_layout:
            raise ValueError(
                "sharded execution runs on the Chimera slot layout; use "
                "attach_sparse=True or a sparse-native mismatch")
        sync = self.sync_policy()
        if self.backend not in (None, "auto", "sparse", "fused_sparse"):
            raise ValueError(
                f"sharded Sessions run the slot-layout scan path or, under "
                f"a launch-resident sync policy, the fused per-shard "
                f"kernel; backend must be 'sparse', 'fused_sparse', or "
                f"'auto', got {self.backend!r}")
        if self.backend == "fused_sparse":
            if not sync.fused_compatible:
                S = sync.sweeps_per_launch
                raise ValueError(
                    f"backend 'fused_sparse' runs whole launches inside one "
                    f"kernel; the kernel-resident halo exchange supports "
                    f"halo_every <= sweeps_per_launch, but sync={sync} has "
                    f"halo_every={sync.halo_every} with sweeps_per_launch="
                    f"{S} (exchange points {sync.exchange_points()}); "
                    f"nearest legal Sync: lower halo_every to {S} "
                    f"(kernel-resident exchange), raise it to >= {2 * S} "
                    f"or math.inf (launch-boundary exchange only), or use "
                    f"backend='sparse'")
            if self.noise != "counter":
                raise ValueError(
                    f"the fused per-shard kernel regenerates noise "
                    f"in-kernel from global (chain, node) coordinates and "
                    f"needs noise='counter', got {self.noise!r}; use "
                    f"backend='sparse' for lfsr")
        n_row = 1
        for ax in rows:
            n_row *= self.mesh.shape[ax]
        if n_row > self.graph.rows:
            raise ValueError(
                f"cannot shard {self.graph.rows} cell rows over {n_row} "
                f"devices; grow the lattice or shrink the rows axes")
        n_chain = 1
        for ax in chains:
            n_chain *= self.mesh.shape[ax]
        if self.chains % n_chain:
            raise ValueError(
                f"chains={self.chains} not divisible by the chain-axis "
                f"size {n_chain}")

    def _validate_faults(self) -> None:
        f = self.faults
        if f is None:
            return
        if not isinstance(f, Faults):
            raise ValueError(
                f"faults= must be an api.Faults instance, got "
                f"{type(f).__name__}")
        f.validate_for(self.graph, self.noise)
        if f.needs_host_hooks and self.backend in FUSED_BACKENDS:
            raise ValueError(
                f"backend {self.backend!r} runs whole sweeps inside one "
                f"kernel and cannot apply per-half-sweep fault hooks "
                f"(transient flips, stuck LFSR bits); use a scan backend "
                f"('ref'/'pallas'/'sparse') or backend='auto' (which "
                f"demotes to the scan path under these faults)")


# ---------------------------------------------------------------------------
# Compile-time resolution (the ONLY place env vars are consulted)
# ---------------------------------------------------------------------------
def resolve_backend(spec: SamplerSpec) -> str:
    """Spec backend -> concrete backend string, resolved once at compile.

    Explicit names win; ``auto``/``None`` consults REPRO_PBIT_BACKEND and
    then the kernels.md model.  The returned string is baked into the
    Session's closures — no env read ever happens at call time.

    A sharded spec (mesh=) runs the slot-layout scan per shard
    ("sparse"), or — when the sync policy is launch-resident and
    fused-compatible (``halo_every <= sweeps_per_launch`` or no
    mid-launch exchange) and the noise is counter — the fused per-shard
    kernel ("fused_sparse"), which ``auto`` picks by itself.  An env
    default naming a backend the partition cannot honor raises instead of
    being silently overridden.
    """
    if spec.mesh is not None:
        return _resolve_sharded_backend(spec)
    b = spec.backend
    if b in (None, "auto"):
        env = os.environ.get("REPRO_PBIT_BACKEND")
        b = env if env else _auto_backend(spec)
    if b not in BACKENDS:
        raise ValueError(f"unknown backend {b!r}; pick from {BACKENDS}")
    if b in FUSED_BACKENDS and spec.noise not in IN_KERNEL_NOISE:
        raise ValueError(
            f"backend {b!r} needs in-kernel noise ('counter' or 'lfsr'), "
            f"got {spec.noise!r}")
    if b in FUSED_BACKENDS and _fault_hooks(spec):
        raise ValueError(
            f"backend {b!r} cannot apply per-half-sweep fault hooks "
            f"(transient flips / stuck LFSR bits); unset "
            f"REPRO_PBIT_BACKEND or pick a scan backend")
    if b in ("ref", "pallas", "fused") and spec.sparse_native:
        raise ValueError(
            f"REPRO_PBIT_BACKEND={b!r} cannot run a sparse-native spec "
            f"(no dense W); use 'sparse' or 'fused_sparse'")
    return b


def _resolve_sharded_backend(spec: SamplerSpec) -> str:
    """Backend resolution under a mesh: 'sparse' or 'fused_sparse' only.

    The env default participates like everywhere else, but a value the
    partition cannot honor is a hard error — a sharded Session silently
    falling back to a different engine than the one the operator pinned
    is exactly the "works on my box" bug class the Session layer exists
    to kill.
    """
    sync = spec.sync_policy()
    fused_ok = (spec.noise == "counter" and sync.fused_compatible
                and not _fault_hooks(spec))
    b = spec.backend
    src = f"backend={b!r}"
    if b in (None, "auto"):
        env = os.environ.get("REPRO_PBIT_BACKEND")
        if env:
            b, src = env, f"REPRO_PBIT_BACKEND={env!r}"
        else:
            return ("fused_sparse"
                    if fused_ok and sync.launch_resident else "sparse")
    if b == "sparse":
        return b
    if b == "fused_sparse":
        if not fused_ok:
            S = sync.sweeps_per_launch
            raise ValueError(
                f"{src} names the fused per-shard kernel, but this sharded "
                f"spec cannot run it (needs noise='counter', a sync "
                f"policy with halo_every <= sweeps_per_launch or no "
                f"mid-launch exchange, and no fault hooks; got noise="
                f"{spec.noise!r}, sync={sync}, faults={spec.faults}); "
                f"nearest legal Sync: lower halo_every to {S}, raise it "
                f"to >= {2 * S} or math.inf, or use backend='sparse'")
        return b
    raise ValueError(
        f"{src} cannot run a mesh-sharded spec: the partitioned engine "
        f"supports 'sparse' (scan per shard) or 'fused_sparse' (launch-"
        f"resident kernel per shard), and the single-device backends "
        f"cannot halo-exchange")


def _fault_hooks(spec: SamplerSpec) -> bool:
    """Does the fault model need host-side per-half-sweep hooks?"""
    return spec.faults is not None and spec.faults.needs_host_hooks


def _auto_backend(spec: SamplerSpec) -> str:
    """kernels.md policy: prefer the slot layout; fall back by VMEM model.

    Fault hooks (transient flips, stuck LFSR bits) run between half-sweeps
    on the host side of the scan, so they demote ``auto`` from the fused
    engines to the matching scan backend.
    """
    in_kernel = spec.noise in IN_KERNEL_NOISE and not _fault_hooks(spec)
    if spec.has_slot_layout:
        return "fused_sparse" if in_kernel else "sparse"
    if in_kernel and dense_vmem_feasible(spec.graph.n_nodes):
        return "fused"
    return "ref"


def spec_fingerprint(spec: SamplerSpec) -> str:
    """Compact hex digest of `SamplerSpec.fingerprint()` — the string form
    used as the serving layer's LRU key and in health/metrics output."""
    import hashlib
    return hashlib.sha1(repr(spec.fingerprint()).encode()).hexdigest()[:16]


def resolve_interpret(spec: SamplerSpec) -> bool:
    """Pallas interpret mode, resolved once at compile.

    Delegates to the kernel layer's `default_interpret` so the
    REPRO_PALLAS_INTERPRET parsing rule exists in exactly one place.
    """
    if spec.interpret is not None:
        return bool(spec.interpret)
    from repro.kernels.ops import default_interpret
    return default_interpret()
