"""Runtime chip programs: the weight-streaming operand.

On silicon, reprogramming is an SPI write of DAC codes — milliseconds,
never a recompiled circuit.  A `Program` is the software twin: the full
runtime description of one programmed problem (edge codes, bias codes,
optional clamps, optional per-chip mismatch draw, optional schedule),
registered as a jax pytree so a compiled `api.Session` closure takes it
as an *argument*.  One executable per (graph-shape, partition, sync,
backend, noise) bucket then serves every program bit-exactly:

    prog = session.make_program(J_codes, h_codes)
    m, ns, _ = session.sample_program(prog, m, ns, betas)   # zero retrace

Swapping problems is a host->device copy of O(E) codes, not an XLA
compile — `benchmarks/bench_kernel.py`'s ``weight_streaming`` section
measures the gap.  Stacking programs along a leading axis
(`stack_programs`) gives the **fleet axis**: `Session.sample_fleet`
vmaps one executable over K mismatch draws / tenants / CD replicas.

The optional ``mismatch`` field carries a per-program chip-instance draw
(same type as the spec's — `Mismatch` or `SparseMismatch`).  ``None``
means "use the Session spec's draw"; a value makes the process variation
itself a runtime operand, which is what lets a virtual-chip fleet share
one compiled step (see `core/cd.py::make_cd_fleet_step`).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Program:
    """One runtime chip program — every field is a pytree leaf (or None).

    ``J_codes``/``h_codes`` are signed 8-bit DAC codes in the edge-list
    layout ((E,) / (N,)); clamp fields follow `Session.sample`'s
    contract ((N,) bool mask, (B, N) values); ``betas`` optionally
    carries the program's own (S,) or (S, B) schedule; ``mismatch``
    optionally overrides the spec's chip-instance draw.  Optional fields
    left ``None`` are structurally absent, so presence/absence selects
    the (cached) trace — values never do.

    Leaves may carry a leading fleet axis (K, ...) — see
    `stack_programs` and `Session.sample_fleet`.
    """

    J_codes: jax.Array
    h_codes: jax.Array
    mismatch: object | None = None
    clamp_mask: jax.Array | None = None
    clamp_values: jax.Array | None = None
    betas: jax.Array | None = None

    def tree_flatten(self):
        f = dataclasses.fields(self)
        return tuple(getattr(self, x.name) for x in f), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def stack_programs(programs) -> Program:
    """Stack same-structure programs along a new leading fleet axis.

    Every program must carry the same optional-field structure (all have
    clamps or none do, all carry a mismatch or none does) — the fleet
    runs one trace, so structure cannot vary across its members.
    Returns a `Program` whose every leaf has shape (K, ...), ready for
    `Session.sample_fleet` / `Session.make_cd_fleet_step`.
    """
    programs = list(programs)
    if not programs:
        raise ValueError("stack_programs needs at least one program")
    ref = jax.tree_util.tree_structure(programs[0])
    for k, p in enumerate(programs[1:], 1):
        if jax.tree_util.tree_structure(p) != ref:
            raise ValueError(
                f"program {k} has a different optional-field structure "
                f"than program 0; a fleet shares one trace, so every "
                f"member must carry the same fields")
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *programs)
