"""Compiled solver sessions: `SamplerSpec` -> jitted closures.

`Session(spec)` is the one choke point between every workload (CD
learning, annealing, Max-Cut, parallel tempering, clamped inference) and
the execution backends in core/pbit.py + kernels/.  Construction does all
the one-time work:

  * validates the spec and resolves ``backend`` / ``interpret`` (the only
    place REPRO_PBIT_BACKEND / REPRO_PALLAS_INTERPRET are read — call
    time never touches the environment);
  * builds the noise step function once (philox / counter / lfsr,
    including the LFSR's per-node gather permutation);
  * caches the graph's color masks, edge list, and Chimera slot tables;
  * materializes the spec's `Schedule` into the default beta array.

Sampling entry points return jitted closures cached per static signature
(clamped / collect / sweep counts), so repeated calls — the CD training
loop, tempering swap rounds, evaluation — pay zero re-trace or dispatch
overhead (benchmarks/bench_kernel.py `session_dispatch` measures this
against the legacy per-call path).

State threading is explicit everywhere: chips, spins, and noise state are
arguments and return values, never hidden attributes — a Session is
immutable after construction and safe to share across workloads.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.program import Program
from repro.api.spec import (
    SamplerSpec,
    resolve_backend,
    resolve_interpret,
)
from repro.core import pbit
from repro.core.hardware import (
    EffectiveChip,
    program_weights,
    program_weights_sparse,
    quantize_codes,
)
from repro.kernels.ref import scatter_edge_slots

# the fleet axis vmaps whole sampling closures; the launch-resident fused
# engines demote to their bit-exact scan siblings under vmap (the Pallas
# batching path is not part of the bit-exactness contract), so a K-fleet
# result is bit-identical to K sequential single-program calls
_FLEET_BACKEND = {"fused": "ref", "fused_sparse": "sparse", "pallas": "ref"}


class SessionState(NamedTuple):
    """Spins + noise state, the carry every closure threads explicitly."""

    m: jax.Array
    noise_state: jax.Array


# ---------------------------------------------------------------------------
# chip programming (spec-level: needs no backend/noise resolution, so it
# works on specs a Session would reject — programming only depends on the
# graph, the mismatch instance, and the analog model)
# ---------------------------------------------------------------------------
def _graph_tables(spec: SamplerSpec, tables=None):
    if tables is not None:
        return tables
    nbr_idx, nbr_mask = spec.graph.neighbor_table()
    slot_ij, slot_ji = spec.graph.edge_slots(nbr_idx)
    return nbr_idx, nbr_mask, slot_ij, slot_ji


def _scale_chip(spec: SamplerSpec, chip: EffectiveChip) -> EffectiveChip:
    # external-resistor scale: DAC LSB units -> neuron-input units
    upd = {"h": chip.h * spec.w_scale}
    if chip.W is not None:
        upd["W"] = chip.W * spec.w_scale
    if chip.nbr_w is not None:
        upd["nbr_w"] = chip.nbr_w * spec.w_scale
    return dataclasses.replace(chip, **upd)


def _saturate_edge_codes(spec: SamplerSpec, codes: jax.Array) -> jax.Array:
    """Apply stuck-at-full-scale weight DACs to (E,) edge codes.

    A saturated coupler drives ±127 regardless of the programmed code (sign
    follows the requested code; + when zero).  Idempotent, so the dense
    programming route may re-apply it at the (n, n) level harmlessly.
    """
    f = spec.faults
    if f is None or not f.saturated_edges:
        return codes
    sat = np.asarray(f.saturated_edges, np.int64)
    cur = codes[sat]
    full = jnp.where(cur < 0, -127, 127).astype(codes.dtype)
    return codes.at[sat].set(full)


def _apply_code_faults(spec: SamplerSpec, J_codes: jax.Array,
                       enable: jax.Array | None):
    """Dense-codes view of the saturation fault (+ forced enable)."""
    f = spec.faults
    if f is None or not f.saturated_edges:
        return J_codes, enable
    e = spec.graph.edges
    sat = np.asarray(f.saturated_edges, np.int64)
    i, j = e[sat, 0], e[sat, 1]
    J = jnp.asarray(J_codes)
    full = jnp.where(J[i, j] < 0, -127, 127).astype(J.dtype)
    J = J.at[i, j].set(full).at[j, i].set(full)
    if enable is not None:
        # the stuck DAC drives current whether or not the coupler was
        # meant to be enabled
        enable = jnp.asarray(enable).at[i, j].set(True).at[j, i].set(True)
    return J, enable


def _kill_dead_edges(spec: SamplerSpec, chip: EffectiveChip,
                     tables) -> EffectiveChip:
    """Open-circuit the dead couplers: zero coupling in both directions,
    including the disabled-coupler leakage (a broken bond wire carries no
    current at all).  Runs after programming/scaling so it is the last
    word on those entries."""
    f = spec.faults
    if f is None or not f.dead_edges:
        return chip
    _, _, slot_ij, slot_ji = tables
    e = spec.graph.edges
    de = np.asarray(f.dead_edges, np.int64)
    i, j = e[de, 0], e[de, 1]
    upd = {}
    if chip.W is not None:
        upd["W"] = chip.W.at[i, j].set(0.0).at[j, i].set(0.0)
    if chip.nbr_w is not None:
        s_ij = np.asarray(slot_ij)[de]
        s_ji = np.asarray(slot_ji)[de]
        upd["nbr_w"] = (chip.nbr_w.at[s_ij, i].set(0.0)
                        .at[s_ji, j].set(0.0))
    return dataclasses.replace(chip, **upd) if upd else chip


def program(spec: SamplerSpec, J_codes: jax.Array, h_codes: jax.Array,
            enable: jax.Array | None = None, *, tables=None
            ) -> EffectiveChip:
    """Program dense (n, n) symmetric 8-bit codes through the spec's
    analog model (sparse-native specs gather the codes into slots).

    The spec's `Faults` apply here: saturated couplers override their codes
    with ±127 before the DAC transfer, dead couplers are open-circuited
    after programming."""
    tables = _graph_tables(spec, tables)
    nbr_idx, nbr_mask, _, _ = tables
    J_codes, enable = _apply_code_faults(spec, J_codes, enable)
    if enable is None:
        enable = jnp.abs(jnp.asarray(J_codes)) > 0
    if spec.sparse_native:
        rows = jnp.arange(spec.graph.n_nodes)[None, :]
        idx = jnp.asarray(nbr_idx)
        chip = program_weights_sparse(
            jnp.asarray(J_codes)[rows, idx], h_codes,
            jnp.asarray(enable)[rows, idx], spec.mismatch, spec.hw,
            idx, jnp.asarray(nbr_mask))
    else:
        adj = jnp.asarray(spec.graph.adjacency())
        neighbors = jnp.asarray(nbr_idx) if spec.attach_sparse else None
        chip = program_weights(J_codes, h_codes, enable, spec.mismatch,
                               spec.hw, adjacency=adj, neighbors=neighbors)
    return _kill_dead_edges(spec, _scale_chip(spec, chip), tables)


def program_edges(spec: SamplerSpec, J_edge_codes: jax.Array,
                  h_codes: jax.Array, *, tables=None) -> EffectiveChip:
    """Program per-edge codes (E,) — the CD master-weight layout."""
    tables = _graph_tables(spec, tables)
    nbr_idx, nbr_mask, slot_ij, slot_ji = tables
    e = spec.graph.edges
    codes = _saturate_edge_codes(spec, jnp.asarray(J_edge_codes))
    if spec.sparse_native:
        J_slots = scatter_edge_slots(codes, e, slot_ij, slot_ji,
                                     nbr_idx.shape[0], spec.graph.n_nodes)
        chip = program_weights_sparse(
            J_slots, h_codes, jnp.abs(J_slots) > 0, spec.mismatch,
            spec.hw, jnp.asarray(nbr_idx), jnp.asarray(nbr_mask))
        return _kill_dead_edges(spec, _scale_chip(spec, chip), tables)
    n = spec.graph.n_nodes
    J = (jnp.zeros((n, n), codes.dtype)
         .at[e[:, 0], e[:, 1]].set(codes)
         .at[e[:, 1], e[:, 0]].set(codes))
    return program(spec, J, h_codes, tables=(nbr_idx, nbr_mask, slot_ij,
                                             slot_ji))


def program_master(spec: SamplerSpec, Jm: jax.Array, hm: jax.Array,
                   *, tables=None) -> EffectiveChip:
    """Quantize float masters — edge-list (E,) or dense (n, n) — and
    program."""
    Jm = jnp.asarray(Jm)
    if Jm.ndim == 1:
        return program_edges(spec, quantize_codes(Jm), quantize_codes(hm),
                             tables=tables)
    return program(spec, quantize_codes(Jm), quantize_codes(hm),
                   tables=tables)


def program_chip(spec: SamplerSpec, prog: Program, *, tables=None
                 ) -> EffectiveChip:
    """Program a runtime `Program` through the spec's analog model.

    This is the weight-streaming path: it runs *inside* the jitted
    sampling closures with the program's leaves as traced operands, so a
    new program never retraces — the scatter + DAC transfer + compression
    chain is part of the compiled executable and only its inputs change.
    A program-borne ``mismatch`` overrides the spec's draw (same pytree
    structure required; `Session.make_program` enforces the type).
    """
    if prog.mismatch is not None:
        spec = spec.replace(mismatch=prog.mismatch)
    return program_edges(spec, prog.J_codes, prog.h_codes, tables=tables)


class Session:
    """A compiled solver: spec-resolved programming + sampling closures."""

    def __init__(self, spec: SamplerSpec):
        self.spec = spec.validate()
        self.backend = resolve_backend(spec)
        self.interpret = resolve_interpret(spec)
        g = spec.graph
        self.graph = g
        self._color = jnp.asarray(g.color)
        self._edges = jnp.asarray(g.edges)
        nbr_idx, nbr_mask = g.neighbor_table()
        slot_ij, slot_ji = g.edge_slots(nbr_idx)
        self._nbr = (nbr_idx, nbr_mask, slot_ij, slot_ji)
        self._fault_cm, self._fault_cv, self._alive_edges = \
            self._compile_faults()
        self._noise_init, self._noise_step = self._make_noise()
        self._flip_fn = self._make_flip_fn()
        self._engine = None
        if spec.mesh is not None:
            # multi-device execution: the partition plan, the sync-policy
            # launch loop, and the shard_map'd sweep live in
            # core/distributed.ShardedEngine; the closures below delegate
            # to it with identical array contracts (incl. the fault hooks:
            # stuck spins ride the clamp path below, flips and stuck LFSR
            # bits are regenerated per shard from global coordinates)
            from repro.core.distributed import ShardedEngine
            self._engine = ShardedEngine(
                g, spec.mesh, spec.partitioning(), spec.noise,
                spec.decimation, spec.chains, sync=spec.sync_policy(),
                backend=self.backend, interpret=self.interpret,
                faults=spec.faults)
        self.default_betas = (
            None if spec.schedule is None
            else spec.schedule.betas(spec.chains))
        self._fns: dict = {}

    @property
    def partition_plan(self):
        """The compile-time `core.distributed.RowPartition` of a sharded
        Session (None when mesh=None) — the public handle for halo /
        boundary accounting (`distributed.halo_bytes_per_sweep`)."""
        return None if self._engine is None else self._engine.plan

    # ------------------------------------------------------------------
    # construction helpers
    # ------------------------------------------------------------------
    def _compile_faults(self):
        """Static fault draw -> device arrays the closures close over.

        Stuck-at-spin faults become a (N,) clamp mask + values merged into
        every entry point's clamp arguments (the same machinery the CD
        positive phase and the sharded frozen-column path use, which is
        what makes the injection bit-exact across all backends).  Dead and
        saturated couplers become the (E,) alive mask that gates the CD
        gradient — their DACs cannot take an update.
        """
        f = self.spec.faults
        n, n_edges = self.graph.n_nodes, self.graph.n_edges
        cm = cv = alive = None
        if f is not None and f.stuck_nodes:
            cm_np = np.zeros((n,), bool)
            cv_np = np.zeros((n,), np.float32)
            cm_np[list(f.stuck_nodes)] = True
            cv_np[list(f.stuck_nodes)] = np.asarray(f.stuck_values,
                                                    np.float32)
            cm, cv = jnp.asarray(cm_np), jnp.asarray(cv_np)
        if f is not None and f.faulty_edges:
            alive_np = np.ones((n_edges,), np.float32)
            alive_np[list(f.faulty_edges)] = 0.0
            alive = jnp.asarray(alive_np)
        return cm, cv, alive

    def _merge_faults(self, m, cm, cv):
        """Fold the stuck-spin fault clamp into a caller's clamp args.

        The stuck values are written into ``m`` up front, so a mask-only
        (freeze-in-place) caller clamp stays mask-only; explicit caller
        values are overridden at fault positions — a latched p-bit reads
        its latched value even when driven by data.
        """
        fm, fv = self._fault_cm, self._fault_cv
        if fm is None:
            return m, cm, cv
        m = jnp.where(fm, fv, m.astype(jnp.float32)).astype(m.dtype)
        if cm is None:
            return m, fm, None
        cm2 = jnp.asarray(cm) | fm
        if cv is None:
            return m, cm2, None
        return m, cm2, jnp.where(fm, fv, jnp.asarray(cv))

    def _make_noise(self) -> tuple[Callable, pbit.NoiseFn]:
        spec = self.spec
        if spec.noise == "lfsr":
            init, step = pbit.make_lfsr_noise(spec.graph, spec.chains,
                                              spec.decimation)
            return self._wrap_lfsr_stuck(init, step)
        if spec.noise == "counter":
            return pbit.make_counter_noise(spec.chains, spec.graph.n_nodes)
        step = pbit.make_philox_noise(spec.chains, spec.graph.n_nodes)
        return (lambda key: key), step

    def _wrap_lfsr_stuck(self, init0, step0):
        """Degraded-RNG fault: force register bits of named per-cell LFSRs
        to 0/1 after every decimated clock (and at seeding), then read the
        uniforms from the forced state."""
        f = self.spec.faults
        if f is None or not f.lfsr_stuck:
            return init0, step0
        from repro.core import lfsr as lfsr_mod
        n_cells = self.graph.n_nodes // 8
        s0 = np.zeros((n_cells,), np.uint32)
        s1 = np.zeros((n_cells,), np.uint32)
        for cell, m0, m1 in f.lfsr_stuck:
            if not 0 <= int(cell) < n_cells:
                raise ValueError(
                    f"lfsr_stuck cell {cell} out of range for "
                    f"{n_cells} unit cells")
            s0[int(cell)] |= np.uint32(m0)
            s1[int(cell)] |= np.uint32(m1)
        s0j, s1j = jnp.asarray(s0), jnp.asarray(s1)
        perm = jnp.asarray(np.asarray(step0.spec.gather_perm))
        dec = self.spec.decimation

        def fix(state):
            return (state & ~s0j) | s1j

        def init(key):
            return fix(init0(key))

        def step(state):
            st = fix(lfsr_mod.lfsr_step_n(state, dec))
            u = jnp.take(lfsr_mod.flat_cell_uniforms(st), perm, axis=-1)
            return st, u

        step.spec = step0.spec
        return init, step

    def _make_flip_fn(self):
        """Seeded transient-flip hook (api.Faults.flip_prob).

        Draws from a stream *salted away from* the sampling noise —
        counter noise XORs the seed, philox folds a constant into the key
        — addressed by the pre-half-sweep noise state, so injecting flips
        never perturbs the underlying Gibbs stream and the same fault draw
        reproduces across backends (and across shards, which regenerate
        the same hash from global (chain, node) coordinates).
        """
        from repro.api.faults import FLIP_FOLD, FLIP_SALT
        f = self.spec.faults
        if f is None or f.flip_prob <= 0.0:
            return None
        p = float(f.flip_prob)
        if self.spec.noise == "counter":
            from repro.core import lfsr as lfsr_mod
            rows = jnp.arange(self.spec.chains, dtype=jnp.uint32)[:, None]
            cols = jnp.arange(self.graph.n_nodes,
                              dtype=jnp.uint32)[None, :]
            thresh = jnp.uint32(round(p * 65536.0))
            salt = jnp.uint32((int(f.flip_seed) ^ FLIP_SALT) & 0xFFFFFFFF)

            def flip(ns0):
                bits = lfsr_mod.counter_bits(ns0[0] ^ salt, ns0[1],
                                             rows, cols)
                return ((bits >> jnp.uint32(16))
                        & jnp.uint32(0xFFFF)) < thresh

            return flip
        if self.spec.noise == "philox":
            shape = (self.spec.chains, self.graph.n_nodes)
            fold = (FLIP_FOLD ^ int(f.flip_seed)) & 0x7FFFFFFF

            def flip(ns0):
                return jax.random.bernoulli(
                    jax.random.fold_in(ns0, fold), p, shape)

            return flip
        return None  # lfsr noise + flips rejected by spec validation

    def _fn(self, key, builder, *args):
        fn = self._fns.get(key)
        if fn is None:
            fn = builder(*args)
            self._fns[key] = fn
        return fn

    def _betas(self, betas) -> jax.Array:
        if betas is None:
            if self.default_betas is None:
                raise ValueError(
                    "this Session's spec has no schedule; pass betas "
                    "explicitly or build the spec with schedule=")
            return self.default_betas
        return jnp.asarray(betas, jnp.float32)

    # ------------------------------------------------------------------
    # state initialization (explicit key threading)
    # ------------------------------------------------------------------
    def random_spins(self, key: jax.Array) -> jax.Array:
        return pbit.random_spins(key, self.spec.chains, self.graph.n_nodes)

    def noise_state(self, key: jax.Array) -> jax.Array:
        return self._noise_init(key)

    def init_state(self, key: jax.Array) -> SessionState:
        k1, k2 = jax.random.split(key)
        return SessionState(self.random_spins(k1), self.noise_state(k2))

    # ------------------------------------------------------------------
    # chip programming (dense or sparse-native, per the spec's mismatch)
    # ------------------------------------------------------------------
    def program(self, J_codes: jax.Array, h_codes: jax.Array,
                enable: jax.Array | None = None) -> EffectiveChip:
        """Program dense (n, n) symmetric 8-bit codes."""
        return program(self.spec, J_codes, h_codes, enable,
                       tables=self._nbr)

    def program_edges(self, J_edge_codes: jax.Array, h_codes: jax.Array
                      ) -> EffectiveChip:
        """Program per-edge codes (E,) — the CD master-weight layout."""
        return program_edges(self.spec, J_edge_codes, h_codes,
                             tables=self._nbr)

    def program_master(self, Jm: jax.Array, hm: jax.Array) -> EffectiveChip:
        """Quantize float masters — edge-list (E,) or dense (n, n) — and
        program."""
        return program_master(self.spec, Jm, hm, tables=self._nbr)

    # ------------------------------------------------------------------
    # runtime weight streaming (program as operand, not constant)
    # ------------------------------------------------------------------
    def make_program(
        self,
        J_edge_codes: jax.Array,
        h_codes: jax.Array,
        *,
        mismatch=None,
        clamp_mask: jax.Array | None = None,
        clamp_values: jax.Array | None = None,
        betas: jax.Array | None = None,
    ) -> Program:
        """Package edge-list codes (E,) + bias codes (N,) as a runtime
        `Program` for `sample_program` / `sample_fleet`.

        Only shapes and the optional-field structure are compile-time;
        the values stream into an already-compiled executable.  An
        explicit ``mismatch`` must be the same type as the spec's (the
        dense/sparse programming route is a static property of the
        trace).
        """
        E, n = self.graph.n_edges, self.graph.n_nodes
        J = jnp.asarray(J_edge_codes)
        h = jnp.asarray(h_codes)
        if J.shape != (E,):
            raise ValueError(
                f"J_edge_codes must be edge-list shaped ({E},), got "
                f"{J.shape}; scatter dense codes to the edge list first")
        if h.shape != (n,):
            raise ValueError(f"h_codes must be ({n},), got {h.shape}")
        if mismatch is not None and \
                type(mismatch) is not type(self.spec.mismatch):
            raise ValueError(
                f"program mismatch type {type(mismatch).__name__} does "
                f"not match the spec's "
                f"{type(self.spec.mismatch).__name__}; the dense/sparse "
                f"programming route is baked into the trace")
        if clamp_mask is not None:
            clamp_mask = jnp.asarray(clamp_mask)
            if clamp_values is not None:
                clamp_values = jnp.asarray(clamp_values, jnp.float32)
        elif clamp_values is not None:
            raise ValueError("clamp_values without clamp_mask")
        if betas is not None:
            betas = jnp.asarray(betas, jnp.float32)
        return Program(J_codes=J, h_codes=h, mismatch=mismatch,
                       clamp_mask=clamp_mask, clamp_values=clamp_values,
                       betas=betas)

    def sample_program(
        self,
        prog: Program,
        m: jax.Array,
        noise_state: jax.Array,
        betas: jax.Array | None = None,
        *,
        collect: bool = False,
    ) -> tuple[jax.Array, jax.Array, jax.Array | None]:
        """`sample`, with the chip programmed *inside* the jit from a
        runtime `Program`: (m', state', traj|None).

        One executable per optional-field structure serves every program
        on this Session's spec — swapping problems is an O(E) host→device
        copy, never a retrace (benchmarks `weight_streaming` section).
        Beta priority: explicit ``betas`` arg > ``prog.betas`` > the
        spec's schedule.
        """
        if betas is None and prog.betas is None:
            betas = self._betas(None)
        elif betas is not None:
            betas = jnp.asarray(betas, jnp.float32)
        fn = self._fn(("sample_program", collect),
                      self._build_sample_program, collect)
        return fn(prog, m, noise_state, betas)

    def _build_sample_program(self, collect: bool):
        def impl(prog, m, ns, betas):
            chip = program_chip(self.spec, prog, tables=self._nbr)
            b = betas if betas is not None else prog.betas
            m, cm, cv = self._merge_faults(m, prog.clamp_mask,
                                           prog.clamp_values)
            if self._engine is not None:
                return self._engine.sample(chip, m, ns, b, cm, cv, collect)
            return pbit.gibbs_sample(
                chip, self._color, m, b, ns, self._noise_step,
                clamp_mask=cm, clamp_values=cv, collect=collect,
                backend=self.backend, interpret=self.interpret,
                flip_fn=self._flip_fn)

        # one jit: a changed optional-field structure (clamps, mismatch,
        # program-borne betas) retraces, changed values never do
        return jax.jit(impl)

    def sample_fleet(
        self,
        progs: Program,
        m: jax.Array,
        noise_state: jax.Array,
        betas: jax.Array | None = None,
    ) -> tuple[jax.Array, jax.Array, jax.Array | None]:
        """Run a stacked K-program fleet (see `api.stack_programs`)
        through ONE executable: (m'[K, B, N], state'[K, ...], None).

        ``m`` / ``noise_state`` carry a leading K axis; ``betas`` (or the
        spec schedule) is shared across the fleet unless the programs
        carry their own.  Fused backends demote to their bit-exact scan
        siblings under vmap, so the fleet result is bit-identical to K
        sequential `sample_program` calls.  Single-device only — shard a
        fleet across a mesh by giving each device its own Session.
        """
        if self._engine is not None:
            raise ValueError(
                "sample_fleet runs on single-device Sessions; a sharded "
                "mesh already owns the device axis — run one fleet per "
                "device instead")
        if betas is not None:
            betas = jnp.asarray(betas, jnp.float32)
        elif progs.betas is None:
            betas = self._betas(None)
        fn = self._fn(("sample_fleet",), self._build_sample_fleet)
        return fn(progs, m, noise_state, betas)

    def _build_sample_fleet(self):
        backend = _FLEET_BACKEND.get(self.backend, self.backend)

        def one(prog, m, ns, betas):
            chip = program_chip(self.spec, prog, tables=self._nbr)
            b = betas if betas is not None else prog.betas
            m, cm, cv = self._merge_faults(m, prog.clamp_mask,
                                           prog.clamp_values)
            return pbit.gibbs_sample(
                chip, self._color, m, b, ns, self._noise_step,
                clamp_mask=cm, clamp_values=cv, collect=False,
                backend=backend, interpret=self.interpret,
                flip_fn=self._flip_fn)

        return jax.jit(jax.vmap(one, in_axes=(0, 0, 0, None)))

    # ------------------------------------------------------------------
    # sampling closures
    # ------------------------------------------------------------------
    def sample(
        self,
        chip: EffectiveChip,
        m: jax.Array,
        noise_state: jax.Array,
        betas: jax.Array | None = None,
        *,
        clamp_mask: jax.Array | None = None,
        clamp_values: jax.Array | None = None,
        collect: bool = False,
    ) -> tuple[jax.Array, jax.Array, jax.Array | None]:
        """Run the schedule (or explicit ``betas``): (m', state', traj|None).

        ``collect=True`` returns the (S, B, N) per-sweep trajectory and
        forces the scan path (the fused engines cannot emit it).
        """
        betas = self._betas(betas)
        clamped = clamp_mask is not None
        fn = self._fn(("sample", collect, clamped),
                      self._build_sample, collect, clamped)
        if clamped:
            return fn(chip, m, noise_state, betas, clamp_mask, clamp_values)
        return fn(chip, m, noise_state, betas)

    def _build_sample(self, collect: bool, clamped: bool):
        def impl(chip, m, ns, betas, cm=None, cv=None):
            m, cm, cv = self._merge_faults(m, cm, cv)
            if self._engine is not None:
                return self._engine.sample(chip, m, ns, betas, cm, cv,
                                           collect)
            return pbit.gibbs_sample(
                chip, self._color, m, betas, ns, self._noise_step,
                clamp_mask=cm, clamp_values=cv, collect=collect,
                backend=self.backend, interpret=self.interpret,
                flip_fn=self._flip_fn)

        if clamped:
            return jax.jit(impl)
        return jax.jit(lambda chip, m, ns, betas: impl(chip, m, ns, betas))

    def stats(
        self,
        chip: EffectiveChip,
        m: jax.Array,
        noise_state: jax.Array,
        n_sweeps: int,
        burn_in: int,
        *,
        clamp_mask: jax.Array | None = None,
        clamp_values: jax.Array | None = None,
        beta: float | None = None,
    ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
        """On-line first/second moments at the spec's base beta:
        (mean_spin[N], mean_edge_corr[E], m', noise_state')."""
        beta = self.spec.beta if beta is None else float(beta)
        clamped = clamp_mask is not None
        fn = self._fn(("stats", n_sweeps, burn_in, beta, clamped),
                      self._build_stats, n_sweeps, burn_in, beta, clamped)
        if clamped:
            return fn(chip, m, noise_state, clamp_mask, clamp_values)
        return fn(chip, m, noise_state)

    def _build_stats(self, n_sweeps, burn_in, beta, clamped):
        def impl(chip, m, ns, cm=None, cv=None):
            m, cm, cv = self._merge_faults(m, cm, cv)
            if self._engine is not None:
                return self._engine.stats(chip, m, ns, beta, n_sweeps,
                                          burn_in, cm, cv)
            return pbit.gibbs_stats(
                chip, self._color, m, beta, n_sweeps, burn_in, ns,
                self._noise_step, self._edges, clamp_mask=cm,
                clamp_values=cv, backend=self.backend,
                interpret=self.interpret, flip_fn=self._flip_fn)

        if clamped:
            return jax.jit(impl)
        return jax.jit(lambda chip, m, ns: impl(chip, m, ns))

    def visible_hist(
        self,
        chip: EffectiveChip,
        m: jax.Array,
        noise_state: jax.Array,
        visible_idx: np.ndarray,
        burn_in: int,
        betas: jax.Array | None = None,
    ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Streaming visible-pattern histogram: (counts[2^nv], m', state')."""
        betas = self._betas(betas)
        vis_key = tuple(int(i) for i in np.asarray(visible_idx))
        fn = self._fn(("hist", vis_key, burn_in),
                      self._build_hist, np.asarray(visible_idx), burn_in)
        return fn(chip, m, noise_state, betas)

    def _build_hist(self, visible_idx, burn_in):
        def impl(chip, m, ns, betas):
            m, cm, cv = self._merge_faults(m, None, None)
            if self._engine is not None:
                return self._engine.visible_hist(chip, m, ns, betas,
                                                 burn_in, visible_idx,
                                                 cm, cv)
            return pbit.gibbs_visible_hist(
                chip, self._color, m, betas, burn_in, ns, self._noise_step,
                visible_idx, backend=self.backend,
                interpret=self.interpret, clamp_mask=cm, clamp_values=cv,
                flip_fn=self._flip_fn)

        return jax.jit(impl)

    # ------------------------------------------------------------------
    # contrastive divergence (the in-situ learning closure)
    # ------------------------------------------------------------------
    def make_cd_step(self, cfg, visible_idx: np.ndarray):
        """Build the jitted one-epoch CD update (paper Fig. 7a).

        ``cfg`` is a core.cd.CDConfig (duck-typed).  Returns
        step(Jm, hm, data_vis, m, noise_state, vel) ->
        (Jm, hm, m, noise_state, vel, metrics) with (E,) edge-list master
        couplings; both Gibbs phases and the weight update run inside one
        jit through this session's backend.

        The mismatch draw enters the jit as an *operand* (the returned
        step partially applies the spec's draw; ``step.with_mismatch``
        exposes the raw (mismatch, Jm, hm, ...) entry), so the compiled
        executable carries no chip-instance constants — the substrate of
        `make_cd_fleet_step` and of zero-retrace hardware-in-the-loop
        epochs.
        """
        if cfg.chains != self.spec.chains:
            raise ValueError(
                f"CDConfig.chains={cfg.chains} but this Session was "
                f"compiled for chains={self.spec.chains}; build the "
                f"session with chains=cfg.chains")
        key = ("cd_step", cfg.lr, cfg.cd_k, cfg.pos_sweeps, cfg.burn_in,
               cfg.h_lr_scale, cfg.weight_decay, cfg.persistent,
               cfg.momentum,
               tuple(int(i) for i in np.asarray(visible_idx)))
        return self._fn(key, self._build_cd_step, cfg,
                        np.asarray(visible_idx))

    def make_cd_fleet_step(self, cfg, visible_idx: np.ndarray):
        """Build the K-replica hardware-aware CD step: one executable,
        per-chip mismatch draws streamed in as operands.

        Returns step(mismatches, Jm, hm, data_vis, m, noise_state, vel)
        -> (Jm, hm, m, noise_state, vel, metrics) where every argument
        except ``data_vis`` (the shared data batch) carries a leading K
        fleet axis: ``mismatches`` is a stacked draw (see
        `core.cd.PBitMachine.fleet_mismatch`), Jm (K, E), hm (K, N),
        m (K, B, N), vel a pair of (K, E)/(K, N) arrays; metrics come
        back stacked per chip.  Fused backends demote to their bit-exact
        scan siblings under vmap, so fleet epochs match K sequential
        per-chip epochs bit-for-bit.
        """
        if self._engine is not None:
            raise ValueError(
                "fleet CD runs on single-device Sessions; a sharded mesh "
                "already owns the device axis — run one fleet per device")
        if cfg.chains != self.spec.chains:
            raise ValueError(
                f"CDConfig.chains={cfg.chains} but this Session was "
                f"compiled for chains={self.spec.chains}; build the "
                f"session with chains=cfg.chains")
        key = ("cd_fleet", cfg.lr, cfg.cd_k, cfg.pos_sweeps, cfg.burn_in,
               cfg.h_lr_scale, cfg.weight_decay, cfg.persistent,
               cfg.momentum,
               tuple(int(i) for i in np.asarray(visible_idx)))

        def build():
            step_mm = self._build_cd_step_mm(cfg, np.asarray(visible_idx),
                                             fleet=True)
            return jax.jit(jax.vmap(step_mm,
                                    in_axes=(0, 0, 0, None, 0, 0, 0)))

        return self._fn(key, build)

    def _build_cd_step(self, cfg, visible_idx):
        step_mm = jax.jit(self._build_cd_step_mm(cfg, visible_idx,
                                                 fleet=False))
        mm = self.spec.mismatch

        def step(Jm, hm, data_vis, m, noise_state, vel):
            return step_mm(mm, Jm, hm, data_vis, m, noise_state, vel)

        step.with_mismatch = step_mm
        return step

    def _build_cd_step_mm(self, cfg, visible_idx, *, fleet: bool):
        from repro.core.hardware import WMAX, WMIN

        n = self.graph.n_nodes
        vis = jnp.asarray(visible_idx)
        clamp_mask = jnp.zeros((n,), bool).at[vis].set(True)
        beta = self.spec.beta
        backend = (_FLEET_BACKEND.get(self.backend, self.backend)
                   if fleet else self.backend)

        def phase(chip, m0, n_sweeps, ns, cm=None, cv=None):
            if self._engine is not None:
                # sharded phases: rows partition halo-exchanges, a chains
                # partition runs the Gibbs replicas per-device and
                # psum-reduces the (E,) gradient moments once per phase
                return self._engine.stats(chip, m0, ns, beta, n_sweeps,
                                          cfg.burn_in, cm, cv)
            return pbit.gibbs_stats(
                chip, self._color, m0, beta, n_sweeps, cfg.burn_in, ns,
                self._noise_step, self._edges, clamp_mask=cm,
                clamp_values=cv, backend=backend,
                interpret=self.interpret, flip_fn=self._flip_fn)

        def step(mismatch, Jm, hm, data_vis, m, noise_state, vel):
            chip = program_edges(self.spec.replace(mismatch=mismatch),
                                 quantize_codes(Jm), quantize_codes(hm),
                                 tables=self._nbr)
            clamp_values = jnp.zeros((cfg.chains, n), jnp.float32)
            clamp_values = clamp_values.at[:, vis].set(data_vis)

            # positive phase: visibles pinned to data (stuck p-bits win
            # over the data drive — the latch reads its latched value)
            m, pos_cm, pos_cv = self._merge_faults(m, clamp_mask,
                                                   clamp_values)
            pos_s, pos_c, m_pos, noise_state = phase(
                chip, m, cfg.pos_sweeps, noise_state, pos_cm, pos_cv)
            # negative phase: CD-k from the positive-phase state, or from
            # the persistent chains (PCD)
            neg_init = m if cfg.persistent else m_pos
            neg_s, neg_c, m_neg, noise_state = phase(
                chip, neg_init, cfg.cd_k, noise_state, self._fault_cm,
                None)

            gJ = pos_c - neg_c
            gh = pos_s - neg_s
            if self._alive_edges is not None:
                # dead/saturated couplers carry no reprogrammable DAC:
                # their gradient is noise and would only corrupt momentum
                gJ = gJ * self._alive_edges
            # skip-and-log guard: a non-finite gradient (bad data batch,
            # device fault) must never reach the master weights
            ok = jnp.isfinite(gJ).all() & jnp.isfinite(gh).all()
            vel_J, vel_h = vel
            vel_J_new = cfg.momentum * vel_J + gJ
            vel_h_new = cfg.momentum * vel_h + gh
            Jm_new = (1.0 - cfg.weight_decay) * Jm + cfg.lr * vel_J_new
            hm_new = (1.0 - cfg.weight_decay) * hm \
                + cfg.lr * cfg.h_lr_scale * vel_h_new
            Jm_new = jnp.clip(Jm_new, WMIN, WMAX)
            hm_new = jnp.clip(hm_new, WMIN, WMAX)
            Jm = jnp.where(ok, Jm_new, Jm)
            hm = jnp.where(ok, hm_new, hm)
            vel_J = jnp.where(ok, vel_J_new, vel_J)
            vel_h = jnp.where(ok, vel_h_new, vel_h)
            # the chains too: NaNs in m_neg would poison the next epoch
            m_out = jnp.where(ok, m_neg, m)
            metrics = {
                "corr_err": jnp.abs(pos_c - neg_c).mean(),
                "mean_err": jnp.abs(pos_s - neg_s).mean(),
                "update_skipped": 1.0 - ok.astype(jnp.float32),
            }
            return Jm, hm, m_out, noise_state, (vel_J, vel_h), metrics

        return step
