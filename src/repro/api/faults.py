"""First-class fault injection: discrete failure modes as a spec field.

The analog `Mismatch` model covers *smooth* imperfection (Gaussian process
variation); real chips — and the related device lines (stochastic-MTJ
in-situ learning, arXiv:2102.05137; CMOS+nanomagnet heterogeneous
inference, arXiv:2304.05949) — also fail *discretely*: a p-bit whose
comparator latched, a coupler bond wire that opened, a weight DAC stuck at
full scale, an RNG register bit welded to the rail.  `Faults` is the
frozen, hashable value object that names one such fault realization; it
rides on `api.SamplerSpec` and `api.Session` compiles it into every
backend (docs/robustness.md has the taxonomy and the per-backend
compilation table):

  * **stuck-at-spin** — node ``i`` reads ±1 forever.  Compiled into the
    clamp machinery every backend already honors (update-mask exclusion +
    value pinning), so it works through the scan backends, the fused
    Pallas kernels, and the sharded halo exchange unchanged.  Noise is
    still drawn for stuck nodes (the full (B, N) stream is consumed per
    half-sweep regardless of masks), which is what keeps every backend
    bit-exact against the others under the same fault draw.
  * **dead coupler** — edge ``e`` is an open circuit: zero current in both
    directions (not even the disabled-coupler leakage).  Applied after
    programming, on both the dense W and the slot-layout nbr_w view.
  * **saturated coupler** — the edge's weight DAC is stuck at full scale:
    the programmed code is replaced by ±127 (sign of the requested code;
    + for zero) before the DAC transfer.  Dead and saturated couplers are
    both excluded from CD's (E,) gradient — their DACs cannot be
    reprogrammed, so accumulating gradient there only corrupts momentum.
  * **stuck LFSR bits** — register bits of specific per-cell LFSRs forced
    to 0/1 after every decimated clock (degraded RNG).  Needs
    ``noise='lfsr'`` and a scan backend (the fused kernels step the LFSR
    in-kernel and cannot apply the mask).
  * **transient flips** — a seeded Bernoulli(``flip_prob``) draw flips
    each just-updated spin once per sweep (applied after its half-sweep),
    from a salted stream independent of the sampling noise.  Scan
    backends only; under a mesh the draw is addressed by *global*
    (chain, node) coordinates so the sharded engine reproduces the
    single-device flip pattern exactly under the barrier policy.

Everything here is static host data (tuples of python ints), so a
`Faults` instance hashes into the Session's closure caches and travels in
the spec's aux treedef like the other declarative fields.
"""
from __future__ import annotations

import dataclasses

import numpy as np

FLIP_SALT = 0xA5A5A5A5   # XOR'd into the counter seed for the flip stream
FLIP_FOLD = 0x0F11B0B5   # folded into the philox key for the flip stream


@dataclasses.dataclass(frozen=True)
class Faults:
    """One discrete fault realization of a virtual chip (all-static)."""

    stuck_nodes: tuple = ()        # node ids with a stuck-at-spin fault
    stuck_values: tuple = ()       # ±1 per stuck node (same length)
    dead_edges: tuple = ()         # edge-list indices: open circuit
    saturated_edges: tuple = ()    # edge-list indices: DAC stuck full-scale
    lfsr_stuck: tuple = ()         # ((cell, stuck0_mask, stuck1_mask), ...)
    flip_prob: float = 0.0         # transient flip probability per sweep
    flip_seed: int = 0             # salts the independent flip stream

    def __post_init__(self):
        if len(self.stuck_nodes) != len(self.stuck_values):
            raise ValueError(
                f"stuck_nodes ({len(self.stuck_nodes)}) and stuck_values "
                f"({len(self.stuck_values)}) must pair up one to one")
        for v in self.stuck_values:
            if v not in (-1, 1, -1.0, 1.0):
                raise ValueError(
                    f"stuck_values must be ±1 (a latched p-bit), got {v!r}")
        if len(set(self.stuck_nodes)) != len(self.stuck_nodes):
            raise ValueError("stuck_nodes contains duplicates")
        overlap = set(self.dead_edges) & set(self.saturated_edges)
        if overlap:
            raise ValueError(
                f"edges {sorted(overlap)} appear in both dead_edges and "
                f"saturated_edges; a coupler is open OR stuck, not both")
        if not (0.0 <= self.flip_prob < 1.0):
            raise ValueError(
                f"flip_prob must be in [0, 1), got {self.flip_prob}")
        for entry in self.lfsr_stuck:
            if len(entry) != 3:
                raise ValueError(
                    f"lfsr_stuck entries are (cell, stuck0, stuck1) "
                    f"triples, got {entry!r}")
            _, s0, s1 = entry
            if s0 & s1:
                raise ValueError(
                    f"lfsr_stuck masks overlap (bit stuck at 0 AND 1): "
                    f"{entry!r}")

    # -- derived ---------------------------------------------------------
    @property
    def any(self) -> bool:
        return bool(self.stuck_nodes or self.dead_edges
                    or self.saturated_edges or self.lfsr_stuck
                    or self.flip_prob > 0.0)

    @property
    def faulty_edges(self) -> tuple:
        """Edges excluded from the CD gradient (unreprogrammable DACs)."""
        return tuple(self.dead_edges) + tuple(self.saturated_edges)

    @property
    def needs_host_hooks(self) -> bool:
        """True when the fault model needs per-half-sweep host-side hooks
        (transient flips, stuck LFSR bits) the fused in-kernel engines
        cannot run — the spec then resolves to a scan backend."""
        return self.flip_prob > 0.0 or bool(self.lfsr_stuck)

    def validate_for(self, graph, noise: str) -> None:
        """Graph/noise-dependent checks (spec.validate calls this)."""
        n, e = graph.n_nodes, graph.n_edges
        for i in self.stuck_nodes:
            if not 0 <= int(i) < n:
                raise ValueError(
                    f"stuck node {i} out of range for {n} nodes")
        for q in self.faulty_edges:
            if not 0 <= int(q) < e:
                raise ValueError(
                    f"faulty edge {q} out of range for {e} edges")
        if self.lfsr_stuck and noise != "lfsr":
            raise ValueError(
                f"lfsr_stuck models stuck register bits of the per-cell "
                f"LFSRs and needs noise='lfsr', got {noise!r}")
        if self.flip_prob > 0.0 and noise == "lfsr":
            raise ValueError(
                "transient flips draw from a salted counter/philox stream "
                "independent of the sampling noise; noise='lfsr' has no "
                "such stream — use noise='counter' or 'philox'")


def sample_faults(
    seed: int,
    graph,
    *,
    stuck_rate: float = 0.0,
    dead_rate: float = 0.0,
    saturated_rate: float = 0.0,
    flip_prob: float = 0.0,
    exclude_nodes=(),
) -> Faults:
    """Draw one random fault realization at the given rates.

    ``stuck_rate`` is the per-node stuck-at probability (value ±1 uniform),
    ``dead_rate``/``saturated_rate`` the per-edge probabilities (an edge
    drawn for both comes out dead).  ``exclude_nodes`` keeps named nodes
    fault-free — yield benchmarks exclude the task's visible nodes, since
    a chip whose *visible* p-bit is latched cannot represent the target
    distribution at all (that is a dead chip, not a mitigation question).
    Deterministic in ``seed``: the same (seed, graph, rates) always names
    the same virtual chip.
    """
    rng = np.random.default_rng(seed)
    excl = set(int(i) for i in np.asarray(exclude_nodes).reshape(-1))
    nodes = [i for i in range(graph.n_nodes) if i not in excl]
    stuck = [i for i in nodes if rng.random() < stuck_rate]
    values = [int(rng.choice((-1, 1))) for _ in stuck]
    dead, sat = [], []
    for q in range(graph.n_edges):
        is_dead = rng.random() < dead_rate
        is_sat = rng.random() < saturated_rate
        if is_dead:
            dead.append(q)
        elif is_sat:
            sat.append(q)
    return Faults(
        stuck_nodes=tuple(stuck), stuck_values=tuple(values),
        dead_edges=tuple(dead), saturated_edges=tuple(sat),
        flip_prob=float(flip_prob), flip_seed=int(seed) & 0xFFFFFFFF)
