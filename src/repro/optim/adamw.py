"""AdamW + gradient clipping + LR schedules, pure JAX (no optax dependency).

Optimizer state mirrors the param pytree (same shardings apply leaf-wise),
so FSDP-sharded params get FSDP-sharded optimizer moments for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    state_bits: int = 32     # 8 => blockwise-quantized moments (1T-param
                             # models: 10 TB of f32 Adam state -> 2.6 TB,
                             # the chip's 8-bit-weight trick applied to the
                             # optimizer; see EXPERIMENTS.md §Perf/kimi)


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


QBLOCK = 256


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """Blockwise int8 quantized moment (bitsandbytes-style, deterministic).

    The logical shape is pytree *aux data* (static), not a leaf — a tuple
    field would flatten its ints into traced leaves and break sharding-spec
    derivation.
    """
    q: jax.Array        # int8 payload, padded flat (nblocks, QBLOCK)
    scale: jax.Array    # f32 per-block scale
    shape: tuple

    def tree_flatten(self):
        return (self.q, self.scale), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)


def _quantize(x: jax.Array) -> QTensor:
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % QBLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, QBLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), 1, keepdims=True) / 127.0,
                        1e-20)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return QTensor(q, scale[:, 0], shape)


def _dequantize(t: QTensor) -> jax.Array:
    flat = (t.q.astype(jnp.float32) * t.scale[:, None]).reshape(-1)
    n = 1
    for d in t.shape:
        n *= d
    return flat[:n].reshape(t.shape)


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(cfg.warmup_steps, 1))
    frac = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    decay = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * decay


def init(params: Any, state_bits: int = 32) -> OptState:
    if state_bits == 8:
        def zq(p):
            return _quantize(jnp.zeros(p.shape, jnp.float32))
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree.map(zq, params),
                        nu=jax.tree.map(zq, params))
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)))


def apply(cfg: AdamWConfig, grads: Any, state: OptState, params: Any
          ) -> tuple[Any, OptState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    quantized = cfg.state_bits == 8

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        if quantized:
            mu, nu = _dequantize(mu), _dequantize(nu)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        u = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (u + decay * p.astype(
            jnp.float32))
        if quantized:
            mu, nu = _quantize(mu), _quantize(nu)
        return new_p.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step, new_mu, new_nu), metrics
