"""``python -m repro.serve`` — demo loop for the p-bit sampling service.

Submits a small multi-tenant workload (AND-gate inference plus random
SK-style instances on a 2x2 Chimera), optionally under a JSON fault
schedule, drives the service to completion, and prints the latency
split and health report.  This is the documented entry point for the
*p-bit* service; the LM inference demo lives at `repro.launch.serve`.

Examples
--------
    python -m repro.serve --requests 8 --tenants 3
    python -m repro.serve --faultplan plan.json   # see serve/faultplan.py
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

import numpy as np


def build_requests(n_requests: int, n_tenants: int, chains: int,
                   n_sweeps: int, rng: np.random.Generator):
    from repro.core.chimera import make_chimera
    from repro.serve import SampleRequest

    g1 = make_chimera(1, 1)
    g2 = make_chimera(2, 2)
    reqs = []
    for i in range(n_requests):
        g = g1 if i % 2 == 0 else g2
        J = rng.integers(-40, 41, size=g.edges.shape[0], dtype=np.int32)
        h = rng.integers(-10, 11, size=g.n_nodes, dtype=np.int32)
        reqs.append(SampleRequest(
            tenant=f"tenant-{i % n_tenants}", graph=g, J_codes=J,
            h_codes=h, chains=chains, n_sweeps=n_sweeps))
    return reqs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="Demo loop for the resilient multi-tenant p-bit "
                    "sampling service (docs/serving.md).")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--chains", type=int, default=2,
                    help="chains per request (batched onto one launch)")
    ap.add_argument("--capacity", type=int, default=8,
                    help="chains capacity of one launch")
    ap.add_argument("--sweeps", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--faultplan", type=Path, default=None,
                    help="JSON fault schedule (serve/faultplan.py format)")
    args = ap.parse_args(argv)

    from repro.serve import (FaultInjector, FaultPlan, SamplerService,
                             ShardHealthMonitor)

    injector = None
    monitor = None
    if args.faultplan is not None:
        plan = FaultPlan.from_json(args.faultplan.read_text())
        injector = FaultInjector(plan)
        monitor = ShardHealthMonitor()
        print(f"fault schedule: {plan.to_json()}")

    svc = SamplerService(seed=args.seed, capacity_chains=args.capacity,
                         monitor=monitor, injector=injector)
    rng = np.random.default_rng(args.seed)
    tickets = [svc.submit(r) for r in build_requests(
        args.requests, args.tenants, args.chains, args.sweeps, rng)]
    svc.drain()

    print(f"{'tenant':<10} {'status':<10} {'bucket':<7} "
          f"{'queue_ms':>9} {'exec_ms':>8} {'attempts':>8}")
    for t in tickets:
        r = t.result()
        bucket = ("-" if r.bucket_shape is None
                  else f"{r.bucket_shape[0]}x{r.bucket_shape[1]}")
        print(f"{r.tenant:<10} {r.status:<10} {bucket:<7} "
              f"{r.queue_s * 1e3:>9.1f} {r.exec_s * 1e3:>8.1f} "
              f"{r.attempts:>8}")
    print(json.dumps(svc.healthz(), indent=2, sort_keys=True))
    ok = all(t.result().status == "ok" for t in tickets)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
