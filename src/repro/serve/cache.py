"""Shape-bucketed compile cache for the sampling service.

Serving many tenants means many *problems*, not many *executables*: a
13-spin full adder and a 440-spin chip instance differ only in which
couplers are programmed, so compiling a fresh `api.Session` per request
would pay seconds of XLA time for microseconds of sampling.  Two pieces
make reuse systematic:

* **Shape buckets + minor embedding.**  Every request graph is embedded
  into the smallest Chimera bucket that contains it (coordinate
  embedding: Chimera nodes are addressed by (row, col, side, k), so a
  small grid maps into a bigger one by cell coordinates — no search).
  The request's edge-list codes are scattered into the bucket's edge
  list; couplers outside the embedded region keep code 0 (disabled), so
  the off-region spins free-run without influencing the embedded
  problem.  One compiled executable per bucket serves every graph that
  fits it — the ROADMAP "runtime weight streaming" idea, realized at the
  serving layer.
* **An LRU over `SamplerSpec.fingerprint()`.**  The fingerprint is a
  pure shape-bucket key (graph bucket, resolved backend/interpret,
  partition/sync/mesh, mismatch *structure* — never drawn values): the
  programmed chip is a runtime operand of the cached Session's compiled
  closures (`api.Program` + `Session.sample_program`), so a cache entry
  needs no per-program state at all — dispatch is "scatter codes, call".
  The service holds one bucket-sized spec per fingerprint and evicts
  least-recently-used Sessions under memory pressure.  Hit/miss/
  eviction counters feed the `serving` benchmark's compile-cache row;
  its `program_swap` vs `recompile` split measures what the operand
  design buys.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict
from typing import Any, Callable, Optional

import numpy as np

from repro.core.chimera import ChimeraGraph, make_chimera

# Bucket ladder: (rows, cols) Chimera shapes, smallest first.  (7, 8) is
# the paper's 440-spin chip (one masked cell on the real die; buckets use
# the unmasked grid so any masked variant embeds).
DEFAULT_BUCKETS = ((1, 1), (2, 2), (4, 4), (7, 8))


def bucket_shape(graph: ChimeraGraph,
                 buckets=DEFAULT_BUCKETS) -> tuple[int, int]:
    """Smallest bucket (rows, cols) containing ``graph``; oversize graphs
    get a dedicated bucket of their own shape."""
    for rows, cols in buckets:
        if graph.rows <= rows and graph.cols <= cols:
            return (int(rows), int(cols))
    return (int(graph.rows), int(graph.cols))


@dataclasses.dataclass(frozen=True)
class Embedding:
    """Coordinate embedding of a request graph into a bucket graph."""

    bucket: ChimeraGraph
    node_map: np.ndarray  # (n_small,) int — small node id -> bucket node id
    edge_map: np.ndarray  # (E_small,) int — small edge id -> bucket edge id


def embed_graph(graph: ChimeraGraph, bucket: ChimeraGraph) -> Embedding:
    """Map ``graph``'s nodes/edges into ``bucket`` by (r, c, side, k).

    Requires ``graph`` to fit (rows/cols <=, same k, and none of its
    cells masked out of the bucket).  Raises ValueError naming the
    violation — the service turns that into a request rejection.
    """
    if graph.k != bucket.k:
        raise ValueError(
            f"cannot embed k={graph.k} graph into k={bucket.k} bucket")
    if graph.rows > bucket.rows or graph.cols > bucket.cols:
        raise ValueError(
            f"graph {graph.rows}x{graph.cols} does not fit bucket "
            f"{bucket.rows}x{bucket.cols}")
    lut = bucket.coord_lut()
    node_map = lut[graph.node_r, graph.node_c, graph.node_side, graph.node_k]
    if (node_map < 0).any():
        bad = np.unique(graph.node_r[node_map < 0] * 1000
                        + graph.node_c[node_map < 0])
        raise ValueError(
            f"graph uses cells masked out of the bucket: "
            f"{[(int(b) // 1000, int(b) % 1000) for b in bad]}")
    edge_lut = bucket.edge_index()
    be = node_map[np.asarray(graph.edges)]  # (E_small, 2) bucket node ids
    edge_map = np.empty(be.shape[0], np.int64)
    for e, (a, b) in enumerate(be):
        key = (int(min(a, b)), int(max(a, b)))
        if key not in edge_lut:
            raise ValueError(
                f"graph edge {e} maps to ({key}) which is not a bucket "
                f"coupler — graph is not Chimera-structured for this bucket")
        edge_map[e] = edge_lut[key]
    return Embedding(bucket=bucket, node_map=node_map, edge_map=edge_map)


def embed_program(emb: Embedding, J_codes, h_codes
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Scatter per-edge / per-node codes into bucket-sized arrays.

    Unmapped bucket couplers keep code 0 — the chip's *disabled* state —
    so spins outside the embedded region decouple from the problem.
    """
    Jb = np.zeros(emb.bucket.edges.shape[0], np.int32)
    hb = np.zeros(emb.bucket.n_nodes, np.int32)
    Jb[emb.edge_map] = np.asarray(J_codes, np.int32)
    hb[emb.node_map] = np.asarray(h_codes, np.int32)
    return Jb, hb


def make_bucket_graph(rows: int, cols: int, k: int = 4) -> ChimeraGraph:
    """The canonical (unmasked) bucket lattice for a ladder entry."""
    return make_chimera(rows, cols, k)


def program_digest(bucket_key: tuple[int, int], J_codes, h_codes,
                   betas, clamp_mask) -> str:
    """Batch-compatibility digest: requests may share one launch iff they
    program the same chip, anneal over the same betas, and clamp the same
    node set (per-chain clamp *values* are free to differ — that is the
    multiplexing axis)."""
    h = hashlib.sha1()
    h.update(repr(bucket_key).encode())
    h.update(np.ascontiguousarray(np.asarray(J_codes, np.int32)).tobytes())
    h.update(np.ascontiguousarray(np.asarray(h_codes, np.int32)).tobytes())
    h.update(np.ascontiguousarray(
        np.asarray(betas, np.float32)).tobytes())
    if clamp_mask is None:
        h.update(b"-")
    else:
        h.update(np.ascontiguousarray(
            np.asarray(clamp_mask, bool)).tobytes())
    return h.hexdigest()[:16]


@dataclasses.dataclass
class CacheEntry:
    """One compiled Session — programs stream in at call time.

    There is deliberately no per-program state here: the programmed chip
    used to live in a per-entry digest->EffectiveChip LRU, but with
    `Session.sample_program` the program is an operand of the compiled
    executable, so dispatch re-scatters the O(E) codes every launch and
    the cache's only job is holding compiled Sessions.
    """

    session: Any                 # api.Session
    spec: Any                    # api.SamplerSpec (bucket-sized)
    embeddable: ChimeraGraph     # the bucket graph
    meshed: bool                 # compiled against a device mesh?
    build_s: float               # wall-clock spent constructing + warming


class SessionCache:
    """LRU of fingerprint -> `CacheEntry` with hit/miss/eviction counters."""

    def __init__(self, capacity: int = 8):
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._entries: OrderedDict[str, CacheEntry] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, fingerprint: str) -> Optional[CacheEntry]:
        entry = self._entries.get(fingerprint)
        if entry is None:
            return None
        self._entries.move_to_end(fingerprint)
        self.hits += 1
        return entry

    def get_or_build(self, fingerprint: str,
                     build: Callable[[], CacheEntry]) -> CacheEntry:
        entry = self.get(fingerprint)
        if entry is not None:
            return entry
        self.misses += 1
        t0 = time.monotonic()
        entry = build()
        if not entry.build_s:
            entry.build_s = time.monotonic() - t0
        self._entries[fingerprint] = entry
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
        return entry

    def invalidate(self, predicate: Callable[[str, CacheEntry], bool]
                   ) -> int:
        """Drop entries matching ``predicate`` (e.g. everything compiled
        against a mesh that just lost a shard).  Returns the drop count."""
        doomed = [fp for fp, e in self._entries.items() if predicate(fp, e)]
        for fp in doomed:
            del self._entries[fp]
        return len(doomed)

    def stats(self) -> dict:
        return {"size": len(self._entries), "capacity": self.capacity,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions}
