"""Deterministic fault schedules for the serving layer.

"We handle shard loss" is not a property CI can check; "launch #3 loses
device 1, launch #1 sees two link flaps, launch #2 runs 50 ms slow — and
every admitted request still completes, bit-identical to a clean run" is.
A `FaultPlan` scripts exactly that: a list of events keyed by the
service's *launch sequence number* (deterministic — it advances once per
batched launch, never with wall time), injected by a `FaultInjector` the
`SamplerService` consults at the top of every launch attempt.

Event kinds
-----------
* ``kill_shard`` — mark a mesh device dead in the service's
  `ShardHealthMonitor`; the next health check raises `ShardLostError`
  and the service walks the degradation ladder.
* ``link_flap`` — raise `TransientError` for the next ``flaps`` launch
  attempts; `retry_step`'s jittered backoff absorbs it.
* ``straggler`` — return an extra ``delay_s`` the service sleeps before
  the launch, which the `StragglerWatchdog` then flags.

Plans serialize to/from JSON (a list of event objects) so CI jobs and
benchmarks can keep schedules as data:

    [{"step": 1, "kind": "link_flap", "flaps": 2},
     {"step": 2, "kind": "straggler", "delay_s": 0.05},
     {"step": 3, "kind": "kill_shard", "shard": 1}]
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable

from repro.runtime.fault_tolerance import TransientError

KINDS = ("kill_shard", "link_flap", "straggler")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    step: int                 # launch sequence number the event fires at
    kind: str                 # one of KINDS
    shard: int | None = None  # kill_shard: device id to kill
    flaps: int = 1            # link_flap: consecutive attempts that raise
    delay_s: float = 0.0      # straggler: injected latency in seconds

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"pick from {KINDS}")
        if self.kind == "kill_shard" and self.shard is None:
            raise ValueError("kill_shard needs shard=<device id>")
        if self.step < 0:
            raise ValueError(f"step must be >= 0, got {self.step}")
        if self.flaps < 1:
            raise ValueError(f"flaps must be >= 1, got {self.flaps}")
        if self.delay_s < 0:
            raise ValueError(f"delay_s must be >= 0, got {self.delay_s}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    events: tuple[FaultEvent, ...] = ()

    @staticmethod
    def make(events: Iterable[FaultEvent]) -> "FaultPlan":
        return FaultPlan(tuple(sorted(events, key=lambda e: e.step)))

    def events_at(self, step: int) -> tuple[FaultEvent, ...]:
        return tuple(e for e in self.events if e.step == step)

    def to_json(self) -> str:
        return json.dumps([dataclasses.asdict(e) for e in self.events],
                          indent=None)

    @staticmethod
    def from_json(text: str) -> "FaultPlan":
        raw = json.loads(text)
        if not isinstance(raw, list):
            raise ValueError("fault plan JSON must be a list of events")
        return FaultPlan.make(FaultEvent(**e) for e in raw)


class FaultInjector:
    """Drives a `FaultPlan` against a running service.

    ``on_launch(step, service)`` is called at the top of every launch
    *attempt*.  Each event fires exactly once (retries of the same launch
    re-enter ``on_launch`` with the same step, so firing is tracked per
    event, not per call) — except link flaps, which by design raise on
    the next ``flaps`` attempts and then clear, letting the retry
    succeed.  Returns the straggler delay to sleep, raises
    `TransientError` while a flap is active.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._fired: set[int] = set()   # indices into plan.events
        self._flaps_left = 0
        self.log: list[tuple[int, str]] = []

    def on_launch(self, step: int, service) -> float:
        delay = 0.0
        for idx, ev in enumerate(self.plan.events):
            if ev.step != step or idx in self._fired:
                continue
            self._fired.add(idx)
            self.log.append((step, ev.kind))
            if ev.kind == "kill_shard":
                service.monitor.mark_dead(ev.shard)
            elif ev.kind == "link_flap":
                self._flaps_left += ev.flaps
            elif ev.kind == "straggler":
                delay += ev.delay_s
        if self._flaps_left > 0:
            self._flaps_left -= 1
            raise TransientError(
                f"scheduled link flap at launch {step} "
                f"({self._flaps_left} more)")
        return delay
