"""`repro.serve` — the resilient multi-tenant p-bit sampling service.

This package is the *p-bit chip* serving layer (docs/serving.md):
admission control + deadlines, chains-axis request batching, a
shape-bucketed LRU compile cache over `api.SamplerSpec.fingerprint()`,
heartbeat-driven shard-loss degradation, and a deterministic
fault-schedule harness.  ``python -m repro.serve`` runs the demo loop.

Not to be confused with `repro.launch.serve`, the decoder-only *language
model* inference demo that predates this subsystem.
"""
from repro.serve.cache import (
    DEFAULT_BUCKETS,
    Embedding,
    SessionCache,
    bucket_shape,
    embed_graph,
    embed_program,
    make_bucket_graph,
    program_digest,
)
from repro.serve.degrade import ShardHealthMonitor, ShardLostError
from repro.serve.faultplan import FaultEvent, FaultInjector, FaultPlan
from repro.serve.service import (
    AdmissionError,
    CircuitBreaker,
    CircuitOpenError,
    RequestResult,
    SampleRequest,
    SamplerService,
    ServiceError,
    Ticket,
)

__all__ = [
    "DEFAULT_BUCKETS", "Embedding", "SessionCache", "bucket_shape",
    "embed_graph", "embed_program", "make_bucket_graph", "program_digest",
    "ShardHealthMonitor", "ShardLostError",
    "FaultEvent", "FaultInjector", "FaultPlan",
    "AdmissionError", "CircuitBreaker", "CircuitOpenError",
    "RequestResult", "SampleRequest", "SamplerService", "ServiceError",
    "Ticket",
]
