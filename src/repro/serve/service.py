"""`SamplerService` — the resilient multi-tenant p-bit sampling service.

One process, many tenants, one chip model: requests carry a (small)
Chimera problem; the service embeds each into a shape bucket
(`serve.cache`), multiplexes compatible requests onto the *chains* axis
of a single resident-sweep launch (the measured 3.3–6x `sync_policies`
latency lever — one launch anneals every tenant's chains at once), and
returns each tenant its slice of the spins.

Control plane
-------------
* **Admission** — a bounded FIFO; `submit` raises `AdmissionError` when
  the queue is full (backpressure, never silent drops) and
  `CircuitOpenError` for tenants whose breaker is open.  Every admitted
  request is eventually *resolved* — completed, or terminally failed
  with a reason — there is no path that loses a ticket.
* **Deadlines** — per-request; requests whose deadline passes while
  queued resolve as ``deadline_exceeded`` without burning a launch, and
  late completions are flagged and fed to the tenant's circuit breaker.
* **Batching** — the queue head defines the launch group: every queued
  request with the same `program_digest` (same bucket chip, betas, clamp
  *mask*; clamp *values* are per-chain and free to differ) packs into
  the launch until ``capacity_chains`` is reached, FIFO order preserved
  for the rest.
* **Determinism** — launch ``seq`` numbers the batched launches; all RNG
  derives from ``fold_in(base_key, seq)``.  An identical admission
  sequence therefore produces identical results regardless of retries,
  replays, or mesh degradation (barrier-sync sharding is bit-exact vs
  single device), which is how the fault-schedule tests can demand
  bit-identical output from a faulted 2-device run and a clean
  single-device run.

Data plane resilience (see `serve.degrade`, `serve.faultplan`)
--------------------------------------------------------------
`TransientError` (link flap) is absorbed by `retry_step` with jittered
backoff; `ShardLostError` walks the degradation ladder (re-plan the row
partition on survivors, else single-device) and *replays* the launch
from its recorded ``seq`` — in-flight requests survive shard loss.  A
`StragglerWatchdog` flags slow launches.  ``healthz()``/``readyz()``
are the probe surface.

The service is deliberately synchronous: callers drive it with
``pump()`` (one launch) or ``drain()`` (until the queue is empty), which
keeps every test deterministic.  A thread or asyncio wrapper is a
five-line loop around ``pump``.
"""
from __future__ import annotations

import dataclasses
import random as _random
import time
from collections import Counter, deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import pbit
from repro.core.chimera import ChimeraGraph
from repro.core.distributed import surviving_mesh
from repro.core.hardware import HardwareConfig, sample_mismatch_sparse
from repro.runtime.fault_tolerance import StragglerWatchdog, retry_step
from repro.serve.cache import (
    DEFAULT_BUCKETS,
    CacheEntry,
    Embedding,
    SessionCache,
    bucket_shape,
    embed_graph,
    embed_program,
    make_bucket_graph,
    program_digest,
)
from repro.serve.degrade import ShardHealthMonitor, ShardLostError


class ServiceError(RuntimeError):
    """Base class for request-rejection errors raised by `submit`."""


class AdmissionError(ServiceError):
    """Queue full — backpressure; the client should retry later."""


class CircuitOpenError(ServiceError):
    """This tenant's circuit breaker is open (repeated deadline misses)."""


@dataclasses.dataclass
class SampleRequest:
    """One tenant's problem: a Chimera graph plus edge-list programming.

    ``betas`` (an explicit (S,) float array) overrides the
    ``n_sweeps``/``beta`` pair.  ``clamp_mask`` is (N,) over the
    *request* graph; ``clamp_values`` is (chains, N) — per-chain data,
    the multiplexing axis (think: same RBM chip, each chain clamped to a
    different tenant query).
    """

    tenant: str
    graph: ChimeraGraph
    J_codes: Any
    h_codes: Any
    chains: int = 1
    n_sweeps: int = 8
    beta: float = 1.0
    betas: Any = None
    clamp_mask: Any = None
    clamp_values: Any = None
    timeout_s: Optional[float] = None


@dataclasses.dataclass
class RequestResult:
    """Terminal state of an admitted request."""

    status: str                       # ok | deadline_exceeded | failed
    tenant: str
    spins: Optional[np.ndarray]       # (chains, n_request_nodes) ±1 float32
    degraded: bool = False            # ran after a shard loss
    deadline_missed: bool = False     # completed, but past its deadline
    error: Optional[str] = None
    t_admitted: float = 0.0
    t_finished: float = 0.0
    queue_s: float = 0.0              # admission -> launch start
    exec_s: float = 0.0               # launch wall time (shared by batch)
    attempts: int = 1                 # launch attempts incl. flap retries
    launch_seq: int = -1
    chain_offset: int = -1
    bucket_shape: Optional[tuple] = None
    bucket_fingerprint: Optional[str] = None
    launch_key: Optional[np.ndarray] = None  # raw key data: full replay
                                             # recipe (tests rebuild the
                                             # launch from it)


class Ticket:
    """Handle returned by `submit`; resolved by `pump`/`drain`."""

    def __init__(self, req: SampleRequest, *, deadline: Optional[float],
                 t_admitted: float, bshape: tuple[int, int],
                 emb: Embedding, Jb: np.ndarray, hb: np.ndarray,
                 betas: np.ndarray, bucket_mask: Optional[np.ndarray],
                 digest: str):
        self.req = req
        self.deadline = deadline
        self.t_admitted = t_admitted
        self.bshape = bshape
        self.emb = emb
        self.Jb = Jb
        self.hb = hb
        self.betas = betas
        self.bucket_mask = bucket_mask
        self.digest = digest
        self._result: Optional[RequestResult] = None

    @property
    def done(self) -> bool:
        return self._result is not None

    def result(self) -> RequestResult:
        if self._result is None:
            raise ServiceError(
                "request not resolved yet — drive the service with "
                "pump() or drain()")
        return self._result

    def _resolve(self, result: RequestResult) -> None:
        self._result = result


class CircuitBreaker:
    """Per-tenant closed -> open -> half-open breaker on deadline misses.

    ``threshold`` consecutive failures open the circuit for
    ``cooldown_s``; after cooldown one probe request is admitted
    (half-open) — success closes the circuit, failure reopens it
    immediately.  Protects other tenants' latency from one tenant whose
    problems chronically blow their deadlines.
    """

    def __init__(self, threshold: int = 3, cooldown_s: float = 30.0):
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self._st: dict[str, dict] = {}

    def state(self, tenant: str, now: float) -> str:
        st = self._st.get(tenant)
        if st is None or st["open_until"] is None:
            return "closed"
        return "open" if now < st["open_until"] else "half_open"

    def allow(self, tenant: str, now: float) -> bool:
        s = self.state(tenant, now)
        if s == "open":
            return False
        if s == "half_open":
            self._st[tenant]["probing"] = True
        return True

    def record(self, tenant: str, ok: bool, now: float) -> None:
        if ok:
            self._st.pop(tenant, None)
            return
        st = self._st.setdefault(
            tenant, {"fails": 0, "open_until": None, "probing": False})
        st["fails"] += 1
        if st["probing"] or st["fails"] >= self.threshold:
            st["open_until"] = now + self.cooldown_s
            st["probing"] = False
            st["fails"] = 0

    def open_tenants(self, now: float) -> list[str]:
        return sorted(t for t in self._st
                      if self.state(t, now) == "open")


class SamplerService:
    """See module docstring.  All time sources (``clock``, ``sleep``,
    ``rng``) are injectable so the fault-schedule tests run with virtual
    time and recorded backoffs; none of them influence sampled results.
    """

    def __init__(self, *,
                 hw: Optional[HardwareConfig] = None,
                 mismatch_seed: int = 0,
                 seed: int = 0,
                 mesh: Any = None,
                 capacity_chains: int = 16,
                 max_queue: int = 64,
                 default_timeout_s: float = 60.0,
                 noise: str = "counter",
                 sync: Optional[api.Sync] = None,
                 buckets=DEFAULT_BUCKETS,
                 cache_capacity: int = 8,
                 breaker: Optional[CircuitBreaker] = None,
                 monitor: Optional[ShardHealthMonitor] = None,
                 injector: Any = None,
                 watchdog: Optional[StragglerWatchdog] = None,
                 max_retries: int = 3,
                 backoff_s: float = 0.05,
                 max_backoff_s: float = 2.0,
                 rng: Optional[_random.Random] = None,
                 clock=time.monotonic,
                 sleep=time.sleep,
                 interpret: Optional[bool] = None):
        if capacity_chains < 1:
            raise ValueError(
                f"capacity_chains must be >= 1, got {capacity_chains}")
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.hw = hw if hw is not None else HardwareConfig()
        self.mismatch_seed = mismatch_seed
        self._base_key = jax.random.PRNGKey(seed)
        self.mesh = mesh
        self.capacity_chains = capacity_chains
        self.max_queue = max_queue
        self.default_timeout_s = default_timeout_s
        self.noise = noise
        self.sync = sync
        self.buckets = tuple(tuple(b) for b in buckets)
        self.cache = SessionCache(cache_capacity)
        self.breaker = breaker if breaker is not None else CircuitBreaker()
        self.monitor = monitor
        self.injector = injector
        self.watchdog = (watchdog if watchdog is not None
                         else StragglerWatchdog(threshold=3.0))
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.max_backoff_s = max_backoff_s
        self._rng = rng
        self._clock = clock
        self._sleep = sleep
        self.interpret = interpret
        self.state = "healthy" if mesh is not None else "single"
        self.metrics: Counter = Counter()
        self._queue: deque[Ticket] = deque()
        self._dead: set[int] = set()
        self._launch_seq = 0
        self._bucket_graphs: dict[tuple, ChimeraGraph] = {}
        self._bucket_mismatch: dict[tuple, Any] = {}
        self._embeddings: dict[tuple, Embedding] = {}

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def submit(self, req: SampleRequest) -> Ticket:
        now = self._clock()
        if not self.breaker.allow(req.tenant, now):
            self.metrics["rejected_breaker"] += 1
            raise CircuitOpenError(
                f"tenant {req.tenant!r}: circuit open after repeated "
                f"deadline misses; retry after cooldown")
        if len(self._queue) >= self.max_queue:
            self.metrics["rejected_backpressure"] += 1
            raise AdmissionError(
                f"admission queue full ({self.max_queue}); apply "
                f"backpressure upstream and retry")
        if not (1 <= req.chains <= self.capacity_chains):
            raise ValueError(
                f"chains={req.chains} out of range [1, "
                f"{self.capacity_chains}] (capacity_chains)")
        bshape = bucket_shape(req.graph, self.buckets)
        emb = self._embedding(req.graph, bshape)
        J = np.asarray(req.J_codes, np.int32)
        h = np.asarray(req.h_codes, np.int32)
        if J.shape != (req.graph.edges.shape[0],):
            raise ValueError(
                f"J_codes shape {J.shape} != (E,)="
                f"({req.graph.edges.shape[0]},)")
        if h.shape != (req.graph.n_nodes,):
            raise ValueError(
                f"h_codes shape {h.shape} != (N,)=({req.graph.n_nodes},)")
        Jb, hb = embed_program(emb, J, h)
        betas = self._canon_betas(req)
        bucket_mask = None
        if req.clamp_mask is not None:
            cm = np.asarray(req.clamp_mask, bool)
            if cm.shape != (req.graph.n_nodes,):
                raise ValueError(
                    f"clamp_mask shape {cm.shape} != (N,)")
            cv = np.asarray(req.clamp_values, np.float32)
            if cv.shape != (req.chains, req.graph.n_nodes):
                raise ValueError(
                    f"clamp_values shape {cv.shape} != (chains, N)="
                    f"({req.chains}, {req.graph.n_nodes})")
            bucket_mask = np.zeros(emb.bucket.n_nodes, bool)
            bucket_mask[emb.node_map] = cm
        timeout = (req.timeout_s if req.timeout_s is not None
                   else self.default_timeout_s)
        ticket = Ticket(
            req, deadline=now + timeout, t_admitted=now, bshape=bshape,
            emb=emb, Jb=Jb, hb=hb, betas=betas, bucket_mask=bucket_mask,
            digest=program_digest(bshape, Jb, hb, betas, bucket_mask))
        self._queue.append(ticket)
        self.metrics["admitted"] += 1
        return ticket

    def _canon_betas(self, req: SampleRequest) -> np.ndarray:
        if req.betas is not None:
            betas = np.asarray(req.betas, np.float32)
            if betas.ndim != 1 or betas.shape[0] < 1:
                raise ValueError(
                    f"betas must be a 1-D (S,) array, got {betas.shape}")
            return betas
        if req.n_sweeps < 1:
            raise ValueError(f"n_sweeps must be >= 1, got {req.n_sweeps}")
        return np.full(req.n_sweeps, req.beta, np.float32)

    def _embedding(self, graph: ChimeraGraph,
                   bshape: tuple[int, int]) -> Embedding:
        sig = (int(graph.rows), int(graph.cols), int(graph.k),
               tuple(sorted(tuple(c) for c in (graph.masked_cells or ()))),
               bshape)
        emb = self._embeddings.get(sig)
        if emb is None:
            bg = self._bucket_graph(bshape)
            emb = embed_graph(graph, bg)
            self._embeddings[sig] = emb
        return emb

    # ------------------------------------------------------------------
    # bucket specs (the compile-cache key surface)
    # ------------------------------------------------------------------
    def _bucket_graph(self, bshape: tuple[int, int]) -> ChimeraGraph:
        bg = self._bucket_graphs.get(bshape)
        if bg is None:
            bg = make_bucket_graph(*bshape)
            self._bucket_graphs[bshape] = bg
        return bg

    def _mismatch_for(self, bshape: tuple[int, int], bg: ChimeraGraph):
        # one virtual chip instance per bucket (a bucket is a chip SKU):
        # derived from (mismatch_seed, bucket shape) so it is identical
        # across mesh states — degradation must not change the physics
        mm = self._bucket_mismatch.get(bshape)
        if mm is None:
            nbr_idx, _ = bg.neighbor_table()
            key = jax.random.fold_in(
                jax.random.PRNGKey(self.mismatch_seed),
                bshape[0] * 1009 + bshape[1])
            mm = sample_mismatch_sparse(key, bg.n_nodes, nbr_idx.shape[0],
                                        self.hw)
            self._bucket_mismatch[bshape] = mm
        return mm

    def bucket_spec(self, graph: ChimeraGraph) -> api.SamplerSpec:
        """The spec a request on ``graph`` compiles under *right now*
        (current mesh state) — public so tests and benchmarks can rebuild
        the exact Session a result came from."""
        return self._spec_for_bucket(bucket_shape(graph, self.buckets))

    def _spec_for_bucket(self, bshape: tuple[int, int]) -> api.SamplerSpec:
        bg = self._bucket_graph(bshape)
        mm = self._mismatch_for(bshape, bg)
        kw: dict = {}
        mesh = self.mesh
        if mesh is not None:
            n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
            # a bucket with fewer cell rows than devices cannot row-shard;
            # it runs single-device even while the service is healthy
            if n_dev <= bg.rows:
                kw = dict(mesh=mesh,
                          partition=api.Partition(rows=mesh.axis_names[0]))
                if self.sync is not None:
                    kw["sync"] = self.sync
        return api.SamplerSpec(
            graph=bg, hw=self.hw, mismatch=mm, noise=self.noise,
            backend="sparse", chains=self.capacity_chains,
            interpret=self.interpret, **kw)

    def _entry_for(self, bshape: tuple[int, int]
                   ) -> tuple[str, CacheEntry]:
        spec = self._spec_for_bucket(bshape)
        fp = api.spec_fingerprint(spec)

        def build() -> CacheEntry:
            t0 = time.monotonic()
            session = api.Session(spec)
            return CacheEntry(session=session, spec=spec,
                              embeddable=spec.graph,
                              meshed=spec.mesh is not None,
                              build_s=time.monotonic() - t0)

        return fp, self.cache.get_or_build(fp, build)

    # ------------------------------------------------------------------
    # the pump: one batched launch per call
    # ------------------------------------------------------------------
    def pump(self) -> int:
        """Form one launch group from the queue head, execute it, resolve
        its tickets.  Returns the number of requests resolved (including
        queue-expired ones)."""
        batch, expired = self._next_batch()
        if not batch:
            return expired
        self._execute(batch)
        return expired + len(batch)

    def drain(self) -> int:
        """Pump until the queue is empty; returns requests resolved."""
        total = 0
        while self._queue:
            total += self.pump()
        return total

    def _next_batch(self) -> tuple[list[Ticket], int]:
        now = self._clock()
        batch: list[Ticket] = []
        free = self.capacity_chains
        rest: deque[Ticket] = deque()
        expired = 0
        while self._queue:
            t = self._queue.popleft()
            if now > t.deadline:
                self._resolve_expired(t, now)
                expired += 1
                continue
            if not batch:
                batch.append(t)
                free -= t.req.chains
            elif (t.digest == batch[0].digest
                  and t.req.chains <= free):
                batch.append(t)
                free -= t.req.chains
            else:
                rest.append(t)
        self._queue = rest
        return batch, expired

    def _resolve_expired(self, t: Ticket, now: float) -> None:
        self.metrics["deadline_expired_queued"] += 1
        self.breaker.record(t.req.tenant, ok=False, now=now)
        t._resolve(RequestResult(
            status="deadline_exceeded", tenant=t.req.tenant, spins=None,
            error="deadline passed while queued",
            t_admitted=t.t_admitted, t_finished=now,
            queue_s=now - t.t_admitted))

    def _execute(self, batch: list[Ticket]) -> None:
        seq = self._launch_seq
        self._launch_seq += 1
        key = jax.random.fold_in(self._base_key, seq)
        t_start = self._clock()
        attempts = [0]

        def attempt():
            attempts[0] += 1
            return self._attempt(batch, seq, key)

        n_dev = 0 if self.mesh is None else int(
            np.prod([self.mesh.shape[a] for a in self.mesh.axis_names]))
        replays = 0
        while True:
            try:
                m, fp, entry = retry_step(
                    attempt, max_retries=self.max_retries,
                    backoff_s=self.backoff_s,
                    max_backoff_s=self.max_backoff_s,
                    rng=self._rng, sleep=self._sleep)
                break
            except ShardLostError as e:
                replays += 1
                self._degrade(e.dead)
                if replays > n_dev + 1:   # can't happen: ladder is finite
                    now = self._clock()
                    for t in batch:
                        t._resolve(RequestResult(
                            status="failed", tenant=t.req.tenant,
                            spins=None, error=str(e),
                            t_admitted=t.t_admitted, t_finished=now))
                    self.metrics["failed"] += len(batch)
                    return
        now = self._clock()
        exec_s = now - t_start
        self.metrics["launches"] += 1
        self.metrics["launch_attempts_total"] += attempts[0]
        if attempts[0] > 1:
            self.metrics["transient_retries"] += attempts[0] - 1
        if replays:
            self.metrics["replays"] += replays
        if self.watchdog.observe(seq, exec_s):
            self.metrics["stragglers_flagged"] += 1
        degraded = bool(self._dead)
        off = 0
        for t in batch:
            spins = np.asarray(
                m[off:off + t.req.chains][:, t.emb.node_map])
            missed = now > t.deadline
            self.breaker.record(t.req.tenant, ok=not missed, now=now)
            self.metrics["completed"] += 1
            if missed:
                self.metrics["deadline_missed_exec"] += 1
            t._resolve(RequestResult(
                status="ok", tenant=t.req.tenant, spins=spins,
                degraded=degraded, deadline_missed=missed,
                t_admitted=t.t_admitted, t_finished=now,
                queue_s=t_start - t.t_admitted, exec_s=exec_s,
                attempts=attempts[0], launch_seq=seq, chain_offset=off,
                bucket_shape=t.bshape, bucket_fingerprint=fp,
                launch_key=np.asarray(key)))
            off += t.req.chains

    def _attempt(self, batch: list[Ticket], seq: int, key):
        if self.injector is not None:
            delay = self.injector.on_launch(seq, self)  # may raise Transient
            if delay:
                self.metrics["straggler_delay_injected"] += 1
                self._sleep(delay)
        self._check_shards()
        head = batch[0]
        fp, entry = self._entry_for(head.bshape)
        bg = entry.embeddable
        km, kn = jax.random.split(key)
        m0 = pbit.random_spins(km, self.capacity_chains, bg.n_nodes)
        ns = entry.session.noise_state(kn)
        cm, cv = self._assemble_clamps(batch, bg)
        # scatter codes, call: the program (codes + clamps) is a runtime
        # operand of the bucket Session's one compiled executable — no
        # per-digest chip cache, no retrace on a new tenant problem
        prog = entry.session.make_program(
            jnp.asarray(head.Jb), jnp.asarray(head.hb),
            clamp_mask=cm, clamp_values=cv)
        m, _, _ = entry.session.sample_program(
            prog, m0, ns, jnp.asarray(head.betas))
        # materialize on the host *inside* the attempt: a shard dying
        # mid-launch surfaces here, where the replay machinery can see it
        return np.asarray(m), fp, entry

    def _assemble_clamps(self, batch: list[Ticket], bg: ChimeraGraph):
        head = batch[0]
        if head.bucket_mask is None:
            return None, None
        cv = np.zeros((self.capacity_chains, bg.n_nodes), np.float32)
        off = 0
        for t in batch:
            vals = np.asarray(t.req.clamp_values, np.float32)
            cv[off:off + t.req.chains, t.emb.node_map] = vals
            off += t.req.chains
        return jnp.asarray(head.bucket_mask), jnp.asarray(cv)

    # ------------------------------------------------------------------
    # degradation ladder
    # ------------------------------------------------------------------
    def _check_shards(self) -> None:
        if self.mesh is None or self.monitor is None:
            return
        mesh_ids = {int(d.id)
                    for d in np.asarray(self.mesh.devices).reshape(-1)}
        dead = set(self.monitor.dead_shards()) & mesh_ids
        if dead:
            raise ShardLostError(dead)

    def _degrade(self, dead) -> None:
        self._dead.update(int(d) for d in dead)
        self.metrics["shard_losses"] += len(set(dead))
        self.metrics["degradations"] += 1
        self.mesh = surviving_mesh(self.mesh, self._dead)
        self.state = "degraded" if self.mesh is not None else "single"
        # every Session compiled against the dead mesh is garbage now;
        # survivors recompile lazily on the re-planned mesh.  That
        # recompile rebuilds the whole engine closure — including the
        # fused-resident-exchange loop shape when the sync policy has
        # mid-launch exchange points — and the numpy row plan itself
        # comes from the memoized plan_row_partition cache, so a re-plan
        # onto a previously-seen shard count never recomputes it
        self.metrics["cache_invalidated"] += self.cache.invalidate(
            lambda fp, e: e.meshed)

    # ------------------------------------------------------------------
    # probes
    # ------------------------------------------------------------------
    def healthz(self) -> dict:
        now = self._clock()
        mesh_ids = ([] if self.mesh is None else
                    [int(d.id)
                     for d in np.asarray(self.mesh.devices).reshape(-1)])
        return {
            "state": self.state,
            "mesh_devices": mesh_ids,
            "dead_shards": sorted(self._dead),
            "queue_depth": len(self._queue),
            "open_breakers": self.breaker.open_tenants(now),
            "cache": self.cache.stats(),
            "stragglers": len(self.watchdog.flagged),
            "metrics": dict(self.metrics),
        }

    def readyz(self) -> bool:
        """Ready = still admitting: queue has room.  Degraded and
        single-device states stay ready — capacity shrank, correctness
        did not."""
        return len(self._queue) < self.max_queue
