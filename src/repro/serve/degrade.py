"""Shard-loss detection and the serving degradation ladder.

The sharded Session distributes Chimera cell-row bands over a device
mesh (docs/sharding.md); a production service must survive losing one of
those devices mid-stream.  Detection and policy live here, action lives
in `service.SamplerService`:

1. **healthy** — requests run on the full mesh.
2. **degraded** — `surviving_mesh` re-plans the row partition over the
   devices that still heartbeat; cached Sessions compiled against the old
   mesh are invalidated and rebuilt lazily on the smaller mesh.
3. **single** — fewer than two survivors: drop ``mesh=`` entirely and run
   the bit-exact single-device path.  Because the barrier sync policy
   makes sharded and single-device Sessions produce *identical* spins,
   degradation changes latency, never results (tests/test_serving.py
   asserts bit-identity under a scripted kill).

In-flight requests at the moment of loss are replayed: every launch's
RNG inputs derive from (service seed, launch sequence number), so the
replay on the degraded mesh reproduces exactly what the healthy launch
would have returned.
"""
from __future__ import annotations

import time
from typing import Iterable, Optional

from repro.runtime.fault_tolerance import Heartbeat


class ShardLostError(RuntimeError):
    """A device in the serving mesh stopped heartbeating (or was killed by
    the fault harness); the launch must be replayed on a re-planned mesh."""

    def __init__(self, dead: Iterable[int]):
        self.dead = frozenset(int(d) for d in dead)
        super().__init__(f"shards lost: {sorted(self.dead)}")


class ShardHealthMonitor:
    """Union of two liveness signals, one query surface.

    * ``mark_dead`` — programmatic kills: the deterministic fault harness
      (`serve.faultplan`) and, in a real deployment, the cluster
      scheduler's preemption notice.
    * heartbeat files — each shard host runs a `Heartbeat`; a missing or
      stale (or torn, see `Heartbeat.dead_hosts`) file marks that host's
      device dead after ``timeout_s``.

    `dead_shards` is consulted before every launch; the service compares
    it against the current mesh's device ids.
    """

    def __init__(self, heartbeat_dir: Optional[str] = None,
                 timeout_s: float = 10.0,
                 time_fn=time.time):
        self.heartbeat_dir = heartbeat_dir
        self.timeout_s = timeout_s
        self._time = time_fn
        self._marked: set[int] = set()

    def mark_dead(self, shard_id: int) -> None:
        self._marked.add(int(shard_id))

    def mark_alive(self, shard_id: int) -> None:
        """Scheduler gave the device back (grow path — the service picks
        it up at the next cache rebuild, not retroactively)."""
        self._marked.discard(int(shard_id))

    def dead_shards(self) -> frozenset[int]:
        dead = set(self._marked)
        if self.heartbeat_dir is not None:
            dead.update(Heartbeat.dead_hosts(
                self.heartbeat_dir, self.timeout_s, now=self._time()))
        return frozenset(dead)
