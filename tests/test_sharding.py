"""Sharding rules + multi-device correctness (subprocess with 8 devices)."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models import sharding as shd

ROOT = Path(__file__).resolve().parent.parent


def test_spec_divisibility_fallback():
    import jax
    mesh = jax.make_mesh((1,), ("model",))  # single device, axis size 1
    s = shd.spec((40, 64), ("heads", None), mesh)
    assert s == P("model", None)  # 40 % 1 == 0

    class FakeMesh:
        shape = {"data": 16, "model": 16, "pod": 2}
    s = shd.spec((40, 64), ("heads", None), FakeMesh())
    assert s == P(None, None)    # 40 % 16 != 0 -> replicate
    s = shd.spec((64, 64), ("heads", "fsdp"), FakeMesh())
    assert s == P("model", "data")
    # batch falls back to a prefix of (pod, data) when not divisible by 32
    s = shd.spec((2, 8), ("batch", None), FakeMesh())
    assert s == P("pod", None)


def test_one_mesh_axis_shards_one_dim_only():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    s = shd.spec((32768, 16, 128), ("kv_seq", "kv_heads", None), FakeMesh())
    # kv_seq takes model first; kv_heads then must replicate
    assert s == P("model", None, None)


_MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, json
    import jax.numpy as jnp
    import numpy as np
    from repro.configs.registry import get_reduced_config
    from repro.configs.base import ShapeCfg
    from repro.launch.steps import make_train_step
    from repro.launch import mesh as mesh_mod
    from repro.models.model import build_model, make_dummy_batch
    from repro.optim import adamw

    cfg = get_reduced_config("{arch}")
    shape = ShapeCfg("t", 64, 8, "train")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_dummy_batch(cfg, shape, jax.random.PRNGKey(1))
    opt = adamw.init(params)

    # single-device reference
    ref_step = make_train_step(cfg, shape,
                               mesh_mod.make_host_mesh(1, 1))
    p1, o1, m1 = ref_step.fn(params, opt, batch)

    # 2x4 sharded
    mesh = mesh_mod.make_host_mesh(2, 4)
    step = make_train_step(cfg, shape, mesh)
    params2 = model.init(jax.random.PRNGKey(0))
    opt2 = adamw.init(params2)
    p2, o2, m2 = step.fn(params2, opt2, batch)
    print(json.dumps({{
        "loss1": float(m1["loss"]), "loss2": float(m2["loss"]),
        "gn1": float(m1["grad_norm"]), "gn2": float(m2["grad_norm"]),
    }}))
""")


@pytest.mark.parametrize("arch", ["gemma2-2b", "granite-moe-1b-a400m",
                                  "rwkv6-3b"])
def test_sharded_train_step_matches_single_device(arch):
    out = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT.format(arch=arch)],
        capture_output=True, text=True, timeout=540,
        env={"PYTHONPATH": f"{ROOT}/src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(rec["loss1"] - rec["loss2"]) < 2e-2, rec
    assert abs(rec["gn1"] - rec["gn2"]) / max(rec["gn1"], 1e-9) < 0.05, rec


def test_distributed_lattice_matches_energy_scale():
    """Sharded Chimera lattice anneals to the same energy scale (4 dev)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, json
        import jax.numpy as jnp
        import numpy as np
        from repro.core.distributed import (LatticeSpec, make_lattice_anneal,
                                            make_sk_lattice,
                                            lattice_input_sharding)
        from repro.core.hardware import HardwareConfig
        spec = LatticeSpec(8, 8)
        chip = make_sk_lattice(spec, jax.random.PRNGKey(0),
                               HardwareConfig.ideal())
        betas = jnp.linspace(0.1, 2.5, 60)
        run1 = make_lattice_anneal(spec, None, n_sweeps=60, record_every=20)
        _, e1 = run1(chip, jax.random.PRNGKey(1), betas)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        run2 = make_lattice_anneal(spec, mesh, n_sweeps=60, record_every=20)
        sh = lattice_input_sharding(mesh)
        chip_sh = jax.device_put(chip, jax.tree.map(lambda _: sh, chip))
        _, e2 = run2(chip_sh, jax.random.PRNGKey(1), betas)
        e1 = np.asarray(e1); e2 = np.asarray(e2)
        print(json.dumps({"e1": float(e1[e1 != 0][-1]),
                          "e2": float(e2[e2 != 0][-1])}))
    """)
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=540,
        env={"PYTHONPATH": f"{ROOT}/src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    # both anneal to low energy; same physics, different RNG streams
    assert rec["e1"] < -450 and rec["e2"] < -450, rec
    assert abs(rec["e1"] - rec["e2"]) / abs(rec["e1"]) < 0.2, rec


def test_elastic_reshard_roundtrip(tmp_path):
    """Checkpoint written under one mesh restores under another (2x4->4x2)."""
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, json
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding
        from repro.configs.registry import get_reduced_config
        from repro.models.model import build_model
        from repro.models import sharding as shd
        from repro.checkpoint import checkpoint as ckpt
        from repro.launch import mesh as mesh_mod

        cfg = get_reduced_config("gemma2-2b")
        model = build_model(cfg)
        mesh1 = mesh_mod.make_host_mesh(2, 4)
        params = jax.jit(model.init, out_shardings=shd.param_shardings(
            jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0))),
            mesh1))(jax.random.PRNGKey(0))
        ckpt.save("{tmp_path}", 1, params)

        mesh2 = mesh_mod.make_host_mesh(4, 2)   # node-count change
        abstract = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        shardings = shd.param_shardings(abstract, mesh2)
        target = jax.tree.map(
            lambda a, s: jax.make_array_from_callback(
                a.shape, s, lambda idx: np.zeros(a.shape, a.dtype)[idx]),
            abstract, shardings)
        step, restored, _ = ckpt.load("{tmp_path}", target=target)
        ok = all(np.allclose(np.asarray(x), np.asarray(y))
                 for x, y in zip(jax.tree.leaves(params),
                                 jax.tree.leaves(restored)))
        print(json.dumps({{"ok": bool(ok), "step": step}}))
    """)
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=540,
        env={"PYTHONPATH": f"{ROOT}/src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["step"] == 1
