"""ElasticState.resume / reshard — elasticity across mesh-size changes.

The contract (docs/robustness.md, fault_tolerance.py): checkpoints store
*logical* arrays, so after a node-count change the procedure is rebuild
mesh -> recompute shardings from the same logical rules -> device_put.
Previously untested.  Covered here:

* `reshard` re-homes a pytree onto a mesh in-process (values untouched).
* checkpoint written under a forced 2-device mesh, resumed under a
  *shrunk* (1-device) and a *grown* (4-device) forced host — arrays
  bit-identical in all three worlds (subprocesses, since the device
  count is fixed at first jax init).
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.runtime.fault_tolerance import ElasticState

ROOT = Path(__file__).resolve().parent.parent
SUBPROC_ENV = {"PYTHONPATH": f"{ROOT}/src", "PATH": "/usr/bin:/bin",
               "HOME": "/root", "JAX_PLATFORMS": "cpu"}


def test_reshard_in_process():
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    tree = {"w": np.arange(8, dtype=np.float32).reshape(2, 4),
            "b": np.ones(4, np.float32)}
    specs = {"w": P("data"), "b": P()}
    out = ElasticState(ckpt_dir="unused").reshard(tree, mesh, specs)
    np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])
    np.testing.assert_array_equal(np.asarray(out["b"]), tree["b"])
    assert out["w"].sharding.mesh.shape["data"] == 1


def _world_script(n_devices: int, mode: str) -> str:
    return textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \\
            "--xla_force_host_platform_device_count={n_devices}"
        import json, sys
        import jax
        import numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.checkpoint import checkpoint as ckpt
        from repro.runtime.fault_tolerance import ElasticState

        ckpt_dir = sys.argv[1]
        assert len(jax.devices()) == {n_devices}
        mesh = Mesh(np.asarray(jax.devices()), ("data",))
        # {n_devices}-divisible leading dims so every world can shard them
        tree = {{"w": np.arange(48, dtype=np.float32).reshape(8, 6),
                 "stats": {{"m2": np.linspace(-1, 1, 16,
                                              dtype=np.float32)}}}}

        def make_specs(t):
            return {{"w": P("data"), "stats": {{"m2": P()}}}}

        if "{mode}" == "save":
            sharded = ElasticState(ckpt_dir).reshard(
                tree, mesh, make_specs(tree))
            ckpt.save(ckpt_dir, 7, sharded)
            print(json.dumps({{"saved": 7}}))
        else:
            target = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
            step, out = ElasticState(ckpt_dir).resume(
                mesh, make_specs, target)
            ok_w = bool(np.array_equal(np.asarray(out["w"]), tree["w"]))
            ok_m2 = bool(np.array_equal(np.asarray(out["stats"]["m2"]),
                                        tree["stats"]["m2"]))
            n_shards = out["w"].sharding.mesh.shape["data"]
            print(json.dumps({{"step": step, "ok_w": ok_w,
                               "ok_m2": ok_m2,
                               "n_shards": int(n_shards)}}))
    """)


def _run_world(n_devices: int, mode: str, ckpt_dir: Path) -> dict:
    out = subprocess.run(
        [sys.executable, "-c", _world_script(n_devices, mode),
         str(ckpt_dir)],
        env=SUBPROC_ENV, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("resume_devices", [1, 4],
                         ids=["shrunk-1dev", "grown-4dev"])
def test_resume_across_mesh_sizes(tmp_path, resume_devices):
    """Save on 2 devices; resume on a shrunk and a grown mesh —
    bit-identical logical arrays, resharded onto the new world."""
    assert _run_world(2, "save", tmp_path) == {"saved": 7}
    report = _run_world(resume_devices, "resume", tmp_path)
    assert report == {"step": 7, "ok_w": True, "ok_m2": True,
                      "n_shards": resume_devices}, report
