"""Flash attention (custom VJP) vs direct softmax oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

import repro.models.flash as F


def direct(q, k, v, KV, scale, softcap=None, causal=True, window=None):
    B, Sq, H, hd = q.shape
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qp, kp = jnp.arange(Sq), jnp.arange(k.shape[1])
    d = qp[:, None] - kp[None, :]
    ok = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        ok &= d >= 0
    if window:
        ok &= d < window
    s = jnp.where(ok, s, -2e38)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqs,bskh->bqkgh", p, v).reshape(B, Sq, H, hd)


@pytest.fixture(autouse=True)
def small_chunks(monkeypatch):
    monkeypatch.setattr(F, "Q_CHUNK", 32)
    monkeypatch.setattr(F, "KV_CHUNK", 16)


@pytest.mark.parametrize("softcap,window", [
    (None, None), (30.0, None), (None, 48), (50.0, 32)])
def test_flash_fwd_bwd_vs_direct(softcap, window):
    rng = np.random.default_rng(0)
    B, S, H, KV, hd = 2, 128, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    scale = 1 / np.sqrt(hd)
    kw = dict(num_kv_heads=KV, scale=scale, softcap=softcap, causal=True,
              window=window)
    o1 = F.flash_attention(q, k, v, **kw)
    o2 = direct(q, k, v, KV, scale, softcap, True, window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)

    f = lambda *a: F.flash_attention(*a, **kw).sum() * 0.01
    g = lambda *a: direct(*a, KV, scale, softcap, True, window).sum() * 0.01
    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 2), nq=st.integers(1, 4), KV=st.sampled_from([1, 2]),
       G=st.sampled_from([1, 2]), hd=st.sampled_from([8, 16]),
       causal=st.booleans())
def test_flash_property_shapes(B, nq, KV, G, hd, causal):
    S = 32 * nq
    rng = np.random.default_rng(B * nq * hd)
    q = jnp.asarray(rng.normal(size=(B, S, KV * G, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    o1 = F.flash_attention(q, k, v, num_kv_heads=KV, scale=0.25,
                           causal=causal)
    o2 = direct(q, k, v, KV, 0.25, None, causal, None)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)
