"""Chimera-native block-sparse compute path vs the dense reference.

The fixed-degree slot layout (ChimeraGraph.neighbor_table) must be
*bit-exact* against the dense path on Chimera graphs: neighbors accumulate
in ascending order, so the degree-≤6 gather reproduces the dense row
reduction term for term (zeros are additive identities), and the sparse
Pallas kernel runs the identical op sequence as the sparse jnp ref.
Covers masked graphs, clamped CD phases, per-chain (S, B) tempering betas,
and all three noise kinds (philox / counter / lfsr).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pbit, tasks
from repro.core.cd import CDConfig, PBitMachine, make_cd_step
from repro.core.chimera import make_chimera, make_chip_graph
from repro.core.hardware import (
    HardwareConfig,
    attach_sparse,
    gather_mismatch,
    ideal_chip,
    program_weights,
    program_weights_sparse,
    sample_mismatch,
)

SPARSE_BACKENDS = ("sparse", "fused_sparse")


def _graph(rows=2, cols=3, masked=((0, 1),)):
    return make_chimera(rows, cols, masked_cells=masked)


def _chip(g, seed=0, scale=0.3):
    rng = np.random.default_rng(seed)
    n = g.n_nodes
    J = np.zeros((n, n), np.float32)
    vals = rng.normal(size=g.n_edges) * scale
    J[g.edges[:, 0], g.edges[:, 1]] = vals
    J[g.edges[:, 1], g.edges[:, 0]] = vals
    h = (rng.normal(size=n) * 0.2).astype(np.float32)
    nbr_idx, _ = g.neighbor_table()
    return ideal_chip(J, h, jnp.asarray(g.adjacency()),
                      neighbors=jnp.asarray(nbr_idx))


def _noise(kind, g, batch, key):
    if kind == "lfsr":
        init, step = pbit.make_lfsr_noise(g, batch)
        return init(key), step
    if kind == "counter":
        init, step = pbit.make_counter_noise(batch, g.n_nodes)
        return init(key), step
    return key, pbit.make_philox_noise(batch, g.n_nodes)


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------
def test_neighbor_table_covers_chip_graph():
    g = make_chip_graph()
    nbr_idx, nbr_mask = g.neighbor_table()
    assert nbr_idx.shape[0] == 6  # 4 in-cell K4,4 + 2 chain couplers
    assert nbr_mask.sum() == 2 * g.n_edges  # every coupler, both directions
    # real slots list each node's neighbors ascending; padding points home
    n = g.n_nodes
    for i in (0, 17, n - 1):
        nbrs = nbr_idx[nbr_mask[:, i], i]
        assert (np.diff(nbrs) > 0).all()
        assert (nbr_idx[~nbr_mask[:, i], i] == i).all()
    # each edge is findable from both endpoints
    sij, sji = g.edge_slots(nbr_idx)
    assert (nbr_idx[sij, g.edges[:, 0]] == g.edges[:, 1]).all()
    assert (nbr_idx[sji, g.edges[:, 1]] == g.edges[:, 0]).all()


def test_attach_sparse_gathers_dense_weights():
    g = _graph()
    chip = _chip(g, seed=5)
    nbr_idx = np.asarray(chip.nbr_idx)
    W = np.asarray(chip.W)
    want = W[np.arange(g.n_nodes)[None, :], nbr_idx]
    np.testing.assert_array_equal(np.asarray(chip.nbr_w), want)


# ---------------------------------------------------------------------------
# bit-exact sampling parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["philox", "counter", "lfsr"])
@pytest.mark.parametrize("masked", [(), ((0, 1), (1, 2))])
def test_sparse_ref_matches_dense_ref(kind, masked):
    """Scan backend "sparse" == "ref", per-chain (S, B) tempering betas."""
    g = _graph(masked=masked)
    chip = _chip(g, seed=len(masked))
    B = 10
    m0 = pbit.random_spins(jax.random.PRNGKey(0), B, g.n_nodes)
    state, step = _noise(kind, g, B, jax.random.PRNGKey(1))
    rng = np.random.default_rng(7)
    betas = jnp.asarray(rng.uniform(0.2, 1.8, (9, B)), jnp.float32)
    color = jnp.asarray(g.color)
    m_d, ns_d, _ = pbit.gibbs_sample(chip, color, m0, betas, state, step,
                                     backend="ref")
    m_s, ns_s, _ = pbit.gibbs_sample(chip, color, m0, betas, state, step,
                                     backend="sparse")
    np.testing.assert_array_equal(np.asarray(m_s), np.asarray(m_d))
    np.testing.assert_array_equal(np.asarray(ns_s), np.asarray(ns_d))


@pytest.mark.parametrize("kind", ["counter", "lfsr"])
def test_fused_sparse_matches_ref(kind):
    """Sweep-resident sparse kernel == dense ref, multiple batch tiles."""
    g = _graph()
    chip = _chip(g, seed=11)
    B = 12
    m0 = pbit.random_spins(jax.random.PRNGKey(2), B, g.n_nodes)
    state, step = _noise(kind, g, B, jax.random.PRNGKey(3))
    betas = jnp.linspace(0.3, 2.0, 9)
    color = jnp.asarray(g.color)
    m_d, ns_d, _ = pbit.gibbs_sample(chip, color, m0, betas, state, step,
                                     backend="ref")
    m_f, ns_f, _ = pbit.gibbs_sample(chip, color, m0, betas, state, step,
                                     backend="fused_sparse")
    np.testing.assert_array_equal(np.asarray(m_f), np.asarray(m_d))
    np.testing.assert_array_equal(np.asarray(ns_f), np.asarray(ns_d))


@pytest.mark.parametrize("kind", ["philox", "counter", "lfsr"])
def test_sparse_clamped_stats_match(kind):
    """Clamped (CD positive phase) gibbs_stats: spins bit-exact, moments
    exact on the scan path and fp-tolerance on the fused kernel."""
    g = _graph(rows=1, cols=2, masked=())
    chip = _chip(g, seed=13)
    B, n = 8, g.n_nodes
    color = jnp.asarray(g.color)
    edges = jnp.asarray(g.edges)
    clamp_mask = jnp.zeros((n,), bool).at[jnp.array([0, 5, 9])].set(True)
    rng = np.random.default_rng(1)
    clamp_values = jnp.asarray(
        np.tile(rng.integers(0, 2, (1, n)) * 2 - 1, (B, 1)), jnp.float32)
    m0 = pbit.random_spins(jax.random.PRNGKey(4), B, n)
    state, step = _noise(kind, g, B, jax.random.PRNGKey(5))

    s_d, c_d, m_d, ns_d = pbit.gibbs_stats(
        chip, color, m0, 1.0, 24, 4, state, step, edges,
        clamp_mask=clamp_mask, clamp_values=clamp_values, backend="ref")
    s_s, c_s, m_s, ns_s = pbit.gibbs_stats(
        chip, color, m0, 1.0, 24, 4, state, step, edges,
        clamp_mask=clamp_mask, clamp_values=clamp_values, backend="sparse")
    np.testing.assert_array_equal(np.asarray(m_s), np.asarray(m_d))
    np.testing.assert_array_equal(np.asarray(s_s), np.asarray(s_d))
    np.testing.assert_array_equal(np.asarray(c_s), np.asarray(c_d))
    if kind == "philox":
        return  # the fused engines need in-kernel noise
    s_f, c_f, m_f, ns_f = pbit.gibbs_stats(
        chip, color, m0, 1.0, 24, 4, state, step, edges,
        clamp_mask=clamp_mask, clamp_values=clamp_values,
        backend="fused_sparse")
    np.testing.assert_array_equal(np.asarray(m_f), np.asarray(m_d))
    np.testing.assert_array_equal(np.asarray(ns_f), np.asarray(ns_d))
    np.testing.assert_allclose(np.asarray(s_f), np.asarray(s_d),
                               rtol=0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(c_f), np.asarray(c_d),
                               rtol=0, atol=1e-5)


def test_sparse_requires_layout():
    g = _graph(rows=1, cols=1, masked=())
    chip = ideal_chip(np.zeros((8, 8), np.float32), np.zeros(8))  # no slots
    m0 = pbit.random_spins(jax.random.PRNGKey(0), 4, 8)
    init, step = pbit.make_counter_noise(4, 8)
    with pytest.raises(ValueError, match="neighbor"):
        pbit.gibbs_sample(chip, jnp.asarray(g.color), m0, jnp.ones((2,)),
                          init(jax.random.PRNGKey(1)), step,
                          backend="sparse")


# ---------------------------------------------------------------------------
# sparse-native programming (no O(N²) anywhere)
# ---------------------------------------------------------------------------
def test_program_weights_sparse_matches_dense_gather():
    """Slot-native programming through a gathered dense mismatch is
    bit-identical to gathering the densely programmed chip."""
    g = _graph()
    n = g.n_nodes
    hw = HardwareConfig()
    mism = sample_mismatch(jax.random.PRNGKey(8), n, hw)
    nbr_idx, nbr_mask = g.neighbor_table()
    rng = np.random.default_rng(2)
    J = np.zeros((n, n), np.int32)
    vals = rng.integers(-100, 100, g.n_edges)
    J[g.edges[:, 0], g.edges[:, 1]] = vals
    J[g.edges[:, 1], g.edges[:, 0]] = vals
    h = rng.integers(-50, 50, n).astype(np.int32)
    enable = np.abs(J) > 0

    dense = program_weights(jnp.asarray(J), jnp.asarray(h),
                            jnp.asarray(enable), mism, hw,
                            adjacency=jnp.asarray(g.adjacency()),
                            neighbors=jnp.asarray(nbr_idx))
    rows = np.arange(n)[None, :]
    sparse = program_weights_sparse(
        jnp.asarray(J[rows, nbr_idx]), jnp.asarray(h),
        jnp.asarray(enable[rows, nbr_idx]), gather_mismatch(mism, nbr_idx),
        hw, jnp.asarray(nbr_idx), jnp.asarray(nbr_mask))
    assert sparse.W is None
    np.testing.assert_array_equal(np.asarray(sparse.nbr_w),
                                  np.asarray(dense.nbr_w))
    np.testing.assert_array_equal(np.asarray(sparse.h), np.asarray(dense.h))


def test_sparse_native_machine_ideal_matches_dense():
    """An ideal sparse-native machine (SparseMismatch, W never built)
    samples the exact same dynamics as the dense machine."""
    g = _graph(rows=1, cols=2, masked=())
    n = g.n_nodes
    rng = np.random.default_rng(3)
    codes_e = jnp.asarray(rng.integers(-40, 40, g.n_edges), jnp.int32)
    h_codes = jnp.asarray(rng.integers(-10, 10, n), jnp.int32)
    kw = dict(noise="counter", w_scale=0.05)
    mach_s = PBitMachine.create(g, jax.random.PRNGKey(0),
                                HardwareConfig.ideal(), sparse=True, **kw)
    mach_d = PBitMachine.create(g, jax.random.PRNGKey(0),
                                HardwareConfig.ideal(), **kw)
    assert mach_s.sparse_native and not mach_d.sparse_native
    chip_s = mach_s.program_edges(codes_e, h_codes)
    chip_d = mach_d.program_edges(codes_e, h_codes)
    assert chip_s.W is None
    B = 8
    m0 = pbit.random_spins(jax.random.PRNGKey(4), B, n)
    state, step = mach_s.noise_fn(jax.random.PRNGKey(5), B)
    betas = jnp.ones((12, B), jnp.float32)
    color = jnp.asarray(g.color)
    m_s, _, _ = pbit.gibbs_sample(chip_s, color, m0, betas, state, step,
                                  backend="fused_sparse")
    m_d, _, _ = pbit.gibbs_sample(chip_d, color, m0, betas, state, step,
                                  backend="ref")
    np.testing.assert_array_equal(np.asarray(m_s), np.asarray(m_d))


def test_sparse_machine_reproduces_dense_chip():
    """ROADMAP item closed: a sparse-native machine reproduces a *given*
    dense machine's mismatch bit-for-bit at chip scale (440 spins, real
    process-variation sigmas) — `machine.to_sparse()` gathers the dense
    draw into the slot layout; same codes => identical couplings and an
    identical spin trajectory for the same noise stream."""
    g = make_chip_graph()
    mach_d = PBitMachine.create(g, jax.random.PRNGKey(3), HardwareConfig(),
                                noise="counter", backend="ref")
    mach_s = mach_d.to_sparse()
    assert mach_s.sparse_native and mach_s.backend == "sparse"

    rng = np.random.default_rng(5)
    codes_e = jnp.asarray(rng.integers(-80, 80, g.n_edges), jnp.int32)
    h_codes = jnp.asarray(rng.integers(-30, 30, g.n_nodes), jnp.int32)
    chip_d = mach_d.program_edges(codes_e, h_codes)
    chip_s = mach_s.program_edges(codes_e, h_codes)
    assert chip_s.W is None and chip_s.nbr_w.shape == (6, 440)
    np.testing.assert_array_equal(np.asarray(chip_s.nbr_w),
                                  np.asarray(chip_d.nbr_w))
    np.testing.assert_array_equal(np.asarray(chip_s.h),
                                  np.asarray(chip_d.h))

    B, S = 4, 6
    ses_d = mach_d.session(chains=B)
    ses_s = mach_s.session(chains=B)
    m0 = ses_d.random_spins(jax.random.PRNGKey(6))
    ns = ses_d.noise_state(jax.random.PRNGKey(7))
    betas = jnp.linspace(0.4, 1.6, S)
    m_d, ns_d, _ = ses_d.sample(chip_d, m0, ns, betas)
    m_s, ns_s, _ = ses_s.sample(chip_s, m0, ns, betas)
    np.testing.assert_array_equal(np.asarray(m_s), np.asarray(m_d))
    np.testing.assert_array_equal(np.asarray(ns_s), np.asarray(ns_d))


def test_large_lattice_sparse_only_smoke():
    """16x16 Chimera (2048 spins) end-to-end on the sparse-native path —
    the layout whose dense (N, N) form would already crowd a VMEM core."""
    g = make_chimera(16, 16)
    assert g.n_nodes == 2048
    mach = PBitMachine.create(g, jax.random.PRNGKey(0),
                              HardwareConfig.ideal(), sparse=True,
                              noise="counter", backend="fused_sparse")
    rng = np.random.default_rng(4)
    codes_e = jnp.asarray(rng.integers(-30, 30, g.n_edges), jnp.int32)
    chip = mach.program_edges(codes_e, jnp.zeros((g.n_nodes,), jnp.int32))
    assert chip.W is None and chip.nbr_w.shape == (6, 2048)
    B = 4
    m0 = pbit.random_spins(jax.random.PRNGKey(1), B, g.n_nodes)
    state, step = mach.noise_fn(jax.random.PRNGKey(2), B)
    m, ns, _ = pbit.gibbs_sample(chip, jnp.asarray(g.color), m0,
                                 jnp.ones((2, B), jnp.float32), state, step,
                                 backend="fused_sparse")
    assert set(np.unique(np.asarray(m))) <= {-1.0, 1.0}


# ---------------------------------------------------------------------------
# CD: edge-list master weights
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["sparse", "fused_sparse"])
def test_cd_step_matches_dense_backend(backend):
    """The edge-list CD update is bit-identical across dense/sparse scan
    backends (same noise stream) and fp-identical on the fused kernel."""
    g = _graph(rows=1, cols=2, masked=())
    task = tasks.and_gate_task(g)
    cfg = CDConfig(lr=4.0, cd_k=6, pos_sweeps=6, burn_in=2, chains=16,
                   epochs=2)
    outs = {}
    for be in ("ref", backend):
        machine = PBitMachine.create(g, jax.random.PRNGKey(0),
                                     HardwareConfig(), noise="counter",
                                     backend=be)
        step = make_cd_step(machine, cfg, task.visible_idx)
        Jm = jnp.zeros((g.n_edges,), jnp.float32)
        hm = jnp.zeros((g.n_nodes,), jnp.float32)
        m = pbit.random_spins(jax.random.PRNGKey(1), cfg.chains, g.n_nodes)
        ns, _ = machine.noise_fn(jax.random.PRNGKey(2), cfg.chains)
        vel = (jnp.zeros((g.n_edges,)), jnp.zeros((g.n_nodes,)))
        dv = jnp.asarray(
            np.tile([[1.0, -1.0, 1.0]], (cfg.chains, 1)), jnp.float32)
        for _ in range(3):
            Jm, hm, m, ns, vel, _ = step(Jm, hm, dv, m, ns, vel)
        outs[be] = (np.asarray(Jm), np.asarray(hm), np.asarray(m))
    tol = 0.0 if backend == "sparse" else 2e-5
    np.testing.assert_allclose(outs[backend][0], outs["ref"][0],
                               rtol=0, atol=tol)
    np.testing.assert_allclose(outs[backend][1], outs["ref"][1],
                               rtol=0, atol=tol)
    np.testing.assert_array_equal(outs[backend][2], outs["ref"][2])


# ---------------------------------------------------------------------------
# streaming visible histogram
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["ref", "sparse", "fused",
                                     "fused_sparse"])
def test_streaming_hist_matches_trajectory(backend):
    """gibbs_visible_hist == histogramming the collected trajectory, for
    every backend (the fused ones accumulate in-kernel)."""
    from repro.core import energy

    g = _graph(rows=1, cols=2, masked=())
    chip = _chip(g, seed=21)
    B, sweeps, burn_in = 16, 40, 8
    vis = np.array([0, 3, 9])
    color = jnp.asarray(g.color)
    m0 = pbit.random_spins(jax.random.PRNGKey(6), B, g.n_nodes)
    state, step = _noise("counter", g, B, jax.random.PRNGKey(7))
    betas = jnp.full((sweeps,), 1.0, jnp.float32)

    hist, m_h, ns_h = pbit.gibbs_visible_hist(
        chip, color, m0, betas, burn_in, state, step, vis, backend=backend)
    _, _, traj = pbit.gibbs_sample(chip, color, m0, betas, state, step,
                                   collect=True, backend="ref")
    samples = np.asarray(traj[burn_in:]).reshape(-1, g.n_nodes)
    want = energy.empirical_visible_dist(samples, vis) * len(samples)
    np.testing.assert_array_equal(np.asarray(hist), want)
    assert float(np.asarray(hist).sum()) == (sweeps - burn_in) * B


# ---------------------------------------------------------------------------
# satellite: MaxCut float32 weight storage
# ---------------------------------------------------------------------------
def test_maxcut_weights_float32_and_cut_consistency():
    from repro.core.maxcut import random_chimera_maxcut

    g = _graph()
    prob = random_chimera_maxcut(g, jax.random.PRNGKey(0), weighted=True)
    assert prob.weights.dtype == np.float32
    assert prob.edges.dtype == np.int32
    rng = np.random.default_rng(0)
    m = rng.integers(0, 2, g.n_nodes) * 2 - 1
    # regression: float32 storage must not change the cut value — integer
    # weights are exact in float32, so f32 and f64 evaluation agree exactly
    cut64 = float(np.sum(prob.weights.astype(np.float64)
                         * (1.0 - m[prob.edges[:, 0]] * m[prob.edges[:, 1]])
                         / 2.0))
    assert prob.cut_value(m) == cut64
