"""Per-arch smoke: reduced config, one forward/train step, decode, prefill.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — see launch/dryrun.py and EXPERIMENTS.md §Dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeCfg
from repro.configs.registry import ARCH_IDS, get_config, get_reduced_config
from repro.models import transformer
from repro.models.model import build_model, make_dummy_batch

SHAPE = ShapeCfg("smoke", 64, 2, "train")


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_reduced_config(arch)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            batch = make_dummy_batch(cfg, SHAPE, jax.random.PRNGKey(1))
            cache[arch] = (cfg, model, params, batch)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grads_finite(arch, arch_state):
    cfg, model, params, batch = arch_state(arch)
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch, arch_state):
    cfg, model, params, batch = arch_state(arch)
    cache = model.init_cache(2, SHAPE.seq_len)
    logits, new_cache = jax.jit(model.decode_step)(
        params, batch["tokens"][:, :1], jnp.int32(3), cache)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).enc_dec is None])
def test_prefill_matches_forward_last_logits(arch, arch_state):
    """Integration invariant: prefill's last-token logits == forward's."""
    cfg, model, params, batch = arch_state(arch)
    logits_fwd, _ = transformer.forward(
        params, cfg, batch["tokens"], batch.get("positions"),
        batch.get("frontend_embeds"))
    logits_pre, cache = transformer.prefill(
        params, cfg, batch["tokens"], batch.get("positions"),
        batch.get("frontend_embeds"))
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, 0]), np.asarray(logits_fwd[:, -1]),
        rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["gemma2-2b", "rwkv6-3b", "jamba-v0.1-52b"])
def test_decode_continues_prefill(arch, arch_state):
    """Decode after prefill == teacher-forced forward at the next position.

    granite (top-8 of 4 reduced experts) is excluded: capacity-based MoE
    drops tokens under teacher forcing but never at single-token decode, so
    the two paths legitimately diverge (see moe.py docstring).
    """
    cfg, model, params, batch = arch_state(arch)
    toks = batch["tokens"]
    S = toks.shape[1]
    # forward over S+1 tokens gives the oracle for position S
    ext = jnp.concatenate([toks, toks[:, :1]], axis=1)
    logits_fwd, _ = transformer.forward(params, cfg, ext)
    _, pcache = transformer.prefill(params, cfg, toks)
    cache = model.init_cache(2, S + 8)

    def graft(dst, src):
        if dst.shape != src.shape:
            pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
            return jnp.pad(src.astype(dst.dtype), pad)
        return src.astype(dst.dtype)

    cache = jax.tree.map(graft, cache, pcache)
    logits_dec, _ = model.decode_step(params, toks[:, :1], jnp.int32(S),
                                      cache)
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                               np.asarray(logits_fwd[:, S]),
                               rtol=3e-2, atol=3e-2)


def test_param_counts_match_claims():
    """Sanity: derived parameter counts are in the right ballpark."""
    expect = {
        "deepseek-67b": (60e9, 75e9),
        "qwen1.5-110b": (100e9, 120e9),
        "gemma2-9b": (8e9, 11e9),
        "gemma2-2b": (2e9, 3.5e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "granite-moe-1b-a400m": (1e9, 1.6e9),
        "rwkv6-3b": (2.5e9, 4.2e9),  # 6·D² tmix approx overcounts ~15%
        "jamba-v0.1-52b": (45e9, 60e9),
        "qwen2-vl-72b": (65e9, 80e9),
        "whisper-tiny": (25e6, 80e6),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_kimi_active_params_about_32b():
    cfg = get_config("kimi-k2-1t-a32b")
    act = cfg.active_param_count()
    assert 25e9 <= act <= 40e9, act
