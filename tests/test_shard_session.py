"""Mesh-sharded Sessions: partition plan, validation, bit-exactness.

The contract (docs/sharding.md): a sharded Session reproduces the
single-device spin trajectory *exactly* for the same noise stream —
rows partitioning (ppermute halo exchange of the chain-coupler boundary
spins), chains partitioning (psum-reduced edge-list moments), and their
2-D composition — with halo traffic O(boundary), never O(N²).

Multi-device cases run in subprocesses with a forced host platform
(XLA_FLAGS device count must be set before jax initializes); both sides
of every parity check are jitted (jit-vs-eager may differ by 1 ulp).
The CI `sharded` job runs this file as its own matrix entry.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import pbit
from repro.core.cd import PBitMachine
from repro.core.chimera import make_chimera, make_chip_graph
from repro.core.distributed import halo_bytes_per_sweep, plan_row_partition
from repro.core.hardware import HardwareConfig

ROOT = Path(__file__).resolve().parent.parent
SUBPROC_ENV = {"PYTHONPATH": f"{ROOT}/src", "PATH": "/usr/bin:/bin",
               "HOME": "/root", "JAX_PLATFORMS": "cpu"}


# ---------------------------------------------------------------------------
# partition plan (pure numpy — no devices involved)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_shards", [1, 2, 3])
def test_plan_covers_chip_graph(n_shards):
    g = make_chip_graph()   # 7x8, one masked cell -> uneven bands
    p = plan_row_partition(g, n_shards, with_lfsr=True)
    # every node owned exactly once
    owned = p.part_ids[p.valid]
    assert sorted(owned.tolist()) == list(range(g.n_nodes))
    # inverse map round-trips
    flat = p.part_ids.reshape(-1)
    assert np.array_equal(flat[p.inv_ids], np.arange(g.n_nodes))
    # local neighbor tables reproduce the global one through the halo
    nbr_g, _ = g.neighbor_table()
    H, n_loc = p.halo, p.n_loc
    for d in range(n_shards):
        ext = np.full((n_loc + 2 * H,), -1, np.int64)
        ext[:n_loc] = p.part_ids[d]
        if d > 0:
            ext[n_loc:n_loc + H] = p.part_ids[d - 1][p.send_dn[d - 1]]
        if d < n_shards - 1:
            ext[n_loc + H:] = p.part_ids[d + 1][p.send_up[d + 1]]
        got = ext[p.nbr_idx[d][:, p.valid[d]]]
        np.testing.assert_array_equal(got, nbr_g[:, p.part_ids[d][p.valid[d]]])
    # each edge accounted exactly once
    assert np.unique(p.edge_inv).size == g.n_edges
    # boundary is O(cols * k), not O(N): verticals of internal cut rows
    # (the masked cell sits in row 6, never on a cut for these shardings)
    assert p.n_boundary == 2 * (n_shards - 1) * 4 * g.cols


def test_halo_bytes_model_is_o_boundary():
    g = make_chimera(16, 16)      # 2048 spins
    p = plan_row_partition(g, 4)
    B = 64
    halo = halo_bytes_per_sweep(p, B)
    dense_w = 4 * g.n_nodes ** 2
    assert halo == 2 * p.n_boundary * B * 4
    # O(√N·B) halo vs the O(N²) a dense-W exchange would move
    assert halo * 10 < dense_w


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------
def _spec(g, mesh=None, partition=None, chains=8, **kw):
    kw.setdefault("noise", "counter")
    kw.setdefault("backend", "sparse")
    mach = PBitMachine.create(g, jax.random.PRNGKey(0), HardwareConfig(),
                              **kw)
    return mach.sampler_spec(chains=chains, mesh=mesh, partition=partition)


def test_partition_validation_errors():
    g = make_chimera(2, 2)
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="mesh=None"):
        _spec(g, partition=api.Partition()).validate()
    with pytest.raises(ValueError, match="not in mesh axes"):
        _spec(g, mesh=mesh, partition=api.Partition(rows="rows")).validate()
    with pytest.raises(ValueError, match="counter"):
        _spec(g, mesh=mesh, noise="philox").validate()
    # fused_sparse under the default per-half-sweep barrier is legal now
    # that the kernel owns the halo refresh (PR 10); the infeasible
    # window S < halo_every < 2S still raises, naming the nearest fix
    _spec(g, mesh=mesh, backend="fused_sparse").validate()
    with pytest.raises(ValueError, match="nearest legal Sync"):
        _spec(g, mesh=mesh, backend="fused_sparse").replace(
            sync=api.Sync(halo_every=6, sweeps_per_launch=4)).validate()
    with pytest.raises(ValueError, match="disjoint"):
        _spec(g, mesh=mesh,
              partition=api.Partition(rows="data",
                                      chains="data")).validate()
    with pytest.raises(ValueError, match="shards nothing"):
        _spec(g, mesh=mesh,
              partition=api.Partition(rows=None, chains=None)).validate()
    class FakeMesh:
        axis_names = ("data",)
        shape = {"data": 2}
    with pytest.raises(ValueError, match="not divisible"):
        _spec(g, mesh=FakeMesh(),
              partition=api.Partition(rows=None, chains="data"),
              chains=7).validate()
    # a sharded spec resolves to the sparse scan path, env var or not
    assert api.resolve_backend(
        _spec(g, mesh=mesh, backend="auto")) == "sparse"


def test_too_many_row_shards_raises():
    g = make_chimera(2, 2)

    class FakeMesh:
        axis_names = ("data",)
        shape = {"data": 3}
    with pytest.raises(ValueError, match="cell rows"):
        _spec(g, mesh=FakeMesh()).validate()


# ---------------------------------------------------------------------------
# single-device mesh: the whole engine machinery, bit-exact vs plain
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("noise", ["counter", "lfsr"])
def test_one_shard_engine_bit_exact(noise):
    g = make_chimera(3, 2, masked_cells=((1, 1),))
    mesh = jax.make_mesh((1,), ("data",))
    mach = PBitMachine.create(g, jax.random.PRNGKey(0), HardwareConfig(),
                              noise=noise, backend="sparse")
    rng = np.random.default_rng(1)
    codes = jnp.asarray(rng.integers(-50, 50, g.n_edges), jnp.int32)
    h = jnp.asarray(rng.integers(-10, 10, g.n_nodes), jnp.int32)
    B, S = 8, 6
    ses0 = api.Session(mach.sampler_spec(chains=B))
    ses1 = api.Session(mach.sampler_spec(
        chains=B, mesh=mesh, partition=api.Partition(rows="data")))
    assert ses1.backend == "sparse" and ses1._engine is not None
    chip = ses0.program_edges(codes, h)
    m0 = ses0.random_spins(jax.random.PRNGKey(2))
    ns = ses0.noise_state(jax.random.PRNGKey(3))
    betas = jnp.linspace(0.3, 1.5, S)
    a = ses0.sample(chip, m0, ns, betas, collect=True)
    b = ses1.sample(chip, m0, ns, betas, collect=True)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(ses0.stats(chip, m0, ns, 10, 2),
                    ses1.stats(chip, m0, ns, 10, 2)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    vis = np.array([0, 3, 9])
    ha = ses0.visible_hist(chip, m0, ns, vis, 2, betas)
    hb = ses1.visible_hist(chip, m0, ns, vis, 2, betas)
    np.testing.assert_array_equal(np.asarray(ha[0]), np.asarray(hb[0]))


# ---------------------------------------------------------------------------
# forced multi-device host platform (subprocess)
# ---------------------------------------------------------------------------
def _run_forced(script: str, n_dev: int, timeout: int = 540) -> dict:
    head = (f"import os\nos.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={n_dev}'\n")
    out = subprocess.run(
        [sys.executable, "-c", head + textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout, env=SUBPROC_ENV,
        cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


_COMMON = """
    import jax, json
    import jax.numpy as jnp
    import numpy as np
    from repro import api
    from repro.core.cd import PBitMachine
    from repro.core.chimera import make_chimera, make_chip_graph
    from repro.core.hardware import HardwareConfig

    def chip_for(mach, ses, g, seed):
        rng = np.random.default_rng(seed)
        return ses.program_edges(
            jnp.asarray(rng.integers(-60, 60, g.n_edges), jnp.int32),
            jnp.asarray(rng.integers(-15, 15, g.n_nodes), jnp.int32))
"""


def test_two_device_rows_bit_exact():
    """Chip graph (440 spins, masked cell) + a masked non-square grid:
    2-device rows sharding == single device, spins/moments/hist, both
    noise kinds, including collect trajectories and clamped stats."""
    rec = _run_forced(_COMMON + """
    mesh = jax.make_mesh((2,), ("data",))
    checks = 0
    for g in (make_chip_graph(),
              make_chimera(3, 2, masked_cells=((0, 1), (2, 0)))):
        for noise in ("counter", "lfsr"):
            mach = PBitMachine.create(g, jax.random.PRNGKey(0),
                                      HardwareConfig(), noise=noise,
                                      backend="sparse")
            B, S = 4, 5
            ses0 = api.Session(mach.sampler_spec(chains=B))
            ses1 = api.Session(mach.sampler_spec(
                chains=B, mesh=mesh, partition=api.Partition(rows="data")))
            chip = chip_for(mach, ses0, g, 1)
            m0 = ses0.random_spins(jax.random.PRNGKey(2))
            ns = ses0.noise_state(jax.random.PRNGKey(3))
            betas = jnp.linspace(0.3, 1.5, S)
            a = ses0.sample(chip, m0, ns, betas, collect=True)
            b = ses1.sample(chip, m0, ns, betas, collect=True)
            for x, y in zip(a, b):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
            cm = jnp.zeros((g.n_nodes,), bool).at[
                jnp.array([0, 5, g.n_nodes - 1])].set(True)
            cv = jnp.tile(jnp.asarray([[1.0]]), (B, g.n_nodes))
            for x, y in zip(
                    ses0.stats(chip, m0, ns, 8, 2, clamp_mask=cm,
                               clamp_values=cv),
                    ses1.stats(chip, m0, ns, 8, 2, clamp_mask=cm,
                               clamp_values=cv)):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
            # clamp_mask without clamp_values (exclusion-only clamping)
            for x, y in zip(ses0.stats(chip, m0, ns, 8, 2, clamp_mask=cm),
                            ses1.stats(chip, m0, ns, 8, 2, clamp_mask=cm)):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
            vis = np.array([0, 3, 9, 11])
            ha = ses0.visible_hist(chip, m0, ns, vis, 2, betas)
            hb = ses1.visible_hist(chip, m0, ns, vis, 2, betas)
            np.testing.assert_array_equal(np.asarray(ha[0]),
                                          np.asarray(hb[0]))
            checks += 1
    print(json.dumps({"checks": checks}))
    """, n_dev=2)
    assert rec["checks"] == 4


def test_two_device_chains_cd_bit_exact():
    """Chains-sharded CD: per-device Gibbs phases + one (E,) gradient
    psum per phase reproduce the single-device weight trajectory exactly
    (power-of-two chains)."""
    rec = _run_forced(_COMMON + """
    from repro.core import tasks
    from repro.core.cd import CDConfig
    mesh = jax.make_mesh((2,), ("data",))
    g = make_chimera(2, 2)
    results = {}
    for noise in ("counter", "lfsr"):
        mach = PBitMachine.create(g, jax.random.PRNGKey(0),
                                  HardwareConfig(), noise=noise,
                                  backend="sparse")
        B = 16
        ses0 = api.Session(mach.sampler_spec(chains=B))
        ses1 = api.Session(mach.sampler_spec(
            chains=B, mesh=mesh,
            partition=api.Partition(rows=None, chains="data")))
        task = tasks.and_gate_task(g)
        cfg = CDConfig(lr=4.0, cd_k=5, pos_sweeps=5, burn_in=1, chains=B,
                       epochs=2)
        outs = {}
        for name, ses in (("single", ses0), ("sharded", ses1)):
            step = ses.make_cd_step(cfg, task.visible_idx)
            Jm = jnp.zeros((g.n_edges,), jnp.float32)
            hm = jnp.zeros((g.n_nodes,), jnp.float32)
            m = ses.random_spins(jax.random.PRNGKey(1))
            ns = ses.noise_state(jax.random.PRNGKey(2))
            vel = (jnp.zeros((g.n_edges,)), jnp.zeros((g.n_nodes,)))
            dv = jnp.asarray(np.tile([[1.0, -1.0, 1.0]], (B, 1)),
                             jnp.float32)
            for _ in range(3):
                Jm, hm, m, ns, vel, _ = step(Jm, hm, dv, m, ns, vel)
            outs[name] = [np.asarray(x) for x in (Jm, hm, m)]
        for x, y in zip(outs["single"], outs["sharded"]):
            np.testing.assert_array_equal(x, y)
        # (S, B) tempered betas chains-sharded through sample AND
        # visible_hist (per-chain beta columns must shard with the
        # chains), plus exclusion-only clamping (clamp_mask, no values)
        rng = np.random.default_rng(7)
        betas = jnp.asarray(rng.uniform(0.2, 1.8, (6, B)), jnp.float32)
        chip = chip_for(mach, ses0, g, 4)
        m0 = ses0.random_spins(jax.random.PRNGKey(3))
        ns0 = ses0.noise_state(jax.random.PRNGKey(4))
        a = ses0.sample(chip, m0, ns0, betas)
        b = ses1.sample(chip, m0, ns0, betas)
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        vis = np.array([0, 3, 9])
        ha = ses0.visible_hist(chip, m0, ns0, vis, 2, betas)
        hb = ses1.visible_hist(chip, m0, ns0, vis, 2, betas)
        np.testing.assert_array_equal(np.asarray(ha[0]), np.asarray(hb[0]))
        cmask = jnp.zeros((g.n_nodes,), bool).at[
            jnp.array([0, 5])].set(True)
        for x, y in zip(ses0.stats(chip, m0, ns0, 8, 2, clamp_mask=cmask),
                        ses1.stats(chip, m0, ns0, 8, 2,
                                   clamp_mask=cmask)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        results[noise] = True
    print(json.dumps(results))
    """, n_dev=2)
    assert rec == {"counter": True, "lfsr": True}


def test_four_device_2d_rows_x_chains():
    """2x2 mesh: rows AND chains sharded at once, stats bit-exact."""
    rec = _run_forced(_COMMON + """
    mesh = jax.make_mesh((2, 2), ("r", "c"))
    g = make_chimera(4, 2, masked_cells=((3, 1),))
    mach = PBitMachine.create(g, jax.random.PRNGKey(0), HardwareConfig(),
                              noise="counter", backend="sparse")
    B = 8
    ses0 = api.Session(mach.sampler_spec(chains=B))
    ses1 = api.Session(mach.sampler_spec(
        chains=B, mesh=mesh, partition=api.Partition(rows="r", chains="c")))
    chip = chip_for(mach, ses0, g, 2)
    m0 = ses0.random_spins(jax.random.PRNGKey(5))
    ns = ses0.noise_state(jax.random.PRNGKey(6))
    for x, y in zip(ses0.stats(chip, m0, ns, 8, 2),
                    ses1.stats(chip, m0, ns, 8, 2)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    betas = jnp.linspace(0.4, 1.4, 6)
    a = ses0.sample(chip, m0, ns, betas)
    b = ses1.sample(chip, m0, ns, betas)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    print(json.dumps({"ok": True}))
    """, n_dev=4)
    assert rec["ok"]


def test_lattice_anneal_sharded_matches_single():
    """make_lattice_anneal through the shared engine: the sharded run is
    bit-identical to the single-device run (same key => same counter
    stream), not merely the same energy scale."""
    rec = _run_forced("""
    import jax, json
    import jax.numpy as jnp
    import numpy as np
    from repro.core.distributed import (LatticeSpec, make_lattice_anneal,
                                        make_sk_lattice)
    from repro.core.hardware import HardwareConfig
    spec = LatticeSpec(4, 4, chains=2)
    chip = make_sk_lattice(spec, jax.random.PRNGKey(0),
                           HardwareConfig.ideal())
    betas = jnp.linspace(0.1, 2.0, 20)
    run1 = make_lattice_anneal(spec, None, n_sweeps=20, record_every=10)
    m1, e1 = run1(chip, jax.random.PRNGKey(1), betas)
    mesh = jax.make_mesh((2,), ("data",))
    run2 = make_lattice_anneal(spec, mesh, n_sweeps=20, record_every=10)
    m2, e2 = run2(chip, jax.random.PRNGKey(1), betas)
    ok_m = bool(np.array_equal(np.asarray(m1), np.asarray(m2)))
    ok_e = bool(np.array_equal(np.asarray(e1), np.asarray(e2)))
    print(json.dumps({"m": ok_m, "e": ok_e,
                      "e_last": float(np.asarray(e2)[-1])}))
    """, n_dev=2)
    assert rec["m"] and rec["e"]
    assert rec["e_last"] < 0
