"""SoA lattice -> shared slot-layout engine: converter + anneal parity.

The old private SoA update loop is retired (PR: mesh-sharded sparse
lattice); `lattice_to_chip` converts the structure-of-arrays couplings to
the shared `EffectiveChip` slot layout and the lattice anneal runs the
same engine as every other workload.  These tests pin the conversion
against an explicit dense reconstruction of the directional W — sampling
through the converted chip must match the dense reference bit for bit.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pbit
from repro.core.chimera import make_chimera
from repro.core.distributed import (
    LatticeChip,
    LatticeSpec,
    lattice_to_chip,
    make_lattice_anneal,
    make_sk_lattice,
    sparse_energy,
)
from repro.core.hardware import EffectiveChip, HardwareConfig


def _dense_from_lattice(spec: LatticeSpec, chip: LatticeChip):
    """Dense directional W (N, N) + h from the SoA lattice arrays."""
    R, C, k = spec.cell_rows, spec.cell_cols, spec.k
    N = R * C * 2 * k

    def nid(r, c, s, i):
        return (((r * C) + c) * 2 + s) * k + i

    W = np.zeros((N, N), np.float32)
    h = np.zeros((N,), np.float32)
    cv = np.asarray
    for r in range(R):
        for c in range(C):
            for i in range(k):
                h[nid(r, c, 0, i)] = cv(chip.h_v)[r, c, i]
                h[nid(r, c, 1, i)] = cv(chip.h_h)[r, c, i]
                for j in range(k):
                    # current INTO vertical i from horizontal j
                    W[nid(r, c, 0, i), nid(r, c, 1, j)] = \
                        cv(chip.W_vh)[r, c, i, j]
                    W[nid(r, c, 1, i), nid(r, c, 0, j)] = \
                        cv(chip.W_hv)[r, c, i, j]
                if r + 1 < R:
                    W[nid(r + 1, c, 0, i), nid(r, c, 0, i)] = \
                        cv(chip.Wv_dn)[r, c, i]
                    W[nid(r, c, 0, i), nid(r + 1, c, 0, i)] = \
                        cv(chip.Wv_up)[r, c, i]
                if c + 1 < C:
                    W[nid(r, c + 1, 1, i), nid(r, c, 1, i)] = \
                        cv(chip.Wh_rt)[r, c, i]
                    W[nid(r, c, 1, i), nid(r, c + 1, 1, i)] = \
                        cv(chip.Wh_lt)[r, c, i]
    return W, h


def _dense_chip(spec, lat):
    """Dense EffectiveChip with the same gains/offsets as the converter."""
    W, h = _dense_from_lattice(spec, lat)
    gain = np.stack([np.asarray(lat.gain_v), np.asarray(lat.gain_h)],
                    axis=2).reshape(-1)
    off = np.stack([np.asarray(lat.off_v), np.asarray(lat.off_h)],
                   axis=2).reshape(-1)
    N = spec.n_spins
    ones = jnp.ones((N,), jnp.float32)
    return EffectiveChip(
        W=jnp.asarray(W), h=jnp.asarray(h), tanh_gain=jnp.asarray(gain),
        tanh_offset=jnp.asarray(off), rand_gain=ones,
        comp_offset=0.0 * ones)


def test_lattice_to_chip_matches_dense_reference():
    """Converted slot weights == a gather of the dense directional W, and
    sampling through the converted chip is bit-exact vs the dense ref."""
    spec = LatticeSpec(3, 2, chains=2)
    lat = make_sk_lattice(spec, jax.random.PRNGKey(0), HardwareConfig())
    g = make_chimera(spec.cell_rows, spec.cell_cols, spec.k)
    chip_s = lattice_to_chip(spec, lat, g)
    chip_d = _dense_chip(spec, lat)

    nbr_idx = np.asarray(chip_s.nbr_idx)
    rows = np.arange(g.n_nodes)[None, :]
    np.testing.assert_array_equal(np.asarray(chip_s.nbr_w),
                                  np.asarray(chip_d.W)[rows, nbr_idx])
    np.testing.assert_array_equal(np.asarray(chip_s.h),
                                  np.asarray(chip_d.h))
    np.testing.assert_array_equal(np.asarray(chip_s.tanh_gain),
                                  np.asarray(chip_d.tanh_gain))

    # full Gibbs parity: sparse slot path on the converted chip vs the
    # dense ref path on the reconstruction, same noise stream
    B = 4
    m0 = pbit.random_spins(jax.random.PRNGKey(1), B, g.n_nodes)
    init, step = pbit.make_counter_noise(B, g.n_nodes)
    state = init(jax.random.PRNGKey(2))
    betas = jnp.linspace(0.3, 1.2, 7)
    color = jnp.asarray(g.color)
    m_s, _, _ = pbit.gibbs_sample(chip_s, color, m0, betas, state, step,
                                  backend="sparse")
    m_d, _, _ = pbit.gibbs_sample(chip_d, color, m0, betas, state, step,
                                  backend="ref")
    np.testing.assert_array_equal(np.asarray(m_s), np.asarray(m_d))

    # energy parity vs the explicit dense quadratic form
    W_sym = 0.5 * (np.asarray(chip_d.W) + np.asarray(chip_d.W).T)
    m_np = np.asarray(m_s)
    e_dense = (-0.5 * np.einsum("bi,ij,bj->b", m_np, W_sym, m_np)
               - m_np @ np.asarray(chip_d.h))
    np.testing.assert_allclose(np.asarray(sparse_energy(chip_s, m_s)),
                               e_dense, rtol=1e-5)


def test_chain_batched_anneal_energy_decreases():
    spec = LatticeSpec(6, 6, chains=8)
    chip = make_sk_lattice(spec, jax.random.PRNGKey(0),
                           HardwareConfig.ideal())
    run = make_lattice_anneal(spec, None, n_sweeps=80, record_every=20)
    m, e = run(chip, jax.random.PRNGKey(1), jnp.linspace(0.05, 2.5, 80))
    e = np.asarray(e)
    assert m.shape == (spec.chains, spec.n_spins)
    assert e[-1] < 0 and e[-1] < 0.8 * e[0]
