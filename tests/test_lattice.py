"""Distributed lattice physics vs the dense p-bit reference."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import (
    LatticeChip,
    LatticeSpec,
    LatticeState,
    lattice_energy,
    lattice_half_sweep,
    make_lattice_anneal,
    make_sk_lattice,
)
from repro.core.hardware import HardwareConfig


def _dense_from_lattice(spec: LatticeSpec, chip: LatticeChip):
    """Dense directional W (N, N) + h from the SoA lattice arrays."""
    R, C, k = spec.cell_rows, spec.cell_cols, spec.k
    N = R * C * 2 * k

    def nid(r, c, s, i):
        return (((r * C) + c) * 2 + s) * k + i

    W = np.zeros((N, N), np.float32)
    h = np.zeros((N,), np.float32)
    cv = np.asarray
    for r in range(R):
        for c in range(C):
            for i in range(k):
                h[nid(r, c, 0, i)] = cv(chip.h_v)[r, c, i]
                h[nid(r, c, 1, i)] = cv(chip.h_h)[r, c, i]
                for j in range(k):
                    # current INTO vertical i from horizontal j
                    W[nid(r, c, 0, i), nid(r, c, 1, j)] = \
                        cv(chip.W_vh)[r, c, i, j]
                    W[nid(r, c, 1, i), nid(r, c, 0, j)] = \
                        cv(chip.W_hv)[r, c, i, j]
                if r + 1 < R:
                    W[nid(r + 1, c, 0, i), nid(r, c, 0, i)] = \
                        cv(chip.Wv_dn)[r, c, i]
                    W[nid(r, c, 0, i), nid(r + 1, c, 0, i)] = \
                        cv(chip.Wv_up)[r, c, i]
                if c + 1 < C:
                    W[nid(r, c + 1, 1, i), nid(r, c, 1, i)] = \
                        cv(chip.Wh_rt)[r, c, i]
                    W[nid(r, c, 1, i), nid(r, c + 1, 1, i)] = \
                        cv(chip.Wh_lt)[r, c, i]
    return W, h


def _pack(spec, m_dense):
    """(B, N) dense spins -> LatticeState (B, R, C, k) x2."""
    R, C, k = spec.cell_rows, spec.cell_cols, spec.k
    B = m_dense.shape[0]
    m = m_dense.reshape(B, R, C, 2, k)
    return LatticeState(jnp.asarray(m[:, :, :, 0]),
                        jnp.asarray(m[:, :, :, 1]))


def test_lattice_half_sweep_matches_dense_reference():
    spec = LatticeSpec(3, 2, chains=2)
    chip = make_sk_lattice(spec, jax.random.PRNGKey(0), HardwareConfig())
    W, h = _dense_from_lattice(spec, chip)
    N = spec.n_spins
    rng = np.random.default_rng(1)
    m0 = (rng.integers(0, 2, (2, N)) * 2 - 1).astype(np.float32)
    u = rng.uniform(-1, 1, (2, N)).astype(np.float32)

    R, C, k = spec.cell_rows, spec.cell_cols, spec.k
    parity = (np.add.outer(np.arange(R), np.arange(C)) % 2)
    state = _pack(spec, m0)
    u_l = _pack(spec, u)
    beta = jnp.float32(0.8)

    for color in (0, 1):
        state = lattice_half_sweep(
            state, chip, color, beta, u_l.m_v, u_l.m_h,
            jnp.asarray(parity), None, 1, None, 1)
        # dense reference: update vertical of parity==color cells and
        # horizontal of parity==(1-color), with per-node gains/offsets
        I = m0 @ W.T + h
        gain = np.concatenate(
            [np.stack([np.asarray(chip.gain_v), np.asarray(chip.gain_h)],
                      axis=2)]).reshape(-1)
        off = np.stack([np.asarray(chip.off_v), np.asarray(chip.off_h)],
                       axis=2).reshape(-1)
        act = np.tanh(0.8 * gain * (I + off))
        new = np.where(act + u >= 0, 1.0, -1.0)
        node_par = (np.add.outer(np.arange(R), np.arange(C)) % 2)
        upd = np.zeros((R, C, 2, k), bool)
        upd[:, :, 0][node_par == color] = True
        upd[:, :, 1][node_par == (1 - color)] = True
        m0 = np.where(upd.reshape(-1), new, m0)

    got = np.stack([np.asarray(state.m_v), np.asarray(state.m_h)],
                   axis=3).reshape(2, -1)
    np.testing.assert_array_equal(got, m0)


def test_chain_batched_anneal_energy_decreases():
    spec = LatticeSpec(6, 6, chains=8)
    chip = make_sk_lattice(spec, jax.random.PRNGKey(0),
                           HardwareConfig.ideal())
    run = make_lattice_anneal(spec, None, n_sweeps=80, record_every=20)
    _, e = run(chip, jax.random.PRNGKey(1), jnp.linspace(0.05, 2.5, 80))
    e = np.asarray(e)
    e = e[e != 0]
    assert e[-1] < e[0] < 0 or e[-1] < 0
    assert e[-1] < 0.8 * e[0]
