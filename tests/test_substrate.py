"""Data pipeline, optimizer, checkpoint, compression, fault tolerance."""
import json
import shutil
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.checkpoint import checkpoint as ckpt
from repro.data.pipeline import DataConfig, SyntheticLM, make_source
from repro.optim import adamw
from repro.runtime import compression as comp
from repro.runtime.fault_tolerance import (
    Heartbeat,
    StragglerWatchdog,
    TransientError,
    retry_step,
)


# ---------------------------------------------------------------- data
def test_data_deterministic_and_host_sharded():
    src = SyntheticLM(DataConfig(seed=3, vocab_size=101))
    b1 = src.batch(step=7, batch=8, seq=16)
    b2 = src.batch(step=7, batch=8, seq=16)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # host slices partition the global batch deterministically
    h0 = src.batch(step=7, batch=8, seq=16, host_id=0, n_hosts=2)
    h1 = src.batch(step=7, batch=8, seq=16, host_id=1, n_hosts=2)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(h0["tokens"]),
                              np.asarray(h1["tokens"]))


def test_data_labels_are_shifted_tokens():
    src = SyntheticLM(DataConfig(seed=0, vocab_size=64))
    b = src.batch(0, 4, 32)
    assert b["tokens"].shape == b["labels"].shape == (4, 32)
    assert int(b["tokens"].max()) < 64


def test_data_has_learnable_structure():
    """Bigram following rate is induced (loss can go below unigram)."""
    src = SyntheticLM(DataConfig(seed=0, vocab_size=64))
    b = src.batch(0, 64, 64)
    toks = np.asarray(b["tokens"])
    nxt = src._perm[toks[:, :-1] % 64]
    follow = (toks[:, 1:] == nxt).mean()
    assert follow > 0.3


# ---------------------------------------------------------------- optim
def test_adamw_optimizes_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                            total_steps=100)
    params = {"w": jnp.ones((4,)) * 5.0}
    state = adamw.init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.apply(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_grad_clip_applies():
    cfg = adamw.AdamWConfig(lr=1e-3, grad_clip=1.0)
    params = {"w": jnp.zeros((3,))}
    state = adamw.init(params)
    _, _, m = adamw.apply(cfg, {"w": jnp.full((3,), 1e6)}, state, params)
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.int32(s))) for s in
           [0, 5, 10, 50, 100]]
    assert lrs[1] == pytest.approx(0.5, abs=0.01)
    assert lrs[2] == pytest.approx(1.0, abs=0.05)
    assert lrs[-1] == pytest.approx(0.1, abs=0.02)


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    ckpt.save(tmp_path, 12, tree, extra={"note": "x"})
    step, restored, extra = ckpt.load(tmp_path, target=tree)
    assert step == 12 and extra == {"note": "x"}
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_crash_consistency(tmp_path):
    """A partially-written (uncommitted) checkpoint is never loaded."""
    tree = {"w": jnp.ones((2,))}
    ckpt.save(tmp_path, 1, tree)
    # simulate a crash mid-write of step 2: directory without marker
    broken = tmp_path / "step_000000002"
    broken.mkdir()
    (broken / "meta.json").write_text("{}")
    assert ckpt.latest_step(tmp_path) == 1
    step, _, _ = ckpt.load(tmp_path, target=tree)
    assert step == 1


def test_checkpoint_gc_keeps_latest(tmp_path):
    tree = {"w": jnp.ones((2,))}
    for s in range(6):
        ckpt.save(tmp_path, s, tree)
    ckpt.gc_old(tmp_path, keep=2)
    assert ckpt.latest_step(tmp_path) == 5
    remaining = sorted(p.name for p in tmp_path.iterdir())
    assert len(remaining) == 2


def test_async_checkpointer(tmp_path):
    w = ckpt.AsyncCheckpointer(tmp_path)
    w.save(3, {"w": jnp.arange(4)})
    w.wait()
    assert ckpt.latest_step(tmp_path) == 3


# ------------------------------------------------------------ compression
def test_compress_error_feedback_identity():
    """decompress(q) + err == g exactly (EF invariant)."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(300,)) * 3.0, jnp.float32)
    c, err = comp.compress(g)
    recon = comp.decompress(c) + err
    np.testing.assert_allclose(np.asarray(recon), np.asarray(g), atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 1000), scale=st.floats(1e-3, 1e3))
def test_compress_error_bounded(n, scale):
    rng = np.random.default_rng(n)
    g = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
    c, err = comp.compress(g)
    blocks = np.asarray(jnp.pad(g, (0, (-n) % comp.BLOCK))).reshape(
        -1, comp.BLOCK)
    per_block_bound = np.abs(blocks).max(1) / 127.0 * 0.5 + 1e-6
    err_blocks = np.abs(np.asarray(jnp.pad(err, (0, (-n) % comp.BLOCK)))
                        ).reshape(-1, comp.BLOCK)
    assert (err_blocks.max(1) <= per_block_bound + 1e-5).all()


def test_ef_training_converges_like_uncompressed():
    """EF-compressed grads reach the same optimum on a quadratic."""
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0)
    target = jnp.asarray([1.0, -2.0, 3.0, 0.5])

    def run(compressed):
        params = {"w": jnp.zeros((4,))}
        state = adamw.init(params)
        err = comp.init_error(params)
        for _ in range(200):
            grads = {"w": 2 * (params["w"] - target)}
            if compressed:
                cgrads, err = comp.ef_compress_tree(grads, err)
                grads = comp.decompress_tree(cgrads)
            params, state, _ = adamw.apply(cfg, grads, state, params)
        return params["w"]

    w_plain = run(False)
    w_comp = run(True)
    np.testing.assert_allclose(np.asarray(w_comp), np.asarray(target),
                               atol=0.05)
    np.testing.assert_allclose(np.asarray(w_comp), np.asarray(w_plain),
                               atol=0.05)


# -------------------------------------------------------- fault tolerance
def test_straggler_watchdog_flags_outliers():
    seen = []
    w = StragglerWatchdog(threshold=2.0, warmup=3,
                          on_straggler=lambda s, dt, e: seen.append(s))
    for s in range(10):
        w.observe(s, 0.1)
    assert w.observe(10, 0.5) is True
    assert seen == [10]
    # EWMA not poisoned by the outlier
    assert w.ewma < 0.12


def test_retry_step_transient_then_success():
    calls = {"n": 0}

    def step():
        calls["n"] += 1
        if calls["n"] < 3:
            raise TransientError("link flap")
        return "ok"

    assert retry_step(step, max_retries=5, sleep=lambda s: None) == "ok"
    assert calls["n"] == 3


def test_retry_step_permanent_fallback():
    def step():
        raise TransientError("dead")

    out = retry_step(step, max_retries=2, sleep=lambda s: None,
                     on_permanent=lambda e: "restored-from-ckpt")
    assert out == "restored-from-ckpt"


def test_heartbeat_detects_dead_hosts(tmp_path):
    hb0 = Heartbeat(tmp_path, 0)
    hb1 = Heartbeat(tmp_path, 1)
    hb0.beat(5)
    hb1.beat(5)
    now = time.time()
    assert Heartbeat.dead_hosts(tmp_path, timeout_s=60, now=now) == []
    assert Heartbeat.dead_hosts(tmp_path, timeout_s=0.0,
                                now=now + 10) == [0, 1]
