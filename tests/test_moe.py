import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoECfg
from repro.models.moe import _capacity, init_moe, moe_layer


def _dense_ref(p, m, x):
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, m.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    B, S, D = x.shape
    y = jnp.zeros_like(x)
    for b in range(B):
        for t in range(S):
            acc = jnp.zeros((D,))
            for j in range(m.top_k):
                e = int(idx[b, t, j])
                h = jax.nn.silu(x[b, t] @ p["we_gate"][e]) * \
                    (x[b, t] @ p["we_up"][e])
                acc += gate[b, t, j] * (h @ p["we_down"][e])
            y = y.at[b, t].set(acc)
    return y


def test_moe_matches_dense_reference_when_capacity_ample():
    m = MoECfg(num_experts=4, top_k=2, d_ff_expert=32, capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(0), 16, m, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y, aux = moe_layer(p, m, x)
    ref = _dense_ref(p, m, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)
    assert float(aux) >= 0.99  # Switch aux loss lower bound is 1 (balanced)


def test_moe_capacity_drops_tokens_not_crashes():
    m = MoECfg(num_experts=4, top_k=2, d_ff_expert=16,
               capacity_factor=0.25)  # deliberately starved
    p = init_moe(jax.random.PRNGKey(0), 8, m, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 8))
    y, aux = moe_layer(p, m, x)
    assert bool(jnp.isfinite(y).all())
    # starved capacity must reduce total output mass vs ample capacity
    m2 = MoECfg(num_experts=4, top_k=2, d_ff_expert=16, capacity_factor=8.0)
    y2, _ = moe_layer(p, m2, x)
    assert float(jnp.abs(y).sum()) < float(jnp.abs(y2).sum())


def test_moe_shared_expert_always_active():
    m = MoECfg(num_experts=4, top_k=1, d_ff_expert=16, num_shared=1,
               capacity_factor=4.0)
    p = init_moe(jax.random.PRNGKey(2), 8, m, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 4, 8))
    y_with, _ = moe_layer(p, m, x)
    p2 = dict(p)
    p2["shared"] = jax.tree.map(jnp.zeros_like, p["shared"])
    y_without, _ = moe_layer(p2, m, x)
    assert float(jnp.abs(y_with - y_without).max()) > 1e-6


def test_moe_chunked_equals_single_shot(monkeypatch):
    import repro.models.moe as moe_mod
    m = MoECfg(num_experts=4, top_k=2, d_ff_expert=16, capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(0), 8, m, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 8))
    monkeypatch.setattr(moe_mod, "TOK_CHUNK", 16)
    y1, _ = moe_layer(p, m, x)
    monkeypatch.setattr(moe_mod, "TOK_CHUNK", 4096)
    y2, _ = moe_layer(p, m, x)
    # chunked capacity is per-chunk; with ample cf results are identical
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_capacity_is_lane_aligned():
    m = MoECfg(num_experts=384, top_k=8, d_ff_expert=16)
    c = _capacity(512, m)
    assert c % 8 == 0 and c >= 512 * 8 * 1.25 / 384
