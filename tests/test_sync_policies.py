"""First-class sync policies for the sharded engine (docs/sharding.md).

`api.Sync(halo_every=k, mode=..., sweeps_per_launch=S)` makes how often
row-band shards synchronize a compiled sampler property:

  * the default per-half-sweep barrier (halo_every=1) keeps the sharded ==
    single-device bit-exactness contract of PR 4 exactly;
  * relaxed policies (k>1, launch-resident, PASS-style async) are
    deterministic, seeded approximations whose sampling-quality cost is
    *measured* here (KL on a 2x2-Chimera visible distribution) rather
    than assumed away;
  * a launch-resident counter-noise policy runs each launch inside the
    sweep-resident Pallas kernel (`fused_shard_sweeps`) — bit-identical
    to the scan path under the same policy, which this file enforces on a
    forced 2-device host.

One-shard cases are the sharpest cheap check: with a single row band the
halos are structurally zero, so EVERY policy must reproduce the
single-device trajectory bit for bit — any deviation is a bug in the
launch-loop restructuring or the kernel's coordinate-shifted RNG, not
staleness.
"""
import json
import math
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core.cd import PBitMachine
from repro.core.chimera import make_chimera
from repro.core.distributed import halo_bytes_per_sweep, plan_row_partition
from repro.core.hardware import HardwareConfig

ROOT = Path(__file__).resolve().parent.parent
SUBPROC_ENV = {"PYTHONPATH": f"{ROOT}/src", "PATH": "/usr/bin:/bin",
               "HOME": "/root", "JAX_PLATFORMS": "cpu"}


# ---------------------------------------------------------------------------
# the Sync value object
# ---------------------------------------------------------------------------
def test_sync_validation():
    with pytest.raises(ValueError, match="halo_every"):
        api.Sync(halo_every=0)
    with pytest.raises(ValueError, match="halo_every"):
        api.Sync(halo_every=2.5)
    with pytest.raises(ValueError, match="mode"):
        api.Sync(mode="fire_and_forget")
    with pytest.raises(ValueError, match="sweeps_per_launch"):
        api.Sync(sweeps_per_launch=0)
    assert api.Sync().bit_exact
    assert not api.Sync(halo_every=2).bit_exact
    assert not api.Sync(mode="async").bit_exact


def test_exchange_points_and_fusibility():
    assert api.Sync().exchange_points() == (0, 1)
    assert api.Sync(sweeps_per_launch=4).exchange_points() == tuple(range(8))
    assert api.Sync(halo_every=4,
                    sweeps_per_launch=4).exchange_points() == (0, 4)
    assert api.Sync(halo_every=math.inf,
                    sweeps_per_launch=8).exchange_points() == (0,)
    # fusible <=> no mid-launch exchange
    assert api.Sync(halo_every=math.inf, sweeps_per_launch=8).kernel_fusible
    assert api.Sync(halo_every=2, sweeps_per_launch=1).kernel_fusible
    assert not api.Sync(halo_every=4, sweeps_per_launch=4).kernel_fusible
    assert not api.Sync().kernel_fusible


def test_halo_bytes_model_scales_with_policy():
    g = make_chimera(8, 8)
    p = plan_row_partition(g, 2)
    B = 16
    base = halo_bytes_per_sweep(p, B)
    assert base == halo_bytes_per_sweep(p, B, sync=api.Sync())
    # k=4 over 4-sweep launches: 2 exchanges per 8 half-sweeps -> /4
    relaxed = halo_bytes_per_sweep(
        p, B, sync=api.Sync(halo_every=4, sweeps_per_launch=4))
    assert relaxed == base / 4
    # launch-resident: 1 exchange per 2S half-sweeps
    resident = halo_bytes_per_sweep(
        p, B, sync=api.Sync(halo_every=math.inf, sweeps_per_launch=8))
    assert resident == base / 16
    # the moment refresh only exists on the bit-exact path
    assert halo_bytes_per_sweep(p, B, refresh_for_moments=True,
                                sync=api.Sync()) == 1.5 * base
    assert halo_bytes_per_sweep(
        p, B, refresh_for_moments=True,
        sync=api.Sync(halo_every=math.inf, sweeps_per_launch=8)) == resident


# ---------------------------------------------------------------------------
# spec validation + backend resolution
# ---------------------------------------------------------------------------
def _machine(g, **kw):
    kw.setdefault("noise", "counter")
    kw.setdefault("backend", "sparse")
    return PBitMachine.create(g, jax.random.PRNGKey(0), HardwareConfig(),
                              **kw)


def _spec(mach, mesh, sync=None, backend=None, **kw):
    sp = mach.sampler_spec(mesh=mesh, partition=api.Partition(rows="data"),
                           sync=sync, chains=kw.pop("chains", 8), **kw)
    return sp if backend is None else sp.replace(backend=backend)


def test_spec_sync_validation(monkeypatch):
    g = make_chimera(2, 2)
    mesh = jax.make_mesh((1,), ("data",))
    mach = _machine(g)
    with pytest.raises(ValueError, match="mesh=None"):
        mach.sampler_spec(sync=api.Sync()).validate()
    # halo_every <= sweeps_per_launch is fused-legal now (the kernel owns
    # the exchange); the infeasible window S < k < 2S raises an error
    # that names the nearest legal Sync instead of only the constraint
    _spec(mach, mesh, api.Sync(halo_every=4, sweeps_per_launch=4),
          backend="fused_sparse").validate()
    with pytest.raises(ValueError,
                       match=r"nearest legal Sync.*lower halo_every to 4"
                             r".*raise it to >= 8 or math\.inf.*"
                             r"backend='sparse'"):
        _spec(mach, mesh, api.Sync(halo_every=6, sweeps_per_launch=4),
              backend="fused_sparse").validate()
    # ...and counter noise
    with pytest.raises(ValueError, match="counter"):
        _spec(_machine(g, noise="lfsr"), mesh,
              api.Sync(halo_every=math.inf, sweeps_per_launch=4),
              backend="fused_sparse").validate()
    # auto: default barrier stays on the scan path; a launch-resident
    # counter policy resolves to the fused per-shard kernel
    assert api.resolve_backend(
        _spec(mach, mesh, backend="auto")) == "sparse"
    assert api.resolve_backend(_spec(
        mach, mesh, api.Sync(halo_every=math.inf, sweeps_per_launch=4),
        backend="auto")) == "fused_sparse"
    # lfsr can relax sync but stays on the scan path
    assert api.resolve_backend(_spec(
        _machine(g, noise="lfsr"), mesh,
        api.Sync(halo_every=math.inf, sweeps_per_launch=4),
        backend="auto")) == "sparse"
    # the env default participates but cannot silently override: a value
    # the partition cannot honor is a hard error naming the env var
    monkeypatch.setenv("REPRO_PBIT_BACKEND", "fused")
    with pytest.raises(ValueError, match="REPRO_PBIT_BACKEND"):
        api.resolve_backend(_spec(mach, mesh, backend="auto"))
    monkeypatch.setenv("REPRO_PBIT_BACKEND", "fused_sparse")
    # the default barrier is fused-compatible now; only the infeasible
    # S < k < 2S window still rejects the env-pinned fused kernel, and
    # the error names both the env var and the nearest legal Sync
    assert api.resolve_backend(
        _spec(mach, mesh, backend="auto")) == "fused_sparse"
    with pytest.raises(ValueError,
                       match=r"REPRO_PBIT_BACKEND.*nearest legal Sync.*"
                             r"lower halo_every to 4"):
        api.resolve_backend(_spec(
            mach, mesh, api.Sync(halo_every=6, sweeps_per_launch=4),
            backend="auto"))
    assert api.resolve_backend(_spec(
        mach, mesh, api.Sync(halo_every=math.inf, sweeps_per_launch=4),
        backend="auto")) == "fused_sparse"
    monkeypatch.setenv("REPRO_PBIT_BACKEND", "sparse")
    assert api.resolve_backend(_spec(mach, mesh, backend="auto")) == "sparse"


# ---------------------------------------------------------------------------
# one-shard mesh: every policy must stay bit-exact (halos are zeros)
# ---------------------------------------------------------------------------
POLICIES = [
    api.Sync(),
    api.Sync(halo_every=2),
    api.Sync(halo_every=4, sweeps_per_launch=2),
    api.Sync(halo_every=math.inf, sweeps_per_launch=4),
    api.Sync(halo_every=math.inf, mode="async", sweeps_per_launch=4),
]


def _chip_state(mach, ses, g, seed=1):
    rng = np.random.default_rng(seed)
    chip = ses.program_edges(
        jnp.asarray(rng.integers(-50, 50, g.n_edges), jnp.int32),
        jnp.asarray(rng.integers(-10, 10, g.n_nodes), jnp.int32))
    m0 = ses.random_spins(jax.random.PRNGKey(2))
    ns = ses.noise_state(jax.random.PRNGKey(3))
    return chip, m0, ns


@pytest.mark.parametrize("sync", POLICIES,
                         ids=lambda s: f"k{s.halo_every}-{s.mode}"
                                       f"-L{s.sweeps_per_launch}")
def test_one_shard_any_policy_bit_exact(sync):
    g = make_chimera(3, 2, masked_cells=((1, 1),))
    mesh = jax.make_mesh((1,), ("data",))
    mach = _machine(g)
    B, S = 8, 8
    ses0 = api.Session(mach.sampler_spec(chains=B))
    ses1 = api.Session(_spec(mach, mesh, sync, chains=B))
    chip, m0, ns = _chip_state(mach, ses0, g)
    betas = jnp.linspace(0.3, 1.5, S)
    a = ses0.sample(chip, m0, ns, betas)
    b = ses1.sample(chip, m0, ns, betas)
    for x, y in zip(a[:2], b[:2]):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(ses0.stats(chip, m0, ns, 8, 2),
                    ses1.stats(chip, m0, ns, 8, 2)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    vis = np.array([0, 3, 9])
    ha = ses0.visible_hist(chip, m0, ns, vis, 2, betas)
    hb = ses1.visible_hist(chip, m0, ns, vis, 2, betas)
    np.testing.assert_array_equal(np.asarray(ha[0]), np.asarray(hb[0]))


def test_one_shard_lfsr_policy_bit_exact():
    g = make_chimera(3, 2)
    mesh = jax.make_mesh((1,), ("data",))
    mach = _machine(g, noise="lfsr")
    B, S = 4, 8
    ses0 = api.Session(mach.sampler_spec(chains=B))
    ses1 = api.Session(_spec(
        mach, mesh, api.Sync(halo_every=4, sweeps_per_launch=4), chains=B))
    chip, m0, ns = _chip_state(mach, ses0, g)
    betas = jnp.linspace(0.3, 1.5, S)
    a = ses0.sample(chip, m0, ns, betas)
    b = ses1.sample(chip, m0, ns, betas)
    for x, y in zip(a[:2], b[:2]):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_one_shard_fused_kernel_matches_scan():
    """The sweep-resident per-shard kernel (in-kernel coordinate-shifted
    RNG, frozen halo columns, in-kernel moments) vs the unsharded scan:
    spins bit-exact, moments to accumulation-order tolerance."""
    g = make_chimera(3, 2, masked_cells=((1, 1),))
    mesh = jax.make_mesh((1,), ("data",))
    mach = _machine(g)
    B, S = 8, 8
    sync = api.Sync(halo_every=math.inf, sweeps_per_launch=4)
    ses0 = api.Session(mach.sampler_spec(chains=B))
    ses1 = api.Session(_spec(mach, mesh, sync, backend="fused_sparse",
                             chains=B, interpret=True))
    assert ses1.backend == "fused_sparse"
    chip, m0, ns = _chip_state(mach, ses0, g)
    betas = jnp.linspace(0.3, 1.5, S)
    a = ses0.sample(chip, m0, ns, betas)
    b = ses1.sample(chip, m0, ns, betas)
    for x, y in zip(a[:2], b[:2]):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # clamped (cm + cv) rides through the kernel too
    cm = jnp.zeros((g.n_nodes,), bool).at[jnp.array([0, 5, 11])].set(True)
    cv = jnp.tile(jnp.asarray([[1.0]]), (B, g.n_nodes))
    a = ses0.sample(chip, m0, ns, betas, clamp_mask=cm, clamp_values=cv)
    b = ses1.sample(chip, m0, ns, betas, clamp_mask=cm, clamp_values=cv)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    sa = ses0.stats(chip, m0, ns, 8, 2)
    sb = ses1.stats(chip, m0, ns, 8, 2)
    np.testing.assert_array_equal(np.asarray(sa[2]), np.asarray(sb[2]))
    np.testing.assert_allclose(np.asarray(sa[0]), np.asarray(sb[0]),
                               atol=2e-6)
    np.testing.assert_allclose(np.asarray(sa[1]), np.asarray(sb[1]),
                               atol=2e-6)


def test_schedule_must_divide_launch():
    g = make_chimera(2, 2)
    mesh = jax.make_mesh((1,), ("data",))
    mach = _machine(g)
    ses = api.Session(_spec(mach, mesh,
                            api.Sync(halo_every=math.inf,
                                     sweeps_per_launch=4), chains=4))
    chip, m0, ns = _chip_state(mach, ses, g)
    with pytest.raises(ValueError, match="sweeps_per_launch"):
        ses.sample(chip, m0, ns, jnp.linspace(0.3, 1.0, 5))


# ---------------------------------------------------------------------------
# forced 2-device host: staleness is real, measured, and bounded
# ---------------------------------------------------------------------------
def _run_forced(script: str, n_dev: int, timeout: int = 540) -> dict:
    head = (f"import os\nos.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={n_dev}'\n")
    out = subprocess.run(
        [sys.executable, "-c", head + textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout, env=SUBPROC_ENV,
        cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_two_device_sync_policies():
    """(i) halo_every=1 stays bit-exact vs single device; (ii) relaxed
    policies are deterministic and genuinely different; (iii) the fused
    per-shard kernel matches the scan path bit-for-bit under the same
    policy across real shards; (iv) k=4 and async keep the visible
    distribution within KL 0.05 of the synchronous baseline (measured
    sampling-noise floor between two sync seeds is ~0.01 here)."""
    rec = _run_forced("""
    import math, json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro import api
    from repro.core.cd import PBitMachine
    from repro.core.chimera import make_chimera
    from repro.core.hardware import HardwareConfig

    g = make_chimera(2, 2)
    mesh = jax.make_mesh((2,), ("data",))
    mach = PBitMachine.create(g, jax.random.PRNGKey(0), HardwareConfig(),
                              noise="counter", backend="sparse")
    rng = np.random.default_rng(5)
    codes = jnp.asarray(rng.integers(-60, 60, g.n_edges), jnp.int32)
    h = jnp.asarray(rng.integers(-15, 15, g.n_nodes), jnp.int32)
    B = 8
    ses0 = api.Session(mach.sampler_spec(chains=B))
    chip = ses0.program_edges(codes, h)
    m0 = ses0.random_spins(jax.random.PRNGKey(2))
    ns = ses0.noise_state(jax.random.PRNGKey(3))

    def spec(sync=None, backend=None):
        sp = mach.sampler_spec(chains=B, mesh=mesh, interpret=True,
                               partition=api.Partition(rows="data"),
                               sync=sync)
        return sp if backend is None else sp.replace(backend=backend)

    rec = {}
    betas = jnp.linspace(0.3, 1.5, 8)
    ref = ses0.sample(chip, m0, ns, betas)
    bar = api.Session(spec(api.Sync())).sample(chip, m0, ns, betas)
    rec["barrier_bit_exact"] = bool(
        np.array_equal(np.asarray(ref[0]), np.asarray(bar[0])))

    rel_ses = api.Session(spec(api.Sync(halo_every=math.inf,
                                        sweeps_per_launch=4)))
    r1 = rel_ses.sample(chip, m0, ns, betas)
    r2 = rel_ses.sample(chip, m0, ns, betas)
    rec["relaxed_deterministic"] = bool(
        np.array_equal(np.asarray(r1[0]), np.asarray(r2[0])))
    rec["relaxed_differs"] = bool(
        not np.array_equal(np.asarray(ref[0]), np.asarray(r1[0])))

    fz = api.Session(spec(api.Sync(halo_every=math.inf,
                                   sweeps_per_launch=4),
                          backend="fused_sparse"))
    of = fz.sample(chip, m0, ns, betas)
    rec["fused_matches_scan"] = bool(
        np.array_equal(np.asarray(r1[0]), np.asarray(of[0]))
        and np.array_equal(np.asarray(r1[1]), np.asarray(of[1])))

    # sampling quality: visible distribution at beta=1 vs sync baseline
    S, burn = 400, 50
    vis = np.array([0, 3, 9, 17])
    betas_q = jnp.full((S,), 1.0, jnp.float32)

    def dist(ses, seed=3):
        nsl = ses.noise_state(jax.random.PRNGKey(seed))
        hist, _, _ = ses.visible_hist(chip, m0, nsl, vis, burn, betas_q)
        p = np.asarray(hist, np.float64)
        return (p + 1e-9) / (p.sum() + 1e-9 * p.size)

    def kl(p, q):
        return float(np.sum(p * np.log(p / q)))

    base = dist(api.Session(spec(api.Sync())))
    base2 = dist(api.Session(spec(api.Sync())), seed=7)
    k4 = dist(api.Session(spec(api.Sync(halo_every=4,
                                        sweeps_per_launch=2))))
    asy = dist(api.Session(spec(api.Sync(halo_every=math.inf,
                                         mode="async",
                                         sweeps_per_launch=4))))
    rec["kl_seed_floor"] = kl(base, base2)
    rec["kl_k4"] = kl(base, k4)
    rec["kl_async"] = kl(base, asy)
    print(json.dumps(rec))
    """, n_dev=2)
    assert rec["barrier_bit_exact"]
    assert rec["relaxed_deterministic"]
    assert rec["relaxed_differs"]
    assert rec["fused_matches_scan"]
    # stated tolerance: relaxed-sync bias must stay within 0.05 nats of
    # the synchronous baseline (~5x the measured seed-to-seed floor)
    assert rec["kl_k4"] < 0.05, rec
    assert rec["kl_async"] < 0.05, rec
    assert rec["kl_seed_floor"] < 0.05, rec
