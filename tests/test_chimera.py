import numpy as np
import pytest

from repro.core.chimera import make_chimera, make_chip_graph


def test_chip_graph_matches_paper():
    g = make_chip_graph()
    assert g.n_nodes == 440                # 55 cells x 8 spins
    assert g.n_cells == 55
    assert g.degree().max() == 6           # 4 in-cell + 2 inter-cell
    assert g.validate_two_coloring()


def test_single_cell_is_k44():
    g = make_chimera(1, 1)
    assert g.n_nodes == 8
    assert g.n_edges == 16                 # complete bipartite 4x4
    deg = g.degree()
    assert (deg == 4).all()


def test_cell_nodes_sides():
    g = make_chip_graph()
    v = g.cell_nodes(0, 0, side=0)
    h = g.cell_nodes(0, 0, side=1)
    assert len(v) == len(h) == 4
    adj = g.adjacency()
    for a in v:
        for b in h:
            assert adj[a, b]
    for a in v:
        for b in v:
            assert not adj[a, b]           # no same-side in-cell couplers


@pytest.mark.parametrize("rows", [1, 2, 3, 4])
@pytest.mark.parametrize("cols", [1, 2, 3, 4])
@pytest.mark.parametrize("mask", [False, True])
def test_chimera_invariants(rows, cols, mask):
    # exhaustive grid (was a hypothesis property test; the pure-pytest sweep
    # covers the full strategy space deterministically)
    masked = [(rows - 1, cols - 1)] if mask and rows * cols > 1 else []
    g = make_chimera(rows, cols, masked_cells=masked)
    # property 1: proper 2-coloring
    assert g.validate_two_coloring()
    # property 2: node count
    assert g.n_nodes == (rows * cols - len(masked)) * 8
    # property 3: degree bound k + 2
    assert g.degree().max() <= 6
    # property 4: symmetric edge list without self loops
    e = g.edges
    assert (e[:, 0] < e[:, 1]).all()
    # property 5: color classes are balanced
    assert (g.color == 0).sum() == (g.color == 1).sum()


def test_masked_cell_removes_wires():
    g = make_chimera(2, 2, masked_cells=[(0, 1)])
    assert g.n_nodes == 24
    for r, c in [(0, 0), (1, 0), (1, 1)]:
        assert len(g.cell_nodes(r, c)) == 8
    assert len(g.cell_nodes(0, 1)) == 0
