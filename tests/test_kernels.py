"""Pallas kernel vs pure-jnp oracle, interpret mode, shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import make_kernel_half_sweep, ref_half_sweep
from repro.kernels.pbit_update import pbit_half_sweep_pallas
from repro.kernels.ref import pbit_half_sweep_ref


def _case(B, N, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    m = (rng.integers(0, 2, (B, N)) * 2 - 1).astype(dtype)
    W = (rng.normal(size=(N, N)) * 0.1).astype(dtype)
    vecs = [rng.normal(size=N).astype(np.float32) for _ in range(5)]
    mask = rng.integers(0, 2, N).astype(bool)
    u = rng.uniform(-1, 1, (B, N)).astype(np.float32)
    return m, W, vecs, mask, u


@pytest.mark.parametrize("B,N,bb,bn,bk", [
    (4, 440, 8, 128, 128),
    (128, 440, 128, 128, 512),
    (64, 1024, 32, 128, 256),
    (3, 77, 8, 128, 128),
    (16, 256, 16, 128, 128),
])
def test_pallas_matches_ref(B, N, bb, bn, bk):
    m, W, (h, g, o, rg, co), mask, u = _case(B, N, seed=B + N)
    ref = pbit_half_sweep_ref(m, W, h, g, o, rg, co, mask, 0.7, u)
    out = pbit_half_sweep_pallas(m, W, h, g, o, rg, co, mask, 0.7, u,
                                 block_b=bb, block_n=bn, block_k=bk,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref))


def test_pallas_bf16():
    m, W, (h, g, o, rg, co), mask, u = _case(16, 440, seed=1)
    mb, Wb = jnp.bfloat16(m), jnp.bfloat16(W)
    ref = pbit_half_sweep_ref(mb, Wb, h, g, o, rg, co, mask, 0.5, u)
    out = pbit_half_sweep_pallas(mb, Wb, h, g, o, rg, co, mask, 0.5, u,
                                 block_b=8, interpret=True)
    # sign decisions may differ at ties under reduced precision: bound the
    # disagreement rate instead of exact equality
    frac = float((np.asarray(out, np.float32) !=
                  np.asarray(ref, np.float32)).mean())
    assert frac < 0.01, frac


def test_kernel_wrapper_integrates_with_sampler():
    """Full Gibbs sweep through the Pallas kernel == through jnp ref."""
    import repro.core.pbit as pbit
    from repro.core.chimera import make_chimera
    from repro.core.hardware import ideal_chip

    g = make_chimera(1, 1)
    rng = np.random.default_rng(0)
    J = np.zeros((8, 8), np.float32)
    vals = rng.normal(size=g.n_edges) * 0.5
    J[g.edges[:, 0], g.edges[:, 1]] = vals
    J[g.edges[:, 1], g.edges[:, 0]] = vals
    chip = ideal_chip(J, np.zeros(8, np.float32),
                      jnp.asarray(g.adjacency()))
    kernel = make_kernel_half_sweep(block_b=8, block_n=128, block_k=128,
                                    interpret=True)
    m0 = pbit.random_spins(jax.random.PRNGKey(0), 8, 8)
    betas = jnp.ones((20,))
    noise = pbit.make_philox_noise(8, 8)
    m_k, _, _ = pbit.gibbs_sample(chip, jnp.asarray(g.color), m0, betas,
                                  jax.random.PRNGKey(1), noise,
                                  kernel=kernel)
    m_r, _, _ = pbit.gibbs_sample(chip, jnp.asarray(g.color), m0, betas,
                                  jax.random.PRNGKey(1), noise)
    np.testing.assert_array_equal(np.asarray(m_k), np.asarray(m_r))


@pytest.mark.parametrize("B,R,C,br", [(2, 8, 8, 4), (4, 16, 4, 8),
                                      (1, 8, 32, 8)])
def test_lattice_kernel_matches_ref(B, R, C, br):
    from repro.kernels.lattice_update import lattice_vertical_update_pallas
    from repro.kernels.ref import lattice_vertical_update_ref

    rng = np.random.default_rng(B * R + C)
    k = 4
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    sp = lambda *s: jnp.asarray(rng.integers(0, 2, s) * 2 - 1, jnp.float32)
    m_v, m_h = sp(B, R, C, k), sp(B, R, C, k)
    up, dn = sp(B, R, C, k), sp(B, R, C, k)
    W = mk(R, C, k, k) * 0.5
    wu, wd, h = mk(R, C, k), mk(R, C, k), mk(R, C, k) * 0.3
    g = 1 + 0.1 * mk(R, C, k)
    u = jnp.asarray(rng.uniform(-1, 1, (B, R, C, k)), jnp.float32)
    par = jnp.asarray(
        np.add.outer(np.arange(R), np.arange(C)) % 2, jnp.int32)
    for color in (0, 1):
        ref = lattice_vertical_update_ref(m_v, m_h, up, dn, W, wu, wd, h,
                                          g, u, par, color)
        out = lattice_vertical_update_pallas(
            m_v, m_h, up, dn, W, wu, wd, h, g, u, par, color=color,
            block_r=br, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
