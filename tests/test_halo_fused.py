"""Kernel-resident halo exchange (docs/kernels.md §In-kernel halo exchange).

The fused-resident-exchange loop shape lets a fused launch refresh halos
MID-FLIGHT: on TPU meshes the kernel itself RDMAs the O(√N) boundary at
every `Sync.exchange_points()` half-sweep; on host CI the engine runs the
bit-exact emulation — the same launch split at the exchange points into
`half_offset`/`n_half` windows of the resident kernel with a ppermute
between windows, one jitted graph.  This file pins the contracts the
hardware path must reproduce:

  * the half-sweep-window kernel parameters chain bit-exactly (a launch
    split at arbitrary cuts equals the unsplit launch, spins + noise +
    moments + staged program uploads);
  * fused kernel-resident exchange under `Sync(halo_every=1,
    mode="barrier")` equals the single-device Session bit for bit on a
    forced 2-device host, chained program streams included;
  * relaxed policies (halo_every=k, async) equal the existing sparse
    segment-scan engine bit for bit under the same seeds;
  * `plan_row_partition` memoizes (serving's shard-loss re-plan hits the
    cache), `Sync.exchange_points()` edge semantics are pinned, and the
    ICI napkin model carries a per-exchange latency term.
"""
import json
import math
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core.cd import PBitMachine
from repro.core.chimera import make_chimera
from repro.core.distributed import (
    clear_plan_cache,
    plan_cache_stats,
    plan_row_partition,
)
from repro.core.hardware import HardwareConfig
from repro.kernels.ref import halo_exchange_segments

ROOT = Path(__file__).resolve().parent.parent
SUBPROC_ENV = {"PYTHONPATH": f"{ROOT}/src", "PATH": "/usr/bin:/bin",
               "HOME": "/root", "JAX_PLATFORMS": "cpu"}


def _run_forced(script: str, n_dev: int, timeout: int = 540) -> dict:
    head = (f"import os\nos.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={n_dev}'\n")
    out = subprocess.run(
        [sys.executable, "-c", head + textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout, env=SUBPROC_ENV,
        cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# ---------------------------------------------------------------------------
# plan memoization (serving's re-plan path)
# ---------------------------------------------------------------------------
def test_plan_row_partition_memoized():
    g = make_chimera(6, 2)
    clear_plan_cache()
    p3 = plan_row_partition(g, 3)
    assert plan_cache_stats() == {"hits": 0, "misses": 1}
    # the degrade ladder: shard dies, re-plan over the survivors...
    p2 = plan_row_partition(g, 2)
    assert plan_cache_stats() == {"hits": 0, "misses": 2}
    # ...and any later Session compile on the same (graph, n_shards)
    # hits the cache — including a re-degrade back through 2 shards
    assert plan_row_partition(g, 2) is p2
    assert plan_row_partition(g, 3) is p3
    assert plan_cache_stats() == {"hits": 2, "misses": 2}
    # the key separates lfsr plans (they carry the cell permutation)...
    plan_row_partition(g, 2, with_lfsr=True)
    assert plan_cache_stats()["misses"] == 3
    # ...and distinct graphs (masked cells change the partition)
    plan_row_partition(make_chimera(6, 2, masked_cells=((1, 1),)), 2)
    assert plan_cache_stats()["misses"] == 4
    # invalid shard counts raise without polluting the cache
    with pytest.raises(ValueError):
        plan_row_partition(g, 7)
    assert plan_cache_stats()["misses"] == 4
    clear_plan_cache()
    assert plan_cache_stats() == {"hits": 0, "misses": 0}


# ---------------------------------------------------------------------------
# Sync edge-case semantics (pinned before the kernel path consumes them)
# ---------------------------------------------------------------------------
def test_exchange_points_property_grid():
    for S in range(1, 7):
        for k in list(range(1, 10)) + [math.inf]:
            sync = api.Sync(halo_every=k, sweeps_per_launch=S)
            pts = sync.exchange_points()
            if k == math.inf:
                expect = (0,)
            else:
                expect = tuple(h for h in range(2 * S) if h % k == 0)
            assert pts == expect, (k, S)
            assert pts[0] == 0  # a launch boundary always refreshes
            assert sync.kernel_fusible == (pts == (0,))
            assert sync.exchanges_per_sweep() == len(pts) / S
            # the bit-exact moment refresh only exists at k=1 barrier
            extra = 1.0 if sync.bit_exact else 0.0
            assert sync.exchanges_per_sweep(refresh_for_moments=True) \
                == len(pts) / S + extra


def test_exchange_points_edges():
    # halo_every > 2*sweeps_per_launch: only the launch boundary
    assert api.Sync(halo_every=5,
                    sweeps_per_launch=2).exchange_points() == (0,)
    # non-dividing halo_every: points land mid-sweep
    assert api.Sync(halo_every=3,
                    sweeps_per_launch=2).exchange_points() == (0, 3)
    # halo_every=1 with S=1: both halves of the single sweep
    assert api.Sync(halo_every=1,
                    sweeps_per_launch=1).exchange_points() == (0, 1)
    assert api.Sync(halo_every=1,
                    sweeps_per_launch=1).exchanges_per_sweep() == 2.0


def test_fused_compatible_windows():
    # kernel-resident exchange: any halo_every <= sweeps_per_launch
    assert api.Sync(halo_every=1, sweeps_per_launch=4).fused_compatible
    assert api.Sync(halo_every=4, sweeps_per_launch=4).fused_compatible
    assert api.Sync(halo_every=1, sweeps_per_launch=1).fused_compatible
    # launch-boundary-only exchange stays fusible
    assert api.Sync(halo_every=math.inf,
                    sweeps_per_launch=8).fused_compatible
    assert api.Sync(halo_every=8, sweeps_per_launch=4).fused_compatible
    # the infeasible window: S < halo_every < 2S
    assert not api.Sync(halo_every=5, sweeps_per_launch=4).fused_compatible
    assert not api.Sync(halo_every=6, sweeps_per_launch=4).fused_compatible
    assert not api.Sync(halo_every=3, sweeps_per_launch=2).fused_compatible


def test_halo_exchange_segments_helper():
    assert halo_exchange_segments((0,), 8) == ((0, 8),)
    assert halo_exchange_segments((0, 4), 8) == ((0, 4), (4, 8))
    assert halo_exchange_segments(tuple(range(4)), 4) \
        == ((0, 1), (1, 2), (2, 3), (3, 4))
    with pytest.raises(ValueError, match="start at 0"):
        halo_exchange_segments((1, 2), 4)
    with pytest.raises(ValueError, match="start at 0"):
        halo_exchange_segments((), 4)
    with pytest.raises(ValueError, match="outside"):
        halo_exchange_segments((0, 9), 8)


# ---------------------------------------------------------------------------
# the half-sweep-window kernel contract (in-process, interpret mode)
# ---------------------------------------------------------------------------
def _sparse_setup(seed=1, B=6, S=6):
    g = make_chimera(2, 2)
    mach = PBitMachine.create(g, jax.random.PRNGKey(0), sparse=True,
                              noise="counter")
    ses = api.Session(mach.sampler_spec(chains=B, interpret=True))
    rng = np.random.default_rng(seed)
    chip = ses.program_edges(
        jnp.asarray(rng.integers(-60, 60, g.n_edges), jnp.int32),
        jnp.asarray(rng.integers(-15, 15, g.n_nodes), jnp.int32))
    m0 = ses.random_spins(jax.random.PRNGKey(2))
    masks = (jnp.asarray(g.color == 0), jnp.asarray(g.color == 1))
    betas = jnp.broadcast_to(jnp.linspace(0.3, 1.5, S)[:, None], (S, B))
    ns0 = jnp.asarray([42, 0], jnp.uint32)
    return g, ses, chip, m0, masks, betas, ns0


@pytest.mark.parametrize("cuts", [(0, 1), (0, 3, 4), (0, 2, 5, 9, 11)],
                         ids=lambda c: "c" + "-".join(map(str, c)))
def test_window_chaining_matches_single_launch(cuts):
    """`half_offset`/`n_half` windows of `sweep_sparse_pallas` chained at
    arbitrary half-sweep cuts == the unsplit launch, bit for bit (spins,
    noise state, in-kernel moments)."""
    from repro.kernels.sweep_fused import sweep_sparse_pallas

    _, _, chip, m0, masks, betas, ns0 = _sparse_setup()
    S = betas.shape[0]
    meas = jnp.ones((S,), jnp.float32)
    kw = dict(noise_mode="counter", accumulate=True, block_b=8,
              interpret=True)
    args = (chip.nbr_idx, chip.nbr_w, chip.h, chip.tanh_gain,
            chip.tanh_offset, chip.rand_gain, chip.comp_offset, *masks,
            betas)
    whole = sweep_sparse_pallas(m0, *args, ns0, measured=meas, **kw)
    m_c, ns_c = m0, ns0
    ssum = jnp.zeros_like(whole[2])
    csum = jnp.zeros_like(whole[3])
    for h0, h1 in halo_exchange_segments(tuple(cuts), 2 * S):
        m_c, ns_c, s_w, c_w = sweep_sparse_pallas(
            m_c, *args, ns_c, measured=meas, half_offset=h0,
            n_half=h1 - h0, **kw)
        ssum, csum = ssum + s_w, csum + c_w
    np.testing.assert_array_equal(np.asarray(m_c), np.asarray(whole[0]))
    np.testing.assert_array_equal(np.asarray(ns_c), np.asarray(whole[1]))
    np.testing.assert_array_equal(np.asarray(ssum), np.asarray(whole[2]))
    np.testing.assert_array_equal(np.asarray(csum), np.asarray(whole[3]))


def test_stream_window_chaining_keeps_staged_upload():
    """A program upload and a segmented launch share one resident stream:
    `sweep_sparse_stream_pallas` windows chain bit-exactly AND every
    window's staged output is the next program's weights — so a halo
    refresh and a weight upload ride the same launch."""
    from repro.kernels.sweep_fused import (
        sweep_sparse_pallas,
        sweep_sparse_stream_pallas,
    )

    g, ses, chip, m0, masks, betas, ns0 = _sparse_setup()
    rng = np.random.default_rng(7)
    nxt = ses.program_edges(
        jnp.asarray(rng.integers(-60, 60, g.n_edges), jnp.int32),
        jnp.asarray(rng.integers(-15, 15, g.n_nodes), jnp.int32))
    S = betas.shape[0]
    plain = sweep_sparse_pallas(
        m0, chip.nbr_idx, chip.nbr_w, chip.h, chip.tanh_gain,
        chip.tanh_offset, chip.rand_gain, chip.comp_offset, *masks,
        betas, ns0, noise_mode="counter", block_b=8, interpret=True)
    m_c, ns_c = m0, ns0
    for h0, h1 in halo_exchange_segments((0, 3, 8), 2 * S):
        m_c, ns_c, w_next, h_next = sweep_sparse_stream_pallas(
            m_c, chip.nbr_idx, chip.nbr_w, chip.h, chip.tanh_gain,
            chip.tanh_offset, chip.rand_gain, chip.comp_offset, *masks,
            betas, ns_c, nxt.nbr_w, nxt.h, block_b=8, interpret=True,
            half_offset=h0, n_half=h1 - h0)
        np.testing.assert_array_equal(
            np.asarray(w_next), np.asarray(nxt.nbr_w, np.float32))
        np.testing.assert_array_equal(
            np.asarray(h_next), np.asarray(nxt.h, np.float32))
    np.testing.assert_array_equal(np.asarray(m_c), np.asarray(plain[0]))
    np.testing.assert_array_equal(np.asarray(ns_c), np.asarray(plain[1]))


def test_window_validation():
    from repro.kernels.sweep_fused import sweep_sparse_pallas

    _, _, chip, m0, masks, betas, ns0 = _sparse_setup()
    with pytest.raises(ValueError, match="half-sweep window"):
        sweep_sparse_pallas(
            m0, chip.nbr_idx, chip.nbr_w, chip.h, chip.tanh_gain,
            chip.tanh_offset, chip.rand_gain, chip.comp_offset, *masks,
            betas, ns0, noise_mode="counter", block_b=8, interpret=True,
            half_offset=10, n_half=4)


# ---------------------------------------------------------------------------
# one-shard fused-exchange Sessions (halos structurally zero => bit-exact)
# ---------------------------------------------------------------------------
EX_POLICIES = [
    api.Sync(halo_every=1, sweeps_per_launch=4),
    api.Sync(halo_every=2, sweeps_per_launch=2),
    api.Sync(halo_every=4, mode="async", sweeps_per_launch=4),
]


@pytest.mark.parametrize("sync", EX_POLICIES,
                         ids=lambda s: f"k{s.halo_every}-{s.mode}"
                                       f"-L{s.sweeps_per_launch}")
def test_one_shard_fused_exchange_bit_exact(sync):
    g = make_chimera(3, 2, masked_cells=((1, 1),))
    mesh = jax.make_mesh((1,), ("data",))
    mach = PBitMachine.create(g, jax.random.PRNGKey(0), HardwareConfig(),
                              noise="counter", backend="sparse")
    B, S = 8, 8
    ses0 = api.Session(mach.sampler_spec(chains=B))
    sp = mach.sampler_spec(chains=B, mesh=mesh, interpret=True,
                           partition=api.Partition(rows="data"), sync=sync)
    ses1 = api.Session(sp.replace(backend="fused_sparse"))
    assert ses1.backend == "fused_sparse"
    rng = np.random.default_rng(1)
    chip = ses0.program_edges(
        jnp.asarray(rng.integers(-50, 50, g.n_edges), jnp.int32),
        jnp.asarray(rng.integers(-10, 10, g.n_nodes), jnp.int32))
    m0 = ses0.random_spins(jax.random.PRNGKey(2))
    ns = ses0.noise_state(jax.random.PRNGKey(3))
    betas = jnp.linspace(0.3, 1.5, S)
    a = ses0.sample(chip, m0, ns, betas)
    b = ses1.sample(chip, m0, ns, betas)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
    for x, y in zip(ses0.stats(chip, m0, ns, 8, 2),
                    ses1.stats(chip, m0, ns, 8, 2)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# forced 2-device host: the acceptance contracts
# ---------------------------------------------------------------------------
def test_two_device_fused_exchange_k1_bit_exact():
    """Fused kernel-resident exchange under Sync(halo_every=1, barrier)
    == the single-device Session bit for bit — spins, noise state, AND
    moments — including a chained program stream (two programs through
    sample_program on the same executable)."""
    rec = _run_forced("""
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro import api
    from repro.core.cd import PBitMachine
    from repro.core.chimera import make_chimera
    from repro.core.hardware import HardwareConfig

    g = make_chimera(2, 2)
    mesh = jax.make_mesh((2,), ("data",))
    mach = PBitMachine.create(g, jax.random.PRNGKey(0), HardwareConfig(),
                              noise="counter", backend="sparse")
    B = 8
    ses0 = api.Session(mach.sampler_spec(chains=B))
    sp = mach.sampler_spec(chains=B, mesh=mesh, interpret=True,
                           partition=api.Partition(rows="data"),
                           sync=api.Sync(halo_every=1, sweeps_per_launch=4))
    ses1 = api.Session(sp.replace(backend="fused_sparse"))
    rng = np.random.default_rng(5)
    codes = jnp.asarray(rng.integers(-60, 60, g.n_edges), jnp.int32)
    h = jnp.asarray(rng.integers(-15, 15, g.n_nodes), jnp.int32)
    chip = ses0.program_edges(codes, h)
    m0 = ses0.random_spins(jax.random.PRNGKey(2))
    ns = ses0.noise_state(jax.random.PRNGKey(3))
    betas = jnp.linspace(0.3, 1.5, 8)

    rec = {"backend": ses1.backend}
    a, b = ses0.sample(chip, m0, ns, betas), ses1.sample(chip, m0, ns, betas)
    rec["spins"] = bool(np.array_equal(np.asarray(a[0]), np.asarray(b[0])))
    rec["noise"] = bool(np.array_equal(np.asarray(a[1]), np.asarray(b[1])))
    sa, sb = ses0.stats(chip, m0, ns, 8, 2), ses1.stats(chip, m0, ns, 8, 2)
    rec["moments"] = bool(all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(sa, sb)))

    # chained program stream: two programs back to back, state threaded
    rng2 = np.random.default_rng(9)
    codes2 = jnp.asarray(rng2.integers(-60, 60, g.n_edges), jnp.int32)
    h2 = jnp.asarray(rng2.integers(-15, 15, g.n_nodes), jnp.int32)
    ok = True
    m_a, ns_a, m_b, ns_b = m0, ns, m0, ns
    for J, hh in ((codes, h), (codes2, h2)):
        m_a, ns_a, _ = ses0.sample_program(
            ses0.make_program(J, hh), m_a, ns_a, betas)
        m_b, ns_b, _ = ses1.sample_program(
            ses1.make_program(J, hh), m_b, ns_b, betas)
        ok = ok and np.array_equal(np.asarray(m_a), np.asarray(m_b)) \
            and np.array_equal(np.asarray(ns_a), np.asarray(ns_b))
    rec["program_chain"] = bool(ok)
    print(json.dumps(rec))
    """, 2)
    assert rec["backend"] == "fused_sparse"
    assert rec["spins"] and rec["noise"] and rec["moments"]
    assert rec["program_chain"]


def test_two_device_fused_exchange_relaxed_matches_segment_scan():
    """Relaxed policies (halo_every=k barrier, async) through the
    kernel-owned exchange == the existing sparse segment-scan engine bit
    for bit under the same seeds (spins and noise state)."""
    rec = _run_forced("""
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro import api
    from repro.core.cd import PBitMachine
    from repro.core.chimera import make_chimera
    from repro.core.hardware import HardwareConfig

    g = make_chimera(2, 2)
    mesh = jax.make_mesh((2,), ("data",))
    mach = PBitMachine.create(g, jax.random.PRNGKey(0), HardwareConfig(),
                              noise="counter", backend="sparse")
    B = 8
    ses0 = api.Session(mach.sampler_spec(chains=B))
    rng = np.random.default_rng(5)
    chip = ses0.program_edges(
        jnp.asarray(rng.integers(-60, 60, g.n_edges), jnp.int32),
        jnp.asarray(rng.integers(-15, 15, g.n_nodes), jnp.int32))
    m0 = ses0.random_spins(jax.random.PRNGKey(2))
    ns = ses0.noise_state(jax.random.PRNGKey(3))
    betas = jnp.linspace(0.3, 1.5, 8)

    def run(sync, backend):
        sp = mach.sampler_spec(chains=B, mesh=mesh, interpret=True,
                               partition=api.Partition(rows="data"),
                               sync=sync)
        return api.Session(sp.replace(backend=backend)).sample(
            chip, m0, ns, betas)

    rec = {}
    for name, sync in (
            ("k4_barrier", api.Sync(halo_every=4, sweeps_per_launch=4)),
            ("k4_async", api.Sync(halo_every=4, mode="async",
                                  sweeps_per_launch=4)),
            ("k2_barrier", api.Sync(halo_every=2, sweeps_per_launch=2))):
        sc = run(sync, "sparse")
        fu = run(sync, "fused_sparse")
        rec[name] = bool(
            np.array_equal(np.asarray(sc[0]), np.asarray(fu[0]))
            and np.array_equal(np.asarray(sc[1]), np.asarray(fu[1])))
    print(json.dumps(rec))
    """, 2)
    assert rec["k4_barrier"]
    assert rec["k4_async"]
    assert rec["k2_barrier"]


# ---------------------------------------------------------------------------
# the ICI napkin model's latency term
# ---------------------------------------------------------------------------
def test_halo_napkin_latency_term():
    from repro.launch.mesh import ICI_BW, ICI_LAT_S, halo_vs_hbm_seconds

    halo, hbm = 4096, 10 * 2 ** 20
    base = halo_vs_hbm_seconds(halo, hbm)
    assert base["ici_latency_s"] == 0.0
    assert base["ici_latency_share"] == 0.0
    assert base["ici_s"] == pytest.approx(halo / ICI_BW)
    two = halo_vs_hbm_seconds(halo, hbm, exchanges=2.0)
    assert two["ici_latency_s"] == pytest.approx(2.0 * ICI_LAT_S)
    assert two["ici_s"] == pytest.approx(halo / ICI_BW + 2.0 * ICI_LAT_S)
    assert 0.0 < two["ici_latency_share"] < 1.0
    # small halos are latency-bound: the fixed cost dominates the wire
    # time — exactly what the kernel-resident exchange amortizes
    small = halo_vs_hbm_seconds(128, hbm, exchanges=2.0)
    assert small["ici_latency_share"] > 0.9
    assert small["ici_over_hbm"] > base["ici_over_hbm"]
