"""Runtime weight streaming: the program as operand, not constant.

An `api.Program` must be invisible to the physics: sampling through
`Session.sample_program` (chip programmed *inside* the jit from runtime
codes) has to be bit-identical to programming the chip eagerly and
calling `Session.sample`, for every backend and noise kind — and swapping
programs must never retrace.  The fleet axis (`sample_fleet`,
`make_cd_fleet_step`) vmaps that operand: a stacked K-program batch
through one executable must match K sequential single-program calls bit
for bit (fused backends demote to their scan siblings under vmap).  The
double-buffered upload kernel (`sweep_sparse_stream_pallas`) must run the
CURRENT program exactly as the plain resident kernel while staging the
NEXT program unchanged.  Multi-device cases run in subprocesses with a
forced host platform (XLA_FLAGS must be set before jax initializes).
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core.cd import CDConfig, PBitMachine
from repro.core.chimera import make_chimera
from repro.core.hardware import sample_mismatch_sparse
from repro.kernels.sweep_fused import (
    sweep_sparse_pallas,
    sweep_sparse_stream_pallas,
)

ROOT = Path(__file__).resolve().parent.parent
SUBPROC_ENV = {"PYTHONPATH": f"{ROOT}/src", "PATH": "/usr/bin:/bin",
               "HOME": "/root", "JAX_PLATFORMS": "cpu"}


def _codes(g, seed):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.integers(-60, 60, g.n_edges), jnp.int32),
            jnp.asarray(rng.integers(-15, 15, g.n_nodes), jnp.int32))


def _machine(backend, noise, seed=0, rows=2, cols=2):
    g = make_chimera(rows, cols)
    sparse = backend in ("sparse", "fused_sparse")
    return g, PBitMachine.create(g, jax.random.PRNGKey(seed), sparse=sparse,
                                 noise=noise, backend=backend)


def _session(mach, chains=4):
    return api.Session(mach.sampler_spec(chains=chains, interpret=True))


# ---------------------------------------------------------------------------
# operand == constant, per backend x noise
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend,noise", [
    ("ref", "philox"), ("ref", "counter"), ("ref", "lfsr"),
    ("sparse", "counter"), ("fused", "counter"),
    ("fused_sparse", "counter"),
])
def test_program_operand_matches_constant(backend, noise):
    """sample_program == program_edges + sample, bit for bit; a second
    program reuses the same executable (zero retraces on a value swap)."""
    g, mach = _machine(backend, noise)
    ses = _session(mach)
    m0 = ses.random_spins(jax.random.PRNGKey(2))
    ns = ses.noise_state(jax.random.PRNGKey(3))
    betas = jnp.linspace(0.3, 1.5, 5)
    for seed in (1, 2):  # two programs, one executable
        J, h = _codes(g, seed)
        m_c, ns_c, _ = ses.sample(ses.program_edges(J, h), m0, ns, betas)
        m_o, ns_o, _ = ses.sample_program(ses.make_program(J, h), m0, ns,
                                          betas)
        np.testing.assert_array_equal(np.asarray(m_o), np.asarray(m_c))
        np.testing.assert_array_equal(np.asarray(ns_o), np.asarray(ns_c))
    fn = ses._fn(("sample_program", False), ses._build_sample_program, False)
    assert fn._cache_size() == 1, "program value swap must not retrace"


def test_program_collect_and_program_borne_betas():
    """collect=True trajectories match, and a program-borne schedule is
    honored (explicit betas arg still wins)."""
    g, mach = _machine("ref", "counter")
    ses = _session(mach)
    J, h = _codes(g, 4)
    chip = ses.program_edges(J, h)
    m0 = ses.random_spins(jax.random.PRNGKey(2))
    ns = ses.noise_state(jax.random.PRNGKey(3))
    betas = jnp.linspace(0.2, 1.2, 4)
    a = ses.sample(chip, m0, ns, betas, collect=True)
    prog = ses.make_program(J, h, betas=betas)
    b = ses.sample_program(prog, m0, ns, collect=True)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    override = jnp.linspace(0.5, 0.9, 4)
    m_ov, _, _ = ses.sample_program(prog, m0, ns, override)
    m_ex, _, _ = ses.sample(chip, m0, ns, override)
    np.testing.assert_array_equal(np.asarray(m_ov), np.asarray(m_ex))


def test_program_clamps_match_sample_clamps():
    """Clamps riding in the Program == clamps passed to sample."""
    g, mach = _machine("sparse", "counter")
    ses = _session(mach)
    J, h = _codes(g, 5)
    chip = ses.program_edges(J, h)
    B = 4
    m0 = ses.random_spins(jax.random.PRNGKey(2))
    ns = ses.noise_state(jax.random.PRNGKey(3))
    betas = jnp.linspace(0.3, 1.5, 5)
    cm = jnp.zeros((g.n_nodes,), bool).at[jnp.array([0, 7, 13])].set(True)
    cv = jnp.tile(jnp.asarray([[-1.0]]), (B, g.n_nodes))
    m_c, ns_c, _ = ses.sample(chip, m0, ns, betas, clamp_mask=cm,
                              clamp_values=cv)
    prog = ses.make_program(J, h, clamp_mask=cm, clamp_values=cv)
    m_o, ns_o, _ = ses.sample_program(prog, m0, ns, betas)
    np.testing.assert_array_equal(np.asarray(m_o), np.asarray(m_c))
    np.testing.assert_array_equal(np.asarray(ns_o), np.asarray(ns_c))
    assert bool(jnp.all(m_o[:, jnp.array([0, 7, 13])] == -1.0))


def test_program_mismatch_operand_matches_baked():
    """A mismatch draw streamed through the Program equals a machine with
    that draw baked into its spec — and both specs share a fingerprint
    (one executable serves every chip instance of the SKU)."""
    g = make_chimera(2, 2)
    mach_a = PBitMachine.create(g, jax.random.PRNGKey(0), sparse=True,
                                noise="counter")
    mach_b = PBitMachine.create(g, jax.random.PRNGKey(1), sparse=True,
                                noise="counter")
    ses_a, ses_b = _session(mach_a), _session(mach_b)
    assert ses_a.spec.fingerprint() == ses_b.spec.fingerprint()
    J, h = _codes(g, 6)
    m0 = ses_a.random_spins(jax.random.PRNGKey(2))
    ns = ses_a.noise_state(jax.random.PRNGKey(3))
    betas = jnp.linspace(0.3, 1.5, 5)
    m_baked, ns_baked, _ = ses_b.sample(ses_b.program_edges(J, h), m0, ns,
                                        betas)
    prog = ses_a.make_program(J, h, mismatch=mach_b.mismatch)
    m_op, ns_op, _ = ses_a.sample_program(prog, m0, ns, betas)
    np.testing.assert_array_equal(np.asarray(m_op), np.asarray(m_baked))
    np.testing.assert_array_equal(np.asarray(ns_op), np.asarray(ns_baked))


# ---------------------------------------------------------------------------
# the fleet axis (acceptance: vmapped K == K sequential, bit-identical)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend,noise", [
    ("sparse", "counter"), ("ref", "philox"), ("fused_sparse", "counter"),
])
def test_fleet_k8_matches_sequential(backend, noise):
    g, mach = _machine(backend, noise)
    ses = _session(mach)
    K, betas = 8, jnp.linspace(0.3, 1.5, 4)
    progs = [ses.make_program(*_codes(g, 10 + k)) for k in range(K)]
    m0 = jnp.stack([ses.random_spins(jax.random.PRNGKey(20 + k))
                    for k in range(K)])
    ns = jnp.stack([ses.noise_state(jax.random.PRNGKey(40 + k))
                    for k in range(K)])
    m_f, ns_f, _ = ses.sample_fleet(api.stack_programs(progs), m0, ns,
                                    betas)
    for k in range(K):
        m_k, ns_k, _ = ses.sample_program(progs[k], m0[k], ns[k], betas)
        np.testing.assert_array_equal(np.asarray(m_f[k]), np.asarray(m_k))
        np.testing.assert_array_equal(np.asarray(ns_f[k]), np.asarray(ns_k))


def test_fleet_mismatch_axis_matches_standalone_machines():
    """fleet_mismatch draw k == a standalone machine built from subkey k;
    the K-chip fleet equals per-machine sampling of one shared program."""
    g = make_chimera(2, 2)
    mach = PBitMachine.create(g, jax.random.PRNGKey(0), sparse=True,
                              noise="counter")
    ses = _session(mach)
    K = 3
    draws = mach.fleet_mismatch(jax.random.PRNGKey(7), K)
    J, h = _codes(g, 8)
    betas = jnp.linspace(0.3, 1.5, 4)
    progs = api.stack_programs([
        ses.make_program(J, h,
                         mismatch=jax.tree_util.tree_map(lambda x: x[k],
                                                         draws))
        for k in range(K)])
    m0 = jnp.stack([ses.random_spins(jax.random.PRNGKey(2))] * K)
    ns = jnp.stack([ses.noise_state(jax.random.PRNGKey(3))] * K)
    m_f, _, _ = ses.sample_fleet(progs, m0, ns, betas)
    subkeys = jax.random.split(jax.random.PRNGKey(7), K)
    for k in range(K):
        mk = PBitMachine.create(g, subkeys[k], sparse=True, noise="counter")
        sk = _session(mk)
        m_k, _, _ = sk.sample(sk.program_edges(J, h), m0[k], ns[k], betas)
        np.testing.assert_array_equal(np.asarray(m_f[k]), np.asarray(m_k))


def test_fleet_cd_matches_sequential():
    """K=2 hardware-aware CD fleet == two sequential per-chip epochs."""
    g = make_chimera(1, 2)
    mach = PBitMachine.create(g, jax.random.PRNGKey(0), sparse=True,
                              noise="counter")
    cfg = CDConfig(chains=4, cd_k=2, pos_sweeps=2, burn_in=1, momentum=0.5)
    ses = mach.session(chains=cfg.chains)
    vis = np.arange(6)
    K = 2
    mms = mach.fleet_mismatch(jax.random.PRNGKey(5), K)
    rng = np.random.default_rng(0)
    Jm = jnp.asarray(rng.normal(size=(K, g.n_edges)) * 8, jnp.float32)
    hm = jnp.asarray(rng.normal(size=(K, g.n_nodes)) * 2, jnp.float32)
    data = jnp.asarray(rng.integers(0, 2, (cfg.chains, len(vis))) * 2 - 1,
                       jnp.float32)
    m0 = jnp.stack([ses.random_spins(jax.random.PRNGKey(30 + k))
                    for k in range(K)])
    ns = jnp.stack([ses.noise_state(jax.random.PRNGKey(50 + k))
                    for k in range(K)])
    vel = (jnp.zeros((K, g.n_edges)), jnp.zeros((K, g.n_nodes)))
    fleet = ses.make_cd_fleet_step(cfg, vis)
    out_f = fleet(mms, Jm, hm, data, m0, ns, vel)
    step = ses.make_cd_step(cfg, vis).with_mismatch
    for k in range(K):
        mm_k = jax.tree_util.tree_map(lambda x: x[k], mms)
        out_k = step(mm_k, Jm[k], hm[k], data, m0[k], ns[k],
                     (vel[0][k], vel[1][k]))
        for f, s in zip(out_f[:5], out_k[:5]):
            for x, y in zip(jax.tree_util.tree_leaves(f),
                            jax.tree_util.tree_leaves(s)):
                np.testing.assert_array_equal(np.asarray(x[k]),
                                              np.asarray(y))
        for name in out_k[5]:
            np.testing.assert_array_equal(np.asarray(out_f[5][name][k]),
                                          np.asarray(out_k[5][name]))


# ---------------------------------------------------------------------------
# double-buffered program upload kernel
# ---------------------------------------------------------------------------
def test_stream_kernel_chain_matches_serialized():
    """A 4-program chain through `sweep_sparse_stream_pallas` (each launch
    runs program i while staging program i+1) is bit-identical to four
    serialized `sweep_sparse_pallas` launches, and every staged output is
    exactly the next program's weights."""
    g = make_chimera(2, 2)
    mach = PBitMachine.create(g, jax.random.PRNGKey(0), sparse=True,
                              noise="counter")
    ses = _session(mach, chains=6)
    chips = [ses.program_edges(*_codes(g, 60 + i)) for i in range(4)]
    c0 = chips[0]
    masks = (jnp.asarray(g.color == 0), jnp.asarray(g.color == 1))
    m0 = ses.random_spins(jax.random.PRNGKey(2))
    betas = jnp.broadcast_to(jnp.linspace(0.3, 1.5, 3)[:, None], (3, 6))
    ns0 = jnp.asarray([42, 0], jnp.uint32)

    def plain(chip, m, ns):
        return sweep_sparse_pallas(
            m, c0.nbr_idx, chip.nbr_w, chip.h, chip.tanh_gain,
            chip.tanh_offset, chip.rand_gain, chip.comp_offset, *masks,
            betas, ns, noise_mode="counter", block_b=8, interpret=True)

    m_s, ns_s = m0, ns0
    for chip in chips:
        m_s, ns_s = plain(chip, m_s, ns_s)

    m_d, ns_d = m0, ns0
    w, h = chips[0].nbr_w, chips[0].h
    for i, chip in enumerate(chips):
        nxt = chips[(i + 1) % 4]
        m_d, ns_d, w_next, h_next = sweep_sparse_stream_pallas(
            m_d, c0.nbr_idx, w, h, chip.tanh_gain, chip.tanh_offset,
            chip.rand_gain, chip.comp_offset, *masks, betas, ns_d,
            nxt.nbr_w, nxt.h, block_b=8, interpret=True)
        np.testing.assert_array_equal(np.asarray(w_next),
                                      np.asarray(nxt.nbr_w, np.float32))
        np.testing.assert_array_equal(np.asarray(h_next),
                                      np.asarray(nxt.h, np.float32))
        w, h = w_next, h_next
    np.testing.assert_array_equal(np.asarray(m_d), np.asarray(m_s))
    np.testing.assert_array_equal(np.asarray(ns_d), np.asarray(ns_s))


# ---------------------------------------------------------------------------
# construction / fingerprint contracts
# ---------------------------------------------------------------------------
def test_make_program_validation():
    g, mach = _machine("sparse", "counter")
    ses = _session(mach)
    J, h = _codes(g, 9)
    with pytest.raises(ValueError, match="edge-list"):
        ses.make_program(jnp.zeros((g.n_nodes,), jnp.int32), h)
    with pytest.raises(ValueError, match="h_codes"):
        ses.make_program(J, jnp.zeros((g.n_edges,), jnp.int32))
    with pytest.raises(ValueError, match="clamp_values"):
        ses.make_program(J, h, clamp_values=jnp.zeros((4, g.n_nodes)))
    dense = PBitMachine.create(g, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="mismatch type"):
        ses.make_program(J, h, mismatch=dense.mismatch)


def test_stack_programs_requires_same_structure():
    g, mach = _machine("sparse", "counter")
    ses = _session(mach)
    J, h = _codes(g, 9)
    a = ses.make_program(J, h)
    b = ses.make_program(J, h, betas=jnp.linspace(0.3, 1.5, 4))
    with pytest.raises(ValueError, match="structure"):
        api.stack_programs([a, b])
    with pytest.raises(ValueError, match="at least one"):
        api.stack_programs([])


def test_fingerprint_is_shape_bucket_key():
    """Fingerprint ignores mismatch *values* (two chip instances share an
    executable) but still keys on mismatch structure and graph shape."""
    g = make_chimera(2, 2)
    a = PBitMachine.create(g, jax.random.PRNGKey(0), sparse=True,
                           noise="counter").sampler_spec(chains=4)
    b = PBitMachine.create(g, jax.random.PRNGKey(1), sparse=True,
                           noise="counter").sampler_spec(chains=4)
    assert a.fingerprint() == b.fingerprint()
    other = PBitMachine.create(make_chimera(1, 2), jax.random.PRNGKey(0),
                               sparse=True,
                               noise="counter").sampler_spec(chains=4)
    assert a.fingerprint() != other.fingerprint()


# ---------------------------------------------------------------------------
# forced multi-device host platform (subprocess)
# ---------------------------------------------------------------------------
def _run_forced(script: str, n_dev: int, timeout: int = 540) -> dict:
    head = (f"import os\nos.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={n_dev}'\n")
    out = subprocess.run(
        [sys.executable, "-c", head + textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout, env=SUBPROC_ENV,
        cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_two_device_sharded_program_operand():
    """Program-as-operand through the sharded engine: a 2-device rows
    partition's sample_program == its own sample(chip) == the
    single-device sample_program, for two programs on one executable."""
    rec = _run_forced("""
    import jax, json
    import jax.numpy as jnp
    import numpy as np
    from repro import api
    from repro.core.cd import PBitMachine
    from repro.core.chimera import make_chimera

    g = make_chimera(2, 2)
    mach = PBitMachine.create(g, jax.random.PRNGKey(0), sparse=True,
                              noise="counter")
    mesh = jax.make_mesh((2,), ("data",))
    ses0 = api.Session(mach.sampler_spec(chains=4, interpret=True))
    ses1 = api.Session(mach.sampler_spec(
        chains=4, interpret=True, mesh=mesh,
        partition=api.Partition(rows="data")))
    m0 = ses0.random_spins(jax.random.PRNGKey(2))
    ns = ses0.noise_state(jax.random.PRNGKey(3))
    betas = jnp.linspace(0.3, 1.5, 5)
    checks = 0
    rng = np.random.default_rng(1)
    for seed in (1, 2):
        rng = np.random.default_rng(seed)
        J = jnp.asarray(rng.integers(-60, 60, g.n_edges), jnp.int32)
        h = jnp.asarray(rng.integers(-15, 15, g.n_nodes), jnp.int32)
        prog = ses1.make_program(J, h)
        m_sh, ns_sh, _ = ses1.sample_program(prog, m0, ns, betas)
        m_c, ns_c, _ = ses1.sample(ses1.program_edges(J, h), m0, ns, betas)
        np.testing.assert_array_equal(np.asarray(m_sh), np.asarray(m_c))
        np.testing.assert_array_equal(np.asarray(ns_sh), np.asarray(ns_c))
        m_1d, ns_1d, _ = ses0.sample_program(
            ses0.make_program(J, h), m0, ns, betas)
        np.testing.assert_array_equal(np.asarray(m_sh), np.asarray(m_1d))
        np.testing.assert_array_equal(np.asarray(ns_sh), np.asarray(ns_1d))
        checks += 1
    fn = ses1._fn(("sample_program", False),
                  ses1._build_sample_program, False)
    print(json.dumps({"checks": checks,
                      "cache_size": fn._cache_size()}))
    """, 2)
    assert rec["checks"] == 2
    assert rec["cache_size"] == 1
