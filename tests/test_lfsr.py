import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lfsr


def test_states_never_zero_and_advance():
    key = jax.random.PRNGKey(0)
    s = lfsr.seed_states(key, (64,))
    assert (np.asarray(s) != 0).all()
    s2 = lfsr.lfsr_step_n(s, 8)
    assert (np.asarray(s2) != np.asarray(s)).all()


def test_byte_reversal_table():
    b = jnp.arange(256, dtype=jnp.uint32)
    r = lfsr.reverse_bytes_bits(b)
    r2 = lfsr.reverse_bytes_bits(r)
    assert (np.asarray(r2) == np.asarray(b)).all()
    assert int(r[0b00000001]) == 0b10000000


def test_uniformity_chi2():
    """Bytes from the decimated LFSR should be ~uniform (chip's RNG DAC)."""
    s = lfsr.seed_states(jax.random.PRNGKey(1), (128,))
    counts = np.zeros(256)
    for _ in range(200):
        s, v, h = lfsr.next_uniforms(s, decimation=8)
        by = np.asarray((v * 128.0 + 127.5)).astype(np.int64).reshape(-1)
        np.add.at(counts, by, 1)
    n = counts.sum()
    expected = n / 256
    chi2 = ((counts - expected) ** 2 / expected).sum()
    # dof=255; mean 255, sd ~22.6 — allow 6 sigma
    assert chi2 < 255 + 6 * 22.6, chi2


def test_reversed_sequence_correlation_benign():
    """Paper: horizontal nodes reuse bit-reversed bytes; claims no
    degradation.  Check the two streams are weakly correlated."""
    s = lfsr.seed_states(jax.random.PRNGKey(2), (256,))
    vs, hs = [], []
    for _ in range(100):
        s, v, h = lfsr.next_uniforms(s)
        vs.append(np.asarray(v).reshape(-1))
        hs.append(np.asarray(h).reshape(-1))
    v = np.concatenate(vs)
    h = np.concatenate(hs)
    corr = np.corrcoef(v, h)[0, 1]
    assert abs(corr) < 0.05, corr


def test_period_smoke():
    """A maximal 32-bit Galois LFSR must not cycle within 10^4 steps."""
    s = jnp.asarray([jnp.uint32(0xACE1)])
    seen = set()
    for _ in range(10_000):
        s = lfsr.lfsr_step(s)
        v = int(s[0])
        assert v not in seen
        seen.add(v)
