"""Hypothesis shim: real hypothesis when installed, deterministic fallback
otherwise.

The container images this repo targets do not all ship `hypothesis`; a hard
import used to kill collection of the whole suite.  Test modules import
``given``/``settings``/``st`` from here instead.  The fallback draws a fixed
number of pseudo-random examples from the same strategy surface the tests
use (integers / booleans / floats / sampled_from), seeded per-test so runs
are reproducible.
"""
from __future__ import annotations

import random

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def sample(self, rng: random.Random):
            return self._draw(rng)

    class st:  # noqa: N801 - mirrors `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: r.random() < 0.5)

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(values):
            vals = list(values)
            return _Strategy(lambda r: vals[r.randrange(len(vals))])

    def settings(max_examples: int = 10, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            # NOT functools.wraps: pytest must see a zero-arg signature or it
            # would treat the strategy parameters as fixtures
            def runner():
                n = getattr(runner, "_max_examples", 10)
                rng = random.Random(fn.__name__)
                for _ in range(n):
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(**drawn)

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner

        return deco
