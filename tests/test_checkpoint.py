"""Crash-consistency contract of repro.checkpoint.

The format promise (checkpoint.py docstring): writes are atomic
(tmp dir + os.replace), readers only trust directories carrying the
``.complete`` marker, bf16 survives the npz round-trip, and the async
writer overlaps with training without ever exposing a torn checkpoint.
"""
from __future__ import annotations

import json

import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.normal(size=(7, 3)).astype(np.float32),
        "step_key": np.asarray([seed, seed + 1], np.uint32),
        "nested": {"v": rng.normal(size=(5,)).astype(np.float32)},
    }


def test_save_load_round_trip(tmp_path):
    tree = _tree(1)
    path = ckpt.save(tmp_path, 12, tree, extra={"kl": [0.5, 0.4]})
    assert path.name == "step_000000012"
    step, got, extra = ckpt.load(tmp_path, target=_tree(99))
    assert step == 12
    assert extra == {"kl": [0.5, 0.4]}
    for k in ("w", "step_key"):
        np.testing.assert_array_equal(np.asarray(got[k]), tree[k])
    np.testing.assert_array_equal(np.asarray(got["nested"]["v"]),
                                  tree["nested"]["v"])


def test_marker_honored(tmp_path):
    """latest_step/load only trust directories with the commit marker."""
    ckpt.save(tmp_path, 3, _tree())
    ckpt.save(tmp_path, 7, _tree())
    assert ckpt.latest_step(tmp_path) == 7
    # simulate a writer killed after os.replace but before... actually the
    # marker is written INSIDE the tmp dir pre-replace, so a committed dir
    # always has it; strip it to model a corrupted/foreign directory
    (tmp_path / "step_000000007" / ".complete").unlink()
    assert ckpt.latest_step(tmp_path) == 3
    step, _, _ = ckpt.load(tmp_path, target=_tree())
    assert step == 3
    with pytest.raises(FileNotFoundError):
        ckpt.load(tmp_path, step=7, target=_tree())


def test_killed_mid_write_dir_ignored(tmp_path):
    """A writer killed mid-write leaves step_*.tmp — readers never see it."""
    ckpt.save(tmp_path, 5, _tree())
    # model a crash partway through serialization: tmp dir with partial
    # contents and no marker
    torn = tmp_path / "step_000000009.tmp"
    torn.mkdir()
    (torn / "arrays.npz").write_bytes(b"\x00partial")
    (torn / "meta.json").write_text(json.dumps({"step": 9}))
    assert ckpt.latest_step(tmp_path) == 5
    # and the next writer at the same step recovers: save() clears the
    # stale tmp dir and commits atomically
    ckpt.save(tmp_path, 9, _tree(2))
    assert ckpt.latest_step(tmp_path) == 9


def test_bf16_round_trip(tmp_path):
    jnp = pytest.importorskip("jax.numpy")
    tree = {"p": jnp.arange(16, dtype=jnp.bfloat16) / 7.0,
            "q": np.ones((3,), np.float32)}
    ckpt.save(tmp_path, 1, tree)
    _, raw, _ = ckpt.load(tmp_path)
    # stored as uint16 bits on disk; load() restores the bfloat16 view
    import ml_dtypes
    (bf16_key,) = [k for k, v in raw.items() if v.dtype == ml_dtypes.bfloat16]
    np.testing.assert_array_equal(
        raw[bf16_key].view(np.uint16),
        np.asarray(tree["p"]).view(np.uint16))
    # and through a typed target the dtype comes back as bfloat16
    _, typed, _ = ckpt.load(tmp_path, target=tree)
    assert np.asarray(typed["p"]).dtype == np.asarray(tree["p"]).dtype
    np.testing.assert_array_equal(np.asarray(typed["p"]).view(np.uint16),
                                  np.asarray(tree["p"]).view(np.uint16))


def test_async_checkpointer_overlap(tmp_path):
    """AsyncCheckpointer commits in the background; wait() surfaces errors
    and a second save blocks on (and therefore observes) the first."""
    ac = ckpt.AsyncCheckpointer(tmp_path, keep=2)
    for step in (1, 2, 3):
        ac.save(step, _tree(step))
    ac.wait()
    assert ckpt.latest_step(tmp_path) == 3
    # keep=2 garbage-collects the oldest committed step
    assert not (tmp_path / "step_000000001").exists()
    assert (tmp_path / "step_000000002").exists()
    # snapshot semantics for device arrays: the host copy is taken before
    # save() returns, so donating/overwriting the device value afterwards
    # must not change what gets committed
    jnp = pytest.importorskip("jax.numpy")
    dev = {"w": jnp.full((4,), 2.5, jnp.float32)}
    ac.save(4, dev)
    ac.wait()
    _, got, _ = ckpt.load(tmp_path, step=4)
    (key,) = got.keys()
    np.testing.assert_array_equal(np.asarray(got[key]),
                                  np.full((4,), 2.5, np.float32))


def test_async_checkpointer_error_propagates(tmp_path):
    ac = ckpt.AsyncCheckpointer(tmp_path / "file_in_the_way")
    (tmp_path / "file_in_the_way").write_text("not a directory")
    ac.save(1, _tree())
    with pytest.raises(Exception):
        ac.wait()
